"""AOT driver: lower every L2 operator x shape bucket to an HLO artifact.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension (0.5.1) rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out, default ``../artifacts``):

* ``<op>__<bucket>.hlo.txt`` — one per operator per row bucket,
* ``manifest.json`` — machine-readable index the rust runtime loads:
  operator name, bucket, artifact path, input/output shapes + dtypes.

Python runs ONCE at build time (``make artifacts``); the rust binary is
self-contained afterwards.

Usage: ``python -m compile.aot [--out DIR] [--only OP[,OP...]] [--buckets N,N]``
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model
from compile.shapes import JOIN_BUILD_BUCKET, NUM_GROUPS, ROW_BUCKETS


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_one(name: str, fn, in_specs) -> tuple[str, list[dict], list[dict]]:
    """Lower ``fn`` at ``in_specs``; return (hlo_text, in_meta, out_meta)."""
    lowered = jax.jit(fn).lower(*in_specs)
    out_shapes = jax.eval_shape(fn, *in_specs)
    if isinstance(out_shapes, (list, tuple)):
        outs = list(out_shapes)
    else:
        outs = [out_shapes]
    return (
        to_hlo_text(lowered),
        [_spec_json(s) for s in in_specs],
        [_spec_json(s) for s in outs],
    )


def build_all(out_dir: str, only: set[str] | None, buckets) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "format": 1,
        "num_groups": NUM_GROUPS,
        "join_build_bucket": JOIN_BUILD_BUCKET,
        "row_buckets": list(buckets),
        "artifacts": [],
    }
    smallest = min(buckets)
    for n in buckets:
        sigs = model.signatures(n, b=JOIN_BUILD_BUCKET)
        for name, (fn, in_specs) in sorted(sigs.items()):
            if only and name not in only:
                continue
            if name in model.GROUP_SPACE_OPS and n != smallest:
                continue  # group-space ops have no row dimension
            fname = f"{name}__n{n}.hlo.txt"
            path = os.path.join(out_dir, fname)
            hlo, in_meta, out_meta = lower_one(name, fn, in_specs)
            with open(path, "w") as fh:
                fh.write(hlo)
            manifest["artifacts"].append(
                {
                    "op": name,
                    "rows": n,
                    "file": fname,
                    "inputs": in_meta,
                    "outputs": out_meta,
                }
            )
            print(f"  {fname}: {len(hlo)} chars, {len(in_meta)} in / {len(out_meta)} out")
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default="", help="comma-separated operator subset")
    ap.add_argument(
        "--buckets",
        default=",".join(str(b) for b in ROW_BUCKETS),
        help="comma-separated row buckets",
    )
    args = ap.parse_args(argv)
    only = {s for s in args.only.split(",") if s} or None
    buckets = [int(b) for b in args.buckets.split(",") if b]

    manifest = build_all(args.out, only, buckets)
    man_path = os.path.join(args.out, "manifest.json")
    with open(man_path, "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + {man_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
