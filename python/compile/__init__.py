"""Build-time compile package (L1 pallas kernels + L2 jax operators + AOT).

Never imported at runtime: ``make artifacts`` runs :mod:`compile.aot` once
and the rust coordinator consumes only ``artifacts/*.hlo.txt`` +
``manifest.json`` from then on.
"""
