"""L2: query-operator compute graphs for LMStream's GPU path.

Each function here is one operator (or one fused operator pipeline) that
the rust coordinator can map to the "GPU" device. They operate over
fixed-shape columnar buffers — f32 data columns plus a 0/1 validity mask —
matching the padded layout the rust engine marshals (see
``rust/src/devices/gpu.rs``). The hot operators call the L1 pallas kernels
so both layers lower into the same HLO artifact.

Conventions shared with the rust runtime (encoded in the AOT manifest):

* all data columns are f32; group ids and permutations are i32,
* every function returns a tuple (lowered with ``return_tuple=True``; the
  rust side unpacks with ``Literal::to_tuple``),
* "scalar" parameters are shape (1,) f32 operands so they stay runtime
  inputs rather than baked constants,
* filtered-out rows are represented by ``valid == 0`` (columnar engines
  keep filtered data in place; compaction happens at shuffle boundaries on
  the rust side).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.filter_project import filter_project
from compile.kernels.topk import topk
from compile.kernels.window_agg import window_agg
from compile.kernels.window_assign import window_assign
from compile.shapes import NUM_GROUPS

# Top-of-the-order head size served by the CM1S ORDER BY kernel.
TOPK = 16

# Expand replication factors needed by the Table III windows:
# LR2S range30/slide10 -> 3, CM1S range60/slide10 -> 6, CM2S r60/s5 -> 12.
EXPAND_SLOTS = (3, 6, 12)

# A large key used to push invalid rows to the end of sort orders.
_SORT_PAD = jnp.float32(3.0e38) / 4


# --------------------------------------------------------------------------
# Filters (predicate -> new validity mask). Column-agnostic: rust passes
# whichever column the predicate references.


def filter_ge(keys, valid, thr):
    """valid_out = valid AND (keys >= thr)."""
    return ((keys >= thr[0]).astype(jnp.float32) * valid,)


def filter_lt(keys, valid, thr):
    """valid_out = valid AND (keys < thr)."""
    return ((keys < thr[0]).astype(jnp.float32) * valid,)


def filter_eq(keys, valid, thr):
    """valid_out = valid AND (keys == thr). Used for eventType == 1 (CM2S)."""
    return ((keys == thr[0]).astype(jnp.float32) * valid,)


def filter_band(keys, valid, lo, hi):
    """valid_out = valid AND (lo <= keys < hi). Window-range pruning."""
    keep = jnp.logical_and(keys >= lo[0], keys < hi[0]).astype(jnp.float32)
    return (keep * valid,)


# --------------------------------------------------------------------------
# Projections.


def project_affine(a, b, alpha, beta):
    """out = alpha*a + beta*b — the arithmetic-projection primitive."""
    return (alpha[0] * a + beta[0] * b,)


def project_scale(a, alpha):
    """out = alpha*a."""
    return (alpha[0] * a,)


def fused_filter_project(keys, a, b, valid, thr, alpha, beta):
    """Fused filter+project via the L1 pallas kernel (the SP fragment of
    the synthetic select-project-join query of Figs. 2/5)."""
    return filter_project(keys, a, b, valid, thr, alpha, beta)


# --------------------------------------------------------------------------
# Window aggregation (pallas hot-spot) and post-aggregation operators.


def window_aggregate(group_ids, values, valid):
    """Per-group (sum, count) via the L1 pallas kernel."""
    return window_agg(group_ids, values, valid)


def avg_having_lt(sums, counts, thr):
    """avgs = sums/counts; keep = (avg < thr) for non-empty groups.

    Implements ``HAVING (avgSpeed < 40.0)`` of LR2S over the window_agg
    output. Empty groups get avg 0 / keep 0.
    """
    safe = jnp.maximum(counts, 1.0)
    avgs = sums / safe
    nonempty = (counts > 0.0).astype(jnp.float32)
    keep = (avgs < thr[0]).astype(jnp.float32) * nonempty
    return avgs * nonempty, keep


def group_avg(sums, counts):
    """avgs per non-empty group (CM2S's AVG(cpu))."""
    safe = jnp.maximum(counts, 1.0)
    nonempty = (counts > 0.0).astype(jnp.float32)
    return (sums / safe * nonempty,)


def topk_groups(sums, counts):
    """Top-TOPK groups by aggregate value (CM1S's ORDER BY head) via the
    L1 pallas selection kernel."""
    return topk(sums, counts, k=TOPK)


def expand_assign(times, valid, rng, sld, *, slots):
    """Sliding-window instance assignment (Expand) via the L1 kernel."""
    return window_assign(times, valid, rng, sld, slots=slots)


def sort_groups_desc(sums, counts):
    """ORDER BY SUM(...) DESC over group aggregates (CM1S).

    Empty groups sort last. Returns (sorted sums, permutation i32).
    """
    nonempty = counts > 0.0
    sort_keys = jnp.where(nonempty, -sums, _SORT_PAD)
    perm = jnp.argsort(sort_keys).astype(jnp.int32)
    return sums[perm], perm


# --------------------------------------------------------------------------
# Sort / join.


def sort_perm(keys, valid):
    """Ascending stable sort permutation; invalid rows pushed to the end."""
    masked = keys + (1.0 - valid) * _SORT_PAD
    return (jnp.argsort(masked, stable=True).astype(jnp.int32),)


def apply_perm3(a, b, c, perm):
    """Gather three columns through a sort permutation."""
    return a[perm], b[perm], c[perm]


def join_probe(probe_keys, probe_valid, build_keys, build_valid):
    """Inner equi-join probe: first matching build index per probe row.

    The rust executor builds windows (the LR1 self-join's build side) into
    fixed JOIN_BUILD_BUCKET buffers and chunks large probe sides, so a
    single artifact shape suffices.

    Returns (idx i32[N] — build index or -1, found f32[N]).
    """
    eq = probe_keys[:, None] == build_keys[None, :]
    eq = jnp.logical_and(eq, build_valid[None, :] > 0.0)
    found = jnp.any(eq, axis=1)
    idx = jnp.argmax(eq, axis=1).astype(jnp.int32)
    found_f = found.astype(jnp.float32) * probe_valid
    idx = jnp.where(found_f > 0.0, idx, -1)
    return idx, found_f


# --------------------------------------------------------------------------
# Fused workload pipelines (one artifact per pipeline per bucket): these are
# what LMStream actually dispatches when a whole GPU-resident chain is
# planned onto the device — no per-operator host round-trips (§Perf, L2).


def lr2s_pipeline(seg_gid, speeds, valid, thr):
    """LR2S: AVG(speed) GROUP BY segment window HAVING avg < thr."""
    sums, counts = window_agg(seg_gid, speeds, valid)
    avgs, keep = avg_having_lt(sums, counts, thr)
    return avgs, keep


def cm1s_pipeline(cat_gid, cpus, valid):
    """CM1S: SUM(cpu) GROUP BY category ORDER BY SUM(cpu)."""
    sums, counts = window_agg(cat_gid, cpus, valid)
    sorted_sums, perm = sort_groups_desc(sums, counts)
    return sorted_sums, perm


def cm2s_pipeline(job_gid, cpus, events, valid, ev_type):
    """CM2S: AVG(cpu) WHERE eventType == ev GROUP BY jobId."""
    (valid2,) = filter_eq(events, valid, ev_type)
    sums, counts = window_agg(job_gid, cpus, valid2)
    (avgs,) = group_avg(sums, counts)
    return avgs, counts


def spj_pipeline(keys, a, b, valid, probe, build_keys, build_valid, thr, alpha, beta):
    """Synthetic select-project-join (Figs. 2/5): fused SP + join probe."""
    out, valid2 = filter_project(keys, a, b, valid, thr, alpha, beta)
    idx, found = join_probe(probe, valid2, build_keys, build_valid)
    return out, idx, found


# --------------------------------------------------------------------------
# Signature registry consumed by aot.py. Each entry: operator name ->
# (callable, [input spec], bucketed-dims description). Input specs are
# templates instantiated per row bucket N (and fixed G / B dims).

F32 = jnp.float32
I32 = jnp.int32


def signatures(n: int, g: int = NUM_GROUPS, b: int = 4096):
    """Instantiate all AOT operator signatures for row bucket ``n``."""
    f = lambda *shape: jax.ShapeDtypeStruct(shape, F32)
    i = lambda *shape: jax.ShapeDtypeStruct(shape, I32)
    scalar = f(1)
    sigs = {
        "filter_ge": (filter_ge, [f(n), f(n), scalar]),
        "filter_lt": (filter_lt, [f(n), f(n), scalar]),
        "filter_eq": (filter_eq, [f(n), f(n), scalar]),
        "filter_band": (filter_band, [f(n), f(n), scalar, scalar]),
        "project_affine": (project_affine, [f(n), f(n), scalar, scalar]),
        "project_scale": (project_scale, [f(n), scalar]),
        "fused_filter_project": (
            fused_filter_project,
            [f(n), f(n), f(n), f(n), scalar, scalar, scalar],
        ),
        "window_aggregate": (window_aggregate, [i(n), f(n), f(n)]),
        "avg_having_lt": (avg_having_lt, [f(g), f(g), scalar]),
        "group_avg": (group_avg, [f(g), f(g)]),
        "sort_groups_desc": (sort_groups_desc, [f(g), f(g)]),
        "sort_perm": (sort_perm, [f(n), f(n)]),
        "apply_perm3": (apply_perm3, [f(n), f(n), f(n), i(n)]),
        "join_probe": (join_probe, [f(n), f(n), f(b), f(b)]),
        "lr2s_pipeline": (lr2s_pipeline, [i(n), f(n), f(n), scalar]),
        "cm1s_pipeline": (cm1s_pipeline, [i(n), f(n), f(n)]),
        "cm2s_pipeline": (cm2s_pipeline, [i(n), f(n), f(n), f(n), scalar]),
        "spj_pipeline": (
            spj_pipeline,
            [f(n), f(n), f(n), f(n), f(n), f(b), f(b), scalar, scalar, scalar],
        ),
        "topk_groups": (topk_groups, [f(g), f(g)]),
    }
    for slots in EXPAND_SLOTS:
        sigs[f"expand_assign_s{slots}"] = (
            functools.partial(expand_assign, slots=slots),
            [f(n), f(n), scalar, scalar],
        )
    return sigs


# Operators whose row dimension participates in bucketing. Aggregate-space
# operators (avg_having_lt, ...) have G-shaped inputs only and are emitted
# once (under the smallest bucket tag) to avoid duplicate artifacts.
GROUP_SPACE_OPS = frozenset(
    {"avg_having_lt", "group_avg", "sort_groups_desc", "topk_groups"}
)
