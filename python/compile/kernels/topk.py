"""L1 pallas kernel: top-k selection over group aggregates.

CM1S's ``ORDER BY SUM(cpu)`` only needs the ordered head of the per-group
sums (the dashboards the paper's motivation cites read the top
categories). A full sort is wasteful: this kernel runs k argmax+mask
rounds over a VMEM-resident copy of the aggregate vector — k*G work
instead of G*log(G) with far better VPU shape for small k.

Single-block kernel (the aggregate vector is NUM_GROUPS long and already
fits VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Python float (not a jnp array: pallas kernels must not capture traced
# constants).
_NEG = -3.0e38


def _topk_kernel(vals_ref, vld_ref, out_vals_ref, out_idx_ref):
    """k rounds of (argmax, record, mask) over the VMEM-resident copy."""
    k = out_vals_ref.shape[0]
    # Empty groups never selected.
    work = jnp.where(vld_ref[...] > 0.0, vals_ref[...], _NEG)

    def round_(i, carry):
        work, out_vals, out_idx = carry
        j = jnp.argmax(work)
        out_vals = out_vals.at[i].set(work[j])
        out_idx = out_idx.at[i].set(j.astype(jnp.int32))
        work = work.at[j].set(_NEG)
        return work, out_vals, out_idx

    init = (
        work,
        jnp.full((k,), _NEG, jnp.float32),
        jnp.full((k,), -1, jnp.int32),
    )
    _, out_vals, out_idx = jax.lax.fori_loop(0, k, round_, init)
    # Slots beyond the number of live groups stay (sentinel, -1);
    # normalize the value to 0 for a clean wire format.
    sentinel = out_vals <= _NEG / 2
    out_vals_ref[...] = jnp.where(sentinel, 0.0, out_vals)
    out_idx_ref[...] = jnp.where(sentinel, -1, out_idx)


@functools.partial(jax.jit, static_argnames=("k",))
def topk(values: jax.Array, valid: jax.Array, *, k: int) -> tuple[jax.Array, jax.Array]:
    """Descending top-k of ``values`` restricted to ``valid > 0`` groups.

    Args:
        values: f32[G] per-group aggregates.
        valid:  f32[G] group liveness (e.g. counts > 0).
        k: static head size.

    Returns:
        (top values f32[k] — 0-filled past the live count,
         indices i32[k]   — -1-filled past the live count).
    """
    (g,) = values.shape
    if k > g:
        raise ValueError(f"k={k} exceeds group count {g}")
    return pl.pallas_call(
        _topk_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=True,
    )(values, valid)
