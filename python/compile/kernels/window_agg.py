"""L1 pallas kernel: windowed segmented aggregation (sum + count per group).

This is the compute hot-spot of the GROUP-BY-over-window queries (LR2S,
CM1S, CM2S in Table III of the paper). The paper's Spark-Rapids baseline
runs this as a cuDF hash aggregation on the GPU; the TPU adaptation here
(DESIGN.md §Hardware-Adaptation) restructures it for the MXU instead of
emulating a CUDA hash table:

* rows are streamed HBM->VMEM in ``ROW_TILE``-sized tiles via the grid +
  BlockSpec schedule (the role threadblock staging plays in the CUDA
  version),
* per tile, group membership is expressed as a one-hot matrix
  ``[TILE, NUM_GROUPS]`` and the per-group sums/counts are computed as a
  matmul against the (masked) value vector — a shape the MXU executes
  natively in bf16/f32, replacing scattered atomic adds which have no
  efficient TPU equivalent,
* the ``[NUM_GROUPS]`` accumulators live in the output VMEM block across
  all grid steps (TPU grids execute sequentially, making the accumulate
  pattern race-free).

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute. Correctness is
pinned against :mod:`compile.kernels.ref` by ``python/tests``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.shapes import NUM_GROUPS, ROW_TILE


def _window_agg_kernel(gid_ref, val_ref, vld_ref, sum_ref, cnt_ref):
    """One grid step: accumulate a row tile into the group accumulators.

    gid_ref: i32[TILE]  dense group ids in [0, NUM_GROUPS); invalid rows may
             carry any id (they are masked by vld).
    val_ref: f32[TILE]  aggregation operand.
    vld_ref: f32[TILE]  1.0 for live rows, 0.0 for padding.
    sum_ref, cnt_ref: f32[NUM_GROUPS] accumulators (same block every step).
    """
    step = pl.program_id(0)

    # Zero the VMEM accumulators on the first tile only.
    @pl.when(step == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    gids = gid_ref[...]
    vals = val_ref[...] * vld_ref[...]
    vld = vld_ref[...]

    # One-hot membership [TILE, NUM_GROUPS]; 2D broadcasted_iota is the
    # TPU-legal iota form (1D iota is not).
    tile = gids.shape[0]
    group_ids = jax.lax.broadcasted_iota(jnp.int32, (tile, NUM_GROUPS), 1)
    onehot = (gids[:, None] == group_ids).astype(jnp.float32)

    # [NUM_GROUPS] = [TILE, NUM_GROUPS]^T @ [TILE] — MXU-friendly contraction.
    sum_ref[...] += onehot.T @ vals
    cnt_ref[...] += onehot.T @ vld


@functools.partial(jax.jit, static_argnames=("num_groups", "tile"))
def window_agg(
    group_ids: jax.Array,
    values: jax.Array,
    valid: jax.Array,
    *,
    num_groups: int = NUM_GROUPS,
    tile: int = ROW_TILE,
) -> tuple[jax.Array, jax.Array]:
    """Segmented sum/count of ``values`` by ``group_ids`` under ``valid``.

    Args:
        group_ids: i32[N] dense group ids, values in [0, num_groups).
        values:    f32[N] operand column.
        valid:     f32[N] row-validity mask (1.0 live / 0.0 padding).

    Returns:
        (sums f32[num_groups], counts f32[num_groups]).
    """
    (n,) = values.shape
    tile = min(tile, n)
    if n % tile != 0:
        raise ValueError(f"row count {n} must be a multiple of tile {tile}")
    grid = (n // tile,)

    return pl.pallas_call(
        _window_agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            # Same [num_groups] block every grid step: the accumulator stays
            # VMEM-resident for the whole row stream.
            pl.BlockSpec((num_groups,), lambda i: (0,)),
            pl.BlockSpec((num_groups,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_groups,), jnp.float32),
            jax.ShapeDtypeStruct((num_groups,), jnp.float32),
        ],
        interpret=True,
    )(group_ids, values, valid)


# --- Analytical resource estimate (perf reporting; see EXPERIMENTS.md §Perf).


def vmem_footprint_bytes(num_groups: int = NUM_GROUPS, tile: int = ROW_TILE) -> int:
    """Per-grid-step VMEM bytes: 3 input tiles + one-hot + 2 accumulators."""
    tiles = 3 * tile * 4
    onehot = tile * num_groups * 4
    accs = 2 * num_groups * 4
    return tiles + onehot + accs


def mxu_flops_per_row(num_groups: int = NUM_GROUPS) -> int:
    """MACs per ingested row: two [1 x NUM_GROUPS] contractions."""
    return 2 * 2 * num_groups
