"""Pure-jnp oracles for the L1 pallas kernels.

These are the correctness ground truth: deliberately written with the most
obvious jnp formulation (segment_sum, plain masking) and no tiling, so a
bug in the pallas BlockSpec schedule cannot be mirrored here. pytest
asserts allclose between each kernel and its oracle across shapes, group
counts and adversarial masks (see python/tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.shapes import NUM_GROUPS


def window_agg_ref(
    group_ids: jax.Array,
    values: jax.Array,
    valid: jax.Array,
    num_groups: int = NUM_GROUPS,
) -> tuple[jax.Array, jax.Array]:
    """Segmented sum/count oracle via jax.ops.segment_sum."""
    w = values * valid
    sums = jax.ops.segment_sum(w, group_ids, num_segments=num_groups)
    counts = jax.ops.segment_sum(valid, group_ids, num_segments=num_groups)
    return sums.astype(jnp.float32), counts.astype(jnp.float32)


def window_assign_ref(
    times: jax.Array,
    valid: jax.Array,
    rng: jax.Array,
    sld: jax.Array,
    slots: int,
) -> tuple[jax.Array, jax.Array]:
    """Window-instance assignment oracle (plain jnp, no tiling)."""
    last = jnp.floor(times / sld[0])
    first = jnp.maximum(jnp.floor((times - rng[0]) / sld[0]) + 1.0, 0.0)
    slot_ids = jnp.arange(slots, dtype=jnp.float32)[:, None]
    wid = first[None, :] + slot_ids
    in_window = (wid <= last[None, :]).astype(jnp.float32)
    return wid.astype(jnp.int32), in_window * valid[None, :]


def topk_ref(values: jax.Array, valid: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k oracle via full sort."""
    neg = jnp.float32(-3.0e38)
    work = jnp.where(valid > 0.0, values, neg)
    order = jnp.argsort(-work)[:k].astype(jnp.int32)
    vals = work[order]
    dead = vals <= neg / 2
    return jnp.where(dead, 0.0, vals), jnp.where(dead, -1, order)


def filter_project_ref(
    keys: jax.Array,
    a: jax.Array,
    b: jax.Array,
    valid: jax.Array,
    thr: jax.Array,
    alpha: jax.Array,
    beta: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Masked affine projection oracle."""
    keep = (keys >= thr[0]).astype(jnp.float32) * valid
    out = (alpha[0] * a + beta[0] * b) * keep
    return out, keep
