"""L1 pallas kernel: fused filter + affine projection over columnar tiles.

The select-project fragment of the paper's synthetic select-project-join
query (Figs. 2/5) and of the Table III workloads. Fusing the comparison and
the projection into one VMEM pass avoids materializing the intermediate
mask in HBM — the TPU analog of what Spark-Rapids gets from cuDF kernel
fusion on GPU.

Scalars (threshold, projection coefficients) are passed as [1]-shaped
operands pinned to block (0,) so every grid step sees them without a fresh
HBM fetch. ``interpret=True`` as required on this image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.shapes import ROW_TILE


def _filter_project_kernel(
    key_ref, a_ref, b_ref, vld_ref, thr_ref, alpha_ref, beta_ref, out_ref, ovld_ref
):
    """out = alpha*a + beta*b where key >= thr (else 0); valid mask ANDed."""
    keys = key_ref[...]
    keep = (keys >= thr_ref[0]).astype(jnp.float32) * vld_ref[...]
    out_ref[...] = (alpha_ref[0] * a_ref[...] + beta_ref[0] * b_ref[...]) * keep
    ovld_ref[...] = keep


@functools.partial(jax.jit, static_argnames=("tile",))
def filter_project(
    keys: jax.Array,
    a: jax.Array,
    b: jax.Array,
    valid: jax.Array,
    thr: jax.Array,
    alpha: jax.Array,
    beta: jax.Array,
    *,
    tile: int = ROW_TILE,
) -> tuple[jax.Array, jax.Array]:
    """Fused ``filter(keys >= thr)`` + ``project(alpha*a + beta*b)``.

    Args:
        keys, a, b, valid: f32[N] columns (valid is the 0/1 row mask).
        thr, alpha, beta:  f32[1] scalars.

    Returns:
        (projected f32[N], valid_out f32[N]); filtered-out / padding rows
        have value 0 and valid 0.
    """
    (n,) = keys.shape
    tile = min(tile, n)
    if n % tile != 0:
        raise ValueError(f"row count {n} must be a multiple of tile {tile}")
    grid = (n // tile,)

    row = lambda i: (i,)
    pinned = lambda i: (0,)
    return pl.pallas_call(
        _filter_project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((1,), pinned),
            pl.BlockSpec((1,), pinned),
            pl.BlockSpec((1,), pinned),
        ],
        out_specs=[
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(keys, a, b, valid, thr, alpha, beta)


def vmem_footprint_bytes(tile: int = ROW_TILE) -> int:
    """Per-grid-step VMEM bytes: 4 input tiles + 3 scalars + 2 output tiles."""
    return 4 * tile * 4 + 3 * 4 + 2 * tile * 4
