"""L1 pallas kernel: sliding-window instance assignment (the Expand op).

Spark rewrites a sliding-window aggregation by replicating every row into
each of the ``ceil(range/slide)`` window instances it belongs to. On GPU
(Spark-Rapids) that is a gather kernel; the TPU formulation here computes,
for each (row, instance-slot) pair in a VMEM tile, the window-id the row
falls into for that slot and its validity — one vectorized pass on the
VPU, no host-side replication loop.

Inputs are event times (seconds); window instance k covers
``[k*slide, k*slide + range)``; a row at time t belongs to instances
``floor((t - range)/slide) + 1 ..= floor(t/slide)`` clipped at 0.

Output layout is row-major replicas: slot j of row i is output index
``j*N + i`` (matching the rust engine's expand()).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.shapes import ROW_TILE


def _window_assign_kernel(t_ref, vld_ref, rng_ref, sld_ref, wid_ref, wvld_ref):
    """One grid step: assign a row tile to its window instances.

    t_ref:   f32[TILE]        event times.
    vld_ref: f32[TILE]        row validity.
    rng_ref: f32[1]           window range (s).
    sld_ref: f32[1]           window slide (s).
    wid_ref: i32[SLOTS, TILE] window id per (slot, row).
    wvld_ref:f32[SLOTS, TILE] validity per (slot, row).
    """
    t = t_ref[...]
    vld = vld_ref[...]
    rng = rng_ref[0]
    sld = sld_ref[0]
    slots = wid_ref.shape[0]

    # Last (newest) window containing t, and the first.
    last = jnp.floor(t / sld)
    first = jnp.maximum(jnp.floor((t - rng) / sld) + 1.0, 0.0)
    tile = t.shape[0]
    slot_ids = jax.lax.broadcasted_iota(jnp.float32, (slots, tile), 0)
    wid = first[None, :] + slot_ids
    in_window = (wid <= last[None, :]).astype(jnp.float32)
    wid_ref[...] = wid.astype(jnp.int32)
    wvld_ref[...] = in_window * vld[None, :]


@functools.partial(jax.jit, static_argnames=("slots", "tile"))
def window_assign(
    times: jax.Array,
    valid: jax.Array,
    rng: jax.Array,
    sld: jax.Array,
    *,
    slots: int,
    tile: int = ROW_TILE,
) -> tuple[jax.Array, jax.Array]:
    """Assign each row to its ``slots = ceil(range/slide)`` window ids.

    Args:
        times: f32[N] event times (seconds).
        valid: f32[N] row validity.
        rng, sld: f32[1] window range / slide in seconds.
        slots: static replication factor (ceil(range/slide)).

    Returns:
        (window_ids i32[slots, N], valid f32[slots, N]).
    """
    (n,) = times.shape
    tile = min(tile, n)
    if n % tile != 0:
        raise ValueError(f"row count {n} must be a multiple of tile {tile}")
    grid = (n // tile,)
    row = lambda i: (0, i)
    return pl.pallas_call(
        _window_assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((slots, tile), row),
            pl.BlockSpec((slots, tile), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((slots, n), jnp.int32),
            jax.ShapeDtypeStruct((slots, n), jnp.float32),
        ],
        interpret=True,
    )(times, valid, rng, sld)


def vmem_footprint_bytes(slots: int, tile: int = ROW_TILE) -> int:
    """Per-grid-step VMEM bytes: 2 input tiles + 2 scalar + 2 outputs."""
    return 2 * tile * 4 + 2 * 4 + 2 * slots * tile * 4
