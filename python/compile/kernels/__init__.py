"""L1 pallas kernels for LMStream's GPU-path operators.

``window_agg`` and ``filter_project`` are the compute hot-spots; ``ref``
holds their pure-jnp oracles. All kernels run under ``interpret=True``
(see DESIGN.md §Hardware-Adaptation).
"""

from compile.kernels.filter_project import filter_project
from compile.kernels.window_agg import window_agg

__all__ = ["filter_project", "window_agg"]
