"""Shape-bucket configuration shared by the AOT pipeline and the manifest.

The rust runtime executes fixed-shape XLA artifacts. Variable-size
micro-batch partitions are padded (with a validity mask) up to the nearest
*shape bucket*. Buckets trade compile-time artifact count against padding
waste; see DESIGN.md §Perf for the measured trade-off.
"""

from __future__ import annotations

from dataclasses import dataclass

# Row-count buckets for columnar operator artifacts. A partition with R
# valid rows runs on the smallest bucket >= R (rust chunks partitions
# larger than the top bucket).
ROW_BUCKETS: tuple[int, ...] = (1024, 4096, 16384, 65536)

# Number of aggregation groups kept resident per window-aggregate artifact.
# Group keys are densified (hash -> [0, NUM_GROUPS)) on the rust side; rust
# spills to a second pass when a partition exceeds NUM_GROUPS distinct keys.
NUM_GROUPS: int = 256

# Join build-side bucket. Probe sides larger than JOIN_PROBE_BUCKET are
# chunked by the rust executor, so the probe artifact only needs one size.
JOIN_BUILD_BUCKET: int = 4096
JOIN_PROBE_BUCKET: int = 4096

# Row tile processed per pallas grid step (VMEM-resident working set).
ROW_TILE: int = 2048


@dataclass(frozen=True)
class Bucket:
    """A single (rows,) shape bucket."""

    rows: int

    @property
    def name(self) -> str:
        return f"n{self.rows}"


def buckets() -> list[Bucket]:
    return [Bucket(rows=r) for r in ROW_BUCKETS]


def bucket_for(rows: int) -> Bucket:
    """Smallest bucket that fits ``rows`` (mirrors rust-side logic)."""
    for r in ROW_BUCKETS:
        if rows <= r:
            return Bucket(rows=r)
    return Bucket(rows=ROW_BUCKETS[-1])
