"""L1 kernel vs pure-jnp oracle — the core correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.filter_project import filter_project, vmem_footprint_bytes as fp_vmem
from compile.kernels.window_agg import (
    mxu_flops_per_row,
    vmem_footprint_bytes,
    window_agg,
)
from compile.shapes import NUM_GROUPS, ROW_TILE


def _case(n, num_groups=NUM_GROUPS, seed=0, valid_p=0.7):
    rng = np.random.default_rng(seed)
    gid = jnp.asarray(rng.integers(0, num_groups, n), jnp.int32)
    val = jnp.asarray(rng.normal(size=n), jnp.float32)
    vld = jnp.asarray((rng.random(n) < valid_p).astype(np.float32))
    return gid, val, vld


class TestWindowAgg:
    @pytest.mark.parametrize("n", [1024, 2048, 4096, 16384])
    def test_matches_ref(self, n):
        gid, val, vld = _case(n, seed=n)
        s, c = window_agg(gid, val, vld)
        s0, c0 = ref.window_agg_ref(gid, val, vld)
        np.testing.assert_allclose(s, s0, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(c, c0)

    def test_all_invalid_rows_are_ignored(self):
        gid, val, _ = _case(2048, seed=1)
        zeros = jnp.zeros(2048, jnp.float32)
        s, c = window_agg(gid, val, zeros)
        assert float(jnp.abs(s).max()) == 0.0
        assert float(c.max()) == 0.0

    def test_all_valid_counts_sum_to_n(self):
        gid, val, _ = _case(4096, seed=2)
        ones = jnp.ones(4096, jnp.float32)
        _, c = window_agg(gid, val, ones)
        assert float(c.sum()) == 4096.0

    def test_single_group_collapses_to_masked_sum(self):
        _, val, vld = _case(2048, seed=3)
        gid = jnp.zeros(2048, jnp.int32)
        s, c = window_agg(gid, val, vld)
        np.testing.assert_allclose(float(s[0]), float((val * vld).sum()), rtol=1e-4)
        assert float(jnp.abs(s[1:]).max()) == 0.0
        np.testing.assert_allclose(float(c[0]), float(vld.sum()))

    def test_output_shapes_and_dtypes(self):
        gid, val, vld = _case(1024)
        s, c = window_agg(gid, val, vld)
        assert s.shape == (NUM_GROUPS,) and c.shape == (NUM_GROUPS,)
        assert s.dtype == jnp.float32 and c.dtype == jnp.float32

    def test_accumulates_across_tiles(self):
        """Rows of one group spread over several grid steps must merge."""
        n = 4 * ROW_TILE
        gid = jnp.full((n,), 7, jnp.int32)
        val = jnp.ones(n, jnp.float32)
        vld = jnp.ones(n, jnp.float32)
        s, c = window_agg(gid, val, vld)
        assert float(s[7]) == float(n)
        assert float(c[7]) == float(n)

    def test_rejects_non_tile_multiple(self):
        with pytest.raises(ValueError):
            window_agg(
                jnp.zeros(ROW_TILE + 3000, jnp.int32),
                jnp.zeros(ROW_TILE + 3000, jnp.float32),
                jnp.zeros(ROW_TILE + 3000, jnp.float32),
            )

    def test_resource_estimates_positive(self):
        assert vmem_footprint_bytes() > 0
        assert mxu_flops_per_row() == 4 * NUM_GROUPS


class TestFilterProject:
    def _fp_case(self, n, seed=0):
        rng = np.random.default_rng(seed)
        mk = lambda: jnp.asarray(rng.normal(size=n), jnp.float32)
        keys, a, b = mk(), mk(), mk()
        vld = jnp.asarray((rng.random(n) < 0.8).astype(np.float32))
        sc = lambda v: jnp.asarray([v], jnp.float32)
        return keys, a, b, vld, sc(0.1), sc(2.0), sc(-0.5)

    @pytest.mark.parametrize("n", [1024, 2048, 8192])
    def test_matches_ref(self, n):
        args = self._fp_case(n, seed=n)
        out, vld = filter_project(*args)
        out0, vld0 = ref.filter_project_ref(*args)
        np.testing.assert_allclose(out, out0, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(vld, vld0)

    def test_threshold_is_inclusive(self):
        n = ROW_TILE
        keys = jnp.full((n,), 0.5, jnp.float32)
        ones = jnp.ones(n, jnp.float32)
        thr = jnp.asarray([0.5], jnp.float32)
        one = jnp.asarray([1.0], jnp.float32)
        zero = jnp.asarray([0.0], jnp.float32)
        _, vld = filter_project(keys, ones, ones, ones, thr, one, zero)
        assert float(vld.min()) == 1.0  # keys >= thr keeps equality

    def test_filtered_rows_zeroed(self):
        args = self._fp_case(2048, seed=9)
        out, vld = filter_project(*args)
        dead = np.asarray(vld) == 0.0
        assert np.all(np.asarray(out)[dead] == 0.0)

    def test_vmem_estimate_positive(self):
        assert fp_vmem() > 0
