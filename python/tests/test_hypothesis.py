"""Hypothesis sweeps over the pallas kernels' shape/value space.

The prompt for these properties: whatever row count (multiple of the tile
constraint), group distribution, validity pattern and scalar parameters the
rust side marshals, the kernels must agree with the jnp oracle bit-for-bit
(counts) / to f32 tolerance (sums).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.filter_project import filter_project
from compile.kernels.window_agg import window_agg
from compile.shapes import NUM_GROUPS

# Row counts the AOT path can emit: powers of two covering sub-tile and
# multi-tile regimes.
ROWS = st.sampled_from([256, 512, 1024, 2048, 4096, 8192])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(n=ROWS, seed=SEEDS, valid_p=st.floats(0.0, 1.0))
def test_window_agg_matches_ref(n, seed, valid_p):
    rng = np.random.default_rng(seed)
    gid = jnp.asarray(rng.integers(0, NUM_GROUPS, n), jnp.int32)
    val = jnp.asarray(rng.normal(size=n) * 100.0, jnp.float32)
    vld = jnp.asarray((rng.random(n) < valid_p).astype(np.float32))
    s, c = window_agg(gid, val, vld)
    s0, c0 = ref.window_agg_ref(gid, val, vld)
    np.testing.assert_allclose(s, s0, rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c0))


@settings(max_examples=25, deadline=None)
@given(n=ROWS, seed=SEEDS, skew=st.integers(1, NUM_GROUPS))
def test_window_agg_skewed_groups(n, seed, skew):
    """Heavy skew (few hot groups) must not break tile accumulation."""
    rng = np.random.default_rng(seed)
    gid = jnp.asarray(rng.integers(0, skew, n), jnp.int32)
    val = jnp.asarray(rng.random(n), jnp.float32)
    vld = jnp.ones(n, jnp.float32)
    s, c = window_agg(gid, val, vld)
    s0, c0 = ref.window_agg_ref(gid, val, vld)
    np.testing.assert_allclose(s, s0, rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c0))
    assert float(c.sum()) == float(n)


@settings(max_examples=25, deadline=None)
@given(
    n=ROWS,
    seed=SEEDS,
    thr=st.floats(-3.0, 3.0),
    alpha=st.floats(-10.0, 10.0),
    beta=st.floats(-10.0, 10.0),
)
def test_filter_project_matches_ref(n, seed, thr, alpha, beta):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=n), jnp.float32)
    keys, a, b = mk(), mk(), mk()
    vld = jnp.asarray((rng.random(n) < 0.5).astype(np.float32))
    sc = lambda v: jnp.asarray([v], jnp.float32)
    out, v = filter_project(keys, a, b, vld, sc(thr), sc(alpha), sc(beta))
    out0, v0 = ref.filter_project_ref(keys, a, b, vld, sc(thr), sc(alpha), sc(beta))
    np.testing.assert_allclose(out, out0, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v0))


@settings(max_examples=15, deadline=None)
@given(n=ROWS, seed=SEEDS)
def test_filter_project_valid_subset(n, seed):
    """Output validity is always a subset of input validity."""
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=n), jnp.float32)
    vld = jnp.asarray((rng.random(n) < 0.5).astype(np.float32))
    sc = lambda v: jnp.asarray([v], jnp.float32)
    _, v = filter_project(mk(), mk(), mk(), vld, sc(0.0), sc(1.0), sc(1.0))
    assert np.all(np.asarray(v) <= np.asarray(vld))
