"""window_assign + topk kernels vs oracles (unit + hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.topk import topk
from compile.kernels.window_assign import window_assign, vmem_footprint_bytes


def sc(v):
    return jnp.asarray([v], jnp.float32)


class TestWindowAssign:
    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        t = jnp.asarray(rng.uniform(0, 200, 1024), jnp.float32)
        v = jnp.asarray((rng.random(1024) < 0.7).astype(np.float32))
        wid, wv = window_assign(t, v, sc(30.0), sc(10.0), slots=3)
        wid0, wv0 = ref.window_assign_ref(t, v, sc(30.0), sc(10.0), 3)
        np.testing.assert_array_equal(np.asarray(wid), np.asarray(wid0))
        np.testing.assert_array_equal(np.asarray(wv), np.asarray(wv0))

    def test_row_belongs_to_exactly_slots_windows_when_old(self):
        # A row far from t=0 belongs to exactly range/slide instances.
        t = jnp.full((256,), 100.0, jnp.float32)
        v = jnp.ones(256, jnp.float32)
        _, wv = window_assign(t, v, sc(30.0), sc(10.0), slots=3)
        assert float(np.asarray(wv).sum()) == 3 * 256

    def test_early_rows_clipped_at_window_zero(self):
        # t=5 with range 30, slide 10: instances floor((5-30)/10)+1=-1→0
        # through floor(5/10)=0 → exactly one live slot, window id 0.
        t = jnp.full((256,), 5.0, jnp.float32)
        v = jnp.ones(256, jnp.float32)
        wid, wv = window_assign(t, v, sc(30.0), sc(10.0), slots=3)
        wv = np.asarray(wv)
        assert wv[0].sum() == 256
        assert wv[1:].sum() == 0
        assert np.all(np.asarray(wid)[0] == 0)

    def test_invalid_rows_never_assigned(self):
        t = jnp.full((256,), 50.0, jnp.float32)
        v = jnp.zeros(256, jnp.float32)
        _, wv = window_assign(t, v, sc(30.0), sc(10.0), slots=3)
        assert float(np.asarray(wv).sum()) == 0.0

    def test_vmem_estimate_positive(self):
        assert vmem_footprint_bytes(3) > 0


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([256, 1024, 4096]),
    seed=st.integers(0, 2**31 - 1),
    rng_s=st.sampled_from([30.0, 60.0]),
    sld_s=st.sampled_from([5.0, 10.0, 30.0]),
)
def test_window_assign_matches_ref_sweep(n, seed, rng_s, sld_s):
    slots = int(np.ceil(rng_s / sld_s))
    r = np.random.default_rng(seed)
    t = jnp.asarray(r.uniform(0, 500, n), jnp.float32)
    v = jnp.asarray((r.random(n) < 0.6).astype(np.float32))
    wid, wv = window_assign(t, v, sc(rng_s), sc(sld_s), slots=slots)
    wid0, wv0 = ref.window_assign_ref(t, v, sc(rng_s), sc(sld_s), slots)
    np.testing.assert_array_equal(np.asarray(wid), np.asarray(wid0))
    np.testing.assert_array_equal(np.asarray(wv), np.asarray(wv0))


class TestTopK:
    def test_matches_ref(self):
        rng = np.random.default_rng(2)
        vals = jnp.asarray(rng.normal(size=256) * 10, jnp.float32)
        cnt = jnp.asarray((rng.random(256) < 0.5).astype(np.float32))
        tv, ti = topk(vals, cnt, k=16)
        tv0, ti0 = ref.topk_ref(vals, cnt, 16)
        np.testing.assert_allclose(tv, tv0, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(ti), np.asarray(ti0))

    def test_descending_order(self):
        vals = jnp.asarray(np.arange(256, dtype=np.float32))
        cnt = jnp.ones(256, jnp.float32)
        tv, ti = topk(vals, cnt, k=8)
        np.testing.assert_allclose(tv, [255, 254, 253, 252, 251, 250, 249, 248])
        np.testing.assert_array_equal(np.asarray(ti), [255, 254, 253, 252, 251, 250, 249, 248])

    def test_fewer_live_groups_than_k(self):
        vals = jnp.zeros(256, jnp.float32).at[3].set(7.0).at[9].set(5.0)
        cnt = jnp.zeros(256, jnp.float32).at[3].set(1.0).at[9].set(1.0)
        tv, ti = topk(vals, cnt, k=16)
        assert float(tv[0]) == 7.0 and int(ti[0]) == 3
        assert float(tv[1]) == 5.0 and int(ti[1]) == 9
        assert np.all(np.asarray(ti)[2:] == -1)
        assert np.all(np.asarray(tv)[2:] == 0.0)

    def test_k_larger_than_groups_rejected(self):
        with pytest.raises(ValueError):
            topk(jnp.zeros(8, jnp.float32), jnp.ones(8, jnp.float32), k=9)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([1, 4, 16, 64]),
       live_p=st.floats(0.0, 1.0))
def test_topk_matches_ref_sweep(seed, k, live_p):
    r = np.random.default_rng(seed)
    vals = jnp.asarray(r.normal(size=256) * 100, jnp.float32)
    cnt = jnp.asarray((r.random(256) < live_p).astype(np.float32))
    tv, ti = topk(vals, cnt, k=k)
    tv0, ti0 = ref.topk_ref(vals, cnt, k)
    np.testing.assert_allclose(tv, tv0, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(ti0))
