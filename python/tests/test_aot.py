"""AOT pipeline: artifact emission, manifest schema, HLO text validity."""

import json
import os

import pytest

from compile import aot, model
from compile.shapes import NUM_GROUPS, bucket_for, buckets


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build a small artifact set once for the whole module."""
    out = str(tmp_path_factory.mktemp("artifacts"))
    only = {"filter_ge", "window_aggregate", "avg_having_lt", "lr2s_pipeline"}
    manifest = aot.build_all(out, only, [1024, 4096])
    return out, manifest


class TestBuild:
    def test_artifact_files_exist_and_are_hlo(self, built):
        out, manifest = built
        assert manifest["artifacts"], "no artifacts emitted"
        for art in manifest["artifacts"]:
            path = os.path.join(out, art["file"])
            assert os.path.exists(path)
            head = open(path).read(200)
            assert "HloModule" in head, f"{art['file']} is not HLO text"

    def test_group_space_ops_emitted_once(self, built):
        _, manifest = built
        gs = [a for a in manifest["artifacts"] if a["op"] in model.GROUP_SPACE_OPS]
        assert len(gs) == 1  # avg_having_lt only at the smallest bucket
        assert gs[0]["rows"] == 1024

    def test_row_ops_emitted_per_bucket(self, built):
        _, manifest = built
        rows = sorted(a["rows"] for a in manifest["artifacts"] if a["op"] == "filter_ge")
        assert rows == [1024, 4096]

    def test_manifest_shapes_match_signatures(self, built):
        _, manifest = built
        for art in manifest["artifacts"]:
            sigs = model.signatures(art["rows"])
            _, specs = sigs[art["op"]]
            got = [tuple(i["shape"]) for i in art["inputs"]]
            want = [tuple(s.shape) for s in specs]
            assert got == want, art["op"]

    def test_manifest_header(self, built):
        _, manifest = built
        assert manifest["format"] == 1
        assert manifest["num_groups"] == NUM_GROUPS
        assert manifest["row_buckets"] == [1024, 4096]

    def test_manifest_json_round_trip(self, built, tmp_path):
        _, manifest = built
        p = tmp_path / "m.json"
        p.write_text(json.dumps(manifest))
        assert json.loads(p.read_text()) == manifest


class TestShapeBuckets:
    def test_bucket_for_monotone(self):
        assert bucket_for(1).rows == 1024
        assert bucket_for(1024).rows == 1024
        assert bucket_for(1025).rows == 4096
        assert bucket_for(10**9).rows == buckets()[-1].rows

    def test_bucket_names(self):
        assert bucket_for(5000).name == "n16384"


class TestLowerOne:
    def test_outputs_are_tupled(self):
        import jax
        import jax.numpy as jnp

        fn, specs = model.signatures(1024)["window_aggregate"]
        hlo, in_meta, out_meta = aot.lower_one("window_aggregate", fn, specs)
        assert len(in_meta) == 3 and len(out_meta) == 2
        assert "ROOT" in hlo

    def test_single_output_ops_tupled_too(self):
        fn, specs = model.signatures(1024)["filter_ge"]
        hlo, _, out_meta = aot.lower_one("filter_ge", fn, specs)
        assert len(out_meta) == 1
        # return_tuple=True => root is a 1-tuple, which the rust side
        # unwraps with to_tuple()
        assert "tuple(" in hlo or "tuple " in hlo
