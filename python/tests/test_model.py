"""L2 operator semantics vs plain numpy references."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.shapes import NUM_GROUPS


def sc(v):
    return jnp.asarray([v], jnp.float32)


def rnd(n, seed=0):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.normal(size=n), jnp.float32),
        jnp.asarray((rng.random(n) < 0.8).astype(np.float32)),
        rng,
    )


class TestFilters:
    def test_filter_ge_lt_partition(self):
        keys, vld, _ = rnd(512, 1)
        (ge,) = model.filter_ge(keys, vld, sc(0.3))
        (lt,) = model.filter_lt(keys, vld, sc(0.3))
        np.testing.assert_allclose(np.asarray(ge) + np.asarray(lt), np.asarray(vld))

    def test_filter_eq(self):
        keys = jnp.asarray([1.0, 2.0, 1.0, 3.0], jnp.float32)
        vld = jnp.ones(4, jnp.float32)
        (out,) = model.filter_eq(keys, vld, sc(1.0))
        np.testing.assert_allclose(out, [1, 0, 1, 0])

    def test_filter_band_half_open(self):
        keys = jnp.asarray([0.0, 1.0, 2.0, 3.0], jnp.float32)
        vld = jnp.ones(4, jnp.float32)
        (out,) = model.filter_band(keys, vld, sc(1.0), sc(3.0))
        np.testing.assert_allclose(out, [0, 1, 1, 0])  # [lo, hi)


class TestProjections:
    def test_project_affine(self):
        a = jnp.asarray([1.0, 2.0], jnp.float32)
        b = jnp.asarray([10.0, 20.0], jnp.float32)
        (out,) = model.project_affine(a, b, sc(2.0), sc(0.5))
        np.testing.assert_allclose(out, [7.0, 14.0])

    def test_project_scale(self):
        (out,) = model.project_scale(jnp.asarray([3.0], jnp.float32), sc(-2.0))
        np.testing.assert_allclose(out, [-6.0])


class TestAggregates:
    def test_avg_having_lt(self):
        sums = jnp.zeros(NUM_GROUPS, jnp.float32).at[0].set(100.0).at[1].set(10.0)
        counts = jnp.zeros(NUM_GROUPS, jnp.float32).at[0].set(2.0).at[1].set(1.0)
        avgs, keep = model.avg_having_lt(sums, counts, sc(40.0))
        assert float(avgs[0]) == 50.0 and float(keep[0]) == 0.0
        assert float(avgs[1]) == 10.0 and float(keep[1]) == 1.0
        assert float(keep[2:].max()) == 0.0  # empty groups never kept

    def test_group_avg_empty_groups_zero(self):
        sums = jnp.zeros(NUM_GROUPS, jnp.float32).at[5].set(9.0)
        counts = jnp.zeros(NUM_GROUPS, jnp.float32).at[5].set(3.0)
        (avgs,) = model.group_avg(sums, counts)
        assert float(avgs[5]) == 3.0
        assert float(jnp.abs(avgs).sum()) == 3.0

    def test_sort_groups_desc(self):
        sums = jnp.zeros(NUM_GROUPS, jnp.float32).at[3].set(5.0).at[9].set(50.0)
        counts = jnp.zeros(NUM_GROUPS, jnp.float32).at[3].set(1.0).at[9].set(1.0)
        sorted_sums, perm = model.sort_groups_desc(sums, counts)
        assert float(sorted_sums[0]) == 50.0 and int(perm[0]) == 9
        assert float(sorted_sums[1]) == 5.0 and int(perm[1]) == 3


class TestSortJoin:
    def test_sort_perm_invalid_rows_last(self):
        keys = jnp.asarray([3.0, 1.0, 2.0, 0.0], jnp.float32)
        vld = jnp.asarray([1.0, 1.0, 1.0, 0.0], jnp.float32)
        (perm,) = model.sort_perm(keys, vld)
        assert perm.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(perm), [1, 2, 0, 3])

    def test_apply_perm3(self):
        a = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
        perm = jnp.asarray([2, 0, 1], jnp.int32)
        x, y, z = model.apply_perm3(a, a * 10, a * 100, perm)
        np.testing.assert_allclose(x, [3.0, 1.0, 2.0])
        np.testing.assert_allclose(y, [30.0, 10.0, 20.0])
        np.testing.assert_allclose(z, [300.0, 100.0, 200.0])

    def test_join_probe_first_match_and_misses(self):
        pk = jnp.asarray([5.0, 7.0, 9.0], jnp.float32)
        pv = jnp.ones(3, jnp.float32)
        bk = jnp.asarray([7.0, 5.0, 7.0, 1.0], jnp.float32)
        bv = jnp.ones(4, jnp.float32)
        idx, found = model.join_probe(pk, pv, bk, bv)
        np.testing.assert_array_equal(np.asarray(idx), [1, 0, -1])
        np.testing.assert_allclose(found, [1.0, 1.0, 0.0])

    def test_join_probe_respects_build_validity(self):
        pk = jnp.asarray([7.0], jnp.float32)
        pv = jnp.ones(1, jnp.float32)
        bk = jnp.asarray([7.0, 7.0], jnp.float32)
        bv = jnp.asarray([0.0, 1.0], jnp.float32)  # first copy dead
        idx, found = model.join_probe(pk, pv, bk, bv)
        assert int(idx[0]) == 1 and float(found[0]) == 1.0

    def test_join_probe_invalid_probe_rows(self):
        pk = jnp.asarray([7.0], jnp.float32)
        pv = jnp.zeros(1, jnp.float32)
        bk = jnp.asarray([7.0], jnp.float32)
        bv = jnp.ones(1, jnp.float32)
        idx, found = model.join_probe(pk, pv, bk, bv)
        assert float(found[0]) == 0.0 and int(idx[0]) == -1


class TestPipelines:
    def test_lr2s_pipeline_matches_composition(self):
        rng = np.random.default_rng(7)
        n = 2048
        gid = jnp.asarray(rng.integers(0, NUM_GROUPS, n), jnp.int32)
        spd = jnp.asarray(rng.uniform(0, 80, n), jnp.float32)
        vld = jnp.ones(n, jnp.float32)
        avgs, keep = model.lr2s_pipeline(gid, spd, vld, sc(40.0))
        sums, counts = model.window_aggregate(gid, spd, vld)
        avgs0, keep0 = model.avg_having_lt(sums, counts, sc(40.0))
        np.testing.assert_allclose(avgs, avgs0, rtol=1e-5)
        np.testing.assert_allclose(keep, keep0)

    def test_cm1s_pipeline_sorted_desc(self):
        rng = np.random.default_rng(8)
        n = 2048
        gid = jnp.asarray(rng.integers(0, 16, n), jnp.int32)
        cpu = jnp.asarray(rng.random(n), jnp.float32)
        vld = jnp.ones(n, jnp.float32)
        sorted_sums, perm = model.cm1s_pipeline(gid, cpu, vld)
        head = np.asarray(sorted_sums[:16])
        assert np.all(np.diff(head) <= 1e-5)  # descending

    def test_cm2s_pipeline_filters_event_type(self):
        n = 2048
        gid = jnp.zeros(n, jnp.int32)
        cpu = jnp.ones(n, jnp.float32)
        ev = jnp.asarray(([1.0, 0.0] * (n // 2)), jnp.float32)
        vld = jnp.ones(n, jnp.float32)
        avgs, counts = model.cm2s_pipeline(gid, cpu, ev, vld, sc(1.0))
        assert float(counts[0]) == n / 2
        assert float(avgs[0]) == 1.0

    def test_spj_pipeline_shapes(self):
        n, bsz = 1024, 4096
        rng = np.random.default_rng(9)
        mk = lambda m: jnp.asarray(rng.normal(size=m), jnp.float32)
        out, idx, found = model.spj_pipeline(
            mk(n), mk(n), mk(n), jnp.ones(n, jnp.float32), mk(n),
            mk(bsz), jnp.ones(bsz, jnp.float32), sc(0.0), sc(1.0), sc(1.0),
        )
        assert out.shape == (n,) and idx.shape == (n,) and found.shape == (n,)
        assert idx.dtype == jnp.int32


class TestSignatureRegistry:
    def test_all_ops_instantiable(self):
        sigs = model.signatures(1024)
        assert len(sigs) >= 18
        for name, (fn, specs) in sigs.items():
            assert callable(fn), name
            assert all(hasattr(s, "shape") for s in specs), name

    def test_group_space_ops_have_no_row_dim(self):
        sigs = model.signatures(4096)
        for name in model.GROUP_SPACE_OPS:
            _, specs = sigs[name]
            for s in specs:
                assert 4096 not in s.shape, (name, s.shape)
