//! Executor fault tolerance: a three-executor session loses and
//! regains an executor mid-stream — and keeps its output intact.
//!
//! A deterministic fault plan drives the cluster through the full
//! failure lifecycle: a transient stall (round 2), a permanent
//! GPU-device failure (round 3, that executor runs CPU-only from then
//! on), an executor crash (round 4, its share is re-planned onto the
//! two survivors after detection + backoff), and a health-gated rejoin
//! (round 6, the executor serves a probation window before it counts
//! as healthy again). Every retry, every charged recovery wait, and
//! every degraded round is visible in the per-batch records and the
//! session's final health report.
//!
//! ```bash
//! cargo run --release --offline --example fault_tolerance [seed]
//! ```

use lmstream::cluster::{ClusterSpec, FaultPlan};
use lmstream::config::{Config, Mode};
use lmstream::engine::ops::filter::Predicate;
use lmstream::query::QueryBuilder;
use lmstream::session::Session;
use lmstream::source::traffic::Traffic;
use lmstream::util::bench::print_table;
use lmstream::workloads::{linear_road, Workload};
use std::time::Duration;

fn main() -> lmstream::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(11);

    // The scripted failure lifecycle (rounds are 1-based).
    let plan = FaultPlan::new()
        .stall(2, 1) // transient: one retry, full topology afterwards
        .gpu_fail(3, 2) // permanent: executor 2 degrades to CPU-only
        .crash(4, 1) // executor 1 drops; survivors absorb its share
        .rejoin(6, 1); // health-gated return through probation

    let query = QueryBuilder::scan("slow-traffic")
        .filter("speed", Predicate::Lt(60.0))
        .select(&["timestamp", "vehicle", "speed", "segment"])
        .build()?;
    let workload =
        Workload::new("slow-traffic", query, Traffic::constant_default(), |s| {
            Box::new(linear_road::LinearRoadGen::new(s))
        });

    let cfg = Config {
        mode: Mode::LmStream,
        cluster: Some(ClusterSpec::of(3)),
        fault_plan: Some(plan),
        seed,
        ..Config::default()
    };
    let mut session = Session::new(cfg)?;
    session.register(workload)?;
    let results = session.run(Duration::from_secs(240))?;

    // Per-round view: where the faults landed and what they cost.
    let rows: Vec<Vec<String>> = results[0]
        .batches
        .iter()
        .map(|b| {
            vec![
                b.round.to_string(),
                b.num_datasets.to_string(),
                format!("{:.1}", b.proc.as_secs_f64() * 1e3),
                b.retries.to_string(),
                format!("{:.0}", b.recovery_wait.as_secs_f64() * 1e3),
                if b.degraded { "yes" } else { "" }.to_string(),
                format!("{}/{}", b.gpu_ops, b.total_ops),
            ]
        })
        .collect();
    print_table(
        "rounds (3 executors, scripted faults)",
        &["round", "datasets", "proc ms", "retries", "recovery ms", "degraded", "gpu ops"],
        &rows,
    );

    // Final health: per-executor fault counters and end state.
    let health = session.health_report().expect("a finished run reports health");
    let rows: Vec<Vec<String>> = health
        .executors
        .iter()
        .map(|e| {
            vec![
                e.executor.to_string(),
                e.crashes.to_string(),
                e.stalls.to_string(),
                e.gpu_faults.to_string(),
                e.rejoins.to_string(),
                e.state.clone(),
            ]
        })
        .collect();
    print_table(
        "executor health",
        &["executor", "crashes", "stalls", "gpu faults", "rejoins", "state"],
        &rows,
    );
    println!(
        "\nsession: {} retried attempt(s), {:.0} ms charged to recovery, \
         {} degraded round(s) of {}",
        health.retries,
        health.recovery_wait.as_secs_f64() * 1e3,
        health.degraded_rounds,
        results[0].batches.len(),
    );
    println!(
        "output is identical to a fault-free run: every lost share was \
         re-executed, never skipped"
    );
    Ok(())
}
