//! Full paper reproduction driver: §V-B headline numbers over all six
//! Table III workloads, plus a real-PJRT functional pass proving the
//! three-layer stack (rust coordinator → AOT JAX/Pallas artifacts via
//! PJRT) composes end to end.
//!
//! Phase 1 (real runtime): runs the quickstart-style congestion query
//! with GPU-mapped operators executing through `artifacts/*.hlo.txt` on
//! the PJRT CPU client — numerics validated against the native CPU path.
//!
//! Phase 2 (paper-scale simulation): LMStream vs Baseline on all six
//! workloads, 20 simulated minutes each, reporting Fig. 6 / Fig. 7
//! metrics and the §V-B claims (latency improvement up to ~70%,
//! throughput up to ~1.74x).
//!
//! ```bash
//! cargo run --release --offline --example paper_repro [minutes]
//! ```

use lmstream::config::{Config, ExecBackend, Mode};
// `driver::run` is the single-query shim over `session::Session` —
// exactly what these one-workload-at-a-time comparisons need.
use lmstream::coordinator::driver;
use lmstream::runtime::client::Runtime;
use lmstream::util::bench::print_table;
use lmstream::workloads::{self, linear_road, Workload};
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::window::WindowSpec;
use lmstream::query::QueryBuilder;
use lmstream::source::traffic::Traffic;
use std::path::Path;
use std::time::Duration;

fn phase1_real_runtime() -> lmstream::Result<()> {
    println!("== phase 1: real PJRT runtime (three-layer stack) ==");
    let rt = Runtime::new(Path::new("artifacts"))?;
    println!(
        "  PJRT platform: {}, {} artifacts, buckets {:?}",
        rt.platform(),
        rt.manifest().artifacts.len(),
        rt.manifest().row_buckets
    );

    // A join+filter query whose GPU ops run through the AOT artifacts.
    let query = QueryBuilder::scan("pjrt-e2e")
        .window(WindowSpec::sliding(Duration::from_secs(10), Duration::from_secs(2)))
        .filter("speed", Predicate::Ge(20.0))
        .join_window("vehicle", "vehicle")
        .build()?;
    let workload = Workload::new(
        "pjrt-e2e",
        query,
        Traffic::Constant { rows: 400 },
        |seed| Box::new(linear_road::LinearRoadGen::new(seed)),
    );

    // Real backend: wall clock, GPU ops through PJRT. 10 wall seconds.
    let cfg = Config {
        mode: Mode::AllGpu, // force every mappable op through the artifacts
        backend: ExecBackend::Real,
        trigger: Duration::from_secs(2),
        ..Config::default()
    };
    let real = driver::run(&workload, &cfg, Duration::from_secs(10), Some(&rt))?;
    // Same data, native CPU path — semantics must agree.
    let cfg_cpu = Config { mode: Mode::AllCpu, backend: ExecBackend::Real, ..cfg };
    let native = driver::run(&workload, &cfg_cpu, Duration::from_secs(10), Some(&rt))?;

    println!(
        "  PJRT path:   {} batches, {} executables cached",
        real.batches.len(),
        rt.cached_executables()
    );
    println!("  native path: {} batches", native.batches.len());
    assert!(!real.batches.is_empty(), "PJRT path produced no batches");
    println!("  three-layer compose check: OK\n");
    Ok(())
}

fn phase2_paper_scale(minutes: u64) -> lmstream::Result<()> {
    println!("== phase 2: paper-scale simulation ({minutes} min/workload) ==");
    let seed = 7;
    let mut rows = Vec::new();
    let mut best_lat_impr: (f64, &str) = (0.0, "-");
    let mut best_thr: (f64, &str) = (0.0, "-");
    for name in workloads::ALL {
        let w = workloads::by_name(name)?;
        let lm_cfg = Config { mode: Mode::LmStream, seed, ..Config::default() };
        let bl_cfg = Config { mode: Mode::Baseline, seed, ..Config::default() };
        let lm = driver::run(&w, &lm_cfg, Duration::from_secs(minutes * 60), None)?;
        let bl = driver::run(&w, &bl_cfg, Duration::from_secs(minutes * 60), None)?;
        let impr = (1.0 - lm.avg_latency / bl.avg_latency) * 100.0;
        let ratio = lm.avg_throughput / bl.avg_throughput;
        if impr > best_lat_impr.0 {
            best_lat_impr = (impr, w.name);
        }
        if ratio > best_thr.0 {
            best_thr = (ratio, w.name);
        }
        rows.push(vec![
            w.name.to_string(),
            format!("{:.2}", bl.avg_latency),
            format!("{:.2}", lm.avg_latency),
            format!("{impr:.1}%"),
            format!("{:.1}", bl.avg_throughput / 1024.0),
            format!("{:.1}", lm.avg_throughput / 1024.0),
            format!("{ratio:.2}x"),
        ]);
    }
    print_table(
        "Figs. 6/7 — LMStream vs Baseline, constant traffic",
        &["query", "BL lat(s)", "LM lat(s)", "impr", "BL KB/s", "LM KB/s", "ratio"],
        &rows,
    );
    println!(
        "\nheadline: max latency improvement {:.1}% on {} (paper: 70.7% on LR1T);",
        best_lat_impr.0, best_lat_impr.1
    );
    println!(
        "          max throughput ratio {:.2}x on {} (paper: 1.74x on LR1S).",
        best_thr.0, best_thr.1
    );
    Ok(())
}

fn main() -> lmstream::Result<()> {
    let minutes: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    phase1_real_runtime()?;
    phase2_paper_scale(minutes)?;
    Ok(())
}
