//! Cluster Monitoring end-to-end driver: the CM1/CM2 queries (Table III)
//! under random (fluctuating) traffic — the paper's realistic setting —
//! including the per-batch timeline LMStream's admission control shapes.
//!
//! ```bash
//! cargo run --release --offline --example cluster_monitoring [minutes] [seed]
//! ```

use lmstream::config::{Config, Mode};
// `driver::run` is the single-query shim over `session::Session` —
// exactly what these one-workload-at-a-time comparisons need.
use lmstream::coordinator::driver;
use lmstream::source::traffic::Traffic;
use lmstream::util::bench::print_table;
use lmstream::workloads;
use std::time::Duration;

fn main() -> lmstream::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let minutes: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(11);

    let mut rows = Vec::new();
    for name in ["cm1s", "cm1t", "cm2s"] {
        let w = workloads::by_name(name)?.with_traffic(Traffic::random_default());
        let lm_cfg = Config { mode: Mode::LmStream, seed, ..Config::default() };
        let bl_cfg = Config { mode: Mode::Baseline, seed, ..Config::default() };
        let lm = driver::run(&w, &lm_cfg, Duration::from_secs(minutes * 60), None)?;
        let bl = driver::run(&w, &bl_cfg, Duration::from_secs(minutes * 60), None)?;
        rows.push(vec![
            w.name.to_string(),
            format!("{}", bl.batches.len()),
            format!("{}", lm.batches.len()),
            format!("{:.2}", bl.avg_latency),
            format!("{:.2}", lm.avg_latency),
            format!("{:.2}", bl.avg_max_latency()),
            format!("{:.2}", lm.avg_max_latency()),
            format!("{:.1}", bl.avg_throughput / 1024.0),
            format!("{:.1}", lm.avg_throughput / 1024.0),
        ]);
    }
    print_table(
        &format!("Cluster Monitoring ({minutes} simulated minutes, random traffic)"),
        &[
            "query", "BL batches", "LM batches", "BL lat", "LM lat", "BL maxlat",
            "LM maxlat", "BL KB/s", "LM KB/s",
        ],
        &rows,
    );

    // Show the admission controller at work on CM2S: batch sizes adapt to
    // the fluctuating ingest while max latency stays near the 5 s slide.
    let w = workloads::by_name("cm2s")?.with_traffic(Traffic::random_default());
    let cfg = Config { mode: Mode::LmStream, seed, ..Config::default() };
    let r = driver::run(&w, &cfg, Duration::from_secs(120), None)?;
    let rows: Vec<Vec<String>> = r
        .batches
        .iter()
        .take(12)
        .map(|b| {
            vec![
                format!("{:.1}", b.admitted_at.as_secs_f64()),
                b.num_datasets.to_string(),
                format!("{:.0}", b.bytes as f64 / 1024.0),
                format!("{:.2}", b.max_latency.as_secs_f64()),
                format!("{}/{}", b.gpu_ops, b.total_ops),
            ]
        })
        .collect();
    print_table(
        "CM2S first batches under LMStream (slide-time bound = 5 s)",
        &["t(s)", "datasets", "KB", "max lat(s)", "gpu ops"],
        &rows,
    );
    Ok(())
}
