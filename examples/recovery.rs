//! Crash recovery: a two-query session is killed mid-stream by a
//! failing sink, then resumed from its checkpoint + write-ahead log —
//! and the exactly-once sink ledger proves no output row is delivered
//! twice.
//!
//! The first incarnation runs with a checkpoint directory and a WAL
//! directory configured: every admitted micro-batch is fsynced to a
//! per-source log *before* execution, and every sink delivery is
//! recorded in a durable ledger. A sink that errors on its Nth delivery
//! plays the part of the crash. The second incarnation opens the same
//! directories, reconciles checkpoint ⨯ WAL ⨯ ledger (Precise mode:
//! the whole uncheckpointed tail replays, the ledger suppresses
//! re-delivery), and continues the stream.
//!
//! ```bash
//! cargo run --release --offline --example recovery [crash_after] [seed]
//! ```

use lmstream::config::{Config, Mode};
use lmstream::durability::{RecoveryMode, SinkLedger};
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::sink::Sink;
use lmstream::query::QueryBuilder;
use lmstream::session::Session;
use lmstream::sim::Time;
use lmstream::source::traffic::Traffic;
use lmstream::util::bench::print_table;
use lmstream::workloads::{linear_road, Workload};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Records every delivered (query, batch index, live rows) into a log
/// shared across incarnations; optionally errors on its Nth delivery to
/// simulate a crash between execution and checkpoint.
struct AuditSink {
    query: &'static str,
    log: Arc<Mutex<Vec<(&'static str, usize, usize)>>>,
    crash_after: Option<usize>,
    delivered: usize,
}

impl Sink for AuditSink {
    fn deliver(
        &mut self,
        batch_index: usize,
        result: &ChunkedBatch,
        _completed_at: Time,
    ) -> lmstream::error::Result<()> {
        if self.crash_after == Some(self.delivered) {
            return Err(lmstream::error::Error::Durability(
                "injected crash: sink lost its connection".into(),
            ));
        }
        self.delivered += 1;
        self.log.lock().unwrap().push((self.query, batch_index, result.live_rows()));
        Ok(())
    }
}

/// One incarnation: build the two-query session over one Linear Road
/// feed, attach audit sinks, run. Returns the run error, if any.
fn incarnation(
    cfg: &Config,
    log: &Arc<Mutex<Vec<(&'static str, usize, usize)>>>,
    crash_after: Option<usize>,
    duration: Duration,
) -> lmstream::error::Result<(Session<'static>, lmstream::error::Result<()>)> {
    // Both queries are stateless (filter + select): window state is not
    // checkpointed, so replay determinism holds per batch.
    let slow = QueryBuilder::scan("slow-traffic")
        .filter("speed", Predicate::Lt(60.0))
        .select(&["timestamp", "vehicle", "speed", "segment"])
        .build()?;
    let workload = Workload::new("slow-traffic", slow, Traffic::constant_default(), |seed| {
        Box::new(linear_road::LinearRoadGen::new(seed))
    });

    let mut session = Session::new(cfg.clone())?;
    let slow_id = session.register(workload)?;
    let fast = QueryBuilder::scan("fast-traffic")
        .filter("speed", Predicate::Ge(80.0))
        .select(&["timestamp", "vehicle", "speed"])
        .build()?;
    let fast_id = session.register_shared(slow_id, "fast-traffic", fast)?;

    session.set_sink(
        slow_id,
        Box::new(AuditSink { query: "slow-traffic", log: log.clone(), crash_after: None, delivered: 0 }),
    )?;
    // The crash lands on the second query's sink, mid-round: the round's
    // WAL record is durable, the first query may already have delivered.
    session.set_sink(
        fast_id,
        Box::new(AuditSink { query: "fast-traffic", log: log.clone(), crash_after, delivered: 0 }),
    )?;

    let outcome = session.run(duration).map(|_| ());
    Ok((session, outcome))
}

fn main() -> lmstream::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let crash_after: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(11);

    let dir = std::env::temp_dir().join(format!("lmstream-recovery-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = Config {
        mode: Mode::LmStream,
        checkpoint_dir: Some(dir.join("ckpt").to_string_lossy().to_string()),
        wal_dir: Some(dir.join("wal").to_string_lossy().to_string()),
        recovery_mode: RecoveryMode::Precise,
        seed,
        ..Config::default()
    };

    let log = Arc::new(Mutex::new(Vec::new()));

    // Incarnation 1: runs until the injected sink failure kills it.
    let (_s1, outcome) =
        incarnation(&cfg, &log, Some(crash_after), Duration::from_secs(600))?;
    let err = outcome.expect_err("the injected sink failure must abort the run");
    let delivered_before = log.lock().unwrap().len();
    println!("incarnation 1: crashed after {delivered_before} deliveries ({err})");

    // Incarnation 2: same directories — reconcile and resume.
    let (s2, outcome) = incarnation(&cfg, &log, None, Duration::from_secs(300))?;
    outcome?;
    let report = s2.recovery_report().expect("a WAL-backed restart reports its recovery");
    for src in &report.sources {
        println!(
            "incarnation 2: source `{}` replayed {} logged micro-batch(es), \
             skipped {}, lost {} (mode {:?})",
            src.source,
            src.replay.len(),
            src.skipped,
            src.lost.len(),
            src.mode,
        );
    }

    // The ledger is the proof: per query, the delivered log must hold
    // every batch index exactly once, contiguously from 0 up to the
    // ledger's durable high-water mark.
    let ledger = SinkLedger::open(&dir.join("wal").join("sink.ledger.json"))?;
    let mut per_query: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for &(q, idx, _) in log.lock().unwrap().iter() {
        per_query.entry(q).or_default().push(idx);
    }
    let mut rows = Vec::new();
    for (query, indices) in &per_query {
        let mut sorted = indices.clone();
        sorted.sort_unstable();
        let contiguous = sorted.iter().enumerate().all(|(i, &v)| i == v);
        assert!(contiguous, "{query}: duplicated or missing batch index in {sorted:?}");
        let hw = ledger
            .high_water(query)
            .expect("every query that delivered has a ledger entry");
        assert_eq!(hw.batch as usize, sorted.len() - 1, "{query}: ledger/high-water drift");
        let live_rows: usize = log
            .lock()
            .unwrap()
            .iter()
            .filter(|(q, _, _)| q == query)
            .map(|&(_, _, r)| r)
            .sum();
        rows.push(vec![
            query.to_string(),
            sorted.len().to_string(),
            format!("0..{}", sorted.len() - 1),
            hw.batch.to_string(),
            live_rows.to_string(),
        ]);
    }
    print_table(
        "Exactly-once across the crash: each batch index delivered once, \
         matching the durable ledger",
        &["query", "deliveries", "indices", "ledger high-water", "live rows"],
        &rows,
    );
    println!(
        "\nno duplicated sink rows: the replayed tail was re-executed but the \
         ledger suppressed re-delivery of the {delivered_before} pre-crash outputs"
    );
    Ok(())
}
