//! Quickstart: build a streaming query, run LMStream for two simulated
//! minutes, and print the headline metrics.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use lmstream::config::{Config, Mode};
use lmstream::coordinator::driver;
use lmstream::engine::ops::aggregate::AggSpec;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::window::WindowSpec;
use lmstream::query::QueryBuilder;
use lmstream::session::Session;
use lmstream::source::traffic::Traffic;
use lmstream::workloads::{linear_road, Workload};
use std::time::Duration;

fn main() -> lmstream::Result<()> {
    // 1. Author a streaming query with the fluent builder — this is the
    //    public API a downstream user writes against: a windowed
    //    congestion report over the Linear Road feed.
    let query = QueryBuilder::scan("quickstart")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
        .filter("speed", Predicate::Lt(60.0))
        .expand()
        .shuffle("segment")
        .aggregate(
            &["highway", "segment"],
            vec![AggSpec::avg("speed", "avgSpeed"), AggSpec::count("reports")],
            Some(("avgSpeed", Predicate::Lt(40.0))),
        )
        .build()?;

    // 2. Attach a data source (Linear Road position reports, 1000 rows/s).
    let workload = Workload::new("quickstart", query, Traffic::constant_default(), |seed| {
        Box::new(linear_road::LinearRoadGen::new(seed))
    });

    // 3. Register it on a Session — the session owns the shared
    //    coordinator state (device model, online optimizer, config) and
    //    can multiplex further queries over the same source (see
    //    examples/multi_query.rs) — and run under the LMStream
    //    coordinator (dynamic batching + dynamic device planning +
    //    online optimizer) on the simulated cluster.
    let cfg = Config { mode: Mode::LmStream, ..Config::default() };
    let mut session = Session::new(cfg)?;
    session.register(workload.clone())?;
    let result = session.run(Duration::from_secs(120))?.remove(0);

    println!("quickstart: {} micro-batches in 2 simulated minutes", result.batches.len());
    println!("  avg end-to-end latency : {:.3} s", result.avg_latency);
    println!("  avg throughput (Eq. 4) : {:.1} KB/s", result.avg_throughput / 1024.0);
    println!("  final inflection point : {:.0} KB", result.final_inf_pt / 1024.0);
    println!(
        "  last plan: {}/{} ops on GPU",
        result.batches.last().map(|b| b.gpu_ops).unwrap_or(0),
        result.batches.last().map(|b| b.total_ops).unwrap_or(0),
    );

    // 4. The same workload under the throughput-oriented baseline, for
    //    contrast (static 10 s trigger, all-GPU) — via the single-query
    //    `driver::run` shim this time, which builds a one-shot session.
    let bl_cfg = Config { mode: Mode::Baseline, ..Config::default() };
    let bl = driver::run(&workload, &bl_cfg, Duration::from_secs(120), None)?;
    println!(
        "baseline for contrast: latency {:.3} s, throughput {:.1} KB/s",
        bl.avg_latency,
        bl.avg_throughput / 1024.0
    );
    Ok(())
}
