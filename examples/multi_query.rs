//! Multi-query session: two queries sharing one source stream, driven
//! concurrently through a single micro-batch loop.
//!
//! The session-centric surface generalizes the paper's coordinator to
//! concurrent workloads: one `Session` owns the shared state (device
//! model, online optimizer, inflection point, config), admission is
//! shared per source (tightest latency bound across the source's
//! queries), while planning (`MapDevice`), window state, learned size
//! ratios and metrics stay per query.
//!
//! Registered here, over one Linear Road position-report feed:
//!
//! * `vehicle-matches` — the LR1-style sliding-window self-join,
//! * `congestion` — an aggregation query whose DAG also *branches*:
//!   the filtered stream fans out to a slow-vehicle sort sink and to a
//!   per-segment congestion aggregate.
//!
//! ```bash
//! cargo run --release --offline --example multi_query [minutes] [seed]
//! ```

use lmstream::config::{Config, Mode};
use lmstream::engine::ops::aggregate::AggSpec;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::sink::CountingSink;
use lmstream::engine::window::WindowSpec;
use lmstream::query::QueryBuilder;
use lmstream::session::Session;
use lmstream::source::traffic::Traffic;
use lmstream::util::bench::print_table;
use lmstream::workloads::{linear_road, Workload};
use std::time::Duration;

fn main() -> lmstream::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let minutes: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(5);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(7);

    // Query 1 — windowed self-join (LR1S shape): which vehicles seen in
    // this micro-batch also reported within the last 30 s?
    let join_query = QueryBuilder::scan("vehicle-matches")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
        .join_window("vehicle", "vehicle")
        .select(&[
            "timestamp", "vehicle", "speed", "highway", "lane", "direction", "segment",
        ])
        .build()?;
    let workload = Workload::new(
        "vehicle-matches",
        join_query,
        Traffic::constant_default(),
        |seed| Box::new(linear_road::LinearRoadGen::new(seed)),
    );

    let cfg = Config { mode: Mode::LmStream, seed, ..Config::default() };
    let mut session = Session::new(cfg)?;
    let join_id = session.register(workload)?;

    // Query 2 — shares the same source stream. Its DAG branches: the
    // slow-traffic filter fans out to (a) a sorted slow-vehicle feed
    // (extra sink) and (b) the per-segment congestion aggregate.
    let congestion = QueryBuilder::scan("congestion")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(10)))
        .filter("speed", Predicate::Lt(60.0))
        .branch(|b| b.sort("speed", false))
        .shuffle("segment")
        .aggregate(
            &["highway", "direction", "segment"],
            vec![AggSpec::avg("speed", "avgSpeed"), AggSpec::count("reports")],
            Some(("avgSpeed", Predicate::Lt(40.0))),
        )
        .build()?;
    let congestion_id = session.register_shared(join_id, "congestion", congestion)?;

    // Per-query sinks: the congestion aggregate's primary output and its
    // slow-vehicle *branch* sink (DAG node 2 — scan(0) → filter(1) →
    // {sort(2), shuffle(3) → aggregate(4)}) each get a counting sink;
    // branch results used to be dropped on the session floor.
    session.set_sink(congestion_id, Box::new(CountingSink::default()))?;
    session.set_branch_sink(congestion_id, 2, Box::new(CountingSink::default()))?;

    // One loop drives both queries over every admitted micro-batch —
    // planned *jointly* (cross-query GPU co-scheduling) and executed on
    // one shared GPU timeline.
    let results = session.run(Duration::from_secs(minutes * 60))?;

    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.workload.clone(),
                r.batches.len().to_string(),
                format!("{:.3}", r.avg_latency),
                format!("{:.3}", r.avg_max_latency()),
                format!("{:.1}", r.avg_throughput / 1024.0),
                format!("{:.3}", r.avg_proc()),
                format!(
                    "{}/{}",
                    r.batches.last().map(|b| b.gpu_ops).unwrap_or(0),
                    r.batches.last().map(|b| b.total_ops).unwrap_or(0)
                ),
            ]
        })
        .collect();
    print_table(
        &format!(
            "Two queries, one source, one micro-batch loop \
             ({minutes} simulated minutes, constant traffic)"
        ),
        &["query", "batches", "avg lat(s)", "avg maxlat(s)", "KB/s", "proc(s)", "gpu ops"],
        &rows,
    );

    // Both queries process every admitted batch: batch counts agree.
    assert_eq!(results[0].batches.len(), results[1].batches.len());
    assert!(!results[0].batches.is_empty(), "no batches admitted");
    println!(
        "\nshared admission: {} micro-batches admitted once, co-scheduled \
         across both queries on one GPU timeline\nfinal inflection point: {:.1} KB",
        results[0].batches.len(),
        results[0].final_inf_pt / 1024.0
    );

    // The registered sinks saw every batch (the branch sink received the
    // slow-vehicle feed that previously never left the executor); they
    // can be reclaimed for inspection once the run ends.
    assert!(session.take_sink(congestion_id).is_some());
    assert!(session.take_branch_sink(congestion_id, 2).is_some());
    let gpu_waits: usize = results
        .iter()
        .flat_map(|r| r.batches.iter())
        .filter(|b| b.gpu_wait > Duration::ZERO)
        .count();
    println!(
        "cross-query contention: {gpu_waits} batch executions waited on the \
         shared GPU timeline"
    );
    Ok(())
}
