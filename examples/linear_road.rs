//! Linear Road end-to-end driver — the repo's primary validation run.
//!
//! Runs all three LR queries (Table III) under Baseline and LMStream on
//! the full simulated pipeline (real operator execution + calibrated
//! device timing), 10 simulated minutes each, and reports the paper's
//! headline metrics: average end-to-end latency (Fig. 6), Eq. 4 average
//! throughput (Fig. 7), and the latency-improvement / throughput-ratio
//! summary of §V-B. Recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --offline --example linear_road [minutes] [seed]
//! ```

use lmstream::config::{Config, Mode};
// `driver::run` is the single-query shim over `session::Session` —
// exactly what these one-workload-at-a-time comparisons need.
use lmstream::coordinator::driver;
use lmstream::util::bench::print_table;
use lmstream::util::stats::percentile;
use lmstream::workloads;
use std::time::Duration;

fn main() -> lmstream::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let minutes: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(10);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(7);

    let mut rows = Vec::new();
    for name in ["lr1s", "lr1t", "lr2s"] {
        let w = workloads::by_name(name)?;
        let lm_cfg = Config { mode: Mode::LmStream, seed, ..Config::default() };
        let bl_cfg = Config { mode: Mode::Baseline, seed, ..Config::default() };
        let lm = driver::run(&w, &lm_cfg, Duration::from_secs(minutes * 60), None)?;
        let bl = driver::run(&w, &bl_cfg, Duration::from_secs(minutes * 60), None)?;
        let impr = (1.0 - lm.avg_latency / bl.avg_latency) * 100.0;
        let ratio = lm.avg_throughput / bl.avg_throughput;
        rows.push(vec![
            w.name.to_string(),
            format!("{:.2}", bl.avg_latency),
            format!("{:.2}", lm.avg_latency),
            format!("{impr:.1}%"),
            format!("{:.1}", bl.avg_throughput / 1024.0),
            format!("{:.1}", lm.avg_throughput / 1024.0),
            format!("{ratio:.2}x"),
            format!("{:.2}", percentile(&lm.dataset_latencies, 99.0)),
            format!("{:.2}", percentile(&bl.dataset_latencies, 99.0)),
        ]);
    }
    print_table(
        &format!("Linear Road end-to-end ({minutes} simulated minutes, constant traffic)"),
        &[
            "query", "BL lat(s)", "LM lat(s)", "lat impr", "BL KB/s", "LM KB/s",
            "thpt ratio", "LM p99", "BL p99",
        ],
        &rows,
    );
    println!(
        "\npaper reference shape: LMStream latency lower on all queries (up to\n\
         ~70% on tumbling windows), throughput >= baseline (up to ~1.74x on LR1S)."
    );
    Ok(())
}
