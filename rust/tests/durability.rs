//! Differential crash-recovery harness for the durability subsystem.
//!
//! The oracle is analytic: a deterministic generator stamps every row
//! with its (tick, row-id) identity, the query is stateless
//! (filter + select — window state is not checkpointed, so windowed
//! aggregates are out of scope here; see ARCHITECTURE.md §Durability),
//! and sinks deliver whole datasets in tick order — so the flattened
//! delivered row sequence of ANY correct run must be an exact prefix of
//! the analytic oracle sequence. A crash is injected at an arbitrary
//! batch boundary (property-tested over crash points, chunk layouts and
//! sources), the session resumes from checkpoint + WAL in a fresh
//! incarnation, and the concatenated deliveries across incarnations
//! must still be that exact prefix: bit-identical to an uninterrupted
//! run, with zero duplicates (`Precise`/`Rollback`), while `Gap`'s loss
//! report must exactly account for every skipped batch id.

use lmstream::config::{Config, Mode};
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::column::{Column, ColumnBatch, Field, Schema};
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::sink::Sink;
use lmstream::error::{Error, Result};
use lmstream::query::QueryBuilder;
use lmstream::session::Session;
use lmstream::sim::Time;
use lmstream::source::stream::RowGen;
use lmstream::source::traffic::Traffic;
use lmstream::workloads::Workload;
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ---------- deterministic identity-stamped workload ----------

/// Every row is (t = tick, v = tick*10_000 + i, m = i % 10): globally
/// unique (t, v) identities, exact in f32 for the tick ranges used.
struct IdentGen;

impl RowGen for IdentGen {
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
        let schema =
            Schema::new(vec![Field::f32("t"), Field::f32("v"), Field::f32("m")]);
        let t: Vec<f32> = vec![tick as f32; rows];
        let v: Vec<f32> =
            (0..rows).map(|i| (tick * 10_000 + i as u64) as f32).collect();
        let m: Vec<f32> = (0..rows).map(|i| (i % 10) as f32).collect();
        ColumnBatch::new(
            schema,
            vec![Column::F32(t.into()), Column::F32(v.into()), Column::F32(m.into())],
        )
        .unwrap()
    }
}

fn make_gen(_seed: u64) -> Box<dyn RowGen> {
    Box::new(IdentGen)
}

/// Stateless query (filter keeps rows with m < 6, i.e. i % 10 < 6).
fn ident_query(name: &str) -> lmstream::query::dag::Query {
    QueryBuilder::scan(name)
        .filter("m", Predicate::Lt(6.0))
        .select(&["t", "v"])
        .build()
        .unwrap()
}

fn ident_workload(name: &'static str, rows_per_tick: usize) -> Workload {
    Workload::new(
        name,
        ident_query(name),
        Traffic::Constant { rows: rows_per_tick },
        make_gen,
    )
}

/// The analytic oracle: the exact flattened row sequence any correct
/// run's sink must observe (one dataset per tick, in tick order).
fn oracle(rows_per_tick: usize, max_tick: u64) -> Vec<(f32, f32)> {
    let mut out = Vec::new();
    for tick in 0..=max_tick {
        for i in 0..rows_per_tick {
            if i % 10 < 6 {
                out.push((tick as f32, (tick * 10_000 + i as u64) as f32));
            }
        }
    }
    out
}

fn assert_oracle_prefix(delivered: &[(f32, f32)], rows_per_tick: usize, ctx: &str) {
    let full = oracle(rows_per_tick, 4_000);
    assert!(delivered.len() <= full.len(), "{ctx}: run too long for oracle");
    assert_eq!(
        delivered,
        &full[..delivered.len()],
        "{ctx}: delivered rows diverge from the uninterrupted oracle"
    );
}

// ---------- crash-injecting, row-recording sink ----------

/// Records every delivered (t, v) row into shared state and optionally
/// fails the Nth delivery of its incarnation ("the sink machine died").
struct RecordingSink {
    rows: Arc<Mutex<Vec<(f32, f32)>>>,
    fail_after: Option<usize>,
    delivered: usize,
}

impl RecordingSink {
    fn new(rows: &Arc<Mutex<Vec<(f32, f32)>>>, fail_after: Option<usize>) -> RecordingSink {
        RecordingSink { rows: Arc::clone(rows), fail_after, delivered: 0 }
    }
}

impl Sink for RecordingSink {
    fn deliver(&mut self, _i: usize, result: &ChunkedBatch, _t: Time) -> Result<()> {
        if self.fail_after == Some(self.delivered) {
            return Err(Error::Durability("injected crash".into()));
        }
        self.delivered += 1;
        let b = result.coalesce();
        let t = b.column("t").unwrap().as_f32().unwrap();
        let v = b.column("v").unwrap().as_f32().unwrap();
        let mut rows = self.rows.lock().unwrap();
        for i in 0..b.rows() {
            if b.validity.is_live(i) {
                rows.push((t[i], v[i]));
            }
        }
        Ok(())
    }
}

// ---------- harness plumbing ----------

struct Dirs {
    ckpt: PathBuf,
    wal: PathBuf,
}

fn dirs(name: &str) -> Dirs {
    let base = std::env::temp_dir()
        .join(format!("lmstream-durability-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    Dirs { ckpt: base.join("ckpt"), wal: base.join("wal") }
}

fn durable_cfg(d: &Dirs, mode: &str) -> Config {
    Config {
        mode: Mode::LmStream,
        checkpoint_dir: Some(d.ckpt.to_string_lossy().into_owned()),
        wal_dir: Some(d.wal.to_string_lossy().into_owned()),
        recovery_mode: lmstream::durability::RecoveryMode::parse(mode).unwrap(),
        seed: 11,
        ..Config::default()
    }
}

/// One incarnation: fresh session, one identity workload, a recording
/// (and optionally crashing) sink; returns the run outcome and whether
/// a recovery reconciliation reported losses.
fn incarnation(
    cfg: Config,
    workload: Workload,
    rows_sink: &Arc<Mutex<Vec<(f32, f32)>>>,
    fail_after: Option<usize>,
    duration: Duration,
) -> (Result<()>, Vec<lmstream::durability::recover::LossEntry>, u64) {
    let mut session = Session::new(cfg).unwrap();
    let qid = session.register(workload).unwrap();
    session
        .set_sink(qid, Box::new(RecordingSink::new(rows_sink, fail_after)))
        .unwrap();
    let outcome = session.run(duration).map(|_| ());
    let (lost, skipped) = match session.recovery_report() {
        Some(rep) => (
            rep.sources.iter().flat_map(|s| s.lost.iter().cloned()).collect(),
            rep.sources.iter().map(|s| s.skipped).sum(),
        ),
        None => (Vec::new(), 0),
    };
    (outcome, lost, skipped)
}

// ---------- the differential property tests ----------

#[test]
fn precise_crash_resume_is_bit_identical_with_zero_duplicates() {
    // Property sweep: crash point × chunk layout (rows per tick changes
    // dataset sizes, hence admission grouping and chunk counts).
    for &rows_per_tick in &[4usize, 10] {
        for &crash_at in &[0usize, 1, 2, 4] {
            let name = format!("precise-{rows_per_tick}-{crash_at}");
            let d = dirs(&name);
            let rows = Arc::new(Mutex::new(Vec::new()));

            // Incarnation 1: crash at the `crash_at`-th delivery.
            // (crash_at = 0 also covers the admitted-but-never-delivered
            // shape: the batch is in the WAL, the ledger and checkpoint
            // know nothing.)
            let (out, _, _) = incarnation(
                durable_cfg(&d, "precise"),
                ident_workload("durprec", rows_per_tick),
                &rows,
                Some(crash_at),
                Duration::from_secs(60),
            );
            assert!(out.is_err(), "{name}: the injected crash must abort the run");
            let delivered_before = rows.lock().unwrap().len();

            // Incarnation 2: resume from checkpoint + WAL.
            let (out, lost, skipped) = incarnation(
                durable_cfg(&d, "precise"),
                ident_workload("durprec", rows_per_tick),
                &rows,
                None,
                Duration::from_secs(60),
            );
            out.unwrap();
            assert!(lost.is_empty(), "{name}: precise recovery reported losses");
            assert_eq!(skipped, 0, "{name}: precise recovery skipped records");

            // Differential check: the concatenation across incarnations
            // is an exact prefix of the uninterrupted oracle — replayed
            // batches were re-delivered exactly once (zero duplicates),
            // already-delivered ones were suppressed by the ledger.
            let all = rows.lock().unwrap().clone();
            assert!(all.len() > delivered_before, "{name}: resume delivered nothing");
            assert_oracle_prefix(&all, rows_per_tick, &name);
        }
    }
}

#[test]
fn rollback_crash_resume_has_no_duplicate_sink_rows() {
    for &crash_at in &[0usize, 2, 3] {
        let name = format!("rollback-{crash_at}");
        let d = dirs(&name);
        let rows = Arc::new(Mutex::new(Vec::new()));
        let (out, _, _) = incarnation(
            durable_cfg(&d, "rollback"),
            ident_workload("durroll", 10),
            &rows,
            Some(crash_at),
            Duration::from_secs(60),
        );
        assert!(out.is_err(), "{name}: the injected crash must abort the run");

        let (out, lost, _) = incarnation(
            durable_cfg(&d, "rollback"),
            ident_workload("durroll", 10),
            &rows,
            None,
            Duration::from_secs(60),
        );
        out.unwrap();
        assert!(lost.is_empty(), "{name}: rollback recovery reported losses");

        // Rollback trades internal-state fidelity, never output: for a
        // stateless query the sink stream is still the exact oracle
        // prefix — and exact-prefix equality implies zero duplicates.
        let all = rows.lock().unwrap().clone();
        assert_oracle_prefix(&all, 10, &name);
    }
}

#[test]
fn gap_mode_loss_report_exactly_accounts_skipped_batch_ids() {
    for &crash_at in &[1usize, 3] {
        let name = format!("gap-{crash_at}");
        let d = dirs(&name);
        let rows_per_tick = 10usize;
        let rows = Arc::new(Mutex::new(Vec::new()));
        let (out, _, _) = incarnation(
            durable_cfg(&d, "gap"),
            ident_workload("durgap", rows_per_tick),
            &rows,
            Some(crash_at),
            Duration::from_secs(60),
        );
        assert!(out.is_err(), "{name}: the injected crash must abort the run");

        let (out, lost, _) = incarnation(
            durable_cfg(&d, "gap"),
            ident_workload("durgap", rows_per_tick),
            &rows,
            None,
            Duration::from_secs(60),
        );
        out.unwrap();
        // The crashed round was in the WAL but not checkpointed: gap
        // mode must surface it as accounted loss, not replay it.
        assert!(!lost.is_empty(), "{name}: no loss reported for the crashed round");

        // With constant traffic every tick yields exactly one dataset,
        // so dataset id == tick: the loss report's batch ids map
        // directly onto oracle ticks.
        let lost_ticks: BTreeSet<u64> =
            lost.iter().flat_map(|l| l.dataset_ids.iter().copied()).collect();
        assert!(!lost_ticks.is_empty(), "{name}: loss entries carry no dataset ids");
        for l in &lost {
            // Raw (pre-filter) rows: rows_per_tick per lost dataset.
            assert_eq!(
                l.rows,
                l.dataset_ids.len() * rows_per_tick,
                "{name}: loss entry row count wrong"
            );
        }

        // Exact accounting: delivered ∪ lost must tile the oracle
        // prefix with no overlap — every processed tick was either
        // delivered exactly once or reported lost, never both/neither.
        let all = rows.lock().unwrap().clone();
        let delivered_ticks: BTreeSet<u64> =
            all.iter().map(|&(t, _)| t as u64).collect();
        assert!(
            delivered_ticks.is_disjoint(&lost_ticks),
            "{name}: a tick was both delivered and reported lost"
        );
        let max_tick = delivered_ticks
            .iter()
            .chain(lost_ticks.iter())
            .copied()
            .max()
            .unwrap();
        let expected: Vec<(f32, f32)> = oracle(rows_per_tick, max_tick)
            .into_iter()
            .filter(|&(t, _)| !lost_ticks.contains(&(t as u64)))
            .collect();
        assert_eq!(all, expected, "{name}: delivered + lost don't tile the oracle");
    }
}

#[test]
fn multi_query_partial_round_redelivery_is_suppressed() {
    // Two queries on one source. The crash lands on the *side* query's
    // delivery, after the primary's delivery of the same round was
    // already ledgered — on replay the primary's re-delivery must be
    // suppressed while the side query receives the batch it never got.
    let d = dirs("multiq");
    let rows_per_tick = 10usize;
    let primary_rows = Arc::new(Mutex::new(Vec::new()));
    let side_rows = Arc::new(Mutex::new(Vec::new()));

    let run = |fail_side: Option<usize>,
               primary_rows: &Arc<Mutex<Vec<(f32, f32)>>>,
               side_rows: &Arc<Mutex<Vec<(f32, f32)>>>| {
        let mut session = Session::new(durable_cfg(&d, "precise")).unwrap();
        let qid = session.register(ident_workload("durmq", rows_per_tick)).unwrap();
        let side = session
            .register_shared(qid, "durmq-side", ident_query("durmq-side"))
            .unwrap();
        session
            .set_sink(qid, Box::new(RecordingSink::new(primary_rows, None)))
            .unwrap();
        session
            .set_sink(side, Box::new(RecordingSink::new(side_rows, fail_side)))
            .unwrap();
        session.run(Duration::from_secs(60)).map(|_| ())
    };

    assert!(run(Some(2), &primary_rows, &side_rows).is_err(), "crash must abort");
    assert!(run(None, &primary_rows, &side_rows).is_ok());

    // Both queries' streams are exact oracle prefixes: no duplicates on
    // the primary (whose crashed-round delivery was ledgered before the
    // side query failed), no holes on the side.
    let p = primary_rows.lock().unwrap().clone();
    let s = side_rows.lock().unwrap().clone();
    assert_oracle_prefix(&p, rows_per_tick, "multiq primary");
    assert_oracle_prefix(&s, rows_per_tick, "multiq side");
    assert!(!p.is_empty() && !s.is_empty());
}

#[test]
fn ledger_persists_once_per_round_not_per_delivery() {
    // Two queries on one source: every round performs two deliveries
    // but the ledger batches its durable write — exactly one persist
    // per round, not one per delivery.
    let d = dirs("persists");
    let primary_rows = Arc::new(Mutex::new(Vec::new()));
    let side_rows = Arc::new(Mutex::new(Vec::new()));

    let mut session = Session::new(durable_cfg(&d, "precise")).unwrap();
    let qid = session.register(ident_workload("durbatch", 10)).unwrap();
    let side = session
        .register_shared(qid, "durbatch-side", ident_query("durbatch-side"))
        .unwrap();
    session
        .set_sink(qid, Box::new(RecordingSink::new(&primary_rows, None)))
        .unwrap();
    session
        .set_sink(side, Box::new(RecordingSink::new(&side_rows, None)))
        .unwrap();
    let results = session.run(Duration::from_secs(60)).unwrap();

    let rounds = results[0].batches.len();
    let deliveries: usize = results.iter().map(|r| r.batches.len()).sum();
    let persists = session.ledger_persists();
    assert!(rounds >= 2, "need multiple rounds to observe batching");
    assert_eq!(deliveries, 2 * rounds, "both queries deliver every round");
    assert!(persists > 0, "deliveries must be made durable");
    assert!(
        persists <= rounds,
        "persists ({persists}) must be per-round, not per-delivery \
         ({deliveries} deliveries over {rounds} rounds)"
    );
}

#[test]
fn wal_group_commit_fsyncs_once_per_source_per_round() {
    // The data-path WAL frames every admitted batch, then commits with
    // one fsync per admitting source per round — the append-before-
    // execute ordering is pinned by the recovery tests above; this pins
    // the sync *count*.
    let d = dirs("walfsync");
    let rows = Arc::new(Mutex::new(Vec::new()));

    let mut session = Session::new(durable_cfg(&d, "precise")).unwrap();
    let qid = session.register(ident_workload("durfsync", 10)).unwrap();
    session
        .set_sink(qid, Box::new(RecordingSink::new(&rows, None)))
        .unwrap();
    let results = session.run(Duration::from_secs(60)).unwrap();

    let rounds = results[0].batches.len();
    let fsyncs = session.wal_fsyncs();
    assert!(rounds >= 2, "need multiple rounds to observe batching");
    assert!(fsyncs > 0, "durable appends must reach disk");
    assert!(
        fsyncs <= rounds,
        "fsyncs ({fsyncs}) must be one group commit per round for a \
         single source ({rounds} rounds)"
    );
}

#[test]
fn two_sources_recover_independently() {
    // Crash with two registered sources (each with its own WAL and
    // checkpoint, different chunk layouts); both must resume to exact
    // oracle prefixes.
    let d = dirs("twosrc");
    let rows_a = Arc::new(Mutex::new(Vec::new()));
    let rows_b = Arc::new(Mutex::new(Vec::new()));

    let run = |fail_a: Option<usize>,
               rows_a: &Arc<Mutex<Vec<(f32, f32)>>>,
               rows_b: &Arc<Mutex<Vec<(f32, f32)>>>| {
        let mut session = Session::new(durable_cfg(&d, "precise")).unwrap();
        let qa = session.register(ident_workload("dursrca", 4)).unwrap();
        let qb = session.register(ident_workload("dursrcb", 10)).unwrap();
        session
            .set_sink(qa, Box::new(RecordingSink::new(rows_a, fail_a)))
            .unwrap();
        session
            .set_sink(qb, Box::new(RecordingSink::new(rows_b, None)))
            .unwrap();
        session.run(Duration::from_secs(60)).map(|_| ())
    };

    assert!(run(Some(3), &rows_a, &rows_b).is_err(), "crash must abort");
    assert!(run(None, &rows_a, &rows_b).is_ok());

    let a = rows_a.lock().unwrap().clone();
    let b = rows_b.lock().unwrap().clone();
    assert_oracle_prefix(&a, 4, "source a");
    assert_oracle_prefix(&b, 10, "source b");
    assert!(!a.is_empty() && !b.is_empty());
}

#[test]
fn cluster_rounds_keep_one_ledger_entry_per_reassembled_batch() {
    // Cluster path: per-executor outputs reassemble into one result
    // before delivery, so a single ledger entry covers the whole batch
    // — crash + resume must still yield the exact oracle prefix.
    let d = dirs("cluster");
    let rows = Arc::new(Mutex::new(Vec::new()));
    let cfg = || Config {
        cluster: Some(lmstream::cluster::ClusterSpec::paper()),
        ..durable_cfg(&d, "precise")
    };
    let (out, _, _) = incarnation(
        cfg(),
        ident_workload("durclu", 10),
        &rows,
        Some(1),
        Duration::from_secs(60),
    );
    assert!(out.is_err(), "crash must abort");
    let (out, lost, _) = incarnation(
        cfg(),
        ident_workload("durclu", 10),
        &rows,
        None,
        Duration::from_secs(60),
    );
    out.unwrap();
    assert!(lost.is_empty());
    let all = rows.lock().unwrap().clone();
    assert_oracle_prefix(&all, 10, "cluster");
    assert!(!all.is_empty());
}

#[test]
fn without_wal_dir_behavior_is_unchanged_and_unreported() {
    // wal_dir unset: no recovery report, and two identical fresh runs
    // produce identical sink streams (the pre-durability engine).
    let run = || {
        let rows = Arc::new(Mutex::new(Vec::new()));
        let cfg = Config { mode: Mode::LmStream, seed: 11, ..Config::default() };
        let mut session = Session::new(cfg).unwrap();
        let qid = session.register(ident_workload("durplain", 10)).unwrap();
        session
            .set_sink(qid, Box::new(RecordingSink::new(&rows, None)))
            .unwrap();
        session.run(Duration::from_secs(30)).unwrap();
        assert!(session.recovery_report().is_none());
        let got = rows.lock().unwrap().clone();
        got
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    assert!(!a.is_empty());
    assert_oracle_prefix(&a, 10, "plain");
}

#[test]
fn stream_rebase_preserves_lifetime_ingest_accounting() {
    // Recovery fast-forwards a fresh incarnation's stream to the
    // recovered horizon (a rebase). Lifetime ingest counters must
    // survive it — they account the logical stream, not one
    // incarnation. (The pre-fix code zeroed them in `fast_forward`,
    // undercounting every post-crash ingest report.)
    let mut stream = ident_workload("duracct", 10).make_stream(11);
    let polled = stream.poll(Time::from_secs_f64(5.0));
    assert_eq!(polled.len(), 6); // ticks 0..=5
    let (n_before, b_before) = stream.totals();
    assert_eq!(n_before, 6);

    // Crash: the next incarnation's stream fast-forwards through
    // everything the checkpoint ∪ WAL horizon covers...
    let mut resumed = ident_workload("duracct", 10).make_stream(11);
    resumed.fast_forward(Time::from_secs_f64(5.0));
    let (n_mid, b_mid) = resumed.totals();
    assert_eq!(n_mid, 6, "rebase dropped consumed-tick accounting");
    assert_eq!(b_mid, b_before, "rebase dropped consumed-byte accounting");

    // ...and post-resume ingest extends the same lifetime count.
    let more = resumed.poll(Time::from_secs_f64(2.0));
    assert!(!more.is_empty());
    let (n_after, b_after) = resumed.totals();
    assert_eq!(n_after, n_mid + more.len() as u64);
    assert!(b_after > b_mid);
}

#[test]
fn clean_restart_after_graceful_run_replays_nothing() {
    // No crash: run to completion, then restart. Everything processed
    // is checkpointed (the WAL is truncated on checkpoint), so the
    // second incarnation replays nothing and appends fresh data only.
    let d = dirs("clean");
    let rows = Arc::new(Mutex::new(Vec::new()));
    let (out, lost, skipped) = incarnation(
        durable_cfg(&d, "precise"),
        ident_workload("durclean", 10),
        &rows,
        None,
        Duration::from_secs(45),
    );
    out.unwrap();
    assert!(lost.is_empty() && skipped == 0);
    let after_first = rows.lock().unwrap().len();
    assert!(after_first > 0);

    let (out, lost, skipped) = incarnation(
        durable_cfg(&d, "precise"),
        ident_workload("durclean", 10),
        &rows,
        None,
        Duration::from_secs(45),
    );
    out.unwrap();
    assert!(lost.is_empty() && skipped == 0);
    let all = rows.lock().unwrap().clone();
    assert!(all.len() > after_first, "second incarnation made no progress");
    assert_oracle_prefix(&all, 10, "clean restart");
}
