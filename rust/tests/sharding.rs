//! Differential harness for the sharded concurrent session runtime
//! (`Config::shards`).
//!
//! The contract under test is determinism-by-construction: sharded
//! planning is always per source group and the timeline bank grants
//! leases in global source order, so the delivered sink streams must be
//! **bit-identical across shard counts** (shards = 1 is the serial
//! reference) and across repeat runs — thread scheduling must never
//! leak into outputs. On top of that: per-shard admission quotas
//! throttle (and report) without dropping data, executor faults retry
//! without perturbing delivered outputs, and sharded durable runs keep
//! one sink ledger per source.
//!
//! The oracle is the same analytic identity stream the durability and
//! fault-tolerance harnesses use: every row is stamped (tick, row-id),
//! the query is a stateless filter + select, so each source's delivered
//! row sequence must be an exact prefix of its analytic oracle.

use lmstream::cluster::{ClusterSpec, FaultPlan};
use lmstream::config::{Config, Mode};
use lmstream::coordinator::HealthReport;
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::column::{Column, ColumnBatch, Field, Schema};
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::sink::Sink;
use lmstream::error::Result;
use lmstream::query::QueryBuilder;
use lmstream::session::{RunResult, Session};
use lmstream::sim::Time;
use lmstream::source::stream::RowGen;
use lmstream::source::traffic::Traffic;
use lmstream::workloads::Workload;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ---------- deterministic identity-stamped workload ----------

/// Every row is (t = tick, v = tick*10_000 + i, m = i % 10): globally
/// unique (t, v) identities, exact in f32 for the tick ranges used.
struct IdentGen;

impl RowGen for IdentGen {
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
        let schema =
            Schema::new(vec![Field::f32("t"), Field::f32("v"), Field::f32("m")]);
        let t: Vec<f32> = vec![tick as f32; rows];
        let v: Vec<f32> =
            (0..rows).map(|i| (tick * 10_000 + i as u64) as f32).collect();
        let m: Vec<f32> = (0..rows).map(|i| (i % 10) as f32).collect();
        ColumnBatch::new(
            schema,
            vec![Column::F32(t.into()), Column::F32(v.into()), Column::F32(m.into())],
        )
        .unwrap()
    }
}

fn make_gen(_seed: u64) -> Box<dyn RowGen> {
    Box::new(IdentGen)
}

fn ident_query(name: &str) -> lmstream::query::dag::Query {
    QueryBuilder::scan(name)
        .filter("m", Predicate::Lt(6.0))
        .select(&["t", "v"])
        .build()
        .unwrap()
}

fn ident_workload(name: &'static str, rows_per_tick: usize) -> Workload {
    Workload::new(
        name,
        ident_query(name),
        Traffic::Constant { rows: rows_per_tick },
        make_gen,
    )
}

/// The analytic oracle: the exact flattened row sequence any correct
/// run's sink must observe for one source (one dataset per tick).
fn oracle(rows_per_tick: usize, max_tick: u64) -> Vec<(f32, f32)> {
    let mut out = Vec::new();
    for tick in 0..=max_tick {
        for i in 0..rows_per_tick {
            if i % 10 < 6 {
                out.push((tick as f32, (tick * 10_000 + i as u64) as f32));
            }
        }
    }
    out
}

fn assert_oracle_prefix(delivered: &[(f32, f32)], rows_per_tick: usize, ctx: &str) {
    let full = oracle(rows_per_tick, 4_000);
    assert!(delivered.len() <= full.len(), "{ctx}: run too long for oracle");
    assert_eq!(
        delivered,
        &full[..delivered.len()],
        "{ctx}: delivered rows diverge from the oracle"
    );
}

// ---------- recording sink + harness plumbing ----------

struct RecordingSink {
    rows: Arc<Mutex<Vec<(f32, f32)>>>,
}

impl Sink for RecordingSink {
    fn deliver(&mut self, _i: usize, result: &ChunkedBatch, _t: Time) -> Result<()> {
        let b = result.coalesce();
        let t = b.column("t").unwrap().as_f32().unwrap();
        let v = b.column("v").unwrap().as_f32().unwrap();
        let mut rows = self.rows.lock().unwrap();
        for i in 0..b.rows() {
            if b.validity.is_live(i) {
                rows.push((t[i], v[i]));
            }
        }
        Ok(())
    }
}

static NAMES: &[&str] = &["shsrc0", "shsrc1", "shsrc2", "shsrc3"];

/// The online optimizer stays off in every sharded differential run:
/// its asynchronous pickup is wall-clock bounded, the one term the
/// bit-identity contract cannot cover.
fn sharded_cfg(shards: Option<usize>) -> Config {
    Config {
        mode: Mode::LmStream,
        shards,
        online_optimizer: false,
        seed: 11,
        ..Config::default()
    }
}

/// One run over `rows_per_tick.len()` identity sources; returns the run
/// outcome, each source's delivered rows (in delivery order), and the
/// health report.
fn run_sources(
    cfg: Config,
    rows_per_tick: &[usize],
    duration: Duration,
) -> (Result<Vec<RunResult>>, Vec<Vec<(f32, f32)>>, Option<HealthReport>) {
    let mut session = Session::new(cfg).unwrap();
    let mut rows: Vec<Arc<Mutex<Vec<(f32, f32)>>>> = Vec::new();
    for (s, &rpt) in rows_per_tick.iter().enumerate() {
        let qid = session.register(ident_workload(NAMES[s], rpt)).unwrap();
        let sink_rows = Arc::new(Mutex::new(Vec::new()));
        session
            .set_sink(qid, Box::new(RecordingSink { rows: Arc::clone(&sink_rows) }))
            .unwrap();
        rows.push(sink_rows);
    }
    let out = session.run(duration);
    let health = session.health_report().cloned();
    let delivered = rows.iter().map(|r| r.lock().unwrap().clone()).collect();
    (out, delivered, health)
}

// ---------- the differential property tests ----------

/// Tentpole property: shard counts 1, 2 and 4 over the same four
/// sources deliver **bit-identical** per-source sink streams (1 is the
/// serial reference — per-source planning and ticket-ordered leases
/// make the outputs a pure function of the sources, not of the shard
/// layout or thread schedule), and every stream is oracle-exact.
#[test]
fn shard_counts_produce_bit_identical_outputs() {
    let rows_per_tick = [4usize, 7, 10, 13];
    let duration = Duration::from_secs(60);

    let (out1, ref_rows, _) =
        run_sources(sharded_cfg(Some(1)), &rows_per_tick, duration);
    let r1 = out1.unwrap();
    for (s, rows) in ref_rows.iter().enumerate() {
        assert!(!rows.is_empty(), "source {s} delivered nothing");
        assert_oracle_prefix(rows, rows_per_tick[s], &format!("shards=1 src {s}"));
    }

    for &k in &[2usize, 4] {
        let (out, rows, health) =
            run_sources(sharded_cfg(Some(k)), &rows_per_tick, duration);
        let rk = out.unwrap();
        for s in 0..rows_per_tick.len() {
            assert_eq!(
                rows[s], ref_rows[s],
                "shards={k} source {s}: outputs diverge from the serial reference"
            );
            assert_eq!(
                rk[s].batches.len(),
                r1[s].batches.len(),
                "shards={k} source {s}: batch counts diverge"
            );
        }
        // Per-shard accounting covers every source and batch exactly.
        let h = health.expect("completed run reports health");
        assert_eq!(h.shards.len(), k);
        assert_eq!(h.shards.iter().map(|st| st.sources).sum::<usize>(), 4);
        let batches: usize = rk.iter().map(|r| r.batches.len()).sum();
        assert_eq!(h.shards.iter().map(|st| st.batches).sum::<usize>(), batches);
        // Every record carries its source's shard id.
        for (s, r) in rk.iter().enumerate() {
            for b in &r.batches {
                assert_eq!(b.shard, s % k, "source {s} record in wrong shard");
            }
        }
    }
}

/// Same shard count, same seed, run twice: byte-identical deliveries
/// and records — the concurrent workers leak nothing schedule-dependent.
#[test]
fn sharded_runs_are_deterministic_across_repeats() {
    let rows_per_tick = [4usize, 7, 10, 13];
    let duration = Duration::from_secs(60);
    let (out_a, rows_a, _) =
        run_sources(sharded_cfg(Some(2)), &rows_per_tick, duration);
    let (out_b, rows_b, _) =
        run_sources(sharded_cfg(Some(2)), &rows_per_tick, duration);
    let (ra, rb) = (out_a.unwrap(), out_b.unwrap());
    assert_eq!(rows_a, rows_b, "repeat sharded runs diverged");
    for (a, b) in ra.iter().zip(&rb) {
        assert_eq!(a.batches.len(), b.batches.len());
        assert_eq!(a.avg_throughput, b.avg_throughput);
        for (x, y) in a.batches.iter().zip(&b.batches) {
            assert_eq!(x.proc, y.proc, "per-record proc diverged across repeats");
            assert_eq!(x.gpu_wait, y.gpu_wait);
        }
    }
}

/// Per-shard admission quotas: a throttled shard has admissions vetoed
/// (re-buffered, never dropped — its stream stays oracle-exact) and its
/// admitted byte volume pinned well under the unthrottled sibling's,
/// with the vetoes reported per shard.
#[test]
fn shard_quotas_throttle_without_losing_data() {
    let rows_per_tick = [10usize, 10];
    let duration = Duration::from_secs(60);

    // Measure the unthrottled per-shard traffic first.
    let (out, _, health) =
        run_sources(sharded_cfg(Some(2)), &rows_per_tick, duration);
    out.unwrap();
    let free = health.unwrap().shards[0].bytes;
    assert!(free > 0, "unthrottled shard admitted nothing");

    // Throttle shard 0 to a quarter of its free-running rate; shard 1
    // gets an effectively unlimited quota.
    let rate0 = free as f64 / duration.as_secs_f64() / 4.0;
    let cfg = Config {
        shard_quotas: Some(vec![rate0, 1e12]),
        ..sharded_cfg(Some(2))
    };
    let (out, rows, health) = run_sources(cfg, &rows_per_tick, duration);
    out.unwrap();
    let h = health.expect("completed run reports health");
    assert!(
        h.shards[0].quota_vetoes > 0,
        "quota never pushed back on the throttled shard"
    );
    assert_eq!(h.shards[1].quota_vetoes, 0, "unlimited shard was vetoed");
    assert!(
        h.shards[0].bytes < free,
        "throttled shard admitted as much as free-running ({} vs {free})",
        h.shards[0].bytes
    );
    // Vetoed batches are deferred, not dropped: still an exact prefix.
    for (s, r) in rows.iter().enumerate() {
        assert!(!r.is_empty(), "source {s} starved entirely");
        assert_oracle_prefix(r, rows_per_tick[s], &format!("quota src {s}"));
    }
}

/// Executor faults under sharding: retries are swept per source on the
/// survivor topology and the delivered outputs stay bit-identical to
/// the fault-free sharded run — recovery cost shows up in the records
/// and the per-shard accounting, never in the data.
#[test]
fn sharded_fault_retries_keep_outputs_identical() {
    let rows_per_tick = [4usize, 7, 10, 13];
    let duration = Duration::from_secs(120);
    let cluster = || Some(ClusterSpec::of(3));

    let clean_cfg = Config { cluster: cluster(), ..sharded_cfg(Some(2)) };
    let (out, clean_rows, clean_health) =
        run_sources(clean_cfg, &rows_per_tick, duration);
    out.unwrap();
    assert_eq!(clean_health.unwrap().retries, 0);

    let faulted_cfg = Config {
        cluster: cluster(),
        fault_plan: Some(FaultPlan::new().stall(2, 1)),
        ..sharded_cfg(Some(2))
    };
    let (out, rows, health) = run_sources(faulted_cfg, &rows_per_tick, duration);
    let results = out.unwrap();
    // The recovery wait legitimately shifts later admission boundaries
    // (it is real round latency), so the two runs may cut off at
    // different ticks — but the *data* must agree: each source's
    // faulted stream is oracle-exact and prefix-compatible with the
    // clean run's.
    for (s, r) in rows.iter().enumerate() {
        assert!(!r.is_empty(), "faulted source {s} delivered nothing");
        assert_oracle_prefix(r, rows_per_tick[s], &format!("faulted src {s}"));
        let n = r.len().min(clean_rows[s].len());
        assert_eq!(
            r[..n],
            clean_rows[s][..n],
            "faulted source {s} diverged from the clean run"
        );
    }
    let h = health.expect("completed run reports health");
    assert!(h.retries > 0, "the stall was never retried");
    assert!(h.recovery_wait > Duration::ZERO);
    assert_eq!(
        h.shards.iter().map(|st| st.retries).sum::<usize>(),
        h.retries,
        "per-shard retry accounting doesn't tile the run total"
    );
    // The faulted round's records carry their own source's charges.
    let charged: usize = results
        .iter()
        .flat_map(|r| r.batches.iter())
        .filter(|b| b.retries > 0)
        .count();
    assert!(charged > 0, "no record carries the retry charge");
}

/// Sharded durable runs keep one sink ledger per source (the legacy
/// shared `sink.ledger.json` must not appear) and the WAL group commit
/// fsyncs at most once per admitting source per round.
#[test]
fn sharded_durable_runs_keep_per_source_ledgers() {
    let base = std::env::temp_dir()
        .join(format!("lmstream-sharding-ledgers-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let wal: PathBuf = base.join("wal");

    let rows_per_tick = [10usize, 10];
    let cfg = Config {
        wal_dir: Some(wal.to_string_lossy().into_owned()),
        ..sharded_cfg(Some(2))
    };
    let mut session = Session::new(cfg).unwrap();
    for (s, &rpt) in rows_per_tick.iter().enumerate() {
        session.register(ident_workload(NAMES[s], rpt)).unwrap();
    }
    let results = session.run(Duration::from_secs(60)).unwrap();
    let rounds = results.iter().map(|r| r.batches.len()).max().unwrap();
    assert!(rounds >= 2, "need multiple rounds to observe batching");

    for name in &NAMES[..2] {
        assert!(
            wal.join(format!("{name}.sink.ledger.json")).exists(),
            "missing per-source ledger for {name}"
        );
    }
    assert!(
        !wal.join("sink.ledger.json").exists(),
        "sharded run created the legacy shared ledger"
    );
    assert!(session.ledger_persists() > 0);
    let fsyncs = session.wal_fsyncs();
    assert!(fsyncs > 0, "durable run never committed its WAL");
    assert!(
        fsyncs <= 2 * rounds,
        "fsyncs ({fsyncs}) exceed one group commit per source per round \
         ({rounds} rounds, 2 sources)"
    );
}
