//! Differential event-time harness.
//!
//! Two layers pin the watermark semantics:
//!
//! * **Engine level** — window state must be *arrival-permutation
//!   invariant*: pushing the same datasets in any arrival order within
//!   the allowed lateness yields bit-identical snapshots at every
//!   watermark boundary (`snapshot_up_to`) and identical eviction
//!   results. This is the exact form of the "reordered arrivals →
//!   identical windowed outputs" contract, free of admission batching
//!   noise.
//! * **Session level** — the same seeded workload run in-order and
//!   disordered (the disorder RNG is separate, so the generated
//!   datasets are identical, only arrival is permuted): with lateness
//!   covering the maximum delay nothing is late and per-tick outputs
//!   agree; with lateness below it, `Drop` and `SideOutput` runs have
//!   bit-identical primary outputs, the side output receives exactly
//!   the rows `Drop` discards, and kept ∪ late tiles the in-order
//!   oracle tick-for-tick (each tick accounted exactly once);
//!   `Recompute` loses nothing.

use lmstream::config::{Config, LatePolicy, Mode};
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::column::{Column, ColumnBatch, Field, Schema};
use lmstream::engine::dataset::Dataset;
use lmstream::engine::sink::Sink;
use lmstream::engine::window::{WindowSpec, WindowState};
use lmstream::error::Result;
use lmstream::query::QueryBuilder;
use lmstream::session::Session;
use lmstream::sim::Time;
use lmstream::source::stream::{Disorder, RowGen};
use lmstream::source::traffic::Traffic;
use lmstream::workloads::Workload;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ================= engine level =================

fn ds(id: u64, event_secs: f64, arrival_secs: f64) -> Dataset {
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch = ColumnBatch::new(
        schema,
        vec![Column::F32(vec![id as f32, id as f32 + 0.5].into())],
    )
    .unwrap();
    let bytes = batch.alloc_bytes();
    Dataset {
        id,
        created_at: Time::from_secs_f64(arrival_secs),
        event_time: Time::from_secs_f64(event_secs),
        batch,
        wire_bytes: bytes,
    }
}

/// Deterministic arrival permutations of `n` datasets, each bounded by a
/// maximum displacement (the "within allowed lateness" constraint).
fn permutations(n: usize) -> Vec<Vec<usize>> {
    let identity: Vec<usize> = (0..n).collect();
    // Adjacent swaps (displacement 1).
    let mut pairs = identity.clone();
    for i in (0..n - 1).step_by(2) {
        pairs.swap(i, i + 1);
    }
    // Block reversal of each run of 3 (displacement 2).
    let mut blocks = identity.clone();
    for start in (0..n).step_by(3) {
        let end = (start + 3).min(n);
        blocks[start..end].reverse();
    }
    // One straggler: the first dataset arrives 4 positions late.
    let mut straggler = identity.clone();
    let d = straggler.remove(0);
    straggler.insert(4.min(straggler.len()), d);
    vec![identity, pairs, blocks, straggler]
}

#[test]
fn window_state_is_arrival_permutation_invariant() {
    let spec = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
    let n = 12;
    // In-order reference: event == arrival, one dataset per second.
    let mut reference = WindowState::new();
    for i in 0..n {
        reference.push(&[ds(i as u64, i as f64, i as f64)]);
    }
    for (pi, perm) in permutations(n).into_iter().enumerate() {
        let mut state = WindowState::new();
        for (arrival_slot, &i) in perm.iter().enumerate() {
            // The permuted run delivers dataset `i` at arrival slot
            // `arrival_slot`, keeping its original event time.
            state.push(&[ds(i as u64, i as f64, arrival_slot as f64)]);
        }
        assert_eq!(state.len(), reference.len(), "perm {pi}");
        // Bit-identical snapshots at every watermark boundary, full and
        // prefix-bounded.
        let full_a = reference.snapshot_chunks().unwrap().unwrap();
        let full_b = state.snapshot_chunks().unwrap().unwrap();
        assert_eq!(full_a, full_b, "perm {pi}: full snapshots diverge");
        for boundary in 0..n {
            let t = Time::from_secs_f64(boundary as f64);
            let a = reference.snapshot_up_to(t).unwrap();
            let b = state.snapshot_up_to(t).unwrap();
            assert_eq!(a, b, "perm {pi}: snapshot_up_to({boundary}s) diverges");
        }
        // Watermark-driven eviction leaves identical states too.
        let mut ev_a = reference_clone(&spec, (0..n).map(|i| (i, i)).collect());
        let mut ev_b = reference_clone(
            &spec,
            perm.iter().enumerate().map(|(slot, &i)| (i, slot)).collect(),
        );
        let wm = Time::from_secs_f64(34.0);
        ev_a.evict(wm, &spec);
        ev_b.evict(wm, &spec);
        assert_eq!(
            ev_a.snapshot_chunks().unwrap(),
            ev_b.snapshot_chunks().unwrap(),
            "perm {pi}: post-eviction states diverge"
        );
    }
}

/// Fresh state from (dataset index, arrival slot) pairs in arrival order.
fn reference_clone(_spec: &WindowSpec, order: Vec<(usize, usize)>) -> WindowState {
    let mut st = WindowState::new();
    let mut arrival_sorted = order;
    arrival_sorted.sort_by_key(|&(_, slot)| slot);
    for (i, slot) in arrival_sorted {
        st.push(&[ds(i as u64, i as f64, slot as f64)]);
    }
    st
}

// ================= session level =================

/// Identity-stamped rows: (t = tick, v = tick*10_000 + i), unique per
/// tick, exact in f32 for the ranges used.
struct IdentGen;

impl RowGen for IdentGen {
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("t"), Field::f32("v")]);
        let t: Vec<f32> = vec![tick as f32; rows];
        let v: Vec<f32> =
            (0..rows).map(|i| (tick * 10_000 + i as u64) as f32).collect();
        ColumnBatch::new(schema, vec![Column::F32(t.into()), Column::F32(v.into())])
            .unwrap()
    }
}

fn make_gen(_seed: u64) -> Box<dyn RowGen> {
    Box::new(IdentGen)
}

fn ident_workload(name: &'static str, rows_per_tick: usize) -> Workload {
    let query = QueryBuilder::scan(name).select(&["t", "v"]).build().unwrap();
    Workload::new(name, query, Traffic::Constant { rows: rows_per_tick }, make_gen)
}

struct RecordingSink {
    rows: Arc<Mutex<Vec<(f32, f32)>>>,
}

impl Sink for RecordingSink {
    fn deliver(&mut self, _i: usize, result: &ChunkedBatch, _t: Time) -> Result<()> {
        let b = result.coalesce();
        let t = b.column("t").unwrap().as_f32().unwrap();
        let v = b.column("v").unwrap().as_f32().unwrap();
        let mut rows = self.rows.lock().unwrap();
        for i in 0..b.rows() {
            if b.validity.is_live(i) {
                rows.push((t[i], v[i]));
            }
        }
        Ok(())
    }
}

fn event_cfg(policy: LatePolicy, lateness: Duration) -> Config {
    Config {
        mode: Mode::LmStream,
        allowed_lateness: Some(lateness),
        late_policy: policy,
        seed: 11,
        ..Config::default()
    }
}

struct SessionRun {
    primary: Vec<(f32, f32)>,
    side: Vec<(f32, f32)>,
    late_rows: usize,
    watermark: Option<Time>,
}

fn run_session(workload: Workload, cfg: Config, duration_secs: u64) -> SessionRun {
    let primary = Arc::new(Mutex::new(Vec::new()));
    let side = Arc::new(Mutex::new(Vec::new()));
    let mut session = Session::new(cfg).unwrap();
    let qid = session.register(workload).unwrap();
    session
        .set_sink(qid, Box::new(RecordingSink { rows: Arc::clone(&primary) }))
        .unwrap();
    session
        .set_late_sink(qid, Box::new(RecordingSink { rows: Arc::clone(&side) }))
        .unwrap();
    let results = session.run(Duration::from_secs(duration_secs)).unwrap();
    let late_rows: usize = results[0].batches.iter().map(|b| b.late_rows).sum();
    let watermark = session.watermarks()[0];
    let p = primary.lock().unwrap().clone();
    let s = side.lock().unwrap().clone();
    SessionRun { primary: p, side: s, late_rows, watermark }
}

/// Tick set of a delivered row stream (constant traffic: dataset == tick).
fn ticks(rows: &[(f32, f32)]) -> BTreeSet<u64> {
    rows.iter().map(|&(t, _)| t as u64).collect()
}

/// Rows grouped per tick, value-sorted (layout-independent content).
fn per_tick(rows: &[(f32, f32)]) -> BTreeMap<u64, Vec<(f32, f32)>> {
    let mut m: BTreeMap<u64, Vec<(f32, f32)>> = BTreeMap::new();
    for &(t, v) in rows {
        m.entry(t as u64).or_default().push((t, v));
    }
    for v in m.values_mut() {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    m
}

#[test]
fn lateness_covering_max_delay_loses_nothing() {
    // Disorder bounded by 3 s, allowed lateness 3 s: a dataset's event
    // can trail the watermark by at most the max delay, so nothing is
    // ever classified late, and every tick both runs consumed carries
    // identical rows.
    let disorder = Disorder::new(0.5, Duration::from_secs(3));
    let lateness = Duration::from_secs(3);
    let ordered = run_session(
        ident_workload("etcov", 8),
        event_cfg(LatePolicy::Drop, lateness),
        60,
    );
    let disordered = run_session(
        ident_workload("etcov", 8).with_disorder(disorder),
        event_cfg(LatePolicy::Drop, lateness),
        60,
    );
    assert_eq!(ordered.late_rows, 0, "in-order run classified data late");
    assert_eq!(disordered.late_rows, 0, "lateness >= max delay must cover all");
    assert!(disordered.side.is_empty());
    assert!(ordered.watermark.is_some() && disordered.watermark.is_some());

    let po = per_tick(&ordered.primary);
    let pd = per_tick(&disordered.primary);
    assert!(!po.is_empty() && !pd.is_empty());
    // Common ticks: bit-identical content (the reordering never
    // corrupted or split a dataset).
    for (tick, rows) in &pd {
        if let Some(reference) = po.get(tick) {
            assert_eq!(rows, reference, "tick {tick}: rows diverge");
        }
    }
    // Coverage: away from the in-flight tail, both runs delivered every
    // tick — no interior holes from reordering.
    let hi = *po.keys().max().unwrap().min(pd.keys().max().unwrap());
    assert!(hi >= 20, "runs too short to compare interiors (max common {hi})");
    for t in 0..hi.saturating_sub(15) {
        assert!(po.contains_key(&t), "ordered run missing interior tick {t}");
        assert!(pd.contains_key(&t), "disordered run missing interior tick {t}");
    }
}

#[test]
fn drop_and_side_output_tile_the_oracle() {
    // Lateness far below the max delay: stragglers are classified late.
    // `Drop` and `SideOutput` runs see identical streams (same seed) so
    // classification is identical; they differ only in where late rows
    // go.
    let disorder = Disorder::new(0.9, Duration::from_secs(10));
    let lateness = Duration::ZERO;
    let dropped = run_session(
        ident_workload("ettile", 8).with_disorder(disorder),
        event_cfg(LatePolicy::Drop, lateness),
        90,
    );
    let sided = run_session(
        ident_workload("ettile", 8).with_disorder(disorder),
        event_cfg(LatePolicy::SideOutput, lateness),
        90,
    );

    // Identical primary outputs bit-for-bit: the policy moves late rows
    // around, it never changes what the pipeline computes on-time.
    assert_eq!(dropped.primary, sided.primary, "late policy leaked into primary");
    assert!(dropped.side.is_empty(), "Drop must not side-route");

    // The side output receives exactly what Drop discards: with heavy
    // disorder some rows must be late, each late dataset lands in the
    // side output whole, and the per-record accounting (flushed on the
    // next admitted batch) never exceeds what the sink observed.
    assert!(!sided.side.is_empty(), "no late data under heavy disorder");
    assert!(sided.late_rows > 0, "late rows never reached BatchRecord");
    assert!(
        sided.late_rows <= sided.side.len(),
        "accounted late rows ({}) exceed side-output rows ({})",
        sided.late_rows,
        sided.side.len()
    );
    assert_eq!(
        dropped.late_rows, sided.late_rows,
        "same stream, same classification: late accounting must agree"
    );

    // Tiling: kept ∪ side is duplicate-free and, away from the
    // in-flight tail, covers every tick exactly once — dropped ∪
    // side-output tiles the in-order oracle.
    let kept = ticks(&sided.primary);
    let late = ticks(&sided.side);
    assert!(kept.is_disjoint(&late), "a tick was both kept and side-routed");
    let pk = per_tick(&sided.primary);
    let pl = per_tick(&sided.side);
    let hi = *kept.union(&late).max().unwrap();
    assert!(hi >= 25, "run too short (max tick {hi})");
    for t in 0..hi.saturating_sub(20) {
        let in_kept = pk.get(&t);
        let in_late = pl.get(&t);
        assert!(
            in_kept.is_some() ^ in_late.is_some(),
            "tick {t} not accounted exactly once (kept: {}, late: {})",
            in_kept.is_some(),
            in_late.is_some()
        );
        // Whole datasets: 8 rows per tick wherever it landed.
        let rows = in_kept.or(in_late).unwrap();
        assert_eq!(rows.len(), 8, "tick {t} split across outputs");
    }
}

#[test]
fn recompute_policy_loses_nothing() {
    // Same heavy disorder, Recompute: late data flows through admission
    // (its window is still open under the lateness-lagged eviction
    // horizon), so every interior tick is delivered exactly once.
    let disorder = Disorder::new(0.9, Duration::from_secs(10));
    let run = run_session(
        ident_workload("etrec", 8).with_disorder(disorder),
        event_cfg(LatePolicy::Recompute, Duration::ZERO),
        90,
    );
    assert!(run.side.is_empty(), "Recompute must not side-route");
    assert!(run.late_rows > 0, "heavy disorder must classify rows late");
    let pt = per_tick(&run.primary);
    let hi = *pt.keys().max().unwrap();
    assert!(hi >= 25, "run too short (max tick {hi})");
    for t in 0..hi.saturating_sub(20) {
        let rows = pt.get(&t).unwrap_or_else(|| panic!("tick {t} lost"));
        assert_eq!(rows.len(), 8, "tick {t} duplicated or split");
    }
}

#[test]
fn event_time_off_reports_no_watermarks_or_late_rows() {
    let cfg = Config { mode: Mode::LmStream, seed: 11, ..Config::default() };
    let primary = Arc::new(Mutex::new(Vec::new()));
    let mut session = Session::new(cfg).unwrap();
    let disorder = Disorder::new(0.5, Duration::from_secs(3));
    let qid = session
        .register(ident_workload("etoff", 8).with_disorder(disorder))
        .unwrap();
    session
        .set_sink(qid, Box::new(RecordingSink { rows: Arc::clone(&primary) }))
        .unwrap();
    let results = session.run(Duration::from_secs(30)).unwrap();
    assert!(session.watermarks().iter().all(|w| w.is_none()));
    assert!(results[0].batches.iter().all(|b| b.late_rows == 0));
    assert!(results[0]
        .batches
        .iter()
        .all(|b| b.watermark_lag == Duration::ZERO));
    assert!(!primary.lock().unwrap().is_empty());
}
