//! Integration tests over the whole simulated pipeline: driver +
//! admission + planner + optimizer + engine + device model.

use lmstream::config::{Config, Mode};
use lmstream::coordinator::driver;
use lmstream::source::traffic::Traffic;
use lmstream::workloads;
use std::time::Duration;

fn run(mode: Mode, workload: &str, secs: u64, seed: u64) -> driver::RunResult {
    let w = workloads::by_name(workload).unwrap();
    let cfg = Config { mode, seed, ..Config::default() };
    driver::run(&w, &cfg, Duration::from_secs(secs), None).unwrap()
}

#[test]
fn dataset_conservation_across_batches() {
    // Every ingested dataset that was admitted appears in exactly one
    // batch; ids are strictly increasing across the run.
    let r = run(Mode::LmStream, "lr1s", 120, 3);
    let total: usize = r.batches.iter().map(|b| b.num_datasets).sum();
    // Constant traffic: 1 dataset/s for 120 s; the tail may still be
    // buffered when the run ends.
    assert!(total <= 120, "{total} datasets in batches");
    assert!(total >= 100, "only {total} of ~120 datasets processed");
}

#[test]
fn latencies_consistent_with_records() {
    let r = run(Mode::LmStream, "cm2s", 120, 4);
    // Per-dataset latency count matches the dataset totals.
    let total: usize = r.batches.iter().map(|b| b.num_datasets).sum();
    assert_eq!(r.dataset_latencies.len(), total);
    // Eq. 5: every batch's max latency >= its proc time.
    for b in &r.batches {
        assert!(b.max_latency >= b.proc, "batch {}: maxlat < proc", b.index);
        assert_eq!(b.max_latency, b.max_buffering + b.proc);
    }
}

#[test]
fn throughput_matches_bytes_over_proc() {
    let r = run(Mode::Baseline, "lr2s", 180, 5);
    let bytes: f64 = r.batches.iter().map(|b| b.bytes as f64).sum();
    let proc: f64 = r.batches.iter().map(|b| b.proc.as_secs_f64()).sum();
    let eq4 = bytes / proc;
    assert!(
        (r.avg_throughput - eq4).abs() / eq4 < 1e-9,
        "Eq.4 mismatch: {} vs {eq4}",
        r.avg_throughput
    );
}

#[test]
fn identical_seeds_identical_runs() {
    let a = run(Mode::LmStream, "cm1s", 90, 42);
    let b = run(Mode::LmStream, "cm1s", 90, 42);
    assert_eq!(a.batches.len(), b.batches.len());
    for (x, y) in a.batches.iter().zip(&b.batches) {
        assert_eq!(x.bytes, y.bytes);
        assert_eq!(x.num_datasets, y.num_datasets);
        assert_eq!(x.proc, y.proc);
    }
}

#[test]
fn different_seeds_differ_under_random_traffic() {
    let w = workloads::by_name("lr1s").unwrap().with_traffic(Traffic::random_default());
    let mk = |seed| {
        let cfg = Config { mode: Mode::LmStream, seed, ..Config::default() };
        driver::run(&w, &cfg, Duration::from_secs(90), None).unwrap()
    };
    let a = mk(1);
    let b = mk(2);
    let a_bytes: Vec<usize> = a.batches.iter().map(|x| x.bytes).collect();
    let b_bytes: Vec<usize> = b.batches.iter().map(|x| x.bytes).collect();
    assert_ne!(a_bytes, b_bytes);
}

#[test]
fn sliding_window_latency_tracks_slide_bound() {
    // LR1S slide is 5 s: LMStream max latency per batch should hover near
    // (not wildly above) the bound once converged.
    let r = run(Mode::LmStream, "lr1s", 300, 6);
    let tail = &r.batches[r.batches.len() / 2..];
    let avg_maxlat: f64 =
        tail.iter().map(|b| b.max_latency.as_secs_f64()).sum::<f64>() / tail.len() as f64;
    assert!(
        (3.0..12.0).contains(&avg_maxlat),
        "LR1S converged max latency {avg_maxlat:.2}s should sit near the 5s slide"
    );
}

#[test]
fn tumbling_running_average_converges() {
    let r = run(Mode::LmStream, "cm1t", 300, 7);
    let lats: Vec<f64> = r.batches.iter().map(|b| b.max_latency.as_secs_f64()).collect();
    let n = lats.len();
    assert!(n > 10);
    let first_half = lats[..n / 2].iter().sum::<f64>() / (n / 2) as f64;
    let second_half = lats[n / 2..].iter().sum::<f64>() / (n - n / 2) as f64;
    // Eq. 3 keeps the running average stable: halves within 2x.
    assert!(
        second_half < first_half * 2.0 + 1.0,
        "tumbling bound diverged: {first_half:.2} -> {second_half:.2}"
    );
}

#[test]
fn baseline_buffers_for_full_trigger() {
    let r = run(Mode::Baseline, "cm1t", 120, 8);
    for b in &r.batches {
        // With a 10 s trigger and 1 dataset/s, each batch spans ~10
        // datasets and the oldest buffered ~10 s (first batch: ~9).
        assert!(
            b.max_buffering >= Duration::from_secs(8),
            "batch {} buffered only {:?}",
            b.index,
            b.max_buffering
        );
    }
}

#[test]
fn static_preference_ignores_size_dynamic_adapts() {
    let stat = run(Mode::StaticPreference, "cm1s", 240, 9);
    // Static plan for CM1S (scan,shuffle,expand,agg,sort) per Table II:
    // GPU for scan/sort/expand(neutral), CPU for shuffle/agg → 3 GPU ops
    // in every batch.
    for b in &stat.batches {
        assert_eq!(b.gpu_ops, 3, "static plan must not vary");
    }
    let dynamic = run(Mode::LmStream, "cm1s", 240, 9);
    let distinct: std::collections::BTreeSet<usize> =
        dynamic.batches.iter().map(|b| b.gpu_ops).collect();
    // Dynamic planning reacts to batch size / learned ratios: over a run
    // it should not be pinned to the static assignment the whole time.
    assert!(
        distinct.len() > 1 || !distinct.contains(&3),
        "dynamic plan never deviated from static: {distinct:?}"
    );
}

#[test]
fn optimizer_moves_inflection_point() {
    let r = run(Mode::LmStream, "lr1s", 300, 10);
    let first = r.batches.first().unwrap().inf_pt;
    let touched = r.batches.iter().any(|b| (b.inf_pt - first).abs() > 1.0);
    assert!(touched, "online optimizer never updated the inflection point");
    // And it stays clamped.
    for b in &r.batches {
        assert!((1024.0..=64.0 * 1024.0 * 1024.0).contains(&b.inf_pt));
    }
}

#[test]
fn phase_totals_cover_all_batches() {
    let r = run(Mode::LmStream, "lr2s", 120, 11);
    let phases = r.phases;
    let proc_sum: f64 = r.batches.iter().map(|b| b.proc.as_secs_f64()).sum();
    assert!((phases.processing.as_secs_f64() - proc_sum).abs() < 1e-6);
    // Mechanism overhead (construct+map+optblock) is far below processing.
    let mech = phases.construct + phases.map_device + phases.opt_blocking;
    assert!(
        mech.as_secs_f64() < 0.05 * phases.processing.as_secs_f64() + 0.5,
        "mechanisms {mech:?} vs processing {:?}",
        phases.processing
    );
}

#[test]
fn all_gpu_and_all_cpu_ablations_run() {
    let gpu = run(Mode::AllGpu, "lr1s", 90, 12);
    let cpu = run(Mode::AllCpu, "lr1s", 90, 12);
    assert!(!gpu.batches.is_empty() && !cpu.batches.is_empty());
    for b in &gpu.batches {
        assert_eq!(b.gpu_ops, b.total_ops);
    }
    for b in &cpu.batches {
        assert_eq!(b.gpu_ops, 0);
    }
}

#[test]
fn empty_traffic_produces_no_batches() {
    let w = workloads::by_name("lr1s").unwrap().with_traffic(Traffic::Constant { rows: 0 });
    let cfg = Config { mode: Mode::LmStream, ..Config::default() };
    let r = driver::run(&w, &cfg, Duration::from_secs(30), None).unwrap();
    assert!(r.batches.is_empty());
    assert_eq!(r.avg_throughput, 0.0);
}
