//! Checkpoint / recovery integration: a run that checkpoints every batch
//! can be killed and resumed, restoring the optimizer state and skipping
//! the processed stream prefix; results flow to sinks either way.

use lmstream::config::{Config, Mode};
use lmstream::coordinator::checkpoint::CheckpointStore;
use lmstream::coordinator::driver;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::sink::{CollectSink, CountingSink};
use lmstream::query::QueryBuilder;
use lmstream::session::Session;
use lmstream::workloads;
use std::path::PathBuf;
use std::time::Duration;

fn ckpt_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lmstream-ckpt-it-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn checkpoints_written_every_batch() {
    let dir = ckpt_dir("written");
    let w = workloads::by_name("cm1t").unwrap();
    let cfg = Config {
        mode: Mode::LmStream,
        checkpoint_dir: Some(dir.to_string_lossy().to_string()),
        ..Config::default()
    };
    let r = driver::run(&w, &cfg, Duration::from_secs(60), None).unwrap();
    assert!(!r.batches.is_empty());
    let store = CheckpointStore::new(&dir).unwrap();
    let ckpt = store.load("cm1t").unwrap().expect("checkpoint exists");
    assert_eq!(ckpt.batches, r.batches.len());
    assert!(ckpt.processed_up_to.as_secs_f64() > 0.0);
    assert!((ckpt.avg_throughput() - r.avg_throughput).abs() < 1e-6);
}

#[test]
fn resume_skips_processed_prefix_and_restores_inf_pt() {
    let dir = ckpt_dir("resume");
    let w = workloads::by_name("lr1s").unwrap();
    let cfg = Config {
        mode: Mode::LmStream,
        checkpoint_dir: Some(dir.to_string_lossy().to_string()),
        ..Config::default()
    };
    // First incarnation.
    let first = driver::run(&w, &cfg, Duration::from_secs(90), None).unwrap();
    let store = CheckpointStore::new(&dir).unwrap();
    let ckpt = store.load("lr1s").unwrap().unwrap();
    assert_eq!(ckpt.batches, first.batches.len());

    // Second incarnation resumes: its first admitted batch must not
    // re-process datasets created before the checkpoint horizon.
    let second = driver::run(&w, &cfg, Duration::from_secs(60), None).unwrap();
    assert!(!second.batches.is_empty());
    let replayed: usize = second.batches.iter().map(|b| b.num_datasets).sum();
    // 60 s of fresh data max (plus the sub-second tail), nowhere near the
    // 90 s + 60 s a cold run would see.
    assert!(replayed <= 61, "resume re-processed {replayed} datasets");
    // Inflection point carried over (first batch of the resumed run uses
    // the checkpointed value, not the 150 KB initial — unless the
    // optimizer had never moved it).
    let resumed_first = second.batches[0].inf_pt;
    assert!(
        (resumed_first - ckpt.inf_pt).abs() < ckpt.inf_pt * 0.1 + 1.0,
        "resumed inf_pt {resumed_first} vs checkpointed {}",
        ckpt.inf_pt
    );
}

/// Multi-query checkpointing: a source's checkpoint (keyed by its
/// primary query's name) carries one metric state per registered query,
/// and a resumed session seeds *secondary* metrics from it too — the
/// per-source primary-key gap this file used to leave untested.
#[test]
fn secondary_query_metrics_survive_recovery() {
    let dir = ckpt_dir("secondary");
    let build_session = || {
        let cfg = Config {
            mode: Mode::LmStream,
            checkpoint_dir: Some(dir.to_string_lossy().to_string()),
            ..Config::default()
        };
        let mut s = Session::new(cfg).unwrap();
        let w = workloads::by_name("lr1s").unwrap();
        let window = w.query.window;
        let first = s.register(w).unwrap();
        let side = QueryBuilder::scan("side")
            .window(window)
            .filter("speed", Predicate::Lt(60.0))
            .build()
            .unwrap();
        s.register_shared(first, "side", side).unwrap();
        s
    };

    // First incarnation: both queries record batches; the checkpoint
    // must carry a metric state for the secondary under its own name.
    let first_rs = build_session().run(Duration::from_secs(90)).unwrap();
    assert!(!first_rs[1].batches.is_empty());
    let store = CheckpointStore::new(&dir).unwrap();
    let ckpt = store.load("lr1s").unwrap().expect("checkpoint exists");
    let side_state = ckpt
        .queries
        .iter()
        .find(|q| q.name == "side")
        .expect("secondary query state persisted");
    assert_eq!(side_state.batches, first_rs[1].batches.len());
    assert!(side_state.cumulative_proc_secs > 0.0);

    // Second incarnation: the secondary's restored batch count offsets
    // its new batch indices — pre-fix, secondary metrics started from
    // zero and the first index was 0 again.
    let second_rs = build_session().run(Duration::from_secs(60)).unwrap();
    assert!(!second_rs[1].batches.is_empty());
    assert_eq!(
        second_rs[1].batches[0].index,
        side_state.batches,
        "secondary metrics were not seeded from the checkpoint"
    );
    // And the resumed primary continues its own count too.
    assert_eq!(second_rs[0].batches[0].index, ckpt.batches);
}

/// Remove a `"key":<value>,` pair from a compact JSON document (the
/// checkpoint writer's values here are plain numbers, always followed
/// by a comma — neither field sorts last).
fn strip_field(text: &str, key: &str) -> String {
    let pat = format!("\"{key}\":");
    let Some(start) = text.find(&pat) else { return text.to_string() };
    let end = start + text[start..].find(',').expect("field not last") + 1;
    format!("{}{}", &text[..start], &text[end..])
}

/// Back-compat: a pre-durability (format-1) checkpoint file — no
/// `wal_high_water`, no `round_high_water` — must still load and drive
/// recovery through the driver path with legacy semantics: the stream
/// prefix is skipped and batch indices continue, exactly as before the
/// format-2 fields existed.
#[test]
fn format1_checkpoint_recovers_through_driver_with_legacy_semantics() {
    let dir = ckpt_dir("format1-it");
    let w = workloads::by_name("lr1s").unwrap();
    let cfg = Config {
        mode: Mode::LmStream,
        checkpoint_dir: Some(dir.to_string_lossy().to_string()),
        ..Config::default()
    };
    let first = driver::run(&w, &cfg, Duration::from_secs(90), None).unwrap();

    // Downgrade the on-disk file to what a format-1 writer produced.
    let path = dir.join("lr1s.ckpt.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let legacy = strip_field(
        &strip_field(&text.replace("\"format\":2,", "\"format\":1,"), "wal_high_water"),
        "round_high_water",
    );
    assert_ne!(text, legacy, "fixture must actually strip the format-2 fields");
    std::fs::write(&path, legacy).unwrap();

    // The loader applies legacy defaults…
    let store = CheckpointStore::new(&dir).unwrap();
    let ckpt = store.load("lr1s").unwrap().unwrap();
    assert_eq!(ckpt.wal_high_water, 0);
    assert_eq!(ckpt.round_high_water, 0);
    assert_eq!(ckpt.batches, first.batches.len());

    // …and the resumed incarnation behaves like the pre-durability
    // engine: no reprocessed prefix, continued batch numbering.
    let second = driver::run(&w, &cfg, Duration::from_secs(60), None).unwrap();
    assert!(!second.batches.is_empty());
    let replayed: usize = second.batches.iter().map(|b| b.num_datasets).sum();
    assert!(replayed <= 61, "legacy resume re-processed {replayed} datasets");
    assert_eq!(second.batches[0].index, first.batches.len());
}

#[test]
fn sinks_receive_every_batch_result() {
    let w = workloads::by_name("lr2s").unwrap();
    let cfg = Config { mode: Mode::LmStream, ..Config::default() };
    let mut sink = CountingSink::default();
    let r =
        driver::run_with_sink(&w, &cfg, Duration::from_secs(90), None, &mut sink).unwrap();
    assert_eq!(sink.batches, r.batches.len());
    assert!(sink.rows > 0, "aggregation results must reach the sink");
}

#[test]
fn collected_results_match_query_semantics() {
    // LR2S results: group rows with avgSpeed < 40 only.
    let w = workloads::by_name("lr2s").unwrap();
    let cfg = Config { mode: Mode::LmStream, ..Config::default() };
    let mut sink = CollectSink::new(8);
    driver::run_with_sink(&w, &cfg, Duration::from_secs(60), None, &mut sink).unwrap();
    assert!(!sink.results.is_empty());
    for (_, _, batch) in &sink.results {
        let avg = batch.column("avgSpeed").unwrap().as_f32().unwrap();
        for (i, &v) in avg.iter().enumerate() {
            if batch.validity.is_live(i) {
                assert!(v < 40.0, "HAVING violated: avgSpeed {v}");
            }
        }
    }
}

#[test]
fn cluster_runs_end_to_end_through_driver() {
    use lmstream::cluster::ClusterSpec;
    let w = workloads::by_name("cm1s").unwrap();
    let cfg = Config {
        mode: Mode::LmStream,
        cluster: Some(ClusterSpec::paper()),
        ..Config::default()
    };
    let r = driver::run(&w, &cfg, Duration::from_secs(90), None).unwrap();
    assert!(!r.batches.is_empty());
    // And the single-executor run with identical seed differs in proc
    // (coordination/network are charged) but conserves dataset counts.
    let cfg1 = Config { cluster: None, ..cfg };
    let r1 = driver::run(&w, &cfg1, Duration::from_secs(90), None).unwrap();
    assert!(r.avg_throughput > 0.0 && r1.avg_throughput > 0.0);
}
