//! Property tests for the operation-DAG invariants (seeded sweeps via
//! `util::prop`): every builder-produced DAG validates and traverses
//! producers-first; corrupted graphs (cycles via forward edges,
//! disconnected nodes, duplicate edges) are rejected; MapDevice covers
//! any valid DAG with a full physical plan.

use lmstream::coordinator::planner::{map_device, SizeEstimator};
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::window::WindowSpec;
use lmstream::query::dag::{OpNode, OpSpec, Query};
use lmstream::query::QueryBuilder;
use lmstream::util::prop::{prop_assert, Gen, Runner};
use std::collections::HashSet;
use std::time::Duration;

/// Append 1..5 random ops (possibly branching/merging recursively).
fn grow(
    mut b: QueryBuilder,
    g: &mut Gen,
    depth: usize,
    join_used: &mut bool,
) -> QueryBuilder {
    let steps = g.usize_in(1..5);
    for _ in 0..steps {
        b = match g.u64(8) {
            0 => b.filter("x", Predicate::Ge(0.0)),
            1 => b.expand(),
            2 => b.shuffle("k"),
            3 => b.sort("x", false),
            4 => b.project_affine("a", "b", 1.0, 1.0, "ab"),
            5 if !*join_used => {
                *join_used = true;
                b.join_window("k", "k")
            }
            6 if depth > 0 => b.branch(|bb| grow(bb, g, depth - 1, join_used)),
            _ if depth > 0 => b.merge_union(|bb| {
                // merge_union's contract: the branch must advance the
                // tip (an all-branch() inner grow would leave it at the
                // fork, making the Union's inputs duplicates) — lead
                // with a real op before growing further.
                grow(bb.filter("m", Predicate::Ge(0.0)), g, depth - 1, join_used)
            }),
            _ => b.filter("y", Predicate::Lt(1.0)),
        };
    }
    b
}

fn random_query(g: &mut Gen) -> Query {
    let mut join_used = false;
    let b = QueryBuilder::scan("prop-dag").window(WindowSpec::sliding(
        Duration::from_secs(30),
        Duration::from_secs(5),
    ));
    grow(b, g, 2, &mut join_used)
        .build()
        .expect("builder-produced DAGs always validate")
}

/// Any DAG the builder can produce validates, and its topological
/// traversal visits every node exactly once, after all of its inputs.
#[test]
fn prop_builder_dags_validate_and_traverse_topologically() {
    let mut r = Runner::new(0xda61, 300);
    r.run("builder DAG validates + topo traversal", |g| {
        let q = random_query(g);
        prop_assert(q.validate().is_ok(), "validate failed")?;
        let mut seen: HashSet<usize> = HashSet::new();
        for op in q.traverse() {
            prop_assert(
                op.inputs.iter().all(|i| seen.contains(i)),
                format!("op {} visited before an input ({:?})", op.id, op.inputs),
            )?;
            prop_assert(seen.insert(op.id), format!("op {} visited twice", op.id))?;
        }
        prop_assert(
            seen.len() == q.len(),
            format!("traversal covered {} of {} ops", seen.len(), q.len()),
        )?;
        prop_assert(!q.sinks().is_empty(), "query has no sinks")
    });
}

/// MapDevice produces a full, deterministic physical plan for any valid
/// DAG — branches and unions included.
#[test]
fn prop_planner_covers_any_valid_dag() {
    let mut r = Runner::new(0xda62, 200);
    r.run("planner covers DAGs", |g| {
        let q = random_query(g);
        let est = SizeEstimator::new(q.len());
        let part = g.f64_in(1024.0, 4.0 * 1024.0 * 1024.0);
        let inf = g.f64_in(1024.0, 4.0 * 1024.0 * 1024.0);
        let p1 = map_device(&q, part, inf, 0.1, &est, 2).expect("plan");
        let p2 = map_device(&q, part, inf, 0.1, &est, 2).expect("plan");
        prop_assert(p1.len() == q.len(), "partial assignment")?;
        prop_assert(p1 == p2, "non-deterministic plan")?;
        prop_assert(
            p1.per_op.iter().enumerate().all(|(i, o)| o.op_id == i),
            "plan not index-aligned with the DAG",
        )
    });
}

/// Corrupting a valid chain — a forward/self edge (cycle), a
/// disconnected node, a duplicate edge, or a non-contiguous id — must
/// make validation fail.
#[test]
fn prop_corrupted_graphs_rejected() {
    let mut r = Runner::new(0xda63, 300);
    r.run("corrupted DAGs rejected", |g| {
        let len = g.usize_in(2..8);
        let mut ops: Vec<OpNode> = vec![OpNode::chained(0, OpSpec::Scan)];
        for id in 1..len {
            ops.push(OpNode::chained(
                id,
                OpSpec::Filter { col: "x".into(), pred: Predicate::Ge(0.0) },
            ));
        }
        let mut q = Query {
            name: "corrupt".into(),
            ops,
            window: WindowSpec::tumbling(Duration::from_secs(30)),
            uses_window_state: false,
        };
        prop_assert(q.validate().is_ok(), "baseline chain must validate")?;

        let victim = g.usize_in(1..len);
        match g.u64(4) {
            0 => {
                // Forward or self edge: the only way to close a cycle.
                let target = victim + g.usize_in(0..len - victim);
                q.ops[victim].inputs = vec![target.min(len - 1).max(victim)];
            }
            1 => q.ops[victim].inputs = vec![], // disconnected
            2 => {
                let inp = q.ops[victim].inputs[0];
                q.ops[victim].inputs = vec![inp, inp]; // duplicate edge
            }
            _ => q.ops[victim].id = victim + len, // non-contiguous id
        }
        prop_assert(
            q.validate().is_err(),
            format!("corrupted graph accepted: {:?}", q.ops[victim]),
        )
    });
}
