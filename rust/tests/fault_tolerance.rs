//! Differential harness for executor fault tolerance.
//!
//! The oracle is analytic, as in `tests/durability.rs`: a deterministic
//! generator stamps every row with its (tick, row-id) identity and the
//! query is a stateless filter + select, so the flattened delivered row
//! sequence of ANY correct run — fault-free or faulted — must be an
//! exact prefix of the analytic oracle sequence. Faults perturb the
//! *clock* (detection + backoff, degraded-topology makespans shift
//! admission boundaries), so faulted runs may deliver more or fewer
//! batches than the fault-free run in the same simulated duration; what
//! they must never do is duplicate, drop, or reorder a row. On top of
//! the prefix property the tests pin exact retry/degradation
//! accounting (per-round `BatchRecord` fields and the session
//! [`HealthReport`]) and determinism (identical faulted runs are
//! bit-identical).

use lmstream::cluster::{ClusterSpec, FaultPlan};
use lmstream::config::{Config, Mode};
use lmstream::coordinator::HealthReport;
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::column::{Column, ColumnBatch, Field, Schema};
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::sink::Sink;
use lmstream::error::{Error, Result};
use lmstream::query::QueryBuilder;
use lmstream::session::{RunResult, Session};
use lmstream::sim::Time;
use lmstream::source::stream::RowGen;
use lmstream::source::traffic::Traffic;
use lmstream::workloads::Workload;
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ---------- deterministic identity-stamped workload ----------

/// Every row is (t = tick, v = tick*10_000 + i, m = i % 10): globally
/// unique (t, v) identities, exact in f32 for the tick ranges used.
struct IdentGen;

impl RowGen for IdentGen {
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
        let schema =
            Schema::new(vec![Field::f32("t"), Field::f32("v"), Field::f32("m")]);
        let t: Vec<f32> = vec![tick as f32; rows];
        let v: Vec<f32> =
            (0..rows).map(|i| (tick * 10_000 + i as u64) as f32).collect();
        let m: Vec<f32> = (0..rows).map(|i| (i % 10) as f32).collect();
        ColumnBatch::new(
            schema,
            vec![Column::F32(t.into()), Column::F32(v.into()), Column::F32(m.into())],
        )
        .unwrap()
    }
}

fn make_gen(_seed: u64) -> Box<dyn RowGen> {
    Box::new(IdentGen)
}

fn ident_workload(name: &'static str, rows_per_tick: usize) -> Workload {
    let query = QueryBuilder::scan(name)
        .filter("m", Predicate::Lt(6.0))
        .select(&["t", "v"])
        .build()
        .unwrap();
    Workload::new(name, query, Traffic::Constant { rows: rows_per_tick }, make_gen)
}

/// The analytic oracle: the exact flattened row sequence any correct
/// run's sink must observe (one dataset per tick, in tick order).
fn oracle(rows_per_tick: usize, max_tick: u64) -> Vec<(f32, f32)> {
    let mut out = Vec::new();
    for tick in 0..=max_tick {
        for i in 0..rows_per_tick {
            if i % 10 < 6 {
                out.push((tick as f32, (tick * 10_000 + i as u64) as f32));
            }
        }
    }
    out
}

fn assert_oracle_prefix(delivered: &[(f32, f32)], rows_per_tick: usize, ctx: &str) {
    let full = oracle(rows_per_tick, 4_000);
    assert!(delivered.len() <= full.len(), "{ctx}: run too long for oracle");
    assert_eq!(
        delivered,
        &full[..delivered.len()],
        "{ctx}: delivered rows diverge from the fault-free oracle \
         (a duplicate, loss, or reorder slipped through recovery)"
    );
}

// ---------- row-recording sink ----------

struct RecordingSink {
    rows: Arc<Mutex<Vec<(f32, f32)>>>,
}

impl Sink for RecordingSink {
    fn deliver(&mut self, _i: usize, result: &ChunkedBatch, _t: Time) -> Result<()> {
        let b = result.coalesce();
        let t = b.column("t").unwrap().as_f32().unwrap();
        let v = b.column("v").unwrap().as_f32().unwrap();
        let mut rows = self.rows.lock().unwrap();
        for i in 0..b.rows() {
            if b.validity.is_live(i) {
                rows.push((t[i], v[i]));
            }
        }
        Ok(())
    }
}

// ---------- harness plumbing ----------

fn faulty_cfg(cluster: Option<ClusterSpec>, plan: Option<FaultPlan>) -> Config {
    Config {
        mode: Mode::LmStream,
        cluster,
        fault_plan: plan,
        seed: 11,
        ..Config::default()
    }
}

/// One run: fresh session, one identity workload, a recording sink.
/// Returns the run outcome, every delivered (t, v) row in delivery
/// order, and the session's health report.
fn run_ident(
    cfg: Config,
    rows_per_tick: usize,
    duration: Duration,
) -> (Result<Vec<RunResult>>, Vec<(f32, f32)>, Option<HealthReport>) {
    let rows = Arc::new(Mutex::new(Vec::new()));
    let mut session = Session::new(cfg).unwrap();
    let qid = session.register(ident_workload("ft", rows_per_tick)).unwrap();
    session
        .set_sink(qid, Box::new(RecordingSink { rows: Arc::clone(&rows) }))
        .unwrap();
    let out = session.run(duration);
    let health = session.health_report().cloned();
    let delivered = rows.lock().unwrap().clone();
    (out, delivered, health)
}

// ---------- the differential property tests ----------

/// Tentpole property: a 3-executor session hit by a transient stall, a
/// permanent GPU-device fault, and a crash-then-probationary-rejoin
/// still delivers an exact oracle prefix — and every retry, every
/// charged recovery wait, and every degraded round is accounted to the
/// batch records and the health report, deterministically.
#[test]
fn faulted_cluster_run_is_oracle_exact_with_precise_accounting() {
    let plan = FaultPlan::new()
        .stall(2, 1)
        .gpu_fail(3, 2)
        .crash(4, 1)
        .rejoin(6, 1);
    let duration = Duration::from_secs(240);

    // Fault-free oracle run on the same topology.
    let (out, clean_rows, clean_health) =
        run_ident(faulty_cfg(Some(ClusterSpec::of(3)), None), 10, duration);
    out.unwrap();
    assert!(!clean_rows.is_empty());
    assert_oracle_prefix(&clean_rows, 10, "fault-free");
    let h = clean_health.expect("completed run reports health");
    assert_eq!(h.retries, 0);
    assert_eq!(h.recovery_wait, Duration::ZERO);
    assert_eq!(h.degraded_rounds, 0);
    assert!(h.executors.iter().all(|e| e.state == "up"));

    // Faulted run: same topology, same workload, same simulated window.
    let (out, rows, health) = run_ident(
        faulty_cfg(Some(ClusterSpec::of(3)), Some(plan.clone())),
        10,
        duration,
    );
    let results = out.unwrap();
    assert!(!rows.is_empty());
    assert_oracle_prefix(&rows, 10, "faulted");

    let recs = &results[0].batches;
    let last_round = recs.iter().map(|r| r.round).max().unwrap();
    assert!(
        last_round >= 8,
        "need rounds past the rejoin+probation window, got {last_round}"
    );
    let by_round =
        |n: usize| recs.iter().find(|r| r.round == n).expect("round executed");

    // Round 1: clean. Round 2: the stall costs exactly one retry
    // (detection + first backoff) and the retry runs on the full
    // topology again — transient, so not a degraded round.
    assert_eq!(by_round(1).retries, 0);
    assert!(!by_round(1).degraded);
    let stall = by_round(2);
    assert_eq!(stall.retries, 1);
    assert!(!stall.degraded);
    assert_eq!(stall.recovery_wait, Duration::from_millis(100 + 50));
    assert!(stall.proc >= stall.recovery_wait, "recovery wait embeds in proc");

    // Round 3 on: executor 2's GPU is gone for good — every later
    // round is degraded. Round 4: the crash costs one retry.
    let gpu = by_round(3);
    assert_eq!(gpu.retries, 0);
    assert!(gpu.degraded);
    assert_eq!(gpu.recovery_wait, Duration::ZERO);
    let crash = by_round(4);
    assert_eq!(crash.retries, 1);
    assert!(crash.degraded);
    assert_eq!(crash.recovery_wait, Duration::from_millis(100 + 50));
    for r in recs.iter().filter(|r| r.round >= 3) {
        assert!(r.degraded, "round {} should be degraded", r.round);
    }

    // Health report: exact fault counters, exact run totals.
    let h = health.expect("completed run reports health");
    assert_eq!(h.retries, 2);
    assert_eq!(h.recovery_wait, Duration::from_millis(2 * (100 + 50)));
    assert_eq!(h.degraded_rounds, recs.iter().filter(|r| r.degraded).count());
    assert_eq!(h.executors[0].crashes, 0);
    assert_eq!(h.executors[1].crashes, 1);
    assert_eq!(h.executors[1].stalls, 1);
    assert_eq!(h.executors[1].rejoins, 1);
    assert_eq!(h.executors[2].gpu_faults, 1);
    assert_eq!(h.executors[0].state, "up");
    assert_eq!(h.executors[1].state, "up", "probation expired back to up");
    assert_eq!(h.executors[2].state, "gpu-degraded");

    // Determinism: the identical faulted run is bit-identical.
    let (out2, rows2, health2) =
        run_ident(faulty_cfg(Some(ClusterSpec::of(3)), Some(plan)), 10, duration);
    let results2 = out2.unwrap();
    assert_eq!(rows, rows2, "faulted runs must be deterministic");
    assert_eq!(results[0].batches.len(), results2[0].batches.len());
    assert_eq!(health2.unwrap().recovery_wait, h.recovery_wait);
}

/// Property sweep: seeded random fault plans (survivable by
/// construction) across cluster widths and chunk layouts never corrupt
/// sink output — always an exact oracle prefix, always deterministic.
#[test]
fn seeded_fault_plans_keep_sink_output_oracle_exact() {
    for &executors in &[2usize, 3] {
        for &seed in &[3u64, 9, 27] {
            for &rows_per_tick in &[4usize, 10] {
                let name = format!("seeded-{executors}-{seed}-{rows_per_tick}");
                let plan = FaultPlan::seeded(seed, 10, executors, 5);
                let cfg = || {
                    faulty_cfg(Some(ClusterSpec::of(executors)), Some(plan.clone()))
                };
                let (out, rows, health) =
                    run_ident(cfg(), rows_per_tick, Duration::from_secs(120));
                out.unwrap_or_else(|e| panic!("{name}: survivable plan died: {e}"));
                assert!(!rows.is_empty(), "{name}: nothing delivered");
                assert_oracle_prefix(&rows, rows_per_tick, &name);
                // Executor 0 is never crashed by construction, so a
                // surviving topology always exists.
                let h = health.unwrap();
                assert_eq!(h.executors[0].crashes, 0, "{name}");

                let (out2, rows2, _) =
                    run_ident(cfg(), rows_per_tick, Duration::from_secs(120));
                out2.unwrap();
                assert_eq!(rows, rows2, "{name}: faulted runs must be deterministic");
            }
        }
    }
}

/// A GPU-device fault on a single node demotes the whole plan to CPU:
/// rows stay oracle-exact, no round fails, and the degradation is
/// visible in records and health.
#[test]
fn single_node_gpu_fault_degrades_to_cpu_without_losing_rows() {
    let (out, rows, health) = run_ident(
        faulty_cfg(None, Some(FaultPlan::new().gpu_fail(2, 0))),
        10,
        Duration::from_secs(120),
    );
    let results = out.unwrap();
    assert!(!rows.is_empty());
    assert_oracle_prefix(&rows, 10, "single-node gpu fault");
    let recs = &results[0].batches;
    assert!(recs.iter().map(|r| r.round).max().unwrap() >= 3);
    for r in recs {
        assert_eq!(r.retries, 0, "a gpu fault must not fail the round");
        assert_eq!(r.degraded, r.round >= 2, "degraded from the fault on");
        if r.round >= 2 {
            assert_eq!(r.gpu_ops, 0, "demoted rounds must plan zero GPU ops");
        }
    }
    let h = health.unwrap();
    assert_eq!(h.retries, 0);
    assert_eq!(h.executors[0].gpu_faults, 1);
    assert_eq!(h.executors[0].state, "gpu-degraded");
    assert!(h.degraded_rounds > 0);
}

/// Crashing every executor leaves nothing to re-plan on: the session
/// surfaces the typed executor error instead of hanging or panicking.
#[test]
fn crash_with_no_survivors_surfaces_typed_error() {
    // Single node: its only executor dies.
    let (out, _, _) = run_ident(
        faulty_cfg(None, Some(FaultPlan::new().crash(1, 0))),
        10,
        Duration::from_secs(60),
    );
    match out {
        Err(Error::Executor { reason, .. }) => {
            assert!(
                reason.contains("no surviving executors"),
                "unexpected reason: {reason}"
            );
        }
        other => panic!("expected Error::Executor, got {other:?}"),
    }

    // Two-executor cluster: both die in the same round.
    let (out, _, _) = run_ident(
        faulty_cfg(
            Some(ClusterSpec::of(2)),
            Some(FaultPlan::new().crash(2, 0).crash(2, 1)),
        ),
        10,
        Duration::from_secs(60),
    );
    assert!(
        matches!(out, Err(Error::Executor { .. })),
        "a fully-crashed round must surface Error::Executor"
    );
}

/// With a zero retry budget even a transient stall is fatal — and the
/// error says the budget ran out.
#[test]
fn exhausted_retry_budget_surfaces_typed_error() {
    let cfg = Config {
        max_round_retries: 0,
        ..faulty_cfg(Some(ClusterSpec::of(3)), Some(FaultPlan::new().stall(1, 1)))
    };
    let (out, _, _) = run_ident(cfg, 10, Duration::from_secs(60));
    match out {
        Err(Error::Executor { executor, reason }) => {
            assert_eq!(executor, 1);
            assert!(reason.contains("retry budget"), "unexpected reason: {reason}");
        }
        other => panic!("expected Error::Executor, got {other:?}"),
    }
}
