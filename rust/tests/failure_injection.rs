//! Failure injection: malformed inputs must fail loudly with typed
//! errors, never corrupt state or panic.

use lmstream::config::{Config, Mode};
use lmstream::devices::Device;
use lmstream::engine::column::{Column, ColumnBatch, Field, Schema};
use lmstream::error::Error;
use lmstream::query::exec::{self, DevicePlan, ExecEnv};
use lmstream::query::physical::PhysicalPlan;
use lmstream::runtime::artifacts::Manifest;
use lmstream::workloads;
use std::io::Write;
use std::path::{Path, PathBuf};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lmstream-fail-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_artifact_dir_is_artifact_error() {
    let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err:?}");
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn corrupt_manifest_json_rejected() {
    let d = tmpdir("badjson");
    let mut f = std::fs::File::create(d.join("manifest.json")).unwrap();
    f.write_all(b"{ this is not json ]").unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(matches!(err, Error::Json(_)), "{err:?}");
}

#[test]
fn wrong_manifest_format_version_rejected() {
    let d = tmpdir("badformat");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"format": 99, "num_groups": 256, "row_buckets": [1024], "artifacts": []}"#,
    )
    .unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(err.to_string().contains("format"), "{err}");
}

#[test]
fn empty_artifact_list_rejected() {
    let d = tmpdir("empty");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"format": 1, "num_groups": 256, "row_buckets": [1024], "artifacts": []}"#,
    )
    .unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err:?}");
}

#[test]
fn manifest_missing_fields_rejected() {
    let d = tmpdir("missingfields");
    std::fs::write(d.join("manifest.json"), r#"{"format": 1}"#).unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(matches!(err, Error::Json(_)), "{err:?}");
}

#[test]
fn invalid_configs_rejected_before_running() {
    for cfg in [
        Config { num_cores: 0, ..Config::default() },
        Config { num_gpus: 0, ..Config::default() },
        Config { trigger: std::time::Duration::ZERO, ..Config::default() },
        Config { initial_inflection_bytes: -1.0, ..Config::default() },
        Config { initial_throughput: 0.0, ..Config::default() },
    ] {
        assert!(cfg.validate().is_err());
        let w = workloads::by_name("lr1s").unwrap();
        let r = lmstream::coordinator::driver::run(
            &w,
            &cfg,
            std::time::Duration::from_secs(5),
            None,
        );
        assert!(r.is_err(), "driver accepted invalid config");
    }
}

#[test]
fn real_backend_without_runtime_fails_on_gpu_ops() {
    use lmstream::config::ExecBackend;
    let w = workloads::by_name("lr1s").unwrap();
    let cfg = Config {
        mode: Mode::AllGpu,
        backend: ExecBackend::Real,
        ..Config::default()
    };
    let r = lmstream::coordinator::driver::run(
        &w,
        &cfg,
        std::time::Duration::from_secs(15),
        None, // no runtime supplied
    );
    assert!(r.is_err(), "GPU ops without a runtime must fail");
}

#[test]
fn plan_arity_mismatch_rejected() {
    let w = workloads::by_name("lr2s").unwrap();
    let model = lmstream::devices::model::DeviceModel::default();
    let env = ExecEnv {
        model: &model,
        backend: lmstream::config::ExecBackend::Simulated,
        num_cores: 12,
        num_gpus: 1,
        runtime: None,
    };
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch = ColumnBatch::new(schema, vec![Column::F32(vec![1.0].into())]).unwrap();
    // Lifting a short device vector onto the DAG is itself rejected…
    let bad_devices = DevicePlan::all(Device::Cpu, 1); // query has more ops
    assert!(matches!(
        PhysicalPlan::from_devices(&w.query, &bad_devices),
        Err(Error::Plan(_))
    ));
    // …and a hand-built under-length physical plan is rejected at
    // execution time.
    let truncated = PhysicalPlan {
        per_op: PhysicalPlan::uniform(&w.query, Device::Cpu).per_op[..1].to_vec(),
    };
    let r = exec::execute(&w.query, &truncated, batch, None, &env);
    assert!(matches!(r, Err(Error::Plan(_))), "{r:?}");
}

#[test]
fn empty_query_planning_and_execution_are_plan_errors() {
    use lmstream::coordinator::planner::{map_device, SizeEstimator};
    use lmstream::engine::window::WindowSpec;
    use lmstream::query::Query;

    let empty = Query {
        name: "empty".into(),
        ops: vec![],
        window: WindowSpec::tumbling(std::time::Duration::from_secs(30)),
        uses_window_state: false,
    };
    // Planning an empty query must error, not underflow `n - 1`.
    let est = SizeEstimator::new(0);
    let planned = map_device(&empty, 64.0 * 1024.0, 150.0 * 1024.0, 0.1, &est, 2);
    assert!(matches!(planned, Err(Error::Plan(_))), "{planned:?}");

    // Executing one must error too.
    let model = lmstream::devices::model::DeviceModel::default();
    let env = ExecEnv {
        model: &model,
        backend: lmstream::config::ExecBackend::Simulated,
        num_cores: 12,
        num_gpus: 1,
        runtime: None,
    };
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch = ColumnBatch::new(schema, vec![Column::F32(vec![1.0].into())]).unwrap();
    let r = exec::execute(&empty, &PhysicalPlan { per_op: vec![] }, batch, None, &env);
    assert!(matches!(r, Err(Error::Plan(_))), "{r:?}");
}

#[test]
fn unknown_columns_surface_schema_errors() {
    use lmstream::engine::ops;
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch = ColumnBatch::new(schema, vec![Column::F32(vec![1.0].into())]).unwrap();
    assert!(matches!(
        ops::filter(&batch, "nope", ops::Predicate::Ge(0.0)),
        Err(Error::Schema(_))
    ));
    assert!(matches!(
        ops::sort_by(&batch, "nope", false),
        Err(Error::Schema(_))
    ));
    assert!(matches!(
        ops::hash_join(&batch, &batch, "nope", "x"),
        Err(Error::Schema(_))
    ));
}

#[test]
fn ragged_concat_rejected() {
    let a = ColumnBatch::new(
        Schema::new(vec![Field::f32("x")]),
        vec![Column::F32(vec![1.0].into())],
    )
    .unwrap();
    let b = ColumnBatch::new(
        Schema::new(vec![Field::f32("y")]),
        vec![Column::F32(vec![1.0].into())],
    )
    .unwrap();
    assert!(ColumnBatch::concat(&[&a, &b]).is_err());
}
