//! Failure injection: malformed inputs must fail loudly with typed
//! errors, never corrupt state or panic.

use lmstream::config::{Config, Mode};
use lmstream::devices::Device;
use lmstream::engine::column::{Column, ColumnBatch, Field, Schema};
use lmstream::error::Error;
use lmstream::query::exec::{self, DevicePlan, ExecEnv};
use lmstream::query::physical::PhysicalPlan;
use lmstream::runtime::artifacts::Manifest;
use lmstream::workloads;
use std::io::Write;
use std::path::{Path, PathBuf};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lmstream-fail-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_artifact_dir_is_artifact_error() {
    let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err:?}");
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn corrupt_manifest_json_rejected() {
    let d = tmpdir("badjson");
    let mut f = std::fs::File::create(d.join("manifest.json")).unwrap();
    f.write_all(b"{ this is not json ]").unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(matches!(err, Error::Json(_)), "{err:?}");
}

#[test]
fn wrong_manifest_format_version_rejected() {
    let d = tmpdir("badformat");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"format": 99, "num_groups": 256, "row_buckets": [1024], "artifacts": []}"#,
    )
    .unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(err.to_string().contains("format"), "{err}");
}

#[test]
fn empty_artifact_list_rejected() {
    let d = tmpdir("empty");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"format": 1, "num_groups": 256, "row_buckets": [1024], "artifacts": []}"#,
    )
    .unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err:?}");
}

#[test]
fn manifest_missing_fields_rejected() {
    let d = tmpdir("missingfields");
    std::fs::write(d.join("manifest.json"), r#"{"format": 1}"#).unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(matches!(err, Error::Json(_)), "{err:?}");
}

#[test]
fn invalid_configs_rejected_before_running() {
    for cfg in [
        Config { num_cores: 0, ..Config::default() },
        Config { num_gpus: 0, ..Config::default() },
        Config { trigger: std::time::Duration::ZERO, ..Config::default() },
        Config { initial_inflection_bytes: -1.0, ..Config::default() },
        Config { initial_throughput: 0.0, ..Config::default() },
    ] {
        assert!(cfg.validate().is_err());
        let w = workloads::by_name("lr1s").unwrap();
        let r = lmstream::coordinator::driver::run(
            &w,
            &cfg,
            std::time::Duration::from_secs(5),
            None,
        );
        assert!(r.is_err(), "driver accepted invalid config");
    }
}

#[test]
fn real_backend_without_runtime_fails_on_gpu_ops() {
    use lmstream::config::ExecBackend;
    let w = workloads::by_name("lr1s").unwrap();
    let cfg = Config {
        mode: Mode::AllGpu,
        backend: ExecBackend::Real,
        ..Config::default()
    };
    let r = lmstream::coordinator::driver::run(
        &w,
        &cfg,
        std::time::Duration::from_secs(15),
        None, // no runtime supplied
    );
    assert!(r.is_err(), "GPU ops without a runtime must fail");
}

#[test]
fn plan_arity_mismatch_rejected() {
    let w = workloads::by_name("lr2s").unwrap();
    let model = lmstream::devices::model::DeviceModel::default();
    let env = ExecEnv {
        model: &model,
        backend: lmstream::config::ExecBackend::Simulated,
        num_cores: 12,
        num_gpus: 1,
        runtime: None,
    };
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch = ColumnBatch::new(schema, vec![Column::F32(vec![1.0].into())]).unwrap();
    // Lifting a short device vector onto the DAG is itself rejected…
    let bad_devices = DevicePlan::all(Device::Cpu, 1); // query has more ops
    assert!(matches!(
        PhysicalPlan::from_devices(&w.query, &bad_devices),
        Err(Error::Plan(_))
    ));
    // …and a hand-built under-length physical plan is rejected at
    // execution time.
    let truncated = PhysicalPlan {
        per_op: PhysicalPlan::uniform(&w.query, Device::Cpu).per_op[..1].to_vec(),
    };
    let r = exec::execute(&w.query, &truncated, batch, None, &env);
    assert!(matches!(r, Err(Error::Plan(_))), "{r:?}");
}

#[test]
fn empty_query_planning_and_execution_are_plan_errors() {
    use lmstream::coordinator::planner::{map_device, SizeEstimator};
    use lmstream::engine::window::WindowSpec;
    use lmstream::query::Query;

    let empty = Query {
        name: "empty".into(),
        ops: vec![],
        window: WindowSpec::tumbling(std::time::Duration::from_secs(30)),
        uses_window_state: false,
    };
    // Planning an empty query must error, not underflow `n - 1`.
    let est = SizeEstimator::new(0);
    let planned = map_device(&empty, 64.0 * 1024.0, 150.0 * 1024.0, 0.1, &est, 2);
    assert!(matches!(planned, Err(Error::Plan(_))), "{planned:?}");

    // Executing one must error too.
    let model = lmstream::devices::model::DeviceModel::default();
    let env = ExecEnv {
        model: &model,
        backend: lmstream::config::ExecBackend::Simulated,
        num_cores: 12,
        num_gpus: 1,
        runtime: None,
    };
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch = ColumnBatch::new(schema, vec![Column::F32(vec![1.0].into())]).unwrap();
    let r = exec::execute(&empty, &PhysicalPlan { per_op: vec![] }, batch, None, &env);
    assert!(matches!(r, Err(Error::Plan(_))), "{r:?}");
}

#[test]
fn unknown_columns_surface_schema_errors() {
    use lmstream::engine::ops;
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch = ColumnBatch::new(schema, vec![Column::F32(vec![1.0].into())]).unwrap();
    assert!(matches!(
        ops::filter(&batch, "nope", ops::Predicate::Ge(0.0)),
        Err(Error::Schema(_))
    ));
    assert!(matches!(
        ops::sort_by(&batch, "nope", false),
        Err(Error::Schema(_))
    ));
    assert!(matches!(
        ops::hash_join(&batch, &batch, "nope", "x"),
        Err(Error::Schema(_))
    ));
}

#[test]
fn ragged_concat_rejected() {
    let a = ColumnBatch::new(
        Schema::new(vec![Field::f32("x")]),
        vec![Column::F32(vec![1.0].into())],
    )
    .unwrap();
    let b = ColumnBatch::new(
        Schema::new(vec![Field::f32("y")]),
        vec![Column::F32(vec![1.0].into())],
    )
    .unwrap();
    assert!(ColumnBatch::concat(&[&a, &b]).is_err());
}

// ---- Durability faults ----------------------------------------------
//
// Damage a real on-disk WAL / ledger / checkpoint-position triple and
// assert each declared recovery mode honors its contract: Precise and
// Rollback fail loudly with typed `Error::Durability`, Gap resumes with
// the damage accounted in the loss report.

use lmstream::durability::{
    reconcile, RecoveryMode, ScanEntry, SinkLedger, Wal, WalPosition,
};
use lmstream::engine::dataset::{Dataset, MicroBatch};
use lmstream::sim::Time;

/// One-dataset micro-batch with `rows` f32 rows, tagged with `id`.
fn mb(id: u64, rows: usize) -> MicroBatch {
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch = ColumnBatch::new(
        schema,
        vec![Column::F32(vec![id as f32; rows].into())],
    )
    .unwrap();
    MicroBatch::new(vec![Dataset {
        id,
        created_at: Time::from_secs_f64(id as f64),
        event_time: Time::from_secs_f64(id as f64),
        wire_bytes: rows * 4,
        batch,
    }])
}

#[test]
fn torn_wal_tail_is_recovered_by_scan_in_every_mode() {
    let d = tmpdir("torn-tail");
    let path = d.join("src.wal");
    let (mut wal, _) = Wal::open(&path).unwrap();
    wal.append(1, &mb(0, 3)).unwrap();
    let before = std::fs::metadata(&path).unwrap().len();
    wal.append(2, &mb(1, 3)).unwrap();
    drop(wal);
    // Crash mid-append of the second record: cut it off mid-frame,
    // leaving the header plus part of the payload.
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(before + 10).unwrap();
    drop(f);

    let (_, scan) = Wal::open(&path).unwrap();
    assert_eq!(scan.torn_tail_bytes, 10, "torn frame must be detected");
    assert_eq!(scan.entries.len(), 1, "records before the tear stay intact");
    assert!(matches!(scan.entries[0], ScanEntry::Ok(_)));
    // A torn tail is NOT an error in any mode — the record never
    // finished its durable append, so the stream regenerates it.
    let ledger = SinkLedger::open(&d.join("l.json")).unwrap();
    let qs = vec![("q".to_string(), 0usize)];
    for mode in [RecoveryMode::Precise, RecoveryMode::Rollback, RecoveryMode::Gap] {
        let (_, scan) = Wal::open(&path).unwrap();
        let r = reconcile("q", None, scan, &ledger, mode, &qs).unwrap();
        assert!(r.lost.is_empty(), "{mode:?}: torn tail is not a loss");
        assert_eq!(r.torn_tail_bytes, 0, "tear already truncated at first reopen");
    }
}

#[test]
fn corrupt_mid_log_record_rejected_with_typed_error() {
    let d = tmpdir("corrupt-mid");
    let path = d.join("src.wal");
    let (mut wal, _) = Wal::open(&path).unwrap();
    let first_end = {
        wal.append(1, &mb(0, 2)).unwrap();
        std::fs::metadata(&path).unwrap().len() as usize
    };
    wal.append(2, &mb(1, 2)).unwrap();
    wal.append(3, &mb(2, 2)).unwrap();
    drop(wal);
    // Flip a payload byte inside the middle record (past its 8-byte
    // frame header) — a complete frame with a CRC mismatch.
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[first_end + 12] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();

    let ledger = SinkLedger::open(&d.join("l.json")).unwrap();
    let qs = vec![("q".to_string(), 0usize)];
    for mode in [RecoveryMode::Precise, RecoveryMode::Rollback] {
        let (_, scan) = Wal::open(&path).unwrap();
        let err = reconcile("q", None, scan, &ledger, mode, &qs).unwrap_err();
        assert!(matches!(err, Error::Durability(_)), "{err:?}");
        assert!(err.to_string().contains("corrupt"), "{err}");
    }
    // Gap accounts the damaged record and keeps the readable ones lost
    // but audited (gap mode replays nothing).
    let (_, scan) = Wal::open(&path).unwrap();
    let r = reconcile("q", None, scan, &ledger, RecoveryMode::Gap, &qs).unwrap();
    assert!(r.replay.is_empty());
    assert!(r.lost.iter().any(|l| l.reason.contains("corrupt")));
}

#[test]
fn checkpoint_wal_position_mismatch_rejected_with_typed_error() {
    let d = tmpdir("pos-mismatch");
    let path = d.join("src.wal");
    let (mut wal, _) = Wal::open(&path).unwrap();
    for i in 0..3 {
        wal.append(1, &mb(i, 2)).unwrap();
    }
    // Checkpoint truncation dropped seqs 1–2, so the log now starts at
    // 3 — but pair it with a *stale* checkpoint claiming high-water 0
    // (as if checkpoint state was restored from an older copy).
    wal.truncate_through(2).unwrap();
    drop(wal);

    let stale = Some(WalPosition { wal_high_water: 0, processed_up_to: Time::ZERO });
    let ledger = SinkLedger::open(&d.join("l.json")).unwrap();
    let qs = vec![("q".to_string(), 0usize)];
    for mode in [RecoveryMode::Precise, RecoveryMode::Rollback] {
        let (_, scan) = Wal::open(&path).unwrap();
        let err = reconcile("q", stale, scan, &ledger, mode, &qs).unwrap_err();
        assert!(matches!(err, Error::Durability(_)), "{err:?}");
        assert!(err.to_string().contains("mismatch"), "{err}");
    }
    // Gap resumes, accounting the unreachable range [1, 3).
    let (_, scan) = Wal::open(&path).unwrap();
    let r = reconcile("q", stale, scan, &ledger, RecoveryMode::Gap, &qs).unwrap();
    assert!(r.lost.iter().any(|l| l.reason.contains("position mismatch")));
}

#[test]
fn ledger_ahead_of_checkpoint_rejected_with_typed_error() {
    let d = tmpdir("ledger-ahead");
    let path = d.join("src.wal");
    let (mut wal, _) = Wal::open(&path).unwrap();
    wal.append(1, &mb(0, 2)).unwrap();
    wal.append(2, &mb(1, 2)).unwrap();
    drop(wal);
    // The ledger proves batch 7 was delivered, but base 0 plus a
    // 2-record tail only reproduces indices 0–1: the WAL was truncated
    // past delivered, uncheckpointed work.
    let mut ledger = SinkLedger::open(&d.join("l.json")).unwrap();
    ledger.record("q", 9, 7);
    ledger.persist().unwrap();

    let qs = vec![("q".to_string(), 0usize)];
    for mode in [RecoveryMode::Precise, RecoveryMode::Rollback] {
        let (_, scan) = Wal::open(&path).unwrap();
        let err = reconcile("q", None, scan, &ledger, mode, &qs).unwrap_err();
        assert!(matches!(err, Error::Durability(_)), "{err:?}");
        assert!(err.to_string().contains("ahead"), "{err}");
    }
    // Gap restarts live batches above the ledger mark instead.
    let (_, scan) = Wal::open(&path).unwrap();
    let r = reconcile("q", None, scan, &ledger, RecoveryMode::Gap, &qs).unwrap();
    assert_eq!(r.batch_base[0].1, 8);
}

// ---- Executor faults × recovery modes -------------------------------
//
// Compose the two failure axes: executors crash/stall *inside* rounds
// (the fault-injection plan) while the sink machine dies *between*
// rounds (a failed delivery aborts the incarnation). Each recovery
// mode must still honor its durability contract across the resume.

use lmstream::cluster::{ClusterSpec, FaultPlan};
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::sink::Sink;
use lmstream::query::QueryBuilder;
use lmstream::session::Session;
use lmstream::source::stream::RowGen;
use lmstream::source::traffic::Traffic;
use lmstream::workloads::Workload;
use std::collections::BTreeSet;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Identity-stamped rows, as in `tests/durability.rs`: (t = tick,
/// v = tick*10_000 + i, m = i % 10) — unique identities, exact in f32.
struct IdentGen;

impl RowGen for IdentGen {
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
        let schema =
            Schema::new(vec![Field::f32("t"), Field::f32("v"), Field::f32("m")]);
        let t: Vec<f32> = vec![tick as f32; rows];
        let v: Vec<f32> =
            (0..rows).map(|i| (tick * 10_000 + i as u64) as f32).collect();
        let m: Vec<f32> = (0..rows).map(|i| (i % 10) as f32).collect();
        ColumnBatch::new(
            schema,
            vec![Column::F32(t.into()), Column::F32(v.into()), Column::F32(m.into())],
        )
        .unwrap()
    }
}

fn make_gen(_seed: u64) -> Box<dyn RowGen> {
    Box::new(IdentGen)
}

const ROWS_PER_TICK: usize = 10;

fn ident_workload(name: &'static str) -> Workload {
    let query = QueryBuilder::scan(name)
        .filter("m", Predicate::Lt(6.0))
        .select(&["t", "v"])
        .build()
        .unwrap();
    Workload::new(name, query, Traffic::Constant { rows: ROWS_PER_TICK }, make_gen)
}

/// Oracle row stream (rows with i % 10 < 6, in tick order).
fn oracle(max_tick: u64) -> Vec<(f32, f32)> {
    let mut out = Vec::new();
    for tick in 0..=max_tick {
        for i in 0..ROWS_PER_TICK {
            if i % 10 < 6 {
                out.push((tick as f32, (tick * 10_000 + i as u64) as f32));
            }
        }
    }
    out
}

struct RecSink {
    rows: Arc<Mutex<Vec<(f32, f32)>>>,
    fail_after: Option<usize>,
    delivered: usize,
}

impl Sink for RecSink {
    fn deliver(
        &mut self,
        _i: usize,
        result: &ChunkedBatch,
        _t: Time,
    ) -> lmstream::error::Result<()> {
        if self.fail_after == Some(self.delivered) {
            return Err(Error::Durability("injected sink crash".into()));
        }
        self.delivered += 1;
        let b = result.coalesce();
        let t = b.column("t").unwrap().as_f32().unwrap();
        let v = b.column("v").unwrap().as_f32().unwrap();
        let mut rows = self.rows.lock().unwrap();
        for i in 0..b.rows() {
            if b.validity.is_live(i) {
                rows.push((t[i], v[i]));
            }
        }
        Ok(())
    }
}

/// One incarnation against the given config; returns the run outcome
/// and the recovery report's lost dataset ids.
fn faulted_incarnation(
    cfg: Config,
    name: &'static str,
    rows: &Arc<Mutex<Vec<(f32, f32)>>>,
    fail_after: Option<usize>,
) -> (lmstream::error::Result<()>, BTreeSet<u64>) {
    let mut session = Session::new(cfg).unwrap();
    let qid = session.register(ident_workload(name)).unwrap();
    session
        .set_sink(
            qid,
            Box::new(RecSink { rows: Arc::clone(rows), fail_after, delivered: 0 }),
        )
        .unwrap();
    let out = session.run(Duration::from_secs(60)).map(|_| ());
    let lost = match session.recovery_report() {
        Some(rep) => rep
            .sources
            .iter()
            .flat_map(|s| s.lost.iter())
            .flat_map(|l| l.dataset_ids.iter().copied())
            .collect(),
        None => BTreeSet::new(),
    };
    (out, lost)
}

fn faulted_durable_cfg(base: &Path, mode: RecoveryMode) -> Config {
    Config {
        mode: Mode::LmStream,
        checkpoint_dir: Some(base.join("ckpt").to_string_lossy().into_owned()),
        wal_dir: Some(base.join("wal").to_string_lossy().into_owned()),
        recovery_mode: mode,
        cluster: Some(ClusterSpec::of(3)),
        fault_plan: Some(
            FaultPlan::new().stall(2, 1).gpu_fail(2, 2).crash(3, 1).rejoin(5, 1),
        ),
        seed: 11,
        ..Config::default()
    }
}

#[test]
fn executor_faults_compose_with_sink_crash_recovery_in_every_mode() {
    for mode in [RecoveryMode::Precise, RecoveryMode::Rollback, RecoveryMode::Gap] {
        for &crash_at in &[0usize, 2] {
            let name = format!("execfault-{mode:?}-{crash_at}").to_lowercase();
            let base = tmpdir(&name);
            let rows = Arc::new(Mutex::new(Vec::new()));

            // Incarnation 1: executors stall/crash mid-round (recovered
            // in-process by retry + re-planning) until the sink machine
            // dies at its `crash_at`-th delivery.
            let (out, _) = faulted_incarnation(
                faulted_durable_cfg(&base, mode),
                "execfault",
                &rows,
                Some(crash_at),
            );
            assert!(out.is_err(), "{name}: injected sink crash must abort the run");

            // Incarnation 2: resume. The same fault plan fires again
            // (rounds restart at 1 in the new incarnation) — executor
            // faults keep being absorbed by the retry machinery while
            // recovery reconciles the durability triple.
            let (out, lost) = faulted_incarnation(
                faulted_durable_cfg(&base, mode),
                "execfault",
                &rows,
                None,
            );
            out.unwrap_or_else(|e| panic!("{name}: resume failed: {e}"));

            let all = rows.lock().unwrap().clone();
            assert!(!all.is_empty(), "{name}: nothing delivered");
            match mode {
                RecoveryMode::Precise | RecoveryMode::Rollback => {
                    // Zero duplicates, zero losses: concatenated output
                    // is an exact oracle prefix despite both failure
                    // axes firing.
                    assert!(lost.is_empty(), "{name}: reported losses");
                    let full = oracle(4_000);
                    assert!(all.len() <= full.len(), "{name}: run too long for oracle");
                    assert_eq!(
                        all,
                        &full[..all.len()],
                        "{name}: output diverged from the oracle"
                    );
                }
                RecoveryMode::Gap => {
                    // Gap may skip the crashed round, but delivered and
                    // lost ticks must tile the stream: each tick's rows
                    // delivered exactly once or reported lost — never
                    // both, never twice.
                    let delivered: BTreeSet<u64> =
                        all.iter().map(|&(t, _)| t as u64).collect();
                    assert!(
                        delivered.is_disjoint(&lost),
                        "{name}: tick both delivered and reported lost"
                    );
                    let max_tick =
                        delivered.iter().chain(lost.iter()).copied().max().unwrap();
                    let expected: Vec<(f32, f32)> = oracle(max_tick)
                        .into_iter()
                        .filter(|&(t, _)| !lost.contains(&(t as u64)))
                        .collect();
                    assert_eq!(all, expected, "{name}: delivered+lost don't tile");
                }
            }
        }
    }
}

// ---- WAL growth cap -------------------------------------------------

#[test]
fn wal_over_cap_without_checkpointing_is_typed_durability_error() {
    // No checkpoint_dir: the log never truncates, so a tiny cap must
    // trip. Precise mode refuses to drop history → typed error.
    let base = tmpdir("walcap-precise");
    let cfg = Config {
        mode: Mode::LmStream,
        wal_dir: Some(base.join("wal").to_string_lossy().into_owned()),
        recovery_mode: RecoveryMode::Precise,
        wal_max_bytes: Some(512),
        seed: 11,
        ..Config::default()
    };
    let rows = Arc::new(Mutex::new(Vec::new()));
    let (out, _) = faulted_incarnation(cfg, "walcap", &rows, None);
    match out {
        Err(Error::Durability(msg)) => {
            assert!(msg.contains("wal_max_bytes"), "unexpected message: {msg}");
        }
        other => panic!("expected Error::Durability, got {other:?}"),
    }
}

#[test]
fn wal_over_cap_in_gap_mode_rolls_the_log_and_keeps_running() {
    let base = tmpdir("walcap-gap");
    let wal_dir = base.join("wal");
    let cfg = Config {
        mode: Mode::LmStream,
        wal_dir: Some(wal_dir.to_string_lossy().into_owned()),
        recovery_mode: RecoveryMode::Gap,
        wal_max_bytes: Some(512),
        seed: 11,
        ..Config::default()
    };
    let rows = Arc::new(Mutex::new(Vec::new()));
    let (out, _) = faulted_incarnation(cfg, "walroll", &rows, None);
    out.unwrap();
    let delivered_batches = {
        let all = rows.lock().unwrap();
        assert!(!all.is_empty(), "gap roll must not stop delivery");
        all.iter().map(|&(t, _)| t as u64).collect::<BTreeSet<_>>().len()
    };
    assert!(delivered_batches >= 3, "need several rounds to exercise the roll");

    // The log rolled: far fewer frames remain than rounds appended.
    let (_, scan) = Wal::open(&wal_dir.join("walroll.wal")).unwrap();
    assert!(
        scan.entries.len() < delivered_batches,
        "log should have rolled: {} frames for {} delivered ticks",
        scan.entries.len(),
        delivered_batches
    );
}
