//! Cross-query GPU co-scheduling: correctness and honesty.
//!
//! * **Differential** — co-scheduled execution (joint plans + shared
//!   per-executor GPU timelines) is bit-identical to independently-
//!   planned execution with idle devices: scheduling moves *time*,
//!   never rows. Covered single-node AND as a 2-source, 2-executor
//!   round (the cross-source/topology-aware tentpole), plus at the
//!   session level (first-round sink outputs across the co_schedule
//!   toggle).
//! * **Property** — across sizes/inflection points/query mixes and
//!   topologies: reordered makespan ≤ FIFO makespan ≤ Σ independent
//!   per-query plan costs, and never worse than all-CPU.
//! * **Pinned contention scenario** (acceptance) — two GPU-leaning
//!   queries on one GPU: independent planning double-books the device
//!   (its idle-GPU latency prediction under-estimates the
//!   shared-timeline simulation), while the joint plan respects the
//!   shared timeline and achieves a lower simulated makespan.
//! * **Pinned reordering scenario** — a round where
//!   shortest-GPU-segment-first provably beats FIFO registration order.

mod common;

use common::fingerprint;
use lmstream::cluster::{self, ClusterSpec, DeviceTopology};
use lmstream::config::{Config, ExecBackend, Mode};
use lmstream::coordinator::planner::SizeEstimator;
use lmstream::coordinator::schedule::{plan_joint, QueryCandidate};
use lmstream::devices::model::DeviceModel;
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::ops::aggregate::AggSpec;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::sink::Sink;
use lmstream::engine::window::WindowSpec;
use lmstream::query::exec::{self, ExecEnv, ExecOutcome, GpuTimeline, NoContention};
use lmstream::query::physical::PhysicalPlan;
use lmstream::query::{Query, QueryBuilder};
use lmstream::session::Session;
use lmstream::sim::Time;
use lmstream::source::stream::RowGen;
use lmstream::workloads::{self, linear_road::LinearRoadGen};
use std::sync::{Arc, Mutex};
use std::time::Duration;

const KB: f64 = 1024.0;

fn single_topo() -> DeviceTopology {
    DeviceTopology::single(12, 1)
}

fn window() -> WindowSpec {
    WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5))
}

/// A mixed bag of query shapes over the Linear Road schema: chain,
/// branch, union, windowed join, aggregate.
fn query_zoo() -> Vec<Query> {
    vec![
        QueryBuilder::scan("chain")
            .window(window())
            .filter("speed", Predicate::Ge(20.0))
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
        QueryBuilder::scan("branchy")
            .window(window())
            .filter("speed", Predicate::Lt(80.0))
            .branch(|b| b.select(&["vehicle"]))
            .sort("speed", false)
            .build()
            .unwrap(),
        QueryBuilder::scan("diamond")
            .window(window())
            .merge_union(|b| b.filter("speed", Predicate::Ge(55.0)))
            .build()
            .unwrap(),
        QueryBuilder::scan("joiny")
            .window(window())
            .join_window("vehicle", "vehicle")
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
        QueryBuilder::scan("aggy")
            .window(window())
            .shuffle("segment")
            .aggregate(&["segment"], vec![AggSpec::avg("speed", "avgSpeed")], None)
            .build()
            .unwrap(),
    ]
}

fn input(seed: u64, rows: usize, chunks: usize) -> ChunkedBatch {
    let mut gen = LinearRoadGen::new(seed);
    let per = rows / chunks;
    let mut out = ChunkedBatch::from_batch(gen.generate(0, per));
    for c in 1..chunks {
        out.push(gen.generate(c as u64, per)).unwrap();
    }
    out
}

fn build_candidates<'a>(
    queries: &'a [Query],
    inputs: &[ChunkedBatch],
    windows: &[Option<ChunkedBatch>],
    part: f64,
    inf: f64,
) -> Vec<QueryCandidate<'a>> {
    queries
        .iter()
        .zip(inputs)
        .zip(windows)
        .map(|((q, i), w)| {
            let est = SizeEstimator::new(q.len());
            let aux = w.as_ref().map(|w| w.alloc_bytes()).unwrap_or(0) as f64;
            let aux_chunks = w.as_ref().map(|w| w.num_chunks()).unwrap_or(0);
            QueryCandidate::build(q, part, inf, 0.1, &est, i.num_chunks(), aux, aux_chunks)
                .unwrap()
        })
        .collect()
}

/// Execute every query against `plans`, arbitrating GPU ops through one
/// shared timeline when `shared` (otherwise each query sees an idle
/// device). Returns the outcomes plus the timeline.
fn run_all(
    queries: &[Query],
    plans: &[PhysicalPlan],
    inputs: &[ChunkedBatch],
    windows: &[Option<ChunkedBatch>],
    shared: bool,
) -> (Vec<ExecOutcome>, GpuTimeline) {
    let model = DeviceModel::default();
    let env = ExecEnv {
        model: &model,
        backend: ExecBackend::Simulated,
        num_cores: 12,
        num_gpus: 1,
        runtime: None,
    };
    let mut timeline = GpuTimeline::new();
    let outcomes = queries
        .iter()
        .zip(plans)
        .zip(inputs)
        .zip(windows)
        .map(|(((q, p), i), w)| {
            if shared {
                exec::execute_with_occupancy(q, p, i.clone(), w.as_ref(), &env, &mut timeline)
                    .unwrap()
            } else {
                exec::execute_with_occupancy(
                    q,
                    p,
                    i.clone(),
                    w.as_ref(),
                    &env,
                    &mut NoContention,
                )
                .unwrap()
            }
        })
        .collect();
    (outcomes, timeline)
}

/// Differential: joint plans on the contended timeline produce exactly
/// the rows the independent plans produce on idle devices — outputs
/// must not depend on scheduling.
#[test]
fn coscheduled_outputs_bit_identical_to_independent() {
    let queries = query_zoo();
    let inputs: Vec<ChunkedBatch> =
        (0..queries.len()).map(|k| input(11 + k as u64, 3000, 5)).collect();
    let windows: Vec<Option<ChunkedBatch>> = queries
        .iter()
        .enumerate()
        .map(|(k, q)| {
            q.ops
                .iter()
                .any(|o| matches!(o.spec.kind(), lmstream::query::OpKind::Join))
                .then(|| input(90 + k as u64, 6000, 6))
        })
        .collect();

    for (part, inf) in [(8.0 * KB, 40.0 * KB), (60.0 * KB, 10.0 * KB), (200.0 * KB, 150.0 * KB)]
    {
        let cands = build_candidates(&queries, &inputs, &windows, part, inf);
        let joint = plan_joint(&cands, &DeviceModel::default(), &single_topo());
        let independent: Vec<PhysicalPlan> =
            cands.iter().map(|c| c.independent.clone()).collect();

        let (contended, timeline) = run_all(&queries, &joint.plans, &inputs, &windows, true);
        let (idle, _) = run_all(&queries, &independent, &inputs, &windows, false);

        for (a, b) in contended.iter().zip(&idle) {
            assert_eq!(
                fingerprint(&a.result.coalesce()),
                fingerprint(&b.result.coalesce()),
                "primary results diverged under co-scheduling"
            );
            assert_eq!(a.branch_results.len(), b.branch_results.len());
            for ((ia, ba), (ib, bb)) in a.branch_results.iter().zip(&b.branch_results) {
                assert_eq!(ia, ib);
                assert_eq!(fingerprint(&ba.coalesce()), fingerprint(&bb.coalesce()));
            }
        }
        // The timeline really arbitrated (it saw every GPU reservation).
        let gpu_ops: usize = joint.plans.iter().map(|p| p.gpu_ops()).sum();
        assert_eq!(timeline.reservations(), gpu_ops);
    }
}

/// Property: across sizes, inflection points, query mixes and
/// topologies, the guarantee chain holds — reordered makespan ≤ FIFO
/// makespan ≤ Σ independent per-query plan costs — and the joint plan
/// is never worse than all-CPU.
#[test]
fn reordered_lte_fifo_lte_independent_sum_across_topologies() {
    let queries = query_zoo();
    let model = DeviceModel::default();
    let est_inputs: Vec<ChunkedBatch> =
        (0..queries.len()).map(|k| input(31 + k as u64, 2000, 4)).collect();
    let windows: Vec<Option<ChunkedBatch>> = queries.iter().map(|_| None).collect();
    let topos = [
        single_topo(),
        DeviceTopology::from_cluster(&ClusterSpec::of(2)),
        DeviceTopology::from_cluster(&ClusterSpec::paper()),
    ];
    for topo in &topos {
        for part_kb in [2.0, 10.0, 50.0, 150.0, 600.0] {
            for inf_kb in [5.0, 50.0, 300.0] {
                for n in 1..=queries.len() {
                    let cands = build_candidates(
                        &queries[..n],
                        &est_inputs[..n],
                        &windows[..n],
                        part_kb * KB,
                        inf_kb * KB,
                    );
                    let jp = plan_joint(&cands, &model, topo);
                    let p = &jp.predicted;
                    let ctx = format!(
                        "E={} part {part_kb}KB inf {inf_kb}KB n {n}",
                        topo.num_executors()
                    );
                    assert!(
                        p.makespan <= p.fifo_makespan + 1e-9,
                        "{ctx}: reordered {} > FIFO {}",
                        p.makespan,
                        p.fifo_makespan
                    );
                    let independent_sum: f64 = p.independent.iter().sum();
                    assert!(
                        p.fifo_makespan <= independent_sum + 1e-6,
                        "{ctx}: FIFO {} > Σ independent {}",
                        p.fifo_makespan,
                        independent_sum
                    );
                    assert!(
                        p.makespan <= p.all_cpu_makespan + 1e-6,
                        "{ctx}: joint {} > all-CPU {}",
                        p.makespan,
                        p.all_cpu_makespan
                    );
                    assert!(
                        p.independent_shared_makespan <= independent_sum + 1e-6,
                        "{ctx}: FIFO-serialized independent {} > Σ independent {}",
                        p.independent_shared_makespan,
                        independent_sum
                    );
                    // Full assignment, every query covered, grant order
                    // a permutation.
                    assert_eq!(jp.plans.len(), n);
                    let mut sorted = p.order.clone();
                    sorted.sort_unstable();
                    assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "{ctx}: {:?}", p.order);
                    for (qc, plan) in cands.iter().zip(&jp.plans) {
                        assert_eq!(plan.len(), qc.query.len());
                    }
                }
            }
        }
    }
}

/// Acceptance pin: a contended two-query scenario.
///
/// 1. Independent planning double-books the GPU: the per-query idle-GPU
///    prediction under-estimates what the shared-timeline simulation
///    actually measures for those same plans.
/// 2. The joint plan respects the shared GPU timeline (every simulated
///    reservation went through it; waits are accounted in proc).
/// 3. The joint plan's simulated makespan beats the independent plans'.
/// 4. Results are bit-identical either way (differential equivalence).
#[test]
fn pinned_two_query_contention_scenario() {
    let queries = vec![
        QueryBuilder::scan("hot-a")
            .window(window())
            .filter("speed", Predicate::Ge(0.0))
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
        QueryBuilder::scan("hot-b")
            .window(window())
            .filter("speed", Predicate::Ge(0.0))
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
    ];
    // ~600 KB per query (50 KB per partition over 12 cores): GPU is
    // faster but the CPU is competitive — exactly the regime where two
    // all-GPU plans queueing on one device lose to rationing.
    let inputs: Vec<ChunkedBatch> = (0..2).map(|k| input(7 + k, 9000, 6)).collect();
    let windows: Vec<Option<ChunkedBatch>> = vec![None, None];
    let part = inputs[0].alloc_bytes() as f64 / 12.0;
    // A small inflection point: Alg. 2 wants every op on the GPU.
    let cands = build_candidates(&queries, &inputs, &windows, part, 10.0 * KB);
    assert!(
        cands.iter().all(|c| c.independent.gpu_ops() == c.query.len()),
        "scenario needs GPU-hungry independent plans"
    );

    let joint = plan_joint(&cands, &DeviceModel::default(), &single_topo());
    let independent: Vec<PhysicalPlan> =
        cands.iter().map(|c| c.independent.clone()).collect();

    // --- Simulate both worlds on the shared device.
    let (ind_contended, ind_timeline) =
        run_all(&queries, &independent, &inputs, &windows, true);
    let (ind_idle, _) = run_all(&queries, &independent, &inputs, &windows, false);
    let (joint_contended, joint_timeline) =
        run_all(&queries, &joint.plans, &inputs, &windows, true);

    // 1. Double-booking: the idle-GPU prediction (what per-query
    //    MapDevice believes) under-estimates the contended simulation of
    //    the very same plans — by at least 20% here, since the second
    //    query queues behind the whole first chain.
    let ind_sim_makespan =
        ind_contended.iter().map(|o| o.proc).max().unwrap().as_secs_f64();
    let ind_idle_makespan = ind_idle.iter().map(|o| o.proc).max().unwrap().as_secs_f64();
    assert!(
        ind_sim_makespan > ind_idle_makespan * 1.2,
        "no double-booking: contended {ind_sim_makespan}s vs idle {ind_idle_makespan}s"
    );
    // The scheduler's own prediction agrees about the under-estimate.
    let predicted_ind_max =
        joint.predicted.independent.iter().copied().fold(0.0, f64::max);
    assert!(
        joint.predicted.independent_shared_makespan > predicted_ind_max * 1.2,
        "prediction missed the double-booking"
    );

    // 2. The joint run respected the shared timeline: every simulated
    //    GPU reservation passed through it, its busy time fits inside
    //    the makespan, and waits surfaced in proc/contention.
    let joint_sim_makespan =
        joint_contended.iter().map(|o| o.proc).max().unwrap().as_secs_f64();
    let joint_gpu_ops: usize = joint.plans.iter().map(|p| p.gpu_ops()).sum();
    assert_eq!(joint_timeline.reservations(), joint_gpu_ops);
    assert!(joint_timeline.busy().as_secs_f64() <= joint_sim_makespan + 1e-9);
    assert_eq!(ind_timeline.reservations(), 2 * queries[0].len());
    for o in &joint_contended {
        assert!(o.proc >= o.contention);
    }

    // 3. Lower simulated makespan than the independent plans.
    assert!(
        joint_sim_makespan < ind_sim_makespan,
        "joint {joint_sim_makespan}s !< independent {ind_sim_makespan}s"
    );
    // And the prediction saw it coming.
    assert!(
        joint.predicted.makespan < joint.predicted.independent_shared_makespan,
        "prediction: joint {} !< independent-serialized {}",
        joint.predicted.makespan,
        joint.predicted.independent_shared_makespan
    );

    // 4. Result equivalence: co-scheduling moved time, not rows.
    for (a, b) in joint_contended.iter().zip(&ind_idle) {
        assert_eq!(
            fingerprint(&a.result.coalesce()),
            fingerprint(&b.result.coalesce())
        );
    }
}

/// Pinned reordering scenario: FIFO registration order is provably
/// beaten by shortest-GPU-segment-first.
///
/// Query 0 owns a long GPU segment and nothing after it; query 1 has a
/// short GPU segment followed by a long CPU tail. Under FIFO grants,
/// putting query 1's segment on the device means queueing it behind
/// query 0's whole segment — so the FIFO scheduler must leave query 1
/// on the CPU and eat its slow scan. Granting the short segment first
/// lets both queries use the device and strictly shrinks the round
/// makespan. Candidates are handcrafted (the scheduler consumes only
/// the byte/chunk estimates and the independent plan).
#[test]
fn pinned_reordering_beats_fifo() {
    use lmstream::devices::Device;
    use lmstream::query::exec::DevicePlan;
    use lmstream::query::OpKind;

    let q_long = QueryBuilder::scan("long").build().unwrap();
    let q_short = QueryBuilder::scan("short")
        .filter("v", Predicate::Ge(0.0))
        .build()
        .unwrap();

    // Per-partition byte estimates (12 cores, 1 GPU, default model):
    // 170 KB scan → ~1.3 s GPU busy / ~2.5 s CPU; 60 KB scan → ~0.64 s
    // GPU busy / ~0.9 s CPU; 215 KB filter → ~1.6 s CPU tail. The
    // Eq. 7/8/9 fields are unused by plan_joint (it re-costs through
    // the DeviceModel), so they are zeroed.
    use lmstream::coordinator::planner::OpCandidate;
    let cand_op = |op_id: usize, kind: OpKind, est: f64| OpCandidate {
        op_id,
        kind,
        est_in_bytes: est,
        est_out_bytes: est,
        est_bytes: est,
        est_in_chunks: 1,
        cpu_cost: 0.0,
        gpu_cost: 0.0,
        trans_cost: 0.0,
    };
    let cands = vec![
        QueryCandidate {
            query: &q_long,
            candidates: vec![cand_op(0, OpKind::Scan, 170.0 * KB)],
            independent: PhysicalPlan::uniform(&q_long, Device::Gpu),
            input_chunks: 1,
            aux_bytes: 0.0,
            aux_chunks: 0,
        },
        QueryCandidate {
            query: &q_short,
            candidates: vec![
                cand_op(0, OpKind::Scan, 60.0 * KB),
                cand_op(1, OpKind::Filter, 215.0 * KB),
            ],
            independent: PhysicalPlan::from_devices(
                &q_short,
                &DevicePlan { per_op: vec![Device::Gpu, Device::Cpu] },
            )
            .unwrap(),
            input_chunks: 1,
            aux_bytes: 0.0,
            aux_chunks: 0,
        },
    ];

    let jp = plan_joint(&cands, &DeviceModel::default(), &single_topo());
    let p = &jp.predicted;
    assert_eq!(p.order, vec![1, 0], "short GPU segment must be granted first: {p:?}");
    assert!(
        p.makespan < p.fifo_makespan * 0.97,
        "reordering must strictly beat FIFO: {} !< {}",
        p.makespan,
        p.fifo_makespan
    );
    // The winning schedule runs BOTH queries on the device (FIFO could
    // only afford one without growing the makespan).
    assert!(jp.plans.iter().all(|plan| plan.gpu_ops() > 0), "{:?}", jp.plans);
    // Guarantee chain intact.
    assert!(p.makespan <= p.all_cpu_makespan + 1e-9);
    assert!(p.fifo_makespan <= p.independent.iter().sum::<f64>() + 1e-9);
}

/// Execute a round of queries on a cluster, arbitrating every query's
/// GPU ops through one shared per-executor timeline bank when `shared`,
/// walking the queries in `order`.
fn run_round_on_cluster(
    spec: &ClusterSpec,
    queries: &[Query],
    plans: &[PhysicalPlan],
    inputs: &[ChunkedBatch],
    order: &[usize],
    shared: bool,
) -> (Vec<cluster::ClusterOutcome>, Vec<GpuTimeline>) {
    let model = DeviceModel::default();
    let mut timelines: Vec<GpuTimeline> =
        vec![GpuTimeline::new(); spec.executors.len()];
    let mut outcomes: Vec<Option<cluster::ClusterOutcome>> =
        (0..queries.len()).map(|_| None).collect();
    for &i in order {
        let o = cluster::execute_on_cluster_with_occupancy(
            spec,
            &queries[i],
            &plans[i],
            inputs[i].clone(),
            None,
            &model,
            ExecBackend::Simulated,
            None,
            if shared { Some(&mut timelines) } else { None },
        )
        .unwrap();
        outcomes[i] = Some(o);
    }
    (outcomes.into_iter().map(|o| o.unwrap()).collect(), timelines)
}

/// The acceptance differential: three GPU-eligible queries staged from
/// two sources plan through ONE topology-aware `plan_joint` over a
/// 2-executor topology, execute against one shared per-executor
/// timeline bank in the scheduler's grant order — and every sink output
/// is bit-identical to independent planning on idle devices.
#[test]
fn two_source_two_executor_round_outputs_identical() {
    let spec = ClusterSpec::of(2);
    let topo = DeviceTopology::from_cluster(&spec);
    let queries = vec![
        // "Source A" queries (same input stream)…
        QueryBuilder::scan("a-main")
            .window(window())
            .filter("speed", Predicate::Ge(20.0))
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
        QueryBuilder::scan("a-side")
            .window(window())
            .filter("speed", Predicate::Lt(80.0))
            .sort("speed", false)
            .build()
            .unwrap(),
        // …and a "source B" query over a different stream.
        QueryBuilder::scan("b-main")
            .window(window())
            .shuffle("segment")
            .build()
            .unwrap(),
    ];
    let src_a = input(51, 9000, 5);
    let src_b = input(52, 7000, 4);
    let inputs = vec![src_a.clone(), src_a, src_b];
    let windows: Vec<Option<ChunkedBatch>> = vec![None, None, None];

    // Per-partition share over the whole topology's cores; a small
    // inflection point makes every independent plan GPU-hungry.
    let part = inputs[0].alloc_bytes() as f64 / topo.total_cores() as f64;
    let cands = build_candidates(&queries, &inputs, &windows, part, 4.0 * KB);
    assert!(
        cands.iter().all(|c| c.independent.gpu_ops() > 0),
        "scenario needs GPU-eligible queries"
    );
    let jp = plan_joint(&cands, &DeviceModel::default(), &topo);
    assert_eq!(jp.plans.len(), 3);
    let independent: Vec<PhysicalPlan> =
        cands.iter().map(|c| c.independent.clone()).collect();
    let fifo: Vec<usize> = (0..queries.len()).collect();

    let (contended, timelines) = run_round_on_cluster(
        &spec,
        &queries,
        &jp.plans,
        &inputs,
        &jp.predicted.order,
        true,
    );
    let (idle, _) =
        run_round_on_cluster(&spec, &queries, &independent, &inputs, &fifo, false);

    for (a, b) in contended.iter().zip(&idle) {
        assert_eq!(
            fingerprint(&a.result.coalesce()),
            fingerprint(&b.result.coalesce()),
            "sink outputs diverged under topology-aware co-scheduling"
        );
        assert_eq!(a.branch_results.len(), b.branch_results.len());
        for ((ia, ba), (ib, bb)) in a.branch_results.iter().zip(&b.branch_results) {
            assert_eq!(ia, ib);
            assert_eq!(fingerprint(&ba.coalesce()), fingerprint(&bb.coalesce()));
        }
    }
    // Every executor's timeline arbitrated its share: each executor
    // books every GPU op of every plan exactly once.
    let joint_gpu_ops: usize = jp.plans.iter().map(|p| p.gpu_ops()).sum();
    assert_eq!(timelines.len(), 2);
    for tl in &timelines {
        assert_eq!(tl.reservations(), joint_gpu_ops);
    }
}

type Fp = (Vec<Vec<u8>>, Vec<u8>);

/// Sink publishing per-delivery fingerprints through shared state, so
/// outputs survive the session consuming the Box.
struct FingerprintSink {
    seen: Arc<Mutex<Vec<Fp>>>,
}

impl Sink for FingerprintSink {
    fn deliver(
        &mut self,
        _i: usize,
        result: &ChunkedBatch,
        _t: Time,
    ) -> lmstream::error::Result<()> {
        self.seen.lock().unwrap().push(fingerprint(&result.coalesce()));
        Ok(())
    }
}

/// Two sources (identical workloads → identical admission instants),
/// three queries; returns per-query run results + captured sink
/// fingerprints.
fn run_two_source_session(
    co_schedule: bool,
    cluster: Option<ClusterSpec>,
) -> (Vec<lmstream::session::RunResult>, Vec<Arc<Mutex<Vec<Fp>>>>) {
    let cfg = Config {
        mode: Mode::LmStream,
        co_schedule,
        cluster,
        // Fixed, small inflection point: plans lean GPU and eligibility
        // does not drift with the optimizer.
        initial_inflection_bytes: 1024.0,
        online_optimizer: false,
        ..Config::default()
    };
    let mut s = Session::new(cfg).unwrap();
    let w = workloads::by_name("lr1s").unwrap();
    let win = w.query.window;
    let first = s.register(w).unwrap();
    let side = QueryBuilder::scan("side")
        .window(win)
        .filter("speed", Predicate::Lt(60.0))
        .build()
        .unwrap();
    let second = s.register_shared(first, "side", side).unwrap();
    // Second source: the same workload again → same stream seed, same
    // bounds, so both sources admit in the same scheduling rounds.
    let third = s.register(workloads::by_name("lr1s").unwrap()).unwrap();

    let mut captured = Vec::new();
    for qid in [first, second, third] {
        let seen: Arc<Mutex<Vec<Fp>>> = Arc::new(Mutex::new(Vec::new()));
        captured.push(Arc::clone(&seen));
        s.set_sink(qid, Box::new(FingerprintSink { seen })).unwrap();
    }
    let rs = s.run(Duration::from_secs(45)).unwrap();
    (rs, captured)
}

/// Cross-source rounds at the session level: queries of *different*
/// sources share scheduling rounds (same `BatchRecord::round` ids, so
/// their procs embed one contended makespan), and the first round's
/// sink outputs are bit-identical across the co_schedule toggle —
/// joint planning moves time, never rows. (Later rounds legitimately
/// diverge in batch *content*: contended clocks admit different data.)
#[test]
fn session_cross_source_rounds_share_timelines_and_outputs() {
    let (rs_on, fp_on) = run_two_source_session(true, None);
    let (rs_off, fp_off) = run_two_source_session(false, None);
    for rs in [&rs_on, &rs_off] {
        assert_eq!(rs.len(), 3);
        assert!(rs.iter().all(|r| !r.batches.is_empty()), "all queries ran");
    }
    // Identical sources start from identical admission state, so the
    // *first* round is shared across sources by construction (later
    // rounds can drift apart once contended throughputs feed Eq. 6
    // differently per source).
    let rounds = |r: &lmstream::session::RunResult| {
        r.batches.iter().map(|b| b.round).collect::<Vec<usize>>()
    };
    assert_eq!(
        rounds(&rs_on[0])[0],
        rounds(&rs_on[2])[0],
        "cross-source queries must co-schedule in the same first round"
    );
    // Queries sharing a source share every round.
    assert_eq!(rounds(&rs_on[0]), rounds(&rs_on[1]));
    // First-round differential across the toggle.
    for (q, (on, off)) in fp_on.iter().zip(&fp_off).enumerate() {
        let on = on.lock().unwrap();
        let off = off.lock().unwrap();
        assert!(!on.is_empty() && !off.is_empty(), "query {q} delivered nothing");
        assert_eq!(
            on[0], off[0],
            "query {q}: first-round outputs diverged across co_schedule toggle"
        );
    }
}

/// The acceptance smoke at the session level: a 2-executor cluster
/// session with 3 GPU-eligible queries runs its rounds through the
/// topology-aware joint path (the single-executor gate is gone) — all
/// queries progress, cross-source rounds align, GPU plans actually
/// execute, and first-round outputs match the ablation.
#[test]
fn cluster_session_coschedules_across_sources() {
    let (rs_on, fp_on) = run_two_source_session(true, Some(ClusterSpec::of(2)));
    let (_rs_off, fp_off) = run_two_source_session(false, Some(ClusterSpec::of(2)));
    assert_eq!(rs_on.len(), 3);
    for r in &rs_on {
        assert!(!r.batches.is_empty(), "{} produced no batches", r.workload);
    }
    let rounds = |r: &lmstream::session::RunResult| {
        r.batches.iter().map(|b| b.round).collect::<Vec<usize>>()
    };
    assert_eq!(
        rounds(&rs_on[0])[0],
        rounds(&rs_on[2])[0],
        "the first cluster round must span both sources"
    );
    assert_eq!(rounds(&rs_on[0]), rounds(&rs_on[1]));
    // GPU-eligible queries kept device work under joint planning.
    let gpu_ops: usize = rs_on
        .iter()
        .flat_map(|r| r.batches.iter())
        .map(|b| b.gpu_ops)
        .sum();
    assert!(gpu_ops > 0, "no GPU ops survived joint planning");
    for (q, (on, off)) in fp_on.iter().zip(&fp_off).enumerate() {
        let on = on.lock().unwrap();
        let off = off.lock().unwrap();
        assert!(!on.is_empty() && !off.is_empty(), "query {q} delivered nothing");
        assert_eq!(on[0], off[0], "query {q}: first cluster round diverged");
    }
    // Waits the shared per-executor timelines handed out are bounded by
    // the procs that absorbed them.
    for r in &rs_on {
        for b in &r.batches {
            assert!(b.gpu_wait <= b.proc);
        }
    }
}

/// The executor surfaces contention: a session-shaped sequential run of
/// two all-GPU queries through one timeline charges the second query's
/// wait into its proc, and the makespan matches the timeline tail.
#[test]
fn contention_delay_is_observable_and_consistent() {
    let q = QueryBuilder::scan("obs")
        .window(window())
        .filter("speed", Predicate::Ge(0.0))
        .build()
        .unwrap();
    let queries = vec![q.clone(), q];
    let plans: Vec<PhysicalPlan> = queries
        .iter()
        .map(|q| PhysicalPlan::uniform(q, lmstream::devices::Device::Gpu))
        .collect();
    let inputs: Vec<ChunkedBatch> = (0..2).map(|k| input(40 + k, 4000, 4)).collect();
    let windows = vec![None, None];
    let (outs, timeline) = run_all(&queries, &plans, &inputs, &windows, true);
    assert_eq!(outs[0].contention, Duration::ZERO, "first query sees a free device");
    assert!(outs[1].contention > Duration::ZERO, "second query must queue");
    assert!(timeline.waited() >= outs[1].contention);
    // Its proc grew by exactly the waits it was handed.
    let (idle, _) = run_all(&queries, &plans, &inputs, &windows, false);
    assert_eq!(outs[1].proc, idle[1].proc + outs[1].contention);
}
