//! Cross-query GPU co-scheduling: correctness and honesty.
//!
//! * **Differential** — co-scheduled execution (joint plans + shared
//!   GPU timeline) is bit-identical to independently-planned execution
//!   with an idle device: scheduling moves *time*, never rows.
//! * **Property** — the joint plan's predicted makespan is never worse
//!   than all-CPU and never exceeds the sum of the independent
//!   per-query GPU plans, across sizes/inflection points/query mixes.
//! * **Pinned contention scenario** (acceptance) — two GPU-leaning
//!   queries on one GPU: independent planning double-books the device
//!   (its idle-GPU latency prediction under-estimates the
//!   shared-timeline simulation), while the joint plan respects the
//!   shared timeline and achieves a lower simulated makespan.

mod common;

use common::fingerprint;
use lmstream::config::ExecBackend;
use lmstream::coordinator::planner::SizeEstimator;
use lmstream::coordinator::schedule::{plan_joint, QueryCandidate};
use lmstream::devices::model::DeviceModel;
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::ops::aggregate::AggSpec;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::window::WindowSpec;
use lmstream::query::exec::{self, ExecEnv, ExecOutcome, GpuTimeline, NoContention};
use lmstream::query::physical::PhysicalPlan;
use lmstream::query::{Query, QueryBuilder};
use lmstream::source::stream::RowGen;
use lmstream::workloads::linear_road::LinearRoadGen;
use std::time::Duration;

const KB: f64 = 1024.0;

fn window() -> WindowSpec {
    WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5))
}

/// A mixed bag of query shapes over the Linear Road schema: chain,
/// branch, union, windowed join, aggregate.
fn query_zoo() -> Vec<Query> {
    vec![
        QueryBuilder::scan("chain")
            .window(window())
            .filter("speed", Predicate::Ge(20.0))
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
        QueryBuilder::scan("branchy")
            .window(window())
            .filter("speed", Predicate::Lt(80.0))
            .branch(|b| b.select(&["vehicle"]))
            .sort("speed", false)
            .build()
            .unwrap(),
        QueryBuilder::scan("diamond")
            .window(window())
            .merge_union(|b| b.filter("speed", Predicate::Ge(55.0)))
            .build()
            .unwrap(),
        QueryBuilder::scan("joiny")
            .window(window())
            .join_window("vehicle", "vehicle")
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
        QueryBuilder::scan("aggy")
            .window(window())
            .shuffle("segment")
            .aggregate(&["segment"], vec![AggSpec::avg("speed", "avgSpeed")], None)
            .build()
            .unwrap(),
    ]
}

fn input(seed: u64, rows: usize, chunks: usize) -> ChunkedBatch {
    let mut gen = LinearRoadGen::new(seed);
    let per = rows / chunks;
    let mut out = ChunkedBatch::from_batch(gen.generate(0, per));
    for c in 1..chunks {
        out.push(gen.generate(c as u64, per)).unwrap();
    }
    out
}

fn build_candidates<'a>(
    queries: &'a [Query],
    inputs: &[ChunkedBatch],
    windows: &[Option<ChunkedBatch>],
    part: f64,
    inf: f64,
) -> Vec<QueryCandidate<'a>> {
    queries
        .iter()
        .zip(inputs)
        .zip(windows)
        .map(|((q, i), w)| {
            let est = SizeEstimator::new(q.len());
            let aux = w.as_ref().map(|w| w.alloc_bytes()).unwrap_or(0) as f64;
            let aux_chunks = w.as_ref().map(|w| w.num_chunks()).unwrap_or(0);
            QueryCandidate::build(q, part, inf, 0.1, &est, i.num_chunks(), aux, aux_chunks)
                .unwrap()
        })
        .collect()
}

/// Execute every query against `plans`, arbitrating GPU ops through one
/// shared timeline when `shared` (otherwise each query sees an idle
/// device). Returns the outcomes plus the timeline.
fn run_all(
    queries: &[Query],
    plans: &[PhysicalPlan],
    inputs: &[ChunkedBatch],
    windows: &[Option<ChunkedBatch>],
    shared: bool,
) -> (Vec<ExecOutcome>, GpuTimeline) {
    let model = DeviceModel::default();
    let env = ExecEnv {
        model: &model,
        backend: ExecBackend::Simulated,
        num_cores: 12,
        num_gpus: 1,
        runtime: None,
    };
    let mut timeline = GpuTimeline::new();
    let outcomes = queries
        .iter()
        .zip(plans)
        .zip(inputs)
        .zip(windows)
        .map(|(((q, p), i), w)| {
            if shared {
                exec::execute_with_occupancy(q, p, i.clone(), w.as_ref(), &env, &mut timeline)
                    .unwrap()
            } else {
                exec::execute_with_occupancy(
                    q,
                    p,
                    i.clone(),
                    w.as_ref(),
                    &env,
                    &mut NoContention,
                )
                .unwrap()
            }
        })
        .collect();
    (outcomes, timeline)
}

/// Differential: joint plans on the contended timeline produce exactly
/// the rows the independent plans produce on idle devices — outputs
/// must not depend on scheduling.
#[test]
fn coscheduled_outputs_bit_identical_to_independent() {
    let queries = query_zoo();
    let inputs: Vec<ChunkedBatch> =
        (0..queries.len()).map(|k| input(11 + k as u64, 3000, 5)).collect();
    let windows: Vec<Option<ChunkedBatch>> = queries
        .iter()
        .enumerate()
        .map(|(k, q)| {
            q.ops
                .iter()
                .any(|o| matches!(o.spec.kind(), lmstream::query::OpKind::Join))
                .then(|| input(90 + k as u64, 6000, 6))
        })
        .collect();

    for (part, inf) in [(8.0 * KB, 40.0 * KB), (60.0 * KB, 10.0 * KB), (200.0 * KB, 150.0 * KB)]
    {
        let cands = build_candidates(&queries, &inputs, &windows, part, inf);
        let joint = plan_joint(&cands, &DeviceModel::default(), 12, 1);
        let independent: Vec<PhysicalPlan> =
            cands.iter().map(|c| c.independent.clone()).collect();

        let (contended, timeline) = run_all(&queries, &joint.plans, &inputs, &windows, true);
        let (idle, _) = run_all(&queries, &independent, &inputs, &windows, false);

        for (a, b) in contended.iter().zip(&idle) {
            assert_eq!(
                fingerprint(&a.result.coalesce()),
                fingerprint(&b.result.coalesce()),
                "primary results diverged under co-scheduling"
            );
            assert_eq!(a.branch_results.len(), b.branch_results.len());
            for ((ia, ba), (ib, bb)) in a.branch_results.iter().zip(&b.branch_results) {
                assert_eq!(ia, ib);
                assert_eq!(fingerprint(&ba.coalesce()), fingerprint(&bb.coalesce()));
            }
        }
        // The timeline really arbitrated (it saw every GPU reservation).
        let gpu_ops: usize = joint.plans.iter().map(|p| p.gpu_ops()).sum();
        assert_eq!(timeline.reservations(), gpu_ops);
    }
}

/// Property: across sizes, inflection points and query mixes, the joint
/// prediction is bounded by all-CPU below-worst and the serialized sum
/// of independent plans above.
#[test]
fn joint_makespan_bounded_by_all_cpu_and_independent_sum() {
    let queries = query_zoo();
    let model = DeviceModel::default();
    let est_inputs: Vec<ChunkedBatch> =
        (0..queries.len()).map(|k| input(31 + k as u64, 2000, 4)).collect();
    let windows: Vec<Option<ChunkedBatch>> = queries.iter().map(|_| None).collect();
    for part_kb in [2.0, 10.0, 50.0, 150.0, 600.0] {
        for inf_kb in [5.0, 50.0, 300.0] {
            for n in 1..=queries.len() {
                let cands = build_candidates(
                    &queries[..n],
                    &est_inputs[..n],
                    &windows[..n],
                    part_kb * KB,
                    inf_kb * KB,
                );
                let jp = plan_joint(&cands, &model, 12, 1);
                let p = &jp.predicted;
                assert!(
                    p.makespan <= p.all_cpu_makespan + 1e-6,
                    "part {part_kb}KB inf {inf_kb}KB n {n}: joint {} > all-CPU {}",
                    p.makespan,
                    p.all_cpu_makespan
                );
                let independent_sum: f64 = p.independent.iter().sum();
                assert!(
                    p.makespan <= independent_sum + 1e-6,
                    "part {part_kb}KB inf {inf_kb}KB n {n}: joint {} > Σ independent {}",
                    p.makespan,
                    independent_sum
                );
                // Full assignment, every query covered.
                assert_eq!(jp.plans.len(), n);
                for (qc, plan) in cands.iter().zip(&jp.plans) {
                    assert_eq!(plan.len(), qc.query.len());
                }
            }
        }
    }
}

/// Acceptance pin: a contended two-query scenario.
///
/// 1. Independent planning double-books the GPU: the per-query idle-GPU
///    prediction under-estimates what the shared-timeline simulation
///    actually measures for those same plans.
/// 2. The joint plan respects the shared GPU timeline (every simulated
///    reservation went through it; waits are accounted in proc).
/// 3. The joint plan's simulated makespan beats the independent plans'.
/// 4. Results are bit-identical either way (differential equivalence).
#[test]
fn pinned_two_query_contention_scenario() {
    let queries = vec![
        QueryBuilder::scan("hot-a")
            .window(window())
            .filter("speed", Predicate::Ge(0.0))
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
        QueryBuilder::scan("hot-b")
            .window(window())
            .filter("speed", Predicate::Ge(0.0))
            .select(&["vehicle", "speed"])
            .build()
            .unwrap(),
    ];
    // ~600 KB per query (50 KB per partition over 12 cores): GPU is
    // faster but the CPU is competitive — exactly the regime where two
    // all-GPU plans queueing on one device lose to rationing.
    let inputs: Vec<ChunkedBatch> = (0..2).map(|k| input(7 + k, 9000, 6)).collect();
    let windows: Vec<Option<ChunkedBatch>> = vec![None, None];
    let part = inputs[0].alloc_bytes() as f64 / 12.0;
    // A small inflection point: Alg. 2 wants every op on the GPU.
    let cands = build_candidates(&queries, &inputs, &windows, part, 10.0 * KB);
    assert!(
        cands.iter().all(|c| c.independent.gpu_ops() == c.query.len()),
        "scenario needs GPU-hungry independent plans"
    );

    let joint = plan_joint(&cands, &DeviceModel::default(), 12, 1);
    let independent: Vec<PhysicalPlan> =
        cands.iter().map(|c| c.independent.clone()).collect();

    // --- Simulate both worlds on the shared device.
    let (ind_contended, ind_timeline) =
        run_all(&queries, &independent, &inputs, &windows, true);
    let (ind_idle, _) = run_all(&queries, &independent, &inputs, &windows, false);
    let (joint_contended, joint_timeline) =
        run_all(&queries, &joint.plans, &inputs, &windows, true);

    // 1. Double-booking: the idle-GPU prediction (what per-query
    //    MapDevice believes) under-estimates the contended simulation of
    //    the very same plans — by at least 20% here, since the second
    //    query queues behind the whole first chain.
    let ind_sim_makespan =
        ind_contended.iter().map(|o| o.proc).max().unwrap().as_secs_f64();
    let ind_idle_makespan = ind_idle.iter().map(|o| o.proc).max().unwrap().as_secs_f64();
    assert!(
        ind_sim_makespan > ind_idle_makespan * 1.2,
        "no double-booking: contended {ind_sim_makespan}s vs idle {ind_idle_makespan}s"
    );
    // The scheduler's own prediction agrees about the under-estimate.
    let predicted_ind_max =
        joint.predicted.independent.iter().copied().fold(0.0, f64::max);
    assert!(
        joint.predicted.independent_shared_makespan > predicted_ind_max * 1.2,
        "prediction missed the double-booking"
    );

    // 2. The joint run respected the shared timeline: every simulated
    //    GPU reservation passed through it, its busy time fits inside
    //    the makespan, and waits surfaced in proc/contention.
    let joint_sim_makespan =
        joint_contended.iter().map(|o| o.proc).max().unwrap().as_secs_f64();
    let joint_gpu_ops: usize = joint.plans.iter().map(|p| p.gpu_ops()).sum();
    assert_eq!(joint_timeline.reservations(), joint_gpu_ops);
    assert!(joint_timeline.busy().as_secs_f64() <= joint_sim_makespan + 1e-9);
    assert_eq!(ind_timeline.reservations(), 2 * queries[0].len());
    for o in &joint_contended {
        assert!(o.proc >= o.contention);
    }

    // 3. Lower simulated makespan than the independent plans.
    assert!(
        joint_sim_makespan < ind_sim_makespan,
        "joint {joint_sim_makespan}s !< independent {ind_sim_makespan}s"
    );
    // And the prediction saw it coming.
    assert!(
        joint.predicted.makespan < joint.predicted.independent_shared_makespan,
        "prediction: joint {} !< independent-serialized {}",
        joint.predicted.makespan,
        joint.predicted.independent_shared_makespan
    );

    // 4. Result equivalence: co-scheduling moved time, not rows.
    for (a, b) in joint_contended.iter().zip(&ind_idle) {
        assert_eq!(
            fingerprint(&a.result.coalesce()),
            fingerprint(&b.result.coalesce())
        );
    }
}

/// The executor surfaces contention: a session-shaped sequential run of
/// two all-GPU queries through one timeline charges the second query's
/// wait into its proc, and the makespan matches the timeline tail.
#[test]
fn contention_delay_is_observable_and_consistent() {
    let q = QueryBuilder::scan("obs")
        .window(window())
        .filter("speed", Predicate::Ge(0.0))
        .build()
        .unwrap();
    let queries = vec![q.clone(), q];
    let plans: Vec<PhysicalPlan> = queries
        .iter()
        .map(|q| PhysicalPlan::uniform(q, lmstream::devices::Device::Gpu))
        .collect();
    let inputs: Vec<ChunkedBatch> = (0..2).map(|k| input(40 + k, 4000, 4)).collect();
    let windows = vec![None, None];
    let (outs, timeline) = run_all(&queries, &plans, &inputs, &windows, true);
    assert_eq!(outs[0].contention, Duration::ZERO, "first query sees a free device");
    assert!(outs[1].contention > Duration::ZERO, "second query must queue");
    assert!(timeline.waited() >= outs[1].contention);
    // Its proc grew by exactly the waits it was handed.
    let (idle, _) = run_all(&queries, &plans, &inputs, &windows, false);
    assert_eq!(outs[1].proc, idle[1].proc + outs[1].contention);
}
