//! Helpers shared across the engine test harnesses (`prop_engine`,
//! `diff_chunked`). Kept in one place so the definition of
//! "bit-identical" cannot drift between suites.

use lmstream::engine::column::{Column, ColumnBatch};

/// Deep byte-level snapshot of a batch's observable content (column
/// values by bit pattern + per-row liveness). Two batches are
/// "bit-identical" exactly when their fingerprints compare equal.
pub fn fingerprint(b: &ColumnBatch) -> (Vec<Vec<u8>>, Vec<u8>) {
    let cols = b
        .columns
        .iter()
        .map(|c| match c {
            Column::F32(v) => {
                v.iter().flat_map(|x| x.to_bits().to_le_bytes()).collect::<Vec<u8>>()
            }
            Column::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>(),
        })
        .collect();
    (cols, b.validity.to_vec())
}
