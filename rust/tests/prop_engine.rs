//! Property tests for the zero-copy columnar engine (buffer sharing /
//! copy-on-write): every operator must produce results identical to
//! deep-copy semantics, must never mutate its input through shared
//! buffers, and the incrementally maintained window snapshot must equal
//! a fresh concatenation after arbitrary push/evict sequences.

use lmstream::engine::column::{Column, ColumnBatch, Field, Schema, Validity};
use lmstream::engine::dataset::Dataset;
use lmstream::engine::ops;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::window::{WindowSpec, WindowState};
use lmstream::sim::Time;
use lmstream::util::prop::{prop_assert, Gen, Runner};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

mod common;
use common::fingerprint;

/// Rebuild a batch with freshly allocated buffers (the pre-refactor
/// deep-copy representation).
fn deep_copy(b: &ColumnBatch) -> ColumnBatch {
    let cols = b
        .columns
        .iter()
        .map(|c| match c {
            Column::F32(v) => Column::F32(v.to_vec().into()),
            Column::I32(v) => Column::I32(v.to_vec().into()),
        })
        .collect();
    let mut out = ColumnBatch::new(Arc::clone(&b.schema), cols).expect("copy of valid");
    out.validity = Validity::from_mask(b.validity.to_vec());
    out
}

/// Random batch: two f32 columns + one low-cardinality i32 key, with a
/// random validity mask.
fn random_batch(g: &mut Gen) -> ColumnBatch {
    let rows = g.usize_in(1..120);
    let schema = Schema::new(vec![Field::f32("v"), Field::f32("w"), Field::i32("k")]);
    let v: Vec<f32> = (0..rows).map(|_| g.f64_in(-50.0, 50.0) as f32).collect();
    let w: Vec<f32> = (0..rows).map(|_| g.f64_in(0.0, 10.0) as f32).collect();
    let k: Vec<i32> = (0..rows).map(|_| g.usize_in(0..7) as i32).collect();
    let mut b = ColumnBatch::new(
        schema,
        vec![Column::F32(v.into()), Column::F32(w.into()), Column::I32(k.into())],
    )
    .expect("consistent batch");
    if g.bool() {
        let mask: Vec<u8> = (0..rows).map(|_| g.bool() as u8).collect();
        b.validity = Validity::from_mask(mask);
    }
    b
}

fn random_pred(g: &mut Gen) -> Predicate {
    match g.usize_in(0..4) {
        0 => Predicate::Ge(g.f64_in(-50.0, 50.0)),
        1 => Predicate::Lt(g.f64_in(-50.0, 50.0)),
        2 => Predicate::Eq(g.f64_in(-50.0, 50.0)),
        _ => {
            let lo = g.f64_in(-50.0, 40.0);
            Predicate::Band(lo, lo + g.f64_in(0.0, 30.0))
        }
    }
}

/// Run one randomly chosen operator; returns every output batch it
/// produced (shuffle emits several).
fn run_random_op(g: &mut Gen, which: usize, b: &ColumnBatch) -> Vec<ColumnBatch> {
    match which {
        0 => vec![ops::filter(b, "v", random_pred(g)).expect("filter")],
        1 => vec![ops::sort_by(b, "v", g.bool()).expect("sort")],
        2 => vec![ops::project_select(b, &["k", "v"]).expect("select")],
        3 => vec![
            ops::project_affine(b, "v", "w", 2.0, -1.0, "mix").expect("affine"),
        ],
        4 => vec![ops::expand(b, 1 + g.usize_in(0..3)).expect("expand")],
        5 => ops::shuffle(b, "k", 1 + g.usize_in(0..4)).expect("shuffle"),
        6 => vec![ops::hash_aggregate(
            b,
            &["k"],
            &[ops::AggSpec::sum("v", "s"), ops::AggSpec::count("c")],
            None,
        )
        .expect("aggregate")],
        7 => vec![ops::hash_join(b, b, "k", "k").expect("join")],
        _ => vec![b.compact()],
    }
}

const NUM_OPS: usize = 9;

/// Every operator leaves its (possibly aliased) input byte-identical:
/// no kernel may mutate shared buffers in place.
#[test]
fn prop_ops_never_mutate_shared_input() {
    let mut r = Runner::new(0xe0e1, 120);
    r.run("ops never mutate shared input", |g| {
        let b = random_batch(g);
        let alias = b.clone(); // shares every buffer with `b`
        let before = fingerprint(&b);
        let which = g.usize_in(0..NUM_OPS);
        let outs = run_random_op(g, which, &alias);
        prop_assert(!outs.is_empty(), "op produced no outputs")?;
        prop_assert(
            fingerprint(&b) == before,
            format!("op {which} mutated its input through shared buffers"),
        )?;
        prop_assert(
            fingerprint(&alias) == before,
            format!("op {which} mutated the aliased batch"),
        )
    });
}

/// Results over shared (aliased/sliced) inputs equal results over fully
/// deep-copied inputs — zero-copy sharing is semantically invisible.
#[test]
fn prop_ops_match_deep_copy_semantics() {
    let mut r = Runner::new(0xe0e2, 120);
    r.run("ops match deep-copy semantics", |g| {
        let whole = random_batch(g);
        // Exercise the view machinery: operate on a shared slice.
        let start = g.usize_in(0..whole.rows());
        let len = 1 + g.usize_in(0..whole.rows() - start);
        let view = whole.slice(start, len);
        let copy = deep_copy(&view);
        let which = g.usize_in(0..NUM_OPS);
        let same = run_same_op_deterministic(which, &view, &copy)?;
        prop_assert(same, format!("op {which} diverged between view and deep copy"))
    });
}

/// Run `which` with fixed parameters on both inputs and compare.
fn run_same_op_deterministic(
    which: usize,
    view: &ColumnBatch,
    copy: &ColumnBatch,
) -> Result<bool, String> {
    let pairs: Vec<(Vec<ColumnBatch>, Vec<ColumnBatch>)> = match which {
        0 => {
            let p = Predicate::Band(-10.0, 25.0);
            vec![(
                vec![ops::filter(view, "v", p).map_err(|e| e.to_string())?],
                vec![ops::filter(copy, "v", p).map_err(|e| e.to_string())?],
            )]
        }
        1 => vec![(
            vec![ops::sort_by(view, "v", false).map_err(|e| e.to_string())?],
            vec![ops::sort_by(copy, "v", false).map_err(|e| e.to_string())?],
        )],
        2 => vec![(
            vec![ops::project_select(view, &["k", "v"]).map_err(|e| e.to_string())?],
            vec![ops::project_select(copy, &["k", "v"]).map_err(|e| e.to_string())?],
        )],
        3 => vec![(
            vec![ops::project_affine(view, "v", "w", 2.0, -1.0, "mix")
                .map_err(|e| e.to_string())?],
            vec![ops::project_affine(copy, "v", "w", 2.0, -1.0, "mix")
                .map_err(|e| e.to_string())?],
        )],
        4 => vec![(
            vec![ops::expand(view, 3).map_err(|e| e.to_string())?],
            vec![ops::expand(copy, 3).map_err(|e| e.to_string())?],
        )],
        5 => vec![(
            ops::shuffle(view, "k", 3).map_err(|e| e.to_string())?,
            ops::shuffle(copy, "k", 3).map_err(|e| e.to_string())?,
        )],
        6 => {
            let aggs = [ops::AggSpec::sum("v", "s"), ops::AggSpec::count("c")];
            vec![(
                vec![ops::hash_aggregate(view, &["k"], &aggs, None)
                    .map_err(|e| e.to_string())?],
                vec![ops::hash_aggregate(copy, &["k"], &aggs, None)
                    .map_err(|e| e.to_string())?],
            )]
        }
        7 => vec![(
            vec![ops::hash_join(view, view, "k", "k").map_err(|e| e.to_string())?],
            vec![ops::hash_join(copy, copy, "k", "k").map_err(|e| e.to_string())?],
        )],
        _ => vec![(vec![view.compact()], vec![copy.compact()])],
    };
    for (a, b) in &pairs {
        if a.len() != b.len() {
            return Ok(false);
        }
        for (x, y) in a.iter().zip(b) {
            if fingerprint(x) != fingerprint(y) {
                return Ok(false);
            }
        }
    }
    Ok(true)
}

/// Filter over shared buffers matches a straight per-row reference using
/// the pre-refactor `get_f64` + `Predicate::eval` semantics.
#[test]
fn prop_filter_matches_reference() {
    let mut r = Runner::new(0xe0e3, 200);
    r.run("typed filter equals per-row reference", |g| {
        let b = random_batch(g);
        let pred = random_pred(g);
        let col = if g.bool() { "v" } else { "k" };
        let out = ops::filter(&b, col, pred).map_err(|e| e.to_string())?;
        let c = b.column(col).map_err(|e| e.to_string())?;
        let expect: Vec<u8> = (0..b.rows())
            .map(|i| (b.validity.is_live(i) && pred.eval(c.get_f64(i))) as u8)
            .collect();
        prop_assert(
            out.validity.to_vec() == expect,
            format!("mask mismatch for {pred:?} on {col}"),
        )?;
        // Zero-copy: the filtered batch shares every column buffer.
        prop_assert(
            b.columns.iter().zip(&out.columns).all(|(x, y)| x.shares_memory(y)),
            "filter copied column data",
        )
    });
}

/// Slicing + concatenation round-trips, and slices share memory.
#[test]
fn prop_slice_concat_roundtrip() {
    let mut r = Runner::new(0xe0e4, 150);
    r.run("slice/concat round trip", |g| {
        let b = random_batch(g);
        let cut = g.usize_in(0..b.rows());
        let left = b.slice(0, cut);
        let right = b.slice(cut, b.rows() - cut);
        prop_assert(
            left.columns.iter().zip(&b.columns).all(|(x, y)| x.shares_memory(y)),
            "slice copied data",
        )?;
        let back = ColumnBatch::concat(&[&left, &right]).map_err(|e| e.to_string())?;
        prop_assert(
            fingerprint(&back) == fingerprint(&b),
            "slice+concat changed content",
        )
    });
}

fn ds(id: u64, t: f64, rows: usize, dead_every: usize) -> Dataset {
    let schema = Schema::new(vec![Field::f32("x"), Field::i32("n")]);
    let x: Vec<f32> = (0..rows).map(|i| t as f32 + i as f32 * 0.25).collect();
    let n: Vec<i32> = (0..rows).map(|i| i as i32).collect();
    let mut batch = ColumnBatch::new(
        schema,
        vec![Column::F32(x.into()), Column::I32(n.into())],
    )
    .expect("window dataset");
    if dead_every > 0 {
        let mask: Vec<u8> =
            (0..rows).map(|i| (i % dead_every != 0) as u8).collect();
        batch.validity = Validity::from_mask(mask);
    }
    Dataset {
        id,
        created_at: Time::from_secs_f64(t),
        event_time: Time::from_secs_f64(t),
        batch,
        wire_bytes: rows * 65,
    }
}

/// The incrementally maintained window snapshot equals (a) a fresh
/// concat of the retained datasets and (b) an independently tracked
/// mirror of the expected rows, after arbitrary push/evict sequences —
/// including while older snapshots are still being held alive (CoW).
#[test]
fn prop_window_incremental_snapshot_equals_fresh() {
    let mut r = Runner::new(0xe0e5, 60);
    r.run("incremental window snapshot equals fresh concat", |g| {
        let range_s = 3 + g.usize_in(0..10) as u64;
        let spec =
            WindowSpec::sliding(Duration::from_secs(range_s), Duration::from_secs(1));
        let mut w = WindowState::new();
        // Independent mirror: (event_time, first-column values).
        let mut mirror: VecDeque<(f64, Vec<f32>)> = VecDeque::new();
        let mut held = Vec::new(); // keep some snapshots alive (CoW path)
        let mut t = 0.0;
        let steps = 5 + g.usize_in(0..40);
        for step in 0..steps {
            t += g.f64_in(0.0, 2.5);
            // Evict exactly like WindowState does: event_time < t - range.
            let horizon = t - range_s as f64;
            w.evict(Time::from_secs_f64(t), &spec);
            while let Some(front) = mirror.front() {
                if front.0 < horizon {
                    mirror.pop_front();
                } else {
                    break;
                }
            }
            let rows = 1 + g.usize_in(0..30);
            let dead_every = if g.bool() { 0 } else { 2 + g.usize_in(0..5) };
            let d = ds(step as u64, t, rows, dead_every);
            let xs = d.batch.column("x").unwrap().as_f32().unwrap().to_vec();
            mirror.push_back((t, xs));
            w.push(&[d]);

            let snap = w.snapshot().map_err(|e| e.to_string())?.expect("non-empty");
            let fresh =
                w.snapshot_fresh().map_err(|e| e.to_string())?.expect("non-empty");
            prop_assert(
                fingerprint(&snap) == fingerprint(&fresh),
                format!("step {step}: incremental != fresh"),
            )?;
            let expect: Vec<f32> =
                mirror.iter().flat_map(|(_, xs)| xs.iter().copied()).collect();
            let got = snap.column("x").unwrap().as_f32().unwrap();
            prop_assert(
                got == expect.as_slice(),
                format!("step {step}: snapshot rows diverged from mirror"),
            )?;
            if g.bool() {
                held.push(Arc::clone(&snap));
                if held.len() > 3 {
                    held.remove(0);
                }
            }
        }
        // Held snapshots must still fingerprint-match what they captured
        // (they alias buffers that were appended/compacted since).
        for s in &held {
            prop_assert(s.rows() > 0, "held snapshot emptied")?;
        }
        Ok(())
    });
}
