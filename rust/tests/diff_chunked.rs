//! Differential harness for the chunked execution path (the proof
//! obligation of the chunked-batch tentpole): **chunked execution is
//! bit-identical to coalesced single-chunk execution**, for arbitrary
//! op pipelines over arbitrary chunk layouts, at three levels —
//!
//! 1. *operator level* — every chunk-aware op (`scan`, `filter`,
//!    `project`, `expand`, `aggregate`, join probe, `shuffle`, `sort`)
//!    against the single-batch kernel on the coalesced input, chained
//!    into random pipelines with random re-chunking between steps;
//! 2. *executor level* — `exec::execute` over a random chunk layout vs.
//!    a single chunk, under random device plans on the simulated
//!    backend (simulated-GPU ops run the same chunked kernels but
//!    exercise the coalesce/transfer charging path), including branch
//!    and Union (diamond) queries and windowed joins — and the result
//!    must also be invariant across device plans;
//! 3. *window level* — chunk-list snapshots under arbitrary push/evict
//!    interleavings with snapshots held across mutations (the old CoW
//!    path, now structurally copy-free).
//!
//! The real-GPU backend coalesces explicitly before each kernel
//! (`gpu::run_op_chunked`), so its chunk-layout invariance follows from
//! these tests plus `tests/gpu_cpu_equivalence.rs` (which needs PJRT
//! artifacts and pins gpu(coalesced) == cpu(coalesced)).

use lmstream::config::ExecBackend;
use lmstream::devices::model::DeviceModel;
use lmstream::devices::Device;
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::column::{Column, ColumnBatch, DType, Field, Schema, Validity};
use lmstream::engine::dataset::Dataset;
use lmstream::engine::ops;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::window::{WindowSpec, WindowState};
use lmstream::query::exec::{self, DevicePlan, ExecEnv, ExecOpts, NoContention};
use lmstream::query::fuse;
use lmstream::query::physical::PhysicalPlan;
use lmstream::query::{Query, QueryBuilder};
use lmstream::sim::Time;
use lmstream::util::prop::{prop_assert, Gen, Runner};
use std::sync::Arc;
use std::time::Duration;

mod common;
use common::fingerprint;

// ---------------------------------------------------------------- helpers

/// Random batch over a fixed seed schema: two f32 columns and one
/// low-cardinality i32 key, with an optional random validity mask.
fn random_batch(g: &mut Gen) -> ColumnBatch {
    let rows = 1 + g.usize_in(0..120);
    let schema = Schema::new(vec![Field::f32("v"), Field::f32("w"), Field::i32("k")]);
    let v: Vec<f32> = (0..rows).map(|_| g.f64_in(-50.0, 50.0) as f32).collect();
    let w: Vec<f32> = (0..rows).map(|_| g.f64_in(0.0, 10.0) as f32).collect();
    let k: Vec<i32> = (0..rows).map(|_| g.usize_in(0..7) as i32).collect();
    let mut b = ColumnBatch::new(
        schema,
        vec![Column::F32(v.into()), Column::F32(w.into()), Column::I32(k.into())],
    )
    .expect("consistent batch");
    if g.bool() {
        let mask: Vec<u8> = (0..rows).map(|_| g.bool() as u8).collect();
        b.validity = Validity::from_mask(mask);
    }
    b
}

/// Random batch over an *arbitrary* schema (join build sides mid-pipeline).
fn random_batch_for(g: &mut Gen, schema: &Arc<Schema>, rows: usize) -> ColumnBatch {
    let columns: Vec<Column> = schema
        .fields
        .iter()
        .map(|f| match f.dtype {
            DType::F32 => Column::F32(
                (0..rows).map(|_| g.f64_in(-20.0, 20.0) as f32).collect::<Vec<f32>>().into(),
            ),
            DType::I32 => Column::I32(
                (0..rows).map(|_| g.usize_in(0..5) as i32).collect::<Vec<i32>>().into(),
            ),
        })
        .collect();
    let mut b = ColumnBatch::new(Arc::clone(schema), columns).expect("generated batch");
    if g.bool() {
        let mask: Vec<u8> = (0..rows).map(|_| g.bool() as u8).collect();
        b.validity = Validity::from_mask(mask);
    }
    b
}

/// Cut a batch into a random chunk layout (1..=5 chunks at random row
/// boundaries). Chunks are O(1) slices sharing the source allocation —
/// exactly what Union assembly / partition splits produce.
fn random_layout(g: &mut Gen, b: &ColumnBatch) -> ChunkedBatch {
    let rows = b.rows();
    let mut out = ChunkedBatch::new(Arc::clone(&b.schema));
    if rows == 0 {
        out.push(b.clone()).expect("same schema");
        return out;
    }
    let cuts = g.usize_in(0..5);
    let mut bounds: Vec<usize> = (0..cuts).map(|_| g.usize_in(0..rows + 1)).collect();
    bounds.push(0);
    bounds.push(rows);
    bounds.sort_unstable();
    for pair in bounds.windows(2) {
        let (start, end) = (pair[0], pair[1]);
        if start == end && !(start == 0 && rows == 0) {
            continue; // skip zero-width cuts (empty chunks are legal but dull)
        }
        out.push(b.slice(start, end - start)).expect("same schema");
    }
    if out.num_chunks() == 0 {
        out.push(b.clone()).expect("same schema");
    }
    out
}

fn random_pred(g: &mut Gen) -> Predicate {
    match g.usize_in(0..4) {
        0 => Predicate::Ge(g.f64_in(-50.0, 50.0)),
        1 => Predicate::Lt(g.f64_in(-50.0, 50.0)),
        2 => Predicate::Eq(g.f64_in(-50.0, 50.0)),
        _ => {
            let lo = g.f64_in(-50.0, 40.0);
            Predicate::Band(lo, lo + g.f64_in(0.0, 30.0))
        }
    }
}

fn any_col(g: &mut Gen, schema: &Schema) -> String {
    schema.fields[g.usize_in(0..schema.len())].name.clone()
}

fn f32_cols(schema: &Schema) -> Vec<String> {
    schema
        .fields
        .iter()
        .filter(|f| f.dtype == DType::F32)
        .map(|f| f.name.clone())
        .collect()
}

// ---------------------------------------------- 1. operator-level pipelines

/// One random pipeline step applied to both representations.
/// `chunked` is the chunk-list path; `reference` is the coalesced
/// single-batch kernel path (the pre-chunking semantics).
fn apply_random_op(
    g: &mut Gen,
    chunked: &ChunkedBatch,
    reference: &ColumnBatch,
) -> Result<(ChunkedBatch, ColumnBatch, &'static str), String> {
    let schema = Arc::clone(chunked.schema());
    let e = |e: lmstream::Error| e.to_string();
    let which = g.usize_in(0..8);
    match which {
        0 => {
            let col = any_col(g, &schema);
            let pred = random_pred(g);
            Ok((
                ops::filter_chunks(chunked, &col, pred).map_err(e)?,
                ops::filter(reference, &col, pred).map_err(e)?,
                "filter",
            ))
        }
        1 => {
            let col = any_col(g, &schema);
            let desc = g.bool();
            Ok((
                ops::sort_chunks(chunked, &col, desc).map_err(e)?,
                ops::sort_by(reference, &col, desc).map_err(e)?,
                "sort",
            ))
        }
        2 => {
            // Random non-empty column subset, in random-ish order.
            let n = 1 + g.usize_in(0..schema.len());
            let mut keep: Vec<String> = Vec::new();
            for _ in 0..n {
                let c = any_col(g, &schema);
                if !keep.contains(&c) {
                    keep.push(c);
                }
            }
            let names: Vec<&str> = keep.iter().map(|s| s.as_str()).collect();
            Ok((
                ops::project_select_chunks(chunked, &names).map_err(e)?,
                ops::project_select(reference, &names).map_err(e)?,
                "select",
            ))
        }
        3 => {
            let fs = f32_cols(&schema);
            if fs.is_empty() {
                // No affine possible on this schema; fall back to filter.
                let col = any_col(g, &schema);
                let pred = random_pred(g);
                return Ok((
                    ops::filter_chunks(chunked, &col, pred).map_err(e)?,
                    ops::filter(reference, &col, pred).map_err(e)?,
                    "filter(fallback)",
                ));
            }
            let a = fs[g.usize_in(0..fs.len())].clone();
            let b = fs[g.usize_in(0..fs.len())].clone();
            Ok((
                ops::project_affine_chunks(chunked, &a, &b, 2.0, -0.5, "mix")
                    .map_err(e)?,
                ops::project_affine(reference, &a, &b, 2.0, -0.5, "mix").map_err(e)?,
                "affine",
            ))
        }
        4 => {
            let factor = 1 + g.usize_in(0..3);
            Ok((
                ops::expand_chunks(chunked, factor).map_err(e)?,
                ops::expand(reference, factor).map_err(e)?,
                "expand",
            ))
        }
        5 => {
            let key = any_col(g, &schema);
            let n = 1 + g.usize_in(0..4);
            let cparts = ops::shuffle_chunks(chunked, &key, n).map_err(e)?;
            let rparts = ops::shuffle(reference, &key, n).map_err(e)?;
            if cparts.len() != rparts.len() {
                return Err("shuffle partition count diverged".into());
            }
            // Every partition must agree; the pipeline continues with
            // partition 0.
            for (cp, rp) in cparts.iter().zip(&rparts) {
                if fingerprint(&cp.coalesce()) != fingerprint(rp) {
                    return Err(format!("shuffle({n}) partition diverged"));
                }
            }
            let c0 = cparts.into_iter().next().expect("n >= 1");
            let r0 = rparts.into_iter().next().expect("n >= 1");
            Ok((c0, r0, "shuffle"))
        }
        6 => {
            let group = any_col(g, &schema);
            let fs = f32_cols(&schema);
            let mut aggs = vec![ops::AggSpec::count("cnt")];
            if !fs.is_empty() {
                let vc = &fs[g.usize_in(0..fs.len())];
                aggs.push(ops::AggSpec::sum(vc, "s"));
                aggs.push(ops::AggSpec::avg(vc, "m"));
            }
            let having = if g.bool() {
                Some(("cnt", Predicate::Ge(2.0)))
            } else {
                None
            };
            let groups: Vec<&str> = vec![group.as_str()];
            Ok((
                ops::hash_aggregate_chunks(chunked, &groups, &aggs, having)
                    .map_err(e)?,
                ops::hash_aggregate(reference, &groups, &aggs, having).map_err(e)?,
                "aggregate",
            ))
        }
        _ => {
            // Windowed-join probe: build side is an independent random
            // batch over the current schema, itself randomly chunked.
            let key = any_col(g, &schema);
            let build_rows = 1 + g.usize_in(0..60);
            let build_flat = random_batch_for(g, &schema, build_rows);
            let build_chunked = random_layout(g, &build_flat);
            Ok((
                ops::hash_join_chunks(chunked, &build_chunked, &key, &key)
                    .map_err(e)?,
                ops::hash_join(reference, &build_flat, &key, &key).map_err(e)?,
                "join",
            ))
        }
    }
}

/// Arbitrary pipelines over arbitrary chunk layouts: after every step
/// the chunked result's coalesced content is bit-identical to the
/// single-batch kernel chain, and the cached row/live counts agree.
#[test]
fn prop_pipelines_chunked_equals_coalesced() {
    let mut r = Runner::new(0xd1ff_0001, 120);
    r.run("chunked pipeline == coalesced pipeline", |g| {
        let seed = random_batch(g);
        let mut chunked = random_layout(g, &seed);
        let mut reference = seed;
        let steps = 1 + g.usize_in(0..5);
        for step in 0..steps {
            let (c, r2, opname) = apply_random_op(g, &chunked, &reference)?;
            chunked = c;
            reference = r2;
            prop_assert(
                *chunked.schema() == reference.schema,
                format!("step {step} ({opname}): schema diverged"),
            )?;
            prop_assert(
                fingerprint(&chunked.coalesce()) == fingerprint(&reference),
                format!("step {step} ({opname}): content diverged"),
            )?;
            prop_assert(
                chunked.rows() == reference.rows()
                    && chunked.live_rows() == reference.live_rows(),
                format!("step {step} ({opname}): cached counts diverged"),
            )?;
            if reference.rows() > 5000 {
                break; // join/expand amplification cap
            }
            // Layout invariance under *re-chunking*: shuffling the rows
            // into a different chunk layout must not change anything
            // downstream.
            if g.bool() {
                chunked = random_layout(g, &chunked.coalesce());
            }
        }
        Ok(())
    });
}

// ------------------------------------------------- 2. executor-level diffs

fn lr_like_query(g: &mut Gen) -> (Query, bool) {
    let w = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
    let pred = random_pred(g);
    match g.usize_in(0..6) {
        0 => (
            QueryBuilder::scan("chain")
                .window(w)
                .filter("v", pred)
                .select(&["k", "v"])
                .build()
                .unwrap(),
            false,
        ),
        1 => (
            QueryBuilder::scan("diamond")
                .window(w)
                .merge_union(|b| b.filter("v", pred))
                .build()
                .unwrap(),
            false,
        ),
        2 => (
            QueryBuilder::scan("branch")
                .window(w)
                .filter("v", pred)
                .branch(|b| b.select(&["k"]))
                .select(&["v"])
                .build()
                .unwrap(),
            false,
        ),
        3 => (
            QueryBuilder::scan("agg")
                .window(w)
                .shuffle("k")
                .aggregate(
                    &["k"],
                    vec![ops::AggSpec::sum("v", "s"), ops::AggSpec::count("c")],
                    None,
                )
                .sort("s", g.bool())
                .build()
                .unwrap(),
            false,
        ),
        4 => (
            QueryBuilder::scan("exp")
                .window(w)
                .expand()
                .filter("w", pred)
                .build()
                .unwrap(),
            false,
        ),
        _ => (
            QueryBuilder::scan("join")
                .window(w)
                .join_window("k", "k")
                .sort("v", false)
                .build()
                .unwrap(),
            true,
        ),
    }
}

fn random_device_plan(g: &mut Gen, q: &Query) -> PhysicalPlan {
    let devices: Vec<Device> = (0..q.len())
        .map(|_| if g.bool() { Device::Gpu } else { Device::Cpu })
        .collect();
    PhysicalPlan::from_devices(q, &DevicePlan { per_op: devices }).expect("arity matches")
}

/// Full-executor diff: random queries (chains, diamonds, branches,
/// windowed joins) × random chunk layouts × random device plans on the
/// simulated backend. The result and every branch result must be
/// bit-identical between a chunked input and its single-chunk coalesce,
/// and invariant across device plans (simulated-GPU vs CPU mapping only
/// moves *time*, never data).
#[test]
fn prop_exec_chunk_layout_and_device_plan_invariant() {
    let model = DeviceModel::default();
    let mut r = Runner::new(0xd1ff_0002, 100);
    r.run("exec chunked == exec coalesced (any device plan)", |g| {
        let (q, needs_window) = lr_like_query(g);
        let seed = random_batch(g);
        let layout_a = random_layout(g, &seed);
        let layout_b = ChunkedBatch::from_batch(seed.clone());
        let window_flat = random_batch_for(g, &seed.schema, 1 + g.usize_in(0..80));
        let window_a = random_layout(g, &window_flat);
        let window_b = ChunkedBatch::from_batch(window_flat);
        let env = ExecEnv {
            model: &model,
            backend: ExecBackend::Simulated,
            num_cores: 12,
            num_gpus: 1,
            runtime: None,
        };
        let plan1 = random_device_plan(g, &q);
        let plan2 = PhysicalPlan::uniform(&q, Device::Cpu);

        let win = |x: &'_ ChunkedBatch| if needs_window { Some(x.clone()) } else { None };
        let wa = win(&window_a);
        let wb = win(&window_b);
        let out_a = exec::execute(&q, &plan1, layout_a, wa.as_ref(), &env)
            .map_err(|e| e.to_string())?;
        let out_b = exec::execute(&q, &plan1, layout_b.clone(), wb.as_ref(), &env)
            .map_err(|e| e.to_string())?;
        let out_c = exec::execute(&q, &plan2, layout_b, wb.as_ref(), &env)
            .map_err(|e| e.to_string())?;

        for (name, x, y) in
            [("layout", &out_a, &out_b), ("device-plan", &out_b, &out_c)]
        {
            prop_assert(
                fingerprint(&x.result.coalesce()) == fingerprint(&y.result.coalesce()),
                format!("{name}: primary result diverged on `{}`", q.name),
            )?;
            prop_assert(
                x.branch_results.len() == y.branch_results.len(),
                format!("{name}: branch sink count diverged on `{}`", q.name),
            )?;
            for ((id_x, bx), (id_y, by)) in
                x.branch_results.iter().zip(&y.branch_results)
            {
                prop_assert(
                    id_x == id_y
                        && fingerprint(&bx.coalesce()) == fingerprint(&by.coalesce()),
                    format!("{name}: branch {id_x} diverged on `{}`", q.name),
                )?;
            }
        }
        Ok(())
    });
}

// --------------------------------------- 3. held-snapshot interleavings

fn ds_at(g: &mut Gen, id: u64, t: f64) -> Dataset {
    let batch = random_batch(g);
    Dataset {
        id,
        created_at: Time::from_secs_f64(t),
        event_time: Time::from_secs_f64(t),
        wire_bytes: batch.alloc_bytes(),
        batch,
    }
}

/// Chunked window snapshots under arbitrary push/evict interleavings:
/// every snapshot equals the fresh reference concat at capture time, and
/// snapshots *held across mutations* keep their captured content without
/// any copy (the chunk list is immutable — the CoW the accumulation
/// buffers needed is structurally gone).
#[test]
fn prop_window_chunked_snapshot_interleavings() {
    let mut r = Runner::new(0xd1ff_0003, 80);
    r.run("held chunked snapshots stay capture-identical", |g| {
        let range_s = 3 + g.usize_in(0..10) as u64;
        let spec =
            WindowSpec::sliding(Duration::from_secs(range_s), Duration::from_secs(1));
        let mut w = WindowState::new();
        let mut held: Vec<(ChunkedBatch, (Vec<Vec<u8>>, Vec<u8>))> = Vec::new();
        let mut t = 0.0;
        let steps = 5 + g.usize_in(0..30);
        for step in 0..steps {
            t += g.f64_in(0.0, 2.5);
            w.evict(Time::from_secs_f64(t), &spec);
            w.push(&[ds_at(g, step as u64, t)]);
            let snap = w
                .snapshot_chunks()
                .map_err(|e| e.to_string())?
                .expect("non-empty state");
            let fresh =
                w.snapshot_fresh().map_err(|e| e.to_string())?.expect("non-empty");
            prop_assert(
                fingerprint(&snap.coalesce()) == fingerprint(&fresh),
                format!("step {step}: chunked snapshot != fresh concat"),
            )?;
            prop_assert(
                snap.num_chunks() == w.len(),
                format!("step {step}: one chunk per in-window dataset"),
            )?;
            // The memoized contiguous snapshot agrees too.
            let contiguous =
                w.snapshot().map_err(|e| e.to_string())?.expect("non-empty");
            prop_assert(
                fingerprint(&contiguous) == fingerprint(&fresh),
                format!("step {step}: contiguous snapshot != fresh concat"),
            )?;
            if g.bool() {
                let fp = fingerprint(&snap.coalesce());
                held.push((snap, fp));
                if held.len() > 3 {
                    held.remove(0);
                }
            }
            // Every held snapshot still matches what it captured.
            for (i, (h, fp)) in held.iter().enumerate() {
                prop_assert(
                    fingerprint(&h.coalesce()) == *fp,
                    format!("step {step}: held snapshot {i} changed under mutation"),
                )?;
            }
        }
        Ok(())
    });
}

/// Dedicated pin for the k-way-merge chunked sort (it no longer
/// coalesces before sorting): arbitrary layouts × masks × directions ×
/// duplicate-heavy keys must stay bit-identical to the single-batch
/// kernel on the coalesced input — including stability across chunk
/// boundaries — and the output must remain a single chunk (sort is the
/// pipeline's coalesce point).
#[test]
fn prop_kway_merge_sort_equals_coalesced_sort() {
    let mut r = Runner::new(0xd1ff_0005, 200);
    r.run("sort_chunks == sort_by(coalesce)", |g| {
        let mut seed = random_batch(g);
        if g.bool() {
            // Duplicate-heavy keys: quantize v to a handful of values so
            // cross-chunk ties (the stability cases) are common.
            let vals: Vec<f32> = seed
                .column("v")
                .map_err(|e| e.to_string())?
                .as_f32()
                .map_err(|e| e.to_string())?
                .iter()
                .map(|x| x.round() / 4.0)
                .collect();
            seed.columns[0] = lmstream::engine::column::Column::F32(vals.into());
        }
        let chunked = random_layout(g, &seed);
        let col = any_col(g, &seed.schema);
        let desc = g.bool();
        let merged =
            lmstream::engine::ops::sort_chunks(&chunked, &col, desc).map_err(|e| e.to_string())?;
        let reference =
            lmstream::engine::ops::sort_by(&seed, &col, desc).map_err(|e| e.to_string())?;
        prop_assert(
            merged.num_chunks() <= 1,
            "sort output must stay a single (or empty) chunk".to_string(),
        )?;
        prop_assert(
            fingerprint(&merged.coalesce()) == fingerprint(&reference),
            format!("k-way merge diverged on `{col}` desc={desc}"),
        )?;
        Ok(())
    });
}

// --------------------------------------- 4. fused vs staged execution

/// Random *fusable* pipeline: scan → 1..4 of {filter, affine, select} →
/// optional aggregate tail. Column availability is tracked so every
/// step resolves (`k` and at least one f32 column always survive a
/// select) — divergence between fused and staged execution, not error
/// plumbing, is what this suite hunts.
fn random_fusable_query(g: &mut Gen) -> Query {
    let w = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
    let mut b = QueryBuilder::scan("fusable").window(w);
    // (name, is_f32) of the columns live at the current pipeline point.
    let mut cols: Vec<(String, bool)> =
        vec![("v".into(), true), ("w".into(), true), ("k".into(), false)];
    let mut next_id = 0usize;
    let steps = 1 + g.usize_in(0..4);
    for _ in 0..steps {
        match g.usize_in(0..3) {
            0 => {
                let c = cols[g.usize_in(0..cols.len())].0.clone();
                let pred = random_pred(g);
                b = b.filter(&c, pred);
            }
            1 => {
                let fs: Vec<String> =
                    cols.iter().filter(|c| c.1).map(|c| c.0.clone()).collect();
                let x = fs[g.usize_in(0..fs.len())].clone();
                let y = fs[g.usize_in(0..fs.len())].clone();
                let out = format!("m{next_id}");
                next_id += 1;
                b = b.project_affine(&x, &y, 1.5, -0.25, &out);
                cols.push((out, true));
            }
            _ => {
                let first_f32 =
                    cols.iter().position(|c| c.1).expect("an f32 column always survives");
                let mut kept: Vec<(String, bool)> = Vec::new();
                for (i, c) in cols.iter().enumerate() {
                    if (c.0 == "k" || i == first_f32 || g.bool())
                        && !kept.iter().any(|x| x.0 == c.0)
                    {
                        kept.push(c.clone());
                    }
                }
                let names: Vec<&str> = kept.iter().map(|c| c.0.as_str()).collect();
                b = b.select(&names);
                cols = kept;
            }
        }
    }
    if g.bool() {
        let f = cols.iter().find(|c| c.1).expect("f32 survives").0.clone();
        b = b.aggregate(
            &["k"],
            vec![ops::AggSpec::sum(&f, "s"), ops::AggSpec::count("c")],
            if g.bool() { Some(("c", Predicate::Ge(2.0))) } else { None },
        );
    }
    b.build().unwrap()
}

/// The fusion proof obligation: for arbitrary fusable pipelines ×
/// chunk layouts × device plans (simulated backend, GPU groups
/// included), executing with the fusion sidecar is **bit-identical** to
/// staged execution — same result, same charged proc/transfer, same
/// per-op trace count — and a non-aggregate chain never stats-prunes
/// (dead rows must still flow, masked, for bit-identity).
#[test]
fn prop_fused_equals_staged_across_layouts_and_plans() {
    let model = DeviceModel::default();
    let mut r = Runner::new(0xd1ff_0006, 120);
    r.run("fused exec == staged exec", |g| {
        let q = random_fusable_query(g);
        let seed = random_batch(g);
        let layout = random_layout(g, &seed);
        let plan = if g.bool() {
            random_device_plan(g, &q)
        } else {
            PhysicalPlan::uniform(&q, if g.bool() { Device::Gpu } else { Device::Cpu })
        };
        let fplan = fuse::fuse(&q, &plan);
        let env = ExecEnv {
            model: &model,
            backend: ExecBackend::Simulated,
            num_cores: 12,
            num_gpus: 1,
            runtime: None,
        };
        let staged = exec::execute(&q, &plan, layout.clone(), None, &env)
            .map_err(|e| e.to_string())?;
        let fused = exec::execute_with_opts(
            &q,
            &plan,
            layout,
            None,
            &env,
            &mut NoContention,
            &ExecOpts { fused: Some(&fplan), aux: None, chunk_stats: None },
        )
        .map_err(|e| e.to_string())?;
        prop_assert(
            fingerprint(&fused.result.coalesce()) == fingerprint(&staged.result.coalesce()),
            format!("fused result diverged (groups: {})", fplan.groups.len()),
        )?;
        prop_assert(
            fused.proc == staged.proc && fused.transfer == staged.transfer,
            format!(
                "fused charging diverged: proc {:?} vs {:?}, transfer {:?} vs {:?}",
                fused.proc, staged.proc, fused.transfer, staged.transfer
            ),
        )?;
        prop_assert(
            fused.traces.len() == staged.traces.len(),
            "fused must emit one trace per member op".to_string(),
        )?;
        if q.ops.iter().all(|o| {
            !matches!(o.spec, lmstream::query::dag::OpSpec::Aggregate { .. })
        }) {
            prop_assert(
                fused.pruned_chunks == 0,
                "non-aggregate chains must not prune".to_string(),
            )?;
        }
        Ok(())
    });
}

// ------------------------- 5. encoded window state vs plain snapshots

/// An RLE-friendly dataset: constant columns, long runs.
fn flat_ds(id: u64, t: f64, rows: usize) -> Dataset {
    let schema = Schema::new(vec![Field::f32("v"), Field::f32("w"), Field::i32("k")]);
    let batch = ColumnBatch::new(
        schema,
        vec![
            Column::F32(vec![(id % 5) as f32; rows].into()),
            Column::F32(vec![0.5; rows].into()),
            Column::I32(vec![(id % 3) as i32; rows].into()),
        ],
    )
    .expect("consistent batch");
    Dataset {
        id,
        created_at: Time::from_secs_f64(t),
        event_time: Time::from_secs_f64(t),
        wire_bytes: batch.alloc_bytes(),
        batch,
    }
}

/// Cold-chunk encoding under push/evict interleavings: snapshots stay
/// bit-identical to the fresh reference concat while chunks past the
/// hot threshold live encoded, and the encoded resident footprint is
/// strictly below raw on this RLE-friendly state.
#[test]
fn prop_encoded_window_state_snapshot_identical_and_smaller() {
    use lmstream::engine::window::WINDOW_HOT_CHUNKS;
    let mut r = Runner::new(0xd1ff_0007, 60);
    r.run("cold-encoded snapshots == plain, and smaller", |g| {
        // Range long enough that most pushes outlive the hot threshold.
        let spec = WindowSpec::sliding(Duration::from_secs(600), Duration::from_secs(1));
        let mut w = WindowState::new();
        let mut t = 0.0;
        let pushes = WINDOW_HOT_CHUNKS + 2 + g.usize_in(0..10);
        for step in 0..pushes {
            t += g.f64_in(0.0, 2.0);
            if g.usize_in(0..8) == 0 {
                w.evict(Time::from_secs_f64(t), &spec);
            }
            w.push(&[flat_ds(step as u64, t, 16 + g.usize_in(0..50))]);
            let snap = w
                .snapshot_chunks()
                .map_err(|e| e.to_string())?
                .expect("non-empty state");
            let fresh =
                w.snapshot_fresh().map_err(|e| e.to_string())?.expect("non-empty");
            prop_assert(
                fingerprint(&snap.coalesce()) == fingerprint(&fresh),
                format!("step {step}: encoded-state snapshot != fresh concat"),
            )?;
            prop_assert(
                w.state_bytes_encoded() <= w.state_bytes_raw(),
                format!("step {step}: encoded footprint above raw"),
            )?;
            if w.cold_chunks() > 0 {
                prop_assert(
                    w.state_bytes_encoded() < w.state_bytes_raw(),
                    format!(
                        "step {step}: {} cold chunks but no shrink ({} >= {})",
                        w.cold_chunks(),
                        w.state_bytes_encoded(),
                        w.state_bytes_raw()
                    ),
                )?;
            }
        }
        prop_assert(
            w.cold_chunks() > 0,
            "pushing past the hot threshold must demote chunks".to_string(),
        )?;
        Ok(())
    });
}

/// Executor-level encoded-vs-plain diff: a windowed join probing a
/// build side that decodes lazily out of cold-encoded state must be
/// bit-identical to probing the plain reference concat.
#[test]
fn prop_join_over_encoded_window_state_matches_plain() {
    use lmstream::engine::window::WINDOW_HOT_CHUNKS;
    let model = DeviceModel::default();
    let mut r = Runner::new(0xd1ff_0008, 60);
    r.run("join(encoded window) == join(plain window)", |g| {
        let spec = WindowSpec::sliding(Duration::from_secs(600), Duration::from_secs(1));
        let mut w = WindowState::new();
        let mut t = 0.0;
        for step in 0..WINDOW_HOT_CHUNKS + 2 + g.usize_in(0..6) {
            t += g.f64_in(0.0, 2.0);
            w.push(&[flat_ds(step as u64, t, 8 + g.usize_in(0..40))]);
        }
        let snap = w
            .snapshot_chunks()
            .map_err(|e| e.to_string())?
            .expect("non-empty state");
        let fresh = w.snapshot_fresh().map_err(|e| e.to_string())?.expect("non-empty");
        let plain = ChunkedBatch::from_batch(fresh);

        let q = QueryBuilder::scan("join-enc")
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .join_window("k", "k")
            .sort("v", false)
            .build()
            .unwrap();
        let plan = random_device_plan(g, &q);
        let env = ExecEnv {
            model: &model,
            backend: ExecBackend::Simulated,
            num_cores: 12,
            num_gpus: 1,
            runtime: None,
        };
        let probe = random_layout(g, &random_batch(g));
        let enc = exec::execute(&q, &plan, probe.clone(), Some(&snap), &env)
            .map_err(|e| e.to_string())?;
        let ref_out = exec::execute(&q, &plan, probe, Some(&plain), &env)
            .map_err(|e| e.to_string())?;
        prop_assert(
            fingerprint(&enc.result.coalesce()) == fingerprint(&ref_out.result.coalesce()),
            "join over lazily-decoded state diverged from plain".to_string(),
        )?;
        Ok(())
    });
}

// ------------------------------- 6. single-node vs cluster branch outputs

/// The cluster path no longer drops branch sinks: a branched query run
/// single-node and on the paper's 4-executor cluster delivers identical
/// branch outputs (same op ids, same rows) — the ROADMAP item this PR
/// closes, proven against `exec::execute` as ground truth.
#[test]
fn cluster_and_single_node_branch_outputs_identical() {
    use lmstream::cluster::{self, ClusterSpec};

    let model = DeviceModel::default();
    let q = QueryBuilder::scan("b")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
        .filter("v", Predicate::Ge(0.0))
        .branch(|b| b.select(&["k"]))
        .branch(|b| b.filter("w", Predicate::Lt(5.0)))
        .select(&["v", "w"])
        .build()
        .unwrap();
    let plan = PhysicalPlan::uniform(&q, Device::Cpu);
    let mut g = Gen::for_tests(0xd1ff_0004);
    let input = random_batch(&mut g);

    let env = ExecEnv {
        model: &model,
        backend: ExecBackend::Simulated,
        num_cores: 12,
        num_gpus: 1,
        runtime: None,
    };
    let single = exec::execute(&q, &plan, input.clone(), None, &env).unwrap();
    let clustered = cluster::execute_on_cluster(
        &ClusterSpec::paper(),
        &q,
        &plan,
        input,
        None,
        &model,
        ExecBackend::Simulated,
        None,
    )
    .unwrap();

    assert_eq!(
        fingerprint(&single.result.coalesce()),
        fingerprint(&clustered.result.coalesce()),
        "primary sink diverged between single-node and cluster"
    );
    assert_eq!(single.branch_results.len(), 2);
    assert_eq!(clustered.branch_results.len(), 2);
    for ((id_s, bs), (id_c, bc)) in
        single.branch_results.iter().zip(&clustered.branch_results)
    {
        assert_eq!(id_s, id_c, "branch op ids must align");
        assert_eq!(
            fingerprint(&bs.coalesce()),
            fingerprint(&bc.coalesce()),
            "branch {id_s} diverged between single-node and cluster"
        );
    }
}
