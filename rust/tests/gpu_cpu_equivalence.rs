//! CPU-path vs GPU-path (PJRT artifacts) semantic equivalence: every
//! operator must produce identical results through both executors.
//!
//! Requires `make artifacts`. Runs on one thread per test (the xla
//! crate's handles are not Send/Sync).

use lmstream::devices::{cpu, gpu};
use lmstream::engine::column::ColumnBatch;
use lmstream::engine::ops::aggregate::AggSpec;
use lmstream::engine::ops::filter::Predicate;
use lmstream::engine::window::WindowSpec;
use lmstream::query::dag::OpSpec;
use lmstream::runtime::client::Runtime;
use lmstream::workloads::linear_road::LinearRoadGen;
use lmstream::source::stream::RowGen;
use std::path::Path;
use std::time::Duration;

fn runtime() -> Runtime {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::new(&dir).expect("runtime (run `make artifacts`)")
}

fn wspec() -> WindowSpec {
    WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5))
}

fn lr_batch(seed: u64, rows: usize) -> ColumnBatch {
    LinearRoadGen::new(seed).generate(0, rows)
}

fn assert_equiv(rt: &Runtime, spec: &OpSpec, batch: &ColumnBatch, window: Option<&ColumnBatch>) {
    let native = cpu::run_op(spec, batch, window, &wspec()).expect("cpu path");
    let device = gpu::run_op(rt, spec, batch, window, &wspec()).expect("gpu path");
    assert_eq!(native.rows(), device.rows(), "{spec:?} row count");
    assert_eq!(native.validity.to_vec(), device.validity.to_vec(), "{spec:?} validity");
    assert_eq!(native.schema, device.schema, "{spec:?} schema");
    for (ci, (a, b)) in native.columns.iter().zip(&device.columns).enumerate() {
        match (a, b) {
            (
                lmstream::engine::column::Column::F32(x),
                lmstream::engine::column::Column::F32(y),
            ) => {
                for (i, (u, v)) in x.iter().zip(y).enumerate() {
                    assert!(
                        (u - v).abs() <= 1e-4 * u.abs().max(1.0),
                        "{spec:?} col {ci} row {i}: {u} vs {v}"
                    );
                }
            }
            (a, b) => assert_eq!(a, b, "{spec:?} col {ci}"),
        }
    }
}

#[test]
fn filters_equivalent() {
    let rt = runtime();
    let mut batch = lr_batch(1, 700);
    for i in 0..700 {
        if i % 7 == 0 {
            batch.validity.set_live(i, false); // pre-dead rows must stay dead
        }
    }
    for pred in [
        Predicate::Ge(40.0),
        Predicate::Lt(40.0),
        Predicate::Eq(2.0),
        Predicate::Band(20.0, 60.0),
    ] {
        let spec = OpSpec::Filter { col: "speed".into(), pred };
        assert_equiv(&rt, &spec, &batch, None);
    }
}

#[test]
fn project_affine_equivalent() {
    let rt = runtime();
    let batch = lr_batch(2, 900);
    let spec = OpSpec::ProjectAffine {
        a: "speed".into(),
        b: "timestamp".into(),
        alpha: 2.0,
        beta: -0.5,
        out: "mix".into(),
    };
    assert_equiv(&rt, &spec, &batch, None);
}

#[test]
fn aggregate_equivalent_single_key() {
    let rt = runtime();
    let batch = lr_batch(3, 1200);
    let spec = OpSpec::Aggregate {
        group: vec!["highway".into()],
        aggs: vec![
            AggSpec::sum("speed", "total"),
            AggSpec::count("n"),
            AggSpec::avg("speed", "avg"),
        ],
        having: None,
    };
    assert_equiv(&rt, &spec, &batch, None);
}

#[test]
fn aggregate_equivalent_multi_key_with_having() {
    let rt = runtime();
    let batch = lr_batch(4, 2000);
    let spec = OpSpec::Aggregate {
        group: vec!["highway".into(), "direction".into(), "segment".into()],
        aggs: vec![AggSpec::avg("speed", "avgSpeed")],
        having: Some(("avgSpeed".into(), Predicate::Lt(40.0))),
    };
    assert_equiv(&rt, &spec, &batch, None);
}

#[test]
fn aggregate_equivalent_many_groups_chunked() {
    // > NUM_GROUPS (256) distinct groups exercises the chunked device
    // reduction path.
    let rt = runtime();
    let batch = lr_batch(5, 3000);
    let spec = OpSpec::Aggregate {
        group: vec!["vehicle".into()], // up to 1000 distinct
        aggs: vec![AggSpec::sum("speed", "s"), AggSpec::count("c")],
        having: None,
    };
    assert_equiv(&rt, &spec, &batch, None);
}

#[test]
fn join_equivalent() {
    let rt = runtime();
    let probe = lr_batch(6, 500);
    let window = lr_batch(7, 1500);
    let spec = OpSpec::JoinWithWindow {
        probe_key: "vehicle".into(),
        build_key: "vehicle".into(),
    };
    assert_equiv(&rt, &spec, &probe, Some(&window));
}

#[test]
fn join_equivalent_large_build_chunked() {
    // Build side > JOIN_CHUNK (4096) exercises probe/build chunking.
    let rt = runtime();
    let probe = lr_batch(8, 300);
    let window = lr_batch(9, 9000);
    let spec = OpSpec::JoinWithWindow {
        probe_key: "vehicle".into(),
        build_key: "vehicle".into(),
    };
    assert_equiv(&rt, &spec, &probe, Some(&window));
}

#[test]
fn pruned_join_equivalent() {
    // The optimizer-generated pruned join (projection pushdown) must
    // agree across executors too.
    let rt = runtime();
    let probe = lr_batch(12, 400);
    let window = lr_batch(13, 1200);
    let spec = OpSpec::JoinWithWindowPruned {
        probe_key: "vehicle".into(),
        build_key: "vehicle".into(),
        probe_cols: vec!["timestamp".into(), "vehicle".into(), "speed".into()],
        build_cols: vec!["speed".into()],
    };
    assert_equiv(&rt, &spec, &probe, Some(&window));
}

#[test]
fn optimized_query_matches_unoptimized_end_to_end() {
    // Full-driver check: the projection-pushdown rewrite must not change
    // observable results, only their cost.
    use lmstream::config::{Config, Mode};
    use lmstream::coordinator::driver;
    use lmstream::engine::sink::CollectSink;
    use lmstream::query::optimize;
    use lmstream::workloads;

    let w = workloads::by_name("lr1s").unwrap();
    let optimized = optimize::optimize(&w.query);
    assert!(optimized
        .ops
        .iter()
        .any(|o| matches!(o.spec, OpSpec::JoinWithWindowPruned { .. })));

    let cfg = Config { mode: Mode::AllCpu, ..Config::default() };
    let mut sink = CollectSink::new(4);
    driver::run_with_sink(&w, &cfg, Duration::from_secs(45), None, &mut sink).unwrap();
    assert!(!sink.results.is_empty());
    for (_, _, batch) in &sink.results {
        // LR1S's SELECT keeps exactly the 7 probe columns.
        assert_eq!(batch.schema.len(), 7);
        assert!(batch.column("vehicle").is_ok());
        assert!(batch.column("r_vehicle").is_err());
    }
}

#[test]
fn sort_equivalent() {
    let rt = runtime();
    let mut batch = lr_batch(10, 800);
    for i in 0..800 {
        if i % 11 == 0 {
            batch.validity.set_live(i, false);
        }
    }
    // Note: device sort uses a stable argsort on the key only, as does
    // the native sort, so full column equality must hold.
    for desc in [false, true] {
        let spec = OpSpec::Sort { col: "timestamp".into(), desc };
        assert_equiv(&rt, &spec, &batch, None);
    }
}

#[test]
fn empty_batches_pass_through_both_paths() {
    let rt = runtime();
    let batch = lr_batch(11, 1).slice(0, 0);
    let spec = OpSpec::Filter { col: "speed".into(), pred: Predicate::Ge(0.0) };
    assert_equiv(&rt, &spec, &batch, None);
}
