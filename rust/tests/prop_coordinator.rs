//! Property-based tests over coordinator invariants (routing, batching,
//! state) using the in-repo prop kit (DESIGN.md: proptest substitute).

use lmstream::coordinator::admission::{
    min_positive_throughput, Admission, AdmissionDecision,
};
use lmstream::coordinator::planner::{map_device, SizeEstimator};
use lmstream::devices::Device;
use lmstream::engine::column::{Column, ColumnBatch, Field, Schema};
use lmstream::engine::dataset::Dataset;
use lmstream::engine::partition;
use lmstream::engine::window::WindowSpec;
use lmstream::engine::ops::filter::Predicate;
use lmstream::query::builder::QueryBuilder;
use lmstream::sim::Time;
use lmstream::util::prop::{prop_assert, Gen, Runner};
use std::time::Duration;

fn dataset(id: u64, t: f64, rows: usize) -> Dataset {
    let schema = Schema::new(vec![Field::f32("x")]);
    let batch =
        ColumnBatch::new(schema, vec![Column::F32(vec![t as f32; rows.max(1)].into())])
            .unwrap();
    let bytes = batch.alloc_bytes();
    Dataset {
        id,
        created_at: Time::from_secs_f64(t),
        event_time: Time::from_secs_f64(t),
        batch,
        wire_bytes: bytes,
    }
}

fn random_datasets(g: &mut Gen, n: usize) -> Vec<Dataset> {
    (0..n)
        .map(|i| {
            let t = g.f64_in(0.0, 30.0);
            let rows = g.usize_in(1..2000);
            dataset(i as u64, t, rows)
        })
        .collect()
}

/// Admission never loses or duplicates datasets: everything fed in is
/// either admitted or still buffered.
#[test]
fn prop_admission_conserves_datasets() {
    let mut r = Runner::new(0xadA11, 150);
    r.run("admission conserves datasets", |g| {
        let slide = g.usize_in(1..10) as u64;
        let mut adm = Admission::new(
            WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(slide)),
            Duration::from_secs(1),
        );
        let rounds = g.usize_in(1..8);
        let mut fed = 0usize;
        let mut admitted = 0usize;
        let mut now = 0.0f64;
        for _ in 0..rounds {
            now += g.f64_in(0.0, 10.0);
            let n = g.usize_in(0..6);
            let data = random_datasets(g, n);
            fed += n;
            let thput = g.f64_in(1.0, 1e7);
            match adm.construct(data, Time::from_secs_f64(now + 31.0), thput, None) {
                AdmissionDecision::Admit(mb) => admitted += mb.num_datasets(),
                AdmissionDecision::Buffer { .. } | AdmissionDecision::Poll => {}
            }
        }
        prop_assert(
            admitted + adm.buffered_datasets() == fed,
            format!(
                "fed {fed}, admitted {admitted}, buffered {}",
                adm.buffered_datasets()
            ),
        )
    });
}

/// Admitted micro-batches are sorted by creation time (Alg. 1 line 5).
#[test]
fn prop_admitted_batches_creation_ordered() {
    let mut r = Runner::new(0xadA12, 150);
    r.run("admitted batches creation-ordered", |g| {
        let mut adm = Admission::new(
            WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(1)),
            Duration::from_secs(1),
        );
        let n = g.usize_in(2..20);
        let data = random_datasets(g, n);
        // Far-future "now" with tiny throughput forces admission.
        match adm.construct(data, Time::from_secs_f64(1000.0), 1.0, None) {
            AdmissionDecision::Admit(mb) => {
                let sorted = mb
                    .datasets
                    .windows(2)
                    .all(|w| w[0].created_at <= w[1].created_at);
                prop_assert(sorted, "datasets out of creation order")
            }
            other => prop_assert(false, format!("expected admit, got {other:?}")),
        }
    });
}

/// Eq. 6 estimate is monotone in polling time and in batch size.
#[test]
fn prop_estimate_monotone() {
    let mut r = Runner::new(0xadA13, 200);
    r.run("Eq.6 estimate monotone", |g| {
        use lmstream::engine::dataset::MicroBatch;
        let n = g.usize_in(1..5);
        let mb_small = MicroBatch::new(random_datasets(g, n));
        let mut bigger = mb_small.clone();
        bigger.absorb(MicroBatch::new(vec![dataset(99, 0.0, 5000)]));
        let thput = g.f64_in(100.0, 1e6);
        let t1 = Time::from_secs_f64(40.0);
        let t2 = Time::from_secs_f64(40.0 + g.f64_in(0.1, 50.0));
        let e_t1 = Admission::estimate_max_latency(&mb_small, t1, thput);
        let e_t2 = Admission::estimate_max_latency(&mb_small, t2, thput);
        prop_assert(e_t2 >= e_t1, format!("time monotonicity {e_t1:?} > {e_t2:?}"))?;
        let e_small = Admission::estimate_max_latency(&mb_small, t1, thput);
        let e_big = Admission::estimate_max_latency(&bigger, t1, thput);
        prop_assert(
            e_big >= e_small,
            format!("size monotonicity {e_small:?} > {e_big:?}"),
        )
    });
}

/// The shared admission throughput (min positive across a source's
/// queries) is the *tightest* choice: it never exceeds any observed
/// per-query estimate, falls back to the bootstrap value only when no
/// query has history, and — because Eq. 6 is anti-monotone in the
/// throughput — yields a latency estimate at least as large as the one
/// any single query (the old primary-only rule included) would produce,
/// so admission fires at least as eagerly for every co-registered query.
#[test]
fn prop_shared_throughput_is_tightest() {
    let mut r = Runner::new(0xadA14, 200);
    r.run("shared throughput is tightest", |g| {
        let n = 1 + g.usize_in(0..6);
        let estimates: Vec<f64> = (0..n)
            .map(|_| if g.bool() { g.f64_in(1.0, 1e6) } else { 0.0 })
            .collect();
        let initial = g.f64_in(1.0, 1e6);
        let shared = min_positive_throughput(estimates.iter().copied(), initial);
        let positives: Vec<f64> =
            estimates.iter().copied().filter(|&e| e > 0.0).collect();
        if positives.is_empty() {
            prop_assert(shared == initial, "no history must fall back to initial")?;
        } else {
            for &e in &positives {
                prop_assert(
                    shared <= e,
                    format!("shared {shared} exceeds a query's estimate {e}"),
                )?;
            }
            prop_assert(
                positives.contains(&shared),
                "shared estimate must be one of the observed ones",
            )?;
        }
        // Anti-monotonicity in action: the shared estimate's latency is
        // >= the estimate under any per-query throughput, so admission
        // (est >= bound) can only fire earlier, never later.
        let mb = lmstream::engine::dataset::MicroBatch::new(random_datasets(g, 3));
        let now = Time::from_secs_f64(40.0);
        let shared_est = Admission::estimate_max_latency(&mb, now, shared);
        for &e in &positives {
            let per_query = Admission::estimate_max_latency(&mb, now, e);
            prop_assert(
                shared_est >= per_query,
                format!("shared {shared_est:?} < per-query {per_query:?}"),
            )?;
        }
        Ok(())
    });
}

fn spj_query() -> lmstream::query::Query {
    QueryBuilder::scan("prop")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
        .filter("key", Predicate::Ge(0.0))
        .project_affine("a", "b", 1.0, 1.0, "ab")
        .join_window("jk", "jk")
        .sort("ab", false)
        .build()
        .unwrap()
}

/// MapDevice always returns a full assignment, is deterministic, and is
/// monotone: growing the partition never moves an op GPU -> CPU.
#[test]
fn prop_planner_total_deterministic_monotone() {
    let mut r = Runner::new(0x9140, 250);
    let q = spj_query();
    r.run("planner total/deterministic/monotone", |g| {
        let est = SizeEstimator::new(q.len());
        let inf = g.f64_in(1024.0, 4.0 * 1024.0 * 1024.0);
        let part = g.f64_in(128.0, 8.0 * 1024.0 * 1024.0);
        let trans = g.f64_in(0.0, 1.0);
        let p1 = map_device(&q, part, inf, trans, &est, 2).expect("plan");
        let p2 = map_device(&q, part, inf, trans, &est, 2).expect("plan");
        prop_assert(p1 == p2, "non-deterministic plan")?;
        prop_assert(p1.per_op.len() == q.len(), "partial assignment")?;
        let p_big = map_device(&q, part * 4.0, inf, trans, &est, 2).expect("plan");
        prop_assert(
            p_big.gpu_ops() >= p1.gpu_ops(),
            format!("bigger partition lost GPU ops: {:?} -> {:?}", p1, p_big),
        )
    });
}

/// Extremes: partitions far below/above the inflection point map all-CPU
/// / all-GPU respectively, whatever the transition cost.
#[test]
fn prop_planner_extremes() {
    let mut r = Runner::new(0x9141, 200);
    let q = spj_query();
    r.run("planner extremes", |g| {
        let est = SizeEstimator::new(q.len());
        let inf = g.f64_in(64.0 * 1024.0, 1024.0 * 1024.0);
        let trans = g.f64_in(0.0, 0.5);
        let tiny = map_device(&q, inf / 1000.0, inf, trans, &est, 2).expect("plan");
        prop_assert(
            tiny.per_op.iter().all(|o| o.device == Device::Cpu),
            format!("tiny partitions must be all-CPU: {tiny:?}"),
        )?;
        let huge = map_device(&q, inf * 1000.0, inf, trans, &est, 2).expect("plan");
        prop_assert(
            huge.per_op.iter().all(|o| o.device == Device::Gpu),
            format!("huge partitions must be all-GPU: {huge:?}"),
        )
    });
}

/// Partitioning covers every row exactly once with near-equal sizes.
#[test]
fn prop_partition_coverage() {
    let mut r = Runner::new(0x9a47, 200);
    r.run("partition coverage", |g| {
        let rows = g.usize_in(0..5000);
        let n = g.usize_in(1..64);
        let schema = Schema::new(vec![Field::f32("x")]);
        let batch = ColumnBatch::new(
            schema,
            vec![Column::F32((0..rows).map(|i| i as f32).collect::<Vec<f32>>().into())],
        )
        .unwrap();
        let parts = partition::split(&batch, rows * 65, n);
        let total: usize = parts.iter().map(|p| p.batch.rows()).sum();
        prop_assert(total == rows, format!("covered {total} of {rows}"))?;
        let sizes: Vec<usize> = parts.iter().map(|p| p.batch.rows()).collect();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        prop_assert(max - min <= 1, format!("imbalanced {sizes:?}"))
    });
}

/// Window eviction keeps exactly the datasets within range, regardless of
/// push/evict interleaving.
#[test]
fn prop_window_eviction_exact() {
    let mut r = Runner::new(0x3139, 200);
    r.run("window eviction exact", |g| {
        use lmstream::engine::window::WindowState;
        let range_s = g.usize_in(5..60) as u64;
        let spec =
            WindowSpec::sliding(Duration::from_secs(range_s), Duration::from_secs(1));
        let mut w = WindowState::new();
        let n = g.usize_in(1..40);
        let mut times = Vec::new();
        let mut t = 0.0;
        for i in 0..n {
            t += g.f64_in(0.0, 5.0);
            times.push(t);
            w.push(&[dataset(i as u64, t, 3)]);
        }
        let now = t + g.f64_in(0.0, 20.0);
        w.evict(Time::from_secs_f64(now), &spec);
        let horizon = now - range_s as f64;
        let expected = times.iter().filter(|&&et| et >= horizon).count();
        prop_assert(
            w.len() == expected,
            format!("kept {} expected {expected} (horizon {horizon:.2})", w.len()),
        )
    });
}
