//! Figs. 8 & 9 — 20-minute timelines under random traffic for LR1S
//! (Fig. 8, sliding) and LR1T (Fig. 9, tumbling): (a) max latency per
//! micro-batch, (b) data size per micro-batch.
//!
//! Paper shape: the baseline's per-batch data size is far larger (10 s of
//! buffering) and its max latency trends upward; LMStream's batch size
//! tracks the fluctuating ingest and its max latency stays bounded near
//! the slide time (LR1S) / the converged running average (LR1T).

use lmstream::config::Mode;
use lmstream::report::figures;
use lmstream::util::bench::print_table;
use lmstream::util::stats::mean;

fn run_one(fig: &str, workload: &str, minutes: u64) {
    let seed = 13;
    let bl = figures::timeline(workload, Mode::Baseline, minutes, seed).expect("bl");
    let lm = figures::timeline(workload, Mode::LmStream, minutes, seed).expect("lm");

    // Print a decimated timeline (every ~minute) for both systems.
    for (label, r) in [("Baseline", &bl), ("LMStream", &lm)] {
        let step = (r.batches.len() / 20).max(1);
        let rows: Vec<Vec<String>> = r
            .batches
            .iter()
            .step_by(step)
            .map(|b| {
                vec![
                    format!("{:.0}", b.admitted_at.as_secs_f64()),
                    format!("{:.2}", b.max_latency.as_secs_f64()),
                    format!("{:.0}", b.bytes as f64 / 1024.0),
                ]
            })
            .collect();
        print_table(
            &format!("Fig.{fig} {workload} [{label}]"),
            &["t (s)", "max lat (s)", "batch KB"],
            &rows,
        );
    }

    // Shape assertions.
    let bl_sizes: Vec<f64> = bl.batches.iter().map(|b| b.bytes as f64).collect();
    let lm_sizes: Vec<f64> = lm.batches.iter().map(|b| b.bytes as f64).collect();
    assert!(
        mean(&bl_sizes) > 2.0 * mean(&lm_sizes),
        "baseline batches must be much larger"
    );
    let bl_lat: Vec<f64> =
        bl.batches.iter().map(|b| b.max_latency.as_secs_f64()).collect();
    let lm_lat: Vec<f64> =
        lm.batches.iter().map(|b| b.max_latency.as_secs_f64()).collect();
    assert!(
        mean(&bl_lat) > 1.5 * mean(&lm_lat),
        "baseline max latency must sit well above LMStream's"
    );
    // LMStream bounded: its late-run latency must not exceed its early-run
    // latency by more than 50%.
    let n = lm_lat.len();
    let early = mean(&lm_lat[..n / 3]);
    let late = mean(&lm_lat[2 * n / 3..]);
    assert!(
        late < early * 1.5 + 1.0,
        "LMStream must stay bounded (early {early:.2} late {late:.2})"
    );
    println!("fig{fig} {workload}: BL mean maxlat {:.2}s, LM {:.2}s — OK", mean(&bl_lat), mean(&lm_lat));
}

fn main() {
    let minutes = 20;
    run_one("8", "lr1s", minutes);
    run_one("9", "lr1t", minutes);
}
