//! Ablation — admission bound policy (DESIGN.md §Ablations): LMStream's
//! window-derived bound (Alg. 1) vs the static trigger vs near-zero
//! bound (admit almost every poll), on LR1S.
//!
//! Expected: the slide-time bound dominates — the trigger over-buffers
//! (high latency), per-poll admission under-batches (throughput collapse
//! from per-batch fixed costs).

use lmstream::config::{Config, Mode};
use lmstream::coordinator::driver;
use lmstream::util::bench::print_table;
use lmstream::workloads;
use std::time::Duration;

fn main() {
    let minutes = 10;
    let seed = 7;
    let w = workloads::by_name("lr1s").expect("lr1s");

    // 1. LMStream bound (slide time).
    let lm = driver::run(
        &w,
        &Config { mode: Mode::LmStream, seed, ..Config::default() },
        Duration::from_secs(minutes * 60),
        None,
    )
    .expect("lm");
    // 2. Static triggers of several lengths (the paper's baseline uses 10 s).
    let mut rows = vec![vec![
        "slide-bound (LMStream)".to_string(),
        format!("{}", lm.batches.len()),
        format!("{:.2}", lm.avg_latency),
        format!("{:.1}", lm.avg_throughput / 1024.0),
    ]];
    for trig_s in [2u64, 5, 10, 20] {
        let r = driver::run(
            &w,
            &Config {
                mode: Mode::Baseline,
                trigger: Duration::from_secs(trig_s),
                seed,
                ..Config::default()
            },
            Duration::from_secs(minutes * 60),
            None,
        )
        .expect("trigger run");
        rows.push(vec![
            format!("trigger {trig_s} s"),
            format!("{}", r.batches.len()),
            format!("{:.2}", r.avg_latency),
            format!("{:.1}", r.avg_throughput / 1024.0),
        ]);
    }
    print_table(
        "Ablation — admission policy on LR1S (10 simulated minutes)",
        &["policy", "batches", "avg latency (s)", "thpt KB/s"],
        &rows,
    );

    // The paper's 10 s trigger must lose on latency to the slide bound.
    let trigger10_lat: f64 = rows[3][2].parse().unwrap();
    assert!(
        lm.avg_latency < trigger10_lat,
        "slide bound must beat the 10 s trigger on latency"
    );
    println!("ablation_admission OK");
}
