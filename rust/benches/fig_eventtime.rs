//! Event-time suite — throughput/latency per traffic shape with the
//! watermark pipeline armed (`allowed_lateness` set), in-order vs
//! disordered arrivals.
//!
//! Shape invariants (not paper figures — LMStream evaluates constant and
//! random traffic only; the extra shapes exercise the same admission +
//! watermark machinery under production load curves):
//! * every shape sustains positive throughput with event time on;
//! * in-order arrivals never produce late rows (event == arrival, so the
//!   watermark trails the stream by exactly `allowed_lateness`);
//! * disordered arrivals with `max_delay > allowed_lateness` surface
//!   late rows somewhere across the suite, and the watermark lag stays
//!   bounded by `max_delay + allowed_lateness` plus admission buffering.

use lmstream::config::{Config, LatePolicy, Mode};
use lmstream::coordinator::driver::run;
use lmstream::source::stream::Disorder;
use lmstream::source::traffic::Traffic;
use lmstream::util::bench::{fmt_secs, print_table};
use lmstream::workloads;
use std::time::Duration;

const LATENESS: Duration = Duration::from_secs(2);
const MAX_DELAY: Duration = Duration::from_secs(8);
const SECS: u64 = 120;
const SEED: u64 = 11;

fn shapes() -> Vec<(&'static str, Traffic)> {
    vec![
        ("constant", Traffic::constant_default()),
        ("random", Traffic::random_default()),
        ("diurnal", Traffic::diurnal_default()),
        ("flash-crowd", Traffic::flash_crowd_default()),
        ("burst", Traffic::burst_default()),
    ]
}

fn main() {
    let cfg = Config {
        mode: Mode::LmStream,
        seed: SEED,
        allowed_lateness: Some(LATENESS),
        late_policy: LatePolicy::Drop,
        ..Config::default()
    };

    let mut rows = Vec::new();
    let mut disordered_late_total = 0usize;
    for (name, traffic) in shapes() {
        for disordered in [false, true] {
            let mut w = workloads::by_name("lr1s")
                .expect("lr1s")
                .with_traffic(traffic);
            if disordered {
                w = w.with_disorder(Disorder::new(0.5, MAX_DELAY));
            }
            let r = run(&w, &cfg, Duration::from_secs(SECS), None).expect(name);
            let late: usize = r.batches.iter().map(|b| b.late_rows).sum();
            let max_lag = r
                .batches
                .iter()
                .map(|b| b.watermark_lag)
                .max()
                .unwrap_or(Duration::ZERO);
            assert!(
                r.avg_throughput > 0.0,
                "{name} ({}) must sustain throughput with event time on",
                if disordered { "disordered" } else { "in-order" }
            );
            if disordered {
                disordered_late_total += late;
            } else {
                assert_eq!(
                    late, 0,
                    "{name}: in-order arrivals can never be late \
                     (event_time == created_at)"
                );
            }
            rows.push(vec![
                name.to_string(),
                if disordered { "disordered" } else { "in-order" }.to_string(),
                format!("{:.1}", r.avg_throughput / 1024.0),
                fmt_secs(r.avg_latency),
                late.to_string(),
                fmt_secs(max_lag.as_secs_f64()),
            ]);
        }
    }

    print_table(
        &format!(
            "Event time per traffic shape (lr1s, lateness {}s, \
             disorder p=0.5 max {}s, {SECS}s)",
            LATENESS.as_secs(),
            MAX_DELAY.as_secs()
        ),
        &["shape", "arrivals", "KB/s", "avg lat", "late rows", "max wm lag"],
        &rows,
    );

    assert!(
        disordered_late_total > 0,
        "with max_delay 4x the allowed lateness, the disordered suite \
         must classify some rows late"
    );
    println!("\nfig_eventtime OK");
}
