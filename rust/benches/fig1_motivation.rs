//! Fig. 1 — motivation: under the static-trigger micro-batch model on
//! CPU with constant traffic, the per-batch maximum latency and the
//! number of datasets per micro-batch both grow without bound.
//!
//! Paper shape to reproduce: both series trend upward batch over batch
//! (the "vicious cycle" of §II-C); LMStream (overlaid) stays flat.

use lmstream::config::{Config, Mode};
use lmstream::coordinator::driver;
use lmstream::report::figures;
use lmstream::util::bench::{print_table, Bencher};
use lmstream::workloads;
use std::time::Duration;

fn main() {
    let minutes = 12;
    let r = figures::fig1_series(minutes, 7).expect("fig1 run");

    let rows: Vec<Vec<String>> = r
        .batches
        .iter()
        .map(|b| {
            vec![
                b.index.to_string(),
                format!("{:.2}", b.max_latency.as_secs_f64()),
                b.num_datasets.to_string(),
            ]
        })
        .collect();
    print_table(
        "Fig.1 — static trigger (10 s), LR1, CPU, constant traffic",
        &["micro-batch", "max latency (s)", "datasets"],
        &rows,
    );

    // Shape assertions: later batches strictly dominate early ones.
    let n = r.batches.len();
    assert!(n >= 6, "need enough batches, got {n}");
    let early: f64 = r.batches[..3]
        .iter()
        .map(|b| b.max_latency.as_secs_f64())
        .sum::<f64>()
        / 3.0;
    let late: f64 = r.batches[n - 3..]
        .iter()
        .map(|b| b.max_latency.as_secs_f64())
        .sum::<f64>()
        / 3.0;
    println!("\nearly-3 avg max latency {early:.2} s → late-3 avg {late:.2} s");
    assert!(
        late > early * 1.25,
        "paper shape: latency must grow (early {early:.2}, late {late:.2})"
    );
    let early_ds: f64 =
        r.batches[..3].iter().map(|b| b.num_datasets as f64).sum::<f64>() / 3.0;
    let late_ds: f64 =
        r.batches[n - 3..].iter().map(|b| b.num_datasets as f64).sum::<f64>() / 3.0;
    assert!(
        late_ds > early_ds,
        "paper shape: datasets/batch must grow ({early_ds} → {late_ds})"
    );

    // LMStream contrast: bounded.
    let w = workloads::by_name("lr1s").expect("workload");
    let cfg = Config { mode: Mode::LmStream, seed: 7, ..Config::default() };
    let lm = driver::run(&w, &cfg, Duration::from_secs(minutes * 60), None).expect("run");
    println!(
        "LMStream contrast: avg max latency {:.2} s (bounded by slide 5 s + proc)",
        lm.avg_max_latency()
    );

    // Timing of the simulation itself.
    let mut b = Bencher::endtoend();
    b.bench("fig1 12-min simulated run", || {
        figures::fig1_series(minutes, 7).unwrap().batches.len()
    });
    b.report();
    println!("fig1 OK");
}
