//! Table IV — overhead analysis: share of total run time spent in each
//! step under LMStream (buffering, ConstructMicroBatch, MapDevice,
//! processing, optimization blocking).
//!
//! Paper shape: the three LMStream mechanisms (gray rows) together take
//! well under a few percent; buffering + processing dominate.

use lmstream::config::Mode;
use lmstream::report::figures;
use lmstream::util::bench::print_table;
use lmstream::workloads;

fn main() {
    let minutes = 10;
    let seed = 7;
    let mut rows = Vec::new();
    for name in workloads::ALL {
        let r = figures::overall(name, Mode::LmStream, minutes, seed).expect("run");
        let ratios = r.phases.ratios();
        let mechanisms: f64 = ratios[1].1 + ratios[2].1 + ratios[4].1;
        rows.push(
            std::iter::once(name.to_uppercase())
                .chain(ratios.iter().map(|(_, v)| format!("{v:.3}")))
                .chain(std::iter::once(format!("{mechanisms:.3}")))
                .collect::<Vec<String>>(),
        );
        assert!(
            mechanisms < 5.0,
            "{name}: LMStream mechanisms take {mechanisms:.2}% (paper: ~<1–4%)"
        );
    }
    print_table(
        "Table IV — time ratio per step (%), LMStream",
        &[
            "workload",
            "buffering",
            "construct",
            "mapdevice",
            "processing",
            "optblock",
            "mechanisms Σ",
        ],
        &rows,
    );
    println!("table4 OK");
}
