//! §Perf — L3 hot-path microbenchmarks: the per-poll and per-batch
//! coordinator work that must stay far below the 10 ms poll interval
//! (Table IV's "Construct Micro-batch" and "Map Device" rows).
//!
//! Measured pieces: admission estimate (Eq. 6), MapDevice planning
//! (Alg. 2), the OLS fit (Eq. 10), micro-batch concat/partition, and the
//! native operator kernels the simulated path runs per batch.

use lmstream::coordinator::admission::Admission;
use lmstream::coordinator::optimizer::{fit_inflection, FitJob, HistoryPoint};
use lmstream::coordinator::planner::{map_device, SizeEstimator};
use lmstream::engine::dataset::{Dataset, MicroBatch};
use lmstream::engine::ops;
use lmstream::engine::partition;
use lmstream::sim::Time;
use lmstream::util::bench::Bencher;
use lmstream::workloads::{self, linear_road::LinearRoadGen};
use lmstream::source::stream::RowGen;

fn lr_micro_batch(datasets: usize, rows_each: usize) -> MicroBatch {
    let mut gen = LinearRoadGen::new(3);
    let ds: Vec<Dataset> = (0..datasets)
        .map(|i| {
            let batch = gen.generate(i as u64, rows_each);
            let bytes = batch.bytes();
            Dataset {
                id: i as u64,
                created_at: Time::from_secs_f64(i as f64),
                event_time: Time::from_secs_f64(i as f64),
                batch,
                wire_bytes: bytes,
            }
        })
        .collect();
    MicroBatch::new(ds)
}

fn main() {
    let mut b = Bencher::default();
    let q = workloads::by_name("lr1s").expect("lr1s").query;

    // Admission estimate (runs every 10 ms poll).
    let mb = lr_micro_batch(10, 1000);
    b.bench("eq6 estimate_max_latency (10 datasets)", || {
        Admission::estimate_max_latency(&mb, Time::from_secs_f64(12.0), 30_000.0)
    });

    // MapDevice planning (runs once per batch).
    let est = SizeEstimator::new(q.len());
    b.bench("alg2 map_device (LR1S dag)", || {
        map_device(&q, 64.0 * 1024.0, 150.0 * 1024.0, 0.1, &est).expect("plan")
    });

    // Eq. 10 fit over a long history (background thread work).
    let history: Vec<HistoryPoint> = (0..1000)
        .map(|k| HistoryPoint {
            throughput: 30_000.0 + (k % 37) as f64 * 100.0,
            max_latency: 4.0 + (k % 11) as f64 * 0.1,
            inf_pt: 140_000.0 + (k % 53) as f64 * 500.0,
        })
        .collect();
    let job = FitJob { history, target_throughput: 40_000.0, target_latency: 5.0 };
    b.bench("eq10 ols fit (1000-point history)", || fit_inflection(&job));

    // Batch assembly + partitioning (once per batch).
    b.bench("micro-batch concat (10x1000 rows)", || mb.concat().unwrap());
    let big = mb.concat().unwrap();
    b.bench("partition split into 12", || partition::split(&big, big.bytes(), 12));

    // Native operator kernels over a 10k-row batch.
    let mut gen = LinearRoadGen::new(9);
    let batch = gen.generate(0, 10_000);
    let window = gen.generate(1, 30_000);
    b.bench("filter 10k rows", || {
        ops::filter(&batch, "speed", ops::Predicate::Ge(40.0)).unwrap()
    });
    b.bench("hash_aggregate 10k rows x 3 keys", || {
        ops::hash_aggregate(
            &batch,
            &["highway", "direction", "segment"],
            &[ops::AggSpec::avg("speed", "avg")],
            None,
        )
        .unwrap()
    });
    b.bench("hash_join 10k probe x 30k window", || {
        ops::hash_join(&batch, &window, "vehicle", "vehicle").unwrap()
    });
    let keep: Vec<String> = ["timestamp", "vehicle", "speed", "highway", "lane",
        "direction", "segment"].iter().map(|s| s.to_string()).collect();
    b.bench("hash_join pruned (probe cols only)", || {
        ops::join::hash_join_pruned(
            &batch, &window, "vehicle", "vehicle", Some(&keep), Some(&[]),
        )
        .unwrap()
    });
    b.bench("sort 10k rows", || ops::sort_by(&batch, "speed", false).unwrap());
    b.report();

    println!("perf_hotpath OK");
}
