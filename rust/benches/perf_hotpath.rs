//! §Perf — L3 hot-path microbenchmarks: the per-poll and per-batch
//! coordinator work that must stay far below the 10 ms poll interval
//! (Table IV's "Construct Micro-batch" and "Map Device" rows).
//!
//! Measured pieces: admission estimate (Eq. 6), MapDevice planning
//! (Alg. 2), joint cross-query scheduling (N queries, one GPU — with
//! the co-scheduled ≤ independent makespan assertion), the OLS fit
//! (Eq. 10), micro-batch assembly (chunked vs. materializing concat)
//! and partitioning, the native operator kernels the simulated path
//! runs per batch, the zero-copy batch plumbing (clone/slice/scan), the
//! window-snapshot path (chunk-list vs. fresh concat — the O(#datasets)
//! vs O(window-rows) claim), 8-way `Union` fan-in assembly (chunk
//! appends must be independent of total row count), and end-to-end
//! `Session::run` micro-batch loops (single- and multi-query).
//!
//! Emits `BENCH_hotpath.json` (machine-readable, schema_version 5) into
//! the working directory — the perf-trajectory artifact CI uploads and
//! gates against the committed baseline (`tools/bench_gate.py`).
//!
//! Schema 4 adds the operator-fusion and encoded-state ratios: a fused
//! scan→filter→affine→select chain must run no slower than its staged
//! member kernels (`fused_vs_staged_ratio <= 1.0`) and cold-encoded
//! window state must sit strictly below its raw footprint on an
//! RLE-friendly workload (`encoded_window_bytes_ratio < 1.0`).
//!
//! Schema 5 adds the sharded-runtime scaling ratio from a 4-source
//! sharded run: `shard_scaling_ratio` = Σ_epochs(max per-source proc) /
//! Σ_epochs(Σ per-source proc). The numerator is what the sharded
//! session clock pays per round epoch (shards run concurrently, the
//! epoch costs the slowest source), the denominator is what a serial
//! round would pay (sources queue one after another) — the ratio must
//! never exceed 1.0 (gated here and by `max_shard_scaling_ratio` in
//! `tools/bench_gate.py`).

use lmstream::cluster::DeviceTopology;
use lmstream::config::{Config, Mode};
use lmstream::coordinator::admission::Admission;
use lmstream::coordinator::optimizer::{fit_inflection, FitJob, HistoryPoint};
use lmstream::coordinator::planner::{map_device, SizeEstimator};
use lmstream::coordinator::schedule::{plan_joint, QueryCandidate};
use lmstream::devices::model::DeviceModel;
use lmstream::devices::Device;
use lmstream::engine::chunked::ChunkedBatch;
use lmstream::engine::column::{Column, ColumnBatch, Field, Schema};
use lmstream::engine::dataset::{Dataset, MicroBatch};
use lmstream::engine::ops;
use lmstream::engine::partition;
use lmstream::engine::window::{WindowSpec, WindowState};
use lmstream::query::physical::PhysicalPlan;
use lmstream::query::{fuse, QueryBuilder};
use lmstream::session::Session;
use lmstream::sim::Time;
use lmstream::source::stream::RowGen;
use lmstream::source::traffic::Traffic;
use lmstream::util::bench::{BenchResult, Bencher};
use lmstream::util::json;
use lmstream::workloads::{self, linear_road::LinearRoadGen, Workload};
use std::collections::BTreeMap;
use std::time::Duration;

fn lr_micro_batch(datasets: usize, rows_each: usize) -> MicroBatch {
    let mut gen = LinearRoadGen::new(3);
    let ds: Vec<Dataset> = (0..datasets)
        .map(|i| {
            let batch = gen.generate(i as u64, rows_each);
            let bytes = batch.alloc_bytes();
            Dataset {
                id: i as u64,
                created_at: Time::from_secs_f64(i as f64),
                event_time: Time::from_secs_f64(i as f64),
                batch,
                wire_bytes: bytes,
            }
        })
        .collect();
    MicroBatch::new(ds)
}

fn dataset_at(id: u64, t: f64, batch: ColumnBatch) -> Dataset {
    Dataset {
        id,
        created_at: Time::from_secs_f64(t),
        event_time: Time::from_secs_f64(t),
        wire_bytes: batch.alloc_bytes(),
        batch,
    }
}

const SNAP_CHUNKED: &str = "window snapshot chunked (30k-row state)";
const SNAP_FRESH: &str = "window snapshot fresh concat (30k-row state)";
const UNION_SMALL: &str = "union fan-in 8-way (10k rows/branch)";
const UNION_BIG: &str = "union fan-in 8-way (80k rows/branch)";
const CHAIN_STAGED: &str = "staged scan>filter>affine>select (100k rows, 8 chunks)";
const CHAIN_FUSED: &str = "fused scan>filter>affine>select (100k rows, 8 chunks)";

/// An RLE-friendly batch: long constant runs in every column, the state
/// shape the cold-chunk codecs are built for (sensor plateaus, repeated
/// keys).
fn rle_friendly_batch(id: u64, rows: usize) -> ColumnBatch {
    let schema = Schema::new(vec![Field::f32("v"), Field::f32("w"), Field::i32("k")]);
    ColumnBatch::new(
        schema,
        vec![
            Column::F32(vec![(id % 5) as f32; rows].into()),
            Column::F32(vec![0.5; rows].into()),
            Column::I32(vec![(id % 3) as i32; rows].into()),
        ],
    )
    .expect("consistent batch")
}

/// Four Linear-Road sources with deliberately skewed rates — the shape
/// the sharded runtime is for (independent round loops meeting only at
/// the timeline bank). The optimizer stays off so the simulated run is
/// a pure function of the sources (same contract the `sharding`
/// differential tests pin).
const SHARD_SOURCES: &[&str] = &["shard-a", "shard-b", "shard-c", "shard-d"];

fn shard_source_gen(seed: u64) -> Box<dyn RowGen> {
    Box::new(LinearRoadGen::new(seed))
}

fn shard_session(shards: usize) -> Session {
    let mut s = Session::new(Config {
        mode: Mode::LmStream,
        shards: Some(shards),
        online_optimizer: false,
        seed: 11,
        ..Config::default()
    })
    .expect("session");
    for (i, name) in SHARD_SOURCES.iter().copied().enumerate() {
        let q = QueryBuilder::scan(name)
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .filter("speed", ops::Predicate::Ge(40.0))
            .build()
            .expect("query");
        s.register(Workload::new(
            name,
            q,
            Traffic::Constant { rows: 400 * (i + 1) },
            shard_source_gen,
        ))
        .expect("register");
    }
    s
}

fn main() {
    let mut b = Bencher::default();
    let q = workloads::by_name("lr1s").expect("lr1s").query;

    // Admission estimate (runs every 10 ms poll).
    let mb = lr_micro_batch(10, 1000);
    b.bench("eq6 estimate_max_latency (10 datasets)", || {
        Admission::estimate_max_latency(&mb, Time::from_secs_f64(12.0), 30_000.0)
    });

    // MapDevice planning (runs once per batch).
    let est = SizeEstimator::new(q.len());
    b.bench("alg2 map_device (LR1S dag)", || {
        map_device(&q, 64.0 * 1024.0, 150.0 * 1024.0, 0.1, &est, 2).expect("plan")
    });

    // Joint cross-query scheduling: 4 GPU-leaning queries, one GPU. The
    // scheduler must stay far below the 10 ms poll interval, and its
    // predicted co-scheduled makespan must never exceed the independent
    // plans' shared-timeline makespan (gated below and in CI).
    let model = DeviceModel::default();
    let contenders: Vec<_> = (0..4).map(|_| q.clone()).collect();
    let make_cands = || {
        contenders
            .iter()
            .map(|cq| {
                let cest = SizeEstimator::new(cq.len());
                QueryCandidate::build(
                    cq,
                    48.0 * 1024.0,
                    10.0 * 1024.0,
                    0.1,
                    &cest,
                    4,
                    0.0,
                    0,
                )
                .expect("candidate")
            })
            .collect::<Vec<_>>()
    };
    let topo = DeviceTopology::single(12, 1);
    b.bench("joint co-schedule (4 queries, 1 GPU)", || {
        let cands = make_cands();
        plan_joint(&cands, &model, &topo).predicted.makespan
    });
    // Topology-aware joint planning over the paper's 4-executor
    // testbed: one simulated timeline per executor GPU.
    let cluster_topo =
        DeviceTopology::from_cluster(&lmstream::cluster::ClusterSpec::paper());
    b.bench("joint co-schedule (4 queries, 4 executors)", || {
        let cands = make_cands();
        plan_joint(&cands, &model, &cluster_topo).predicted.makespan
    });
    let cands = make_cands();
    let joint = plan_joint(&cands, &model, &topo);
    let cosched_ratio = if joint.predicted.independent_shared_makespan > 0.0 {
        joint.predicted.makespan / joint.predicted.independent_shared_makespan
    } else {
        0.0
    };
    println!(
        "co-schedule makespan ratio (joint / independent-serialized): {cosched_ratio:.3}"
    );

    // Eq. 10 fit over a long history (background thread work).
    let history: Vec<HistoryPoint> = (0..1000)
        .map(|k| HistoryPoint {
            throughput: 30_000.0 + (k % 37) as f64 * 100.0,
            max_latency: 4.0 + (k % 11) as f64 * 0.1,
            inf_pt: 140_000.0 + (k % 53) as f64 * 500.0,
        })
        .collect();
    let job = FitJob { history, target_throughput: 40_000.0, target_latency: 5.0 };
    b.bench("eq10 ols fit (1000-point history)", || fit_inflection(&job));

    // Batch assembly + partitioning (once per batch). The chunked
    // assembly is what the session actually runs now; the materializing
    // concat stays as the baseline it replaced.
    b.bench("micro-batch chunked assembly (10x1000 rows)", || {
        mb.chunked().unwrap().rows()
    });
    b.bench("micro-batch concat (10x1000 rows)", || mb.concat().unwrap());
    let big = mb.concat().unwrap();
    b.bench("partition split into 12 (O(1) views)", || {
        partition::split(&big, big.alloc_bytes(), 12)
    });

    // Union fan-in: an 8-way Union's input assembly is a chunk-list
    // append — its cost must be independent of the total row count (no
    // O(total) copy). Measured at 10k and 80k rows per branch; the gate
    // below asserts the 8x-data point costs nowhere near 8x.
    let mut fan_gen = LinearRoadGen::new(11);
    let branches_small: Vec<ChunkedBatch> =
        (0..8).map(|i| ChunkedBatch::from_batch(fan_gen.generate(i, 10_000))).collect();
    let branches_big: Vec<ChunkedBatch> =
        (0..8).map(|i| ChunkedBatch::from_batch(fan_gen.generate(8 + i, 80_000))).collect();
    b.bench(UNION_SMALL, || {
        let refs: Vec<&ChunkedBatch> = branches_small.iter().collect();
        ChunkedBatch::concat(&refs).expect("same schema").rows()
    });
    b.bench(UNION_BIG, || {
        let refs: Vec<&ChunkedBatch> = branches_big.iter().collect();
        ChunkedBatch::concat(&refs).expect("same schema").rows()
    });

    // Zero-copy batch plumbing: clone / slice / scan are Arc bumps, not
    // row copies — these should sit at ns scale independent of rows.
    let lr_schema = workloads::linear_road::schema();
    b.bench("batch clone (10k rows, Arc bumps)", || big.clone());
    b.bench("batch slice 1/12 (view)", || big.slice(0, big.rows() / 12));
    b.bench("scan passthrough (zero-copy)", || {
        ops::scan(&big, &lr_schema).expect("scan")
    });

    // Native operator kernels over a 10k-row batch.
    let mut gen = LinearRoadGen::new(9);
    let batch = gen.generate(0, 10_000);
    let window = gen.generate(1, 30_000);
    b.bench("filter 10k rows", || {
        ops::filter(&batch, "speed", ops::Predicate::Ge(40.0)).unwrap()
    });
    b.bench("hash_aggregate 10k rows x 3 keys", || {
        ops::hash_aggregate(
            &batch,
            &["highway", "direction", "segment"],
            &[ops::AggSpec::avg("speed", "avg")],
            None,
        )
        .unwrap()
    });
    b.bench("hash_join 10k probe x 30k window", || {
        ops::hash_join(&batch, &window, "vehicle", "vehicle").unwrap()
    });
    let keep: Vec<String> = ["timestamp", "vehicle", "speed", "highway", "lane",
        "direction", "segment"].iter().map(|s| s.to_string()).collect();
    b.bench("hash_join pruned (probe cols only)", || {
        ops::join::hash_join_pruned(
            &batch, &window, "vehicle", "vehicle", Some(&keep), Some(&[]),
        )
        .unwrap()
    });
    b.bench("sort 10k rows", || ops::sort_by(&batch, "speed", false).unwrap());

    // Operator fusion: the same scan>filter>affine>select chain run as
    // staged member kernels (each materializing its intermediate) vs.
    // one fused traversal per chunk. The fused spec comes out of the
    // real fusion pass, so this measures exactly what the executor runs.
    let fq = QueryBuilder::scan("fused-bench")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
        .filter("speed", ops::Predicate::Ge(40.0))
        .project_affine("speed", "speed", 0.5, 0.5, "eff")
        .select(&["vehicle", "eff"])
        .build()
        .expect("fusable chain");
    let fplan = fuse::fuse(&fq, &PhysicalPlan::uniform(&fq, Device::Cpu));
    assert_eq!(fplan.groups.len(), 1, "chain must fuse into one group");
    let fspec = &fplan.groups[0].spec;
    let mut cgen = LinearRoadGen::new(13);
    let mut fin = ChunkedBatch::from_batch(cgen.generate(0, 12_500));
    for i in 1..8u64 {
        fin.push(cgen.generate(i, 12_500)).expect("same schema");
    }
    b.bench(CHAIN_STAGED, || {
        let a = ops::filter_chunks(&fin, "speed", ops::Predicate::Ge(40.0)).unwrap();
        let c = ops::project_affine_chunks(&a, "speed", "speed", 0.5, 0.5, "eff").unwrap();
        ops::project_select_chunks(&c, &["vehicle", "eff"]).unwrap().rows()
    });
    b.bench(CHAIN_FUSED, || {
        ops::fused::run_chunks(&fin, fspec).unwrap().0.rows()
    });

    // Encoded window state: push well past the hot threshold so most
    // chunks live cold-encoded, then compare the resident footprint to
    // what plain chunks would hold. The snapshot stays exact (decode is
    // lazy and cached) — only the resident bytes shrink.
    let mut ew = WindowState::new();
    for i in 0..32u64 {
        ew.push(&[dataset_at(i, i as f64, rle_friendly_batch(i, 4096))]);
    }
    let enc_ratio = if ew.state_bytes_raw() > 0 {
        ew.state_bytes_encoded() as f64 / ew.state_bytes_raw() as f64
    } else {
        1.0
    };
    println!(
        "encoded window footprint: {} of {} raw bytes ({:.3}x, {} cold chunks)",
        ew.state_bytes_encoded(),
        ew.state_bytes_raw(),
        enc_ratio,
        ew.cold_chunks()
    );
    b.bench("window snapshot over cold-encoded state (32 chunks)", || {
        ew.snapshot_chunks().expect("snapshot").expect("non-empty").rows()
    });

    // Window snapshot: steady-state per-batch cycle (evict + push 1k
    // rows + snapshot) over a ~30k-row window. The chunk-list snapshot
    // pays O(#datasets) Arc bumps; the fresh-concat baseline pays
    // O(window rows) — the acceptance bar is >= 5x between the two at
    // this state size.
    let spec = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
    let mut wgen = LinearRoadGen::new(7);
    let pool: Vec<ColumnBatch> = (0..64).map(|i| wgen.generate(i, 1000)).collect();
    let mut w = WindowState::new();
    for i in 0..30u64 {
        w.push(&[dataset_at(i, i as f64, pool[i as usize % pool.len()].clone())]);
    }
    let mut t = 30.0f64;
    let mut id = 30u64;
    b.bench(SNAP_CHUNKED, || {
        w.evict(Time::from_secs_f64(t), &spec);
        w.push(&[dataset_at(id, t, pool[id as usize % pool.len()].clone())]);
        t += 1.0;
        id += 1;
        w.snapshot_chunks().expect("snapshot").expect("non-empty").rows()
    });
    b.bench(SNAP_FRESH, || {
        w.evict(Time::from_secs_f64(t), &spec);
        w.push(&[dataset_at(id, t, pool[id as usize % pool.len()].clone())]);
        t += 1.0;
        id += 1;
        w.snapshot_fresh().expect("snapshot").expect("non-empty").rows()
    });

    // End-to-end micro-batch loop: a whole simulated Session::run
    // (poll -> admission -> plan -> execute -> metrics -> window upkeep).
    let mut e2e = Bencher::endtoend();
    e2e.bench("session::run lr1s (60s simulated loop)", || {
        let mut s = Session::new(Config { mode: Mode::LmStream, ..Config::default() })
            .expect("session");
        s.register(workloads::by_name("lr1s").expect("lr1s")).expect("register");
        s.run(Duration::from_secs(60)).expect("run").len()
    });
    // Multi-query contention loop: two queries, one source, one shared
    // GPU timeline, joint planning per batch.
    e2e.bench("session::run 2-query co-scheduled (60s simulated loop)", || {
        use lmstream::engine::ops::filter::Predicate;
        use lmstream::query::QueryBuilder;
        let mut s = Session::new(Config { mode: Mode::LmStream, ..Config::default() })
            .expect("session");
        let w = workloads::by_name("lr1s").expect("lr1s");
        let window = w.query.window;
        let first = s.register(w).expect("register");
        let side = QueryBuilder::scan("side")
            .window(window)
            .filter("speed", Predicate::Lt(60.0))
            .build()
            .expect("query");
        s.register_shared(first, "side", side).expect("register_shared");
        s.run(Duration::from_secs(60)).expect("run").len()
    });
    // Sharded runtime: the same 4 skewed sources run serial (shards=1,
    // one round loop visits every source) and sharded (shards=4, one
    // concurrent round loop per source meeting at the timeline bank).
    e2e.bench("session::run 4-source serial (shards=1, 60s simulated loop)", || {
        shard_session(1).run(Duration::from_secs(60)).expect("run").len()
    });
    e2e.bench("session::run 4-source sharded (shards=4, 60s simulated loop)", || {
        shard_session(4).run(Duration::from_secs(60)).expect("run").len()
    });

    // Shard scaling ratio from one sharded run's records: per round
    // epoch the sharded clock pays the slowest source's proc (max); a
    // serial round pays all of them back to back (sum). The ratio over
    // the whole run is the concurrency win and can never exceed 1.0 —
    // max <= sum holds per epoch by construction, so a ratio above 1.0
    // means the epoch accounting itself regressed.
    let shard_run =
        shard_session(4).run(Duration::from_secs(60)).expect("sharded run");
    let mut per_round: BTreeMap<usize, BTreeMap<usize, f64>> = BTreeMap::new();
    for (src, r) in shard_run.iter().enumerate() {
        for rec in &r.batches {
            *per_round.entry(rec.round).or_default().entry(src).or_insert(0.0) +=
                rec.proc.as_secs_f64();
        }
    }
    let mut epoch_total = 0.0f64; // Σ_epochs max-source proc (sharded clock)
    let mut serial_total = 0.0f64; // Σ_epochs Σ-source proc (serial clock)
    for sources in per_round.values() {
        epoch_total += sources.values().fold(0.0f64, |a, &p| a.max(p));
        serial_total += sources.values().sum::<f64>();
    }
    let shard_ratio =
        if serial_total > 0.0 { epoch_total / serial_total } else { 0.0 };
    println!(
        "shard scaling ratio (epoch max / serial sum over {} rounds): {shard_ratio:.3}",
        per_round.len()
    );

    b.report();
    e2e.report();

    let chunked = b.mean_of(SNAP_CHUNKED);
    let fresh = b.mean_of(SNAP_FRESH);
    let speedup = if chunked > 0.0 { fresh / chunked } else { 0.0 };
    println!("\nwindow snapshot speedup (fresh / chunked): {speedup:.1}x");

    let union_small = b.mean_of(UNION_SMALL);
    let union_big = b.mean_of(UNION_BIG);
    let union_scaling = if union_small > 0.0 { union_big / union_small } else { 0.0 };
    println!("union fan-in scaling (80k/branch vs 10k/branch): {union_scaling:.2}x");

    let staged_chain = b.mean_of(CHAIN_STAGED);
    let fused_chain = b.mean_of(CHAIN_FUSED);
    let fused_ratio = if staged_chain > 0.0 { fused_chain / staged_chain } else { 0.0 };
    println!("fused / staged chain ratio: {fused_ratio:.3}x");

    // Machine-readable trajectory point.
    let row = |r: &BenchResult| {
        json::obj(vec![
            ("name", json::s(&r.name)),
            ("mean_s", json::num(r.summary.mean)),
            ("p50_s", json::num(r.summary.p50)),
            ("p99_s", json::num(r.summary.p99)),
            ("n", json::num(r.summary.n as f64)),
        ])
    };
    let results: Vec<json::Json> =
        b.results().iter().chain(e2e.results().iter()).map(row).collect();
    let doc = json::obj(vec![
        ("bench", json::s("perf_hotpath")),
        ("schema_version", json::num(5.0)),
        ("window_snapshot_speedup", json::num(speedup)),
        ("union_fanin_scaling", json::num(union_scaling)),
        ("coschedule_makespan_ratio", json::num(cosched_ratio)),
        ("fused_vs_staged_ratio", json::num(fused_ratio)),
        ("encoded_window_bytes_ratio", json::num(enc_ratio)),
        ("shard_scaling_ratio", json::num(shard_ratio)),
        ("results", json::arr(results)),
    ]);
    std::fs::write("BENCH_hotpath.json", doc.render() + "\n")
        .expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json");

    assert!(
        speedup >= 5.0,
        "window snapshot must be >=5x over fresh concat at 30k-row state, got {speedup:.1}x"
    );
    // 8x the rows must not approach 8x the assembly cost: the 8-way
    // Union is chunk appends, independent of total row count (3x leaves
    // room for timer noise at ~100ns scale while still refuting any
    // O(total) copy).
    assert!(
        union_scaling < 3.0,
        "union fan-in must be independent of row count, got {union_scaling:.2}x"
    );
    // Co-scheduling must never predict a worse makespan than the
    // independent plans serialized on the same shared device (the
    // scheduler falls back to exactly those plans if it cannot improve).
    assert!(
        cosched_ratio > 0.0 && cosched_ratio <= 1.0 + 1e-6,
        "co-scheduled makespan must be <= independent-plan makespan, ratio {cosched_ratio:.3}"
    );
    // Fusion must never lose to staged execution: one traversal per
    // chunk with no intermediate Validity/column materialization has
    // strictly less work — at 100k rows the margin dwarfs timer noise.
    assert!(
        fused_ratio > 0.0 && fused_ratio <= 1.0,
        "fused chain must run no slower than staged members, ratio {fused_ratio:.3}"
    );
    // Cold-encoded state must shrink strictly below raw on this
    // RLE-friendly workload (constant runs compress to per-run pairs).
    assert!(
        enc_ratio > 0.0 && enc_ratio < 1.0,
        "encoded window state must be strictly smaller than raw, ratio {enc_ratio:.3}"
    );
    // The sharded epoch clock pays the max source proc per round; a
    // serial round pays the sum. max <= sum per epoch, so any ratio
    // above 1.0 (modulo float slack) is an epoch-accounting regression.
    assert!(
        shard_ratio > 0.0 && shard_ratio <= 1.0 + 1e-6,
        "shard epoch cost must not exceed the serial sum, ratio {shard_ratio:.3}"
    );
    println!("perf_hotpath OK");
}
