//! Ablation — cluster scale-out: LMStream on 1/2/4/8 executors at
//! proportionally scaled traffic (the paper's testbed is 4 executors,
//! §V-A). Checks that the distributed runtime keeps latency bounded as
//! both resources and load grow, and that shuffle-heavy queries pay a
//! visible-but-sane network share.

use lmstream::cluster::ClusterSpec;
use lmstream::config::{Config, Mode};
use lmstream::coordinator::driver;
use lmstream::source::traffic::Traffic;
use lmstream::util::bench::print_table;
use lmstream::workloads;
use std::time::Duration;

fn main() {
    let minutes = 6;
    let mut rows = Vec::new();
    let mut lat_by_scale = Vec::new();
    for executors in [1usize, 2, 4, 8] {
        // Scale ingest with cluster size (weak scaling).
        let w = workloads::by_name("cm2s")
            .expect("cm2s")
            .with_traffic(Traffic::Constant { rows: 2000 * executors });
        let cfg = Config {
            mode: Mode::LmStream,
            cluster: Some(ClusterSpec::of(executors)),
            seed: 7,
            ..Config::default()
        };
        let r = driver::run(&w, &cfg, Duration::from_secs(minutes * 60), None)
            .expect("cluster run");
        lat_by_scale.push(r.avg_latency);
        rows.push(vec![
            executors.to_string(),
            format!("{}", r.batches.len()),
            format!("{:.2}", r.avg_latency),
            format!("{:.1}", r.avg_throughput / 1024.0),
            format!("{:.3}", r.avg_proc()),
        ]);
    }
    print_table(
        "Ablation — weak scaling on CM2S (LMStream, constant traffic x executors)",
        &["executors", "batches", "avg lat (s)", "thpt KB/s", "avg proc (s)"],
        &rows,
    );

    // Weak scaling must keep latency bounded: 8 executors at 8x load stay
    // within 2.5x of the single-executor latency.
    let single = lat_by_scale[0];
    let eight = *lat_by_scale.last().unwrap();
    assert!(
        eight < single * 2.5 + 2.0,
        "weak scaling broke the latency bound: {single:.2}s -> {eight:.2}s"
    );
    println!("ablation_cluster OK");
}
