//! Ablation — online optimizer (Eq. 10): LMStream with the regression
//! on vs off (inflection point frozen at the 150 KB initial), and the
//! paper's §III-E future-work policy (last-N history window), on the
//! workload mix.
//!
//! Expected: optimizer-on tracks or beats optimizer-off (it can only
//! refine the initial value), and the last-N policy stays within noise
//! of full history while bounding memory.

use lmstream::config::{Config, Mode};
use lmstream::coordinator::driver;
use lmstream::util::bench::print_table;
use lmstream::workloads;
use std::time::Duration;

fn run_cfg(workload: &str, optimizer: bool, cap: Option<usize>) -> (f64, f64, f64) {
    let w = workloads::by_name(workload).expect("workload");
    let cfg = Config {
        mode: Mode::LmStream,
        online_optimizer: optimizer,
        history_cap: cap,
        seed: 7,
        ..Config::default()
    };
    let r = driver::run(&w, &cfg, Duration::from_secs(10 * 60), None).expect("run");
    (r.avg_latency, r.avg_throughput / 1024.0, r.final_inf_pt / 1024.0)
}

fn main() {
    let mut rows = Vec::new();
    for workload in ["lr1s", "lr2s", "cm2s"] {
        let (off_lat, off_thr, off_inf) = run_cfg(workload, false, None);
        let (on_lat, on_thr, on_inf) = run_cfg(workload, true, None);
        let (n_lat, n_thr, n_inf) = run_cfg(workload, true, Some(32));
        rows.push(vec![
            workload.to_uppercase(),
            format!("{off_lat:.2}/{off_thr:.0} ({off_inf:.0}K)"),
            format!("{on_lat:.2}/{on_thr:.0} ({on_inf:.0}K)"),
            format!("{n_lat:.2}/{n_thr:.0} ({n_inf:.0}K)"),
        ]);
        // The optimizer must not wreck performance relative to frozen.
        assert!(
            on_lat < off_lat * 1.35 + 0.5,
            "{workload}: optimizer-on latency {on_lat:.2} vs frozen {off_lat:.2}"
        );
        assert!(
            n_lat < on_lat * 1.35 + 0.5,
            "{workload}: last-32 policy within range of full history"
        );
    }
    print_table(
        "Ablation — online optimizer (lat s / thpt KB/s, final InfPT)",
        &["workload", "frozen 150K", "online (full hist)", "online (last 32)"],
        &rows,
    );
    println!("ablation_optimizer OK");
}
