//! Fig. 10 — effectiveness of dynamic device preference: average
//! processing-phase time per micro-batch, LMStream's dynamic planner vs
//! the FineStream-like static-preference planner (same batching, same
//! data — only the MapDevice policy differs), random traffic.
//!
//! Paper shape: dynamic wins on every query (up to 37.86% on CM1S, where
//! buffered batch growth forces all ops toward the GPU while the static
//! plan pins aggregate/filter/shuffle to the CPU).

use lmstream::report::figures;
use lmstream::util::bench::print_table;
use lmstream::workloads;

fn main() {
    let minutes = 12;
    let seed = 21;
    let mut rows = Vec::new();
    let mut any_big_win = false;
    for name in workloads::ALL {
        let (dynamic, stat) = figures::dynamic_vs_static(name, minutes, seed).expect("runs");
        let impr = (1.0 - dynamic.avg_proc() / stat.avg_proc().max(1e-12)) * 100.0;
        if impr > 15.0 {
            any_big_win = true;
        }
        rows.push(vec![
            name.to_uppercase(),
            format!("{:.3}", stat.avg_proc()),
            format!("{:.3}", dynamic.avg_proc()),
            format!("{impr:.1}%"),
        ]);
        assert!(
            dynamic.avg_proc() <= stat.avg_proc() * 1.05,
            "{name}: dynamic ({:.3}) must not lose to static ({:.3})",
            dynamic.avg_proc(),
            stat.avg_proc()
        );
    }
    print_table(
        "Fig.10 — avg processing phase time (s): static vs dynamic preference",
        &["workload", "static", "dynamic", "improvement"],
        &rows,
    );
    assert!(any_big_win, "paper shape: at least one workload sees a large win");
    println!("fig10 OK");
}
