//! Figs. 6 & 7 — overall performance: average per-dataset end-to-end
//! latency (Fig. 6) and Eq. 4 average throughput (Fig. 7) for all six
//! Table III workloads, LMStream vs Baseline, constant traffic.
//!
//! Paper shape: LMStream latency lower on every query (largest win on
//! tumbling windows — 70.7% on LR1T in the paper); throughput similar or
//! better, largest gain on LR1S (1.74x in the paper); CM1S nearly tied
//! (trigger == slide there, §V-B).

use lmstream::config::Mode;
use lmstream::report::figures;
use lmstream::util::bench::print_table;
use lmstream::workloads;

fn main() {
    let minutes = 15;
    let seed = 7;
    let mut rows = Vec::new();
    let mut worst_lat_impr = f64::INFINITY;
    let mut best_lat_impr = f64::NEG_INFINITY;
    let mut best_thr = f64::NEG_INFINITY;
    let mut tumbling_imprs = Vec::new();
    for name in workloads::ALL {
        let lm = figures::overall(name, Mode::LmStream, minutes, seed).expect("lm");
        let bl = figures::overall(name, Mode::Baseline, minutes, seed).expect("bl");
        let impr = (1.0 - lm.avg_latency / bl.avg_latency) * 100.0;
        let ratio = lm.avg_throughput / bl.avg_throughput;
        worst_lat_impr = worst_lat_impr.min(impr);
        best_lat_impr = best_lat_impr.max(impr);
        best_thr = best_thr.max(ratio);
        if name.ends_with('t') {
            tumbling_imprs.push(impr);
        }
        rows.push(figures::compare_row(&lm, &bl));
    }
    print_table(
        "Figs.6/7 — LMStream vs Baseline (constant traffic)",
        &["workload", "BL lat", "LM lat", "impr", "BL KB/s", "LM KB/s", "ratio"],
        &rows,
    );

    println!(
        "\nlatency improvement range {worst_lat_impr:.1}%..{best_lat_impr:.1}% \
         (paper max 70.7%); best throughput ratio {best_thr:.2}x (paper 1.74x)"
    );
    assert!(
        worst_lat_impr > 0.0,
        "paper shape: LMStream latency must win on every workload"
    );
    assert!(
        best_lat_impr > 45.0,
        "paper shape: the best-case latency win should be large (got {best_lat_impr:.1}%)"
    );
    assert!(
        tumbling_imprs.iter().all(|&i| i > 40.0),
        "paper shape: tumbling windows see the biggest latency wins ({tumbling_imprs:?})"
    );
    assert!(
        best_thr > 1.1,
        "paper shape: LMStream throughput should exceed baseline somewhere (got {best_thr:.2}x)"
    );
    println!("fig67 OK");
}
