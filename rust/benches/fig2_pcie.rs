//! Fig. 2 — PCIe overhead ratio (transfer time / total execution time)
//! for the synthetic select-project-join query across batch sizes and
//! device-mapping scenarios.
//!
//! Paper shape: < 1 % for small batches regardless of mapping; surges to
//! a significant share once the batch size passes the inflection region.

use lmstream::report::figures;
use lmstream::util::bench::print_table;
use lmstream::workloads;

fn main() {
    let q = workloads::by_name("spj").expect("spj").query;
    let scenarios: Vec<_> = figures::spj_scenarios(q.len())
        .into_iter()
        .filter(|(name, _)| *name != "all-CPU") // PCIe needs a GPU mapping
        .collect();

    let sizes_kb: [usize; 9] = [1, 4, 15, 50, 150, 500, 1500, 5000, 20000];
    let mut rows = Vec::new();
    let mut small_ratios = Vec::new();
    let mut large_ratios = Vec::new();
    for &kb in &sizes_kb {
        let mut row = vec![format!("{kb} KB")];
        for (_name, plan) in &scenarios {
            let (total, transfer) = figures::spj_cell(kb * 1024, plan, 3).expect("cell");
            let ratio = transfer / total * 100.0;
            if kb <= 4 {
                small_ratios.push(ratio);
            }
            if kb >= 5000 {
                large_ratios.push(ratio);
            }
            row.push(format!("{ratio:.2}%"));
        }
        rows.push(row);
    }
    let header: Vec<&str> = std::iter::once("batch size")
        .chain(scenarios.iter().map(|(n, _)| *n))
        .collect();
    print_table("Fig.2 — PCIe transfer share of total execution time", &header, &rows);

    let small_max = small_ratios.iter().cloned().fold(0.0, f64::max);
    let large_min = large_ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("\nsmall-batch max ratio {small_max:.2}% | large-batch min ratio {large_min:.2}%");
    assert!(small_max < 1.0, "paper shape: <1% overhead for small data");
    assert!(large_min > 2.0, "paper shape: significant overhead for large data");
    println!("fig2 OK");
}
