//! Fig. 5 — normalized execution times of the synthetic SPJ query across
//! batch sizes for the four mapping scenarios (all-CPU, all-GPU,
//! filter-on-CPU, project-on-CPU), normalized to all-CPU.
//!
//! Paper shape: below ~15 KB all-CPU wins (ratios > 1); in the 15–150 KB
//! band mixed mappings beat single-device; past the inflection region
//! all-GPU wins and CPU affinity collapses.

use lmstream::report::figures;
use lmstream::util::bench::print_table;
use lmstream::workloads;

fn main() {
    let q = workloads::by_name("spj").expect("spj").query;
    let scenarios = figures::spj_scenarios(q.len());
    let sizes_kb: [usize; 8] = [2, 8, 15, 50, 150, 500, 2000, 8000];

    let mut rows = Vec::new();
    let mut table: Vec<Vec<f64>> = Vec::new();
    for &kb in &sizes_kb {
        let cpu_total = figures::spj_cell(kb * 1024, &scenarios[0].1, 5).expect("cell").0;
        let mut row = vec![format!("{kb} KB")];
        let mut vals = Vec::new();
        for (_name, plan) in &scenarios {
            let (total, _) = figures::spj_cell(kb * 1024, plan, 5).expect("cell");
            let norm = total / cpu_total;
            vals.push(norm);
            row.push(format!("{norm:.2}"));
        }
        rows.push(row);
        table.push(vals);
    }
    let header: Vec<&str> = std::iter::once("batch size")
        .chain(scenarios.iter().map(|(n, _)| *n))
        .collect();
    print_table("Fig.5 — execution time normalized to all-CPU", &header, &rows);

    // Shape assertions (scenario order: all-CPU, all-GPU, filter-CPU,
    // project-CPU).
    let small = &table[0]; // 2 KB
    assert!(
        small[1] > 1.0,
        "small data: all-GPU must lose to all-CPU (got {:.2})",
        small[1]
    );
    let large = table.last().unwrap(); // 8 MB
    assert!(
        large[1] < 1.0,
        "large data: all-GPU must beat all-CPU (got {:.2})",
        large[1]
    );
    // CPU affinity drops as size grows: the all-GPU ratio must decrease
    // monotonically-ish across the sweep.
    let first_gpu = table[0][1];
    let last_gpu = table.last().unwrap()[1];
    assert!(last_gpu < first_gpu * 0.5, "GPU ratio must fall steeply");
    // Somewhere in the middle band a mixed mapping beats all-GPU.
    let mixed_wins = table.iter().any(|v| v[2] < v[1] || v[3] < v[1]);
    assert!(mixed_wins, "mixed mapping must win somewhere in the band");
    println!("fig5 OK");
}
