//! The session — the top of the query stack.
//!
//! A [`Session`] owns everything the LMStream coordinator shares across
//! queries: the calibrated [`DeviceModel`], the asynchronous
//! [`OnlineOptimizer`] (and the inflection point it maintains), the PJRT
//! [`Runtime`] handle, the [`Config`], and per-query learned
//! [`SizeEstimator`]s. Queries are *registered* —
//! [`Session::register`] attaches a workload (query + source),
//! [`Session::register_shared`] attaches an additional query to an
//! already-registered source — and [`Session::run`] drives them all
//! through one micro-batch loop (Fig. 3's execution flow, generalized to
//! concurrent queries):
//!
//! * **shared admission** — per source, `ConstructMicroBatch` (Alg. 1)
//!   admits against the *tightest* latency bound across that source's
//!   queries, so a sliding-window query co-registered with a tumbling
//!   one keeps the batch latency-bounded for both;
//! * **session-wide scheduling rounds** — everything admitted in one
//!   loop iteration, across *all* sources, forms one round: a single
//!   [`crate::coordinator::schedule::plan_joint`] call plans every
//!   staged query against the session's
//!   [`DeviceTopology`](crate::cluster::DeviceTopology) (one simulated
//!   GPU timeline per executor; single-node is the 1-executor special
//!   case), rationing the devices by benefit-per-GPU-second and picking
//!   the round's grant order (shortest-GPU-segment-first where that
//!   beats FIFO) — because concurrent idle-GPU `MapDevice` plans would
//!   double-book the devices (single-query rounds keep the plain Alg. 2
//!   path; `Config::co_schedule = false` ablates back to independent
//!   plans);
//! * **per-executor GPU timelines** — execution charges every query's
//!   simulated GPU ops against one FIFO
//!   [`GpuTimeline`](crate::query::exec::GpuTimeline) per executor of
//!   the topology, walking the round in the scheduler's grant order, so
//!   a round advances the clock by the *contended makespan* across its
//!   queries and sources, not per-query (or per-source) fictions; the
//!   contended latencies are what metrics, Eq. 6 admission and the
//!   Eq. 10 optimizer then learn from, and each record carries the
//!   `round` it was co-scheduled in;
//! * **per-query windows, estimators, metrics, sinks** — each query
//!   keeps its own window state, [`SizeEstimator`], metrics, and
//!   (optionally) registered sinks: [`Session::set_sink`] routes a
//!   query's primary results, [`Session::set_branch_sink`] routes one
//!   of its DAG's branch sinks ([`ExecOutcome::branch_results`] /
//!   `ClusterOutcome::branch_results`), instead of dropping all but the
//!   primary output;
//! * **shared optimization** — one online regression (Eq. 10) fits the
//!   inflection point from the primary query's history.
//!
//! One iteration: poll every source → admission (or the baseline's
//! static trigger) → **one round** over everything admitted: collect
//! the async optimizer's latest inflection point → window upkeep +
//! joint (or per-query) planning → execution on the per-executor
//! timelines in the scheduler's grant order → one clock advance by the
//! round makespan → metrics update → sink routing → per-source
//! optimizer/checkpoint upkeep. Identical code drives the simulated
//! clock (paper-scale experiments) and the wall clock (real PJRT runs).
//!
//! The free functions in [`crate::coordinator::driver`] remain as thin
//! single-query shims over this type.
//!
//! [`ExecOutcome::branch_results`]: crate::query::exec::ExecOutcome::branch_results

use crate::cluster;
use crate::config::{Config, ExecBackend, LatePolicy, Mode};
use crate::coordinator::admission::{
    min_positive_throughput, Admission, AdmissionDecision,
};
use crate::coordinator::checkpoint::{Checkpoint, CheckpointStore, QueryMetricState};
use crate::coordinator::metrics::{
    BatchRecord, HealthReport, Metrics, PhaseTotals, ShardStats,
};
use crate::coordinator::optimizer::{HistoryPoint, OnlineOptimizer};
use crate::coordinator::planner::{map_device, static_preference_plan, SizeEstimator};
use crate::coordinator::schedule::{self, QueryCandidate};
use crate::coordinator::timeline_bank::TimelineBank;
use crate::devices::model::DeviceModel;
use crate::devices::Device;
use crate::durability::{
    self, RecoveryMode, RecoveryReport, SinkLedger, Wal, WalPosition, WalRecord,
};
use crate::engine::chunked::ChunkedBatch;
use crate::engine::dataset::{Dataset, MicroBatch};
use crate::engine::encode::ChunkStats;
use crate::engine::partition::mean_partition_bytes;
use crate::engine::sink::Sink;
use crate::engine::window::{WindowKind, WindowState};
use crate::error::{Error, Result};
use crate::query::dag::{OpKind, Query};
use crate::query::exec::{self, ExecEnv, ExecOpts, GpuTimeline, OpTrace};
use crate::query::fuse;
use crate::query::physical::PhysicalPlan;
use crate::runtime::client::Runtime;
use crate::sim::{Clock, SimClock, Time, WallClock};
use crate::util::exec::par_map;
use crate::util::rng::Rng;
use crate::workloads::Workload;
use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Tumbling-window bootstrap bound before any history exists (§III-C's
/// Eq. 3 is undefined for i < 2; the paper seeds parameters from
/// pre-experiments — three seconds is our seed).
pub(crate) const INITIAL_TUMBLING_BOUND: Duration = Duration::from_secs(3);

/// Optimizer pickup timeout: how long the session will wait on the async
/// regression before planning (bounds Table IV's "Optimization Blocking").
const OPT_PICKUP_TIMEOUT: Duration = Duration::from_millis(20);

/// Handle to a query registered on a [`Session`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueryId(pub(crate) usize);

/// Everything a finished per-query run reports.
#[derive(Debug)]
pub struct RunResult {
    /// Registered query name.
    pub workload: String,
    pub mode: Mode,
    pub batches: Vec<BatchRecord>,
    /// Mean per-dataset end-to-end latency, seconds (Fig. 6 metric).
    pub avg_latency: f64,
    /// Eq. 4 average throughput, bytes/s (Fig. 7 metric).
    pub avg_throughput: f64,
    /// Table IV phase totals.
    pub phases: PhaseTotals,
    /// Per-dataset latencies (distribution analysis).
    pub dataset_latencies: Vec<f64>,
    /// Final inflection point (bytes).
    pub final_inf_pt: f64,
}

impl RunResult {
    /// Mean processing-phase time per micro-batch (Fig. 10 metric), s.
    pub fn avg_proc(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches.iter().map(|b| b.proc.as_secs_f64()).sum::<f64>()
            / self.batches.len() as f64
    }

    /// Mean per-batch max latency, s.
    pub fn avg_max_latency(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches
            .iter()
            .map(|b| b.max_latency.as_secs_f64())
            .sum::<f64>()
            / self.batches.len() as f64
    }
}

/// One registered query: its (rewritten) logical plan plus the per-query
/// state the session keeps across runs.
struct QueryDef {
    name: String,
    source: usize,
    /// The optimizer-rewritten logical DAG the planner/executor use.
    query: Query,
    has_join: bool,
    size_est: SizeEstimator,
    /// Owned primary sink ([`Session::set_sink`]).
    sink: Option<Box<dyn Sink>>,
    /// Owned branch sinks keyed by sink op id
    /// ([`Session::set_branch_sink`]).
    branch_sinks: Vec<(usize, Box<dyn Sink>)>,
}

/// One registered source: the workload whose generator/traffic feed it,
/// and the queries consuming its micro-batches.
struct SourceDef {
    workload: Workload,
    /// Index into `Session::queries` of the source's first-registered
    /// (primary) query — admission throughput estimates, optimizer
    /// history, and checkpoints key off it.
    primary: usize,
    queries: Vec<usize>,
    /// Owned side-output sink for late data
    /// ([`Session::set_late_sink`]); receives one batch per dataset the
    /// watermark classified late when [`Config::late_policy`] is
    /// [`LatePolicy::SideOutput`].
    late_sink: Option<Box<dyn Sink>>,
}

/// A streaming session: shared coordinator state + registered queries.
/// See the module docs for the execution model.
pub struct Session<'rt> {
    cfg: Config,
    model: DeviceModel,
    owned_runtime: Option<Runtime>,
    borrowed_runtime: Option<&'rt Runtime>,
    optimizer: OnlineOptimizer,
    inf_pt: f64,
    sources: Vec<SourceDef>,
    queries: Vec<QueryDef>,
    /// What the last `run`'s startup reconciliation replayed, skipped
    /// and lost (Some only when `Config::wal_dir` is set).
    last_recovery: Option<RecoveryReport>,
    /// Fault-tolerance accounting for the most recent *completed* run
    /// (per-executor counters, retries, recovery wait, degraded rounds).
    last_health: Option<HealthReport>,
    /// Sink-ledger disk writes the most recent run performed (pins the
    /// one-persist-per-round batching; 0 without `Config::wal_dir`).
    last_ledger_persists: usize,
    /// Data-path WAL fsyncs the most recent run performed (pins the
    /// group-commit batching: one commit per admitting source per
    /// round; 0 without `Config::wal_dir`).
    last_wal_fsyncs: usize,
    /// Per-source low-watermark where the most recent run ended
    /// (`None` per source until an event is seen; all-`None` when
    /// event time is off, i.e. `Config::allowed_lateness` unset).
    last_watermarks: Vec<Option<Time>>,
}

impl<'rt> Session<'rt> {
    /// Create a session without a PJRT runtime (Simulated backend, or
    /// Real backend with CPU-only plans).
    pub fn new(cfg: Config) -> Result<Session<'rt>> {
        Self::build(cfg, None, None)
    }

    /// Create a session owning `runtime` (Real backend GPU path).
    pub fn with_runtime(cfg: Config, runtime: Runtime) -> Result<Session<'rt>> {
        Self::build(cfg, Some(runtime), None)
    }

    /// Create a session borrowing an externally-managed runtime (the
    /// driver-shim path).
    pub fn with_runtime_ref(cfg: Config, runtime: Option<&'rt Runtime>) -> Result<Session<'rt>> {
        Self::build(cfg, None, runtime)
    }

    fn build(
        cfg: Config,
        owned: Option<Runtime>,
        borrowed: Option<&'rt Runtime>,
    ) -> Result<Session<'rt>> {
        cfg.validate()?;
        let optimizer = OnlineOptimizer::new(
            cfg.online_optimizer && cfg.mode == Mode::LmStream,
            cfg.history_cap,
            cfg.seed,
        );
        let inf_pt = cfg.initial_inflection_bytes;
        Ok(Session {
            cfg,
            model: DeviceModel::default(),
            owned_runtime: owned,
            borrowed_runtime: borrowed,
            optimizer,
            inf_pt,
            sources: Vec::new(),
            queries: Vec::new(),
            last_recovery: None,
            last_health: None,
            last_ledger_persists: 0,
            last_wal_fsyncs: 0,
            last_watermarks: Vec::new(),
        })
    }

    /// The recovery reconciliation report from the most recent
    /// [`Session::run`] start: per source, what the durability pipeline
    /// replayed from the WAL, skipped (rollback), or lost-with-receipt
    /// (gap). `None` unless [`Config::wal_dir`] is set.
    pub fn recovery_report(&self) -> Option<&RecoveryReport> {
        self.last_recovery.as_ref()
    }

    /// The fault-tolerance report of the most recent completed
    /// [`Session::run`]: per-executor crash/stall/GPU-fault/rejoin
    /// counters and final health states, plus run totals for retried
    /// attempts, charged recovery wait, and degraded rounds. `None`
    /// before the first completed run (or after a run that errored).
    pub fn health_report(&self) -> Option<&HealthReport> {
        self.last_health.as_ref()
    }

    /// How many sink-ledger disk writes the most recent run performed —
    /// one per round with fresh deliveries, not one per delivery
    /// (always 0 without [`Config::wal_dir`]).
    pub fn ledger_persists(&self) -> usize {
        self.last_ledger_persists
    }

    /// How many data-path WAL fsyncs the most recent run performed —
    /// one group commit per admitting source per round, however many
    /// batches that round appended (always 0 without
    /// [`Config::wal_dir`]). Maintenance rewrites (truncation, rolls)
    /// are not counted: this pins the *append path* batching.
    pub fn wal_fsyncs(&self) -> usize {
        self.last_wal_fsyncs
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Registered query count.
    pub fn num_queries(&self) -> usize {
        self.queries.len()
    }

    /// Register a workload: its query plus the source stream feeding it.
    /// The logical plan is rewritten ([`crate::query::optimize`]) and
    /// validated here, once, not per run.
    pub fn register(&mut self, workload: Workload) -> Result<QueryId> {
        let query = Self::prepare(&workload.query)?;
        let source = self.sources.len();
        let qidx = self.queries.len();
        self.queries.push(QueryDef {
            name: workload.name.to_string(),
            source,
            has_join: has_join(&query),
            size_est: SizeEstimator::new(query.len()),
            query,
            sink: None,
            branch_sinks: Vec::new(),
        });
        self.sources.push(SourceDef {
            workload,
            primary: qidx,
            queries: vec![qidx],
            late_sink: None,
        });
        Ok(QueryId(qidx))
    }

    /// Register an additional query on the source of an
    /// already-registered query: both consume every micro-batch the
    /// shared admission controller admits, each through its own plan,
    /// window state and metrics.
    pub fn register_shared(
        &mut self,
        share_source_with: QueryId,
        name: &str,
        query: Query,
    ) -> Result<QueryId> {
        let source = self
            .queries
            .get(share_source_with.0)
            .ok_or_else(|| {
                Error::Plan(format!("unknown query id {}", share_source_with.0))
            })?
            .source;
        let query = Self::prepare(&query)?;
        let qidx = self.queries.len();
        self.queries.push(QueryDef {
            name: name.to_string(),
            source,
            has_join: has_join(&query),
            size_est: SizeEstimator::new(query.len()),
            query,
            sink: None,
            branch_sinks: Vec::new(),
        });
        self.sources[source].queries.push(qidx);
        Ok(QueryId(qidx))
    }

    /// Register an owned sink receiving `query`'s primary results on
    /// every [`Session::run`] (in addition to any `run_with_sink`
    /// delivery). Replaces a previously set sink; take it back with
    /// [`Session::take_sink`].
    pub fn set_sink(&mut self, query: QueryId, sink: Box<dyn Sink>) -> Result<()> {
        let q = self.query_mut(query)?;
        q.sink = Some(sink);
        Ok(())
    }

    /// Register an owned sink for one of `query`'s *branch* sinks: the
    /// DAG node `branch_op` must be a sink (no consumers) other than
    /// the primary (highest-id) one. Its per-batch output —
    /// `ExecOutcome::branch_results` / `ClusterOutcome::branch_results`,
    /// previously dropped — is delivered there every run.
    pub fn set_branch_sink(
        &mut self,
        query: QueryId,
        branch_op: usize,
        sink: Box<dyn Sink>,
    ) -> Result<()> {
        let q = self.query_mut(query)?;
        let sinks = q.query.sinks();
        let primary = *sinks.last().expect("validated query has a sink");
        if branch_op == primary {
            return Err(Error::Plan(format!(
                "op {branch_op} is the primary sink — use set_sink for it"
            )));
        }
        if !sinks.contains(&branch_op) {
            return Err(Error::Plan(format!(
                "op {branch_op} is not a sink of query `{}` (sinks: {sinks:?})",
                q.name
            )));
        }
        match q.branch_sinks.iter_mut().find(|(id, _)| *id == branch_op) {
            Some(slot) => slot.1 = sink,
            None => q.branch_sinks.push((branch_op, sink)),
        }
        Ok(())
    }

    /// Remove and return `query`'s registered primary sink, if any.
    pub fn take_sink(&mut self, query: QueryId) -> Option<Box<dyn Sink>> {
        self.queries.get_mut(query.0).and_then(|q| q.sink.take())
    }

    /// Remove and return the sink registered for `query`'s branch
    /// `branch_op`, if any.
    pub fn take_branch_sink(
        &mut self,
        query: QueryId,
        branch_op: usize,
    ) -> Option<Box<dyn Sink>> {
        let q = self.queries.get_mut(query.0)?;
        let pos = q.branch_sinks.iter().position(|(id, _)| *id == branch_op)?;
        Some(q.branch_sinks.remove(pos).1)
    }

    /// Register an owned side-output sink for late data on the *source*
    /// feeding `query` (late classification is per source, so queries
    /// sharing a source share the side output). Effective only when
    /// [`Config::allowed_lateness`] is set and [`Config::late_policy`]
    /// is [`LatePolicy::SideOutput`]; each dataset behind the watermark
    /// is delivered as its own batch, in arrival order. The side output
    /// is a diagnostic tap: late rows are routed *before* the WAL, so
    /// they are not covered by exactly-once replay.
    pub fn set_late_sink(&mut self, query: QueryId, sink: Box<dyn Sink>) -> Result<()> {
        let source = self.query_mut(query)?.source;
        self.sources[source].late_sink = Some(sink);
        Ok(())
    }

    /// Remove and return the late-data sink on `query`'s source, if any.
    pub fn take_late_sink(&mut self, query: QueryId) -> Option<Box<dyn Sink>> {
        let source = self.queries.get(query.0)?.source;
        self.sources[source].late_sink.take()
    }

    /// Per-source low-watermarks (`max event time seen − allowed
    /// lateness`) where the most recent [`Session::run`] ended, in
    /// source registration order. A source's entry is `None` until it
    /// has seen an event; every entry is `None` when event-time mode is
    /// off ([`Config::allowed_lateness`] unset) or before the first run.
    pub fn watermarks(&self) -> &[Option<Time>] {
        &self.last_watermarks
    }

    fn query_mut(&mut self, query: QueryId) -> Result<&mut QueryDef> {
        let n = self.queries.len();
        self.queries.get_mut(query.0).ok_or_else(|| {
            Error::Plan(format!(
                "unknown query id {} (session has {n} registered queries)",
                query.0
            ))
        })
    }

    /// Logical rewrites + validation (register-time, not per-run).
    fn prepare(query: &Query) -> Result<Query> {
        let optimized = crate::query::optimize::optimize(query);
        optimized.validate()?;
        Ok(optimized)
    }

    fn runtime(&self) -> Option<&Runtime> {
        match self.borrowed_runtime {
            Some(r) => Some(r),
            None => self.owned_runtime.as_ref(),
        }
    }

    /// Run every registered query for `duration` (simulated or wall
    /// time); returns one [`RunResult`] per query, in registration
    /// order. Learned state (size estimators, optimizer history, the
    /// inflection point) persists across calls; streams, windows and
    /// metrics start fresh.
    pub fn run(&mut self, duration: Duration) -> Result<Vec<RunResult>> {
        self.run_delivering(duration, &mut |_, _, _, _| Ok(()))
    }

    /// [`Session::run`] delivering one query's results to `sink`.
    pub fn run_with_sink(
        &mut self,
        duration: Duration,
        query: QueryId,
        sink: &mut dyn Sink,
    ) -> Result<Vec<RunResult>> {
        if query.0 >= self.queries.len() {
            return Err(Error::Plan(format!(
                "unknown query id {} (session has {} registered queries)",
                query.0,
                self.queries.len()
            )));
        }
        self.run_delivering(duration, &mut |qidx, batch_idx, result, at| {
            if qidx == query.0 {
                sink.deliver(batch_idx, result, at)?;
            }
            Ok(())
        })
    }

    fn run_delivering(
        &mut self,
        duration: Duration,
        deliver: &mut dyn FnMut(usize, usize, &ChunkedBatch, Time) -> Result<()>,
    ) -> Result<Vec<RunResult>> {
        if self.queries.is_empty() {
            return Err(Error::Plan("no queries registered on this session".into()));
        }
        let clock: Box<dyn Clock> = match self.cfg.backend {
            ExecBackend::Simulated => Box::new(SimClock::new()),
            ExecBackend::Real => Box::new(WallClock::new()),
        };
        self.run_with_clock(duration, clock.as_ref(), deliver)
    }

    fn run_with_clock(
        &mut self,
        duration: Duration,
        clock: &dyn Clock,
        deliver: &mut dyn FnMut(usize, usize, &ChunkedBatch, Time) -> Result<()>,
    ) -> Result<Vec<RunResult>> {
        let cfg = self.cfg.clone();
        let runtime = match self.borrowed_runtime {
            Some(r) => Some(r),
            None => self.owned_runtime.as_ref(),
        };

        // §III-E checkpoint/state-flush substrate (keyed per source by
        // its primary query's name).
        let ckpt_store = match &cfg.checkpoint_dir {
            Some(dir) => Some(CheckpointStore::new(Path::new(dir))?),
            None => None,
        };

        // Durability pipeline (per-source WAL + exactly-once sink
        // ledger + recovery reconciliation) — active only when
        // `wal_dir` is set; without it the run is byte-identical to the
        // pre-durability engine.
        let wal_dir = cfg.wal_dir.as_ref().map(PathBuf::from);
        // Sharded runs keep one ledger *per source* (each shard's round
        // loop delivers independently; a shared file would serialize
        // them on one durable write). The legacy single-file layout is
        // preserved byte-for-byte when sharding is off.
        let mut ledgers: Ledgers = match (&wal_dir, cfg.shards) {
            (Some(dir), None) => {
                Ledgers::Shared(SinkLedger::open(&dir.join("sink.ledger.json"))?)
            }
            (Some(_), Some(_)) => Ledgers::PerSource(Vec::new()),
            (None, _) => Ledgers::Off,
        };
        self.last_recovery = None;
        self.last_health = None;
        self.last_ledger_persists = 0;
        self.last_wal_fsyncs = 0;

        // ---- Per-query run state (metrics first: checkpoint recovery
        // below seeds them).
        let num_queries = self.queries.len();
        let mut windows: Vec<WindowState> =
            (0..num_queries).map(|_| WindowState::new()).collect();
        let mut metrics: Vec<Metrics> = (0..num_queries).map(|_| Metrics::new()).collect();

        // ---- Per-source run state.
        let num_sources = self.sources.len();
        let mut streams = Vec::with_capacity(num_sources);
        let mut admissions = Vec::with_capacity(num_sources);
        // Shared coordinator state (inflection point, optimizer history)
        // is snapshotted identically into every source's checkpoint —
        // restore it from the first checkpoint found only, so resume is
        // independent of registration order and history isn't
        // re-recorded once per source. Stream fast-forward and per-query
        // metric recovery stay per source.
        let mut shared_state_restored = false;
        // Monotone scheduling-round counter — records sharing a `round`
        // were co-scheduled on the same device timelines. Resumes from
        // the checkpoint's round high-water so WAL-logged rounds stay
        // unique across incarnations.
        let mut round: usize = 0;
        // Per-source WAL handles, the highest fully-processed WAL seq
        // per source (what the next checkpoint may truncate through),
        // and the replay rounds recovery reconstructed (keyed by their
        // original round number so co-admitted batches re-execute as
        // one round again).
        let mut wals: Option<Vec<Wal>> = wal_dir.as_ref().map(|_| Vec::new());
        let mut wal_high: Vec<u64> = vec![0; num_sources];
        let mut replay_by_round: BTreeMap<usize, Vec<(usize, WalRecord)>> = BTreeMap::new();
        let mut recoveries: Vec<durability::SourceRecovery> = Vec::new();
        // Sharded runs fork one seed per source off the session seed (in
        // registration order, so the derivation is shard-count
        // invariant): concurrent source groups carry *distinct* data
        // streams. Legacy runs keep the shared seed byte-for-byte.
        let mut source_seeds = cfg.shards.map(|_| Rng::new(cfg.seed));
        for (s, src) in self.sources.iter().enumerate() {
            let stream_seed = match source_seeds.as_mut() {
                Some(master) => master.fork().next_u64(),
                None => cfg.seed,
            };
            let mut stream = src.workload.make_stream(stream_seed);
            let primary_window = self.queries[src.primary].query.window;
            admissions.push(Admission::new(primary_window, INITIAL_TUMBLING_BOUND));
            let mut ckpt = None;
            if let Some(st) = &ckpt_store {
                ckpt = st.load(&self.queries[src.primary].name)?;
            }
            if let Some(ckpt) = &ckpt {
                if !shared_state_restored {
                    self.inf_pt = ckpt.inf_pt.max(1.0);
                    for h in &ckpt.history {
                        self.optimizer.record(*h, INITIAL_TUMBLING_BOUND);
                    }
                    shared_state_restored = true;
                }
                round = round.max(ckpt.round_high_water);
                // Metric recovery for *every* query on the source
                // (checkpoints are keyed by the primary query's name
                // but carry per-query states, so secondary-query
                // metrics survive too; pre-`queries` checkpoints
                // fall back to the legacy primary-only fields).
                for &qi in &src.queries {
                    let name = &self.queries[qi].name;
                    if let Some(qs) = ckpt
                        .queries
                        .iter()
                        .find(|q| q.name.eq_ignore_ascii_case(name))
                    {
                        metrics[qi].restore(
                            qs.batches,
                            qs.cumulative_bytes,
                            qs.cumulative_proc_secs,
                            qs.max_lat_sum_secs,
                        );
                    } else if qi == src.primary {
                        metrics[qi].restore(
                            ckpt.batches,
                            ckpt.cumulative_bytes,
                            ckpt.cumulative_proc_secs,
                            ckpt.max_lat_sum_secs,
                        );
                    }
                }
            }
            match (&wal_dir, wals.as_mut()) {
                (Some(dir), Some(ws)) => {
                    // Reconcile checkpoint ⨯ WAL ⨯ ledger under the
                    // configured recovery mode. The stream fast-forwards
                    // to the *recovery* horizon (checkpoint ∪ newest
                    // logged data): logged batches must never regenerate
                    // from the live stream — replayed they would
                    // duplicate, lost (gap) they are lost.
                    let name = self.queries[src.primary].name.clone();
                    let (wal, scan) =
                        Wal::open(&dir.join(format!("{}.wal", name.to_lowercase())))?;
                    let pos = ckpt.as_ref().map(|c| WalPosition {
                        wal_high_water: c.wal_high_water,
                        processed_up_to: c.processed_up_to,
                    });
                    let bases: Vec<(String, usize)> = src
                        .queries
                        .iter()
                        .map(|&qi| (self.queries[qi].name.clone(), metrics[qi].batches()))
                        .collect();
                    // Sharded: open (and reconcile against) this
                    // source's own ledger file, keyed like its WAL.
                    if let Ledgers::PerSource(v) = &mut ledgers {
                        v.push(SinkLedger::open(
                            &dir.join(format!("{}.sink.ledger.json", name.to_lowercase())),
                        )?);
                    }
                    let rec = durability::reconcile(
                        &name,
                        pos,
                        scan,
                        ledgers.for_source(s).expect("wal_dir implies a ledger"),
                        cfg.recovery_mode,
                        &bases,
                    )?;
                    stream.fast_forward(rec.horizon);
                    // Rollback/Gap: bump each query's batch-index base
                    // so live indices line up with the ledger (skipped
                    // and lost batches still consume an index).
                    for (&qi, (_, base)) in src.queries.iter().zip(&rec.batch_base) {
                        if *base > metrics[qi].batches() {
                            let (by, pr, ml) = (
                                metrics[qi].cumulative_bytes(),
                                metrics[qi].cumulative_proc_secs(),
                                metrics[qi].max_lat_sum_secs(),
                            );
                            metrics[qi].restore(*base, by, pr, ml);
                        }
                    }
                    wal_high[s] = rec.checkpointed_through;
                    for r in &rec.replay {
                        replay_by_round.entry(r.round).or_default().push((s, r.clone()));
                    }
                    recoveries.push(rec);
                    ws.push(wal);
                }
                _ => {
                    if let Some(ckpt) = &ckpt {
                        stream.fast_forward(ckpt.processed_up_to);
                    }
                }
            }
            streams.push(stream);
        }
        let mut replay_rounds: VecDeque<(usize, Vec<(usize, WalRecord)>)> =
            replay_by_round.into_iter().collect();
        if !recoveries.is_empty() {
            let report = RecoveryReport { sources: recoveries };
            if let Some(dir) = &wal_dir {
                std::fs::write(
                    dir.join("recovery_report.json"),
                    report.to_json().render(),
                )?;
            }
            self.last_recovery = Some(report);
        }
        let mut next_trigger: Vec<Time> =
            vec![Time::ZERO.add(cfg.trigger); num_sources];
        let mut construct_acc: Vec<Duration> = vec![Duration::ZERO; num_sources];

        // ---- Event-time state (active only when `allowed_lateness` is
        // set; `None` keeps arrival-time semantics byte-for-byte). The
        // per-source low-watermark is `max event time seen − allowed
        // lateness`: it classifies late arrivals at poll time, drives
        // window eviction in staging, and force-admits buffered data
        // when it crosses a window-close boundary.
        let mut max_event: Vec<Option<Time>> = vec![None; num_sources];
        let mut late_rows_pending: Vec<usize> = vec![0; num_sources];
        let mut late_delivered: Vec<usize> = vec![0; num_sources];
        // Window-close cadence per source: the earliest close period
        // across its queries — the slide for sliding windows, the range
        // for tumbling ones.
        let close_period: Vec<Duration> = self
            .sources
            .iter()
            .map(|src| {
                src.queries
                    .iter()
                    .map(|&qi| {
                        let w = &self.queries[qi].query.window;
                        match w.kind() {
                            WindowKind::Sliding => w.slide,
                            WindowKind::Tumbling => w.range,
                        }
                    })
                    .min()
                    .expect("source has >=1 query")
            })
            .collect();
        let mut next_close: Vec<Time> =
            close_period.iter().map(|&p| Time::ZERO.add(p)).collect();

        // The full (fault-free) device topology: per-executor GPUs on a
        // cluster, the 1-executor special case on a single node. Each
        // round plans and executes against the *surviving* view the
        // health detector derives from it — with no fault plan the two
        // are identical.
        let base_topo = cfg.topology();
        let mut health = cluster::ExecutorHealth::new(
            base_topo.num_executors(),
            cfg.fault_plan.clone().unwrap_or_default(),
            cfg.probation_rounds,
        );
        let mut total_retries = 0usize;
        let mut total_recovery_wait = Duration::ZERO;
        let mut degraded_rounds = 0usize;

        // ---- Sharded-runtime state (`Config::shards`). The timeline
        // bank arbitrates the *physical* per-executor GPU timelines
        // across the concurrent source groups: every source books a
        // reservation lease (in global source order) before its shard
        // executes, so cross-shard GPU contention is priced into the
        // offsets and never double-booked. Quotas are per-shard deficit
        // token buckets over admitted wire bytes (burst = one second of
        // rate); a veto returns the batch to the admission buffer.
        let shard_count = cfg.shards.unwrap_or(1);
        let mut bank = cfg.shards.map(|_| TimelineBank::new(base_topo.num_executors()));
        let mut quota_tokens: Vec<f64> =
            cfg.shard_quotas.clone().unwrap_or_default();
        let mut quota_last = Time::ZERO;
        let mut quota_vetoes: Vec<usize> = vec![0; shard_count];
        let mut shard_stats: Vec<ShardStats> = match cfg.shards {
            Some(k) => (0..k)
                .map(|sh| ShardStats {
                    shard: sh,
                    sources: (0..num_sources)
                        .filter(|&s| cluster::shard_of(s, k) == sh)
                        .count(),
                    ..ShardStats::default()
                })
                .collect(),
            None => Vec::new(),
        };

        let end = Time::ZERO.add(duration);

        while clock.now() < end {
            // ---- Buffering phase: recovery replay first (batches come
            // from the WAL — already admitted, durably, by a previous
            // incarnation), then trigger (baseline) or admission
            // (LMStream), per source.
            let mut admitted: Vec<(usize, MicroBatch)> = Vec::new();
            let mut replay_seqs: Option<Vec<Option<u64>>> = None;
            if let Some((orig_round, group)) = replay_rounds.pop_front() {
                // Keep the round counter monotone across incarnations
                // while preserving the original co-scheduling grouping
                // (the `round += 1` below lands at >= orig_round).
                round = round.max(orig_round.saturating_sub(1));
                let mut seqs = Vec::with_capacity(group.len());
                for (s, r) in group {
                    seqs.push(Some(r.seq));
                    admitted.push((s, r.batch));
                }
                replay_seqs = Some(seqs);
            } else if cfg.mode.uses_trigger() {
                let wake = next_trigger.iter().min().copied().expect(">=1 source");
                clock.sleep_until(wake);
                if clock.now() >= end {
                    break;
                }
                for s in 0..num_sources {
                    if next_trigger[s] > clock.now() {
                        continue;
                    }
                    let mut data = streams[s].poll(clock.now());
                    if let Some(lateness) = cfg.allowed_lateness {
                        data = apply_late_policy(
                            data,
                            cfg.late_policy,
                            lateness,
                            &mut max_event[s],
                            &mut late_rows_pending[s],
                            &mut self.sources[s].late_sink,
                            &mut late_delivered[s],
                            clock.now(),
                        )?;
                    }
                    next_trigger[s] = next_trigger[s].add(cfg.trigger);
                    if !data.is_empty() {
                        admitted.push((s, MicroBatch::new(data)));
                    }
                }
            } else {
                let deadline = clock.now().add(cfg.poll_interval);
                clock.sleep_until(deadline);
                if clock.now() >= end {
                    break;
                }
                // Per-shard admission quotas: refill each shard's token
                // bucket by the simulated time elapsed since the last
                // poll, capped at one second of burst.
                if let Some(rates) = &cfg.shard_quotas {
                    let dt = clock.now().saturating_sub(quota_last).as_secs_f64();
                    for (sh, tokens) in quota_tokens.iter_mut().enumerate() {
                        *tokens = (*tokens + rates[sh] * dt).min(rates[sh]);
                    }
                    quota_last = clock.now();
                }
                for s in 0..num_sources {
                    let t0 = Instant::now();
                    let mut data = streams[s].poll(clock.now());
                    // Event time: classify against the source watermark
                    // and apply the late policy *before* admission, so
                    // routed-away rows never reach the WAL (replay stays
                    // consistent) or the Eq. 6 estimate.
                    if let Some(lateness) = cfg.allowed_lateness {
                        data = apply_late_policy(
                            data,
                            cfg.late_policy,
                            lateness,
                            &mut max_event[s],
                            &mut late_rows_pending[s],
                            &mut self.sources[s].late_sink,
                            &mut late_delivered[s],
                            clock.now(),
                        )?;
                    }
                    // Eq. 6's AvgThPut over a multi-query source: the
                    // *minimum* observed throughput across its queries
                    // (the slowest query dominates the batch's real
                    // processing time), not the primary's alone — the
                    // estimate stays conservative, so admission is at
                    // least as eager for every co-registered query.
                    let thput = min_positive_throughput(
                        self.sources[s]
                            .queries
                            .iter()
                            .map(|&qi| metrics[qi].avg_throughput()),
                        cfg.initial_throughput,
                    );
                    // Shared admission: the tightest bound across the
                    // source's queries keeps every query's latency
                    // target honored.
                    let bound = self.sources[s]
                        .queries
                        .iter()
                        .map(|&qi| query_bound(&self.queries[qi].query, &metrics[qi]))
                        .min()
                        .expect("source has >=1 query");
                    let decision = admissions[s].construct_with_bound(
                        data,
                        clock.now(),
                        thput,
                        bound,
                    );
                    construct_acc[s] += t0.elapsed();
                    match decision {
                        AdmissionDecision::Poll | AdmissionDecision::Buffer { .. } => {}
                        AdmissionDecision::Admit(mb) => match &cfg.shard_quotas {
                            // Deficit bucket: a shard in debt has its
                            // admission vetoed — the batch goes back
                            // into the buffer (never dropped; Alg. 1
                            // re-offers it next poll) and the WAL, which
                            // runs after this phase, never sees it. An
                            // in-credit shard admits even if the batch
                            // overdraws (at most one burst of debt).
                            Some(_) => {
                                let sh = cluster::shard_of(s, shard_count);
                                if quota_tokens[sh] < 0.0 {
                                    quota_vetoes[sh] += 1;
                                    admissions[s].restore(mb);
                                } else {
                                    quota_tokens[sh] -= mb.wire_bytes() as f64;
                                    admitted.push((s, mb));
                                }
                            }
                            None => admitted.push((s, mb)),
                        },
                    }
                    // Event time: when the watermark crosses a
                    // window-close boundary, the window the buffered
                    // data belongs to is complete in event time —
                    // force-admit past the Eq. 6 estimate (the window
                    // term of the admission rule follows watermark
                    // progress, not the wall clock).
                    if let (Some(lateness), Some(m)) =
                        (cfg.allowed_lateness, max_event[s])
                    {
                        let wm = Time(m.0.saturating_sub(lateness.as_nanos() as u64));
                        if wm >= next_close[s] {
                            if admissions[s].buffered_datasets() > 0 {
                                admitted.push((s, admissions[s].take_buffered()));
                            }
                            while next_close[s] <= wm {
                                next_close[s] = next_close[s].add(close_period[s]);
                            }
                        }
                    }
                }
            }

            if admitted.is_empty() {
                continue;
            }
            // ================= One session-wide scheduling round =====
            // Everything admitted in this loop iteration — across *all*
            // sources — stages, plans, and executes together against one
            // set of per-executor device timelines, and the clock
            // advances once by the round's contended makespan.
            round += 1;
            // Fire this round's scheduled faults (and expire probation)
            // before anything plans: crashes/stalls arm a failed first
            // attempt, GPU faults degrade the executor in place.
            health.begin_round(round);
            let admitted_at = clock.now();
            // The round's shared phase costs (the joint planning pass,
            // the optimizer pickup) are charged once, to the first
            // admitted source's primary query; per-source construct work
            // stays with each source's own primary.
            let lead_primary = self.sources[admitted[0].0].primary;

            // ---- Write-ahead log: every live admitted micro-batch is
            // appended and fsynced *before* execution, so a crash
            // anywhere past this point replays deterministically from
            // the log. Replayed rounds are already in it and keep their
            // original sequence numbers.
            let admitted_seqs: Vec<Option<u64>> = match (replay_seqs, wals.as_mut()) {
                (Some(seqs), _) => seqs,
                (None, Some(ws)) => {
                    // Group commit: frame every admitted batch first,
                    // then one fsync per distinct source — the round's
                    // append-before-execute ordering is preserved
                    // (every commit lands before planning starts), the
                    // sync count per source per round drops to one.
                    let mut seqs = Vec::with_capacity(admitted.len());
                    for &(s, ref batch) in &admitted {
                        seqs.push(Some(ws[s].append_deferred(round, batch)?));
                    }
                    let mut synced: Vec<usize> = Vec::new();
                    for &(s, _) in &admitted {
                        if !synced.contains(&s) {
                            ws[s].commit()?;
                            synced.push(s);
                        }
                    }
                    seqs
                }
                (None, None) => vec![None; admitted.len()],
            };

            // ---- WAL growth guard. Without checkpoints the log never
            // truncates (the ROADMAP's unbounded-growth caveat): at the
            // configured cap, Gap mode *rolls* the log (oldest frames
            // dropped — the next recovery accounts them as loss), the
            // precise modes surface a typed error rather than silently
            // weakening their replay contract or filling the disk.
            if let (Some(cap), Some(ws)) = (cfg.wal_max_bytes, wals.as_mut()) {
                for &(s, _) in &admitted {
                    if ws[s].size_bytes() > cap {
                        if cfg.recovery_mode == RecoveryMode::Gap {
                            ws[s].roll_to_cap(cap)?;
                        } else {
                            let name = &self.queries[self.sources[s].primary].name;
                            return Err(Error::Durability(format!(
                                "wal for source `{name}` is {} bytes, over \
                                 wal_max_bytes={cap}: enable checkpointing (the \
                                 log truncates) or Gap recovery (the log rolls)",
                                ws[s].size_bytes()
                            )));
                        }
                    }
                }
            }

            // ---- Optimizer pickup (must land before planning).
            let (new_inf, opt_blocking) = if cfg.mode == Mode::LmStream {
                self.optimizer.take(self.inf_pt, OPT_PICKUP_TIMEOUT)
            } else {
                (self.inf_pt, Duration::ZERO)
            };
            self.inf_pt = new_inf;

            // ---- Window upkeep + execution input assembly, per query
            // of every admitted source (before planning: the joint
            // scheduler needs every staged query's input sizes at
            // once). The snapshot is a chunk list — one shared chunk
            // per in-window dataset (O(#datasets) Arc bumps, zero row
            // copies, no copy-on-write even while a sink retains an old
            // snapshot — see engine::window).
            let mut staged: Vec<Staged> = Vec::new();
            for &(s, ref batch) in &admitted {
                // Watermark upkeep for paths that bypass the poll-time
                // classification (WAL replay): the admitted batch still
                // advances the source's max event.
                if cfg.allowed_lateness.is_some() {
                    if let Some(newest) = batch.newest_event_time() {
                        if max_event[s].is_none_or(|m| newest > m) {
                            max_event[s] = Some(newest);
                        }
                    }
                }
                for &qi in &self.sources[s].queries {
                    let qdef = &self.queries[qi];
                    let query = &qdef.query;
                    match cfg.allowed_lateness {
                        // Event time: the low-watermark — not arrival
                        // progress — closes windows, so data within the
                        // allowed lateness can still land in its window.
                        Some(lateness) => {
                            if let Some(m) = max_event[s] {
                                let wm = Time(
                                    m.0.saturating_sub(lateness.as_nanos() as u64),
                                );
                                windows[qi].evict(wm, &query.window);
                            }
                        }
                        None => {
                            if let Some(newest) = batch.newest_event_time() {
                                windows[qi].evict(newest, &query.window);
                            }
                        }
                    }
                    let (input, snapshot, stats): (
                        ChunkedBatch,
                        Option<ChunkedBatch>,
                        Vec<Option<ChunkStats>>,
                    ) =
                        if query.uses_window_state && !qdef.has_join {
                            // Windowed aggregation recomputes over state ∪
                            // new: ingest the new datasets first (O(delta)
                            // chunk appends), then the input *is* the
                            // chunk-list union — the old per-batch concat
                            // (and the CoW copy a retained snapshot used
                            // to force) is gone. The late push below
                            // skips these queries.
                            windows[qi].push(&batch.datasets);
                            let snap = windows[qi].snapshot_chunks()?;
                            // Encode-time stats ride along only when the
                            // snapshot is the execution input — a fused
                            // aggregate tail then prunes/min-maxes off
                            // the encoded blocks instead of rescanning.
                            let stats = match &snap {
                                Some(_) => windows[qi].snapshot_chunk_stats(),
                                None => Vec::new(),
                            };
                            let input = match &snap {
                                Some(st) => st.clone(),
                                None => batch.chunked()?,
                            };
                            (input, snap, stats)
                        } else {
                            (batch.chunked()?, windows[qi].snapshot_chunks()?, Vec::new())
                        };
                    let aux = if qdef.has_join {
                        snapshot
                            .as_ref()
                            .map(|w| (windows[qi].state_bytes_encoded() as f64, w.num_chunks()))
                    } else {
                        None
                    };
                    staged.push(Staged { s, qi, input, snapshot, aux, stats });
                }
            }

            // ---- Planning + execution, under the round's retry loop.
            // A multi-query LMStream round is planned jointly across
            // *everything* staged — all sources, all executors: the
            // scheduler collects every query's Eq. 7–9 candidate costs
            // (the same SizeEstimator-fed path map_device runs on) and
            // rations the topology's per-executor GPUs by
            // benefit-per-GPU-second, choosing the grant order
            // (shortest-GPU-segment-first where that beats FIFO) the
            // execution below follows — concurrent idle-GPU MapDevice
            // plans would double-book the devices. Single-query rounds,
            // ablations (co_schedule = false) and fixed policies keep
            // per-query plans in staging order.
            //
            // Fault tolerance: every attempt plans and executes against
            // the *surviving* topology the health detector reports —
            // crashed executors excluded, GPU-faulted ones CPU-only. An
            // injected fault fails the attempt with `Error::Executor`;
            // the session transitions health, charges detection plus
            // exponential backoff to the round clock, re-plans on the
            // survivors and retries, up to `Config::max_round_retries`.
            // Staging and the WAL append stay outside the loop (the
            // window pushes above are stateful; the log already holds
            // the round) — attempts re-execute from the staged chunk
            // lists, whose clones are O(#chunks) Arc bumps.
            let mut round_retries = 0usize;
            let mut recovery_wait = Duration::ZERO;
            let (mut pending, mut makespan, map_device_total, degraded) = loop {
                // Sharded rounds (`Config::shards`) take the concurrent
                // per-source-group path instead — one pass (its retry
                // sweeps live inside): ticket-ordered timeline-bank
                // leases, parallel per-shard execution, main-thread
                // failure sweeps. The rest of this loop is the legacy
                // session-wide round, byte-identical when sharding is
                // off.
                if let Some(shards) = cfg.shards {
                    break self.run_sharded_round(
                        &cfg,
                        &staged,
                        &mut health,
                        &base_topo,
                        bank.as_mut().expect("sharded config builds a bank"),
                        shards,
                        round,
                        &mut round_retries,
                        &mut recovery_wait,
                    )?;
                }
                // Faults armed for this attempt (the first attempt of a
                // faulty round only: a crash keeps failing through
                // topology exclusion, not re-injection) and the
                // surviving executors, in physical ids.
                let fail_phys = health.attempt_faults();
                let active = health.active();
                if active.is_empty() {
                    return Err(Error::Executor {
                        executor: fail_phys.first().copied().unwrap_or(0),
                        reason: "no surviving executors to re-plan on".into(),
                    });
                }
                // The degraded view this attempt plans against, and the
                // fault set execution observes, in subset-local indices.
                let mut topo = base_topo.subset(&active);
                for (local, &phys) in active.iter().enumerate() {
                    if !health.gpu_ok(phys) {
                        topo.degrade_gpu(local);
                    }
                }
                let faults = cluster::RoundFaults {
                    fail: fail_phys
                        .iter()
                        .filter_map(|&p| active.iter().position(|&a| a == p))
                        .collect(),
                    cpu_only: (0..active.len())
                        .filter(|&l| !topo.gpu_usable(l))
                        .collect(),
                };
                let degraded_now = health.is_degraded() || !faults.is_clean();
                let run_cluster = cfg.cluster.as_ref().map(|spec| spec.subset(&active));

                let run_attempt = || -> Result<(Vec<Pending>, Duration, Duration)> {
                    let t_plan = Instant::now();
                    let (plans, exec_order): (Vec<PhysicalPlan>, Vec<usize>) = if cfg.mode
                        == Mode::LmStream
                        && cfg.co_schedule
                        && staged.len() > 1
                    {
                        let mut cands: Vec<QueryCandidate> =
                            Vec::with_capacity(staged.len());
                        for st in &staged {
                            let qdef = &self.queries[st.qi];
                            // Part_(i,j): partition share of the data
                            // the processing phase actually touches —
                            // one core of the surviving topology (each
                            // executor's per-core share of its row
                            // split is exactly this).
                            let part = mean_partition_bytes(
                                st.input.alloc_bytes(),
                                topo.total_cores(),
                            );
                            // Join build side priced at its *encoded*
                            // resident footprint (see Staged::aux) —
                            // identical figure to the executor's
                            // ExecOpts::aux below, so Eq. 9 never
                            // diverges between planning and execution.
                            let (aux_bytes, aux_chunks) = st.aux.unwrap_or((0.0, 0));
                            cands.push(
                                QueryCandidate::build(
                                    &qdef.query,
                                    part,
                                    self.inf_pt,
                                    cfg.base_trans_cost,
                                    &qdef.size_est,
                                    st.input.num_chunks(),
                                    aux_bytes,
                                    aux_chunks,
                                )?
                                // Per-executor share layouts: cluster
                                // slicing can shrink a share's chunk
                                // count below the batch's, and the
                                // coalesce estimate must price what
                                // each executor actually assembles.
                                .with_exec_chunks(schedule::share_chunk_counts(
                                    &st.input,
                                    &topo,
                                )),
                            );
                        }
                        let jp = schedule::plan_joint(&cands, &self.model, &topo);
                        let order = jp.predicted.order.clone();
                        (jp.plans, order)
                    } else {
                        let mut plans = Vec::with_capacity(staged.len());
                        for st in &staged {
                            let qdef = &self.queries[st.qi];
                            let query = &qdef.query;
                            let plan = match cfg.mode {
                                Mode::LmStream => {
                                    let part = mean_partition_bytes(
                                        st.input.alloc_bytes(),
                                        topo.total_cores(),
                                    );
                                    map_device(
                                        query,
                                        part,
                                        self.inf_pt,
                                        cfg.base_trans_cost,
                                        &qdef.size_est,
                                        st.input.num_chunks(),
                                    )?
                                }
                                Mode::Baseline | Mode::AllGpu => {
                                    PhysicalPlan::uniform(query, Device::Gpu)
                                }
                                Mode::BaselineCpu | Mode::AllCpu => {
                                    PhysicalPlan::uniform(query, Device::Cpu)
                                }
                                Mode::StaticPreference => static_preference_plan(query),
                            };
                            plans.push(plan);
                        }
                        (plans, (0..staged.len()).collect())
                    };
                    let map_device_total = t_plan.elapsed();

                    // ---- Execution on the attempt's shared device
                    // timelines. Queries run concurrently from round
                    // start (their CPU pipelines are independent Spark
                    // jobs) while all simulated GPU ops of the round
                    // serialize on one GpuTimeline per surviving
                    // executor, in the scheduler's chosen grant order —
                    // so the clock advances by the *contended makespan*
                    // across every admitted source, not per-source
                    // fictions, and each query's proc carries its
                    // observable gpu_wait share.
                    let mut pending: Vec<Pending> = Vec::new();
                    let mut makespan = Duration::ZERO;
                    let mut timelines: Vec<GpuTimeline> =
                        vec![GpuTimeline::new(); topo.num_executors()];
                    for &idx in &exec_order {
                        let st = &staged[idx];
                        let qdef = &self.queries[st.qi];
                        // Processing phase (single executor or
                        // cluster-wide, on the surviving spec).
                        let eq = execute_staged_query(
                            &qdef.query,
                            qdef.has_join,
                            &plans[idx],
                            st,
                            &self.model,
                            &cfg,
                            runtime,
                            run_cluster.as_ref(),
                            &faults,
                            &fail_phys,
                            &mut timelines,
                        )?;
                        makespan = makespan.max(eq.proc);
                        pending.push(Pending {
                            s: st.s,
                            qi: st.qi,
                            result: eq.result,
                            branch_results: eq.branch_results,
                            proc: eq.proc,
                            gpu_wait: eq.gpu_wait,
                            traces: eq.traces,
                            gpu_ops: eq.gpu_ops,
                            total_ops: qdef.query.len(),
                            pruned_chunks: eq.pruned_chunks,
                            retries: 0,
                            recovery_wait: Duration::ZERO,
                            shard: 0,
                        });
                    }
                    Ok((pending, makespan, map_device_total))
                };
                let attempt = run_attempt();

                match attempt {
                    Ok((pending, makespan, map_device_total)) => {
                        break (pending, makespan, map_device_total, degraded_now);
                    }
                    Err(Error::Executor { executor, reason }) => {
                        // Detection: transition the failed executor's
                        // health, then either give up (budget spent) or
                        // charge detection + exponential backoff to the
                        // round and re-plan on the survivors.
                        health.note_attempt_failed();
                        round_retries += 1;
                        if round_retries > cfg.max_round_retries {
                            return Err(Error::Executor {
                                executor,
                                reason: format!(
                                    "{reason}; round {round} exhausted its retry \
                                     budget ({} retries)",
                                    cfg.max_round_retries
                                ),
                            });
                        }
                        recovery_wait += cfg.failure_detection
                            + cfg.retry_backoff * (1u32 << (round_retries - 1).min(16));
                    }
                    Err(e) => return Err(e),
                }
            };
            // The recovery wait (detection + backoff over every failed
            // attempt) is real round latency: charge it to the round's
            // makespan and into each batch's proc, so Eq. 10 and
            // admission learn true degraded-round behavior (the same
            // convention gpu_wait follows). Sharded rounds already
            // folded recovery per source and filled the per-batch retry
            // fields inside `run_sharded_round`.
            if cfg.shards.is_none() {
                if !recovery_wait.is_zero() {
                    for p in &mut pending {
                        p.proc += recovery_wait;
                    }
                    makespan += recovery_wait;
                }
                for p in &mut pending {
                    p.retries = round_retries;
                    p.recovery_wait = recovery_wait;
                }
            }
            total_retries += round_retries;
            total_recovery_wait += recovery_wait;
            if degraded {
                degraded_rounds += 1;
            }

            // The round's construct work: every admitted source spent
            // its accumulated admission time getting here.
            let construct_total: Duration =
                admitted.iter().map(|&(s, _)| construct_acc[s]).sum();
            if cfg.shards.is_some() {
                // Sharded epoch: the clock advances by the max source
                // makespan alone — no wall-measured planning/construct
                // terms — so the sharded timeline is a pure function of
                // the simulated execution (bit-identical across shard
                // counts and repeat runs).
                clock.advance(makespan);
            } else {
                clock.advance(makespan + map_device_total + construct_total + opt_blocking);
            }

            // ---- Metrics (Eqs. 4/5, Table IV) + sinks + learning.
            // Per-source batch context (bytes, dataset count, buffering
            // shares) for the records below.
            let mut src_bytes: Vec<usize> = vec![0; num_sources];
            let mut src_datasets: Vec<usize> = vec![0; num_sources];
            let mut src_buffs: Vec<Vec<Duration>> = vec![Vec::new(); num_sources];
            for &(s, ref batch) in &admitted {
                src_bytes[s] = batch.wire_bytes();
                src_datasets[s] = batch.num_datasets();
                src_buffs[s] = batch
                    .datasets
                    .iter()
                    .map(|d| admitted_at.saturating_sub(d.created_at))
                    .collect();
            }
            // Per-shard fairness accounting (sharded runs only):
            // admission traffic, executed batches, per-source attempts.
            if cfg.shards.is_some() {
                let mut counted_round = vec![false; shard_count];
                for &(s, ref batch) in &admitted {
                    let sh = cluster::shard_of(s, shard_count);
                    if !counted_round[sh] {
                        shard_stats[sh].rounds += 1;
                        counted_round[sh] = true;
                    }
                    shard_stats[sh].bytes += batch.wire_bytes();
                }
                let mut counted_src: Vec<usize> = Vec::new();
                for p in &pending {
                    shard_stats[p.shard].batches += 1;
                    shard_stats[p.shard].proc += p.proc;
                    // Retries are per source, not per query: count each
                    // source's attempts once however many queries it
                    // staged.
                    if !counted_src.contains(&p.s) {
                        counted_src.push(p.s);
                        shard_stats[p.shard].retries += p.retries;
                    }
                }
            }
            for p in pending {
                let batch_index = metrics[p.qi].batches();
                let completed_at = clock.now();
                // Exactly-once gate: on WAL replay the ledger suppresses
                // re-delivery of batch indices the sinks already
                // received (cluster rounds included — per-executor
                // outputs were already reassembled into `p.result`, so
                // one ledger entry covers the whole reassembled batch).
                // Metrics and learning below still record either way:
                // replay rebuilds them identically.
                let fresh = match ledgers.for_source(p.s) {
                    Some(l) => {
                        !l.already_delivered(&self.queries[p.qi].name, batch_index as u64)
                    }
                    None => true,
                };
                if fresh {
                    let mut deliver_all = || -> Result<()> {
                        deliver(p.qi, batch_index, &p.result, completed_at)?;
                        // Owned per-query sinks: primary result plus any
                        // registered branch sinks (ExecOutcome/
                        // ClusterOutcome branch_results — no longer dropped).
                        let qdef = &mut self.queries[p.qi];
                        if let Some(sink) = qdef.sink.as_mut() {
                            sink.deliver(batch_index, &p.result, completed_at)?;
                        }
                        for (op_id, sink) in qdef.branch_sinks.iter_mut() {
                            if let Some((_, b)) =
                                p.branch_results.iter().find(|(id, _)| *id == *op_id)
                            {
                                sink.deliver(batch_index, b, completed_at)?;
                            }
                        }
                        Ok(())
                    };
                    let delivered = deliver_all();
                    if let Err(e) = delivered {
                        // Deliveries that succeeded earlier this round
                        // are made durable before the failure
                        // propagates (see durability::ledger docs).
                        ledgers.persist_all()?;
                        self.last_ledger_persists = ledgers.persists();
                        return Err(e);
                    }
                    // Record the delivery; the durable write happens
                    // once, at the end of the round's delivery loop.
                    if let Some(l) = ledgers.for_source_mut(p.s) {
                        l.record(&self.queries[p.qi].name, round as u64, batch_index as u64);
                    }
                }
                // Shared phase costs are charged once so phase totals
                // never double count: per-source construct work to that
                // source's primary, the round-wide planning pass and
                // optimizer pickup to the round's lead primary.
                let rec = BatchRecord {
                    index: batch_index,
                    round,
                    admitted_at,
                    num_datasets: src_datasets[p.s],
                    bytes: src_bytes[p.s],
                    max_buffering: Duration::ZERO, // filled by record
                    proc: p.proc,
                    gpu_wait: p.gpu_wait,
                    max_latency: Duration::ZERO, // filled by record
                    inf_pt: self.inf_pt,
                    gpu_ops: p.gpu_ops,
                    total_ops: p.total_ops,
                    construct_time: if p.qi == self.sources[p.s].primary {
                        construct_acc[p.s]
                    } else {
                        Duration::ZERO
                    },
                    map_device_time: if p.qi == lead_primary {
                        map_device_total
                    } else {
                        Duration::ZERO
                    },
                    opt_blocking: if p.qi == lead_primary {
                        opt_blocking
                    } else {
                        Duration::ZERO
                    },
                    retries: p.retries,
                    recovery_wait: p.recovery_wait,
                    degraded,
                    // Late rows accumulate per source between rounds and
                    // flush once, to the source's primary query, so
                    // multi-query sources never double count them.
                    late_rows: if p.qi == self.sources[p.s].primary {
                        std::mem::take(&mut late_rows_pending[p.s])
                    } else {
                        0
                    },
                    watermark_lag: match (cfg.allowed_lateness, max_event[p.s]) {
                        (Some(lateness), Some(m)) => admitted_at.saturating_sub(
                            Time(m.0.saturating_sub(lateness.as_nanos() as u64)),
                        ),
                        _ => Duration::ZERO,
                    },
                    // Resident window-state footprint as this round
                    // observed it (join builds still pre-ingest here —
                    // their push lands after delivery, below).
                    state_bytes_raw: windows[p.qi].state_bytes_raw(),
                    state_bytes_encoded: windows[p.qi].state_bytes_encoded(),
                    pruned_chunks: p.pruned_chunks,
                    shard: p.shard,
                };
                metrics[p.qi].record(rec, &src_buffs[p.s]);
                self.queries[p.qi].size_est.observe(&p.traces);
            }
            // One durable ledger write per dirty ledger covers the whole
            // round's deliveries (not one write per delivery; per-source
            // ledgers only write for sources that delivered).
            ledgers.persist_all()?;
            self.last_ledger_persists = ledgers.persists();

            // ---- Per-source learning, window upkeep, checkpointing.
            for (ai, &(s, ref batch)) in admitted.iter().enumerate() {
                construct_acc[s] = Duration::ZERO;
                let primary = self.sources[s].primary;
                // The source's WAL record for this round is now fully
                // processed (executed, delivered, metered): the next
                // checkpoint covers it and may truncate through it.
                if let Some(seq) = admitted_seqs[ai] {
                    wal_high[s] = wal_high[s].max(seq);
                }

                // Async parameter optimization (Eq. 10 inputs), fed from
                // the source's primary query — whose latest record now
                // carries the *round's* contended latency.
                if cfg.mode == Mode::LmStream {
                    let m = &metrics[primary];
                    let last = m.records().last().expect("just recorded");
                    let target = query_bound(&self.queries[primary].query, m);
                    self.optimizer.record(
                        HistoryPoint {
                            throughput: m.avg_throughput(),
                            max_latency: last.max_latency.as_secs_f64(),
                            inf_pt: self.inf_pt,
                        },
                        target,
                    );
                }

                // Window state ingests the processed datasets.
                // (Aggregation-path queries already ingested the batch
                // before snapshotting their execution input, above.)
                for &qi in &self.sources[s].queries {
                    let q = &self.queries[qi];
                    if q.query.uses_window_state && q.has_join {
                        windows[qi].push(&batch.datasets);
                    }
                }

                // §III-E checkpoint / state flush. The file stays keyed
                // by the source's primary query name, but carries one
                // metric state per registered query, so secondary
                // queries recover too.
                if let Some(st) = &ckpt_store {
                    let newest = batch
                        .datasets
                        .iter()
                        .map(|d| d.created_at)
                        .max()
                        .unwrap_or(admitted_at);
                    let m = &metrics[primary];
                    let queries: Vec<QueryMetricState> = self.sources[s]
                        .queries
                        .iter()
                        .map(|&qi| QueryMetricState {
                            name: self.queries[qi].name.clone(),
                            batches: metrics[qi].batches(),
                            cumulative_bytes: metrics[qi].cumulative_bytes(),
                            cumulative_proc_secs: metrics[qi].cumulative_proc_secs(),
                            max_lat_sum_secs: metrics[qi].max_lat_sum_secs(),
                        })
                        .collect();
                    st.save(&Checkpoint {
                        workload: self.queries[primary].name.clone(),
                        batches: m.batches(),
                        processed_up_to: newest,
                        inf_pt: self.inf_pt,
                        cumulative_bytes: m.cumulative_bytes(),
                        cumulative_proc_secs: m.cumulative_proc_secs(),
                        max_lat_sum_secs: m.max_lat_sum_secs(),
                        queries,
                        history: self.optimizer.history().to_vec(),
                        wal_high_water: wal_high[s],
                        round_high_water: round,
                    })?;
                    // Checkpointed batches no longer need the log.
                    // Truncation is safe only *after* the checkpoint is
                    // durable — save() fsyncs before returning.
                    if let Some(ws) = wals.as_mut() {
                        ws[s].truncate_through(wal_high[s])?;
                    }
                }

                // Baseline trigger catches up if processing overran.
                if cfg.mode.uses_trigger() && next_trigger[s] < clock.now() {
                    next_trigger[s] = clock.now();
                }
            }
        }

        self.last_wal_fsyncs = wals
            .as_ref()
            .map(|ws| ws.iter().map(|w| w.fsyncs()).sum())
            .unwrap_or(0);
        for (sh, &v) in quota_vetoes.iter().enumerate() {
            if let Some(st) = shard_stats.get_mut(sh) {
                st.quota_vetoes = v;
            }
        }
        self.last_health = Some(HealthReport {
            executors: health.stats(),
            retries: total_retries,
            recovery_wait: total_recovery_wait,
            degraded_rounds,
            shards: shard_stats,
        });
        self.last_watermarks = match cfg.allowed_lateness {
            Some(lateness) => max_event
                .iter()
                .map(|m| {
                    m.map(|m| Time(m.0.saturating_sub(lateness.as_nanos() as u64)))
                })
                .collect(),
            None => vec![None; num_sources],
        };

        Ok(self
            .queries
            .iter()
            .zip(metrics)
            .map(|(q, m)| RunResult {
                workload: q.name.clone(),
                mode: cfg.mode,
                avg_latency: m.avg_dataset_latency(),
                avg_throughput: m.avg_throughput(),
                phases: m.phase_totals(),
                dataset_latencies: m.dataset_latencies().to_vec(),
                final_inf_pt: self.inf_pt,
                batches: m.records().to_vec(),
            })
            .collect())
    }

    /// One sharded scheduling round (`Config::shards`): plan each
    /// admitted source's query group independently — in global source
    /// order, each booking a [`TimelineBank`] reservation lease off its
    /// predicted per-executor horizons, so cross-shard GPU contention
    /// is priced into the lease offsets and never double-booked — then
    /// execute the source groups concurrently, one worker per shard,
    /// against [`GpuTimeline::starting_at`] the leased offsets.
    ///
    /// Failures sweep on the coordinator thread: a failed source
    /// re-plans on the survivor topology (keeping its original lease
    /// window) and re-executes next sweep under its own retry budget
    /// and exponential backoff, while completed sources never
    /// re-execute — retries stay shard-local. Planning is per source
    /// whatever the shard count, so outputs are bit-identical across
    /// shard counts by construction.
    ///
    /// Returns `(pending, epoch_makespan, planning_wall, degraded)`;
    /// `retries_out`/`recovery_out` accumulate the round's per-source
    /// attempt totals. The epoch makespan is the max source proc — a
    /// pure function of the simulated execution, with no wall-clock
    /// terms.
    #[allow(clippy::too_many_arguments)]
    fn run_sharded_round(
        &self,
        cfg: &Config,
        staged: &[Staged],
        health: &mut cluster::ExecutorHealth,
        base_topo: &cluster::DeviceTopology,
        bank: &mut TimelineBank,
        shards: usize,
        round: usize,
        retries_out: &mut usize,
        recovery_out: &mut Duration,
    ) -> Result<(Vec<Pending>, Duration, Duration, bool)> {
        // Worker threads must not see the session itself (the owned
        // sinks are not Sync): hoist the Sync state they need.
        let model = &self.model;
        let qrefs: Vec<&Query> = self.queries.iter().map(|q| &q.query).collect();
        let qjoin: Vec<bool> = self.queries.iter().map(|q| q.has_join).collect();

        bank.reset_epoch()?;

        // Source groups in staging order — which is source registration
        // order, the bank's ticket order.
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for (i, st) in staged.iter().enumerate() {
            match groups.iter_mut().find(|(s, _)| *s == st.s) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((st.s, vec![i])),
            }
        }
        let ngroups = groups.len();

        // Per-source sweep state. Leases are granted on the first sweep
        // only; a retrying source re-plans on fewer executors but its
        // granted window stands (the prediction drift is bounded by the
        // backoff it also pays).
        let mut plans: Vec<Option<PhysicalPlan>> = vec![None; staged.len()];
        let mut exec_order: Vec<Vec<usize>> = vec![Vec::new(); ngroups];
        let mut offsets: Vec<Option<Vec<Duration>>> = vec![None; ngroups];
        let mut done = vec![false; ngroups];
        let mut src_retries = vec![0usize; ngroups];
        let mut src_recovery = vec![Duration::ZERO; ngroups];
        let mut results: Vec<Option<Vec<Pending>>> =
            (0..ngroups).map(|_| None).collect();
        let mut planning_wall = Duration::ZERO;

        let degraded = loop {
            // Survivor topology for this sweep — same derivation as the
            // legacy attempt loop.
            let fail_phys = health.attempt_faults();
            let active = health.active();
            if active.is_empty() {
                return Err(Error::Executor {
                    executor: fail_phys.first().copied().unwrap_or(0),
                    reason: "no surviving executors to re-plan on".into(),
                });
            }
            let mut topo = base_topo.subset(&active);
            for (local, &phys) in active.iter().enumerate() {
                if !health.gpu_ok(phys) {
                    topo.degrade_gpu(local);
                }
            }
            let faults = cluster::RoundFaults {
                fail: fail_phys
                    .iter()
                    .filter_map(|&p| active.iter().position(|&a| a == p))
                    .collect(),
                cpu_only: (0..active.len())
                    .filter(|&l| !topo.gpu_usable(l))
                    .collect(),
            };
            let degraded_now = health.is_degraded() || !faults.is_clean();
            let run_cluster = cfg.cluster.as_ref().map(|spec| spec.subset(&active));

            // ---- Plan every pending source and book its lease, in
            // ticket order, on this thread. A group always plans as a
            // group (plan_joint even for a single query): the plan is a
            // function of the source alone, never of shard layout.
            let t_plan = Instant::now();
            for (g, (_, idxs)) in groups.iter().enumerate() {
                if done[g] {
                    continue;
                }
                let mut cands: Vec<QueryCandidate> = Vec::with_capacity(idxs.len());
                for &i in idxs {
                    let st = &staged[i];
                    let qdef = &self.queries[st.qi];
                    let part =
                        mean_partition_bytes(st.input.alloc_bytes(), topo.total_cores());
                    let (aux_bytes, aux_chunks) = st.aux.unwrap_or((0.0, 0));
                    cands.push(
                        QueryCandidate::build(
                            &qdef.query,
                            part,
                            self.inf_pt,
                            cfg.base_trans_cost,
                            &qdef.size_est,
                            st.input.num_chunks(),
                            aux_bytes,
                            aux_chunks,
                        )?
                        .with_exec_chunks(schedule::share_chunk_counts(
                            &st.input,
                            &topo,
                        )),
                    );
                }
                let (group_plans, group_order, predicted) =
                    if cfg.mode == Mode::LmStream && cfg.co_schedule {
                        let jp = schedule::plan_joint(&cands, model, &topo);
                        let order = jp.predicted.order.clone();
                        let pred = jp.predicted.clone();
                        (jp.plans, order, pred)
                    } else {
                        // Fixed policies keep per-query plans, replayed
                        // through the same simulator for the lease
                        // horizons.
                        let mut group_plans = Vec::with_capacity(idxs.len());
                        for &i in idxs {
                            let st = &staged[i];
                            let qdef = &self.queries[st.qi];
                            let query = &qdef.query;
                            let plan = match cfg.mode {
                                Mode::LmStream => {
                                    let part = mean_partition_bytes(
                                        st.input.alloc_bytes(),
                                        topo.total_cores(),
                                    );
                                    map_device(
                                        query,
                                        part,
                                        self.inf_pt,
                                        cfg.base_trans_cost,
                                        &qdef.size_est,
                                        st.input.num_chunks(),
                                    )?
                                }
                                Mode::Baseline | Mode::AllGpu => {
                                    PhysicalPlan::uniform(query, Device::Gpu)
                                }
                                Mode::BaselineCpu | Mode::AllCpu => {
                                    PhysicalPlan::uniform(query, Device::Cpu)
                                }
                                Mode::StaticPreference => static_preference_plan(query),
                            };
                            group_plans.push(plan);
                        }
                        let pred = schedule::predict_fixed(
                            &cands,
                            &group_plans,
                            model,
                            &topo,
                        );
                        (group_plans, (0..idxs.len()).collect::<Vec<_>>(), pred)
                    };
                if offsets[g].is_none() {
                    // Book the group's GPU window off the prediction:
                    // the lease starts where earlier tickets' committed
                    // horizons end, per physical executor.
                    let lease = bank.lease()?;
                    let local =
                        schedule::executor_horizons(&predicted, topo.num_executors());
                    let mut phys = vec![0.0f64; bank.num_executors()];
                    for (l, &p) in active.iter().enumerate() {
                        phys[p] = local[l];
                    }
                    offsets[g] = Some(lease.offsets.clone());
                    bank.commit(lease, &phys)?;
                }
                exec_order[g] = group_order.iter().map(|&o| idxs[o]).collect();
                for (j, plan) in group_plans.into_iter().enumerate() {
                    plans[idxs[j]] = Some(plan);
                }
            }
            planning_wall += t_plan.elapsed();

            // ---- Concurrent execution: one work item per shard (a
            // shard's sources run sequentially inside it — it *is* a
            // round loop), shards in parallel. par_map preserves item
            // order and each source's timelines seed from its own lease
            // offsets, so nothing observable depends on thread timing.
            let mut shard_tasks: Vec<(usize, Vec<usize>)> = Vec::new();
            for (g, (s, _)) in groups.iter().enumerate() {
                if done[g] {
                    continue;
                }
                let sh = cluster::shard_of(*s, shards);
                match shard_tasks.iter_mut().find(|(t, _)| *t == sh) {
                    Some((_, gs)) => gs.push(g),
                    None => shard_tasks.push((sh, vec![g])),
                }
            }
            let order_ref = &exec_order;
            let plans_ref = &plans;
            let offsets_ref = &offsets;
            let active_ref = &active;
            let faults_ref = &faults;
            let fail_ref = fail_phys.as_slice();
            let cluster_ref = run_cluster.as_ref();
            let qrefs_ref = &qrefs;
            let qjoin_ref = &qjoin;
            let threads = shard_tasks.len();
            let sweep: Vec<Vec<(usize, Result<Vec<Pending>>)>> =
                par_map(shard_tasks, threads, |_, (sh, gs)| {
                    gs.into_iter()
                        .map(|g| {
                            let offs = offsets_ref[g]
                                .as_ref()
                                .expect("leased before execution");
                            let mut timelines: Vec<GpuTimeline> = active_ref
                                .iter()
                                .map(|&phys| GpuTimeline::starting_at(offs[phys]))
                                .collect();
                            let mut run = || -> Result<Vec<Pending>> {
                                let mut out = Vec::new();
                                for &i in &order_ref[g] {
                                    let st = &staged[i];
                                    let eq = execute_staged_query(
                                        qrefs_ref[st.qi],
                                        qjoin_ref[st.qi],
                                        plans_ref[i].as_ref().expect("planned"),
                                        st,
                                        model,
                                        cfg,
                                        // Sharding validates Simulated-only:
                                        // no PJRT runtime crosses threads.
                                        None,
                                        cluster_ref,
                                        faults_ref,
                                        fail_ref,
                                        &mut timelines,
                                    )?;
                                    out.push(Pending {
                                        s: st.s,
                                        qi: st.qi,
                                        result: eq.result,
                                        branch_results: eq.branch_results,
                                        proc: eq.proc,
                                        gpu_wait: eq.gpu_wait,
                                        traces: eq.traces,
                                        gpu_ops: eq.gpu_ops,
                                        total_ops: qrefs_ref[st.qi].len(),
                                        pruned_chunks: eq.pruned_chunks,
                                        retries: 0,
                                        recovery_wait: Duration::ZERO,
                                        shard: sh,
                                    });
                                }
                                Ok(out)
                            };
                            (g, run())
                        })
                        .collect()
                });

            // ---- Collect (coordinator thread): successes finish their
            // source; failures charge detection + backoff against that
            // source's own budget and re-enter the next sweep.
            let mut any_failed = false;
            for (g, res) in sweep.into_iter().flatten() {
                match res {
                    Ok(ps) => {
                        results[g] = Some(ps);
                        done[g] = true;
                    }
                    Err(Error::Executor { executor, reason }) => {
                        if !any_failed {
                            // One health transition per failed sweep
                            // (mirrors one per failed legacy attempt).
                            health.note_attempt_failed();
                            any_failed = true;
                        }
                        src_retries[g] += 1;
                        if src_retries[g] > cfg.max_round_retries {
                            return Err(Error::Executor {
                                executor,
                                reason: format!(
                                    "{reason}; round {round} exhausted its retry \
                                     budget ({} retries)",
                                    cfg.max_round_retries
                                ),
                            });
                        }
                        src_recovery[g] += cfg.failure_detection
                            + cfg.retry_backoff * (1u32 << (src_retries[g] - 1).min(16));
                    }
                    Err(e) => return Err(e),
                }
            }
            if !any_failed {
                break degraded_now;
            }
        };

        // ---- Fold. Recovery wait lands in the failing source's batches
        // only (healthy shards never pay for another shard's faults);
        // the epoch advances by the max source proc.
        let mut pending: Vec<Pending> = Vec::new();
        let mut epoch_makespan = Duration::ZERO;
        for (g, res) in results.into_iter().enumerate() {
            let mut ps = res.expect("every source completed or the round errored");
            for p in &mut ps {
                p.proc += src_recovery[g];
                p.retries = src_retries[g];
                p.recovery_wait = src_recovery[g];
                epoch_makespan = epoch_makespan.max(p.proc);
            }
            *retries_out += src_retries[g];
            *recovery_out += src_recovery[g];
            pending.extend(ps);
        }
        Ok((pending, epoch_makespan, planning_wall, degraded))
    }
}

/// One staged (source, query) execution input for a round: assembled
/// once — window upkeep is stateful — then re-executed as-is across
/// retry attempts (clones are O(#chunks) Arc bumps).
struct Staged {
    s: usize,
    qi: usize,
    input: ChunkedBatch,
    snapshot: Option<ChunkedBatch>,
    /// Eq. 9 aux `(bytes, chunks)` for join builds: the window's
    /// *encoded* resident footprint (cold chunks price their
    /// RLE/dict/delta blocks, not the decoded rows) — mirrored into
    /// both the scheduler's `QueryCandidate` and the executor's
    /// `ExecOpts::aux`.
    aux: Option<(f64, usize)>,
    /// Per-chunk encode-time stats aligned with `input` when the input
    /// *is* the window chunk list (aggregation-path snapshots): cold
    /// chunks reuse the min/max their encoded blocks already carry, hot
    /// ones recompute inline. Empty whenever the input is the fresh
    /// batch alone — the executor then scans as before.
    stats: Vec<Option<ChunkStats>>,
}

/// One executed (source, query) batch awaiting metrics + delivery.
struct Pending {
    s: usize,
    qi: usize,
    result: ChunkedBatch,
    branch_results: Vec<(usize, ChunkedBatch)>,
    proc: Duration,
    gpu_wait: Duration,
    traces: Vec<OpTrace>,
    gpu_ops: usize,
    total_ops: usize,
    pruned_chunks: usize,
    /// Failed attempts charged to this batch's round (legacy:
    /// round-wide; sharded: this *source's* attempts only).
    retries: usize,
    /// Detection + backoff wait folded into `proc`.
    recovery_wait: Duration,
    /// `source % shards` (0 when sharding is off).
    shard: usize,
}

/// What executing one staged query yields (shared by the legacy round
/// loop and the sharded per-shard workers).
struct ExecutedQuery {
    result: ChunkedBatch,
    branch_results: Vec<(usize, ChunkedBatch)>,
    proc: Duration,
    gpu_wait: Duration,
    traces: Vec<OpTrace>,
    gpu_ops: usize,
    pruned_chunks: usize,
}

/// Execute one staged query against `plan` on the attempt's surviving
/// topology — single executor or cluster-wide — charging its simulated
/// GPU ops to `timelines` (subset-local indexing, like `faults`). This
/// is the round loop's per-query execution factored out so the sharded
/// runtime's worker threads share it: it touches no session state (the
/// owned sinks are not Sync and stay on the coordinator thread).
#[allow(clippy::too_many_arguments)]
fn execute_staged_query(
    query: &Query,
    query_has_join: bool,
    plan: &PhysicalPlan,
    st: &Staged,
    model: &DeviceModel,
    cfg: &Config,
    runtime: Option<&Runtime>,
    run_cluster: Option<&cluster::ClusterSpec>,
    faults: &cluster::RoundFaults,
    fail_phys: &[usize],
    timelines: &mut [GpuTimeline],
) -> Result<ExecutedQuery> {
    let input = st.input.clone();
    // A join's build side before any state: empty window.
    let empty_window = ChunkedBatch::new(input.schema().clone());
    let join_side = if query_has_join {
        Some(st.snapshot.as_ref().unwrap_or(&empty_window))
    } else {
        None
    };
    let chunk_stats =
        if st.stats.is_empty() { None } else { Some(st.stats.as_slice()) };
    match run_cluster {
        None => {
            // Single node: a faulted executor has no peer to re-plan
            // around — the share is simply lost this attempt.
            if let Some(&e) = fail_phys.first() {
                return Err(Error::Executor {
                    executor: e,
                    reason: "lost its share mid-round (injected fault)".into(),
                });
            }
            let env = ExecEnv {
                model,
                backend: cfg.backend,
                num_cores: cfg.num_cores,
                num_gpus: cfg.num_gpus,
                runtime,
            };
            let demoted;
            let share_plan = if faults.cpu_only.contains(&0) {
                demoted = plan.demoted_to_cpu();
                &demoted
            } else {
                plan
            };
            let ops = share_plan.gpu_ops();
            // Fuse against the plan actually executed (a GPU-demoted
            // plan re-fuses as all-CPU groups).
            let fplan = fuse::fuse(query, share_plan);
            let o = exec::execute_with_opts(
                query,
                share_plan,
                input,
                join_side,
                &env,
                &mut timelines[0],
                &ExecOpts { fused: Some(&fplan), aux: st.aux, chunk_stats },
            )?;
            Ok(ExecutedQuery {
                result: o.result,
                branch_results: o.branch_results,
                proc: o.proc,
                gpu_wait: o.contention,
                traces: o.traces,
                gpu_ops: ops,
                pruned_chunks: o.pruned_chunks,
            })
        }
        Some(spec) => {
            let fplan = fuse::fuse(query, plan);
            let o = cluster::execute_on_cluster_opts(
                spec,
                query,
                plan,
                input,
                join_side,
                model,
                cfg.backend,
                runtime,
                Some(timelines),
                faults,
                // Chunk stats stop at the cluster boundary: shares are
                // row slices, so per-chunk stats no longer align
                // (cluster::exec forces None per share).
                &ExecOpts { fused: Some(&fplan), aux: st.aux, chunk_stats },
            )?;
            // Merge per-executor traces (sum byte volumes per op) for
            // the size estimator.
            let mut merged: Vec<OpTrace> = o.per_executor[0].traces.clone();
            for ex in &o.per_executor[1..] {
                for (m, t) in merged.iter_mut().zip(&ex.traces) {
                    m.in_bytes += t.in_bytes;
                    m.out_bytes += t.out_bytes;
                }
            }
            // The batch completes at the straggler, so the wait that
            // actually sits inside this record's proc is the *straggler
            // executor's* contention (another executor's larger wait
            // can hide entirely behind the barrier).
            let wait = o
                .per_executor
                .iter()
                .max_by_key(|e| e.proc)
                .map(|e| e.contention)
                .unwrap_or(Duration::ZERO);
            let pruned: usize =
                o.per_executor.iter().map(|e| e.pruned_chunks).sum();
            Ok(ExecutedQuery {
                result: o.result,
                branch_results: o.branch_results,
                proc: o.proc,
                gpu_wait: wait,
                traces: merged,
                gpu_ops: plan.gpu_ops(),
                pruned_chunks: pruned,
            })
        }
    }
}

/// The run's sink-ledger layout: one shared file (the legacy layout,
/// preserved byte-for-byte), one file per source (sharded runs — each
/// source group delivers and persists independently), or none (no
/// `wal_dir`).
enum Ledgers {
    Off,
    Shared(SinkLedger),
    PerSource(Vec<SinkLedger>),
}

impl Ledgers {
    fn for_source(&self, s: usize) -> Option<&SinkLedger> {
        match self {
            Ledgers::Off => None,
            Ledgers::Shared(l) => Some(l),
            Ledgers::PerSource(v) => v.get(s),
        }
    }

    fn for_source_mut(&mut self, s: usize) -> Option<&mut SinkLedger> {
        match self {
            Ledgers::Off => None,
            Ledgers::Shared(l) => Some(l),
            Ledgers::PerSource(v) => v.get_mut(s),
        }
    }

    /// Persist every dirty ledger (`SinkLedger::persist` is a no-op
    /// while clean, so only sources with fresh deliveries write).
    fn persist_all(&mut self) -> Result<()> {
        match self {
            Ledgers::Off => Ok(()),
            Ledgers::Shared(l) => l.persist(),
            Ledgers::PerSource(v) => {
                for l in v {
                    l.persist()?;
                }
                Ok(())
            }
        }
    }

    /// Total durable ledger writes so far, across every ledger.
    fn persists(&self) -> usize {
        match self {
            Ledgers::Off => 0,
            Ledgers::Shared(l) => l.persists(),
            Ledgers::PerSource(v) => v.iter().map(|l| l.persists()).sum(),
        }
    }
}

/// Classify freshly polled datasets against a source's low-watermark
/// (`max event time seen − allowed lateness`) and apply the configured
/// late policy *before* admission. Filtering ahead of the WAL keeps
/// replay consistent: a logged round never contains rows a policy
/// already routed away. Datasets arrive in arrival order; each is
/// classified against the watermark derived from the events seen
/// *before* it, then advances the (monotone) max event.
///
/// Returns the datasets that continue into admission. All late rows —
/// dropped, side-routed, or recomputed — count into `late_rows`;
/// [`LatePolicy::Recompute`] keeps the dataset flowing (its window,
/// still open under the watermark-lagged eviction horizon, recomputes
/// with it), [`LatePolicy::SideOutput`] delivers it to `late_sink` as
/// its own batch, [`LatePolicy::Drop`] discards it.
#[allow(clippy::too_many_arguments)]
fn apply_late_policy(
    data: Vec<Dataset>,
    policy: LatePolicy,
    lateness: Duration,
    max_event: &mut Option<Time>,
    late_rows: &mut usize,
    late_sink: &mut Option<Box<dyn Sink>>,
    late_delivered: &mut usize,
    now: Time,
) -> Result<Vec<Dataset>> {
    let mut kept = Vec::with_capacity(data.len());
    for d in data {
        let watermark =
            max_event.map(|m| Time(m.0.saturating_sub(lateness.as_nanos() as u64)));
        let late = watermark.is_some_and(|wm| d.event_time < wm);
        if max_event.is_none_or(|m| d.event_time > m) {
            *max_event = Some(d.event_time);
        }
        if !late {
            kept.push(d);
            continue;
        }
        *late_rows += d.rows();
        match policy {
            LatePolicy::Recompute => kept.push(d),
            LatePolicy::Drop => {}
            LatePolicy::SideOutput => {
                if let Some(sink) = late_sink.as_mut() {
                    let batch = ChunkedBatch::from_batch(d.batch);
                    sink.deliver(*late_delivered, &batch, now)?;
                    *late_delivered += 1;
                }
            }
        }
    }
    Ok(kept)
}

fn has_join(query: &Query) -> bool {
    query
        .ops
        .iter()
        .any(|o| matches!(o.spec.kind(), OpKind::Join))
}

/// Eq. 2/3's per-query latency bound: the slide time for sliding
/// windows, the running average of past max-latencies (bootstrapped) for
/// tumbling windows.
fn query_bound(query: &Query, metrics: &Metrics) -> Duration {
    match query.window.kind() {
        WindowKind::Sliding => query.window.slide_time(),
        WindowKind::Tumbling => metrics
            .past_max_lat_avg()
            .unwrap_or(INITIAL_TUMBLING_BOUND),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ops::aggregate::AggSpec;
    use crate::engine::ops::filter::Predicate;
    use crate::query::QueryBuilder;
    use crate::workloads;

    fn session(mode: Mode) -> Session<'static> {
        Session::new(Config { mode, ..Config::default() }).unwrap()
    }

    #[test]
    fn empty_session_rejects_run() {
        let mut s = session(Mode::LmStream);
        assert!(s.run(Duration::from_secs(10)).is_err());
    }

    #[test]
    fn single_query_session_matches_driver_shim() {
        let w = workloads::by_name("lr1s").unwrap();
        let mut s = session(Mode::LmStream);
        s.register(w).unwrap();
        let rs = s.run(Duration::from_secs(60)).unwrap();
        assert_eq!(rs.len(), 1);
        assert!(!rs[0].batches.is_empty());

        let w2 = workloads::by_name("lr1s").unwrap();
        let cfg = Config { mode: Mode::LmStream, ..Config::default() };
        let shim = crate::coordinator::driver::run(&w2, &cfg, Duration::from_secs(60), None)
            .unwrap();
        assert_eq!(shim.batches.len(), rs[0].batches.len());
        assert_eq!(shim.avg_throughput, rs[0].avg_throughput);
    }

    #[test]
    fn two_queries_share_one_source() {
        let w = workloads::by_name("lr1s").unwrap();
        let window = w.query.window;
        let mut s = session(Mode::LmStream);
        let first = s.register(w).unwrap();
        let agg = QueryBuilder::scan("congestion")
            .window(window)
            .filter("speed", Predicate::Lt(60.0))
            .aggregate(&["segment"], vec![AggSpec::avg("speed", "avgSpeed")], None)
            .build()
            .unwrap();
        s.register_shared(first, "congestion", agg).unwrap();
        let rs = s.run(Duration::from_secs(90)).unwrap();
        assert_eq!(rs.len(), 2);
        // Both queries saw every admitted batch.
        assert_eq!(rs[0].batches.len(), rs[1].batches.len());
        assert!(!rs[0].batches.is_empty());
        assert!(rs[0].avg_throughput > 0.0 && rs[1].avg_throughput > 0.0);
        assert_eq!(rs[1].workload, "congestion");
    }

    #[test]
    fn multi_query_runs_are_deterministic() {
        // Same seed, same registrations → byte-identical outcomes.
        let run_once = || {
            let w = workloads::by_name("lr1s").unwrap();
            let window = w.query.window;
            let mut s = session(Mode::LmStream);
            let first = s.register(w).unwrap();
            let q = QueryBuilder::scan("side")
                .window(window)
                .filter("speed", Predicate::Lt(60.0))
                .build()
                .unwrap();
            s.register_shared(first, "side", q).unwrap();
            s.run(Duration::from_secs(60)).unwrap()
        };
        let a = run_once();
        let b = run_once();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.batches.len(), y.batches.len());
            assert_eq!(x.avg_throughput, y.avg_throughput);
        }
    }

    #[test]
    fn unknown_share_handle_rejected() {
        let mut s = session(Mode::LmStream);
        let q = QueryBuilder::scan("q").build().unwrap();
        assert!(s.register_shared(QueryId(7), "q", q).is_err());
    }

    #[test]
    fn run_with_sink_rejects_unknown_query_id() {
        let mut s = session(Mode::LmStream);
        s.register(workloads::by_name("lr1s").unwrap()).unwrap();
        let mut sink = crate::engine::sink::NullSink;
        let r = s.run_with_sink(Duration::from_secs(5), QueryId(5), &mut sink);
        assert!(r.is_err(), "out-of-range QueryId must error, not no-op");
    }

    #[test]
    fn invalid_config_rejected_at_session_creation() {
        let cfg = Config { num_cores: 0, ..Config::default() };
        assert!(Session::new(cfg).is_err());
    }

    /// A sink publishing its delivery count/rows through shared state —
    /// observable after the session consumed the Box.
    struct SharedCountSink {
        batches: std::sync::Arc<std::sync::atomic::AtomicUsize>,
        rows: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    }

    impl SharedCountSink {
        fn new() -> (
            SharedCountSink,
            std::sync::Arc<std::sync::atomic::AtomicUsize>,
            std::sync::Arc<std::sync::atomic::AtomicUsize>,
        ) {
            let batches = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
            let rows = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
            (
                SharedCountSink {
                    batches: std::sync::Arc::clone(&batches),
                    rows: std::sync::Arc::clone(&rows),
                },
                batches,
                rows,
            )
        }
    }

    impl Sink for SharedCountSink {
        fn deliver(&mut self, _i: usize, result: &ChunkedBatch, _t: Time) -> Result<()> {
            use std::sync::atomic::Ordering;
            self.batches.fetch_add(1, Ordering::SeqCst);
            self.rows.fetch_add(result.rows(), Ordering::SeqCst);
            Ok(())
        }
    }

    #[test]
    fn registered_sinks_receive_per_query_results() {
        use std::sync::atomic::Ordering;
        let w = workloads::by_name("lr1s").unwrap();
        let window = w.query.window;
        let mut s = session(Mode::LmStream);
        let first = s.register(w).unwrap();
        let side = QueryBuilder::scan("side")
            .window(window)
            .filter("speed", Predicate::Lt(60.0))
            .build()
            .unwrap();
        let second = s.register_shared(first, "side", side).unwrap();
        let (sink, batches, _rows) = SharedCountSink::new();
        s.set_sink(second, Box::new(sink)).unwrap();
        let rs = s.run(Duration::from_secs(60)).unwrap();
        assert_eq!(batches.load(Ordering::SeqCst), rs[1].batches.len());
        assert!(batches.load(Ordering::SeqCst) > 0);
        assert!(s.take_sink(second).is_some(), "sink still registered");
        assert!(s.take_sink(second).is_none(), "sink already taken");
    }

    #[test]
    fn branch_sinks_route_branch_results() {
        use std::sync::atomic::Ordering;
        let w = workloads::by_name("lr1s").unwrap();
        let window = w.query.window;
        let mut s = session(Mode::LmStream);
        let first = s.register(w).unwrap();
        // scan(0) -> filter(1) -> {select vehicle (2, branch sink),
        // select speed (3, primary)}.
        let fanout = QueryBuilder::scan("fanout")
            .window(window)
            .filter("speed", Predicate::Lt(80.0))
            .branch(|b| b.select(&["vehicle"]))
            .select(&["speed"])
            .build()
            .unwrap();
        let second = s.register_shared(first, "fanout", fanout).unwrap();
        let (sink, batches, rows) = SharedCountSink::new();
        s.set_branch_sink(second, 2, Box::new(sink)).unwrap();
        let rs = s.run(Duration::from_secs(60)).unwrap();
        // Every executed batch delivered its branch output.
        assert_eq!(batches.load(Ordering::SeqCst), rs[1].batches.len());
        assert!(batches.load(Ordering::SeqCst) > 0);
        assert!(rows.load(Ordering::SeqCst) > 0, "branch delivered no rows");
        assert!(s.take_branch_sink(second, 2).is_some());
        assert!(s.take_branch_sink(second, 2).is_none());
    }

    #[test]
    fn branch_sink_registration_validated() {
        let w = workloads::by_name("lr1s").unwrap();
        let window = w.query.window;
        let mut s = session(Mode::LmStream);
        let first = s.register(w).unwrap();
        let fanout = QueryBuilder::scan("fanout")
            .window(window)
            .filter("speed", Predicate::Lt(80.0))
            .branch(|b| b.select(&["vehicle"]))
            .select(&["speed"])
            .build()
            .unwrap();
        let second = s.register_shared(first, "fanout", fanout).unwrap();
        let sink = || Box::new(crate::engine::sink::NullSink);
        // Interior (non-sink) node rejected.
        assert!(s.set_branch_sink(second, 1, sink()).is_err());
        // Primary sink rejected (that's set_sink's job).
        assert!(s.set_branch_sink(second, 3, sink()).is_err());
        // Unknown query id rejected.
        assert!(s.set_sink(QueryId(9), sink()).is_err());
        assert!(s.set_branch_sink(QueryId(9), 2, sink()).is_err());
    }

    #[test]
    fn multi_query_batches_record_contended_gpu_waits() {
        // Two GPU-using queries per batch on one simulated GPU: the
        // shared timeline makes at least one query's records carry a
        // nonzero gpu_wait, and every proc bounds its wait.
        let w = workloads::by_name("lr1s").unwrap();
        let window = w.query.window;
        let mut s = session(Mode::AllGpu);
        let first = s.register(w).unwrap();
        let q = QueryBuilder::scan("side")
            .window(window)
            .filter("speed", Predicate::Lt(60.0))
            .build()
            .unwrap();
        s.register_shared(first, "side", q).unwrap();
        let rs = s.run(Duration::from_secs(60)).unwrap();
        assert!(!rs[0].batches.is_empty());
        for r in &rs {
            for b in &r.batches {
                assert!(b.gpu_wait <= b.proc, "wait beyond proc");
            }
        }
        let waited: u32 = rs
            .iter()
            .flat_map(|r| r.batches.iter())
            .map(|b| u32::from(b.gpu_wait > Duration::ZERO))
            .sum();
        assert!(waited > 0, "all-GPU two-query batches never contended");
    }

    #[test]
    fn co_schedule_ablation_still_runs() {
        // co_schedule = false keeps independent per-query plans but the
        // shared timeline still arbitrates the device.
        let w = workloads::by_name("lr1s").unwrap();
        let window = w.query.window;
        let cfg = Config { mode: Mode::LmStream, co_schedule: false, ..Config::default() };
        let mut s = Session::new(cfg).unwrap();
        let first = s.register(w).unwrap();
        let q = QueryBuilder::scan("side")
            .window(window)
            .filter("speed", Predicate::Lt(60.0))
            .build()
            .unwrap();
        s.register_shared(first, "side", q).unwrap();
        let rs = s.run(Duration::from_secs(60)).unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].batches.len(), rs[1].batches.len());
        assert!(!rs[0].batches.is_empty());
    }

    #[test]
    fn branched_query_runs_through_session() {
        let w = workloads::by_name("lr2s").unwrap();
        let window = w.query.window;
        let mut s = session(Mode::LmStream);
        let first = s.register(w).unwrap();
        // One scan fanning out: congestion aggregate + slow-vehicle sort.
        let fanout = QueryBuilder::scan("fanout")
            .window(window)
            .filter("speed", Predicate::Lt(80.0))
            .branch(|b| {
                b.aggregate(&["segment"], vec![AggSpec::count("reports")], None)
            })
            .sort("speed", false)
            .build()
            .unwrap();
        s.register_shared(first, "fanout", fanout).unwrap();
        let rs = s.run(Duration::from_secs(60)).unwrap();
        assert_eq!(rs.len(), 2);
        assert!(!rs[1].batches.is_empty());
    }
}
