//! The session — the top of the query stack.
//!
//! A [`Session`] owns everything the LMStream coordinator shares across
//! queries: the calibrated [`DeviceModel`], the asynchronous
//! [`OnlineOptimizer`] (and the inflection point it maintains), the PJRT
//! [`Runtime`] handle, the [`Config`], and per-query learned
//! [`SizeEstimator`]s. Queries are *registered* —
//! [`Session::register`] attaches a workload (query + source),
//! [`Session::register_shared`] attaches an additional query to an
//! already-registered source — and [`Session::run`] drives them all
//! through one micro-batch loop (Fig. 3's execution flow, generalized to
//! concurrent queries):
//!
//! * **shared admission** — per source, `ConstructMicroBatch` (Alg. 1)
//!   admits against the *tightest* latency bound across that source's
//!   queries, so a sliding-window query co-registered with a tumbling
//!   one keeps the batch latency-bounded for both;
//! * **per-query planning & windows** — every admitted micro-batch is
//!   planned (`MapDevice`, Alg. 2) and executed once per query, each
//!   with its own window state, [`SizeEstimator`], and metrics;
//! * **shared optimization** — one online regression (Eq. 10) fits the
//!   inflection point from the primary query's history.
//!
//! One iteration: poll the source(s) → admission (or the baseline's
//! static trigger) → collect the async optimizer's latest inflection
//! point → per-query `MapDevice` planning → per-query execution →
//! metrics update → window-state maintenance → submit the optimizer's
//! next fit. Identical code drives the simulated clock (paper-scale
//! experiments) and the wall clock (real PJRT runs).
//!
//! The free functions in [`crate::coordinator::driver`] remain as thin
//! single-query shims over this type.

use crate::cluster;
use crate::config::{Config, ExecBackend, Mode};
use crate::coordinator::admission::{
    min_positive_throughput, Admission, AdmissionDecision,
};
use crate::coordinator::checkpoint::{Checkpoint, CheckpointStore, QueryMetricState};
use crate::coordinator::metrics::{BatchRecord, Metrics, PhaseTotals};
use crate::coordinator::optimizer::{HistoryPoint, OnlineOptimizer};
use crate::coordinator::planner::{map_device, static_preference_plan, SizeEstimator};
use crate::devices::model::DeviceModel;
use crate::devices::Device;
use crate::engine::chunked::ChunkedBatch;
use crate::engine::dataset::MicroBatch;
use crate::engine::partition::mean_partition_bytes;
use crate::engine::sink::Sink;
use crate::engine::window::{WindowKind, WindowState};
use crate::error::{Error, Result};
use crate::query::dag::{OpKind, Query};
use crate::query::exec::{self, ExecEnv, OpTrace};
use crate::query::physical::PhysicalPlan;
use crate::runtime::client::Runtime;
use crate::sim::{Clock, SimClock, Time, WallClock};
use crate::workloads::Workload;
use std::path::Path;
use std::time::{Duration, Instant};

/// Tumbling-window bootstrap bound before any history exists (§III-C's
/// Eq. 3 is undefined for i < 2; the paper seeds parameters from
/// pre-experiments — three seconds is our seed).
pub(crate) const INITIAL_TUMBLING_BOUND: Duration = Duration::from_secs(3);

/// Optimizer pickup timeout: how long the session will wait on the async
/// regression before planning (bounds Table IV's "Optimization Blocking").
const OPT_PICKUP_TIMEOUT: Duration = Duration::from_millis(20);

/// Handle to a query registered on a [`Session`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueryId(pub(crate) usize);

/// Everything a finished per-query run reports.
#[derive(Debug)]
pub struct RunResult {
    /// Registered query name.
    pub workload: String,
    pub mode: Mode,
    pub batches: Vec<BatchRecord>,
    /// Mean per-dataset end-to-end latency, seconds (Fig. 6 metric).
    pub avg_latency: f64,
    /// Eq. 4 average throughput, bytes/s (Fig. 7 metric).
    pub avg_throughput: f64,
    /// Table IV phase totals.
    pub phases: PhaseTotals,
    /// Per-dataset latencies (distribution analysis).
    pub dataset_latencies: Vec<f64>,
    /// Final inflection point (bytes).
    pub final_inf_pt: f64,
}

impl RunResult {
    /// Mean processing-phase time per micro-batch (Fig. 10 metric), s.
    pub fn avg_proc(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches.iter().map(|b| b.proc.as_secs_f64()).sum::<f64>()
            / self.batches.len() as f64
    }

    /// Mean per-batch max latency, s.
    pub fn avg_max_latency(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches
            .iter()
            .map(|b| b.max_latency.as_secs_f64())
            .sum::<f64>()
            / self.batches.len() as f64
    }
}

/// One registered query: its (rewritten) logical plan plus the per-query
/// state the session keeps across runs.
struct QueryDef {
    name: String,
    source: usize,
    /// The optimizer-rewritten logical DAG the planner/executor use.
    query: Query,
    has_join: bool,
    size_est: SizeEstimator,
}

/// One registered source: the workload whose generator/traffic feed it,
/// and the queries consuming its micro-batches.
struct SourceDef {
    workload: Workload,
    /// Index into `Session::queries` of the source's first-registered
    /// (primary) query — admission throughput estimates, optimizer
    /// history, and checkpoints key off it.
    primary: usize,
    queries: Vec<usize>,
}

/// A streaming session: shared coordinator state + registered queries.
/// See the module docs for the execution model.
pub struct Session<'rt> {
    cfg: Config,
    model: DeviceModel,
    owned_runtime: Option<Runtime>,
    borrowed_runtime: Option<&'rt Runtime>,
    optimizer: OnlineOptimizer,
    inf_pt: f64,
    sources: Vec<SourceDef>,
    queries: Vec<QueryDef>,
}

impl<'rt> Session<'rt> {
    /// Create a session without a PJRT runtime (Simulated backend, or
    /// Real backend with CPU-only plans).
    pub fn new(cfg: Config) -> Result<Session<'rt>> {
        Self::build(cfg, None, None)
    }

    /// Create a session owning `runtime` (Real backend GPU path).
    pub fn with_runtime(cfg: Config, runtime: Runtime) -> Result<Session<'rt>> {
        Self::build(cfg, Some(runtime), None)
    }

    /// Create a session borrowing an externally-managed runtime (the
    /// driver-shim path).
    pub fn with_runtime_ref(cfg: Config, runtime: Option<&'rt Runtime>) -> Result<Session<'rt>> {
        Self::build(cfg, None, runtime)
    }

    fn build(
        cfg: Config,
        owned: Option<Runtime>,
        borrowed: Option<&'rt Runtime>,
    ) -> Result<Session<'rt>> {
        cfg.validate()?;
        let optimizer = OnlineOptimizer::new(
            cfg.online_optimizer && cfg.mode == Mode::LmStream,
            cfg.history_cap,
            cfg.seed,
        );
        let inf_pt = cfg.initial_inflection_bytes;
        Ok(Session {
            cfg,
            model: DeviceModel::default(),
            owned_runtime: owned,
            borrowed_runtime: borrowed,
            optimizer,
            inf_pt,
            sources: Vec::new(),
            queries: Vec::new(),
        })
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Registered query count.
    pub fn num_queries(&self) -> usize {
        self.queries.len()
    }

    /// Register a workload: its query plus the source stream feeding it.
    /// The logical plan is rewritten ([`crate::query::optimize`]) and
    /// validated here, once, not per run.
    pub fn register(&mut self, workload: Workload) -> Result<QueryId> {
        let query = Self::prepare(&workload.query)?;
        let source = self.sources.len();
        let qidx = self.queries.len();
        self.queries.push(QueryDef {
            name: workload.name.to_string(),
            source,
            has_join: has_join(&query),
            size_est: SizeEstimator::new(query.len()),
            query,
        });
        self.sources.push(SourceDef {
            workload,
            primary: qidx,
            queries: vec![qidx],
        });
        Ok(QueryId(qidx))
    }

    /// Register an additional query on the source of an
    /// already-registered query: both consume every micro-batch the
    /// shared admission controller admits, each through its own plan,
    /// window state and metrics.
    pub fn register_shared(
        &mut self,
        share_source_with: QueryId,
        name: &str,
        query: Query,
    ) -> Result<QueryId> {
        let source = self
            .queries
            .get(share_source_with.0)
            .ok_or_else(|| {
                Error::Plan(format!("unknown query id {}", share_source_with.0))
            })?
            .source;
        let query = Self::prepare(&query)?;
        let qidx = self.queries.len();
        self.queries.push(QueryDef {
            name: name.to_string(),
            source,
            has_join: has_join(&query),
            size_est: SizeEstimator::new(query.len()),
            query,
        });
        self.sources[source].queries.push(qidx);
        Ok(QueryId(qidx))
    }

    /// Logical rewrites + validation (register-time, not per-run).
    fn prepare(query: &Query) -> Result<Query> {
        let optimized = crate::query::optimize::optimize(query);
        optimized.validate()?;
        Ok(optimized)
    }

    fn runtime(&self) -> Option<&Runtime> {
        match self.borrowed_runtime {
            Some(r) => Some(r),
            None => self.owned_runtime.as_ref(),
        }
    }

    /// Run every registered query for `duration` (simulated or wall
    /// time); returns one [`RunResult`] per query, in registration
    /// order. Learned state (size estimators, optimizer history, the
    /// inflection point) persists across calls; streams, windows and
    /// metrics start fresh.
    pub fn run(&mut self, duration: Duration) -> Result<Vec<RunResult>> {
        self.run_delivering(duration, &mut |_, _, _, _| Ok(()))
    }

    /// [`Session::run`] delivering one query's results to `sink`.
    pub fn run_with_sink(
        &mut self,
        duration: Duration,
        query: QueryId,
        sink: &mut dyn Sink,
    ) -> Result<Vec<RunResult>> {
        if query.0 >= self.queries.len() {
            return Err(Error::Plan(format!(
                "unknown query id {} (session has {} registered queries)",
                query.0,
                self.queries.len()
            )));
        }
        self.run_delivering(duration, &mut |qidx, batch_idx, result, at| {
            if qidx == query.0 {
                sink.deliver(batch_idx, result, at)?;
            }
            Ok(())
        })
    }

    fn run_delivering(
        &mut self,
        duration: Duration,
        deliver: &mut dyn FnMut(usize, usize, &ChunkedBatch, Time) -> Result<()>,
    ) -> Result<Vec<RunResult>> {
        if self.queries.is_empty() {
            return Err(Error::Plan("no queries registered on this session".into()));
        }
        let clock: Box<dyn Clock> = match self.cfg.backend {
            ExecBackend::Simulated => Box::new(SimClock::new()),
            ExecBackend::Real => Box::new(WallClock::new()),
        };
        self.run_with_clock(duration, clock.as_ref(), deliver)
    }

    fn run_with_clock(
        &mut self,
        duration: Duration,
        clock: &dyn Clock,
        deliver: &mut dyn FnMut(usize, usize, &ChunkedBatch, Time) -> Result<()>,
    ) -> Result<Vec<RunResult>> {
        let cfg = self.cfg.clone();
        let runtime = match self.borrowed_runtime {
            Some(r) => Some(r),
            None => self.owned_runtime.as_ref(),
        };

        // §III-E checkpoint/state-flush substrate (keyed per source by
        // its primary query's name).
        let ckpt_store = match &cfg.checkpoint_dir {
            Some(dir) => Some(CheckpointStore::new(Path::new(dir))?),
            None => None,
        };

        // ---- Per-query run state (metrics first: checkpoint recovery
        // below seeds them).
        let num_queries = self.queries.len();
        let mut windows: Vec<WindowState> =
            (0..num_queries).map(|_| WindowState::new()).collect();
        let mut metrics: Vec<Metrics> = (0..num_queries).map(|_| Metrics::new()).collect();

        // ---- Per-source run state.
        let num_sources = self.sources.len();
        let mut streams = Vec::with_capacity(num_sources);
        let mut admissions = Vec::with_capacity(num_sources);
        // Shared coordinator state (inflection point, optimizer history)
        // is snapshotted identically into every source's checkpoint —
        // restore it from the first checkpoint found only, so resume is
        // independent of registration order and history isn't
        // re-recorded once per source. Stream fast-forward and per-query
        // metric recovery stay per source.
        let mut shared_state_restored = false;
        for src in &self.sources {
            let mut stream = src.workload.make_stream(cfg.seed);
            let primary_window = self.queries[src.primary].query.window;
            admissions.push(Admission::new(primary_window, INITIAL_TUMBLING_BOUND));
            if let Some(st) = &ckpt_store {
                if let Some(ckpt) = st.load(&self.queries[src.primary].name)? {
                    if !shared_state_restored {
                        self.inf_pt = ckpt.inf_pt.max(1.0);
                        for h in &ckpt.history {
                            self.optimizer.record(*h, INITIAL_TUMBLING_BOUND);
                        }
                        shared_state_restored = true;
                    }
                    stream.fast_forward(ckpt.processed_up_to);
                    // Metric recovery for *every* query on the source
                    // (checkpoints are keyed by the primary query's name
                    // but carry per-query states, so secondary-query
                    // metrics survive too; pre-`queries` checkpoints
                    // fall back to the legacy primary-only fields).
                    for &qi in &src.queries {
                        let name = &self.queries[qi].name;
                        if let Some(qs) = ckpt
                            .queries
                            .iter()
                            .find(|q| q.name.eq_ignore_ascii_case(name))
                        {
                            metrics[qi].restore(
                                qs.batches,
                                qs.cumulative_bytes,
                                qs.cumulative_proc_secs,
                                qs.max_lat_sum_secs,
                            );
                        } else if qi == src.primary {
                            metrics[qi].restore(
                                ckpt.batches,
                                ckpt.cumulative_bytes,
                                ckpt.cumulative_proc_secs,
                                ckpt.max_lat_sum_secs,
                            );
                        }
                    }
                }
            }
            streams.push(stream);
        }
        let mut next_trigger: Vec<Time> =
            vec![Time::ZERO.add(cfg.trigger); num_sources];
        let mut construct_acc: Vec<Duration> = vec![Duration::ZERO; num_sources];

        let end = Time::ZERO.add(duration);

        while clock.now() < end {
            // ---- Buffering phase: trigger (baseline) or admission
            // (LMStream), per source.
            let mut admitted: Vec<(usize, MicroBatch)> = Vec::new();
            if cfg.mode.uses_trigger() {
                let wake = next_trigger.iter().min().copied().expect(">=1 source");
                clock.sleep_until(wake);
                if clock.now() >= end {
                    break;
                }
                for s in 0..num_sources {
                    if next_trigger[s] > clock.now() {
                        continue;
                    }
                    let data = streams[s].poll(clock.now());
                    next_trigger[s] = next_trigger[s].add(cfg.trigger);
                    if !data.is_empty() {
                        admitted.push((s, MicroBatch::new(data)));
                    }
                }
            } else {
                let deadline = clock.now().add(cfg.poll_interval);
                clock.sleep_until(deadline);
                if clock.now() >= end {
                    break;
                }
                for s in 0..num_sources {
                    let t0 = Instant::now();
                    let data = streams[s].poll(clock.now());
                    // Eq. 6's AvgThPut over a multi-query source: the
                    // *minimum* observed throughput across its queries
                    // (the slowest query dominates the batch's real
                    // processing time), not the primary's alone — the
                    // estimate stays conservative, so admission is at
                    // least as eager for every co-registered query.
                    let thput = min_positive_throughput(
                        self.sources[s]
                            .queries
                            .iter()
                            .map(|&qi| metrics[qi].avg_throughput()),
                        cfg.initial_throughput,
                    );
                    // Shared admission: the tightest bound across the
                    // source's queries keeps every query's latency
                    // target honored.
                    let bound = self.sources[s]
                        .queries
                        .iter()
                        .map(|&qi| query_bound(&self.queries[qi].query, &metrics[qi]))
                        .min()
                        .expect("source has >=1 query");
                    let decision = admissions[s].construct_with_bound(
                        data,
                        clock.now(),
                        thput,
                        bound,
                    );
                    construct_acc[s] += t0.elapsed();
                    match decision {
                        AdmissionDecision::Poll | AdmissionDecision::Buffer { .. } => {}
                        AdmissionDecision::Admit(mb) => admitted.push((s, mb)),
                    }
                }
            }

            for (s, batch) in admitted {
                let admitted_at = clock.now();
                let batch_bytes = batch.wire_bytes();
                let primary = self.sources[s].primary;

                // ---- Optimizer pickup (must land before planning).
                let (new_inf, opt_blocking) = if cfg.mode == Mode::LmStream {
                    self.optimizer.take(self.inf_pt, OPT_PICKUP_TIMEOUT)
                } else {
                    (self.inf_pt, Duration::ZERO)
                };
                self.inf_pt = new_inf;

                // ---- Per-query planning + execution.
                struct Pending {
                    qi: usize,
                    result: ChunkedBatch,
                    proc: Duration,
                    traces: Vec<OpTrace>,
                    map_device_time: Duration,
                    gpu_ops: usize,
                    total_ops: usize,
                }
                let mut pending: Vec<Pending> = Vec::new();
                let mut advance = Duration::ZERO;
                let query_ids = self.sources[s].queries.clone();
                for &qi in &query_ids {
                    let qdef = &self.queries[qi];
                    let query = &qdef.query;

                    // Window maintenance + execution input assembly. The
                    // snapshot is a chunk list — one shared chunk per
                    // in-window dataset (O(#datasets) Arc bumps, zero
                    // row copies, no copy-on-write even while a sink
                    // retains an old snapshot — see engine::window).
                    if let Some(newest) = batch.newest_event_time() {
                        windows[qi].evict(newest, &query.window);
                    }
                    let (input, snapshot): (ChunkedBatch, Option<ChunkedBatch>) =
                        if query.uses_window_state && !qdef.has_join {
                            // Windowed aggregation recomputes over state ∪
                            // new: ingest the new datasets first (O(delta)
                            // chunk appends), then the input *is* the
                            // chunk-list union — the old per-batch concat
                            // (and the CoW copy a retained snapshot used
                            // to force) is gone. The late push below
                            // skips these queries.
                            windows[qi].push(&batch.datasets);
                            let snap = windows[qi].snapshot_chunks()?;
                            let input = match &snap {
                                Some(st) => st.clone(),
                                None => batch.chunked()?,
                            };
                            (input, snap)
                        } else {
                            (batch.chunked()?, windows[qi].snapshot_chunks()?)
                        };

                    // Query planning (MapDevice or a fixed policy).
                    let t_plan = Instant::now();
                    let plan: PhysicalPlan = match cfg.mode {
                        Mode::LmStream => {
                            // Part_(i,j): partition share of the data the
                            // processing phase actually touches.
                            let part =
                                mean_partition_bytes(input.alloc_bytes(), cfg.num_cores);
                            map_device(
                                query,
                                part,
                                self.inf_pt,
                                cfg.base_trans_cost,
                                &qdef.size_est,
                            )?
                        }
                        Mode::Baseline | Mode::AllGpu => {
                            PhysicalPlan::uniform(query, Device::Gpu)
                        }
                        Mode::BaselineCpu | Mode::AllCpu => {
                            PhysicalPlan::uniform(query, Device::Cpu)
                        }
                        Mode::StaticPreference => static_preference_plan(query),
                    };
                    let map_device_time = t_plan.elapsed();
                    // A join's build side before any state: empty window.
                    let empty_window = ChunkedBatch::new(input.schema().clone());
                    let join_side = if qdef.has_join {
                        Some(snapshot.as_ref().unwrap_or(&empty_window))
                    } else {
                        None
                    };

                    // Processing phase (single executor or cluster-wide).
                    let (result, proc, traces): (ChunkedBatch, Duration, Vec<OpTrace>) =
                        match &cfg.cluster {
                            None => {
                                let env = ExecEnv {
                                    model: &self.model,
                                    backend: cfg.backend,
                                    num_cores: cfg.num_cores,
                                    num_gpus: cfg.num_gpus,
                                    runtime,
                                };
                                let o =
                                    exec::execute(query, &plan, input, join_side, &env)?;
                                (o.result, o.proc, o.traces)
                            }
                            Some(spec) => {
                                let o = cluster::execute_on_cluster(
                                    spec,
                                    query,
                                    &plan,
                                    input,
                                    join_side,
                                    &self.model,
                                    cfg.backend,
                                    runtime,
                                )?;
                                // Merge per-executor traces (sum byte
                                // volumes per op) for the size estimator.
                                let mut merged: Vec<OpTrace> =
                                    o.per_executor[0].traces.clone();
                                for ex in &o.per_executor[1..] {
                                    for (m, t) in merged.iter_mut().zip(&ex.traces) {
                                        m.in_bytes += t.in_bytes;
                                        m.out_bytes += t.out_bytes;
                                    }
                                }
                                (o.result, o.proc, merged)
                            }
                        };
                    advance += proc + map_device_time;
                    pending.push(Pending {
                        qi,
                        result,
                        proc,
                        traces,
                        map_device_time,
                        gpu_ops: plan.gpu_ops(),
                        total_ops: query.len(),
                    });
                }

                clock.advance(advance + construct_acc[s] + opt_blocking);

                // ---- Metrics (Eqs. 4/5, Table IV) + sinks + learning.
                let buffs: Vec<Duration> = batch
                    .datasets
                    .iter()
                    .map(|d| admitted_at.saturating_sub(d.created_at))
                    .collect();
                for p in pending {
                    deliver(p.qi, metrics[p.qi].batches(), &p.result, clock.now())?;
                    // Shared (per-source) phase costs are charged to the
                    // primary query only, so phase totals don't double-
                    // count admission/optimizer time.
                    let shared = p.qi == primary;
                    let rec = BatchRecord {
                        index: metrics[p.qi].batches(),
                        admitted_at,
                        num_datasets: batch.num_datasets(),
                        bytes: batch_bytes,
                        max_buffering: Duration::ZERO, // filled by record
                        proc: p.proc,
                        max_latency: Duration::ZERO, // filled by record
                        inf_pt: self.inf_pt,
                        gpu_ops: p.gpu_ops,
                        total_ops: p.total_ops,
                        construct_time: if shared {
                            construct_acc[s]
                        } else {
                            Duration::ZERO
                        },
                        map_device_time: p.map_device_time,
                        opt_blocking: if shared { opt_blocking } else { Duration::ZERO },
                    };
                    metrics[p.qi].record(rec, &buffs);
                    self.queries[p.qi].size_est.observe(&p.traces);
                }
                construct_acc[s] = Duration::ZERO;

                // ---- Async parameter optimization (Eq. 10 inputs), fed
                // from the source's primary query.
                if cfg.mode == Mode::LmStream {
                    let m = &metrics[primary];
                    let last = m.records().last().expect("just recorded");
                    let target = query_bound(&self.queries[primary].query, m);
                    self.optimizer.record(
                        HistoryPoint {
                            throughput: m.avg_throughput(),
                            max_latency: last.max_latency.as_secs_f64(),
                            inf_pt: self.inf_pt,
                        },
                        target,
                    );
                }

                // ---- Window state ingests the processed datasets.
                // (Aggregation-path queries already ingested the batch
                // before snapshotting their execution input, above.)
                for &qi in &query_ids {
                    let q = &self.queries[qi];
                    if q.query.uses_window_state && q.has_join {
                        windows[qi].push(&batch.datasets);
                    }
                }

                // ---- §III-E checkpoint / state flush. The file stays
                // keyed by the source's primary query name, but carries
                // one metric state per registered query, so secondary
                // queries recover too.
                if let Some(st) = &ckpt_store {
                    let newest = batch
                        .datasets
                        .iter()
                        .map(|d| d.created_at)
                        .max()
                        .unwrap_or(admitted_at);
                    let m = &metrics[primary];
                    let queries: Vec<QueryMetricState> = query_ids
                        .iter()
                        .map(|&qi| QueryMetricState {
                            name: self.queries[qi].name.clone(),
                            batches: metrics[qi].batches(),
                            cumulative_bytes: metrics[qi].cumulative_bytes(),
                            cumulative_proc_secs: metrics[qi].cumulative_proc_secs(),
                            max_lat_sum_secs: metrics[qi].max_lat_sum_secs(),
                        })
                        .collect();
                    st.save(&Checkpoint {
                        workload: self.queries[primary].name.clone(),
                        batches: m.batches(),
                        processed_up_to: newest,
                        inf_pt: self.inf_pt,
                        cumulative_bytes: m.cumulative_bytes(),
                        cumulative_proc_secs: m.cumulative_proc_secs(),
                        max_lat_sum_secs: m.max_lat_sum_secs(),
                        queries,
                        history: self.optimizer.history().to_vec(),
                    })?;
                }

                // Baseline trigger catches up if processing overran.
                if cfg.mode.uses_trigger() && next_trigger[s] < clock.now() {
                    next_trigger[s] = clock.now();
                }
            }
        }

        Ok(self
            .queries
            .iter()
            .zip(metrics)
            .map(|(q, m)| RunResult {
                workload: q.name.clone(),
                mode: cfg.mode,
                avg_latency: m.avg_dataset_latency(),
                avg_throughput: m.avg_throughput(),
                phases: m.phase_totals(),
                dataset_latencies: m.dataset_latencies().to_vec(),
                final_inf_pt: self.inf_pt,
                batches: m.records().to_vec(),
            })
            .collect())
    }
}

fn has_join(query: &Query) -> bool {
    query
        .ops
        .iter()
        .any(|o| matches!(o.spec.kind(), OpKind::Join))
}

/// Eq. 2/3's per-query latency bound: the slide time for sliding
/// windows, the running average of past max-latencies (bootstrapped) for
/// tumbling windows.
fn query_bound(query: &Query, metrics: &Metrics) -> Duration {
    match query.window.kind() {
        WindowKind::Sliding => query.window.slide_time(),
        WindowKind::Tumbling => metrics
            .past_max_lat_avg()
            .unwrap_or(INITIAL_TUMBLING_BOUND),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ops::aggregate::AggSpec;
    use crate::engine::ops::filter::Predicate;
    use crate::query::QueryBuilder;
    use crate::workloads;

    fn session(mode: Mode) -> Session<'static> {
        Session::new(Config { mode, ..Config::default() }).unwrap()
    }

    #[test]
    fn empty_session_rejects_run() {
        let mut s = session(Mode::LmStream);
        assert!(s.run(Duration::from_secs(10)).is_err());
    }

    #[test]
    fn single_query_session_matches_driver_shim() {
        let w = workloads::by_name("lr1s").unwrap();
        let mut s = session(Mode::LmStream);
        s.register(w).unwrap();
        let rs = s.run(Duration::from_secs(60)).unwrap();
        assert_eq!(rs.len(), 1);
        assert!(!rs[0].batches.is_empty());

        let w2 = workloads::by_name("lr1s").unwrap();
        let cfg = Config { mode: Mode::LmStream, ..Config::default() };
        let shim = crate::coordinator::driver::run(&w2, &cfg, Duration::from_secs(60), None)
            .unwrap();
        assert_eq!(shim.batches.len(), rs[0].batches.len());
        assert_eq!(shim.avg_throughput, rs[0].avg_throughput);
    }

    #[test]
    fn two_queries_share_one_source() {
        let w = workloads::by_name("lr1s").unwrap();
        let window = w.query.window;
        let mut s = session(Mode::LmStream);
        let first = s.register(w).unwrap();
        let agg = QueryBuilder::scan("congestion")
            .window(window)
            .filter("speed", Predicate::Lt(60.0))
            .aggregate(&["segment"], vec![AggSpec::avg("speed", "avgSpeed")], None)
            .build()
            .unwrap();
        s.register_shared(first, "congestion", agg).unwrap();
        let rs = s.run(Duration::from_secs(90)).unwrap();
        assert_eq!(rs.len(), 2);
        // Both queries saw every admitted batch.
        assert_eq!(rs[0].batches.len(), rs[1].batches.len());
        assert!(!rs[0].batches.is_empty());
        assert!(rs[0].avg_throughput > 0.0 && rs[1].avg_throughput > 0.0);
        assert_eq!(rs[1].workload, "congestion");
    }

    #[test]
    fn multi_query_runs_are_deterministic() {
        // Same seed, same registrations → byte-identical outcomes.
        let run_once = || {
            let w = workloads::by_name("lr1s").unwrap();
            let window = w.query.window;
            let mut s = session(Mode::LmStream);
            let first = s.register(w).unwrap();
            let q = QueryBuilder::scan("side")
                .window(window)
                .filter("speed", Predicate::Lt(60.0))
                .build()
                .unwrap();
            s.register_shared(first, "side", q).unwrap();
            s.run(Duration::from_secs(60)).unwrap()
        };
        let a = run_once();
        let b = run_once();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.batches.len(), y.batches.len());
            assert_eq!(x.avg_throughput, y.avg_throughput);
        }
    }

    #[test]
    fn unknown_share_handle_rejected() {
        let mut s = session(Mode::LmStream);
        let q = QueryBuilder::scan("q").build().unwrap();
        assert!(s.register_shared(QueryId(7), "q", q).is_err());
    }

    #[test]
    fn run_with_sink_rejects_unknown_query_id() {
        let mut s = session(Mode::LmStream);
        s.register(workloads::by_name("lr1s").unwrap()).unwrap();
        let mut sink = crate::engine::sink::NullSink;
        let r = s.run_with_sink(Duration::from_secs(5), QueryId(5), &mut sink);
        assert!(r.is_err(), "out-of-range QueryId must error, not no-op");
    }

    #[test]
    fn invalid_config_rejected_at_session_creation() {
        let cfg = Config { num_cores: 0, ..Config::default() };
        assert!(Session::new(cfg).is_err());
    }

    #[test]
    fn branched_query_runs_through_session() {
        let w = workloads::by_name("lr2s").unwrap();
        let window = w.query.window;
        let mut s = session(Mode::LmStream);
        let first = s.register(w).unwrap();
        // One scan fanning out: congestion aggregate + slow-vehicle sort.
        let fanout = QueryBuilder::scan("fanout")
            .window(window)
            .filter("speed", Predicate::Lt(80.0))
            .branch(|b| {
                b.aggregate(&["segment"], vec![AggSpec::count("reports")], None)
            })
            .sort("speed", false)
            .build()
            .unwrap();
        s.register_shared(first, "fanout", fanout).unwrap();
        let rs = s.run(Duration::from_secs(60)).unwrap();
        assert_eq!(rs.len(), 2);
        assert!(!rs[1].batches.is_empty());
    }
}
