//! # LMStream — latency-bounded GPU-enabled micro-batch stream processing
//!
//! Reproduction of *"LMStream: When Distributed Micro-Batch Stream
//! Processing Systems Meet GPU"* (Lee & Park, 2021) as a three-layer
//! Rust + JAX + Pallas system. This crate is the **L3 coordinator**: the
//! streaming substrate (a from-scratch Spark-analog columnar micro-batch
//! engine) plus the paper's three mechanisms:
//!
//! * [`coordinator::admission`] — `ConstructMicroBatch` (Alg. 1): dynamic
//!   batching that bounds per-dataset latency to the window slide time
//!   (sliding) or the running average (tumbling) instead of a static
//!   trigger,
//! * [`coordinator::planner`] — `MapDevice` (Alg. 2): operation-level
//!   CPU/GPU planning from dynamic, data-size-dependent device preference
//!   around an *inflection point*,
//! * [`coordinator::optimizer`] — online regression
//!   `InfPT = β0 + β1·Throughput + β2·Latency` fitted asynchronously on
//!   per-batch history.
//!
//! The public query surface is session-centric: a [`session::Session`]
//! owns the shared coordinator state (device model, online optimizer,
//! PJRT runtime, config) and multiplexes any number of registered
//! queries — logical DAGs that `MapDevice` lowers to per-op
//! device-annotated physical plans — through one micro-batch loop. See
//! `ARCHITECTURE.md` §Query-stack.
//!
//! The "GPU" compute path executes AOT-compiled XLA artifacts (lowered
//! once from JAX/Pallas by `python/compile/aot.py`) through the PJRT C
//! API ([`runtime`]); python is never on the request path. Paper-scale
//! experiments run on a discrete-event virtual clock with a calibrated
//! device timing model ([`devices::model`]) — see `DESIGN.md`
//! §Hardware-Adaptation for the substitution rationale.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod devices;
pub mod durability;
pub mod engine;
pub mod error;
pub mod query;
pub mod report;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod source;
pub mod util;
pub mod workloads;

pub use config::Config;
pub use error::{Error, Result};
pub use session::Session;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
