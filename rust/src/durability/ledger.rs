//! Exactly-once sink ledger.
//!
//! One JSON file per session records, per query, the high-water batch
//! index (and the scheduling round that produced it) whose output has
//! been durably delivered to the sinks. Batch indices are per-query
//! monotone (checkpoint-restored counts keep them monotone *across*
//! incarnations), so a high-water mark is a complete dedup record: on
//! WAL replay the session consults [`SinkLedger::already_delivered`]
//! and skips re-emission, turning at-least-once replay into
//! exactly-once output.
//!
//! Persistence is batched per scheduling round: the session calls
//! [`SinkLedger::record`] after each delivery and [`SinkLedger::persist`]
//! once at the end of the round's delivery loop (atomic replace + fsync
//! file + fsync dir) — and again on the error path before a failed
//! delivery propagates, so deliveries that succeeded earlier in the
//! round are never lost. `persist` is a no-op while nothing changed.
//! The crash window is therefore one round's deliveries, which Precise
//! replay already covers: every batch of an unpersisted round is still
//! in the WAL, so a restart re-executes and re-delivers it exactly once.
//! The remaining window — a crash after the sink accepted a batch but
//! before its round's ledger write hit disk — degrades that round to
//! at-least-once; a transactional sink protocol (two-phase commit with
//! the sink) is the documented follow-up.

use crate::error::{Error, Result};
use crate::util::json::{num, obj, Json};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Per-query delivery high-water.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LedgerEntry {
    /// Scheduling round of the highest delivered batch.
    pub round: u64,
    /// Highest batch index delivered (indices below it are delivered
    /// too — delivery is in index order).
    pub batch: u64,
}

/// Durable record of what each query's sinks have already received.
pub struct SinkLedger {
    path: PathBuf,
    /// Keyed by lowercased query name.
    entries: BTreeMap<String, LedgerEntry>,
    /// Unpersisted records since the last [`SinkLedger::persist`].
    dirty: bool,
    /// Actual disk writes performed (per-round batching pin).
    persists: usize,
}

impl SinkLedger {
    /// Load the ledger at `path`, or start empty if absent.
    pub fn open(path: &Path) -> Result<SinkLedger> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(SinkLedger {
                    path: path.to_path_buf(),
                    entries: BTreeMap::new(),
                    dirty: false,
                    persists: 0,
                })
            }
            Err(e) => return Err(e.into()),
        };
        let j = Json::parse(&text)?;
        let format = j.req("format")?.as_usize().unwrap_or(0);
        if format != 1 {
            return Err(Error::Durability(format!(
                "unsupported sink ledger format {format}"
            )));
        }
        let mut entries = BTreeMap::new();
        if let Some(Json::Obj(queries)) = j.get("queries") {
            for (name, e) in queries {
                entries.insert(
                    name.clone(),
                    LedgerEntry {
                        round: e.req("round")?.as_f64().unwrap_or(0.0) as u64,
                        batch: e.req("batch")?.as_f64().unwrap_or(0.0) as u64,
                    },
                );
            }
        }
        Ok(SinkLedger { path: path.to_path_buf(), entries, dirty: false, persists: 0 })
    }

    /// Highest delivered batch index for `query`, if any delivery has
    /// been recorded.
    pub fn high_water(&self, query: &str) -> Option<LedgerEntry> {
        self.entries.get(&query.to_lowercase()).copied()
    }

    /// True when `batch_index` of `query` has already been durably
    /// delivered (replay must not re-emit it).
    pub fn already_delivered(&self, query: &str, batch_index: u64) -> bool {
        self.high_water(query).is_some_and(|e| e.batch >= batch_index)
    }

    /// Record a delivery (monotone: an older index never regresses the
    /// mark). Call [`SinkLedger::persist`] to make it durable.
    pub fn record(&mut self, query: &str, round: u64, batch_index: u64) {
        let key = query.to_lowercase();
        match self.entries.get_mut(&key) {
            Some(e) if e.batch >= batch_index => {}
            Some(e) => {
                *e = LedgerEntry { round, batch: batch_index };
                self.dirty = true;
            }
            None => {
                self.entries.insert(key, LedgerEntry { round, batch: batch_index });
                self.dirty = true;
            }
        }
    }

    /// Durably persist: write-temp → fsync temp → rename → fsync dir
    /// (the same ordering invariant the checkpoint store states).
    /// No-op while nothing changed since the last persist — the session
    /// calls this once per round (and on the deliver-error path), not
    /// per delivery.
    pub fn persist(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        let queries = Json::Obj(
            self.entries
                .iter()
                .map(|(name, e)| {
                    (
                        name.clone(),
                        obj(vec![
                            ("round", num(e.round as f64)),
                            ("batch", num(e.batch as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let doc = obj(vec![("format", num(1.0)), ("queries", queries)]);
        let tmp = self.path.with_extension("json.tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(doc.render().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &self.path)?;
        super::wal::sync_parent_dir(&self.path)?;
        self.dirty = false;
        self.persists += 1;
        Ok(())
    }

    /// How many disk writes [`SinkLedger::persist`] actually performed
    /// (skipped clean persists don't count) — pins the one-persist-per-
    /// round batching.
    pub fn persists(&self) -> usize {
        self.persists
    }

    /// All recorded entries (report/printing surface), in name order.
    pub fn entries(&self) -> impl Iterator<Item = (&str, LedgerEntry)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger_path(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("lmstream-ledger-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d.join("sink.ledger.json")
    }

    #[test]
    fn record_persist_reload_round_trip() {
        let path = ledger_path("roundtrip");
        let mut l = SinkLedger::open(&path).unwrap();
        assert!(l.high_water("q").is_none());
        l.record("Q", 3, 5);
        l.record("side", 3, 2);
        l.persist().unwrap();

        let l2 = SinkLedger::open(&path).unwrap();
        assert_eq!(l2.high_water("q"), Some(LedgerEntry { round: 3, batch: 5 }));
        assert!(l2.already_delivered("q", 5));
        assert!(l2.already_delivered("q", 0));
        assert!(!l2.already_delivered("q", 6));
        assert!(!l2.already_delivered("other", 0));
        assert_eq!(l2.entries().count(), 2);
    }

    #[test]
    fn high_water_is_monotone() {
        let path = ledger_path("monotone");
        let mut l = SinkLedger::open(&path).unwrap();
        l.record("q", 9, 7);
        l.record("q", 2, 3); // stale replay record: must not regress
        assert_eq!(l.high_water("q"), Some(LedgerEntry { round: 9, batch: 7 }));
    }

    #[test]
    fn index_zero_delivery_is_recorded() {
        // batch 0 delivered vs nothing delivered are distinct states.
        let path = ledger_path("zero");
        let mut l = SinkLedger::open(&path).unwrap();
        assert!(!l.already_delivered("q", 0));
        l.record("q", 1, 0);
        assert!(l.already_delivered("q", 0));
        assert!(!l.already_delivered("q", 1));
    }

    #[test]
    fn clean_persist_is_a_no_op_and_persists_are_counted() {
        let path = ledger_path("batch");
        let mut l = SinkLedger::open(&path).unwrap();
        // Nothing recorded: no write, no file.
        l.persist().unwrap();
        assert_eq!(l.persists(), 0);
        assert!(!path.exists());

        // Many records, one round-end persist: one disk write.
        l.record("a", 1, 0);
        l.record("b", 1, 0);
        l.record("c", 1, 0);
        l.persist().unwrap();
        assert_eq!(l.persists(), 1);
        l.persist().unwrap();
        assert_eq!(l.persists(), 1, "clean persist must not rewrite");

        // A stale (monotone-suppressed) record does not dirty the ledger.
        l.record("a", 1, 0);
        l.persist().unwrap();
        assert_eq!(l.persists(), 1);

        l.record("a", 2, 1);
        l.persist().unwrap();
        assert_eq!(l.persists(), 2);
        let l2 = SinkLedger::open(&path).unwrap();
        assert_eq!(l2.high_water("a"), Some(LedgerEntry { round: 2, batch: 1 }));
        assert_eq!(l2.persists(), 0, "persist count is per-instance");
    }

    #[test]
    fn corrupt_ledger_rejected() {
        let path = ledger_path("corrupt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, "{\"format\": 9}").unwrap();
        assert!(matches!(
            SinkLedger::open(&path),
            Err(Error::Durability(_))
        ));
        std::fs::write(&path, "not json").unwrap();
        assert!(SinkLedger::open(&path).is_err());
    }
}
