//! Durability: the failure story the engine previously lacked.
//!
//! §III-E treats checkpointing as a post-batch side task; production
//! micro-batch streaming is defined by its fault-tolerance semantics
//! (SNIPPETS.md §1 on Spark/Dataflow exactly-once mechanics). Three
//! parts compose the pipeline:
//!
//! * [`wal`] — a per-source **write-ahead log**: every admitted
//!   micro-batch is appended (length-prefixed, CRC-checksummed) and
//!   fsynced *before* execution, so replay from the last checkpoint is
//!   deterministic;
//! * [`ledger`] — an **exactly-once sink ledger**: the high-water
//!   (query, round, batch-index) durably delivered; the session skips
//!   re-delivery on replay, turning at-least-once WAL replay into
//!   exactly-once output;
//! * [`recover`] — the **recovery driver**: on restart it reconciles
//!   checkpoint ⨯ WAL ⨯ ledger into one of three explicit modes (the
//!   SNIPPETS.md §3 taxonomy), selected by
//!   [`Config::recovery_mode`](crate::config::Config::recovery_mode).
//!
//! The session activates all three when
//! [`Config::wal_dir`](crate::config::Config::wal_dir) is set; without
//! it, behavior is byte-identical to the pre-durability engine.

pub mod ledger;
pub mod recover;
pub mod wal;

pub use ledger::SinkLedger;
pub use recover::{reconcile, LossEntry, RecoveryReport, SourceRecovery, WalPosition};
pub use wal::{ScanEntry, Wal, WalRecord, WalScan};

use crate::error::{Error, Result};

/// How a restart treats the gap between the last checkpoint and the
/// crash point (SNIPPETS.md §3's recovery taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryMode {
    /// Replay every logged-but-uncheckpointed micro-batch from the WAL;
    /// the sink ledger suppresses re-delivery, so a failure has no
    /// visible effect on the output stream beyond latency.
    Precise,
    /// Roll back to the checkpoint: tail batches whose output every
    /// query already delivered (per the ledger) are *skipped* — not
    /// re-executed — and only the undelivered remainder replays. Sink
    /// output stays exactly-once, but internal state (windows, metric
    /// records) diverges from the uninterrupted run: side effects
    /// without information loss.
    Rollback,
    /// Resume from the live stream only: nothing replays, and every
    /// logged-but-undelivered batch is reported as an accounted loss
    /// (amnesia with a receipt).
    Gap,
}

impl RecoveryMode {
    /// Parse a CLI token.
    pub fn parse(s: &str) -> Result<RecoveryMode> {
        match s {
            "precise" => Ok(RecoveryMode::Precise),
            "rollback" => Ok(RecoveryMode::Rollback),
            "gap" => Ok(RecoveryMode::Gap),
            other => Err(Error::Config(format!("unknown recovery mode `{other}`"))),
        }
    }

    /// Display name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryMode::Precise => "precise",
            RecoveryMode::Rollback => "rollback",
            RecoveryMode::Gap => "gap",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_round_trip() {
        for m in [RecoveryMode::Precise, RecoveryMode::Rollback, RecoveryMode::Gap] {
            assert_eq!(RecoveryMode::parse(m.name()).unwrap(), m);
        }
        assert!(RecoveryMode::parse("bogus").is_err());
    }
}
