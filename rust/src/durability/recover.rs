//! Recovery driver: reconcile checkpoint ⨯ WAL ⨯ ledger on restart.
//!
//! Per source, [`reconcile`] takes what the last checkpoint claims
//! (`wal_high_water`: WAL records at or below it are fully processed
//! *and* checkpointed), what the WAL actually holds (the open-time
//! [`WalScan`]), and what the sink ledger proves was delivered, and
//! resolves them under the configured [`RecoveryMode`]:
//!
//! * **Precise** — the whole uncheckpointed tail replays; the ledger
//!   suppresses re-delivery. Requires an intact, contiguous tail
//!   (corrupt records, sequence gaps, or a ledger that claims
//!   deliveries beyond the replayable range are typed
//!   [`Error::Durability`] failures — precise recovery cannot invent
//!   the missing bytes).
//! * **Rollback** — the tail prefix every query of the source already
//!   delivered (per the ledger) is skipped outright — not re-executed —
//!   and only the undelivered remainder replays. Same intactness
//!   requirements: rollback trades internal-state fidelity for work,
//!   never output loss.
//! * **Gap** — nothing replays. Every tail record (including corrupt
//!   ones and inferred sequence gaps) becomes a [`LossEntry`], so the
//!   loss is *accounted*, batch id by batch id, rather than silent.
//!
//! The returned [`SourceRecovery`] also carries the stream
//! fast-forward horizon (checkpoint horizon ∪ newest logged
//! `created_at` — logged data must not regenerate from the source, in
//! any mode: replayed it would duplicate, lost it is lost) and the
//! per-query batch-index bases the session must seed so live indices
//! never collide with ledger-recorded deliveries.

use super::ledger::SinkLedger;
use super::wal::{ScanEntry, WalRecord, WalScan};
use super::RecoveryMode;
use crate::error::{Error, Result};
use crate::sim::Time;
use crate::util::json::{arr, num, obj, s, Json};

/// What the checkpoint knew about the WAL when it was written.
#[derive(Clone, Copy, Debug, Default)]
pub struct WalPosition {
    /// Highest WAL sequence number whose batch the checkpoint covers.
    pub wal_high_water: u64,
    /// Stream horizon the checkpoint persisted.
    pub processed_up_to: Time,
}

/// One batch that did not survive recovery (Gap mode), identified well
/// enough to audit: which WAL record, which datasets, how many rows.
#[derive(Clone, Debug)]
pub struct LossEntry {
    pub seq: u64,
    pub round: usize,
    pub dataset_ids: Vec<u64>,
    pub rows: usize,
    /// Why it was lost: `"not replayed (gap mode)"`, `"crc mismatch"`,
    /// `"missing wal records"`, ...
    pub reason: String,
}

/// The reconciled plan for one source.
#[derive(Debug)]
pub struct SourceRecovery {
    /// Source name (its primary query's name).
    pub source: String,
    pub mode: RecoveryMode,
    /// Records to re-execute, in sequence order (empty in Gap mode).
    pub replay: Vec<WalRecord>,
    /// Rollback: tail records skipped because every query of the source
    /// already delivered their output.
    pub skipped: u64,
    /// Gap: accounted losses.
    pub lost: Vec<LossEntry>,
    /// Stream fast-forward horizon (max of checkpoint horizon and the
    /// newest logged dataset creation time).
    pub horizon: Time,
    /// Highest WAL seq the *next* checkpoint may immediately truncate
    /// through (already-checkpointed prefix, plus skipped records in
    /// Rollback, plus accounted records in Gap).
    pub checkpointed_through: u64,
    /// Per query (in the order given to [`reconcile`]): the batch-index
    /// base the session must seed its metrics to before replaying, so
    /// replayed and live indices line up with the ledger.
    pub batch_base: Vec<(String, usize)>,
    /// Torn trailing bytes the WAL scan truncated away (that data was
    /// never durably admitted; the stream regenerates it).
    pub torn_tail_bytes: usize,
}

impl SourceRecovery {
    /// Render an audit summary (the session writes one per recovery).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("source", s(&self.source)),
            ("mode", s(self.mode.name())),
            ("replayed", num(self.replay.len() as f64)),
            ("skipped", num(self.skipped as f64)),
            ("torn_tail_bytes", num(self.torn_tail_bytes as f64)),
            ("horizon_ns", num(self.horizon.0 as f64)),
            (
                "lost",
                arr(self
                    .lost
                    .iter()
                    .map(|l| {
                        obj(vec![
                            ("seq", num(l.seq as f64)),
                            ("round", num(l.round as f64)),
                            (
                                "dataset_ids",
                                arr(l
                                    .dataset_ids
                                    .iter()
                                    .map(|&id| num(id as f64))
                                    .collect()),
                            ),
                            ("rows", num(l.rows as f64)),
                            ("reason", s(&l.reason)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Everything one restart reconciled, across sources.
#[derive(Debug, Default)]
pub struct RecoveryReport {
    pub sources: Vec<SourceRecovery>,
}

impl RecoveryReport {
    /// Total accounted losses across sources.
    pub fn total_lost_rows(&self) -> usize {
        self.sources.iter().flat_map(|s| &s.lost).map(|l| l.rows).sum()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![(
            "sources",
            arr(self.sources.iter().map(|s| s.to_json()).collect()),
        )])
    }
}

/// Reconcile one source's checkpoint, WAL scan, and the sink ledger
/// into a recovery plan. `queries` lists the source's queries in
/// registration order with their checkpoint-restored batch counts (the
/// index the next recorded batch would take).
pub fn reconcile(
    source: &str,
    ckpt: Option<WalPosition>,
    scan: WalScan,
    ledger: &SinkLedger,
    mode: RecoveryMode,
    queries: &[(String, usize)],
) -> Result<SourceRecovery> {
    let pos = ckpt.unwrap_or_default();
    let wal_high = pos.wal_high_water;
    let mut lost: Vec<LossEntry> = Vec::new();

    // The horizon must cover every durably logged record — replayed or
    // lost, its data must not regenerate from the live stream.
    let mut horizon = pos.processed_up_to;
    for e in &scan.entries {
        if let ScanEntry::Ok(r) = e {
            if let Some(newest) = r.batch.datasets.iter().map(|d| d.created_at).max() {
                horizon = horizon.max(newest);
            }
        }
    }

    // Partition the scan: records at or below the checkpoint's
    // high-water are done (they survive in the file only until the next
    // truncation); the rest is the crash tail.
    let mut tail: Vec<WalRecord> = Vec::new();
    for e in scan.entries {
        match e {
            ScanEntry::Ok(r) if r.seq <= wal_high => {}
            ScanEntry::Ok(r) => tail.push(r),
            ScanEntry::Corrupt { offset, inferred_seq, reason } => match mode {
                RecoveryMode::Gap => lost.push(LossEntry {
                    seq: inferred_seq,
                    round: 0,
                    dataset_ids: Vec::new(),
                    rows: 0,
                    reason: format!("corrupt wal record at byte {offset}: {reason}"),
                }),
                _ => {
                    return Err(Error::Durability(format!(
                        "source `{source}`: corrupt WAL record at byte {offset} \
                         ({reason}) — {} recovery cannot reconstruct it \
                         (use gap mode to resume with accounted loss)",
                        mode.name()
                    )))
                }
            },
        }
    }

    // Contiguity: the tail must continue exactly where the checkpoint
    // stopped. A gap means the checkpoint and the log disagree about
    // what was admitted.
    let mut expected = wal_high + 1;
    for r in &tail {
        if r.seq != expected {
            match mode {
                RecoveryMode::Gap => {
                    lost.push(LossEntry {
                        seq: expected,
                        round: 0,
                        dataset_ids: Vec::new(),
                        rows: 0,
                        reason: format!(
                            "missing wal records [{expected}, {}) — \
                             checkpoint/WAL position mismatch",
                            r.seq
                        ),
                    });
                    expected = r.seq;
                }
                _ => {
                    return Err(Error::Durability(format!(
                        "source `{source}`: checkpoint/WAL position mismatch — \
                         expected seq {expected}, log holds {} ({} recovery \
                         requires a contiguous tail)",
                        r.seq,
                        mode.name()
                    )))
                }
            }
        }
        expected = r.seq + 1;
    }

    let last_seq = tail.last().map(|r| r.seq).unwrap_or(wal_high);
    let tail_len = tail.len();

    // The ledger cannot claim deliveries the log can't reproduce:
    // each tail record advances every query's batch index by exactly
    // one, so the replayable index range per query is
    // [base, base + tail_len).
    if mode != RecoveryMode::Gap {
        for (name, base) in queries {
            if let Some(hw) = ledger.high_water(name) {
                if hw.batch >= (*base as u64) + tail_len as u64 {
                    return Err(Error::Durability(format!(
                        "source `{source}`: sink ledger for `{name}` is ahead of \
                         the WAL (delivered through batch {}, replayable range \
                         ends at {}) — the log was truncated past delivered, \
                         uncheckpointed batches",
                        hw.batch,
                        *base as u64 + tail_len as u64
                    )));
                }
            }
        }
    }

    let (replay, skipped, batch_base) = match mode {
        RecoveryMode::Precise => {
            // Replay everything; the ledger gates re-delivery downstream.
            let base = queries.to_vec();
            (tail, 0u64, base)
        }
        RecoveryMode::Rollback => {
            // Skip the prefix whose output every query already has.
            let mut skip = 0usize;
            'prefix: while skip < tail_len {
                for (name, base) in queries {
                    let idx = (*base + skip) as u64;
                    if !ledger.already_delivered(name, idx) {
                        break 'prefix;
                    }
                }
                skip += 1;
            }
            let replay = tail.into_iter().skip(skip).collect();
            let base = queries
                .iter()
                .map(|(n, b)| (n.clone(), b + skip))
                .collect();
            (replay, skip as u64, base)
        }
        RecoveryMode::Gap => {
            // Nothing replays; account every tail record as lost, and
            // bump each query past any ledger-recorded delivery so live
            // batches never collide with (and get suppressed by) it.
            for r in &tail {
                lost.push(LossEntry {
                    seq: r.seq,
                    round: r.round,
                    dataset_ids: r.batch.datasets.iter().map(|d| d.id).collect(),
                    rows: r.batch.rows(),
                    reason: "not replayed (gap mode)".into(),
                });
            }
            let base = queries
                .iter()
                .map(|(n, b)| {
                    let floor = ledger
                        .high_water(n)
                        .map(|hw| hw.batch as usize + 1)
                        .unwrap_or(0);
                    (n.clone(), (*b).max(floor))
                })
                .collect();
            (Vec::new(), 0u64, base)
        }
    };

    let checkpointed_through = match mode {
        RecoveryMode::Precise => wal_high,
        RecoveryMode::Rollback => wal_high + skipped,
        RecoveryMode::Gap => last_seq,
    };

    Ok(SourceRecovery {
        source: source.to_string(),
        mode,
        replay,
        skipped,
        lost,
        horizon,
        checkpointed_through,
        batch_base,
        torn_tail_bytes: scan.torn_tail_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};
    use crate::engine::dataset::{Dataset, MicroBatch};

    fn rec(seq: u64, ids: &[u64]) -> WalRecord {
        let datasets = ids
            .iter()
            .map(|&id| {
                let schema = Schema::new(vec![Field::f32("x")]);
                Dataset {
                    id,
                    created_at: Time::from_secs_f64(id as f64),
                    event_time: Time::from_secs_f64(id as f64),
                    wire_bytes: 8,
                    batch: ColumnBatch::new(
                        schema,
                        vec![Column::F32(vec![id as f32, 0.0].into())],
                    )
                    .unwrap(),
                }
            })
            .collect();
        WalRecord { seq, round: seq as usize, batch: MicroBatch::new(datasets) }
    }

    fn scan(recs: Vec<WalRecord>) -> WalScan {
        WalScan {
            entries: recs.into_iter().map(ScanEntry::Ok).collect(),
            torn_tail_bytes: 0,
        }
    }

    fn ledger_with(entries: &[(&str, u64)]) -> SinkLedger {
        let d = std::env::temp_dir().join(format!(
            "lmstream-reconcile-{}-{}",
            entries.len(),
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        let mut l = SinkLedger::open(&d.join("l.json")).unwrap();
        for (name, batch) in entries {
            l.record(name, 0, *batch);
        }
        l
    }

    fn pos(high: u64) -> Option<WalPosition> {
        Some(WalPosition { wal_high_water: high, processed_up_to: Time::ZERO })
    }

    #[test]
    fn precise_replays_whole_tail() {
        let l = ledger_with(&[("q", 2)]);
        let qs = vec![("q".to_string(), 2usize)];
        let r = reconcile(
            "q",
            pos(2),
            scan(vec![rec(1, &[0]), rec(2, &[1]), rec(3, &[2]), rec(4, &[3])]),
            &l,
            RecoveryMode::Precise,
            &qs,
        )
        .unwrap();
        // Seqs 1–2 are checkpointed; 3–4 replay.
        assert_eq!(r.replay.iter().map(|x| x.seq).collect::<Vec<_>>(), vec![3, 4]);
        assert_eq!(r.skipped, 0);
        assert!(r.lost.is_empty());
        assert_eq!(r.checkpointed_through, 2);
        assert_eq!(r.batch_base, qs);
        assert_eq!(r.horizon, Time::from_secs_f64(3.0));
    }

    #[test]
    fn rollback_skips_fully_delivered_prefix() {
        // base 2; tail indices are 2,3 — ledger says q delivered
        // through 2, side through 2: record at index 2 skips, 3 replays.
        let l = ledger_with(&[("q", 2), ("side", 2)]);
        let qs = vec![("q".to_string(), 2usize), ("side".to_string(), 2usize)];
        let r = reconcile(
            "q",
            pos(2),
            scan(vec![rec(3, &[2]), rec(4, &[3])]),
            &l,
            RecoveryMode::Rollback,
            &qs,
        )
        .unwrap();
        assert_eq!(r.skipped, 1);
        assert_eq!(r.replay.iter().map(|x| x.seq).collect::<Vec<_>>(), vec![4]);
        assert_eq!(r.checkpointed_through, 3);
        assert_eq!(r.batch_base[0].1, 3);
        assert_eq!(r.batch_base[1].1, 3);
    }

    #[test]
    fn rollback_partial_delivery_does_not_skip() {
        // side never delivered index 2 → the record must replay (the
        // ledger will suppress q's re-emission downstream).
        let l = ledger_with(&[("q", 2)]);
        let qs = vec![("q".to_string(), 2usize), ("side".to_string(), 2usize)];
        let r = reconcile(
            "q",
            pos(2),
            scan(vec![rec(3, &[2])]),
            &l,
            RecoveryMode::Rollback,
            &qs,
        )
        .unwrap();
        assert_eq!(r.skipped, 0);
        assert_eq!(r.replay.len(), 1);
    }

    #[test]
    fn gap_accounts_every_tail_record() {
        let l = ledger_with(&[("q", 2)]);
        let qs = vec![("q".to_string(), 2usize)];
        let r = reconcile(
            "q",
            pos(2),
            scan(vec![rec(3, &[2, 5]), rec(4, &[6])]),
            &l,
            RecoveryMode::Gap,
            &qs,
        )
        .unwrap();
        assert!(r.replay.is_empty());
        assert_eq!(r.lost.len(), 2);
        assert_eq!(r.lost[0].dataset_ids, vec![2, 5]);
        assert_eq!(r.lost[0].rows, 4);
        assert_eq!(r.lost[1].dataset_ids, vec![6]);
        // Live batches start above the ledger's high-water.
        assert_eq!(r.batch_base[0].1, 3);
        assert_eq!(r.checkpointed_through, 4);
        // Lost data is inside the horizon: amnesia, not duplication.
        assert_eq!(r.horizon, Time::from_secs_f64(6.0));
    }

    #[test]
    fn corrupt_record_fatal_except_in_gap() {
        let l = ledger_with(&[]);
        let qs = vec![("q".to_string(), 0usize)];
        let entries = || WalScan {
            entries: vec![
                ScanEntry::Ok(rec(1, &[0])),
                ScanEntry::Corrupt {
                    offset: 99,
                    inferred_seq: 2,
                    reason: "crc mismatch".into(),
                },
            ],
            torn_tail_bytes: 0,
        };
        for mode in [RecoveryMode::Precise, RecoveryMode::Rollback] {
            let err = reconcile("q", pos(0), entries(), &l, mode, &qs).unwrap_err();
            assert!(matches!(err, Error::Durability(_)), "{err:?}");
            assert!(err.to_string().contains("corrupt"), "{err}");
        }
        let r = reconcile("q", pos(0), entries(), &l, RecoveryMode::Gap, &qs).unwrap();
        assert!(r.lost.iter().any(|x| x.reason.contains("corrupt")));
    }

    #[test]
    fn position_mismatch_fatal_except_in_gap() {
        let l = ledger_with(&[]);
        let qs = vec![("q".to_string(), 0usize)];
        // Checkpoint says high-water 1, but the log starts at 3.
        for mode in [RecoveryMode::Precise, RecoveryMode::Rollback] {
            let err = reconcile("q", pos(1), scan(vec![rec(3, &[2])]), &l, mode, &qs)
                .unwrap_err();
            assert!(matches!(err, Error::Durability(_)), "{err:?}");
            assert!(err.to_string().contains("mismatch"), "{err}");
        }
        let r = reconcile("q", pos(1), scan(vec![rec(3, &[2])]), &l, RecoveryMode::Gap, &qs)
            .unwrap();
        assert!(r.lost.iter().any(|x| x.reason.contains("missing wal records")));
    }

    #[test]
    fn ledger_beyond_replayable_range_fatal_except_in_gap() {
        // Ledger claims delivery through batch 5 but base 0 + 2 tail
        // records only reproduce indices 0–1.
        let l = ledger_with(&[("q", 5)]);
        let qs = vec![("q".to_string(), 0usize)];
        for mode in [RecoveryMode::Precise, RecoveryMode::Rollback] {
            let err = reconcile(
                "q",
                pos(0),
                scan(vec![rec(1, &[0]), rec(2, &[1])]),
                &l,
                mode,
                &qs,
            )
            .unwrap_err();
            assert!(matches!(err, Error::Durability(_)), "{err:?}");
            assert!(err.to_string().contains("ahead"), "{err}");
        }
        let r = reconcile(
            "q",
            pos(0),
            scan(vec![rec(1, &[0]), rec(2, &[1])]),
            &l,
            RecoveryMode::Gap,
            &qs,
        )
        .unwrap();
        // Live indices start above the ledger mark.
        assert_eq!(r.batch_base[0].1, 6);
    }

    #[test]
    fn empty_everything_is_a_clean_start() {
        let l = ledger_with(&[]);
        let qs = vec![("q".to_string(), 0usize)];
        for mode in [RecoveryMode::Precise, RecoveryMode::Rollback, RecoveryMode::Gap] {
            let r = reconcile("q", None, WalScan::default(), &l, mode, &qs).unwrap();
            assert!(r.replay.is_empty() && r.lost.is_empty());
            assert_eq!(r.checkpointed_through, 0);
            assert_eq!(r.horizon, Time::ZERO);
        }
    }
}
