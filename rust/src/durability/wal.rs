//! Per-source write-ahead log.
//!
//! Layout: an 8-byte magic header (`LMWAL01\n`) followed by framed
//! records — `[u32 LE payload_len][u32 LE crc32(payload)][payload]`.
//! The payload is a JSON document (the in-repo writer; serde is
//! unavailable offline) carrying the record's monotone sequence number,
//! the scheduling round it was admitted in, and the *full* micro-batch
//! content — per-dataset ids, timestamps, schema, columns, and validity
//! mask — so replay re-executes exactly the bytes that were admitted,
//! independent of the source generator's state.
//!
//! Append durability: [`Wal::append`] writes the frame and fsyncs
//! before returning, so by the time a batch executes its log record is
//! on stable storage. When one round admits several batches for a
//! source, the session instead *group-commits*: each batch is framed
//! with [`Wal::append_deferred`] and a single [`Wal::commit`] fsync
//! per source per round makes them all durable before any of them
//! executes — same append-before-execute ordering, one sync instead of
//! N (mirroring the sink ledger's one-persist-per-round batching). A
//! crash between a deferred append and its commit can tear the
//! *uncommitted* tail only — none of those batches had started
//! executing, and the stream regenerates them deterministically. A
//! crash mid-append leaves a *torn tail* — an
//! incomplete final frame — which [`Wal::open`]'s scan detects (length
//! prefix exceeds the remaining bytes) and cleanly truncates away; a
//! complete frame whose CRC mismatches is a *corrupt record*, surfaced
//! as [`ScanEntry::Corrupt`] for the recovery driver to judge by mode.
//!
//! Checkpoint upkeep calls [`Wal::truncate_through`] to drop records
//! the checkpoint now covers; the log is rewritten atomically
//! (write-temp → fsync → rename → fsync dir) from the retained frames,
//! so it stays one checkpoint interval long.

use crate::engine::column::{Column, ColumnBatch, DType, Field, Schema, Validity};
use crate::engine::dataset::{Dataset, MicroBatch};
use crate::error::{Error, Result};
use crate::sim::Time;
use crate::util::json::{arr, num, obj, s, Json};
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

/// File magic: identifies a WAL and pins its framing version.
const MAGIC: &[u8; 8] = b"LMWAL01\n";

/// CRC-32 (IEEE 802.3, reflected) lookup table, built once.
fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        table
    })
}

/// CRC-32 checksum of `bytes` (IEEE polynomial).
pub fn crc32(bytes: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// One logged admission: which batch (by per-source sequence number),
/// which scheduling round admitted it, and its full content.
#[derive(Clone, Debug)]
pub struct WalRecord {
    /// Per-source monotone sequence number (1-based; the checkpoint's
    /// `wal_high_water` is "processed through this seq").
    pub seq: u64,
    /// Scheduling round the batch was admitted in
    /// ([`BatchRecord::round`](crate::coordinator::metrics::BatchRecord::round)).
    pub round: usize,
    /// The admitted micro-batch, bit-reconstructible.
    pub batch: MicroBatch,
}

/// One scanned frame: either a valid record or a corrupt one (complete
/// frame, bad CRC / unparseable payload). `inferred_seq` positions a
/// corrupt record for loss accounting: the previous readable seq + 1.
#[derive(Debug)]
pub enum ScanEntry {
    Ok(WalRecord),
    Corrupt { offset: usize, inferred_seq: u64, reason: String },
}

/// Result of scanning a log at open.
#[derive(Debug, Default)]
pub struct WalScan {
    pub entries: Vec<ScanEntry>,
    /// Bytes of an incomplete final frame (torn by a crash mid-append);
    /// already truncated off the file by the time `open` returns.
    pub torn_tail_bytes: usize,
}

impl WalScan {
    /// Highest readable sequence number (0 when the log is empty).
    pub fn last_seq(&self) -> u64 {
        self.entries
            .iter()
            .map(|e| match e {
                ScanEntry::Ok(r) => r.seq,
                ScanEntry::Corrupt { inferred_seq, .. } => *inferred_seq,
            })
            .max()
            .unwrap_or(0)
    }
}

/// An open, appendable write-ahead log for one source.
pub struct Wal {
    path: PathBuf,
    file: File,
    /// Every complete valid frame currently in the file, by seq —
    /// retained so [`Wal::truncate_through`] can rewrite without
    /// re-reading. Checkpoint-interval sized (truncated every round).
    pending: Vec<(u64, Vec<u8>)>,
    /// Corrupt frames were scanned at open: force a rewrite on the next
    /// truncation even if no pending frame is dropped, so they leave
    /// the file.
    dirty: bool,
    next_seq: u64,
    /// Frames written by [`Wal::append_deferred`] since the last
    /// [`Wal::commit`] — not yet durable.
    deferred: bool,
    /// Data-path fsyncs issued so far ([`Wal::append`] /
    /// [`Wal::commit`]; open/rewrite maintenance syncs excluded) —
    /// what the group-commit tests pin.
    fsyncs: usize,
}

impl Wal {
    /// Open (creating if absent) the log at `path`, scanning existing
    /// records. A torn final frame is truncated off the file here; the
    /// scan reports it and any corrupt (CRC-mismatch) records for the
    /// recovery driver.
    pub fn open(path: &Path) -> Result<(Wal, WalScan)> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };
        let fresh = bytes.is_empty();
        if !fresh && (bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC) {
            return Err(Error::Durability(format!(
                "{}: not a WAL (bad magic header)",
                path.display()
            )));
        }

        let mut scan = WalScan::default();
        let mut pending = Vec::new();
        let mut dirty = false;
        let mut pos = if fresh { 0 } else { MAGIC.len() };
        let mut last_seq = 0u64;
        let mut end_of_complete = pos;
        while pos < bytes.len() {
            if bytes.len() - pos < 8 {
                scan.torn_tail_bytes = bytes.len() - pos;
                break;
            }
            let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
            if bytes.len() - pos - 8 < len {
                scan.torn_tail_bytes = bytes.len() - pos;
                break;
            }
            let payload = &bytes[pos + 8..pos + 8 + len];
            let frame_end = pos + 8 + len;
            if crc32(payload) != crc {
                last_seq += 1;
                scan.entries.push(ScanEntry::Corrupt {
                    offset: pos,
                    inferred_seq: last_seq,
                    reason: "crc mismatch".into(),
                });
                dirty = true;
            } else {
                match parse_record(payload) {
                    Ok(rec) => {
                        last_seq = rec.seq;
                        pending.push((rec.seq, bytes[pos..frame_end].to_vec()));
                        scan.entries.push(ScanEntry::Ok(rec));
                    }
                    Err(e) => {
                        last_seq += 1;
                        scan.entries.push(ScanEntry::Corrupt {
                            offset: pos,
                            inferred_seq: last_seq,
                            reason: format!("bad payload: {e}"),
                        });
                        dirty = true;
                    }
                }
            }
            pos = frame_end;
            end_of_complete = frame_end;
        }

        let mut file = OpenOptions::new().create(true).append(true).open(path)?;
        if fresh {
            file.write_all(MAGIC)?;
            file.sync_all()?;
            sync_parent_dir(path)?;
        } else if scan.torn_tail_bytes > 0 {
            // Drop the torn frame so future appends start on a clean
            // frame boundary (its data was never durably admitted; the
            // stream regenerates it deterministically).
            file.set_len(end_of_complete as u64)?;
            file.sync_all()?;
        }
        let next_seq = scan.last_seq() + 1;
        let wal = Wal {
            path: path.to_path_buf(),
            file,
            pending,
            dirty,
            next_seq,
            deferred: false,
            fsyncs: 0,
        };
        Ok((wal, scan))
    }

    /// Sequence number the next [`Wal::append`] will assign.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Append one admitted micro-batch and fsync — returns its assigned
    /// sequence number. Callers must not start executing the batch
    /// before this returns (the WAL's one ordering invariant).
    pub fn append(&mut self, round: usize, batch: &MicroBatch) -> Result<u64> {
        let seq = self.append_deferred(round, batch)?;
        self.commit()?;
        Ok(seq)
    }

    /// Write one admitted micro-batch's frame *without* syncing —
    /// returns its assigned sequence number. The record is not durable
    /// until the next [`Wal::commit`]; callers must not start executing
    /// the batch before that commit returns (group-commit form of the
    /// append-before-execute invariant).
    pub fn append_deferred(&mut self, round: usize, batch: &MicroBatch) -> Result<u64> {
        let seq = self.next_seq;
        let payload = render_record(seq, round, batch).into_bytes();
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        self.pending.push((seq, frame));
        self.next_seq = seq + 1;
        self.deferred = true;
        Ok(seq)
    }

    /// Make every deferred append durable with one fsync. No-op (and no
    /// fsync) when nothing is deferred.
    pub fn commit(&mut self) -> Result<()> {
        if !self.deferred {
            return Ok(());
        }
        self.file.sync_all()?;
        self.deferred = false;
        self.fsyncs += 1;
        Ok(())
    }

    /// Data-path fsyncs issued so far: one per [`Wal::append`], one per
    /// non-empty [`Wal::commit`] group. Maintenance syncs (open-time
    /// header/truncation, checkpoint rewrites) are not counted.
    pub fn fsyncs(&self) -> usize {
        self.fsyncs
    }

    /// Drop every record with `seq <= upto` (the checkpoint now covers
    /// them), rewriting the log atomically. No-op when nothing would
    /// change.
    pub fn truncate_through(&mut self, upto: u64) -> Result<()> {
        let before = self.pending.len();
        self.pending.retain(|(seq, _)| *seq > upto);
        if self.pending.len() == before && !self.dirty {
            return Ok(());
        }
        self.rewrite()
    }

    /// Current on-disk size of the log: header plus every complete
    /// frame (what a fresh open would find; torn bytes are gone).
    pub fn size_bytes(&self) -> u64 {
        (MAGIC.len() + self.pending.iter().map(|(_, f)| f.len()).sum::<usize>()) as u64
    }

    /// Complete frames currently in the log.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Roll the log under `cap` bytes by dropping the *oldest* frames
    /// (Gap mode's answer to an un-truncatable log: bounded disk over
    /// replayability — the next recovery accounts the dropped range as
    /// loss). The newest frame is always kept even if it alone exceeds
    /// `cap`. Returns how many frames were dropped; the file is
    /// rewritten atomically only when the cap forces drops.
    pub fn roll_to_cap(&mut self, cap: u64) -> Result<usize> {
        let mut dropped = 0usize;
        while self.pending.len() > 1 && self.size_bytes() > cap {
            self.pending.remove(0);
            dropped += 1;
        }
        if dropped == 0 {
            return Ok(0);
        }
        self.rewrite()?;
        Ok(dropped)
    }

    /// Rewrite the file from `pending`: write-temp → fsync → rename →
    /// fsync dir, then reopen for appending.
    fn rewrite(&mut self) -> Result<()> {
        let tmp = self.path.with_extension("wal.tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(MAGIC)?;
            for (_, frame) in &self.pending {
                f.write_all(frame)?;
            }
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &self.path)?;
        sync_parent_dir(&self.path)?;
        self.file = OpenOptions::new().append(true).open(&self.path)?;
        self.dirty = false;
        // The rewrite synced every pending frame — deferred appends
        // included — so there is nothing left for a commit to flush.
        self.deferred = false;
        Ok(())
    }
}

/// fsync the directory holding `path`, making a rename/create durable.
pub(crate) fn sync_parent_dir(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            File::open(parent)?.sync_all()?;
        }
    }
    Ok(())
}

// ---- Record payload (de)serialization -------------------------------

fn render_record(seq: u64, round: usize, batch: &MicroBatch) -> String {
    let datasets = batch
        .datasets
        .iter()
        .map(|d| {
            let schema = arr(d
                .batch
                .schema
                .fields
                .iter()
                .map(|f| {
                    let dt = match f.dtype {
                        DType::F32 => "f32",
                        DType::I32 => "i32",
                    };
                    arr(vec![s(&f.name), s(dt)])
                })
                .collect());
            let cols = arr(d
                .batch
                .columns
                .iter()
                .map(|c| match c {
                    Column::F32(v) => {
                        arr(v.iter().map(|&x| num(x as f64)).collect())
                    }
                    Column::I32(v) => {
                        arr(v.iter().map(|&x| num(x as f64)).collect())
                    }
                })
                .collect());
            let mask = match d.batch.validity.mask() {
                None => Json::Null,
                Some(m) => arr(m.iter().map(|&b| num(b as f64)).collect()),
            };
            obj(vec![
                ("id", num(d.id as f64)),
                ("created_ns", num(d.created_at.0 as f64)),
                ("event_ns", num(d.event_time.0 as f64)),
                ("wire", num(d.wire_bytes as f64)),
                ("schema", schema),
                ("cols", cols),
                ("mask", mask),
            ])
        })
        .collect();
    obj(vec![
        ("seq", num(seq as f64)),
        ("round", num(round as f64)),
        ("datasets", arr(datasets)),
    ])
    .render()
}

fn parse_record(payload: &[u8]) -> Result<WalRecord> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| Error::Json("wal payload not utf8".into()))?;
    let j = Json::parse(text)?;
    let seq = j.req("seq")?.as_f64().unwrap_or(0.0) as u64;
    let round = j.req("round")?.as_usize().unwrap_or(0);
    let mut datasets = Vec::new();
    for d in j
        .req("datasets")?
        .as_arr()
        .ok_or_else(|| Error::Json("datasets not array".into()))?
    {
        let fields = d
            .req("schema")?
            .as_arr()
            .ok_or_else(|| Error::Json("schema not array".into()))?
            .iter()
            .map(|f| {
                let pair =
                    f.as_arr().ok_or_else(|| Error::Json("field not pair".into()))?;
                let name = pair
                    .first()
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| Error::Json("field name".into()))?;
                match pair.get(1).and_then(|t| t.as_str()) {
                    Some("f32") => Ok(Field::f32(name)),
                    Some("i32") => Ok(Field::i32(name)),
                    other => Err(Error::Json(format!("bad dtype {other:?}"))),
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let schema = Schema::new(fields);
        let cols = d
            .req("cols")?
            .as_arr()
            .ok_or_else(|| Error::Json("cols not array".into()))?;
        if cols.len() != schema.len() {
            return Err(Error::Json("cols/schema arity mismatch".into()));
        }
        let columns = schema
            .fields
            .iter()
            .zip(cols)
            .map(|(f, c)| {
                let vals =
                    c.as_arr().ok_or_else(|| Error::Json("column not array".into()))?;
                Ok(match f.dtype {
                    DType::F32 => Column::F32(
                        vals.iter()
                            .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                            .collect::<Vec<_>>()
                            .into(),
                    ),
                    DType::I32 => Column::I32(
                        vals.iter()
                            .map(|v| v.as_f64().unwrap_or(0.0) as i32)
                            .collect::<Vec<_>>()
                            .into(),
                    ),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut batch = ColumnBatch::new(schema, columns)?;
        if let Some(mask) = d.req("mask")?.as_arr() {
            if mask.len() != batch.rows() {
                return Err(Error::Json("mask length mismatch".into()));
            }
            batch.validity = Validity::from_mask(
                mask.iter().map(|v| v.as_f64().unwrap_or(0.0) as u8).collect(),
            );
        }
        datasets.push(Dataset {
            id: d.req("id")?.as_f64().unwrap_or(0.0) as u64,
            created_at: Time(d.req("created_ns")?.as_f64().unwrap_or(0.0) as u64),
            event_time: Time(d.req("event_ns")?.as_f64().unwrap_or(0.0) as u64),
            wire_bytes: d.req("wire")?.as_usize().unwrap_or(0),
            batch,
        });
    }
    Ok(WalRecord { seq, round, batch: MicroBatch::new(datasets) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn ds(id: u64, t: f64, vals: &[f32]) -> Dataset {
        let schema = Schema::new(vec![Field::f32("x"), Field::i32("k")]);
        let batch = ColumnBatch::new(
            schema,
            vec![
                Column::F32(vals.to_vec().into()),
                Column::I32(vals.iter().map(|&v| v as i32).collect::<Vec<_>>().into()),
            ],
        )
        .unwrap();
        Dataset {
            id,
            created_at: Time::from_secs_f64(t),
            event_time: Time::from_secs_f64(t),
            wire_bytes: vals.len() * 65,
            batch,
        }
    }

    fn wal_path(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("lmstream-wal-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d.join("src.wal")
    }

    #[test]
    fn crc32_known_vector() {
        // The classic check value for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
    }

    #[test]
    fn append_scan_round_trip() {
        let path = wal_path("roundtrip");
        let (mut wal, scan) = Wal::open(&path).unwrap();
        assert!(scan.entries.is_empty());
        let mb = MicroBatch::new(vec![ds(3, 1.0, &[1.5, 2.5]), ds(4, 2.0, &[3.5])]);
        assert_eq!(wal.append(7, &mb).unwrap(), 1);
        assert_eq!(wal.append(8, &MicroBatch::new(vec![ds(5, 3.0, &[9.0])])).unwrap(), 2);
        drop(wal);

        let (_, scan) = Wal::open(&path).unwrap();
        assert_eq!(scan.entries.len(), 2);
        assert_eq!(scan.torn_tail_bytes, 0);
        let ScanEntry::Ok(first) = &scan.entries[0] else { panic!("corrupt") };
        assert_eq!((first.seq, first.round), (1, 7));
        assert_eq!(first.batch.num_datasets(), 2);
        assert_eq!(first.batch.datasets[0].id, 3);
        assert_eq!(first.batch.datasets[0].created_at, Time::from_secs_f64(1.0));
        assert_eq!(
            first.batch.datasets[0].batch.column("x").unwrap().as_f32().unwrap(),
            &[1.5, 2.5]
        );
        assert_eq!(first.batch.datasets[0].wire_bytes, 2 * 65);
    }

    #[test]
    fn validity_mask_round_trips() {
        let path = wal_path("mask");
        let mut d = ds(0, 1.0, &[1.0, 2.0, 3.0]);
        d.batch.validity = Validity::from_mask(vec![1, 0, 1]);
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(1, &MicroBatch::new(vec![d])).unwrap();
        drop(wal);
        let (_, scan) = Wal::open(&path).unwrap();
        let ScanEntry::Ok(rec) = &scan.entries[0] else { panic!() };
        assert_eq!(rec.batch.datasets[0].batch.validity.to_vec(), vec![1, 0, 1]);
        assert_eq!(rec.batch.datasets[0].batch.live_rows(), 2);
    }

    #[test]
    fn group_commit_syncs_once_for_many_appends() {
        let path = wal_path("groupcommit");
        let (mut wal, _) = Wal::open(&path).unwrap();
        assert_eq!(wal.fsyncs(), 0, "open-time maintenance syncs are not counted");
        for i in 0..3 {
            let seq =
                wal.append_deferred(4, &MicroBatch::new(vec![ds(i, i as f64, &[i as f32])]))
                    .unwrap();
            assert_eq!(seq, i + 1);
        }
        assert_eq!(wal.fsyncs(), 0, "deferred appends must not sync");
        wal.commit().unwrap();
        assert_eq!(wal.fsyncs(), 1, "one group = one fsync");
        wal.commit().unwrap();
        assert_eq!(wal.fsyncs(), 1, "empty commit is a no-op");
        // The plain append path still syncs per record.
        wal.append(5, &MicroBatch::new(vec![ds(9, 9.0, &[9.0])])).unwrap();
        assert_eq!(wal.fsyncs(), 2);
        drop(wal);
        let (_, scan) = Wal::open(&path).unwrap();
        let seqs: Vec<u64> = scan
            .entries
            .iter()
            .map(|e| match e {
                ScanEntry::Ok(r) => r.seq,
                _ => panic!("corrupt"),
            })
            .collect();
        assert_eq!(seqs, vec![1, 2, 3, 4]);
    }

    #[test]
    fn torn_tail_detected_and_truncated() {
        let path = wal_path("torn");
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(1, &MicroBatch::new(vec![ds(0, 1.0, &[1.0])])).unwrap();
        drop(wal);
        // Crash mid-append: half a frame header lands.
        let mut bytes = std::fs::read(&path).unwrap();
        let full = bytes.len();
        bytes.extend_from_slice(&[0x40, 0x00, 0x00]);
        std::fs::write(&path, &bytes).unwrap();

        let (mut wal, scan) = Wal::open(&path).unwrap();
        assert_eq!(scan.entries.len(), 1);
        assert_eq!(scan.torn_tail_bytes, 3);
        assert!(matches!(scan.entries[0], ScanEntry::Ok(_)));
        // The torn bytes are gone; appends resume on a clean boundary.
        assert_eq!(std::fs::metadata(&path).unwrap().len() as usize, full);
        assert_eq!(wal.append(2, &MicroBatch::new(vec![ds(1, 2.0, &[2.0])])).unwrap(), 2);
        drop(wal);
        let (_, scan) = Wal::open(&path).unwrap();
        assert_eq!(scan.entries.len(), 2);
        assert_eq!(scan.torn_tail_bytes, 0);
    }

    #[test]
    fn corrupt_record_isolated_by_framing() {
        let path = wal_path("corrupt");
        let (mut wal, _) = Wal::open(&path).unwrap();
        for i in 0..3 {
            wal.append(1, &MicroBatch::new(vec![ds(i, i as f64, &[i as f32])])).unwrap();
        }
        drop(wal);
        // Flip one payload byte inside the middle record.
        let mut bytes = std::fs::read(&path).unwrap();
        let frame1 = {
            // Walk: magic, then frame 0's length.
            let l0 = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
            8 + 8 + l0
        };
        bytes[frame1 + 12] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();

        let (_, scan) = Wal::open(&path).unwrap();
        assert_eq!(scan.entries.len(), 3);
        assert!(matches!(scan.entries[0], ScanEntry::Ok(_)));
        let ScanEntry::Corrupt { inferred_seq, .. } = &scan.entries[1] else {
            panic!("CRC must catch the flipped byte")
        };
        assert_eq!(*inferred_seq, 2);
        // The framing carries the scan past the damage.
        let ScanEntry::Ok(third) = &scan.entries[2] else { panic!() };
        assert_eq!(third.seq, 3);
    }

    #[test]
    fn truncate_drops_checkpointed_prefix() {
        let path = wal_path("trunc");
        let (mut wal, _) = Wal::open(&path).unwrap();
        for i in 0..4 {
            wal.append(1, &MicroBatch::new(vec![ds(i, i as f64, &[i as f32])])).unwrap();
        }
        wal.truncate_through(2).unwrap();
        // Appends continue the sequence after a truncation.
        assert_eq!(wal.append(2, &MicroBatch::new(vec![ds(9, 9.0, &[9.0])])).unwrap(), 5);
        drop(wal);
        let (_, scan) = Wal::open(&path).unwrap();
        let seqs: Vec<u64> = scan
            .entries
            .iter()
            .map(|e| match e {
                ScanEntry::Ok(r) => r.seq,
                _ => panic!("corrupt"),
            })
            .collect();
        assert_eq!(seqs, vec![3, 4, 5]);
    }

    #[test]
    fn size_bytes_matches_file_length() {
        let path = wal_path("size");
        let (mut wal, _) = Wal::open(&path).unwrap();
        assert_eq!(wal.size_bytes(), MAGIC.len() as u64);
        for i in 0..3 {
            wal.append(1, &MicroBatch::new(vec![ds(i, i as f64, &[i as f32])])).unwrap();
            assert_eq!(wal.size_bytes(), std::fs::metadata(&path).unwrap().len());
        }
        wal.truncate_through(1).unwrap();
        assert_eq!(wal.size_bytes(), std::fs::metadata(&path).unwrap().len());
    }

    #[test]
    fn roll_to_cap_drops_oldest_frames_only() {
        let path = wal_path("roll");
        let (mut wal, _) = Wal::open(&path).unwrap();
        for i in 0..4 {
            wal.append(1, &MicroBatch::new(vec![ds(i, i as f64, &[i as f32])])).unwrap();
        }
        let full = wal.size_bytes();
        // A generous cap drops nothing and rewrites nothing.
        assert_eq!(wal.roll_to_cap(full).unwrap(), 0);
        // Roll to roughly half: oldest frames go, newest survive.
        let dropped = wal.roll_to_cap(full / 2).unwrap();
        assert!(dropped >= 1);
        assert!(wal.size_bytes() <= full / 2 || wal.pending_len() == 1);
        // Appends continue the sequence after a roll.
        assert_eq!(wal.append(2, &MicroBatch::new(vec![ds(9, 9.0, &[9.0])])).unwrap(), 5);
        drop(wal);
        let (_, scan) = Wal::open(&path).unwrap();
        let seqs: Vec<u64> = scan
            .entries
            .iter()
            .map(|e| match e {
                ScanEntry::Ok(r) => r.seq,
                _ => panic!("corrupt"),
            })
            .collect();
        assert_eq!(*seqs.last().unwrap(), 5);
        assert!(seqs.windows(2).all(|w| w[0] < w[1]));
        assert!(seqs[0] > 1, "oldest frames must be the dropped ones");
    }

    #[test]
    fn roll_always_keeps_newest_frame() {
        let path = wal_path("roll-min");
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(1, &MicroBatch::new(vec![ds(0, 0.0, &[0.0])])).unwrap();
        wal.append(1, &MicroBatch::new(vec![ds(1, 1.0, &[1.0])])).unwrap();
        // Cap smaller than any single frame: everything but the newest
        // frame is dropped, the newest survives over-cap.
        wal.roll_to_cap(1).unwrap();
        assert_eq!(wal.pending_len(), 1);
        drop(wal);
        let (_, scan) = Wal::open(&path).unwrap();
        assert_eq!(scan.entries.len(), 1);
        let ScanEntry::Ok(r) = &scan.entries[0] else { panic!() };
        assert_eq!(r.seq, 2);
    }

    #[test]
    fn non_wal_file_rejected() {
        let path = wal_path("notawal");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"definitely not a wal").unwrap();
        let err = Wal::open(&path).unwrap_err();
        assert!(matches!(err, Error::Durability(_)), "{err:?}");
    }
}
