//! Arrival-stamped input stream.
//!
//! Datasets materialize at one-second ticks (the paper's ingestion
//! granularity) according to the traffic pattern; the coordinator polls
//! every 10 ms (§III-A) and receives all datasets created up to "now".

use crate::engine::column::ColumnBatch;
use crate::engine::dataset::Dataset;
use crate::sim::Time;
use crate::source::traffic::Traffic;
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::time::Duration;

/// Workload-specific row synthesis.
pub trait RowGen: Send {
    /// Generate `rows` rows; `tick` is the dataset's event-time second
    /// (generators use it for timestamp columns).
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch;
}

/// The polled source.
pub struct InputStream {
    gen: Box<dyn RowGen>,
    traffic: Traffic,
    rng: Rng,
    tick: Duration,
    next_tick_at: Time,
    next_tick_no: u64,
    next_id: u64,
    pending: VecDeque<Dataset>,
    total_datasets: u64,
    total_bytes: u64,
}

impl InputStream {
    pub fn new(gen: Box<dyn RowGen>, traffic: Traffic, seed: u64) -> InputStream {
        InputStream {
            gen,
            traffic,
            rng: Rng::new(seed),
            tick: Duration::from_secs(1),
            next_tick_at: Time::ZERO,
            next_tick_no: 0,
            next_id: 0,
            pending: VecDeque::new(),
            total_datasets: 0,
            total_bytes: 0,
        }
    }

    /// Materialize all ticks up to `now`.
    fn advance_to(&mut self, now: Time) {
        while self.next_tick_at <= now {
            let rows = self.traffic.next_rows(&mut self.rng);
            if rows > 0 {
                let batch = self.gen.generate(self.next_tick_no, rows);
                let bytes = batch.alloc_bytes();
                self.pending.push_back(Dataset {
                    id: self.next_id,
                    created_at: self.next_tick_at,
                    event_time: self.next_tick_at,
                    batch,
                    wire_bytes: bytes,
                });
                self.next_id += 1;
                self.total_datasets += 1;
                self.total_bytes += bytes as u64;
            }
            self.next_tick_at = self.next_tick_at.add(self.tick);
            self.next_tick_no += 1;
        }
    }

    /// Take every dataset created up to `now` (the "get all new data in
    /// the source path" of Alg. 1).
    pub fn poll(&mut self, now: Time) -> Vec<Dataset> {
        self.advance_to(now);
        let mut out = Vec::new();
        while let Some(front) = self.pending.front() {
            if front.created_at <= now {
                out.push(self.pending.pop_front().unwrap());
            } else {
                break;
            }
        }
        out
    }

    /// Lifetime counters (ingest accounting for reports).
    pub fn totals(&self) -> (u64, u64) {
        (self.total_datasets, self.total_bytes)
    }

    /// Checkpoint recovery: consume (and discard) everything up to
    /// `horizon`, then re-base so the next tick lands at the new run's
    /// time zero — the resumed process's clock restarts while the logical
    /// stream continues where the checkpoint left off.
    pub fn fast_forward(&mut self, horizon: Time) {
        self.advance_to(horizon);
        self.pending.clear();
        self.total_datasets = 0;
        self.total_bytes = 0;
        self.next_tick_at = Time::ZERO;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, Field, Schema};

    struct OneColGen;

    impl RowGen for OneColGen {
        fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
            let schema = Schema::new(vec![Field::f32("t")]);
            ColumnBatch::new(schema, vec![Column::F32(vec![tick as f32; rows].into())])
                .unwrap()
        }
    }

    fn stream(traffic: Traffic) -> InputStream {
        InputStream::new(Box::new(OneColGen), traffic, 7)
    }

    #[test]
    fn one_dataset_per_second() {
        let mut s = stream(Traffic::Constant { rows: 10 });
        let got = s.poll(Time::from_secs_f64(3.5));
        assert_eq!(got.len(), 4); // t = 0, 1, 2, 3
        assert_eq!(got[0].created_at, Time::ZERO);
        assert_eq!(got[3].created_at, Time::from_secs_f64(3.0));
        assert!(got.iter().all(|d| d.rows() == 10));
    }

    #[test]
    fn poll_is_incremental() {
        let mut s = stream(Traffic::Constant { rows: 5 });
        assert_eq!(s.poll(Time::from_secs_f64(1.0)).len(), 2);
        assert_eq!(s.poll(Time::from_secs_f64(1.5)).len(), 0);
        assert_eq!(s.poll(Time::from_secs_f64(2.0)).len(), 1);
    }

    #[test]
    fn event_times_stamped_into_rows() {
        let mut s = stream(Traffic::Constant { rows: 1 });
        let got = s.poll(Time::from_secs_f64(2.0));
        let t2 = got[2].batch.column("t").unwrap().as_f32().unwrap()[0];
        assert_eq!(t2, 2.0);
    }

    #[test]
    fn totals_accumulate() {
        let mut s = stream(Traffic::Constant { rows: 10 });
        s.poll(Time::from_secs_f64(4.0));
        let (n, bytes) = s.totals();
        assert_eq!(n, 5);
        assert_eq!(bytes, 5 * (10 * 4 + 10) as u64);
    }

    #[test]
    fn fast_forward_rebases_to_zero() {
        let mut s = stream(Traffic::Constant { rows: 5 });
        s.fast_forward(Time::from_secs_f64(10.0));
        // Next data materializes at the new time origin.
        let got = s.poll(Time::from_secs_f64(1.0));
        assert!(!got.is_empty());
        assert_eq!(got[0].created_at, Time::ZERO);
        // Event ticks continue the logical stream (tick 11 onward).
        let t = got[0].batch.column("t").unwrap().as_f32().unwrap()[0];
        assert!(t >= 11.0, "tick {t}");
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = stream(Traffic::random_default());
        let mut b = stream(Traffic::random_default());
        let ra: Vec<usize> =
            a.poll(Time::from_secs_f64(10.0)).iter().map(|d| d.rows()).collect();
        let rb: Vec<usize> =
            b.poll(Time::from_secs_f64(10.0)).iter().map(|d| d.rows()).collect();
        assert_eq!(ra, rb);
    }
}
