//! Arrival-stamped input stream.
//!
//! Datasets materialize at one-second ticks (the paper's ingestion
//! granularity) according to the traffic pattern; the coordinator polls
//! every 10 ms (§III-A) and receives all datasets created up to "now".
//!
//! # Event time vs arrival time
//!
//! Each dataset carries two timestamps: `event_time` is the *logical
//! tick* the rows belong to (tick number × tick duration — it continues
//! across [`InputStream::fast_forward`] rebases, so the logical stream
//! is incarnation-invariant), while `created_at` is when the dataset
//! became visible to [`InputStream::poll`]. Without a [`Disorder`] knob
//! the two advance in lockstep (arrival == event tick); with one,
//! arrival is randomly delayed, producing the out-of-order and late data
//! that event-time windows must tolerate. Disorder draws from its *own*
//! RNG, so enabling it never perturbs the generated row content — a
//! disordered run carries exactly the in-order run's datasets, permuted
//! in arrival.

use crate::engine::column::ColumnBatch;
use crate::engine::dataset::Dataset;
use crate::sim::Time;
use crate::source::traffic::Traffic;
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::time::Duration;

/// Workload-specific row synthesis.
pub trait RowGen: Send {
    /// Generate `rows` rows; `tick` is the dataset's event-time second
    /// (generators use it for timestamp columns).
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch;
}

/// Out-of-order arrival knob: each dataset's arrival is delayed past its
/// event tick with probability `delay_prob`, by a uniform draw in
/// `[0, max_delay]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Disorder {
    pub delay_prob: f64,
    pub max_delay: Duration,
}

impl Disorder {
    pub fn new(delay_prob: f64, max_delay: Duration) -> Disorder {
        Disorder { delay_prob, max_delay }
    }
}

/// The polled source.
pub struct InputStream {
    gen: Box<dyn RowGen>,
    traffic: Traffic,
    rng: Rng,
    disorder: Option<Disorder>,
    /// Separate stream so disorder draws never desync the traffic/row
    /// RNG: in-order and disordered runs generate identical datasets.
    disorder_rng: Rng,
    tick: Duration,
    next_tick_at: Time,
    next_tick_no: u64,
    next_id: u64,
    /// Pending datasets ordered by arrival (`created_at`, then id).
    pending: VecDeque<Dataset>,
    total_datasets: u64,
    total_bytes: u64,
}

impl InputStream {
    pub fn new(gen: Box<dyn RowGen>, traffic: Traffic, seed: u64) -> InputStream {
        InputStream {
            gen,
            traffic,
            rng: Rng::new(seed),
            disorder: None,
            disorder_rng: Rng::new(seed ^ 0x0d15_0d0e_5eed_cafe),
            tick: Duration::from_secs(1),
            next_tick_at: Time::ZERO,
            next_tick_no: 0,
            next_id: 0,
            pending: VecDeque::new(),
            total_datasets: 0,
            total_bytes: 0,
        }
    }

    /// Enable out-of-order arrivals (builder style).
    pub fn with_disorder(mut self, disorder: Disorder) -> InputStream {
        self.disorder = Some(disorder);
        self
    }

    /// Whether this stream delivers out-of-order arrivals.
    pub fn is_disordered(&self) -> bool {
        self.disorder.is_some()
    }

    /// Materialize all ticks up to `now`.
    fn advance_to(&mut self, now: Time) {
        while self.next_tick_at <= now {
            let rows = self.traffic.next_rows(self.next_tick_no, &mut self.rng);
            if rows > 0 {
                let batch = self.gen.generate(self.next_tick_no, rows);
                let bytes = batch.alloc_bytes();
                let event_time =
                    Time::from_secs_f64(self.next_tick_no as f64 * self.tick.as_secs_f64());
                let mut created_at = self.next_tick_at;
                if let Some(d) = self.disorder {
                    if self.disorder_rng.chance(d.delay_prob) {
                        let delay = Duration::from_secs_f64(
                            self.disorder_rng.f64() * d.max_delay.as_secs_f64(),
                        );
                        created_at = created_at.add(delay);
                    }
                }
                let ds = Dataset {
                    id: self.next_id,
                    created_at,
                    event_time,
                    batch,
                    wire_bytes: bytes,
                };
                // Keep `pending` arrival-ordered: a delayed dataset files
                // in behind everything that arrives before it.
                let pos = self
                    .pending
                    .iter()
                    .rposition(|p| (p.created_at, p.id) <= (ds.created_at, ds.id))
                    .map(|p| p + 1)
                    .unwrap_or(0);
                self.pending.insert(pos, ds);
                self.next_id += 1;
                self.total_datasets += 1;
                self.total_bytes += bytes as u64;
            }
            self.next_tick_at = self.next_tick_at.add(self.tick);
            self.next_tick_no += 1;
        }
    }

    /// Take every dataset that has *arrived* by `now` (the "get all new
    /// data in the source path" of Alg. 1).
    pub fn poll(&mut self, now: Time) -> Vec<Dataset> {
        // Materialize one max-delay horizon past `now` so a delayed
        // dataset from an earlier tick can't hide behind ticks that
        // haven't been generated yet.
        let gen_to = match self.disorder {
            Some(d) => now.add(d.max_delay),
            None => now,
        };
        self.advance_to(gen_to);
        let mut out = Vec::new();
        while let Some(front) = self.pending.front() {
            if front.created_at <= now {
                out.push(self.pending.pop_front().unwrap());
            } else {
                break;
            }
        }
        out
    }

    /// Lifetime counters (ingest accounting for reports).
    pub fn totals(&self) -> (u64, u64) {
        (self.total_datasets, self.total_bytes)
    }

    /// Checkpoint recovery: consume (and discard) everything up to
    /// `horizon`, then re-base so the next tick lands at the new run's
    /// time zero — the resumed process's clock restarts while the logical
    /// stream (tick numbers, hence `event_time`) continues where the
    /// checkpoint left off. Lifetime ingest counters survive the rebase:
    /// they account the logical stream, not one incarnation.
    pub fn fast_forward(&mut self, horizon: Time) {
        self.advance_to(horizon);
        self.pending.clear();
        self.next_tick_at = Time::ZERO;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, Field, Schema};

    struct OneColGen;

    impl RowGen for OneColGen {
        fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
            let schema = Schema::new(vec![Field::f32("t")]);
            ColumnBatch::new(schema, vec![Column::F32(vec![tick as f32; rows].into())])
                .unwrap()
        }
    }

    fn stream(traffic: Traffic) -> InputStream {
        InputStream::new(Box::new(OneColGen), traffic, 7)
    }

    #[test]
    fn one_dataset_per_second() {
        let mut s = stream(Traffic::Constant { rows: 10 });
        let got = s.poll(Time::from_secs_f64(3.5));
        assert_eq!(got.len(), 4); // t = 0, 1, 2, 3
        assert_eq!(got[0].created_at, Time::ZERO);
        assert_eq!(got[3].created_at, Time::from_secs_f64(3.0));
        assert!(got.iter().all(|d| d.rows() == 10));
        // In-order streams stamp event == arrival.
        assert!(got.iter().all(|d| d.event_time == d.created_at));
    }

    #[test]
    fn poll_is_incremental() {
        let mut s = stream(Traffic::Constant { rows: 5 });
        assert_eq!(s.poll(Time::from_secs_f64(1.0)).len(), 2);
        assert_eq!(s.poll(Time::from_secs_f64(1.5)).len(), 0);
        assert_eq!(s.poll(Time::from_secs_f64(2.0)).len(), 1);
    }

    #[test]
    fn event_times_stamped_into_rows() {
        let mut s = stream(Traffic::Constant { rows: 1 });
        let got = s.poll(Time::from_secs_f64(2.0));
        let t2 = got[2].batch.column("t").unwrap().as_f32().unwrap()[0];
        assert_eq!(t2, 2.0);
    }

    #[test]
    fn totals_accumulate() {
        let mut s = stream(Traffic::Constant { rows: 10 });
        s.poll(Time::from_secs_f64(4.0));
        let (n, bytes) = s.totals();
        assert_eq!(n, 5);
        assert_eq!(bytes, 5 * (10 * 4 + 10) as u64);
    }

    #[test]
    fn fast_forward_rebases_to_zero() {
        let mut s = stream(Traffic::Constant { rows: 5 });
        s.fast_forward(Time::from_secs_f64(10.0));
        // Next data materializes at the new time origin.
        let got = s.poll(Time::from_secs_f64(1.0));
        assert!(!got.is_empty());
        assert_eq!(got[0].created_at, Time::ZERO);
        // Event ticks continue the logical stream (tick 11 onward) —
        // both in the rows and in the decoupled event_time stamp.
        let t = got[0].batch.column("t").unwrap().as_f32().unwrap()[0];
        assert!(t >= 11.0, "tick {t}");
        assert!(got[0].event_time >= Time::from_secs_f64(11.0));
        assert!(got[0].event_time > got[0].created_at);
    }

    #[test]
    fn fast_forward_preserves_lifetime_totals() {
        // The rebase must not zero ingest accounting: totals accumulate
        // across incarnations (crash/resume undercount bugfix).
        let mut s = stream(Traffic::Constant { rows: 10 });
        s.poll(Time::from_secs_f64(4.0));
        let (n0, b0) = s.totals();
        assert_eq!(n0, 5);
        s.fast_forward(Time::from_secs_f64(9.0));
        let (n1, b1) = s.totals();
        assert_eq!(n1, 10, "fast_forward dropped consumed-tick accounting");
        assert!(b1 >= b0);
        s.poll(Time::from_secs_f64(2.0));
        let (n2, b2) = s.totals();
        assert_eq!(n2, 13, "post-resume ingest must extend the lifetime count");
        assert!(b2 > b1);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = stream(Traffic::random_default());
        let mut b = stream(Traffic::random_default());
        let ra: Vec<usize> =
            a.poll(Time::from_secs_f64(10.0)).iter().map(|d| d.rows()).collect();
        let rb: Vec<usize> =
            b.poll(Time::from_secs_f64(10.0)).iter().map(|d| d.rows()).collect();
        assert_eq!(ra, rb);
    }

    #[test]
    fn disorder_permutes_arrival_but_not_content() {
        let horizon = Time::from_secs_f64(40.0);
        let mut ordered = stream(Traffic::Constant { rows: 3 });
        let mut disordered = stream(Traffic::Constant { rows: 3 })
            .with_disorder(Disorder::new(0.5, Duration::from_secs(5)));
        let a = ordered.poll(horizon);
        // Poll far enough past the horizon that every delayed dataset of
        // the compared event range has arrived.
        let b: Vec<Dataset> = disordered
            .poll(Time::from_secs_f64(50.0))
            .into_iter()
            .filter(|d| d.event_time <= horizon)
            .collect();
        assert_eq!(a.len(), b.len());
        // Same datasets by id: identical event times and row content.
        let mut b_sorted = b.clone();
        b_sorted.sort_by_key(|d| d.id);
        for (x, y) in a.iter().zip(b_sorted.iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.event_time, y.event_time);
            assert_eq!(x.batch, y.batch);
        }
        // Arrival is genuinely delayed/reordered somewhere.
        assert!(b.iter().any(|d| d.created_at > d.event_time), "no delays drawn");
        assert!(
            b.windows(2).any(|w| w[0].event_time > w[1].event_time),
            "arrival order never inverted event order"
        );
        // And poll order is still arrival order.
        assert!(b.windows(2).all(|w| (w[0].created_at, w[0].id)
            <= (w[1].created_at, w[1].id)));
    }

    #[test]
    fn disorder_is_deterministic_for_seed() {
        let mk = || {
            stream(Traffic::random_default())
                .with_disorder(Disorder::new(0.3, Duration::from_secs(3)))
        };
        let a: Vec<(u64, u64)> = mk()
            .poll(Time::from_secs_f64(20.0))
            .iter()
            .map(|d| (d.id, d.created_at.0))
            .collect();
        let b: Vec<(u64, u64)> = mk()
            .poll(Time::from_secs_f64(20.0))
            .iter()
            .map(|d| (d.id, d.created_at.0))
            .collect();
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }
}
