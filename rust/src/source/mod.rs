//! Input side: traffic patterns ([`traffic`]) and the arrival-stamped
//! input stream the coordinator polls ([`stream`]).

pub mod stream;
pub mod traffic;

pub use stream::{InputStream, RowGen};
pub use traffic::Traffic;
