//! Stream ingestion traffic patterns (§V-A).
//!
//! * **Constant**: every second, a fixed number of rows arrives as one
//!   dataset (the paper's fair-comparison traffic).
//! * **RandomNormal**: per-second row counts drawn from a normal
//!   distribution (the paper's realistic fluctuating traffic; mean 1000).

use crate::util::rng::Rng;

/// Rows-per-second generator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Traffic {
    /// `rows` rows every tick.
    Constant { rows: usize },
    /// Normal(mean, std) rows per tick, clamped to >= 0.
    RandomNormal { mean: f64, std: f64 },
}

impl Traffic {
    /// Paper default: 1000 rows/s constant.
    pub fn constant_default() -> Traffic {
        Traffic::Constant { rows: 1000 }
    }

    /// Paper default random traffic: Normal(1000, 250).
    pub fn random_default() -> Traffic {
        Traffic::RandomNormal { mean: 1000.0, std: 250.0 }
    }

    /// Rows arriving in the next one-second tick.
    pub fn next_rows(&self, rng: &mut Rng) -> usize {
        match *self {
            Traffic::Constant { rows } => rows,
            Traffic::RandomNormal { mean, std } => {
                rng.normal_ms(mean, std).round().max(0.0) as usize
            }
        }
    }

    /// Long-run mean rows/s.
    pub fn mean_rows(&self) -> f64 {
        match *self {
            Traffic::Constant { rows } => rows as f64,
            Traffic::RandomNormal { mean, .. } => mean,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let mut rng = Rng::new(1);
        let t = Traffic::Constant { rows: 123 };
        for _ in 0..10 {
            assert_eq!(t.next_rows(&mut rng), 123);
        }
    }

    #[test]
    fn random_mean_close_to_target() {
        let mut rng = Rng::new(2);
        let t = Traffic::random_default();
        let n = 20_000;
        let total: usize = (0..n).map(|_| t.next_rows(&mut rng)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 1000.0).abs() < 15.0, "mean {mean}");
    }

    #[test]
    fn random_never_negative() {
        let mut rng = Rng::new(3);
        let t = Traffic::RandomNormal { mean: 10.0, std: 100.0 };
        for _ in 0..1000 {
            let _ = t.next_rows(&mut rng); // usize: would panic on negative
        }
    }
}
