//! Stream ingestion traffic patterns (§V-A + production shapes).
//!
//! * **Constant**: every second, a fixed number of rows arrives as one
//!   dataset (the paper's fair-comparison traffic).
//! * **RandomNormal**: per-second row counts drawn from a normal
//!   distribution (the paper's realistic fluctuating traffic; mean 1000).
//! * **Diurnal**: a sinusoidal day/night curve — the slow periodic load
//!   swing of user-facing services.
//! * **FlashCrowd**: a baseline rate with one scheduled spike that ramps
//!   up linearly and decays exponentially (breaking-news load).
//! * **Burst**: a normal baseline where each tick is independently
//!   multiplied by a burst factor with small probability (multiplicative
//!   heavy-tail bursts).
//!
//! Patterns are functions of the *tick number* (plus the stream's RNG for
//! the stochastic ones), so a shape is reproducible for a seed and
//! shifting the clock never changes which tick gets which load.

use crate::util::rng::Rng;

/// Rows-per-second generator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Traffic {
    /// `rows` rows every tick.
    Constant { rows: usize },
    /// Normal(mean, std) rows per tick, clamped to >= 0.
    RandomNormal { mean: f64, std: f64 },
    /// `base + amplitude * sin(2π · tick / period_secs)` rows per tick,
    /// clamped to >= 0.
    Diurnal { base: f64, amplitude: f64, period_secs: u64 },
    /// `base` rows per tick until `at_tick`; then a spike toward `peak`
    /// ramping linearly over `ramp_secs` and decaying exponentially with
    /// time constant `decay_secs` back toward `base`.
    FlashCrowd {
        base: usize,
        peak: usize,
        at_tick: u64,
        ramp_secs: u64,
        decay_secs: u64,
    },
    /// Normal(mean, std) baseline; with probability `prob` a tick's rows
    /// are multiplied by `factor` (multiplicative burst).
    Burst { mean: f64, std: f64, factor: f64, prob: f64 },
}

impl Traffic {
    /// Paper default: 1000 rows/s constant.
    pub fn constant_default() -> Traffic {
        Traffic::Constant { rows: 1000 }
    }

    /// Paper default random traffic: Normal(1000, 250).
    pub fn random_default() -> Traffic {
        Traffic::RandomNormal { mean: 1000.0, std: 250.0 }
    }

    /// Compressed diurnal curve (one "day" every 5 simulated minutes so
    /// benches see whole periods): 1000 ± 600 rows/s.
    pub fn diurnal_default() -> Traffic {
        Traffic::Diurnal { base: 1000.0, amplitude: 600.0, period_secs: 300 }
    }

    /// Flash crowd: 500 rows/s baseline, 10x spike at t=60s, 5 s ramp,
    /// 20 s decay constant.
    pub fn flash_crowd_default() -> Traffic {
        Traffic::FlashCrowd {
            base: 500,
            peak: 5000,
            at_tick: 60,
            ramp_secs: 5,
            decay_secs: 20,
        }
    }

    /// Multiplicative bursts: Normal(1000, 250) with an 8x burst on ~2%
    /// of ticks.
    pub fn burst_default() -> Traffic {
        Traffic::Burst { mean: 1000.0, std: 250.0, factor: 8.0, prob: 0.02 }
    }

    /// Rows arriving in one-second tick number `tick`.
    pub fn next_rows(&self, tick: u64, rng: &mut Rng) -> usize {
        match *self {
            Traffic::Constant { rows } => rows,
            Traffic::RandomNormal { mean, std } => {
                rng.normal_ms(mean, std).round().max(0.0) as usize
            }
            Traffic::Diurnal { base, amplitude, period_secs } => {
                let phase =
                    2.0 * std::f64::consts::PI * tick as f64 / period_secs.max(1) as f64;
                (base + amplitude * phase.sin()).round().max(0.0) as usize
            }
            Traffic::FlashCrowd { base, peak, at_tick, ramp_secs, decay_secs } => {
                if tick < at_tick {
                    return base;
                }
                let dt = tick - at_tick;
                let excess = peak.saturating_sub(base) as f64;
                let x = if dt < ramp_secs.max(1) {
                    // Linear ramp reaches the peak on the last ramp tick.
                    excess * (dt + 1) as f64 / ramp_secs.max(1) as f64
                } else {
                    excess * (-((dt - ramp_secs) as f64) / decay_secs.max(1) as f64).exp()
                };
                base + x.round().max(0.0) as usize
            }
            Traffic::Burst { mean, std, factor, prob } => {
                let base = rng.normal_ms(mean, std).round().max(0.0);
                if rng.chance(prob) {
                    (base * factor).round() as usize
                } else {
                    base as usize
                }
            }
        }
    }

    /// Long-run mean rows/s (the sinusoid averages to `base`; the flash
    /// crowd's spike is a transient, so its steady state is `base`).
    pub fn mean_rows(&self) -> f64 {
        match *self {
            Traffic::Constant { rows } => rows as f64,
            Traffic::RandomNormal { mean, .. } => mean,
            Traffic::Diurnal { base, .. } => base,
            Traffic::FlashCrowd { base, .. } => base as f64,
            Traffic::Burst { mean, factor, prob, .. } => {
                mean * (1.0 + prob * (factor - 1.0))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let mut rng = Rng::new(1);
        let t = Traffic::Constant { rows: 123 };
        for tick in 0..10 {
            assert_eq!(t.next_rows(tick, &mut rng), 123);
        }
    }

    #[test]
    fn random_mean_close_to_target() {
        let mut rng = Rng::new(2);
        let t = Traffic::random_default();
        let n = 20_000u64;
        let total: usize = (0..n).map(|tick| t.next_rows(tick, &mut rng)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 1000.0).abs() < 15.0, "mean {mean}");
    }

    #[test]
    fn random_never_negative() {
        let mut rng = Rng::new(3);
        let t = Traffic::RandomNormal { mean: 10.0, std: 100.0 };
        for tick in 0..1000 {
            let _ = t.next_rows(tick, &mut rng); // usize: would panic on negative
        }
    }

    #[test]
    fn diurnal_oscillates_around_base_over_one_period() {
        let mut rng = Rng::new(4);
        let t = Traffic::Diurnal { base: 1000.0, amplitude: 600.0, period_secs: 100 };
        let rows: Vec<usize> = (0..100).map(|tick| t.next_rows(tick, &mut rng)).collect();
        let max = *rows.iter().max().unwrap();
        let min = *rows.iter().min().unwrap();
        assert!(max >= 1590 && max <= 1600, "peak {max}");
        assert!(min <= 410, "trough {min}");
        let mean = rows.iter().sum::<usize>() as f64 / rows.len() as f64;
        assert!((mean - 1000.0).abs() < 20.0, "period mean {mean}");
        // Deterministic in the tick, independent of RNG state.
        assert_eq!(t.next_rows(25, &mut rng), t.next_rows(25, &mut rng));
    }

    #[test]
    fn flash_crowd_ramps_and_decays() {
        let mut rng = Rng::new(5);
        let t = Traffic::FlashCrowd {
            base: 500,
            peak: 5000,
            at_tick: 10,
            ramp_secs: 5,
            decay_secs: 20,
        };
        assert_eq!(t.next_rows(0, &mut rng), 500);
        assert_eq!(t.next_rows(9, &mut rng), 500);
        // Ramp is monotone up to the peak.
        let ramp: Vec<usize> = (10..15).map(|k| t.next_rows(k, &mut rng)).collect();
        assert!(ramp.windows(2).all(|w| w[0] < w[1]), "ramp {ramp:?}");
        assert_eq!(*ramp.last().unwrap(), 5000);
        // Decay is monotone down and approaches base.
        let decay: Vec<usize> = (15..80).map(|k| t.next_rows(k, &mut rng)).collect();
        assert!(decay.windows(2).all(|w| w[0] >= w[1]), "decay not monotone");
        assert!(*decay.last().unwrap() < 700, "decay tail {}", decay.last().unwrap());
        assert!(decay.iter().all(|&r| r >= 500));
    }

    #[test]
    fn burst_mean_reflects_burst_factor() {
        let mut rng = Rng::new(6);
        let t = Traffic::Burst { mean: 1000.0, std: 100.0, factor: 8.0, prob: 0.02 };
        let n = 50_000u64;
        let rows: Vec<usize> = (0..n).map(|tick| t.next_rows(tick, &mut rng)).collect();
        let mean = rows.iter().sum::<usize>() as f64 / n as f64;
        // Long-run mean ≈ mean_rows() = 1140; generous tolerance.
        assert!((mean - t.mean_rows()).abs() < 40.0, "mean {mean}");
        // Bursts actually happen and are multiplicative outliers.
        let bursts = rows.iter().filter(|&&r| r > 4000).count();
        let frac = bursts as f64 / n as f64;
        assert!(frac > 0.005 && frac < 0.05, "burst fraction {frac}");
    }

    #[test]
    fn mean_rows_matches_shapes() {
        assert_eq!(Traffic::constant_default().mean_rows(), 1000.0);
        assert_eq!(Traffic::diurnal_default().mean_rows(), 1000.0);
        assert_eq!(Traffic::flash_crowd_default().mean_rows(), 500.0);
        let b = Traffic::burst_default().mean_rows();
        assert!((b - 1140.0).abs() < 1e-9, "burst mean {b}");
    }
}
