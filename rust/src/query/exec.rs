//! Physical execution of a planned query over a micro-batch.
//!
//! Given a [`DevicePlan`] (one device per DAG operation, from MapDevice or
//! a baseline policy), runs the operator chain and accounts processing
//! time:
//!
//! * **Simulated backend** — operators transform data natively; *time* is
//!   charged by the calibrated [`DeviceModel`]: CPU ops at per-partition
//!   volume (partitions run on `NumCores` cores in parallel), GPU ops at
//!   coalesced volume divided across `NumGpus`, plus host↔device transfer
//!   on every device boundary (Alg. 2's `Trans` placement: first / last /
//!   device-switch).
//! * **Real backend** — CPU ops run native, GPU ops run through the PJRT
//!   artifacts; wall-clock timing.

use crate::config::ExecBackend;
use crate::devices::model::{DeviceModel, OpVolume};
use crate::devices::{cpu, gpu, Device};
use crate::engine::column::ColumnBatch;
use crate::error::{Error, Result};
use crate::query::dag::{OpKind, Query};
use crate::runtime::client::Runtime;
use std::time::{Duration, Instant};

/// Device assignment per DAG operation (index-aligned with `query.ops`).
#[derive(Clone, Debug, PartialEq)]
pub struct DevicePlan {
    pub per_op: Vec<Device>,
}

impl DevicePlan {
    pub fn all(device: Device, n: usize) -> DevicePlan {
        DevicePlan { per_op: vec![device; n] }
    }

    pub fn gpu_ops(&self) -> usize {
        self.per_op.iter().filter(|d| **d == Device::Gpu).count()
    }
}

/// Execution environment.
pub struct ExecEnv<'a> {
    pub model: &'a DeviceModel,
    pub backend: ExecBackend,
    pub num_cores: usize,
    pub num_gpus: usize,
    /// Required for the Real backend's GPU path.
    pub runtime: Option<&'a Runtime>,
}

/// Per-operation execution record.
#[derive(Clone, Debug)]
pub struct OpTrace {
    pub op_id: usize,
    pub kind: OpKind,
    pub device: Device,
    pub time: Duration,
    pub in_bytes: usize,
    pub out_bytes: usize,
}

/// Result of one micro-batch execution.
#[derive(Debug)]
pub struct ExecOutcome {
    pub result: ColumnBatch,
    /// `Proc_i`: full processing-phase duration.
    pub proc: Duration,
    /// Host↔device transfer share of `proc`.
    pub transfer: Duration,
    pub traces: Vec<OpTrace>,
}

/// Execute `query` over `input` with `plan`.
///
/// `window` is the window-state snapshot (join build side / windowed
/// aggregation scope); `aux_bytes` its size for cost accounting.
pub fn execute(
    query: &Query,
    plan: &DevicePlan,
    input: ColumnBatch,
    window: Option<&ColumnBatch>,
    env: &ExecEnv,
) -> Result<ExecOutcome> {
    if plan.per_op.len() != query.ops.len() {
        return Err(Error::Plan(format!(
            "plan covers {} ops, query has {}",
            plan.per_op.len(),
            query.ops.len()
        )));
    }
    if env.num_cores == 0 || env.num_gpus == 0 {
        return Err(Error::Plan("need at least one core and one gpu".into()));
    }
    let aux_bytes = window.map(|w| w.bytes()).unwrap_or(0) as f64;
    let last = query.ops.len() - 1;

    let mut current = input;
    let mut proc = env.model.batch_fixed;
    let mut transfer_total = Duration::ZERO;
    let mut traces = Vec::with_capacity(query.ops.len());

    for (i, op) in query.ops.iter().enumerate() {
        let device = plan.per_op[i];
        let kind = op.spec.kind();
        let in_bytes = current.bytes();

        let (next, measured) = match (env.backend, device) {
            (ExecBackend::Real, Device::Gpu) => {
                let rt = env.runtime.ok_or_else(|| {
                    Error::Plan("Real backend needs a PJRT runtime for GPU ops".into())
                })?;
                let t0 = Instant::now();
                let out = gpu::run_op(rt, &op.spec, &current, window, &query.window)?;
                (out, Some(t0.elapsed()))
            }
            (ExecBackend::Real, Device::Cpu) => {
                let t0 = Instant::now();
                let out = cpu::run_op(&op.spec, &current, window, &query.window)?;
                (out, Some(t0.elapsed()))
            }
            (ExecBackend::Simulated, _) => {
                let out = cpu::run_op(&op.spec, &current, window, &query.window)?;
                (out, None)
            }
        };
        let out_bytes = next.bytes();

        // Windowed operators also consume the window side input.
        let op_aux = match op.spec.kind() {
            OpKind::Join => aux_bytes,
            _ => 0.0,
        };

        let op_time = match measured {
            Some(t) => t,
            None => {
                let vol_total =
                    OpVolume::new(in_bytes as f64, out_bytes as f64, op_aux);
                match device {
                    Device::Cpu => {
                        // Each core processes its partition in parallel;
                        // the chain waits for the slowest ≈ mean share.
                        let n = env.num_cores as f64;
                        let vol = OpVolume::new(
                            vol_total.in_bytes / n,
                            vol_total.out_bytes / n,
                            vol_total.aux_bytes,
                        );
                        env.model.op_time(Device::Cpu, kind, vol)
                    }
                    Device::Gpu => {
                        // Partitions coalesced per op; GPUs split the work.
                        let t = env.model.op_time(Device::Gpu, kind, vol_total);
                        Duration::from_secs_f64(t.as_secs_f64() / env.num_gpus as f64)
                    }
                }
            }
        };

        // Transfer charges (Alg. 2 placement): entering the device at the
        // first op or on a CPU→GPU switch; leaving at the last op or on a
        // GPU→CPU switch. Simulated backend only (real GPU ops include
        // marshaling in their measured time).
        let mut op_transfer = Duration::ZERO;
        if env.backend == ExecBackend::Simulated && device == Device::Gpu {
            let entering = i == 0 || plan.per_op[i - 1] == Device::Cpu;
            let leaving = i == last || plan.per_op[i + 1] == Device::Cpu;
            if entering {
                op_transfer += env.model.transfer_time(in_bytes as f64 + op_aux);
            }
            if leaving {
                op_transfer += env.model.transfer_time(out_bytes as f64);
            }
        }

        proc += op_time + op_transfer;
        transfer_total += op_transfer;
        traces.push(OpTrace {
            op_id: i,
            kind,
            device,
            time: op_time + op_transfer,
            in_bytes,
            out_bytes,
        });
        current = next;
    }

    Ok(ExecOutcome { result: current, proc, transfer: transfer_total, traces })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};
    use crate::engine::ops::filter::Predicate;
    use crate::engine::window::WindowSpec;
    use crate::query::builder::QueryBuilder;
    use std::time::Duration as D;

    fn batch(rows: usize) -> ColumnBatch {
        let schema = Schema::new(vec![Field::i32("k"), Field::f32("v")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::I32((0..rows as i32).collect()),
                Column::F32((0..rows).map(|i| i as f32).collect()),
            ],
        )
        .unwrap()
    }

    fn query() -> Query {
        QueryBuilder::scan("t")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .filter("v", Predicate::Ge(10.0))
            .select(&["k", "v"])
            .build()
            .unwrap()
    }

    fn env(model: &DeviceModel) -> ExecEnv<'_> {
        ExecEnv {
            model,
            backend: ExecBackend::Simulated,
            num_cores: 12,
            num_gpus: 1,
            runtime: None,
        }
    }

    #[test]
    fn sim_execution_transforms_and_times() {
        let model = DeviceModel::default();
        let q = query();
        let plan = DevicePlan::all(Device::Cpu, q.len());
        let out = execute(&q, &plan, batch(100), None, &env(&model)).unwrap();
        assert_eq!(out.result.live_rows(), 90);
        assert!(out.proc >= model.batch_fixed);
        assert_eq!(out.traces.len(), 3);
        assert_eq!(out.transfer, Duration::ZERO); // all-CPU: no PCIe
    }

    #[test]
    fn gpu_plan_charges_transfers() {
        let model = DeviceModel::default();
        let q = query();
        let plan = DevicePlan::all(Device::Gpu, q.len());
        let out = execute(&q, &plan, batch(100), None, &env(&model)).unwrap();
        assert!(out.transfer > Duration::ZERO);
    }

    #[test]
    fn device_switch_adds_boundary_transfers() {
        let model = DeviceModel::default();
        let q = query();
        // CPU -> GPU -> CPU: two boundaries around op 1.
        let plan = DevicePlan {
            per_op: vec![Device::Cpu, Device::Gpu, Device::Cpu],
        };
        let hybrid = execute(&q, &plan, batch(100), None, &env(&model)).unwrap();
        assert!(hybrid.transfer > Duration::ZERO);
        let all_cpu = execute(
            &q,
            &DevicePlan::all(Device::Cpu, q.len()),
            batch(100),
            None,
            &env(&model),
        )
        .unwrap();
        assert_eq!(all_cpu.transfer, Duration::ZERO);
    }

    #[test]
    fn more_gpus_cut_gpu_time() {
        let model = DeviceModel::default();
        let q = query();
        let plan = DevicePlan::all(Device::Gpu, q.len());
        let mut e1 = env(&model);
        e1.num_gpus = 1;
        let t1 = execute(&q, &plan, batch(50_000), None, &e1).unwrap().proc;
        let mut e4 = env(&model);
        e4.num_gpus = 4;
        let t4 = execute(&q, &plan, batch(50_000), None, &e4).unwrap().proc;
        assert!(t4 < t1);
    }

    #[test]
    fn plan_arity_checked() {
        let model = DeviceModel::default();
        let q = query();
        let plan = DevicePlan::all(Device::Cpu, 1);
        assert!(execute(&q, &plan, batch(10), None, &env(&model)).is_err());
    }

    #[test]
    fn join_uses_window_aux() {
        let model = DeviceModel::default();
        let q = QueryBuilder::scan("j")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .join_window("k", "k")
            .build()
            .unwrap();
        let w = batch(100);
        let plan = DevicePlan::all(Device::Cpu, q.len());
        let out = execute(&q, &plan, batch(100), Some(&w), &env(&model)).unwrap();
        // Self-join on unique keys: 100 matches.
        assert_eq!(out.result.rows(), 100);
    }
}
