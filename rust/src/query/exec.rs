//! Physical execution of a planned query over a micro-batch.
//!
//! Given a [`PhysicalPlan`] (one device-annotated op per DAG node, from
//! MapDevice or a baseline policy), walks the operation DAG in
//! topological order and accounts processing time:
//!
//! * **Simulated backend** — operators transform data natively; *time* is
//!   charged by the calibrated [`DeviceModel`]: CPU ops at per-partition
//!   volume (partitions run on `NumCores` cores in parallel), GPU ops at
//!   coalesced volume divided across `NumGpus`, plus host↔device transfer
//!   on every device boundary (Alg. 2's `Trans` placement, shared with
//!   the planner via [`transfer_boundaries`]) — including boundaries
//!   where a branch fans out to consumers on the other device. A
//!   GPU-mapped op additionally pays `DeviceModel::coalesce_time` on its
//!   **entering** boundary: the explicit contiguous staging of its
//!   chunked input (the real backend performs exactly that coalesce in
//!   [`gpu::run_op_chunked`]).
//! * **Real backend** — CPU ops run native, GPU ops run through the PJRT
//!   artifacts; wall-clock timing.
//!
//! Data flows as [`ChunkedBatch`]es end to end: a `Union` node's input
//! assembly and branch fan-out are O(#chunks) appends/Arc bumps, never a
//! materializing concat.
//!
//! A branching DAG can end in several sinks; [`ExecOutcome::result`] is
//! the primary (highest-id) sink's output and
//! [`ExecOutcome::branch_results`] carries the others.
//!
//! # Shared-device occupancy
//!
//! A session round multiplexes many queries — across sources — over
//! **one** GPU per executor of its topology, so a simulated GPU op
//! cannot assume the device is idle: [`execute_with_occupancy`] takes
//! an externally-imposed device plan plus a [`GpuOccupancy`] arbiter.
//! Before each simulated GPU op runs, the executor requests the device
//! at the op's ready time on the query's local timeline; the arbiter
//! (one of the session round's per-executor [`GpuTimeline`]s) returns
//! the contention wait, which is charged into `proc` and surfaced
//! separately as [`ExecOutcome::contention`] — so metrics, admission
//! (Eq. 6) and the online optimizer all learn the *contended*
//! latencies. Each timeline serializes reservations FIFO **in request
//! order**, which is the order the session executes the round's queries
//! — the scheduler's chosen grant order
//! ([`Prediction::order`](crate::coordinator::schedule::Prediction::order),
//! shortest-GPU-segment-first when that beats FIFO), so the executed
//! serialization realizes exactly the predicted one. [`execute`] is the
//! uncontended form ([`NoContention`]).

use crate::config::ExecBackend;
use crate::devices::model::{DeviceModel, OpVolume};
use crate::devices::{cpu, gpu, Device};
use crate::engine::chunked::ChunkedBatch;
use crate::engine::encode::ChunkStats;
use crate::error::{Error, Result};
use crate::query::dag::{OpKind, OpNode, OpSpec, Query};
use crate::query::fuse::{FusedGroup, FusedPlan};
use crate::query::physical::{transfer_boundaries, PhysicalPlan};
use crate::runtime::client::Runtime;
use std::time::{Duration, Instant};

pub use crate::query::physical::DevicePlan;

/// Execution environment.
pub struct ExecEnv<'a> {
    pub model: &'a DeviceModel,
    pub backend: ExecBackend,
    pub num_cores: usize,
    pub num_gpus: usize,
    /// Required for the Real backend's GPU path.
    pub runtime: Option<&'a Runtime>,
}

/// Arbiter of simulated shared-GPU occupancy. The executor calls
/// [`GpuOccupancy::request`] once per simulated GPU-mapped op with the
/// op's ready time on the *query-local* timeline (elapsed `proc` so far)
/// and the device-busy duration (kernel time + its boundary transfers);
/// the arbiter returns the extra wait before the op may start.
pub trait GpuOccupancy {
    fn request(&mut self, local_start: Duration, busy: Duration) -> Duration;
}

/// An unshared device: every op starts the moment it is ready.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoContention;

impl GpuOccupancy for NoContention {
    fn request(&mut self, _local_start: Duration, _busy: Duration) -> Duration {
        Duration::ZERO
    }
}

/// FIFO single-device timeline shared across the queries of one
/// scheduling round: reservations serialize in request order (queries
/// run in the round's grant order, each walking its ops in topological
/// order), so the device is never double-booked. The session charges
/// every query's simulated GPU ops against one of these **per executor
/// of its topology** instead of per-query idle-GPU clocks.
/// Deliberately *not* `Copy`: a timeline is mutable shared state — an
/// accidental by-value use would fork it and silently double-book the
/// device.
#[derive(Clone, Debug, Default)]
pub struct GpuTimeline {
    free_at: Duration,
    busy: Duration,
    waited: Duration,
    reservations: usize,
}

impl GpuTimeline {
    pub fn new() -> GpuTimeline {
        GpuTimeline::default()
    }

    /// A timeline whose device is already occupied until `offset` on
    /// the epoch clock. The sharded session seeds each source's local
    /// timelines from its timeline-bank lease
    /// ([`crate::coordinator::timeline_bank`]) so this source's
    /// reservations queue behind the busy horizons earlier tickets
    /// committed — cross-shard contention is priced without sharing a
    /// mutable timeline across threads. The seeded occupancy is not
    /// this query's work: `busy`/`waited`/`reservations` start at zero.
    pub fn starting_at(offset: Duration) -> GpuTimeline {
        GpuTimeline { free_at: offset, ..GpuTimeline::default() }
    }

    /// When the device next becomes free (local-timeline offset).
    pub fn free_at(&self) -> Duration {
        self.free_at
    }

    /// Total reserved device-busy time.
    pub fn busy(&self) -> Duration {
        self.busy
    }

    /// Total contention wait handed out to requesters.
    pub fn waited(&self) -> Duration {
        self.waited
    }

    pub fn reservations(&self) -> usize {
        self.reservations
    }
}

impl GpuOccupancy for GpuTimeline {
    fn request(&mut self, local_start: Duration, busy: Duration) -> Duration {
        let start = self.free_at.max(local_start);
        let wait = start - local_start;
        self.free_at = start + busy;
        self.busy += busy;
        self.waited += wait;
        self.reservations += 1;
        wait
    }
}

/// Per-operation execution record.
#[derive(Clone, Debug)]
pub struct OpTrace {
    pub op_id: usize,
    pub kind: OpKind,
    pub device: Device,
    pub time: Duration,
    pub in_bytes: usize,
    pub out_bytes: usize,
}

/// Result of one micro-batch execution.
#[derive(Debug)]
pub struct ExecOutcome {
    /// Primary sink output (for a linear chain: the last op's output).
    pub result: ChunkedBatch,
    /// Outputs of the query's other sinks (empty for linear chains),
    /// as `(op_id, batch)` in ascending op id.
    pub branch_results: Vec<(usize, ChunkedBatch)>,
    /// `Proc_i`: full processing-phase duration (contention included).
    pub proc: Duration,
    /// Host↔device transfer share of `proc` (incl. coalesce staging).
    pub transfer: Duration,
    /// Share of `proc` spent waiting on the shared GPU timeline
    /// (cross-query contention; zero under [`NoContention`]).
    pub contention: Duration,
    /// Per-op traces in topological (= op id) order.
    pub traces: Vec<OpTrace>,
    /// Chunks a fused chain skipped outright because min/max block
    /// stats proved its filter predicates unsatisfiable (zero without a
    /// fused plan; data results are identical either way).
    pub pruned_chunks: usize,
}

/// Optional execution inputs beyond the plan itself.
#[derive(Default)]
pub struct ExecOpts<'a> {
    /// Fusion sidecar from [`crate::query::fuse::fuse`]: member runs
    /// execute as one traversal. Charged times, transfers and occupancy
    /// requests are **identical** to staged execution — each member
    /// still emits its own [`OpTrace`] with the virtual intermediate
    /// sizes the staged pipeline would have materialized — so plans,
    /// schedules and metrics are unaffected; only real wall-clock work
    /// (and the intermediate allocations) shrink. On the Real backend a
    /// GPU-device group falls back to staged member execution (the PJRT
    /// artifacts are per-op).
    pub fused: Option<&'a FusedPlan>,
    /// Override for the window-side (aux) `(bytes, chunks)` the Eq. 9
    /// transfer/coalesce terms charge — the *encoded* window footprint
    /// when cold state is encoded, in place of the plain snapshot's
    /// allocation. Mirrored with the planner's `QueryCandidate` aux so
    /// the two never diverge.
    pub aux: Option<(f64, usize)>,
    /// Per-chunk encode-time min/max stats for the *scan-headed* input,
    /// index-aligned with its chunk list
    /// ([`crate::engine::window::WindowState::snapshot_chunk_stats`]):
    /// fused aggregate-tail pruning reuses the bounds already computed
    /// when a cold window chunk was encoded instead of recomputing them
    /// inline. Applied only to fused groups headed by the source scan
    /// and only when the lengths line up (a sliced cluster share passes
    /// `None`); `None` entries mean "unknown — compute inline". Data
    /// results are identical either way.
    pub chunk_stats: Option<&'a [Option<ChunkStats>]>,
}

/// Execute `query` over `input` with `plan` on an unshared device
/// ([`execute_with_occupancy`] with [`NoContention`]).
///
/// `window` is the window-state snapshot (join build side / windowed
/// aggregation scope) as a chunk list; `aux_bytes` its size for cost
/// accounting. `input` accepts a [`ChunkedBatch`] or a plain
/// `ColumnBatch` (lifted to a single chunk).
pub fn execute(
    query: &Query,
    plan: &PhysicalPlan,
    input: impl Into<ChunkedBatch>,
    window: Option<&ChunkedBatch>,
    env: &ExecEnv,
) -> Result<ExecOutcome> {
    execute_with_occupancy(query, plan, input, window, env, &mut NoContention)
}

/// Execute `query` over `input` with an externally-imposed `plan`,
/// arbitrating simulated GPU ops through `occupancy` (see the module
/// docs on shared-device occupancy). Data results are *identical* for
/// every occupancy — contention only adds simulated wait time.
pub fn execute_with_occupancy(
    query: &Query,
    plan: &PhysicalPlan,
    input: impl Into<ChunkedBatch>,
    window: Option<&ChunkedBatch>,
    env: &ExecEnv,
    occupancy: &mut dyn GpuOccupancy,
) -> Result<ExecOutcome> {
    execute_with_opts(query, plan, input, window, env, occupancy, &ExecOpts::default())
}

/// [`execute_with_occupancy`] plus [`ExecOpts`]: the fusion sidecar and
/// the encoded-aux pricing override. The full entry point the session
/// drives.
pub fn execute_with_opts(
    query: &Query,
    plan: &PhysicalPlan,
    input: impl Into<ChunkedBatch>,
    window: Option<&ChunkedBatch>,
    env: &ExecEnv,
    occupancy: &mut dyn GpuOccupancy,
    opts: &ExecOpts,
) -> Result<ExecOutcome> {
    let input = input.into();
    if query.ops.is_empty() {
        return Err(Error::Plan("cannot execute an empty query".into()));
    }
    if plan.per_op.len() != query.ops.len() {
        return Err(Error::Plan(format!(
            "plan covers {} ops, query has {}",
            plan.per_op.len(),
            query.ops.len()
        )));
    }
    if env.num_cores == 0 || env.num_gpus == 0 {
        return Err(Error::Plan("need at least one core and one gpu".into()));
    }
    // Aux (window-state) pricing: the encoded footprint when the caller
    // supplies one, else the plain snapshot allocation.
    let (aux_bytes, aux_chunks) = match opts.aux {
        Some((bytes, chunks)) => (bytes, chunks),
        None => (
            window.map(|w| w.alloc_bytes()).unwrap_or(0) as f64,
            window.map(|w| w.num_chunks()).unwrap_or(0),
        ),
    };
    let order = query.topo_order()?;
    let consumers = query.consumers();

    // Fusion sidecar → per-op dispatch tables. Real-backend GPU groups
    // fall back to staged member execution (PJRT artifacts are per-op);
    // everything else runs the group as one traversal at its head.
    let n_ops = query.ops.len();
    let mut fused_head: Vec<Option<&FusedGroup>> = vec![None; n_ops];
    let mut fused_follower = vec![false; n_ops];
    if let Some(f) = opts.fused {
        for g in &f.groups {
            if g.ops.iter().any(|&m| m >= n_ops) {
                return Err(Error::Plan("fused plan does not match query".into()));
            }
            if env.backend == ExecBackend::Real && g.device == Device::Gpu {
                continue;
            }
            fused_head[g.head()] = Some(g);
            for &m in &g.ops[1..] {
                fused_follower[m] = true;
            }
        }
    }

    // Per-node output slots; a slot is taken (moved) by its last
    // consumer and cloned for earlier ones.
    let mut outputs: Vec<Option<ChunkedBatch>> = Vec::new();
    outputs.resize_with(query.ops.len(), || None);
    let mut remaining_uses: Vec<usize> = consumers.iter().map(|c| c.len()).collect();
    let mut source = Some(input);

    let mut proc = env.model.batch_fixed;
    let mut transfer_total = Duration::ZERO;
    let mut contention_total = Duration::ZERO;
    let mut pruned_chunks = 0usize;
    let mut traces = Vec::with_capacity(query.ops.len());

    for &i in &order {
        let op = &query.ops[i];
        // Interior/tail members of an active fused group: the head's
        // traversal already produced (or will have produced — members
        // are contiguous in id order) the tail's output; nothing to do.
        if fused_follower[i] {
            continue;
        }

        if let Some(group) = fused_head[i] {
            let current =
                assemble_input(op, &mut source, &mut outputs, &mut remaining_uses)?;
            // Encode-time stats flow into scan-headed groups only: their
            // input *is* the staged chunk list the stats were taken
            // over. A group fed by an upstream op sees transformed
            // chunks the stored bounds no longer describe.
            let group_stats = opts.chunk_stats.filter(|s| {
                query.ops[group.head()].inputs.is_empty()
                    && s.len() == current.num_chunks()
            });
            let fused = run_fused_group(
                query, plan, &consumers, group, current, env, occupancy, &mut proc,
                &mut traces, group_stats,
            )?;
            transfer_total += fused.transfer;
            contention_total += fused.contention;
            pruned_chunks += fused.pruned;
            outputs[group.tail()] = Some(fused.result);
            continue;
        }

        let device = plan.per_op[i].device;
        let kind = op.spec.kind();

        let current = assemble_input(op, &mut source, &mut outputs, &mut remaining_uses)?;
        // Cost models charge *allocated* bytes (dead rows still travel
        // through kernels and over PCIe until a shuffle compacts them).
        let in_bytes = current.alloc_bytes();
        let in_chunks = current.num_chunks();

        let (next, measured) = match (env.backend, device) {
            (ExecBackend::Real, Device::Gpu) => {
                let rt = env.runtime.ok_or_else(|| {
                    Error::Plan("Real backend needs a PJRT runtime for GPU ops".into())
                })?;
                let t0 = Instant::now();
                let out =
                    gpu::run_op_chunked(rt, &op.spec, &current, window, &query.window)?;
                (out, Some(t0.elapsed()))
            }
            (ExecBackend::Real, Device::Cpu) => {
                let t0 = Instant::now();
                let out = cpu::run_op_chunked(&op.spec, &current, window, &query.window)?;
                (out, Some(t0.elapsed()))
            }
            (ExecBackend::Simulated, _) => {
                let out = cpu::run_op_chunked(&op.spec, &current, window, &query.window)?;
                (out, None)
            }
        };
        let out_bytes = next.alloc_bytes();

        // Windowed operators also consume the window side input.
        let op_aux = match kind {
            OpKind::Join => aux_bytes,
            _ => 0.0,
        };

        let op_time = match measured {
            Some(t) => t,
            None => {
                let vol_total =
                    OpVolume::new(in_bytes as f64, out_bytes as f64, op_aux);
                match device {
                    Device::Cpu => {
                        // Each core processes its partition in parallel;
                        // the chain waits for the slowest ≈ mean share.
                        let n = env.num_cores as f64;
                        let vol = OpVolume::new(
                            vol_total.in_bytes / n,
                            vol_total.out_bytes / n,
                            vol_total.aux_bytes,
                        );
                        env.model.op_time(Device::Cpu, kind, vol)
                    }
                    Device::Gpu => {
                        // Partitions coalesced per op; GPUs split the work.
                        let t = env.model.op_time(Device::Gpu, kind, vol_total);
                        Duration::from_secs_f64(t.as_secs_f64() / env.num_gpus as f64)
                    }
                }
            }
        };

        // Transfer charges (Alg. 2 placement, shared with the planner):
        // entering the device at a source op or on a CPU→GPU boundary —
        // paying the contiguous coalesce staging (chunk-count-aware: a
        // single-chunk side coalesces as an O(1) clone, free) plus the
        // PCIe copy — and leaving at a sink op or on a GPU→CPU boundary
        // (already contiguous device-side, PCIe only) — branch edges
        // included. Simulated backend only (real GPU ops include
        // marshaling in their measured time).
        let mut op_transfer = Duration::ZERO;
        let mut op_wait = Duration::ZERO;
        if env.backend == ExecBackend::Simulated && device == Device::Gpu {
            let (entering, leaving) =
                transfer_boundaries(&op.inputs, &consumers[i], |n| {
                    plan.per_op[n].device == Device::Cpu
                });
            if entering {
                let staged = in_bytes as f64 + op_aux;
                op_transfer += env.model.coalesce_time(in_bytes as f64, in_chunks)
                    + env.model.transfer_time(staged);
                if op_aux > 0.0 {
                    op_transfer += env.model.coalesce_time(op_aux, aux_chunks);
                }
            }
            if leaving {
                op_transfer += env.model.transfer_time(out_bytes as f64);
            }
            // Shared-device arbitration: the op is ready at the local
            // elapsed `proc`; it holds the device for its kernel time
            // plus its boundary transfers.
            op_wait = occupancy.request(proc, op_time + op_transfer);
        }

        proc += op_wait + op_time + op_transfer;
        transfer_total += op_transfer;
        contention_total += op_wait;
        traces.push(OpTrace {
            op_id: i,
            kind,
            device,
            time: op_time + op_transfer,
            in_bytes,
            out_bytes,
        });
        outputs[i] = Some(next);
    }

    // Collect sink outputs (slots never consumed); the highest-id sink
    // is the primary result — for a linear chain, the last op.
    let mut sink_outputs: Vec<(usize, ChunkedBatch)> = outputs
        .iter_mut()
        .enumerate()
        .filter(|(i, _)| consumers[*i].is_empty())
        .map(|(i, slot)| {
            let batch = slot.take().expect("sink executed");
            (i, batch)
        })
        .collect();
    // Kahn's min-ready rule on a validated (producers-before-consumers)
    // DAG emits ids in ascending order, so `traces` is already sorted
    // by op id — no sort needed.
    let (_, result) = sink_outputs.pop().expect("validated query has a sink");

    Ok(ExecOutcome {
        result,
        branch_results: sink_outputs,
        proc,
        transfer: transfer_total,
        contention: contention_total,
        traces,
        pruned_chunks,
    })
}

/// Input assembly: move/clone/append producer outputs. A multi-input
/// node (Union) appends its branches' chunk lists here — O(#chunks),
/// zero row copies — so the operator itself stays unary. Branch fan-out
/// clones are O(#chunks) Arc bumps.
fn assemble_input(
    op: &OpNode,
    source: &mut Option<ChunkedBatch>,
    outputs: &mut [Option<ChunkedBatch>],
    remaining_uses: &mut [usize],
) -> Result<ChunkedBatch> {
    if op.inputs.is_empty() {
        source
            .take()
            .ok_or_else(|| Error::Plan("query has more than one source scan".into()))
    } else if op.inputs.len() == 1 {
        take_output(outputs, remaining_uses, op.inputs[0])
    } else {
        let parts: Vec<ChunkedBatch> = op
            .inputs
            .iter()
            .map(|&p| take_output(outputs, remaining_uses, p))
            .collect::<Result<_>>()?;
        let refs: Vec<&ChunkedBatch> = parts.iter().collect();
        ChunkedBatch::concat(&refs)
    }
}

struct FusedRun {
    result: ChunkedBatch,
    transfer: Duration,
    contention: Duration,
    pruned: usize,
}

/// Execute one fused group as a single traversal and charge every
/// member exactly as staged execution would have: the same per-member
/// modeled times over the same *virtual* intermediate sizes (filter
/// keeps its input allocation; select is `4·kept·rows + rows`; affine
/// appends one 4-byte column; the aggregate tail is priced at its real
/// output), the same transfer boundaries, and one occupancy request per
/// simulated GPU member in member order. Plans, schedules and metrics
/// therefore cannot tell fused from staged — only wall-clock work and
/// intermediate allocations differ. On the Real backend (CPU groups)
/// the single measured duration is attributed to the tail's trace.
#[allow(clippy::too_many_arguments)]
fn run_fused_group(
    query: &Query,
    plan: &PhysicalPlan,
    consumers: &[Vec<usize>],
    group: &FusedGroup,
    current: ChunkedBatch,
    env: &ExecEnv,
    occupancy: &mut dyn GpuOccupancy,
    proc: &mut Duration,
    traces: &mut Vec<OpTrace>,
    chunk_stats: Option<&[Option<ChunkStats>]>,
) -> Result<FusedRun> {
    let device = group.device;
    let head_in_chunks = current.num_chunks();
    let rows_total = current.rows();
    let measured_start =
        (env.backend == ExecBackend::Real).then(Instant::now);
    let (result, pruned) = match chunk_stats {
        Some(stats) => cpu::run_fused_chain_with_stats(&group.spec, &current, stats)?,
        None => cpu::run_fused_chain(&group.spec, &current)?,
    };
    let measured = measured_start.map(|t| t.elapsed());

    let mut transfer_total = Duration::ZERO;
    let mut contention_total = Duration::ZERO;
    let mut cur_bytes = current.alloc_bytes();
    for (mi, &m) in group.ops.iter().enumerate() {
        let mop = &query.ops[m];
        let kind = mop.spec.kind();
        let m_in_bytes = cur_bytes;
        let m_out_bytes = match &mop.spec {
            OpSpec::Scan => cur_bytes,
            OpSpec::Filter { .. } => cur_bytes,
            OpSpec::ProjectSelect { keep } => 4 * keep.len() * rows_total + rows_total,
            OpSpec::ProjectAffine { .. } => cur_bytes + 4 * rows_total,
            OpSpec::Aggregate { .. } => result.alloc_bytes(),
            other => {
                return Err(Error::Plan(format!(
                    "op {m} ({}) is not fusable",
                    other.kind().name()
                )))
            }
        };
        let op_time = match measured {
            // One real traversal: the chain's wall-clock lands on the
            // tail (where the output materializes).
            Some(t) if mi + 1 == group.ops.len() => t,
            Some(_) => Duration::ZERO,
            None => {
                let vol_total =
                    OpVolume::new(m_in_bytes as f64, m_out_bytes as f64, 0.0);
                match device {
                    Device::Cpu => {
                        let n = env.num_cores as f64;
                        let vol = OpVolume::new(
                            vol_total.in_bytes / n,
                            vol_total.out_bytes / n,
                            vol_total.aux_bytes,
                        );
                        env.model.op_time(Device::Cpu, kind, vol)
                    }
                    Device::Gpu => {
                        let t = env.model.op_time(Device::Gpu, kind, vol_total);
                        Duration::from_secs_f64(t.as_secs_f64() / env.num_gpus as f64)
                    }
                }
            }
        };
        let mut op_transfer = Duration::ZERO;
        let mut op_wait = Duration::ZERO;
        if env.backend == ExecBackend::Simulated && device == Device::Gpu {
            let (entering, leaving) =
                transfer_boundaries(&mop.inputs, &consumers[m], |n| {
                    plan.per_op[n].device == Device::Cpu
                });
            // Fusable members never read the window side: no aux terms.
            // Interior members sit between same-device neighbors, so
            // only the head can enter and only the tail can leave — the
            // group coalesces once at its entering boundary, as staged.
            if entering {
                op_transfer += env
                    .model
                    .coalesce_time(m_in_bytes as f64, head_in_chunks)
                    + env.model.transfer_time(m_in_bytes as f64);
            }
            if leaving {
                op_transfer += env.model.transfer_time(m_out_bytes as f64);
            }
            op_wait = occupancy.request(*proc, op_time + op_transfer);
        }
        *proc += op_wait + op_time + op_transfer;
        transfer_total += op_transfer;
        contention_total += op_wait;
        traces.push(OpTrace {
            op_id: m,
            kind,
            device,
            time: op_time + op_transfer,
            in_bytes: m_in_bytes,
            out_bytes: m_out_bytes,
        });
        cur_bytes = m_out_bytes;
    }
    Ok(FusedRun {
        result,
        transfer: transfer_total,
        contention: contention_total,
        pruned,
    })
}

/// Consume producer `p`'s output slot: move it out on the last use,
/// clone it while other consumers still need it (O(#chunks) Arc bumps).
fn take_output(
    outputs: &mut [Option<ChunkedBatch>],
    remaining_uses: &mut [usize],
    p: usize,
) -> Result<ChunkedBatch> {
    remaining_uses[p] = remaining_uses[p].saturating_sub(1);
    if outputs[p].is_none() {
        return Err(Error::Plan(format!("op {p} consumed before it produced")));
    }
    if remaining_uses[p] == 0 {
        Ok(outputs[p].take().expect("checked above"))
    } else {
        Ok(outputs[p].as_ref().expect("checked above").clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};
    use crate::engine::ops::filter::Predicate;
    use crate::engine::window::WindowSpec;
    use crate::query::builder::QueryBuilder;
    use std::time::Duration as D;

    fn batch(rows: usize) -> ColumnBatch {
        let schema = Schema::new(vec![Field::i32("k"), Field::f32("v")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::I32((0..rows as i32).collect::<Vec<i32>>().into()),
                Column::F32((0..rows).map(|i| i as f32).collect::<Vec<f32>>().into()),
            ],
        )
        .unwrap()
    }

    fn query() -> Query {
        QueryBuilder::scan("t")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .filter("v", Predicate::Ge(10.0))
            .select(&["k", "v"])
            .build()
            .unwrap()
    }

    fn env(model: &DeviceModel) -> ExecEnv<'_> {
        ExecEnv {
            model,
            backend: ExecBackend::Simulated,
            num_cores: 12,
            num_gpus: 1,
            runtime: None,
        }
    }

    fn all(q: &Query, d: Device) -> PhysicalPlan {
        PhysicalPlan::uniform(q, d)
    }

    #[test]
    fn sim_execution_transforms_and_times() {
        let model = DeviceModel::default();
        let q = query();
        let out = execute(&q, &all(&q, Device::Cpu), batch(100), None, &env(&model)).unwrap();
        assert_eq!(out.result.live_rows(), 90);
        assert!(out.proc >= model.batch_fixed);
        assert_eq!(out.traces.len(), 3);
        assert_eq!(out.transfer, Duration::ZERO); // all-CPU: no PCIe
        assert!(out.branch_results.is_empty());
    }

    #[test]
    fn gpu_plan_charges_transfers() {
        let model = DeviceModel::default();
        let q = query();
        let out = execute(&q, &all(&q, Device::Gpu), batch(100), None, &env(&model)).unwrap();
        assert!(out.transfer > Duration::ZERO);
    }

    #[test]
    fn device_switch_adds_boundary_transfers() {
        let model = DeviceModel::default();
        let q = query();
        // CPU -> GPU -> CPU: two boundaries around op 1.
        let plan = PhysicalPlan::from_devices(
            &q,
            &DevicePlan { per_op: vec![Device::Cpu, Device::Gpu, Device::Cpu] },
        )
        .unwrap();
        let hybrid = execute(&q, &plan, batch(100), None, &env(&model)).unwrap();
        assert!(hybrid.transfer > Duration::ZERO);
        let all_cpu =
            execute(&q, &all(&q, Device::Cpu), batch(100), None, &env(&model)).unwrap();
        assert_eq!(all_cpu.transfer, Duration::ZERO);
    }

    #[test]
    fn more_gpus_cut_gpu_time() {
        let model = DeviceModel::default();
        let q = query();
        let plan = all(&q, Device::Gpu);
        let mut e1 = env(&model);
        e1.num_gpus = 1;
        let t1 = execute(&q, &plan, batch(50_000), None, &e1).unwrap().proc;
        let mut e4 = env(&model);
        e4.num_gpus = 4;
        let t4 = execute(&q, &plan, batch(50_000), None, &e4).unwrap().proc;
        assert!(t4 < t1);
    }

    #[test]
    fn plan_arity_checked() {
        let model = DeviceModel::default();
        let q = query();
        let plan = PhysicalPlan {
            per_op: PhysicalPlan::uniform(&q, Device::Cpu).per_op[..1].to_vec(),
        };
        assert!(execute(&q, &plan, batch(10), None, &env(&model)).is_err());
    }

    #[test]
    fn empty_query_is_plan_error_not_panic() {
        let model = DeviceModel::default();
        let q = Query {
            name: "e".into(),
            ops: vec![],
            window: WindowSpec::tumbling(D::from_secs(30)),
            uses_window_state: false,
        };
        let plan = PhysicalPlan { per_op: vec![] };
        let r = execute(&q, &plan, batch(1), None, &env(&model));
        assert!(matches!(r, Err(Error::Plan(_))), "{r:?}");
    }

    /// Pins the byte measure the device cost model charges: *allocated*
    /// bytes (dead rows included), not live bytes — filtered rows still
    /// flow through downstream kernels until a shuffle compacts them.
    #[test]
    fn cost_model_charges_allocated_not_live_bytes() {
        let model = DeviceModel::default();
        let q = query();
        let mut input = batch(100);
        for i in 0..50 {
            input.validity.set_live(i, false);
        }
        assert!(input.live_bytes() < input.alloc_bytes());
        let expected_in = input.alloc_bytes();
        let out = execute(&q, &all(&q, Device::Cpu), input, None, &env(&model)).unwrap();
        // The scan (op 0) sees the full allocated volume.
        assert_eq!(out.traces[0].in_bytes, expected_in);
    }

    #[test]
    fn join_uses_window_aux() {
        let model = DeviceModel::default();
        let q = QueryBuilder::scan("j")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .join_window("k", "k")
            .build()
            .unwrap();
        let w = ChunkedBatch::from_batch(batch(100));
        let out = execute(&q, &all(&q, Device::Cpu), batch(100), Some(&w), &env(&model)).unwrap();
        // Self-join on unique keys: 100 matches.
        assert_eq!(out.result.rows(), 100);
    }

    #[test]
    fn branched_query_yields_multiple_sink_results() {
        let model = DeviceModel::default();
        // scan -> filter -> {select-k (branch sink), select-v (main sink)}
        let q = QueryBuilder::scan("b")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .filter("v", Predicate::Ge(10.0))
            .branch(|b| b.select(&["k"]))
            .select(&["v"])
            .build()
            .unwrap();
        let out = execute(&q, &all(&q, Device::Cpu), batch(100), None, &env(&model)).unwrap();
        // Primary sink = highest id (select-v); one branch sink.
        assert_eq!(out.result.schema().len(), 1);
        assert!(out.result.coalesce().column("v").is_ok());
        assert_eq!(out.branch_results.len(), 1);
        let (branch_id, branch) = &out.branch_results[0];
        assert_eq!(*branch_id, 2);
        assert!(branch.coalesce().column("k").is_ok());
        assert_eq!(branch.live_rows(), out.result.live_rows());
        assert_eq!(out.traces.len(), 4);
    }

    #[test]
    fn union_merges_branches() {
        let model = DeviceModel::default();
        // Diamond: rows < 10 fail the branch filter; union = all ∪ filtered.
        let q = QueryBuilder::scan("u")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .merge_union(|b| b.filter("v", Predicate::Ge(10.0)))
            .build()
            .unwrap();
        let out = execute(&q, &all(&q, Device::Cpu), batch(100), None, &env(&model)).unwrap();
        assert_eq!(out.result.live_rows(), 100 + 90);
        assert!(out.branch_results.is_empty());
    }

    /// The tentpole claim at the executor level: a Union's input
    /// assembly appends its branches' chunk lists — the merged batch
    /// aliases the branch outputs' chunk allocations, row copies never
    /// happen.
    #[test]
    fn union_assembly_shares_branch_chunks() {
        let model = DeviceModel::default();
        let q = QueryBuilder::scan("u")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .merge_union(|b| b.filter("v", Predicate::Ge(10.0)))
            .build()
            .unwrap();
        let input = batch(100);
        let input_col = input.columns[1].clone();
        let out = execute(&q, &all(&q, Device::Cpu), input, None, &env(&model)).unwrap();
        // Union output: scan branch chunk + filter branch chunk, both
        // sharing the source allocation (scan and filter are zero-copy).
        assert_eq!(out.result.num_chunks(), 2);
        for chunk in out.result.chunks() {
            assert!(
                chunk.columns[1].shares_memory(&input_col),
                "union materialized a branch instead of appending its chunks"
            );
        }
    }

    #[test]
    fn branch_boundary_charges_transfer_once() {
        let model = DeviceModel::default();
        // GPU filter fanning out to two CPU selects: the filter leaves
        // the device once (one out-transfer), plus its entry (coalesce
        // staging + in-transfer). Two-chunk input: the entering coalesce
        // is charged (a single-chunk input would cross via an O(1)
        // clone, below).
        let q = QueryBuilder::scan("b")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .filter("v", Predicate::Ge(10.0))
            .branch(|b| b.select(&["k"]))
            .select(&["v"])
            .build()
            .unwrap();
        let plan = PhysicalPlan::from_devices(
            &q,
            &DevicePlan {
                per_op: vec![Device::Cpu, Device::Gpu, Device::Cpu, Device::Cpu],
            },
        )
        .unwrap();
        let mut input = ChunkedBatch::from_batch(batch(60));
        input.push(batch(40)).unwrap();
        let out = execute(&q, &plan, input, None, &env(&model)).unwrap();
        assert!(out.transfer > Duration::ZERO);
        // The transfer equals coalesce(in, 2 chunks) + entry(in) +
        // exit(out) for the filter only (scan preserves the chunk list).
        let filter_trace = out.traces.iter().find(|t| t.op_id == 1).unwrap();
        let expected = model.coalesce_time(filter_trace.in_bytes as f64, 2)
            + model.transfer_time(filter_trace.in_bytes as f64)
            + model.transfer_time(filter_trace.out_bytes as f64);
        assert_eq!(out.transfer, expected);
        assert!(model.coalesce_time(filter_trace.in_bytes as f64, 2) > Duration::ZERO);
    }

    #[test]
    fn single_chunk_entry_skips_coalesce_charge() {
        // The same plan over a one-chunk input pays PCIe but no staging
        // copy: the real backend's coalesce of one chunk is an O(1)
        // clone (ROADMAP chunk-count-aware coalesce charge).
        let model = DeviceModel::default();
        let q = query();
        let plan = PhysicalPlan::from_devices(
            &q,
            &DevicePlan { per_op: vec![Device::Cpu, Device::Gpu, Device::Cpu] },
        )
        .unwrap();
        let out = execute(&q, &plan, batch(100), None, &env(&model)).unwrap();
        let filter_trace = out.traces.iter().find(|t| t.op_id == 1).unwrap();
        let expected = model.transfer_time(filter_trace.in_bytes as f64)
            + model.transfer_time(filter_trace.out_bytes as f64);
        assert_eq!(out.transfer, expected, "single-chunk coalesce must be free");
    }

    #[test]
    fn occupancy_waits_extend_proc_not_results() {
        // A busy shared timeline delays GPU ops (contention observable
        // in `proc`/`contention`) without perturbing data results.
        let model = DeviceModel::default();
        let q = query();
        let plan = all(&q, Device::Gpu);
        let free = execute(&q, &plan, batch(1000), None, &env(&model)).unwrap();

        let mut timeline = GpuTimeline::new();
        // Pre-book the device for one simulated second.
        timeline.request(Duration::ZERO, Duration::from_secs(1));
        let contended = execute_with_occupancy(
            &q,
            &plan,
            batch(1000),
            None,
            &env(&model),
            &mut timeline,
        )
        .unwrap();
        assert!(contended.contention > Duration::ZERO);
        assert_eq!(contended.proc, free.proc + contended.contention);
        assert_eq!(free.contention, Duration::ZERO);
        assert_eq!(contended.result, free.result);
    }

    #[test]
    fn gpu_timeline_serializes_reservations() {
        let mut t = GpuTimeline::new();
        // First op: ready at 0, runs 2s.
        assert_eq!(t.request(Duration::ZERO, Duration::from_secs(2)), Duration::ZERO);
        // Second requester ready at 1s must wait 1s (device busy to 2s).
        assert_eq!(
            t.request(Duration::from_secs(1), Duration::from_secs(3)),
            Duration::from_secs(1)
        );
        // Third ready at 10s: device free at 5s, no wait.
        assert_eq!(t.request(Duration::from_secs(10), Duration::from_secs(1)), Duration::ZERO);
        assert_eq!(t.free_at(), Duration::from_secs(11));
        assert_eq!(t.busy(), Duration::from_secs(6));
        assert_eq!(t.waited(), Duration::from_secs(1));
        assert_eq!(t.reservations(), 3);
    }

    // ---- fused execution -------------------------------------------------

    use crate::engine::ops::aggregate::AggSpec;
    use crate::query::fuse;

    fn ranged_batch(lo: i32, rows: usize) -> ColumnBatch {
        let schema = Schema::new(vec![Field::i32("k"), Field::f32("v")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::I32((0..rows as i32).map(|i| i % 4).collect::<Vec<i32>>().into()),
                Column::F32(
                    (0..rows as i32).map(|i| (lo + i) as f32).collect::<Vec<f32>>().into(),
                ),
            ],
        )
        .unwrap()
    }

    fn chunked_input() -> ChunkedBatch {
        let mut c = ChunkedBatch::from_batch(ranged_batch(0, 40));
        c.push(ranged_batch(40, 30)).unwrap();
        c.push(ranged_batch(70, 30)).unwrap();
        c
    }

    fn fused_query() -> Query {
        QueryBuilder::scan("f")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .filter("v", Predicate::Ge(10.0))
            .project_affine("v", "v", 2.0, -1.0, "m")
            .select(&["k", "m"])
            .build()
            .unwrap()
    }

    /// The fused-execution contract, executor level: identical data,
    /// identical `proc`, identical per-member traces (times and the
    /// virtual intermediate sizes) as staged execution.
    #[test]
    fn fused_cpu_matches_staged_results_and_charges() {
        let model = DeviceModel::default();
        let q = fused_query();
        let plan = all(&q, Device::Cpu);
        let fplan = fuse::fuse(&q, &plan);
        assert_eq!(fplan.fused_ops(), 4);
        let staged = execute(&q, &plan, chunked_input(), None, &env(&model)).unwrap();
        let fused = execute_with_opts(
            &q,
            &plan,
            chunked_input(),
            None,
            &env(&model),
            &mut NoContention,
            &ExecOpts { fused: Some(&fplan), aux: None, chunk_stats: None },
        )
        .unwrap();
        assert_eq!(fused.result, staged.result);
        assert_eq!(fused.proc, staged.proc);
        assert_eq!(fused.transfer, staged.transfer);
        assert_eq!(fused.traces.len(), staged.traces.len());
        for (f, s) in fused.traces.iter().zip(&staged.traces) {
            assert_eq!(f.op_id, s.op_id);
            assert_eq!(f.time, s.time, "op {} time diverged", f.op_id);
            assert_eq!(f.in_bytes, s.in_bytes, "op {} in_bytes diverged", f.op_id);
            assert_eq!(f.out_bytes, s.out_bytes, "op {} out_bytes diverged", f.op_id);
        }
        assert_eq!(fused.pruned_chunks, 0);
    }

    /// On a shared GPU the fused group must make the same occupancy
    /// reservations in the same order as staged members would — the
    /// round's predicted serialization stays realized.
    #[test]
    fn fused_gpu_matches_staged_occupancy_and_transfers() {
        let model = DeviceModel::default();
        let q = fused_query();
        let plan = all(&q, Device::Gpu);
        let fplan = fuse::fuse(&q, &plan);
        let mut t_staged = GpuTimeline::new();
        t_staged.request(Duration::ZERO, Duration::from_millis(700));
        let staged = execute_with_occupancy(
            &q,
            &plan,
            chunked_input(),
            None,
            &env(&model),
            &mut t_staged,
        )
        .unwrap();
        let mut t_fused = GpuTimeline::new();
        t_fused.request(Duration::ZERO, Duration::from_millis(700));
        let fused = execute_with_opts(
            &q,
            &plan,
            chunked_input(),
            None,
            &env(&model),
            &mut t_fused,
            &ExecOpts { fused: Some(&fplan), aux: None, chunk_stats: None },
        )
        .unwrap();
        assert_eq!(fused.result, staged.result);
        assert_eq!(fused.proc, staged.proc);
        assert_eq!(fused.transfer, staged.transfer);
        assert_eq!(fused.contention, staged.contention);
        assert!(fused.contention > Duration::ZERO);
        assert_eq!(t_fused.reservations(), t_staged.reservations());
        assert_eq!(t_fused.free_at(), t_staged.free_at());
        assert_eq!(t_fused.busy(), t_staged.busy());
    }

    #[test]
    fn fused_aggregate_chain_matches_staged() {
        let model = DeviceModel::default();
        let q = QueryBuilder::scan("a")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .filter("v", Predicate::Ge(10.0))
            .aggregate(&["k"], vec![AggSpec::sum("v", "s")], None)
            .build()
            .unwrap();
        let plan = all(&q, Device::Cpu);
        let fplan = fuse::fuse(&q, &plan);
        assert_eq!(fplan.fused_ops(), 3);
        let staged = execute(&q, &plan, chunked_input(), None, &env(&model)).unwrap();
        let fused = execute_with_opts(
            &q,
            &plan,
            chunked_input(),
            None,
            &env(&model),
            &mut NoContention,
            &ExecOpts { fused: Some(&fplan), aux: None, chunk_stats: None },
        )
        .unwrap();
        assert_eq!(fused.result, staged.result);
        assert_eq!(fused.proc, staged.proc);
    }

    /// Min/max chunk pruning under an aggregate tail: the dead chunk is
    /// skipped (and counted) without perturbing the result.
    #[test]
    fn fused_aggregate_prunes_dead_chunks_and_reports_them() {
        let model = DeviceModel::default();
        let q = QueryBuilder::scan("p")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .filter("v", Predicate::Ge(50.0))
            .aggregate(&["k"], vec![AggSpec::sum("v", "s")], None)
            .build()
            .unwrap();
        let plan = all(&q, Device::Cpu);
        let fplan = fuse::fuse(&q, &plan);
        let staged = execute(&q, &plan, chunked_input(), None, &env(&model)).unwrap();
        let fused = execute_with_opts(
            &q,
            &plan,
            chunked_input(),
            None,
            &env(&model),
            &mut NoContention,
            &ExecOpts { fused: Some(&fplan), aux: None, chunk_stats: None },
        )
        .unwrap();
        assert_eq!(fused.result, staged.result);
        // Chunk 0 holds v ∈ [0, 40): provably dead under `v ≥ 50`.
        assert_eq!(fused.pruned_chunks, 1);
        assert_eq!(staged.pruned_chunks, 0);
    }

    /// The encoded-aux override reaches both the Eq. 9 transfer term and
    /// the windowed op's work volume: smaller priced window state means
    /// strictly cheaper transfer and proc, with identical data.
    #[test]
    fn aux_override_prices_encoded_window_bytes() {
        let model = DeviceModel::default();
        let q = QueryBuilder::scan("j")
            .window(WindowSpec::sliding(D::from_secs(30), D::from_secs(5)))
            .join_window("k", "k")
            .build()
            .unwrap();
        // CPU scan feeding a GPU join: the join *enters* the device, so
        // its entering boundary stages batch + window-state bytes.
        let plan = PhysicalPlan::from_devices(
            &q,
            &DevicePlan { per_op: vec![Device::Cpu, Device::Gpu] },
        )
        .unwrap();
        let mut w = ChunkedBatch::from_batch(batch(100));
        w.push(batch(100)).unwrap();
        let plain = execute(&q, &plan, batch(100), Some(&w), &env(&model)).unwrap();
        let encoded = execute_with_opts(
            &q,
            &plan,
            batch(100),
            Some(&w),
            &env(&model),
            &mut NoContention,
            &ExecOpts {
                fused: None,
                aux: Some((w.alloc_bytes() as f64 / 2.0, w.num_chunks())),
                chunk_stats: None,
            },
        )
        .unwrap();
        assert_eq!(encoded.result, plain.result);
        assert!(encoded.transfer < plain.transfer);
        assert!(encoded.proc < plain.proc);
    }
}
