//! Fluent query builder — the "Spark SQL" authoring surface of the
//! substrate. Workloads (Table III) are defined through this API; see
//! [`crate::workloads`].
//!
//! The builder grows a true DAG: every fluent call appends an op reading
//! the current *tip*; [`QueryBuilder::branch`] forks a side branch at the
//! tip (its last op becomes an additional sink), and
//! [`QueryBuilder::merge_union`] forks a branch and merges it back into
//! the main chain through an [`OpSpec::Union`].

use crate::engine::ops::aggregate::AggSpec;
use crate::engine::ops::filter::Predicate;
use crate::engine::window::WindowSpec;
use crate::error::Result;
use crate::query::dag::{OpNode, OpSpec, Query};
use std::time::Duration;

/// Builder accumulating an operation DAG.
pub struct QueryBuilder {
    name: String,
    ops: Vec<OpNode>,
    /// Node the next fluent call will read from.
    tip: usize,
    window: WindowSpec,
    uses_window_state: bool,
}

impl QueryBuilder {
    /// Start a query; every query begins with a source scan.
    pub fn scan(name: &str) -> QueryBuilder {
        QueryBuilder {
            name: name.to_string(),
            ops: vec![OpNode { id: 0, spec: OpSpec::Scan, inputs: vec![] }],
            tip: 0,
            window: WindowSpec::tumbling(Duration::from_secs(60)),
            uses_window_state: false,
        }
    }

    /// Append `spec` reading the current tip; the new op becomes the tip.
    fn push(&mut self, spec: OpSpec) {
        let id = self.ops.len();
        self.ops.push(OpNode { id, spec, inputs: vec![self.tip] });
        self.tip = id;
    }

    /// Set the window (`[range R slide S]` of Table III).
    pub fn window(mut self, spec: WindowSpec) -> Self {
        self.window = spec;
        self
    }

    /// WHERE `col` satisfies `pred`.
    pub fn filter(mut self, col: &str, pred: Predicate) -> Self {
        self.push(OpSpec::Filter { col: col.to_string(), pred });
        self
    }

    /// SELECT a column subset.
    pub fn select(mut self, keep: &[&str]) -> Self {
        self.push(OpSpec::ProjectSelect {
            keep: keep.iter().map(|s| s.to_string()).collect(),
        });
        self
    }

    /// Computed column `out = alpha*a + beta*b`.
    pub fn project_affine(mut self, a: &str, b: &str, alpha: f32, beta: f32, out: &str) -> Self {
        self.push(OpSpec::ProjectAffine {
            a: a.to_string(),
            b: b.to_string(),
            alpha,
            beta,
            out: out.to_string(),
        });
        self
    }

    /// Sliding-window instance replication (Spark's Expand rewrite).
    pub fn expand(mut self) -> Self {
        self.push(OpSpec::Expand);
        self
    }

    /// Exchange by key before a partition-crossing operator.
    pub fn shuffle(mut self, key: &str) -> Self {
        self.push(OpSpec::Shuffle { key: key.to_string() });
        self
    }

    /// GROUP BY + aggregates (+ optional HAVING).
    pub fn aggregate(
        mut self,
        group: &[&str],
        aggs: Vec<AggSpec>,
        having: Option<(&str, Predicate)>,
    ) -> Self {
        self.push(OpSpec::Aggregate {
            group: group.iter().map(|s| s.to_string()).collect(),
            aggs,
            having: having.map(|(c, p)| (c.to_string(), p)),
        });
        self
    }

    /// Join the stream against its own window state (LR1's self-join).
    pub fn join_window(mut self, probe_key: &str, build_key: &str) -> Self {
        self.push(OpSpec::JoinWithWindow {
            probe_key: probe_key.to_string(),
            build_key: build_key.to_string(),
        });
        self.uses_window_state = true;
        self
    }

    /// Windowed aggregation scope: aggregate over window state, not just
    /// the current micro-batch (marks the query as window-reading).
    pub fn over_window_state(mut self) -> Self {
        self.uses_window_state = true;
        self
    }

    /// ORDER BY.
    pub fn sort(mut self, col: &str, desc: bool) -> Self {
        self.push(OpSpec::Sort { col: col.to_string(), desc });
        self
    }

    /// Fork a side branch at the current tip. `f` continues building
    /// from the fork point; the branch's final op becomes an additional
    /// sink of the query, and the main chain resumes from the fork
    /// point. One scan can thus feed several independent pipelines:
    ///
    /// ```text
    /// scan ──┬── filter ── aggregate   (branch sink)
    ///        └── join_window ── sort   (main sink)
    /// ```
    pub fn branch(mut self, f: impl FnOnce(QueryBuilder) -> QueryBuilder) -> Self {
        let fork = self.tip;
        self = f(self);
        self.tip = fork;
        self
    }

    /// Fork a side branch at the current tip and union its output back
    /// into the main chain: after `merge_union`, the tip is a
    /// [`OpSpec::Union`] reading both the fork point and the branch's
    /// final op (a diamond). The branch must append at least one op and
    /// preserve the fork point's schema, or `build()`/execution will
    /// reject the plan.
    pub fn merge_union(mut self, f: impl FnOnce(QueryBuilder) -> QueryBuilder) -> Self {
        let fork = self.tip;
        self = f(self);
        let branch_tip = self.tip;
        let id = self.ops.len();
        self.ops.push(OpNode {
            id,
            spec: OpSpec::Union,
            inputs: vec![fork, branch_tip],
        });
        self.tip = id;
        self
    }

    /// Finalize and validate.
    pub fn build(self) -> Result<Query> {
        let q = Query {
            name: self.name,
            ops: self.ops,
            window: self.window,
            uses_window_state: self.uses_window_state,
        };
        q.validate()?;
        Ok(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::dag::OpKind;

    #[test]
    fn builds_lr2s_like_chain() {
        let q = QueryBuilder::scan("lr2s")
            .window(WindowSpec::sliding(
                Duration::from_secs(30),
                Duration::from_secs(10),
            ))
            .expand()
            .shuffle("segment")
            .aggregate(
                &["highway", "direction", "segment"],
                vec![AggSpec::avg("speed", "avgSpeed")],
                Some(("avgSpeed", Predicate::Lt(40.0))),
            )
            .build()
            .unwrap();
        let kinds: Vec<OpKind> = q.traverse().map(|o| o.spec.kind()).collect();
        assert_eq!(
            kinds,
            vec![OpKind::Scan, OpKind::Expand, OpKind::Shuffle, OpKind::Aggregate]
        );
        assert!(!q.uses_window_state);
        // Chain wiring: every op reads its predecessor.
        for (i, op) in q.ops.iter().enumerate().skip(1) {
            assert_eq!(op.inputs, vec![i - 1]);
        }
    }

    #[test]
    fn join_window_marks_state_usage() {
        let q = QueryBuilder::scan("lr1")
            .join_window("vehicle", "vehicle")
            .build()
            .unwrap();
        assert!(q.uses_window_state);
    }

    #[test]
    fn window_defaults_to_tumbling() {
        let q = QueryBuilder::scan("t").build().unwrap();
        assert_eq!(q.window.slide_time(), Duration::ZERO);
    }

    #[test]
    fn branch_fans_out_to_two_sinks() {
        let q = QueryBuilder::scan("b")
            .filter("speed", Predicate::Lt(60.0))
            .branch(|b| b.aggregate(&["segment"], vec![AggSpec::count("n")], None))
            .sort("speed", false)
            .build()
            .unwrap();
        // scan(0) -> filter(1) -> {aggregate(2), sort(3)}
        assert_eq!(q.ops[2].inputs, vec![1]);
        assert_eq!(q.ops[3].inputs, vec![1]);
        assert_eq!(q.sinks(), vec![2, 3]);
    }

    #[test]
    fn merge_union_builds_a_diamond() {
        let q = QueryBuilder::scan("d")
            .merge_union(|b| b.filter("speed", Predicate::Lt(20.0)))
            .sort("speed", false)
            .build()
            .unwrap();
        // scan(0) -> {direct, filter(1)} -> union(2) -> sort(3)
        assert_eq!(q.ops[2].spec.kind(), OpKind::Union);
        assert_eq!(q.ops[2].inputs, vec![0, 1]);
        assert_eq!(q.sinks(), vec![3]);
    }

    #[test]
    fn empty_merge_union_branch_rejected() {
        // A branch that appends nothing would union the fork with itself.
        let r = QueryBuilder::scan("d").merge_union(|b| b).build();
        assert!(r.is_err());
    }

    #[test]
    fn branched_query_traverses_inputs_first() {
        let q = QueryBuilder::scan("t")
            .branch(|b| b.expand())
            .branch(|b| b.filter("v", Predicate::Ge(0.0)))
            .select(&["v"])
            .build()
            .unwrap();
        let mut seen = std::collections::HashSet::new();
        for op in q.traverse() {
            assert!(op.inputs.iter().all(|i| seen.contains(i)), "op {} early", op.id);
            seen.insert(op.id);
        }
        assert_eq!(seen.len(), q.len());
    }
}
