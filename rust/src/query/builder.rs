//! Fluent query builder — the "Spark SQL" authoring surface of the
//! substrate. Workloads (Table III) are defined through this API; see
//! [`crate::workloads`].

use crate::engine::ops::aggregate::AggSpec;
use crate::engine::ops::filter::Predicate;
use crate::engine::window::WindowSpec;
use crate::error::Result;
use crate::query::dag::{OpNode, OpSpec, Query};
use std::time::Duration;

/// Builder accumulating an operator chain.
pub struct QueryBuilder {
    name: String,
    ops: Vec<OpSpec>,
    window: WindowSpec,
    uses_window_state: bool,
}

impl QueryBuilder {
    /// Start a query; every query begins with a source scan.
    pub fn scan(name: &str) -> QueryBuilder {
        QueryBuilder {
            name: name.to_string(),
            ops: vec![OpSpec::Scan],
            window: WindowSpec::tumbling(Duration::from_secs(60)),
            uses_window_state: false,
        }
    }

    /// Set the window (`[range R slide S]` of Table III).
    pub fn window(mut self, spec: WindowSpec) -> Self {
        self.window = spec;
        self
    }

    /// WHERE `col` satisfies `pred`.
    pub fn filter(mut self, col: &str, pred: Predicate) -> Self {
        self.ops.push(OpSpec::Filter { col: col.to_string(), pred });
        self
    }

    /// SELECT a column subset.
    pub fn select(mut self, keep: &[&str]) -> Self {
        self.ops.push(OpSpec::ProjectSelect {
            keep: keep.iter().map(|s| s.to_string()).collect(),
        });
        self
    }

    /// Computed column `out = alpha*a + beta*b`.
    pub fn project_affine(mut self, a: &str, b: &str, alpha: f32, beta: f32, out: &str) -> Self {
        self.ops.push(OpSpec::ProjectAffine {
            a: a.to_string(),
            b: b.to_string(),
            alpha,
            beta,
            out: out.to_string(),
        });
        self
    }

    /// Sliding-window instance replication (Spark's Expand rewrite).
    pub fn expand(mut self) -> Self {
        self.ops.push(OpSpec::Expand);
        self
    }

    /// Exchange by key before a partition-crossing operator.
    pub fn shuffle(mut self, key: &str) -> Self {
        self.ops.push(OpSpec::Shuffle { key: key.to_string() });
        self
    }

    /// GROUP BY + aggregates (+ optional HAVING).
    pub fn aggregate(
        mut self,
        group: &[&str],
        aggs: Vec<AggSpec>,
        having: Option<(&str, Predicate)>,
    ) -> Self {
        self.ops.push(OpSpec::Aggregate {
            group: group.iter().map(|s| s.to_string()).collect(),
            aggs,
            having: having.map(|(c, p)| (c.to_string(), p)),
        });
        self
    }

    /// Join the stream against its own window state (LR1's self-join).
    pub fn join_window(mut self, probe_key: &str, build_key: &str) -> Self {
        self.ops.push(OpSpec::JoinWithWindow {
            probe_key: probe_key.to_string(),
            build_key: build_key.to_string(),
        });
        self.uses_window_state = true;
        self
    }

    /// Windowed aggregation scope: aggregate over window state, not just
    /// the current micro-batch (marks the query as window-reading).
    pub fn over_window_state(mut self) -> Self {
        self.uses_window_state = true;
        self
    }

    /// ORDER BY.
    pub fn sort(mut self, col: &str, desc: bool) -> Self {
        self.ops.push(OpSpec::Sort { col: col.to_string(), desc });
        self
    }

    /// Finalize and validate.
    pub fn build(self) -> Result<Query> {
        let q = Query {
            name: self.name,
            ops: self
                .ops
                .into_iter()
                .enumerate()
                .map(|(id, spec)| OpNode { id, spec })
                .collect(),
            window: self.window,
            uses_window_state: self.uses_window_state,
        };
        q.validate()?;
        Ok(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::dag::OpKind;

    #[test]
    fn builds_lr2s_like_chain() {
        let q = QueryBuilder::scan("lr2s")
            .window(WindowSpec::sliding(
                Duration::from_secs(30),
                Duration::from_secs(10),
            ))
            .expand()
            .shuffle("segment")
            .aggregate(
                &["highway", "direction", "segment"],
                vec![AggSpec::avg("speed", "avgSpeed")],
                Some(("avgSpeed", Predicate::Lt(40.0))),
            )
            .build()
            .unwrap();
        let kinds: Vec<OpKind> = q.traverse().map(|o| o.spec.kind()).collect();
        assert_eq!(
            kinds,
            vec![OpKind::Scan, OpKind::Expand, OpKind::Shuffle, OpKind::Aggregate]
        );
        assert!(!q.uses_window_state);
    }

    #[test]
    fn join_window_marks_state_usage() {
        let q = QueryBuilder::scan("lr1")
            .join_window("vehicle", "vehicle")
            .build()
            .unwrap();
        assert!(q.uses_window_state);
    }

    #[test]
    fn window_defaults_to_tumbling() {
        let q = QueryBuilder::scan("t").build().unwrap();
        assert_eq!(q.window.slide_time(), Duration::ZERO);
    }
}
