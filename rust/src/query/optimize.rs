//! Logical plan rewrites (the Catalyst-analog optimizer pass).
//!
//! Currently one rule, found during the §Perf pass to dominate join cost:
//! **projection pushdown into windowed joins**. A join materializes
//! |output rows| x |probe cols + build cols| gathers; when the next
//! operation is a column selection, only the surviving columns need to be
//! materialized (LR1 keeps just the probe side — half the gather work).

use crate::query::dag::{OpSpec, Query};

/// Apply all rewrite rules, returning the optimized query.
pub fn optimize(query: &Query) -> Query {
    pushdown_projection(query)
}

/// Rewrite `JoinWithWindow -> ProjectSelect(keep)` so the join only
/// materializes the kept columns (plus nothing else); the subsequent
/// selection becomes a metadata-only reorder.
///
/// DAG-aware: the rule fires only when the join's *sole* consumer is a
/// projection — a join fanning out to several branches must still
/// materialize every column the branches might read.
pub fn pushdown_projection(query: &Query) -> Query {
    let mut out = query.clone();
    let consumers = out.consumers();
    for i in 0..out.ops.len() {
        let keep = match consumers[i].as_slice() {
            [only] => match &out.ops[*only].spec {
                OpSpec::ProjectSelect { keep } => keep.clone(),
                _ => continue,
            },
            _ => continue,
        };
        if let OpSpec::JoinWithWindow { probe_key, build_key } = &out.ops[i].spec {
            // Split kept names into probe-side and build-side ("r_"-
            // prefixed) column lists, order-preserving.
            let mut probe_cols = Vec::new();
            let mut build_cols = Vec::new();
            for name in &keep {
                match name.strip_prefix("r_") {
                    Some(b) => build_cols.push(b.to_string()),
                    None => probe_cols.push(name.clone()),
                }
            }
            out.ops[i].spec = OpSpec::JoinWithWindowPruned {
                probe_key: probe_key.clone(),
                build_key: build_key.clone(),
                probe_cols,
                build_cols,
            };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::window::WindowSpec;
    use crate::query::builder::QueryBuilder;
    use std::time::Duration;

    fn join_select_query(keep: &[&str]) -> Query {
        QueryBuilder::scan("t")
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .join_window("k", "k")
            .select(keep)
            .build()
            .unwrap()
    }

    #[test]
    fn join_followed_by_select_is_pruned() {
        let q = join_select_query(&["a", "b", "r_c"]);
        let o = optimize(&q);
        match &o.ops[1].spec {
            OpSpec::JoinWithWindowPruned { probe_cols, build_cols, .. } => {
                assert_eq!(probe_cols, &["a", "b"]);
                assert_eq!(build_cols, &["c"]);
            }
            other => panic!("not pruned: {other:?}"),
        }
        // The select stays (cheap reorder) and the plan length is stable.
        assert_eq!(o.ops.len(), q.ops.len());
    }

    #[test]
    fn probe_only_selection_drops_all_build_columns() {
        let q = join_select_query(&["a"]);
        let o = optimize(&q);
        match &o.ops[1].spec {
            OpSpec::JoinWithWindowPruned { build_cols, .. } => {
                assert!(build_cols.is_empty());
            }
            other => panic!("not pruned: {other:?}"),
        }
    }

    #[test]
    fn join_without_following_select_untouched() {
        let q = QueryBuilder::scan("t")
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .join_window("k", "k")
            .build()
            .unwrap();
        let o = optimize(&q);
        assert!(matches!(o.ops[1].spec, OpSpec::JoinWithWindow { .. }));
    }

    #[test]
    fn join_feeding_two_branches_not_pruned() {
        // A branch may read columns the projection drops: no pushdown.
        let q = QueryBuilder::scan("t")
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .join_window("k", "k")
            .branch(|b| b.sort("k", false))
            .select(&["a"])
            .build()
            .unwrap();
        let o = optimize(&q);
        assert!(matches!(o.ops[1].spec, OpSpec::JoinWithWindow { .. }));
    }

    #[test]
    fn non_join_plans_pass_through() {
        use crate::engine::ops::filter::Predicate;
        let q = QueryBuilder::scan("t")
            .filter("x", Predicate::Ge(0.0))
            .select(&["x"])
            .build()
            .unwrap();
        let o = optimize(&q);
        assert!(matches!(o.ops[1].spec, OpSpec::Filter { .. }));
    }
}
