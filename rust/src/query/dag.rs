//! Operation DAG: the logical plan MapDevice traverses (Alg. 2).
//!
//! The Table III workloads compile to operator chains with a window
//! side-input (the self-join's build side / the aggregation scope), so
//! the DAG is stored in topological order; `traverse(queryPlan)` of
//! Alg. 2 is iteration over that order.

use crate::engine::ops::aggregate::AggSpec;
use crate::engine::ops::filter::Predicate;
use crate::engine::window::WindowSpec;
use crate::error::{Error, Result};

/// Operation categories of Table II (base costs / initial preferences).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Scan,
    Filter,
    Project,
    Expand,
    Shuffle,
    Aggregate,
    Join,
    Sort,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Scan => "Scan",
            OpKind::Filter => "Filter",
            OpKind::Project => "Project",
            OpKind::Expand => "Expand",
            OpKind::Shuffle => "Shuffle",
            OpKind::Aggregate => "Aggregate",
            OpKind::Join => "Join",
            OpKind::Sort => "Sort",
        }
    }
}

/// Concrete operation configuration (what the executor needs).
#[derive(Clone, Debug)]
pub enum OpSpec {
    /// Source scan (CSV parse in the paper; schema check here).
    Scan,
    /// Predicate filter on a column.
    Filter { col: String, pred: Predicate },
    /// Column selection.
    ProjectSelect { keep: Vec<String> },
    /// Arithmetic projection `out = alpha*a + beta*b`.
    ProjectAffine { a: String, b: String, alpha: f32, beta: f32, out: String },
    /// Sliding-window instance replication (factor = range/slide).
    Expand,
    /// Hash repartition by key.
    Shuffle { key: String },
    /// GROUP BY + aggregates + optional HAVING.
    Aggregate {
        group: Vec<String>,
        aggs: Vec<AggSpec>,
        having: Option<(String, Predicate)>,
    },
    /// Equi-join of the micro-batch against the window state snapshot.
    JoinWithWindow { probe_key: String, build_key: String },
    /// Join with projection pushed down (optimizer-generated, see
    /// [`crate::query::optimize`]): only the listed probe/build columns
    /// are materialized.
    JoinWithWindowPruned {
        probe_key: String,
        build_key: String,
        probe_cols: Vec<String>,
        build_cols: Vec<String>,
    },
    /// Order by column.
    Sort { col: String, desc: bool },
}

impl OpSpec {
    pub fn kind(&self) -> OpKind {
        match self {
            OpSpec::Scan => OpKind::Scan,
            OpSpec::Filter { .. } => OpKind::Filter,
            OpSpec::ProjectSelect { .. } | OpSpec::ProjectAffine { .. } => OpKind::Project,
            OpSpec::Expand => OpKind::Expand,
            OpSpec::Shuffle { .. } => OpKind::Shuffle,
            OpSpec::Aggregate { .. } => OpKind::Aggregate,
            OpSpec::JoinWithWindow { .. } | OpSpec::JoinWithWindowPruned { .. } => {
                OpKind::Join
            }
            OpSpec::Sort { .. } => OpKind::Sort,
        }
    }
}

/// One node of the operation DAG.
#[derive(Clone, Debug)]
pub struct OpNode {
    pub id: usize,
    pub spec: OpSpec,
}

/// A compiled streaming query: operator chain + window semantics.
#[derive(Clone, Debug)]
pub struct Query {
    pub name: String,
    pub ops: Vec<OpNode>,
    pub window: WindowSpec,
    /// Whether an operator reads the window state (join build side /
    /// windowed aggregation scope) — sizes windowed-op cost.
    pub uses_window_state: bool,
}

impl Query {
    /// Validate structural invariants (non-empty, scan-first, ids
    /// contiguous, at most one windowed join).
    pub fn validate(&self) -> Result<()> {
        if self.ops.is_empty() {
            return Err(Error::Plan("empty query".into()));
        }
        if !matches!(self.ops[0].spec, OpSpec::Scan) {
            return Err(Error::Plan("first operation must be Scan".into()));
        }
        for (i, op) in self.ops.iter().enumerate() {
            if op.id != i {
                return Err(Error::Plan(format!("non-contiguous op id {}", op.id)));
            }
            if i > 0 && matches!(op.spec, OpSpec::Scan) {
                return Err(Error::Plan("Scan only allowed at position 0".into()));
            }
        }
        let joins = self
            .ops
            .iter()
            .filter(|o| o.spec.kind() == OpKind::Join)
            .count();
        if joins > 1 {
            return Err(Error::Plan("at most one windowed join supported".into()));
        }
        Ok(())
    }

    /// Topological traversal order (Alg. 2's `traverse`).
    pub fn traverse(&self) -> impl Iterator<Item = &OpNode> {
        self.ops.iter()
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn q(ops: Vec<OpSpec>) -> Query {
        Query {
            name: "t".into(),
            ops: ops
                .into_iter()
                .enumerate()
                .map(|(id, spec)| OpNode { id, spec })
                .collect(),
            window: WindowSpec::tumbling(Duration::from_secs(30)),
            uses_window_state: false,
        }
    }

    #[test]
    fn valid_chain_passes() {
        let query = q(vec![
            OpSpec::Scan,
            OpSpec::Filter { col: "v".into(), pred: Predicate::Ge(1.0) },
        ]);
        query.validate().unwrap();
        assert_eq!(query.len(), 2);
    }

    #[test]
    fn empty_query_rejected() {
        assert!(q(vec![]).validate().is_err());
    }

    #[test]
    fn scan_must_lead() {
        let query = q(vec![OpSpec::Expand, OpSpec::Scan]);
        assert!(query.validate().is_err());
    }

    #[test]
    fn double_join_rejected() {
        let join = OpSpec::JoinWithWindow { probe_key: "k".into(), build_key: "k".into() };
        let query = q(vec![OpSpec::Scan, join.clone(), join]);
        assert!(query.validate().is_err());
    }

    #[test]
    fn op_kinds_classified() {
        assert_eq!(OpSpec::Scan.kind(), OpKind::Scan);
        assert_eq!(
            OpSpec::ProjectAffine {
                a: "a".into(),
                b: "b".into(),
                alpha: 1.0,
                beta: 1.0,
                out: "o".into()
            }
            .kind(),
            OpKind::Project
        );
        assert_eq!(OpSpec::Expand.kind(), OpKind::Expand);
    }
}
