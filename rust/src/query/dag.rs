//! Operation DAG: the logical plan MapDevice traverses (Alg. 2).
//!
//! A query is a true directed acyclic graph of operations: every
//! [`OpNode`] names its producer nodes in `inputs`, so one scan can fan
//! out into several branches (e.g. an aggregation branch and a
//! window-join branch) and branches can merge again through a
//! [`OpSpec::Union`] or terminate in their own sinks. Nodes are stored
//! with `inputs[k] < id` (producers before consumers), which makes the
//! stored order a topological order; `traverse()` — Alg. 2's
//! `traverse(queryPlan)` — recomputes that order with Kahn's algorithm
//! from the edges rather than trusting the storage order.

use crate::engine::ops::aggregate::AggSpec;
use crate::engine::ops::filter::Predicate;
use crate::engine::window::WindowSpec;
use crate::error::{Error, Result};

/// Operation categories of Table II (base costs / initial preferences).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Scan,
    Filter,
    Project,
    Expand,
    Shuffle,
    Aggregate,
    Join,
    Sort,
    /// Branch merge: concatenates the outputs of its input nodes.
    Union,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Scan => "Scan",
            OpKind::Filter => "Filter",
            OpKind::Project => "Project",
            OpKind::Expand => "Expand",
            OpKind::Shuffle => "Shuffle",
            OpKind::Aggregate => "Aggregate",
            OpKind::Join => "Join",
            OpKind::Sort => "Sort",
            OpKind::Union => "Union",
        }
    }
}

/// Concrete operation configuration (what the executor needs).
#[derive(Clone, Debug)]
pub enum OpSpec {
    /// Source scan (CSV parse in the paper; schema check here).
    Scan,
    /// Predicate filter on a column.
    Filter { col: String, pred: Predicate },
    /// Column selection.
    ProjectSelect { keep: Vec<String> },
    /// Arithmetic projection `out = alpha*a + beta*b`.
    ProjectAffine { a: String, b: String, alpha: f32, beta: f32, out: String },
    /// Sliding-window instance replication (factor = range/slide).
    Expand,
    /// Hash repartition by key.
    Shuffle { key: String },
    /// GROUP BY + aggregates + optional HAVING.
    Aggregate {
        group: Vec<String>,
        aggs: Vec<AggSpec>,
        having: Option<(String, Predicate)>,
    },
    /// Equi-join of the micro-batch against the window state snapshot.
    JoinWithWindow { probe_key: String, build_key: String },
    /// Join with projection pushed down (optimizer-generated, see
    /// [`crate::query::optimize`]): only the listed probe/build columns
    /// are materialized.
    JoinWithWindowPruned {
        probe_key: String,
        build_key: String,
        probe_cols: Vec<String>,
        build_cols: Vec<String>,
    },
    /// Order by column.
    Sort { col: String, desc: bool },
    /// Merge the rows of all input branches (schemas must agree). The
    /// executor concatenates the inputs while assembling this node's
    /// input batch, so the operator itself is a pass-through.
    Union,
}

impl OpSpec {
    pub fn kind(&self) -> OpKind {
        match self {
            OpSpec::Scan => OpKind::Scan,
            OpSpec::Filter { .. } => OpKind::Filter,
            OpSpec::ProjectSelect { .. } | OpSpec::ProjectAffine { .. } => OpKind::Project,
            OpSpec::Expand => OpKind::Expand,
            OpSpec::Shuffle { .. } => OpKind::Shuffle,
            OpSpec::Aggregate { .. } => OpKind::Aggregate,
            OpSpec::JoinWithWindow { .. } | OpSpec::JoinWithWindowPruned { .. } => {
                OpKind::Join
            }
            OpSpec::Sort { .. } => OpKind::Sort,
            OpSpec::Union => OpKind::Union,
        }
    }
}

/// One node of the operation DAG.
#[derive(Clone, Debug)]
pub struct OpNode {
    pub id: usize,
    pub spec: OpSpec,
    /// Producer node ids (empty only for the source scan). A linear
    /// chain is the special case `inputs == [id - 1]`.
    pub inputs: Vec<usize>,
}

impl OpNode {
    /// A chain node: reads the immediately preceding op (the scan, at
    /// id 0, reads the source).
    pub fn chained(id: usize, spec: OpSpec) -> OpNode {
        let inputs = if id == 0 { vec![] } else { vec![id - 1] };
        OpNode { id, spec, inputs }
    }
}

/// A compiled streaming query: operation DAG + window semantics.
#[derive(Clone, Debug)]
pub struct Query {
    pub name: String,
    pub ops: Vec<OpNode>,
    pub window: WindowSpec,
    /// Whether an operator reads the window state (join build side /
    /// windowed aggregation scope) — sizes windowed-op cost.
    pub uses_window_state: bool,
}

impl Query {
    /// Validate structural invariants: non-empty, the scan is the unique
    /// source (node 0, no inputs), ids contiguous, every edge points
    /// backward (`input < id` — which also rules out cycles), no
    /// duplicate edges, every non-scan node has at least one input (no
    /// disconnected islands), and at most one windowed join.
    pub fn validate(&self) -> Result<()> {
        if self.ops.is_empty() {
            return Err(Error::Plan("empty query".into()));
        }
        if !matches!(self.ops[0].spec, OpSpec::Scan) {
            return Err(Error::Plan("first operation must be Scan".into()));
        }
        for (i, op) in self.ops.iter().enumerate() {
            if op.id != i {
                return Err(Error::Plan(format!("non-contiguous op id {}", op.id)));
            }
            if i > 0 && matches!(op.spec, OpSpec::Scan) {
                return Err(Error::Plan("Scan only allowed at position 0".into()));
            }
            if i == 0 {
                if !op.inputs.is_empty() {
                    return Err(Error::Plan("Scan cannot have inputs".into()));
                }
            } else if op.inputs.is_empty() {
                return Err(Error::Plan(format!(
                    "op {i} ({}) is disconnected: no inputs",
                    op.spec.kind().name()
                )));
            }
            for (k, &inp) in op.inputs.iter().enumerate() {
                if inp >= i {
                    return Err(Error::Plan(format!(
                        "op {i} reads op {inp}: edges must point backward \
                         (forward edges would allow cycles)"
                    )));
                }
                if op.inputs[..k].contains(&inp) {
                    return Err(Error::Plan(format!("op {i} reads op {inp} twice")));
                }
            }
        }
        // The backward-edge rule above makes the graph acyclic by
        // construction; Kahn's algorithm double-checks (and guards any
        // future relaxation of the storage order).
        self.topo_order()?;
        let joins = self
            .ops
            .iter()
            .filter(|o| o.spec.kind() == OpKind::Join)
            .count();
        if joins > 1 {
            return Err(Error::Plan("at most one windowed join supported".into()));
        }
        Ok(())
    }

    /// Forward adjacency: `consumers()[i]` lists the nodes reading op
    /// `i`'s output (ascending). Nodes with no consumers are sinks.
    pub fn consumers(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.ops.len()];
        for op in &self.ops {
            for &inp in &op.inputs {
                if inp < out.len() {
                    out[inp].push(op.id);
                }
            }
        }
        out
    }

    /// Sink node ids (ops whose output leaves the query), ascending.
    pub fn sinks(&self) -> Vec<usize> {
        self.consumers()
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_empty())
            .map(|(i, _)| i)
            .collect()
    }

    /// Topological order via Kahn's algorithm, choosing the smallest
    /// ready id at every step (so a chain traverses in id order).
    /// Errors on a cycle — every node must be emitted.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let n = self.ops.len();
        let mut indegree = vec![0usize; n];
        for op in &self.ops {
            for &inp in &op.inputs {
                if inp < n {
                    indegree[op.id] += 1;
                } else {
                    return Err(Error::Plan(format!(
                        "op {} reads nonexistent op {inp}",
                        op.id
                    )));
                }
            }
        }
        let consumers = self.consumers();
        let mut ready: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(pos) = ready
            .iter()
            .enumerate()
            .min_by_key(|&(_, &id)| id)
            .map(|(p, _)| p)
        {
            let id = ready.swap_remove(pos);
            order.push(id);
            for &c in &consumers[id] {
                indegree[c] -= 1;
                if indegree[c] == 0 {
                    ready.push(c);
                }
            }
        }
        if order.len() != n {
            return Err(Error::Plan("operation graph contains a cycle".into()));
        }
        Ok(order)
    }

    /// Topological traversal (Alg. 2's `traverse`): every node is
    /// visited after all of its inputs. Falls back to storage order if
    /// the graph is invalid (callers validate first).
    pub fn traverse(&self) -> impl Iterator<Item = &OpNode> {
        let order = self
            .topo_order()
            .unwrap_or_else(|_| (0..self.ops.len()).collect());
        order.into_iter().map(move |i| &self.ops[i])
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn q(ops: Vec<OpSpec>) -> Query {
        Query {
            name: "t".into(),
            ops: ops
                .into_iter()
                .enumerate()
                .map(|(id, spec)| OpNode::chained(id, spec))
                .collect(),
            window: WindowSpec::tumbling(Duration::from_secs(30)),
            uses_window_state: false,
        }
    }

    #[test]
    fn valid_chain_passes() {
        let query = q(vec![
            OpSpec::Scan,
            OpSpec::Filter { col: "v".into(), pred: Predicate::Ge(1.0) },
        ]);
        query.validate().unwrap();
        assert_eq!(query.len(), 2);
    }

    #[test]
    fn empty_query_rejected() {
        assert!(q(vec![]).validate().is_err());
    }

    #[test]
    fn scan_must_lead() {
        let query = q(vec![OpSpec::Expand, OpSpec::Scan]);
        assert!(query.validate().is_err());
    }

    #[test]
    fn double_join_rejected() {
        let join = OpSpec::JoinWithWindow { probe_key: "k".into(), build_key: "k".into() };
        let query = q(vec![OpSpec::Scan, join.clone(), join]);
        assert!(query.validate().is_err());
    }

    #[test]
    fn op_kinds_classified() {
        assert_eq!(OpSpec::Scan.kind(), OpKind::Scan);
        assert_eq!(
            OpSpec::ProjectAffine {
                a: "a".into(),
                b: "b".into(),
                alpha: 1.0,
                beta: 1.0,
                out: "o".into()
            }
            .kind(),
            OpKind::Project
        );
        assert_eq!(OpSpec::Expand.kind(), OpKind::Expand);
        assert_eq!(OpSpec::Union.kind(), OpKind::Union);
    }

    fn diamond() -> Query {
        // scan -> {filter, expand} -> union
        Query {
            name: "d".into(),
            ops: vec![
                OpNode { id: 0, spec: OpSpec::Scan, inputs: vec![] },
                OpNode {
                    id: 1,
                    spec: OpSpec::Filter { col: "v".into(), pred: Predicate::Ge(1.0) },
                    inputs: vec![0],
                },
                OpNode { id: 2, spec: OpSpec::Expand, inputs: vec![0] },
                OpNode { id: 3, spec: OpSpec::Union, inputs: vec![1, 2] },
            ],
            window: WindowSpec::tumbling(Duration::from_secs(30)),
            uses_window_state: false,
        }
    }

    #[test]
    fn diamond_validates_and_traverses_in_topo_order() {
        let d = diamond();
        d.validate().unwrap();
        let order: Vec<usize> = d.traverse().map(|o| o.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
        assert_eq!(d.sinks(), vec![3]);
        assert_eq!(d.consumers()[0], vec![1, 2]);
    }

    #[test]
    fn fan_out_has_multiple_sinks() {
        let mut d = diamond();
        d.ops.pop(); // drop the union: filter and expand both terminate
        d.validate().unwrap();
        assert_eq!(d.sinks(), vec![1, 2]);
    }

    #[test]
    fn forward_edge_rejected() {
        let mut d = diamond();
        d.ops[1].inputs = vec![3]; // 1 reads 3 while 3 reads 1: a cycle
        assert!(d.validate().is_err());
    }

    #[test]
    fn disconnected_node_rejected() {
        let mut d = diamond();
        d.ops[2].inputs = vec![]; // expand floats free
        assert!(d.validate().is_err());
    }

    #[test]
    fn duplicate_edge_rejected() {
        let mut d = diamond();
        d.ops[3].inputs = vec![1, 1];
        assert!(d.validate().is_err());
    }

    #[test]
    fn scan_with_inputs_rejected() {
        let mut d = diamond();
        d.ops[0].inputs = vec![1];
        assert!(d.validate().is_err());
    }

    #[test]
    fn topo_order_detects_out_of_range_input() {
        let mut d = diamond();
        d.ops[3].inputs = vec![1, 99];
        assert!(d.topo_order().is_err());
    }
}
