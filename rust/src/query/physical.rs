//! Physical plans: the device-annotated form of a logical [`Query`].
//!
//! The query surface is layered (see `ARCHITECTURE.md`):
//!
//! 1. **Logical plan** — the [`Query`] DAG the builder produces and
//!    [`crate::query::optimize`] rewrites (device-agnostic),
//! 2. **Physical plan** — this module: one [`PhysicalOp`] per logical
//!    node carrying the device assignment and the planner's processed-
//!    size estimate (`MapDevice`'s Eq. 7/8 inputs),
//! 3. **Execution** — [`crate::query::exec`] walks the physical DAG.
//!
//! [`DevicePlan`] (a bare device vector) remains as the compact
//! interchange form baselines and figure scenarios are written in; a
//! `PhysicalPlan` subsumes it and is what the executor consumes.

use crate::devices::Device;
use crate::error::{Error, Result};
use crate::query::dag::{OpKind, Query};

/// Device assignment per DAG operation (index-aligned with `query.ops`).
/// The compact policy form: baselines and Fig. 2/5 scenarios are
/// expressed as bare device vectors and lifted into a [`PhysicalPlan`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DevicePlan {
    pub per_op: Vec<Device>,
}

impl DevicePlan {
    pub fn all(device: Device, n: usize) -> DevicePlan {
        DevicePlan { per_op: vec![device; n] }
    }

    pub fn gpu_ops(&self) -> usize {
        self.per_op.iter().filter(|d| **d == Device::Gpu).count()
    }
}

/// One physical operation: a logical node bound to a device, with the
/// planner's size estimate attached for inspection/replanning.
#[derive(Clone, Debug, PartialEq)]
pub struct PhysicalOp {
    /// Logical node id (index into `query.ops`).
    pub op_id: usize,
    pub kind: OpKind,
    pub device: Device,
    /// Planner-estimated processed bytes per partition (Eq. 7/8's
    /// `Part`-derived size); 0.0 when produced by a fixed policy.
    pub est_bytes: f64,
}

/// The physical plan `MapDevice` (or a baseline policy) produces:
/// index-aligned with the logical DAG it was planned for.
#[derive(Clone, Debug, PartialEq)]
pub struct PhysicalPlan {
    pub per_op: Vec<PhysicalOp>,
}

impl PhysicalPlan {
    /// Every op on one device (the all-GPU / all-CPU baselines).
    pub fn uniform(query: &Query, device: Device) -> PhysicalPlan {
        PhysicalPlan {
            per_op: query
                .ops
                .iter()
                .map(|op| PhysicalOp {
                    op_id: op.id,
                    kind: op.spec.kind(),
                    device,
                    est_bytes: 0.0,
                })
                .collect(),
        }
    }

    /// Lift a bare device vector onto `query`'s DAG.
    pub fn from_devices(query: &Query, devices: &DevicePlan) -> Result<PhysicalPlan> {
        if devices.per_op.len() != query.ops.len() {
            return Err(Error::Plan(format!(
                "device plan covers {} ops, query has {}",
                devices.per_op.len(),
                query.ops.len()
            )));
        }
        Ok(PhysicalPlan {
            per_op: query
                .ops
                .iter()
                .zip(&devices.per_op)
                .map(|(op, &device)| PhysicalOp {
                    op_id: op.id,
                    kind: op.spec.kind(),
                    device,
                    est_bytes: 0.0,
                })
                .collect(),
        })
    }

    /// The bare device vector (compat / display form).
    pub fn devices(&self) -> DevicePlan {
        DevicePlan { per_op: self.per_op.iter().map(|o| o.device).collect() }
    }

    pub fn device(&self, op_id: usize) -> Device {
        self.per_op[op_id].device
    }

    pub fn gpu_ops(&self) -> usize {
        self.per_op.iter().filter(|o| o.device == Device::Gpu).count()
    }

    pub fn len(&self) -> usize {
        self.per_op.len()
    }

    pub fn is_empty(&self) -> bool {
        self.per_op.is_empty()
    }

    /// The same plan with every op forced to CPU — what an executor with
    /// a faulted GPU device runs for its share. Operators are device-
    /// invariant, so the demoted share produces bit-identical rows; only
    /// the charged physics change (per-core CPU cost, no PCIe segments).
    pub fn demoted_to_cpu(&self) -> PhysicalPlan {
        PhysicalPlan {
            per_op: self
                .per_op
                .iter()
                .map(|o| PhysicalOp { device: Device::Cpu, ..o.clone() })
                .collect(),
        }
    }
}

/// Alg. 2's `Trans` placement rule (first op / last op / device switch),
/// generalized to the DAG and shared by the planner ([`map_device`]'s
/// cost charging) and the executor (PCIe time charging) so the two can
/// never diverge:
///
/// * a GPU-side op pays the **host→device** boundary when it is a source
///   (reads host data) or any of its producers is CPU-mapped,
/// * it pays the **device→host** boundary when it is a sink (its output
///   leaves to the output stream) or any of its consumers is CPU-mapped.
///
/// `is_cpu(id)` reports whether node `id` is CPU-mapped; the planner,
/// which maps in topological order over a line-3 all-GPU default, passes
/// a closure that answers for already-visited nodes and defaults
/// not-yet-mapped consumers to GPU — exactly Alg. 2's traversal.
///
/// [`map_device`]: crate::coordinator::planner::map_device
pub fn transfer_boundaries(
    inputs: &[usize],
    consumers: &[usize],
    is_cpu: impl Fn(usize) -> bool,
) -> (bool, bool) {
    let entering = inputs.is_empty() || inputs.iter().any(|&i| is_cpu(i));
    let leaving = consumers.is_empty() || consumers.iter().any(|&c| is_cpu(c));
    (entering, leaving)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ops::filter::Predicate;
    use crate::query::builder::QueryBuilder;

    fn chain() -> Query {
        QueryBuilder::scan("t")
            .filter("v", Predicate::Ge(0.0))
            .select(&["v"])
            .build()
            .unwrap()
    }

    #[test]
    fn uniform_covers_every_op() {
        let q = chain();
        let p = PhysicalPlan::uniform(&q, Device::Gpu);
        assert_eq!(p.len(), q.len());
        assert_eq!(p.gpu_ops(), q.len());
        assert_eq!(p.devices(), DevicePlan::all(Device::Gpu, q.len()));
    }

    #[test]
    fn demotion_keeps_shape_and_zeroes_gpu_ops() {
        let q = chain();
        let p = PhysicalPlan::uniform(&q, Device::Gpu);
        let d = p.demoted_to_cpu();
        assert_eq!(d.len(), p.len());
        assert_eq!(d.gpu_ops(), 0);
        assert_eq!(d.per_op[1].op_id, p.per_op[1].op_id);
        assert_eq!(d.per_op[1].est_bytes, p.per_op[1].est_bytes);
    }

    #[test]
    fn from_devices_checks_arity() {
        let q = chain();
        let ok = PhysicalPlan::from_devices(&q, &DevicePlan::all(Device::Cpu, q.len()));
        assert!(ok.is_ok());
        let bad = PhysicalPlan::from_devices(&q, &DevicePlan::all(Device::Cpu, 1));
        assert!(bad.is_err());
    }

    #[test]
    fn boundaries_match_linear_chain_rule() {
        // chain of 3, all GPU: op0 enters (source), op2 leaves (sink),
        // op1 pays nothing.
        let never = |_: usize| false;
        assert_eq!(transfer_boundaries(&[], &[1], never), (true, false));
        assert_eq!(transfer_boundaries(&[0], &[2], never), (false, false));
        assert_eq!(transfer_boundaries(&[1], &[], never), (false, true));
    }

    #[test]
    fn boundaries_fire_on_device_switch() {
        // CPU -> GPU -> CPU sandwich: the GPU op pays both directions.
        let cpu_neighbors = |_: usize| true;
        assert_eq!(transfer_boundaries(&[0], &[2], cpu_neighbors), (true, true));
    }

    #[test]
    fn branch_boundary_fires_when_any_consumer_is_cpu() {
        // GPU op fanning out to one GPU consumer and one CPU consumer
        // still pays the device->host hop once.
        let is_cpu = |id: usize| id == 2;
        assert_eq!(transfer_boundaries(&[0], &[1, 2], is_cpu), (false, true));
    }
}
