//! Operator fusion pass: collapse same-device scan→filter→project→
//! (aggregate) chains of a [`PhysicalPlan`] into [`FusedGroup`]s that
//! execute as one typed loop per chunk
//! ([`crate::engine::ops::fused`]).
//!
//! The pass is a *sidecar*: the `PhysicalPlan` itself is untouched (its
//! arity, per-op devices and `PartialEq` stay exactly as planned), and
//! the executor consults the [`FusedPlan`] to know which member runs are
//! replaced by a single fused traversal. The scheduler does the same to
//! cost a fused chain as ONE op with the chain's combined row/byte/chunk
//! flow.
//!
//! # Eligibility
//!
//! A maximal run of ops fuses when every member:
//!
//! * is a fusable kind — `Scan`, `Filter`, `ProjectSelect`,
//!   `ProjectAffine` — plus at most one terminal `Aggregate`,
//! * sits on the **same device** as the head (a device switch is a
//!   transfer boundary; fusing across it would hide a PCIe hop the
//!   planner priced),
//! * is **strictly linear** past the head: each non-head member reads
//!   exactly its predecessor (`inputs == [prev]`), and each non-tail
//!   member feeds exactly its successor (`consumers == [next]`). A
//!   branch point ends the run — fusing through it would force the
//!   shared intermediate to materialize anyway,
//!
//! and the run has ≥ 2 members (fusing a single op buys nothing).
//! An `Aggregate` can only ever be the tail: it collapses rows, so
//! nothing downstream of it belongs to the same traversal.

use crate::devices::Device;
use crate::engine::ops::fused::{FusedAgg, FusedChainSpec, FusedStep};
use crate::query::dag::{OpSpec, Query};
use crate::query::physical::PhysicalPlan;

/// One fused chain: member op ids in chain order, the shared device,
/// and the engine-level spec the fused kernel executes.
#[derive(Clone, Debug)]
pub struct FusedGroup {
    /// Member logical op ids, ascending; each reads the previous.
    pub ops: Vec<usize>,
    pub device: Device,
    pub spec: FusedChainSpec,
}

impl FusedGroup {
    /// First member — the fused chain consumes this op's input batch.
    pub fn head(&self) -> usize {
        self.ops[0]
    }

    /// Last member — the fused result lands in this op's output slot.
    pub fn tail(&self) -> usize {
        *self.ops.last().expect("group is non-empty")
    }
}

/// The fusion sidecar for one (query, physical plan) pair.
#[derive(Clone, Debug, Default)]
pub struct FusedPlan {
    pub groups: Vec<FusedGroup>,
    /// Index-aligned with `query.ops`: which group (index into
    /// `groups`) each op belongs to, if any.
    member_of: Vec<Option<usize>>,
}

impl FusedPlan {
    /// The no-fusion sidecar (staged execution for every op).
    pub fn none(n_ops: usize) -> FusedPlan {
        FusedPlan { groups: Vec::new(), member_of: vec![None; n_ops] }
    }

    /// The group containing `op_id`, if it was fused.
    pub fn group_of(&self, op_id: usize) -> Option<&FusedGroup> {
        self.member_of
            .get(op_id)
            .copied()
            .flatten()
            .map(|g| &self.groups[g])
    }

    /// Is `op_id` a fused member that is *not* its group's head? The
    /// executor skips these entirely (the head's traversal already
    /// produced the tail's output).
    pub fn is_follower(&self, op_id: usize) -> bool {
        self.group_of(op_id).is_some_and(|g| g.head() != op_id)
    }

    pub fn fused_ops(&self) -> usize {
        self.member_of.iter().filter(|m| m.is_some()).count()
    }
}

fn fusable_member(spec: &OpSpec) -> bool {
    matches!(
        spec,
        OpSpec::Scan
            | OpSpec::Filter { .. }
            | OpSpec::ProjectSelect { .. }
            | OpSpec::ProjectAffine { .. }
    )
}

fn step_of(spec: &OpSpec) -> FusedStep {
    match spec {
        OpSpec::Scan => FusedStep::Scan,
        OpSpec::Filter { col, pred } => {
            FusedStep::Filter { col: col.clone(), pred: *pred }
        }
        OpSpec::ProjectSelect { keep } => FusedStep::Select { keep: keep.clone() },
        OpSpec::ProjectAffine { a, b, alpha, beta, out } => FusedStep::Affine {
            a: a.clone(),
            b: b.clone(),
            alpha: *alpha,
            beta: *beta,
            out: out.clone(),
        },
        other => unreachable!("non-fusable member {:?}", other.kind()),
    }
}

/// Run the fusion pass. Greedy maximal runs in id order: because every
/// edge points backward (`input < id`), scanning heads in ascending id
/// order and extending forward always discovers a chain from its
/// earliest fusable member, so runs are maximal and each op lands in at
/// most one group.
pub fn fuse(query: &Query, plan: &PhysicalPlan) -> FusedPlan {
    let n = query.len();
    let consumers = query.consumers();
    let mut member_of: Vec<Option<usize>> = vec![None; n];
    let mut groups: Vec<FusedGroup> = Vec::new();
    for head in 0..n {
        if member_of[head].is_some() || !fusable_member(&query.ops[head].spec) {
            continue;
        }
        let device = plan.device(head);
        let mut ops = vec![head];
        let mut agg: Option<FusedAgg> = None;
        let mut cur = head;
        loop {
            // The run continues only through a strictly linear,
            // same-device edge.
            let next = match consumers[cur].as_slice() {
                &[next] => next,
                _ => break,
            };
            let node = &query.ops[next];
            if node.inputs.as_slice() != [cur]
                || member_of[next].is_some()
                || plan.device(next) != device
            {
                break;
            }
            if fusable_member(&node.spec) {
                ops.push(next);
                cur = next;
                continue;
            }
            if let OpSpec::Aggregate { group, aggs, having } = &node.spec {
                ops.push(next);
                agg = Some(FusedAgg {
                    group: group.clone(),
                    aggs: aggs.clone(),
                    having: having.clone(),
                });
            }
            break;
        }
        if ops.len() < 2 {
            continue;
        }
        let g = groups.len();
        for &id in &ops {
            member_of[id] = Some(g);
        }
        let steps = ops
            .iter()
            .take(ops.len() - usize::from(agg.is_some()))
            .map(|&id| step_of(&query.ops[id].spec))
            .collect();
        groups.push(FusedGroup { ops, device, spec: FusedChainSpec { steps, agg } });
    }
    FusedPlan { groups, member_of }
}

/// Device-agnostic structural runs: the maximal fusable chains of the
/// *logical* DAG, ignoring device placement, as `op id → run id`. The
/// scheduler consults this while it explores device assignments — any
/// sub-run whose members currently share a device will execute as one
/// traversal, so it books ONE device reservation with the chain's
/// combined flow. [`fuse`] (device-aware, over the final plan) decides
/// what actually executes fused.
pub fn fusable_runs(query: &Query) -> Vec<Option<usize>> {
    let n = query.len();
    let consumers = query.consumers();
    let mut run_of: Vec<Option<usize>> = vec![None; n];
    let mut next_run = 0usize;
    for head in 0..n {
        if run_of[head].is_some() || !fusable_member(&query.ops[head].spec) {
            continue;
        }
        let mut ops = vec![head];
        let mut cur = head;
        loop {
            let next = match consumers[cur].as_slice() {
                &[next] => next,
                _ => break,
            };
            let node = &query.ops[next];
            if node.inputs.as_slice() != [cur] || run_of[next].is_some() {
                break;
            }
            if fusable_member(&node.spec) {
                ops.push(next);
                cur = next;
                continue;
            }
            if matches!(node.spec, OpSpec::Aggregate { .. }) {
                ops.push(next);
            }
            break;
        }
        if ops.len() < 2 {
            continue;
        }
        for &id in &ops {
            run_of[id] = Some(next_run);
        }
        next_run += 1;
    }
    run_of
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ops::aggregate::AggSpec;
    use crate::engine::ops::filter::Predicate;
    use crate::engine::window::WindowSpec;
    use crate::query::dag::OpNode;
    use crate::query::physical::DevicePlan;
    use std::time::Duration;

    fn filter() -> OpSpec {
        OpSpec::Filter { col: "v".into(), pred: Predicate::Ge(1.0) }
    }

    fn select() -> OpSpec {
        OpSpec::ProjectSelect { keep: vec!["v".into(), "k".into()] }
    }

    fn aggregate() -> OpSpec {
        OpSpec::Aggregate {
            group: vec!["k".into()],
            aggs: vec![AggSpec::count("c")],
            having: None,
        }
    }

    fn chain_query(specs: Vec<OpSpec>) -> Query {
        Query {
            name: "t".into(),
            ops: specs
                .into_iter()
                .enumerate()
                .map(|(id, spec)| OpNode::chained(id, spec))
                .collect(),
            window: WindowSpec::tumbling(Duration::from_secs(30)),
            uses_window_state: false,
        }
    }

    fn plan(q: &Query, devices: Vec<Device>) -> PhysicalPlan {
        PhysicalPlan::from_devices(q, &DevicePlan { per_op: devices }).unwrap()
    }

    #[test]
    fn full_chain_fuses_into_one_group_with_aggregate_tail() {
        let q = chain_query(vec![OpSpec::Scan, filter(), select(), aggregate()]);
        let p = plan(&q, vec![Device::Cpu; 4]);
        let f = fuse(&q, &p);
        assert_eq!(f.groups.len(), 1);
        assert_eq!(f.groups[0].ops, vec![0, 1, 2, 3]);
        assert!(f.groups[0].spec.agg.is_some());
        assert_eq!(f.groups[0].spec.steps.len(), 3, "aggregate is the tail, not a step");
        assert_eq!(f.fused_ops(), 4);
        assert!(!f.is_follower(0));
        assert!(f.is_follower(3));
    }

    #[test]
    fn device_switch_splits_the_run() {
        // scan,filter on GPU; select,filter on CPU: two groups of 2.
        let q = chain_query(vec![OpSpec::Scan, filter(), select(), filter()]);
        let p = plan(&q, vec![Device::Gpu, Device::Gpu, Device::Cpu, Device::Cpu]);
        let f = fuse(&q, &p);
        assert_eq!(f.groups.len(), 2);
        assert_eq!(f.groups[0].ops, vec![0, 1]);
        assert_eq!(f.groups[0].device, Device::Gpu);
        assert_eq!(f.groups[1].ops, vec![2, 3]);
        assert_eq!(f.groups[1].device, Device::Cpu);
    }

    #[test]
    fn single_op_runs_do_not_fuse() {
        // Alternating devices: every run has length 1.
        let q = chain_query(vec![OpSpec::Scan, filter(), select()]);
        let p = plan(&q, vec![Device::Cpu, Device::Gpu, Device::Cpu]);
        let f = fuse(&q, &p);
        assert!(f.groups.is_empty());
        assert_eq!(f.fused_ops(), 0);
        assert!(f.group_of(1).is_none());
    }

    #[test]
    fn non_fusable_kind_breaks_the_chain() {
        // scan→filter | expand | filter→select: expand interrupts.
        let q = chain_query(vec![
            OpSpec::Scan,
            filter(),
            OpSpec::Expand,
            filter(),
            select(),
        ]);
        let p = plan(&q, vec![Device::Cpu; 5]);
        let f = fuse(&q, &p);
        assert_eq!(f.groups.len(), 2);
        assert_eq!(f.groups[0].ops, vec![0, 1]);
        assert_eq!(f.groups[1].ops, vec![3, 4]);
        assert!(f.group_of(2).is_none());
    }

    #[test]
    fn aggregate_is_terminal_only() {
        // Ops after the aggregate start a fresh run.
        let q = chain_query(vec![OpSpec::Scan, filter(), aggregate(), filter(), select()]);
        let p = plan(&q, vec![Device::Cpu; 5]);
        let f = fuse(&q, &p);
        assert_eq!(f.groups.len(), 2);
        assert_eq!(f.groups[0].ops, vec![0, 1, 2]);
        assert_eq!(f.groups[0].tail(), 2);
        assert_eq!(f.groups[1].ops, vec![3, 4]);
        assert!(f.groups[1].spec.agg.is_none());
    }

    #[test]
    fn branch_point_stops_fusion_but_branches_fuse_internally() {
        // scan -> {filter->select, filter->select} -> union: the scan
        // fans out (not fused); each branch is a 2-op group.
        let q = Query {
            name: "d".into(),
            ops: vec![
                OpNode { id: 0, spec: OpSpec::Scan, inputs: vec![] },
                OpNode { id: 1, spec: filter(), inputs: vec![0] },
                OpNode { id: 2, spec: select(), inputs: vec![1] },
                OpNode { id: 3, spec: filter(), inputs: vec![0] },
                OpNode { id: 4, spec: select(), inputs: vec![3] },
                OpNode { id: 5, spec: OpSpec::Union, inputs: vec![2, 4] },
            ],
            window: WindowSpec::tumbling(Duration::from_secs(30)),
            uses_window_state: false,
        };
        q.validate().unwrap();
        let p = plan(&q, vec![Device::Cpu; 6]);
        let f = fuse(&q, &p);
        assert_eq!(f.groups.len(), 2);
        assert_eq!(f.groups[0].ops, vec![1, 2]);
        assert_eq!(f.groups[1].ops, vec![3, 4]);
        assert!(f.group_of(0).is_none(), "fan-out head must not fuse");
        assert!(f.group_of(5).is_none());
    }

    #[test]
    fn fusable_runs_ignore_devices_but_match_fuse_on_uniform_plans() {
        let q = chain_query(vec![OpSpec::Scan, filter(), select(), aggregate()]);
        // A mid-chain device switch splits `fuse` but not the
        // structural runs (the scheduler re-splits per assignment).
        let runs = fusable_runs(&q);
        assert_eq!(runs, vec![Some(0), Some(0), Some(0), Some(0)]);
        let split = fuse(&q, &plan(&q, vec![Device::Gpu, Device::Gpu, Device::Cpu, Device::Cpu]));
        assert_eq!(split.groups.len(), 2);
        // On a uniform plan the two agree.
        let uniform = fuse(&q, &plan(&q, vec![Device::Cpu; 4]));
        assert_eq!(uniform.groups.len(), 1);
        assert_eq!(uniform.groups[0].ops, vec![0, 1, 2, 3]);
        // Non-fusable kinds stay unassigned in both.
        let q2 = chain_query(vec![OpSpec::Scan, filter(), OpSpec::Expand]);
        let runs2 = fusable_runs(&q2);
        assert_eq!(runs2, vec![Some(0), Some(0), None]);
    }

    #[test]
    fn none_sidecar_reports_nothing_fused() {
        let f = FusedPlan::none(4);
        assert_eq!(f.fused_ops(), 0);
        assert!(f.group_of(2).is_none());
        assert!(!f.is_follower(2));
    }
}
