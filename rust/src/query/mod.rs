//! Query representation, layered the way production engines converge on
//! (see `ARCHITECTURE.md` §Query-stack):
//!
//! 1. **Authoring** — the fluent [`builder`] (`scan → filter → … →
//!    build`), including true DAG construction via
//!    [`builder::QueryBuilder::branch`] (fan-out to multiple sinks) and
//!    [`builder::QueryBuilder::merge_union`] (diamond merges);
//! 2. **Logical plan** — the validated operation DAG ([`dag`]): nodes
//!    name their producers, `validate()` enforces acyclicity /
//!    connectivity / topological storage, `traverse()` is a real
//!    Kahn-order iteration. [`optimize`] rewrites this DAG
//!    (device-agnostic rules such as projection pushdown into joins);
//! 3. **Physical plan** — [`physical`]: `MapDevice` (Alg. 2) annotates
//!    every logical op with a device and the size estimate that drove
//!    the choice, producing a [`physical::PhysicalPlan`]; [`fuse`] then
//!    collapses same-device scan→filter→project→(aggregate) runs into
//!    single-traversal [`fuse::FusedGroup`]s (a sidecar — the plan
//!    itself is untouched);
//! 4. **Execution** — [`exec`] walks the physical DAG over a
//!    micro-batch, charging host↔device transfer at every boundary
//!    (branch edges included) through the placement rule it shares with
//!    the planner ([`physical::transfer_boundaries`]).
//!
//! Sessions ([`crate::session`]) sit on top: they own the shared
//! coordinator state and drive many registered queries through one
//! micro-batch loop.

pub mod builder;
pub mod dag;
pub mod exec;
pub mod fuse;
pub mod optimize;
pub mod physical;

pub use builder::QueryBuilder;
pub use dag::{OpKind, OpNode, OpSpec, Query};
pub use fuse::{FusedGroup, FusedPlan};
pub use physical::{DevicePlan, PhysicalOp, PhysicalPlan};
