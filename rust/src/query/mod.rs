//! Query representation: logical operation DAGs ([`dag`]), the fluent
//! builder ([`builder`]) and physical execution over partitions with a
//! per-operation device plan ([`exec`]).

pub mod builder;
pub mod dag;
pub mod exec;
pub mod optimize;

pub use builder::QueryBuilder;
pub use dag::{OpKind, OpNode, OpSpec, Query};
