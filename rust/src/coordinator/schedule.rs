//! Cross-query GPU co-scheduling — the shared-device layer between the
//! session and the per-query planner.
//!
//! `MapDevice` (Alg. 2) maps each op of *one* query assuming the GPU is
//! idle. Since the session multiplexes many queries per micro-batch,
//! concurrent independent plans double-book the device: every plan's
//! latency prediction (and therefore Eq. 6 admission and the Eq. 10
//! history) is wrong exactly when the system is loaded. This module
//! plans one micro-batch **jointly across all of a source's queries**:
//!
//! 1. collect per-query candidates — each op's Eq. 7/8/9 cost vectors
//!    from [`planner::op_candidates`] (the same `SizeEstimator`-fed path
//!    `map_device` runs on) plus the independently-selected plan;
//! 2. convert candidates to *seconds* through the calibrated
//!    [`DeviceModel`] — mirroring exactly how the executor charges
//!    simulated time (per-core CPU shares, coalesced GPU volumes divided
//!    across `num_gpus`, PCIe + chunk-count-aware coalesce staging at
//!    the [`transfer_boundaries`] the planner and executor share);
//! 3. solve the shared-GPU-budget assignment greedily by
//!    **GPU-benefit-per-GPU-second**: starting all-CPU, repeatedly flip
//!    the op (among those the per-query planner itself would put on the
//!    GPU) whose flip buys the largest reduction in summed completion
//!    time per second of device time it books — respecting Alg. 2's
//!    transfer/coalesce boundary economics at every evaluation — while
//!    never letting the predicted makespan grow.
//!
//! The result is a [`JointPlan`]: one [`PhysicalPlan`] per query plus a
//! [`Prediction`] with the **serialized GPU timeline** ([`GpuSlot`]s) the
//! assignment implies. The prediction uses the same FIFO arbitration as
//! the executor's [`GpuTimeline`](crate::query::exec::GpuTimeline), so
//! predicted and simulated contention
//! agree by construction:
//!
//! * `makespan ≤ all-CPU makespan` — the greedy starts all-CPU and only
//!   accepts non-worsening moves (and the final plan is the best of
//!   {greedy, independent-under-timeline, all-CPU});
//! * `makespan ≤ Σ independent per-query plan costs` — under FIFO
//!   serialization a query waits at most the total device time of the
//!   queries ahead of it.
//!
//! Data results never depend on the schedule (pinned by the
//! differential test in `rust/tests/coscheduling.rs`) — co-scheduling
//! moves *time*, not rows.

use crate::coordinator::planner::{self, OpCandidate};
use crate::devices::model::{DeviceModel, OpVolume};
use crate::devices::Device;
use crate::error::Result;
use crate::query::dag::{OpKind, Query};
use crate::query::physical::{transfer_boundaries, PhysicalOp, PhysicalPlan};

/// Makespan slack treated as "no worse" (absolute seconds): float noise
/// guard for the greedy's monotonicity invariant.
const EPS: f64 = 1e-9;

/// One query's joint-planning inputs: the logical DAG, its Eq. 7/8/9
/// candidate costs, and the micro-batch context the executor will charge
/// (chunk count, window side size).
pub struct QueryCandidate<'a> {
    pub query: &'a Query,
    /// Per-op Eq. 7/8/9 cost vectors ([`planner::op_candidates`]).
    pub candidates: Vec<OpCandidate>,
    /// The plan Alg. 2 picks for this query alone (idle-GPU assumption).
    pub independent: PhysicalPlan,
    /// Chunk count of the micro-batch entering the query (gates the
    /// coalesce staging charge, as everywhere else).
    pub input_chunks: usize,
    /// Window-state bytes the query's join reads (0 without a join).
    pub aux_bytes: f64,
    /// Chunk count of the window-state snapshot (0 without one): the
    /// executor coalesces a single-chunk build side for free, and the
    /// prediction must agree.
    pub aux_chunks: usize,
}

impl<'a> QueryCandidate<'a> {
    /// Build a candidate the way the session plans: Eq. 7/8/9 costing
    /// via the query's learned [`planner::SizeEstimator`], plus the
    /// independent Alg. 2 selection for reference.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        query: &'a Query,
        part_bytes: f64,
        inf_pt: f64,
        base_trans: f64,
        estimator: &planner::SizeEstimator,
        input_chunks: usize,
        aux_bytes: f64,
        aux_chunks: usize,
    ) -> Result<QueryCandidate<'a>> {
        let candidates =
            planner::op_candidates(query, part_bytes, inf_pt, base_trans, estimator)?;
        let independent = planner::select_devices(query, &candidates, input_chunks)?;
        Ok(QueryCandidate {
            query,
            candidates,
            independent,
            input_chunks,
            aux_bytes,
            aux_chunks,
        })
    }
}

/// One reservation on the predicted serialized GPU timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSlot {
    /// Index into the candidate list (session registration order).
    pub query: usize,
    /// Logical op id within that query.
    pub op_id: usize,
    /// Reservation start/end, seconds from micro-batch start.
    pub start: f64,
    pub end: f64,
}

/// What the scheduler predicts for the assignment it emits.
#[derive(Clone, Debug, Default)]
pub struct Prediction {
    /// Per-query completion under the shared timeline (seconds from
    /// micro-batch start), in candidate order.
    pub completions: Vec<f64>,
    /// max(completions): the joint plan's predicted batch makespan.
    pub makespan: f64,
    /// Total GPU-busy seconds the joint plan books.
    pub gpu_busy: f64,
    /// Per-query completion each *independent* plan predicts for itself
    /// (idle-GPU assumption) — what per-query `map_device` believes.
    pub independent: Vec<f64>,
    /// Makespan the independent plans actually reach once their GPU ops
    /// serialize on the shared timeline (the double-booking corrected).
    pub independent_shared_makespan: f64,
    /// Makespan with every op of every query on the CPU.
    pub all_cpu_makespan: f64,
    /// The serialized device reservations of the emitted assignment.
    pub timeline: Vec<GpuSlot>,
}

/// The scheduler's output: per-query physical plans (candidate order)
/// plus the shared-timeline prediction.
#[derive(Clone, Debug)]
pub struct JointPlan {
    pub plans: Vec<PhysicalPlan>,
    pub predicted: Prediction,
}

/// Per-op seconds profile, mirroring the executor's simulated charging
/// (`query::exec`): CPU per-core share, GPU coalesced volume over
/// `num_gpus`, PCIe + staging at boundaries.
#[derive(Clone, Copy, Debug)]
struct OpSecs {
    cpu: f64,
    gpu: f64,
    trans_in: f64,
    trans_out: f64,
    coalesce: f64,
}

/// Precomputed per-query scheduling context.
struct ChainCtx {
    order: Vec<usize>,
    inputs: Vec<Vec<usize>>,
    consumers: Vec<Vec<usize>>,
    secs: Vec<OpSecs>,
}

/// A query's predicted execution shape under one device assignment: the
/// CPU time run before each GPU reservation, then a trailing CPU tail.
/// `segments[k] = (cpu_before, gpu_busy, op_id)`; the final element has
/// `gpu_busy == 0`.
struct Chain {
    segments: Vec<(f64, f64, usize)>,
}

fn op_secs(
    cand: &OpCandidate,
    aux: f64,
    input_chunks: usize,
    aux_chunks: usize,
    model: &DeviceModel,
    num_cores: usize,
    num_gpus: usize,
) -> OpSecs {
    // Estimates are per partition (Part_(i,j)); the executor charges the
    // whole batch: CPU ops at per-core volume, GPU ops at the coalesced
    // total divided across the GPUs.
    let total_in = cand.est_in_bytes * num_cores as f64;
    let total_out = cand.est_out_bytes * num_cores as f64;
    let op_aux = match cand.kind {
        OpKind::Join => aux,
        _ => 0.0,
    };
    let cpu = model
        .op_time(
            Device::Cpu,
            cand.kind,
            OpVolume::new(cand.est_in_bytes, cand.est_out_bytes, op_aux),
        )
        .as_secs_f64();
    let gpu = model
        .op_time(Device::Gpu, cand.kind, OpVolume::new(total_in, total_out, op_aux))
        .as_secs_f64()
        / num_gpus as f64;
    let staged = total_in + op_aux;
    OpSecs {
        cpu,
        gpu,
        trans_in: model.transfer_time(staged).as_secs_f64(),
        trans_out: model.transfer_time(total_out).as_secs_f64(),
        // Both the batch side and (for joins) the window side stage at
        // the boundary, each by its own real chunk count — a
        // single-chunk side coalesces for free, exactly as the
        // executor charges it.
        coalesce: model.coalesce_time(total_in, input_chunks).as_secs_f64()
            + model.coalesce_time(op_aux, aux_chunks).as_secs_f64(),
    }
}

fn chain_ctx(
    qc: &QueryCandidate,
    model: &DeviceModel,
    num_cores: usize,
    num_gpus: usize,
) -> ChainCtx {
    // QueryCandidate::build already ran topo_order()? via
    // op_candidates, so an invalid DAG here is a caller bug — fail
    // loudly rather than lay out a silently wrong chain.
    let order = qc
        .query
        .topo_order()
        .expect("QueryCandidate requires a validated (acyclic) query");
    let inputs: Vec<Vec<usize>> =
        qc.query.ops.iter().map(|op| op.inputs.clone()).collect();
    let consumers = qc.query.consumers();
    let secs = qc
        .candidates
        .iter()
        .map(|c| {
            op_secs(
                c,
                qc.aux_bytes,
                qc.input_chunks,
                qc.aux_chunks,
                model,
                num_cores,
                num_gpus,
            )
        })
        .collect();
    ChainCtx { order, inputs, consumers, secs }
}

/// Lay one query's ops out on its local timeline under `devices`,
/// charging boundary transfers exactly where the executor does
/// ([`transfer_boundaries`] over the *full* assignment).
fn chain(ctx: &ChainCtx, devices: &[Device], batch_fixed: f64) -> Chain {
    let mut segments = Vec::new();
    let mut cpu_acc = batch_fixed;
    for &o in &ctx.order {
        match devices[o] {
            Device::Cpu => cpu_acc += ctx.secs[o].cpu,
            Device::Gpu => {
                let (entering, leaving) =
                    transfer_boundaries(&ctx.inputs[o], &ctx.consumers[o], |i| {
                        devices[i] == Device::Cpu
                    });
                let mut busy = ctx.secs[o].gpu;
                if entering {
                    busy += ctx.secs[o].coalesce + ctx.secs[o].trans_in;
                }
                if leaving {
                    busy += ctx.secs[o].trans_out;
                }
                segments.push((cpu_acc, busy, o));
                cpu_acc = 0.0;
            }
        }
    }
    segments.push((cpu_acc, 0.0, usize::MAX));
    Chain { segments }
}

/// FIFO shared-timeline simulation — the predictive twin of the
/// executor's [`GpuTimeline`](crate::query::exec::GpuTimeline)
/// arbitration: queries run concurrently from
/// batch start (in candidate order), each GPU reservation starts at
/// `max(ready, device free)`.
fn simulate(chains: &[Chain]) -> (Vec<f64>, f64, f64, Vec<GpuSlot>) {
    let mut cursor = 0.0f64;
    let mut busy_total = 0.0f64;
    let mut completions = Vec::with_capacity(chains.len());
    let mut slots = Vec::new();
    for (qi, chain) in chains.iter().enumerate() {
        let mut local = 0.0f64;
        for &(cpu, busy, op_id) in &chain.segments {
            local += cpu;
            if busy > 0.0 {
                let start = cursor.max(local);
                local = start + busy;
                cursor = local;
                busy_total += busy;
                slots.push(GpuSlot { query: qi, op_id, start, end: local });
            }
        }
        completions.push(local);
    }
    let makespan = completions.iter().copied().fold(0.0, f64::max);
    (completions, makespan, busy_total, slots)
}

/// Σ completions — the greedy's tie-breaking objective (mean latency).
fn total(completions: &[f64]) -> f64 {
    completions.iter().sum()
}

/// Plan one micro-batch jointly across `cands` (a source's queries, in
/// registration order) under one shared GPU. See the module docs for the
/// algorithm and the guarantees on [`Prediction::makespan`].
pub fn plan_joint(
    cands: &[QueryCandidate],
    model: &DeviceModel,
    num_cores: usize,
    num_gpus: usize,
) -> JointPlan {
    if cands.is_empty() {
        return JointPlan { plans: Vec::new(), predicted: Prediction::default() };
    }
    let batch_fixed = model.batch_fixed.as_secs_f64();
    let ctxs: Vec<ChainCtx> =
        cands.iter().map(|qc| chain_ctx(qc, model, num_cores, num_gpus)).collect();

    // Reference assignments.
    let independent_devices: Vec<Vec<Device>> = cands
        .iter()
        .map(|qc| qc.independent.per_op.iter().map(|o| o.device).collect())
        .collect();
    let ind_chains: Vec<Chain> = ctxs
        .iter()
        .zip(&independent_devices)
        .map(|(ctx, d)| chain(ctx, d, batch_fixed))
        .collect();
    // What each independent plan believes, alone on an idle device.
    let independent: Vec<f64> = ind_chains
        .iter()
        .map(|c| {
            let (comp, _, _, _) = simulate(std::slice::from_ref(c));
            comp[0]
        })
        .collect();
    let (_, ind_shared_makespan, _, _) = simulate(&ind_chains);

    let all_cpu_devices: Vec<Vec<Device>> =
        cands.iter().map(|qc| vec![Device::Cpu; qc.query.ops.len()]).collect();
    let all_cpu_chains: Vec<Chain> = ctxs
        .iter()
        .zip(&all_cpu_devices)
        .map(|(ctx, d)| chain(ctx, d, batch_fixed))
        .collect();
    let (_, all_cpu_makespan, _, _) = simulate(&all_cpu_chains);

    // Greedy: start all-CPU; flip the best CPU→GPU move (restricted to
    // ops the per-query planner itself mapped to GPU — the scheduler
    // *rations* the device, it never overrides Alg. 2's per-op
    // economics) by benefit-per-GPU-second until no move helps.
    let mut devices = all_cpu_devices;
    let movable: Vec<(usize, usize)> = independent_devices
        .iter()
        .enumerate()
        .flat_map(|(q, d)| {
            d.iter()
                .enumerate()
                .filter(|(_, dev)| **dev == Device::Gpu)
                .map(move |(o, _)| (q, o))
        })
        .collect();
    let mut chains: Vec<Chain> = ctxs
        .iter()
        .zip(&devices)
        .map(|(ctx, d)| chain(ctx, d, batch_fixed))
        .collect();
    let (mut completions, mut makespan, mut busy, _) = simulate(&chains);
    loop {
        let cur_total = total(&completions);
        let mut best: Option<(f64, usize, usize)> = None;
        for &(q, o) in &movable {
            if devices[q][o] == Device::Gpu {
                continue;
            }
            devices[q][o] = Device::Gpu;
            let trial = chain(&ctxs[q], &devices[q], batch_fixed);
            let saved = std::mem::replace(&mut chains[q], trial);
            let (comp, mk, b, _) = simulate(&chains);
            if mk <= makespan + EPS && total(&comp) < cur_total - EPS {
                // Benefit per GPU-second; a flip that *frees* device
                // time (boundary merging) is a free win.
                let gpu_added = b - busy;
                let score = if gpu_added > EPS {
                    (cur_total - total(&comp)) / gpu_added
                } else {
                    f64::INFINITY
                };
                if best.map(|(s, _, _)| score > s).unwrap_or(true) {
                    best = Some((score, q, o));
                }
            }
            chains[q] = saved;
            devices[q][o] = Device::Cpu;
        }
        match best {
            Some((_, q, o)) => {
                devices[q][o] = Device::Gpu;
                chains[q] = chain(&ctxs[q], &devices[q], batch_fixed);
                let (comp, mk, b, _) = simulate(&chains);
                completions = comp;
                makespan = mk;
                busy = b;
            }
            None => break,
        }
    }

    // Final pick: the greedy result unless the independent plans, once
    // serialized on the shared timeline, are predicted strictly better
    // (e.g. a lone GPU segment only pays off as a block the one-op-at-a-
    // time greedy cannot reach). The all-CPU bound holds either way:
    // greedy starts there and never worsens.
    let chosen_devices = if ind_shared_makespan + EPS < makespan {
        independent_devices
    } else {
        devices
    };
    let chosen_chains: Vec<Chain> = ctxs
        .iter()
        .zip(&chosen_devices)
        .map(|(ctx, d)| chain(ctx, d, batch_fixed))
        .collect();
    let (completions, makespan, gpu_busy, timeline) = simulate(&chosen_chains);

    let plans: Vec<PhysicalPlan> = cands
        .iter()
        .zip(&chosen_devices)
        .map(|(qc, d)| PhysicalPlan {
            per_op: qc
                .candidates
                .iter()
                .map(|c| PhysicalOp {
                    op_id: c.op_id,
                    kind: c.kind,
                    device: d[c.op_id],
                    est_bytes: c.est_bytes,
                })
                .collect(),
        })
        .collect();

    JointPlan {
        plans,
        predicted: Prediction {
            completions,
            makespan,
            gpu_busy,
            independent,
            independent_shared_makespan: ind_shared_makespan,
            all_cpu_makespan,
            timeline,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::SizeEstimator;
    use crate::engine::ops::filter::Predicate;
    use crate::engine::window::WindowSpec;
    use crate::query::builder::QueryBuilder;
    use std::time::Duration;

    const KB: f64 = 1024.0;

    fn chain_query(name: &str) -> Query {
        QueryBuilder::scan(name)
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .filter("v", Predicate::Ge(0.0))
            .select(&["v"])
            .build()
            .unwrap()
    }

    fn cand(query: &Query, part: f64, inf: f64, chunks: usize) -> QueryCandidate<'_> {
        let est = SizeEstimator::new(query.len());
        QueryCandidate::build(query, part, inf, 0.1, &est, chunks, 0.0, 0).unwrap()
    }

    #[test]
    fn empty_input_yields_empty_plan() {
        let jp = plan_joint(&[], &DeviceModel::default(), 12, 1);
        assert!(jp.plans.is_empty());
        assert_eq!(jp.predicted.makespan, 0.0);
    }

    #[test]
    fn single_query_never_worse_than_all_cpu_or_independent() {
        let q = chain_query("solo");
        let model = DeviceModel::default();
        for part in [4.0 * KB, 50.0 * KB, 400.0 * KB] {
            let qc = cand(&q, part, 10.0 * KB, 4);
            let jp = plan_joint(std::slice::from_ref(&qc), &model, 12, 1);
            assert_eq!(jp.plans.len(), 1);
            assert_eq!(jp.plans[0].len(), q.len());
            let p = &jp.predicted;
            assert!(p.makespan <= p.all_cpu_makespan + 1e-6, "{p:?}");
            assert!(p.makespan <= p.independent.iter().sum::<f64>() + 1e-6, "{p:?}");
            assert_eq!(p.completions.len(), 1);
            assert!((p.makespan - p.completions[0]).abs() < 1e-12);
        }
    }

    #[test]
    fn joint_gpu_set_is_subset_of_independent() {
        // The scheduler rations the device: it may demote independent
        // GPU ops to CPU, never promote CPU ops to GPU.
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        let cands = vec![cand(&q1, 60.0 * KB, 8.0 * KB, 4), cand(&q2, 60.0 * KB, 8.0 * KB, 4)];
        let jp = plan_joint(&cands, &model, 12, 1);
        for (qc, plan) in cands.iter().zip(&jp.plans) {
            for (ind, joint) in qc.independent.per_op.iter().zip(&plan.per_op) {
                if joint.device == Device::Gpu {
                    assert_eq!(ind.device, Device::Gpu, "scheduler promoted an op");
                }
            }
        }
    }

    #[test]
    fn predicted_timeline_is_serialized() {
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        let cands = vec![cand(&q1, 60.0 * KB, 8.0 * KB, 4), cand(&q2, 60.0 * KB, 8.0 * KB, 4)];
        let jp = plan_joint(&cands, &model, 12, 1);
        let tl = &jp.predicted.timeline;
        for w in tl.windows(2) {
            assert!(w[0].end <= w[1].start + 1e-12, "overlapping slots {w:?}");
        }
        for s in tl {
            assert!(s.end > s.start, "empty slot {s:?}");
            assert!(s.end <= jp.predicted.makespan + 1e-9);
        }
        let booked: f64 = tl.iter().map(|s| s.end - s.start).sum();
        assert!((booked - jp.predicted.gpu_busy).abs() < 1e-9);
    }

    #[test]
    fn contended_queries_beat_serialized_independent_plans() {
        // Two GPU-leaning queries on one GPU: independent plans
        // serialize back-to-back; the joint plan keeps one query on the
        // device and runs the other where it does not have to queue.
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        // ~50 KB per-partition (600 KB batch): GPU is faster but the CPU
        // is competitive — the regime where rationing pays.
        let cands = vec![cand(&q1, 50.0 * KB, 10.0 * KB, 4), cand(&q2, 50.0 * KB, 10.0 * KB, 4)];
        // Sanity: the per-query planner wants the GPU for both.
        assert!(cands[0].independent.gpu_ops() > 0);
        assert!(cands[1].independent.gpu_ops() > 0);
        let jp = plan_joint(&cands, &model, 12, 1);
        let p = &jp.predicted;
        assert!(
            p.makespan < p.independent_shared_makespan - 1e-9,
            "joint {} !< independent-serialized {}",
            p.makespan,
            p.independent_shared_makespan
        );
        // And the independent plans' own predictions under-estimate what
        // they actually cost on the shared device.
        let ind_max = p.independent.iter().copied().fold(0.0, f64::max);
        assert!(
            p.independent_shared_makespan > ind_max + 1e-9,
            "no double-booking detected: {} vs {}",
            p.independent_shared_makespan,
            ind_max
        );
    }
}
