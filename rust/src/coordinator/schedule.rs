//! Cross-query GPU co-scheduling — the shared-device layer between the
//! session's scheduling rounds and the per-query planner.
//!
//! `MapDevice` (Alg. 2) maps each op of *one* query assuming the GPU is
//! idle. Since a session round multiplexes many queries — across
//! sources, over the executors of a [`DeviceTopology`] — concurrent
//! independent plans double-book the devices: every plan's latency
//! prediction (and therefore Eq. 6 admission and the Eq. 10 history) is
//! wrong exactly when the system is loaded. This module plans one
//! scheduling round **jointly across every admitted query**:
//!
//! 1. collect per-query candidates — each op's Eq. 7/8/9 cost vectors
//!    from [`planner::op_candidates`] (the same `SizeEstimator`-fed path
//!    `map_device` runs on) plus the independently-selected plan;
//! 2. convert candidates to *seconds* through the calibrated
//!    [`DeviceModel`], **per executor of the topology** — mirroring
//!    exactly how the cluster executor charges simulated time: each
//!    executor processes its core-proportional row share (per-core CPU
//!    volumes are share-invariant; GPU volumes scale with the share and
//!    divide across that executor's GPUs), window sides are broadcast in
//!    full, and PCIe + chunk-count-aware coalesce staging land at the
//!    [`transfer_boundaries`] the planner and executor share (each op's
//!    *own* propagated input layout gates the staging charge). A
//!    single-node session is the 1-executor topology — the old
//!    one-device model is the special case, not the rule;
//! 3. solve the shared-GPU-budget assignment greedily by
//!    **GPU-benefit-per-GPU-second**: starting all-CPU, repeatedly flip
//!    the op (among those the per-query planner itself would put on the
//!    GPU) whose flip buys the largest completion-time reduction per
//!    second of device time it books — respecting Alg. 2's
//!    transfer/coalesce boundary economics at every evaluation — while
//!    never letting the predicted makespan grow;
//! 4. choose the **grant order**: FIFO registration order is just one
//!    permutation of the round's queries on the per-executor timelines.
//!    Two list-scheduling generators are evaluated against FIFO for
//!    every candidate assignment — shortest-GPU-segment-first (queries
//!    sorted by total device busy time, ascending) and
//!    longest-tail-last (queries with the longest trailing CPU tail
//!    granted the device first, so their tails drain overlapped with
//!    everyone else's device time) — and the argmin is emitted as
//!    [`Prediction::order`] — the session executes the round in that
//!    order, so the executor's FIFO-in-request-order timelines realize
//!    exactly the predicted serialization.
//!
//! Structurally-fusable runs ([`crate::query::fuse::fusable_runs`])
//! whose members share a device under the assignment being evaluated
//! are costed as **one** op: a single device reservation carrying the
//! chain's combined row/byte/chunk flow (entering staging at the head,
//! leaving transfer at the tail), mirroring the fused execution the
//! session will actually run.
//!
//! The result is a [`JointPlan`]: one [`PhysicalPlan`] per query plus a
//! [`Prediction`] with the **serialized per-executor GPU timelines**
//! ([`GpuSlot`]s, each tagged with its executor). The prediction uses
//! the same FIFO arbitration as the executor's
//! [`GpuTimeline`](crate::query::exec::GpuTimeline) (one per executor),
//! so predicted and simulated contention agree by construction:
//!
//! * `makespan ≤ all-CPU makespan` — the greedy starts all-CPU and only
//!   accepts non-worsening moves;
//! * `makespan ≤ fifo_makespan` — the emitted (assignment, order) pair
//!   is the argmin over a pool that includes every assignment under
//!   plain FIFO; [`Prediction::fifo_makespan`] is what the
//!   registration-order scheduler would have emitted;
//! * `fifo_makespan ≤ Σ independent per-query plan costs` — under FIFO
//!   serialization a query waits at most the total device time of the
//!   queries ahead of it.
//!
//! The predicted makespan covers the processing chains (batch overhead +
//! op/transfer/contention time); a cluster round's network exchanges and
//! master coordination are plan-independent per-round constants, so they
//! cancel out of every comparison the scheduler makes.
//!
//! Data results never depend on the schedule (pinned by the
//! differential tests in `rust/tests/coscheduling.rs`) — co-scheduling
//! moves *time*, not rows.

use crate::cluster::DeviceTopology;
use crate::coordinator::planner::{self, OpCandidate};
use crate::devices::model::{DeviceModel, OpVolume};
use crate::devices::Device;
use crate::error::Result;
use crate::query::dag::{OpKind, Query};
use crate::query::physical::{transfer_boundaries, PhysicalOp, PhysicalPlan};

/// Makespan slack treated as "no worse" (absolute seconds): float noise
/// guard for the greedy's monotonicity invariant.
const EPS: f64 = 1e-9;

/// One query's joint-planning inputs: the logical DAG, its Eq. 7/8/9
/// candidate costs, and the micro-batch context the executor will charge
/// (chunk count, window side size).
pub struct QueryCandidate<'a> {
    pub query: &'a Query,
    /// Per-op Eq. 7/8/9 cost vectors ([`planner::op_candidates`]).
    pub candidates: Vec<OpCandidate>,
    /// The plan Alg. 2 picks for this query alone (idle-GPU assumption).
    pub independent: PhysicalPlan,
    /// Chunk count of the micro-batch entering the query (seeds the
    /// per-op chunk propagation gating coalesce staging, as everywhere
    /// else).
    pub input_chunks: usize,
    /// Window-state bytes the query's join reads (0 without a join).
    pub aux_bytes: f64,
    /// Chunk count of the window-state snapshot (0 without one): the
    /// executor coalesces a single-chunk build side for free, and the
    /// prediction must agree.
    pub aux_chunks: usize,
    /// Chunk count of each *executor's row share* of the micro-batch
    /// ([`share_chunk_counts`]), in executor order. Cluster slicing can
    /// shrink a share's chunk count below the query-level
    /// `input_chunks` (a share covering one chunk coalesces for free),
    /// so per-executor costing must seed the chunk propagation from the
    /// share's own layout — not the whole batch's. Empty (the default)
    /// falls back to `input_chunks` on every executor, which is exact
    /// for the 1-executor topology.
    pub exec_in_chunks: Vec<usize>,
}

impl<'a> QueryCandidate<'a> {
    /// Build a candidate the way the session plans: Eq. 7/8/9 costing
    /// via the query's learned [`planner::SizeEstimator`], plus the
    /// independent Alg. 2 selection for reference.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        query: &'a Query,
        part_bytes: f64,
        inf_pt: f64,
        base_trans: f64,
        estimator: &planner::SizeEstimator,
        input_chunks: usize,
        aux_bytes: f64,
        aux_chunks: usize,
    ) -> Result<QueryCandidate<'a>> {
        let candidates = planner::op_candidates(
            query,
            part_bytes,
            inf_pt,
            base_trans,
            estimator,
            input_chunks,
        )?;
        let independent = planner::select_devices(query, &candidates)?;
        Ok(QueryCandidate {
            query,
            candidates,
            independent,
            input_chunks,
            aux_bytes,
            aux_chunks,
            exec_in_chunks: Vec::new(),
        })
    }

    /// Attach per-executor share chunk counts ([`share_chunk_counts`])
    /// so the scheduler prices each executor's coalesce staging at the
    /// layout that executor will actually assemble.
    pub fn with_exec_chunks(mut self, exec_in_chunks: Vec<usize>) -> QueryCandidate<'a> {
        self.exec_in_chunks = exec_in_chunks;
        self
    }
}

/// Chunk count of each executor's row share of `input`, mirroring the
/// cluster executor's core-proportional split (`cluster::exec`:
/// remainder rows to the last executor, shares taken as chunk-list
/// views, so a share fully inside one chunk counts 1 however chunked
/// the whole batch is). This is the planner↔executor agreement point
/// the per-share coalesce estimate depends on.
pub fn share_chunk_counts(
    input: &crate::engine::chunked::ChunkedBatch,
    topo: &DeviceTopology,
) -> Vec<usize> {
    let rows = input.rows();
    let total_cores = topo.total_cores();
    let n = topo.num_executors();
    let mut counts = Vec::with_capacity(n);
    let mut start = 0usize;
    for (i, e) in topo.executors.iter().enumerate() {
        let len = if i + 1 == n {
            rows - start
        } else {
            rows * e.cores / total_cores.max(1)
        };
        counts.push(input.slice(start, len).num_chunks());
        start += len;
    }
    counts
}

/// One reservation on a predicted serialized per-executor GPU timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSlot {
    /// Index into the candidate list (round staging order).
    pub query: usize,
    /// Logical op id within that query.
    pub op_id: usize,
    /// Executor whose GPU the reservation occupies.
    pub exec: usize,
    /// Reservation start/end, seconds from round start.
    pub start: f64,
    pub end: f64,
}

/// What the scheduler predicts for the assignment it emits.
#[derive(Clone, Debug, Default)]
pub struct Prediction {
    /// Per-query completion under the shared per-executor timelines
    /// (seconds from round start), in candidate order.
    pub completions: Vec<f64>,
    /// max(completions): the joint plan's predicted round makespan.
    pub makespan: f64,
    /// Total GPU-busy seconds the joint plan books (all executors).
    pub gpu_busy: f64,
    /// The grant order the session should execute the round in
    /// (candidate indices). FIFO is `[0, 1, …]`; a reordered round puts
    /// shorter total-GPU queries first where that shrinks the makespan.
    pub order: Vec<usize>,
    /// Makespan the plain FIFO registration-order scheduler would have
    /// emitted (its best assignment, FIFO grants). `makespan ≤
    /// fifo_makespan` by construction.
    pub fifo_makespan: f64,
    /// Per-query completion each *independent* plan predicts for itself
    /// (idle devices) — what per-query `map_device` believes.
    pub independent: Vec<f64>,
    /// Makespan the independent plans actually reach once their GPU ops
    /// serialize FIFO on the shared timelines (the double-booking
    /// corrected).
    pub independent_shared_makespan: f64,
    /// Makespan with every op of every query on the CPU.
    pub all_cpu_makespan: f64,
    /// The serialized per-executor device reservations of the emitted
    /// (assignment, order) pair.
    pub timeline: Vec<GpuSlot>,
}

/// The scheduler's output: per-query physical plans (candidate order)
/// plus the shared-timeline prediction.
#[derive(Clone, Debug)]
pub struct JointPlan {
    pub plans: Vec<PhysicalPlan>,
    pub predicted: Prediction,
}

/// Per-op seconds profile on one executor, mirroring the executor's
/// simulated charging (`query::exec` / `cluster::exec`): CPU per-core
/// share (share-invariant), GPU at the executor's coalesced row-share
/// volume over its GPUs, PCIe + staging at boundaries.
#[derive(Clone, Copy, Debug)]
struct OpSecs {
    cpu: f64,
    gpu: f64,
    trans_in: f64,
    trans_out: f64,
    coalesce: f64,
}

/// Precomputed per-query scheduling context: DAG shape plus one
/// `OpSecs` vector per executor of the topology.
struct ChainCtx {
    order: Vec<usize>,
    inputs: Vec<Vec<usize>>,
    consumers: Vec<Vec<usize>>,
    /// `secs[e][o]`: op `o`'s seconds profile on executor `e`.
    secs: Vec<Vec<OpSecs>>,
    /// Per-executor GPU health (from the round's possibly-degraded
    /// topology): a `false` executor charges its GPU-assigned ops at CPU
    /// cost with no segments or transfers — the predictive twin of the
    /// executor running a CPU-demoted share plan.
    gpu_ok: Vec<bool>,
    /// Structural fusable runs of the logical DAG
    /// ([`crate::query::fuse::fusable_runs`]): adjacent run members that
    /// share a device under the assignment being evaluated execute as
    /// one fused traversal, so the chain layout books them as ONE device
    /// reservation with the members' combined busy time.
    fused_run: Vec<Option<usize>>,
}

/// One (query, executor) predicted execution shape under a device
/// assignment: the CPU time run before each GPU reservation, then a
/// trailing CPU tail. `segments[k] = (cpu_before, gpu_busy, op_id)`; the
/// final element has `gpu_busy == 0`.
struct Chain {
    segments: Vec<(f64, f64, usize)>,
}

fn op_secs(
    cand: &OpCandidate,
    share_in_chunks: usize,
    aux: f64,
    aux_chunks: usize,
    model: &DeviceModel,
    total_cores: usize,
    row_share: f64,
    gpus: usize,
) -> OpSecs {
    // Estimates are per partition (Part over the topology's total
    // cores); this executor's share of the batch is `row_share` of the
    // total. CPU ops charge per-core volume (identical on every
    // executor: share/cores_e == batch/total_cores); GPU ops charge the
    // executor's coalesced share divided across its GPUs — exactly the
    // volumes `cluster::execute_on_cluster` hands `query::exec`.
    let share_in = cand.est_in_bytes * total_cores as f64 * row_share;
    let share_out = cand.est_out_bytes * total_cores as f64 * row_share;
    // The window side is broadcast: every executor reads it in full.
    let op_aux = match cand.kind {
        OpKind::Join => aux,
        _ => 0.0,
    };
    let cpu = model
        .op_time(
            Device::Cpu,
            cand.kind,
            OpVolume::new(cand.est_in_bytes, cand.est_out_bytes, op_aux),
        )
        .as_secs_f64();
    let gpu = model
        .op_time(Device::Gpu, cand.kind, OpVolume::new(share_in, share_out, op_aux))
        .as_secs_f64()
        / gpus as f64;
    let staged = share_in + op_aux;
    OpSecs {
        cpu,
        gpu,
        trans_in: model.transfer_time(staged).as_secs_f64(),
        trans_out: model.transfer_time(share_out).as_secs_f64(),
        // Both the batch side and (for joins) the window side stage at
        // the boundary, each by its own layout: the batch side by the
        // op's propagated input chunk count *seeded from this
        // executor's share* (an aggregate/sort upstream collapses it to
        // one — free; cluster slicing can hand the executor fewer
        // chunks than the whole batch has), the window side by the
        // snapshot's — exactly as the executor charges it.
        coalesce: model.coalesce_time(share_in, share_in_chunks).as_secs_f64()
            + model.coalesce_time(op_aux, aux_chunks).as_secs_f64(),
    }
}

fn chain_ctx(qc: &QueryCandidate, model: &DeviceModel, topo: &DeviceTopology) -> ChainCtx {
    // QueryCandidate::build already ran topo_order()? via
    // op_candidates, so an invalid DAG here is a caller bug — fail
    // loudly rather than lay out a silently wrong chain.
    let order = qc
        .query
        .topo_order()
        .expect("QueryCandidate requires a validated (acyclic) query");
    let inputs: Vec<Vec<usize>> =
        qc.query.ops.iter().map(|op| op.inputs.clone()).collect();
    let consumers = qc.query.consumers();
    let total_cores = topo.total_cores();
    let secs = (0..topo.num_executors())
        .map(|e| {
            // Seed the chunk propagation from *this executor's* share
            // layout where known; the query-level candidate counts are
            // exact only when the share has as many chunks as the
            // whole batch (always true on the 1-executor topology).
            let seed = qc.exec_in_chunks.get(e).copied().unwrap_or(qc.input_chunks);
            let chunk_flows = planner::op_chunk_flows(qc.query, seed);
            qc.candidates
                .iter()
                .map(|c| {
                    op_secs(
                        c,
                        chunk_flows[c.op_id].0,
                        qc.aux_bytes,
                        qc.aux_chunks,
                        model,
                        total_cores,
                        topo.row_share(e),
                        topo.executors[e].gpus,
                    )
                })
                .collect()
        })
        .collect();
    ChainCtx {
        order,
        inputs,
        consumers,
        secs,
        gpu_ok: topo.gpu_ok.clone(),
        fused_run: crate::query::fuse::fusable_runs(qc.query),
    }
}

/// Lay one query's ops out on executor `e`'s local timeline under
/// `devices`, charging boundary transfers exactly where the executor
/// does ([`transfer_boundaries`] over the *full* assignment).
fn chain(ctx: &ChainCtx, e: usize, devices: &[Device], batch_fixed: f64) -> Chain {
    let secs = &ctx.secs[e];
    let mut segments = Vec::new();
    let mut cpu_acc = batch_fixed;
    for &o in &ctx.order {
        match devices[o] {
            Device::Cpu => cpu_acc += secs[o].cpu,
            // Faulted GPU device: the executor runs this op on CPU (the
            // session hands it a demoted share plan), so charge CPU cost
            // and book nothing on the device timeline.
            Device::Gpu if !ctx.gpu_ok[e] => cpu_acc += secs[o].cpu,
            Device::Gpu => {
                let (entering, leaving) =
                    transfer_boundaries(&ctx.inputs[o], &ctx.consumers[o], |i| {
                        devices[i] == Device::Cpu
                    });
                let mut busy = secs[o].gpu;
                if entering {
                    busy += secs[o].coalesce + secs[o].trans_in;
                }
                if leaving {
                    busy += secs[o].trans_out;
                }
                // Members of a structurally-fusable run that share the
                // device execute as ONE fused traversal: extend the
                // run's open reservation (they are adjacent — no CPU
                // between — so this is time-equivalent to back-to-back
                // slots, and the timeline shows the chain as one op).
                let fused_adjacent = cpu_acc == 0.0
                    && ctx.fused_run[o].is_some()
                    && segments.last().is_some_and(|&(_, b, prev)| {
                        b > 0.0
                            && prev != usize::MAX
                            && ctx.fused_run[prev] == ctx.fused_run[o]
                    });
                if fused_adjacent {
                    segments.last_mut().expect("checked above").1 += busy;
                } else {
                    segments.push((cpu_acc, busy, o));
                }
                cpu_acc = 0.0;
            }
        }
    }
    segments.push((cpu_acc, 0.0, usize::MAX));
    Chain { segments }
}

/// One query's chains across every executor of the topology.
fn query_chains(ctx: &ChainCtx, devices: &[Device], batch_fixed: f64) -> Vec<Chain> {
    (0..ctx.secs.len()).map(|e| chain(ctx, e, devices, batch_fixed)).collect()
}

/// Simulation result of one (assignment, grant order) pair.
struct Sim {
    completions: Vec<f64>,
    makespan: f64,
    busy: f64,
    slots: Vec<GpuSlot>,
}

/// FIFO-per-executor shared-timeline simulation — the predictive twin of
/// the executor's [`GpuTimeline`](crate::query::exec::GpuTimeline)
/// arbitration: the round's queries run concurrently from round start,
/// each executor runs its row-share chain of every query, and grants on
/// each executor's timeline serialize in `grant_order` (the order the
/// session executes the round in). A query completes at its slowest
/// executor chain (the barrier).
fn simulate(chains: &[Vec<Chain>], num_execs: usize, grant_order: &[usize]) -> Sim {
    let mut cursors = vec![0.0f64; num_execs];
    let mut busy_total = 0.0f64;
    let mut completions = vec![0.0f64; chains.len()];
    let mut slots = Vec::new();
    for &qi in grant_order {
        let mut comp = 0.0f64;
        for (e, chain) in chains[qi].iter().enumerate() {
            let mut local = 0.0f64;
            for &(cpu, busy, op_id) in &chain.segments {
                local += cpu;
                if busy > 0.0 {
                    let start = cursors[e].max(local);
                    local = start + busy;
                    cursors[e] = local;
                    busy_total += busy;
                    slots.push(GpuSlot { query: qi, op_id, exec: e, start, end: local });
                }
            }
            comp = comp.max(local);
        }
        completions[qi] = comp;
    }
    let makespan = completions.iter().copied().fold(0.0, f64::max);
    Sim { completions, makespan, busy: busy_total, slots }
}

/// Σ completions — the greedy's tie-breaking objective (mean latency).
fn total(completions: &[f64]) -> f64 {
    completions.iter().sum()
}

/// Shortest-GPU-segment-first grant order: queries sorted by total
/// booked device time ascending (ties keep registration order), so
/// short device users are not queued behind a long occupant they would
/// otherwise idle on.
fn shortest_first_order(chains: &[Vec<Chain>]) -> Vec<usize> {
    let busy: Vec<f64> = chains
        .iter()
        .map(|per_exec| {
            per_exec
                .iter()
                .flat_map(|c| c.segments.iter())
                .map(|&(_, b, _)| b)
                .sum()
        })
        .collect();
    let mut order: Vec<usize> = (0..chains.len()).collect();
    order.sort_by(|&a, &b| busy[a].total_cmp(&busy[b]).then(a.cmp(&b)));
    order
}

/// Longest-tail-last list-scheduling order: queries granted the device
/// in descending order of their trailing CPU tail (the work after the
/// last reservation), ties keeping registration order. Early device
/// grants let the long tails drain *last*, overlapped with everyone
/// else's device time instead of idling serialized behind it — the
/// classic list-scheduling complement to shortest-first, which wins
/// when tails (not device segments) dominate completion.
fn longest_tail_last_order(chains: &[Vec<Chain>]) -> Vec<usize> {
    let tail: Vec<f64> = chains
        .iter()
        .map(|per_exec| {
            per_exec
                .iter()
                .map(|c| c.segments.last().map_or(0.0, |&(cpu, _, _)| cpu))
                .fold(0.0, f64::max)
        })
        .collect();
    let mut order: Vec<usize> = (0..chains.len()).collect();
    order.sort_by(|&a, &b| tail[b].total_cmp(&tail[a]).then(a.cmp(&b)));
    order
}

/// Evaluate an assignment's chains: FIFO always; when `reorder`, the
/// argmin additionally spans shortest-GPU-first and longest-tail-last
/// grants (makespan, then Σ completions; FIFO wins ties, and earlier
/// generators win ties against later ones).
fn evaluate(chains: &[Vec<Chain>], num_execs: usize, reorder: bool) -> (Sim, Vec<usize>) {
    let fifo: Vec<usize> = (0..chains.len()).collect();
    let sim_fifo = simulate(chains, num_execs, &fifo);
    let mut best = (sim_fifo, fifo);
    if !reorder {
        return best;
    }
    for alt in [shortest_first_order(chains), longest_tail_last_order(chains)] {
        if alt == best.1 {
            continue;
        }
        let sim_alt = simulate(chains, num_execs, &alt);
        if sim_alt.makespan < best.0.makespan - EPS
            || (sim_alt.makespan <= best.0.makespan + EPS
                && total(&sim_alt.completions) < total(&best.0.completions) - EPS)
        {
            best = (sim_alt, alt);
        }
    }
    best
}

/// Greedy CPU→GPU rationing over `movable` (the ops the per-query
/// planner itself mapped to GPU — the scheduler rations the devices, it
/// never overrides Alg. 2's per-op economics), evaluated under FIFO
/// grants or (with `reorder`) the better of FIFO/shortest-first. Starts
/// all-CPU; never worsens the evaluated makespan.
fn greedy_assign(
    ctxs: &[ChainCtx],
    movable: &[(usize, usize)],
    num_execs: usize,
    batch_fixed: f64,
    reorder: bool,
) -> Vec<Vec<Device>> {
    let mut devices: Vec<Vec<Device>> = ctxs
        .iter()
        .map(|ctx| vec![Device::Cpu; ctx.inputs.len()])
        .collect();
    let mut chains: Vec<Vec<Chain>> = ctxs
        .iter()
        .zip(&devices)
        .map(|(ctx, d)| query_chains(ctx, d, batch_fixed))
        .collect();
    let (mut cur, _) = evaluate(&chains, num_execs, reorder);
    loop {
        let cur_total = total(&cur.completions);
        let mut best: Option<(f64, usize, usize)> = None;
        for &(q, o) in movable {
            if devices[q][o] == Device::Gpu {
                continue;
            }
            devices[q][o] = Device::Gpu;
            let trial = query_chains(&ctxs[q], &devices[q], batch_fixed);
            let saved = std::mem::replace(&mut chains[q], trial);
            let (sim, _) = evaluate(&chains, num_execs, reorder);
            let improves = sim.makespan < cur.makespan - EPS
                || (sim.makespan <= cur.makespan + EPS
                    && total(&sim.completions) < cur_total - EPS);
            if improves {
                // Benefit per GPU-second (makespan reductions weighted
                // by round width so they dominate mean-latency ones); a
                // flip that *frees* device time (boundary merging) is a
                // free win.
                let gain = (cur_total - total(&sim.completions))
                    + (cur.makespan - sim.makespan) * ctxs.len() as f64;
                let gpu_added = sim.busy - cur.busy;
                let score = if gpu_added > EPS { gain / gpu_added } else { f64::INFINITY };
                if best.map(|(s, _, _)| score > s).unwrap_or(true) {
                    best = Some((score, q, o));
                }
            }
            chains[q] = saved;
            devices[q][o] = Device::Cpu;
        }
        match best {
            Some((_, q, o)) => {
                devices[q][o] = Device::Gpu;
                chains[q] = query_chains(&ctxs[q], &devices[q], batch_fixed);
                let (sim, _) = evaluate(&chains, num_execs, reorder);
                cur = sim;
            }
            None => break,
        }
    }
    devices
}

/// Plan one scheduling round jointly across `cands` (the round's
/// queries, in staging order) over the per-executor GPUs of `topo`. See
/// the module docs for the algorithm and the guarantees on
/// [`Prediction::makespan`].
pub fn plan_joint(
    cands: &[QueryCandidate],
    model: &DeviceModel,
    topo: &DeviceTopology,
) -> JointPlan {
    if cands.is_empty() {
        return JointPlan { plans: Vec::new(), predicted: Prediction::default() };
    }
    let batch_fixed = model.batch_fixed.as_secs_f64();
    let num_execs = topo.num_executors();
    let ctxs: Vec<ChainCtx> = cands.iter().map(|qc| chain_ctx(qc, model, topo)).collect();
    let build = |devices: &[Vec<Device>]| -> Vec<Vec<Chain>> {
        ctxs.iter()
            .zip(devices)
            .map(|(ctx, d)| query_chains(ctx, d, batch_fixed))
            .collect()
    };

    // Reference assignments.
    let independent_devices: Vec<Vec<Device>> = cands
        .iter()
        .map(|qc| qc.independent.per_op.iter().map(|o| o.device).collect())
        .collect();
    let ind_chains = build(&independent_devices);
    // What each independent plan believes, alone on idle devices.
    let independent: Vec<f64> = (0..cands.len())
        .map(|q| simulate(&ind_chains, num_execs, &[q]).completions[q])
        .collect();
    let fifo: Vec<usize> = (0..cands.len()).collect();
    let ind_shared_makespan = simulate(&ind_chains, num_execs, &fifo).makespan;

    let all_cpu_devices: Vec<Vec<Device>> =
        cands.iter().map(|qc| vec![Device::Cpu; qc.query.ops.len()]).collect();
    let all_cpu_makespan =
        simulate(&build(&all_cpu_devices), num_execs, &fifo).makespan;

    let movable: Vec<(usize, usize)> = independent_devices
        .iter()
        .enumerate()
        .flat_map(|(q, d)| {
            d.iter()
                .enumerate()
                .filter(|(_, dev)| **dev == Device::Gpu)
                .map(move |(o, _)| (q, o))
        })
        .collect();

    // Two greedy passes: the plain FIFO rationer (what the
    // registration-order scheduler emits — its makespan is reported as
    // `fifo_makespan`), and a reorder-aware pass that can accept flips
    // only a different grant order makes profitable.
    let dev_fifo = greedy_assign(&ctxs, &movable, num_execs, batch_fixed, false);
    let dev_reorder = greedy_assign(&ctxs, &movable, num_execs, batch_fixed, true);

    // Final pick: the best (assignment, order) pair across the
    // independent plans and both greedy results, with the grant order
    // drawn from the full generator pool — FIFO, shortest-GPU-first,
    // longest-tail-last. Including every assignment's FIFO variant
    // guarantees makespan ≤ fifo_makespan; the FIFO greedy's all-CPU
    // start guarantees ≤ all-CPU; FIFO serialization of the independent
    // plans guarantees ≤ Σ independent.
    let assignments = [&independent_devices, &dev_fifo, &dev_reorder];
    let mut fifo_makespan = f64::INFINITY;
    let mut chosen: Option<(Sim, Vec<usize>, usize)> = None;
    for (ai, &devices) in assignments.iter().enumerate() {
        let chains = build(devices);
        let orders = [
            fifo.clone(),
            shortest_first_order(&chains),
            longest_tail_last_order(&chains),
        ];
        for (oi, order) in orders.into_iter().enumerate() {
            let sim = simulate(&chains, num_execs, &order);
            // The FIFO scheduler's emission: its own greedy (ai == 1) or
            // the independent fallback (ai == 0), FIFO grants.
            if oi == 0 && ai < 2 {
                fifo_makespan = fifo_makespan.min(sim.makespan);
            }
            let better = match &chosen {
                None => true,
                Some((best, _, _)) => {
                    sim.makespan < best.makespan - EPS
                        || (sim.makespan <= best.makespan + EPS
                            && total(&sim.completions) < total(&best.completions) - EPS)
                }
            };
            if better {
                chosen = Some((sim, order, ai));
            }
        }
    }
    let (sim, order, chosen_ai) = chosen.expect("non-empty candidate pool");
    let chosen_devices = assignments[chosen_ai];

    let plans: Vec<PhysicalPlan> = cands
        .iter()
        .zip(chosen_devices)
        .map(|(qc, d)| PhysicalPlan {
            per_op: qc
                .candidates
                .iter()
                .map(|c| PhysicalOp {
                    op_id: c.op_id,
                    kind: c.kind,
                    device: d[c.op_id],
                    est_bytes: c.est_bytes,
                })
                .collect(),
        })
        .collect();

    JointPlan {
        plans,
        predicted: Prediction {
            completions: sim.completions,
            makespan: sim.makespan,
            gpu_busy: sim.busy,
            order,
            fifo_makespan,
            independent,
            independent_shared_makespan: ind_shared_makespan,
            all_cpu_makespan,
            timeline: sim.slots,
        },
    }
}

/// Predict the shared-timeline execution of `cands` under **fixed**
/// per-query device plans — the same FIFO per-executor simulation
/// [`plan_joint`] scores assignments with, run once over the devices
/// `plans` already chose. This is how the sharded session prices a
/// source's round against its timeline-bank lease when the mode is not
/// re-planning jointly (Baseline / AllGpu / LmStream without
/// co-scheduling): the plan is whatever the mode produced, but the
/// lease commit still needs honest per-executor busy horizons.
///
/// Only the fields the fixed simulation actually determines are
/// populated: `completions`, `makespan`, `gpu_busy`, `order` (FIFO),
/// `fifo_makespan` (= `makespan`) and `timeline`. The counterfactual
/// comparatives (`independent*`, `all_cpu_makespan`) stay at their
/// defaults — there is no assignment search to compare against.
pub fn predict_fixed(
    cands: &[QueryCandidate],
    plans: &[PhysicalPlan],
    model: &DeviceModel,
    topo: &DeviceTopology,
) -> Prediction {
    assert_eq!(cands.len(), plans.len(), "one plan per candidate");
    if cands.is_empty() {
        return Prediction::default();
    }
    let batch_fixed = model.batch_fixed.as_secs_f64();
    let num_execs = topo.num_executors();
    let ctxs: Vec<ChainCtx> = cands.iter().map(|qc| chain_ctx(qc, model, topo)).collect();
    let chains: Vec<Vec<Chain>> = ctxs
        .iter()
        .zip(plans)
        .map(|(ctx, plan)| {
            let devices: Vec<Device> = plan.per_op.iter().map(|o| o.device).collect();
            query_chains(ctx, &devices, batch_fixed)
        })
        .collect();
    let fifo: Vec<usize> = (0..cands.len()).collect();
    let sim = simulate(&chains, num_execs, &fifo);
    Prediction {
        completions: sim.completions,
        makespan: sim.makespan,
        gpu_busy: sim.busy,
        order: fifo,
        fifo_makespan: sim.makespan,
        timeline: sim.slots,
        ..Prediction::default()
    }
}

/// Per-executor predicted GPU busy horizons of a prediction's timeline:
/// `horizons[e]` = the latest reservation end on executor `e` (seconds
/// from round start; 0.0 for an executor the round books nothing on).
/// This is what a shard commits to the
/// [`TimelineBank`](crate::coordinator::timeline_bank::TimelineBank)
/// after planning against its lease.
pub fn executor_horizons(pred: &Prediction, num_execs: usize) -> Vec<f64> {
    let mut horizons = vec![0.0f64; num_execs];
    for s in &pred.timeline {
        horizons[s.exec] = horizons[s.exec].max(s.end);
    }
    horizons
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::SizeEstimator;
    use crate::engine::ops::filter::Predicate;
    use crate::engine::window::WindowSpec;
    use crate::query::builder::QueryBuilder;
    use std::time::Duration;

    const KB: f64 = 1024.0;

    fn single_topo() -> DeviceTopology {
        DeviceTopology::single(12, 1)
    }

    fn chain_query(name: &str) -> Query {
        QueryBuilder::scan(name)
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .filter("v", Predicate::Ge(0.0))
            .select(&["v"])
            .build()
            .unwrap()
    }

    fn cand(query: &Query, part: f64, inf: f64, chunks: usize) -> QueryCandidate<'_> {
        let est = SizeEstimator::new(query.len());
        QueryCandidate::build(query, part, inf, 0.1, &est, chunks, 0.0, 0).unwrap()
    }

    fn chunked(rows_per_chunk: &[usize]) -> crate::engine::chunked::ChunkedBatch {
        use crate::engine::column::{Column, ColumnBatch, Field, Schema};
        let mk = |n: usize| {
            ColumnBatch::new(
                Schema::new(vec![Field::f32("v")]),
                vec![Column::F32(vec![1.0; n].into())],
            )
            .unwrap()
        };
        let mut cb = crate::engine::chunked::ChunkedBatch::from_batch(mk(rows_per_chunk[0]));
        for &n in &rows_per_chunk[1..] {
            cb.push(mk(n)).unwrap();
        }
        cb
    }

    #[test]
    fn share_chunk_counts_mirror_executor_slicing() {
        let two = DeviceTopology::from_cluster(&crate::cluster::ClusterSpec::of(2));
        // 2 equal chunks over 2 equal executors: the split lands on the
        // chunk boundary, each share covers exactly one chunk — fewer
        // than the batch's 2 the query-level estimate would charge.
        assert_eq!(share_chunk_counts(&chunked(&[8, 8]), &two), vec![1, 1]);
        // The 1-executor topology keeps the full layout.
        assert_eq!(share_chunk_counts(&chunked(&[8, 8]), &single_topo()), vec![2]);
        // An uneven split crosses a chunk boundary: both shares touch
        // two chunks (7 = chunk0 + a slice of chunk1; 8 = the rest of
        // chunk1 + chunk2).
        assert_eq!(share_chunk_counts(&chunked(&[5, 5, 5]), &two), vec![2, 2]);
    }

    #[test]
    fn exec_chunks_gate_per_executor_coalesce() {
        // The scheduler's per-share coalesce estimate must price the
        // layout each executor actually assembles, not the query-level
        // chunk count: a share covering a single chunk coalesces free.
        let q = chain_query("a");
        let model = DeviceModel::default();
        let topo = DeviceTopology::from_cluster(&crate::cluster::ClusterSpec::of(2));
        let naive = cand(&q, 50.0 * KB, 10.0 * KB, 2);
        let aware = cand(&q, 50.0 * KB, 10.0 * KB, 2).with_exec_chunks(vec![1, 1]);
        let ctx_naive = chain_ctx(&naive, &model, &topo);
        let ctx_aware = chain_ctx(&aware, &model, &topo);
        for e in 0..topo.num_executors() {
            for o in 0..q.len() {
                // Only the staging charge moves; op and transfer
                // profiles are share-layout-independent.
                assert_eq!(ctx_aware.secs[e][o].cpu, ctx_naive.secs[e][o].cpu);
                assert_eq!(ctx_aware.secs[e][o].gpu, ctx_naive.secs[e][o].gpu);
                assert_eq!(ctx_aware.secs[e][o].trans_in, ctx_naive.secs[e][o].trans_in);
                assert_eq!(
                    ctx_aware.secs[e][o].trans_out,
                    ctx_naive.secs[e][o].trans_out
                );
            }
            // The scan stages the share at any entering boundary: the
            // single-chunk share is free, the 2-chunk estimate is not.
            assert_eq!(ctx_aware.secs[e][0].coalesce, 0.0);
            assert!(ctx_naive.secs[e][0].coalesce > 0.0);
        }
        // And a share-aware 1-chunk seed agrees with building the
        // candidate from a single-chunk batch outright — the
        // planner↔executor agreement point.
        let single_seed = cand(&q, 50.0 * KB, 10.0 * KB, 1);
        let ctx_single = chain_ctx(&single_seed, &model, &topo);
        for e in 0..topo.num_executors() {
            for o in 0..q.len() {
                assert_eq!(
                    ctx_aware.secs[e][o].coalesce,
                    ctx_single.secs[e][o].coalesce
                );
            }
        }
    }

    #[test]
    fn empty_input_yields_empty_plan() {
        let jp = plan_joint(&[], &DeviceModel::default(), &single_topo());
        assert!(jp.plans.is_empty());
        assert_eq!(jp.predicted.makespan, 0.0);
    }

    #[test]
    fn single_query_never_worse_than_all_cpu_or_independent() {
        let q = chain_query("solo");
        let model = DeviceModel::default();
        for part in [4.0 * KB, 50.0 * KB, 400.0 * KB] {
            let qc = cand(&q, part, 10.0 * KB, 4);
            let jp = plan_joint(std::slice::from_ref(&qc), &model, &single_topo());
            assert_eq!(jp.plans.len(), 1);
            assert_eq!(jp.plans[0].len(), q.len());
            let p = &jp.predicted;
            assert!(p.makespan <= p.all_cpu_makespan + 1e-6, "{p:?}");
            assert!(p.makespan <= p.independent.iter().sum::<f64>() + 1e-6, "{p:?}");
            assert_eq!(p.completions.len(), 1);
            assert_eq!(p.order, vec![0]);
            assert!((p.makespan - p.completions[0]).abs() < 1e-12);
        }
    }

    #[test]
    fn joint_gpu_set_is_subset_of_independent() {
        // The scheduler rations the device: it may demote independent
        // GPU ops to CPU, never promote CPU ops to GPU.
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        let cands = vec![cand(&q1, 60.0 * KB, 8.0 * KB, 4), cand(&q2, 60.0 * KB, 8.0 * KB, 4)];
        let jp = plan_joint(&cands, &model, &single_topo());
        for (qc, plan) in cands.iter().zip(&jp.plans) {
            for (ind, joint) in qc.independent.per_op.iter().zip(&plan.per_op) {
                if joint.device == Device::Gpu {
                    assert_eq!(ind.device, Device::Gpu, "scheduler promoted an op");
                }
            }
        }
    }

    #[test]
    fn predicted_timeline_is_serialized_per_executor() {
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        let two_exec = DeviceTopology::from_cluster(&crate::cluster::ClusterSpec::of(2));
        for topo in [single_topo(), two_exec] {
            let cands =
                vec![cand(&q1, 60.0 * KB, 8.0 * KB, 4), cand(&q2, 60.0 * KB, 8.0 * KB, 4)];
            let jp = plan_joint(&cands, &model, &topo);
            let tl = &jp.predicted.timeline;
            for e in 0..topo.num_executors() {
                let per_exec: Vec<&GpuSlot> = tl.iter().filter(|s| s.exec == e).collect();
                for w in per_exec.windows(2) {
                    assert!(
                        w[0].end <= w[1].start + 1e-12,
                        "executor {e}: overlapping slots {w:?}"
                    );
                }
            }
            for s in tl {
                assert!(s.end > s.start, "empty slot {s:?}");
                assert!(s.end <= jp.predicted.makespan + 1e-9);
                assert!(s.exec < topo.num_executors());
            }
            let booked: f64 = tl.iter().map(|s| s.end - s.start).sum();
            assert!((booked - jp.predicted.gpu_busy).abs() < 1e-9);
        }
    }

    #[test]
    fn predict_fixed_replays_a_single_query_plan_exactly() {
        // For one query the joint plan executes FIFO, so re-predicting
        // its emitted plan with the fixed-device simulation must land on
        // the identical makespan/completions/timeline — the agreement
        // the sharded runtime's lease commits depend on.
        let q = chain_query("solo");
        let model = DeviceModel::default();
        for part in [4.0 * KB, 60.0 * KB, 400.0 * KB] {
            let qc = cand(&q, part, 10.0 * KB, 4);
            let jp = plan_joint(std::slice::from_ref(&qc), &model, &single_topo());
            let qc2 = cand(&q, part, 10.0 * KB, 4);
            let fixed =
                predict_fixed(std::slice::from_ref(&qc2), &jp.plans, &model, &single_topo());
            assert!((fixed.makespan - jp.predicted.makespan).abs() < 1e-12);
            assert_eq!(fixed.completions.len(), 1);
            assert_eq!(fixed.timeline, jp.predicted.timeline);
            assert_eq!(fixed.order, vec![0]);
        }
    }

    #[test]
    fn executor_horizons_cover_every_predicted_slot() {
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        let topo = DeviceTopology::from_cluster(&crate::cluster::ClusterSpec::of(2));
        let cands =
            vec![cand(&q1, 60.0 * KB, 8.0 * KB, 4), cand(&q2, 60.0 * KB, 8.0 * KB, 4)];
        let jp = plan_joint(&cands, &model, &topo);
        let h = executor_horizons(&jp.predicted, topo.num_executors());
        assert_eq!(h.len(), topo.num_executors());
        for s in &jp.predicted.timeline {
            assert!(s.end <= h[s.exec] + 1e-12, "slot {s:?} past horizon {h:?}");
        }
        for (e, &he) in h.iter().enumerate() {
            let booked = jp.predicted.timeline.iter().any(|s| s.exec == e);
            assert_eq!(he > 0.0, booked, "horizon {he} vs booked={booked} on {e}");
            assert!(he <= jp.predicted.makespan + 1e-9);
        }
    }

    #[test]
    fn contended_queries_beat_serialized_independent_plans() {
        // Two GPU-leaning queries on one GPU: independent plans
        // serialize back-to-back; the joint plan keeps one query on the
        // device and runs the other where it does not have to queue.
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        // ~50 KB per-partition (600 KB batch): GPU is faster but the CPU
        // is competitive — the regime where rationing pays.
        let cands = vec![cand(&q1, 50.0 * KB, 10.0 * KB, 4), cand(&q2, 50.0 * KB, 10.0 * KB, 4)];
        // Sanity: the per-query planner wants the GPU for both.
        assert!(cands[0].independent.gpu_ops() > 0);
        assert!(cands[1].independent.gpu_ops() > 0);
        let jp = plan_joint(&cands, &model, &single_topo());
        let p = &jp.predicted;
        assert!(
            p.makespan < p.independent_shared_makespan - 1e-9,
            "joint {} !< independent-serialized {}",
            p.makespan,
            p.independent_shared_makespan
        );
        // And the independent plans' own predictions under-estimate what
        // they actually cost on the shared device.
        let ind_max = p.independent.iter().copied().fold(0.0, f64::max);
        assert!(
            p.independent_shared_makespan > ind_max + 1e-9,
            "no double-booking detected: {} vs {}",
            p.independent_shared_makespan,
            ind_max
        );
    }

    #[test]
    fn two_executor_topology_halves_gpu_pressure() {
        // The same contended pair over a 2-executor topology: each
        // executor carries half the rows on its own GPU, so the
        // independent plans' shared-timeline makespan shrinks vs the
        // single shared device (the one-device model over-predicts
        // cluster contention — the mis-prediction the topology-aware
        // scheduler removes).
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        let mk = |topo: &DeviceTopology| {
            let cands =
                vec![cand(&q1, 50.0 * KB, 10.0 * KB, 4), cand(&q2, 50.0 * KB, 10.0 * KB, 4)];
            plan_joint(&cands, &model, topo).predicted.independent_shared_makespan
        };
        let one = mk(&single_topo());
        let two = mk(&DeviceTopology::from_cluster(&crate::cluster::ClusterSpec::of(2)));
        assert!(two < one, "2-executor {two} !< 1-executor {one}");
    }

    #[test]
    fn fully_degraded_topology_plans_cpu_only() {
        // Every executor's GPU has faulted: no segment may be booked and
        // the chosen makespan must collapse to the all-CPU makespan,
        // while the ordering bounds still hold.
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        for n in [1usize, 2] {
            let mut topo = if n == 1 {
                single_topo()
            } else {
                DeviceTopology::from_cluster(&crate::cluster::ClusterSpec::of(n))
            };
            for e in 0..topo.num_executors() {
                topo.degrade_gpu(e);
            }
            let cands =
                vec![cand(&q1, 50.0 * KB, 10.0 * KB, 4), cand(&q2, 50.0 * KB, 10.0 * KB, 4)];
            let jp = plan_joint(&cands, &model, &topo);
            let p = &jp.predicted;
            assert!(p.timeline.is_empty(), "degraded topology booked GPU slots: {p:?}");
            assert_eq!(p.gpu_busy, 0.0);
            assert!((p.makespan - p.all_cpu_makespan).abs() < 1e-9, "{p:?}");
            assert!(p.makespan <= p.fifo_makespan + 1e-9, "{p:?}");
            assert!(p.fifo_makespan <= p.independent.iter().sum::<f64>() + 1e-6, "{p:?}");
        }
    }

    #[test]
    fn partially_degraded_topology_books_only_healthy_executors() {
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let model = DeviceModel::default();
        let mut topo = DeviceTopology::from_cluster(&crate::cluster::ClusterSpec::of(2));
        topo.degrade_gpu(0);
        let cands =
            vec![cand(&q1, 50.0 * KB, 10.0 * KB, 4), cand(&q2, 50.0 * KB, 10.0 * KB, 4)];
        let jp = plan_joint(&cands, &model, &topo);
        let p = &jp.predicted;
        assert!(p.timeline.iter().all(|s| s.exec == 1), "booked the faulted device: {p:?}");
        // Makespan ordering survives degradation.
        assert!(p.makespan <= p.fifo_makespan + 1e-9, "{p:?}");
        assert!(p.fifo_makespan <= p.independent.iter().sum::<f64>() + 1e-6, "{p:?}");
        assert!(p.makespan <= p.all_cpu_makespan + 1e-6, "{p:?}");
    }

    /// Queries with long post-device CPU tails (sort is not fusable and
    /// CPU-leaning at small sizes) exercise the longest-tail-last
    /// generator's regime.
    fn tail_query(name: &str) -> Query {
        QueryBuilder::scan(name)
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .filter("v", Predicate::Ge(0.0))
            .select(&["v"])
            .sort("v", false)
            .build()
            .unwrap()
    }

    #[test]
    fn order_is_a_permutation_and_bounds_hold() {
        let q1 = chain_query("a");
        let q2 = chain_query("b");
        let q3 = chain_query("c");
        let q4 = tail_query("d");
        let model = DeviceModel::default();
        for part in [10.0 * KB, 50.0 * KB, 200.0 * KB] {
            let cands = vec![
                cand(&q1, part, 10.0 * KB, 4),
                cand(&q2, 2.0 * part, 10.0 * KB, 4),
                cand(&q3, 0.5 * part, 10.0 * KB, 4),
                cand(&q4, 1.5 * part, 10.0 * KB, 4),
            ];
            let p = plan_joint(&cands, &model, &single_topo()).predicted;
            let mut sorted = p.order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "not a permutation: {:?}", p.order);
            assert!(p.makespan <= p.fifo_makespan + 1e-9, "{p:?}");
            assert!(p.fifo_makespan <= p.independent.iter().sum::<f64>() + 1e-6, "{p:?}");
            assert!(p.makespan <= p.all_cpu_makespan + 1e-6, "{p:?}");
            assert_eq!(p.completions.len(), 4);
            // Every completion is reachable within the makespan.
            for c in &p.completions {
                assert!(*c <= p.makespan + 1e-12);
            }
        }
    }

    #[test]
    fn longest_tail_last_grants_long_tails_first() {
        let mk = |tail: f64| {
            vec![Chain { segments: vec![(0.1, 1.0, 0), (tail, 0.0, usize::MAX)] }]
        };
        let chains = vec![mk(0.1), mk(5.0), mk(2.0)];
        assert_eq!(longest_tail_last_order(&chains), vec![1, 2, 0]);
        // Ties keep registration order.
        let tied = vec![mk(1.0), mk(1.0)];
        assert_eq!(longest_tail_last_order(&tied), vec![0, 1]);
    }

    #[test]
    fn fused_chain_merges_into_one_reservation() {
        // scan→filter→select is one structural run: under an all-GPU
        // assignment the chain books ONE device reservation carrying the
        // members' combined flow (entering staging at the head, leaving
        // transfer at the tail); a device switch mid-run splits it.
        let q = chain_query("f");
        let model = DeviceModel::default();
        let qc = cand(&q, 50.0 * KB, 10.0 * KB, 4);
        let ctx = chain_ctx(&qc, &model, &single_topo());
        let bf = model.batch_fixed.as_secs_f64();
        let c = chain(&ctx, 0, &vec![Device::Gpu; q.len()], bf);
        assert_eq!(c.segments.len(), 2, "merged reservation + CPU tail");
        let (cpu_before, busy, head) = c.segments[0];
        assert_eq!(head, 0);
        assert!((cpu_before - bf).abs() < 1e-12);
        let s = &ctx.secs[0];
        let expected = s[0].gpu + s[1].gpu + s[2].gpu
            + s[0].coalesce
            + s[0].trans_in
            + s[2].trans_out;
        assert!((busy - expected).abs() < 1e-12, "{busy} vs {expected}");
        let mixed = vec![Device::Gpu, Device::Cpu, Device::Gpu];
        let c2 = chain(&ctx, 0, &mixed, bf);
        assert_eq!(c2.segments.len(), 3, "device switch splits the run");
    }
}
