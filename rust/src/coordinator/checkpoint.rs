//! Checkpointing and state flushing.
//!
//! §III-E: "After the query execution completion, stream processing needs
//! to run additional tasks such as check-pointing and state flushing.
//! Since the optimization process is performed during this period ... it
//! rarely blocks real-time streaming applications." This module is that
//! substrate: after each batch the driver can persist the coordinator's
//! recoverable state (window contents metadata, metrics history, the
//! optimizer's inflection point and history) and recover from it on
//! restart.
//!
//! Format: a single JSON document (the in-repo writer; serde is
//! unavailable offline), atomically replaced via write-to-temp + rename.

use crate::coordinator::optimizer::HistoryPoint;
use crate::error::{Error, Result};
use crate::sim::Time;
use crate::util::json::{arr, num, obj, s, Json};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Per-query recoverable metric state. Checkpoints are keyed per source
/// by its *primary* query's name, but a source can carry any number of
/// co-registered queries — their Eq. 3/4 running state is persisted here
/// so secondary-query metrics survive recovery too.
#[derive(Clone, Debug, PartialEq)]
pub struct QueryMetricState {
    pub name: String,
    pub batches: usize,
    pub cumulative_bytes: f64,
    pub cumulative_proc_secs: f64,
    pub max_lat_sum_secs: f64,
}

/// Recoverable coordinator state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Workload the state belongs to (mismatched recovery is rejected).
    pub workload: String,
    /// Batches executed so far.
    pub batches: usize,
    /// Stream position: everything created at or before this is processed.
    pub processed_up_to: Time,
    /// Current inflection point (bytes).
    pub inf_pt: f64,
    /// Eq. 4 cumulative state (primary query; kept for compatibility —
    /// `queries` carries the authoritative per-query states).
    pub cumulative_bytes: f64,
    pub cumulative_proc_secs: f64,
    /// Eq. 3 running state (primary query).
    pub max_lat_sum_secs: f64,
    /// Per-query metric states for every query registered on the source
    /// (primary included). Empty when loading a pre-multi-query file;
    /// recovery then falls back to the legacy primary-only fields.
    pub queries: Vec<QueryMetricState>,
    /// Optimizer history.
    pub history: Vec<HistoryPoint>,
    /// Highest WAL sequence number this checkpoint covers (format ≥ 2;
    /// 0 for legacy files and WAL-less runs — every logged record is
    /// then part of the recovery tail).
    pub wal_high_water: u64,
    /// Scheduling round counter at checkpoint time (format ≥ 2; the
    /// resumed session continues numbering from here so WAL-logged
    /// rounds stay unique across incarnations).
    pub round_high_water: usize,
}

impl Checkpoint {
    fn to_json(&self) -> Json {
        obj(vec![
            // Format 2 = format 1 + WAL position / round high-water
            // (absent fields read back as 0 under the same loader).
            ("format", num(2.0)),
            ("workload", s(&self.workload)),
            ("wal_high_water", num(self.wal_high_water as f64)),
            ("round_high_water", num(self.round_high_water as f64)),
            ("batches", num(self.batches as f64)),
            ("processed_up_to_ns", num(self.processed_up_to.0 as f64)),
            ("inf_pt", num(self.inf_pt)),
            ("cumulative_bytes", num(self.cumulative_bytes)),
            ("cumulative_proc_secs", num(self.cumulative_proc_secs)),
            ("max_lat_sum_secs", num(self.max_lat_sum_secs)),
            (
                "queries",
                arr(self
                    .queries
                    .iter()
                    .map(|q| {
                        obj(vec![
                            ("name", s(&q.name)),
                            ("batches", num(q.batches as f64)),
                            ("bytes", num(q.cumulative_bytes)),
                            ("proc", num(q.cumulative_proc_secs)),
                            ("maxlat", num(q.max_lat_sum_secs)),
                        ])
                    })
                    .collect()),
            ),
            (
                "history",
                arr(self
                    .history
                    .iter()
                    .map(|h| {
                        obj(vec![
                            ("t", num(h.throughput)),
                            ("l", num(h.max_latency)),
                            ("i", num(h.inf_pt)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<Checkpoint> {
        let format = j.req("format")?.as_usize().unwrap_or(0);
        if !(1..=2).contains(&format) {
            return Err(Error::Json(format!("unsupported checkpoint format {format}")));
        }
        let history = j
            .req("history")?
            .as_arr()
            .ok_or_else(|| Error::Json("history not array".into()))?
            .iter()
            .map(|h| {
                Ok(HistoryPoint {
                    throughput: h.req("t")?.as_f64().unwrap_or(0.0),
                    max_latency: h.req("l")?.as_f64().unwrap_or(0.0),
                    inf_pt: h.req("i")?.as_f64().unwrap_or(0.0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // Optional: absent in files written before multi-query metric
        // persistence (recovery then uses the legacy primary fields).
        let queries = match j.get("queries").and_then(|q| q.as_arr()) {
            None => Vec::new(),
            Some(items) => items
                .iter()
                .map(|q| {
                    Ok(QueryMetricState {
                        name: q.req("name")?.as_str().unwrap_or("").to_string(),
                        batches: q.req("batches")?.as_usize().unwrap_or(0),
                        cumulative_bytes: q.req("bytes")?.as_f64().unwrap_or(0.0),
                        cumulative_proc_secs: q.req("proc")?.as_f64().unwrap_or(0.0),
                        max_lat_sum_secs: q.req("maxlat")?.as_f64().unwrap_or(0.0),
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(Checkpoint {
            workload: j.req("workload")?.as_str().unwrap_or("").to_string(),
            batches: j.req("batches")?.as_usize().unwrap_or(0),
            processed_up_to: Time(j.req("processed_up_to_ns")?.as_f64().unwrap_or(0.0) as u64),
            inf_pt: j.req("inf_pt")?.as_f64().unwrap_or(0.0),
            cumulative_bytes: j.req("cumulative_bytes")?.as_f64().unwrap_or(0.0),
            cumulative_proc_secs: j.req("cumulative_proc_secs")?.as_f64().unwrap_or(0.0),
            max_lat_sum_secs: j.req("max_lat_sum_secs")?.as_f64().unwrap_or(0.0),
            queries,
            history,
            // Format-1 files predate the WAL: high-water 0 means "the
            // whole log is tail", round numbering restarts — exactly the
            // legacy primary-only recovery semantics.
            wal_high_water: j
                .get("wal_high_water")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0) as u64,
            round_high_water: j
                .get("round_high_water")
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
        })
    }

    /// Derived: Eq. 4 average throughput at checkpoint time.
    pub fn avg_throughput(&self) -> f64 {
        if self.cumulative_proc_secs <= 0.0 {
            0.0
        } else {
            self.cumulative_bytes / self.cumulative_proc_secs
        }
    }

    /// Derived: Eq. 3 running average of max latencies.
    pub fn past_max_lat_avg(&self) -> Option<Duration> {
        if self.batches == 0 {
            None
        } else {
            Some(Duration::from_secs_f64(
                self.max_lat_sum_secs / self.batches as f64,
            ))
        }
    }
}

/// Durable checkpoint store (one file per workload).
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    pub fn new(dir: &Path) -> Result<CheckpointStore> {
        std::fs::create_dir_all(dir)?;
        Ok(CheckpointStore { dir: dir.to_path_buf() })
    }

    fn path_for(&self, workload: &str) -> PathBuf {
        self.dir.join(format!("{}.ckpt.json", workload.to_lowercase()))
    }

    /// Durably and atomically persist.
    ///
    /// Ordering invariant: write temp → fsync temp → rename → fsync
    /// parent dir. The temp fsync guarantees the *contents* are on disk
    /// before the rename can make them visible (else a crash after the
    /// rename journals can surface an empty/partial checkpoint); the
    /// directory fsync guarantees the rename itself survives. The WAL
    /// is only truncated after this returns, so a checkpoint that
    /// didn't make it durable leaves the log covering its batches.
    pub fn save(&self, ckpt: &Checkpoint) -> Result<()> {
        let path = self.path_for(&ckpt.workload);
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            use std::io::Write as _;
            f.write_all(ckpt.to_json().render().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &path)?;
        crate::durability::wal::sync_parent_dir(&path)?;
        Ok(())
    }

    /// Load the latest checkpoint for `workload`; `Ok(None)` if absent.
    pub fn load(&self, workload: &str) -> Result<Option<Checkpoint>> {
        let path = self.path_for(workload);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        let j = Json::parse(&text)?;
        let ckpt = Checkpoint::from_json(&j)?;
        if !ckpt.workload.eq_ignore_ascii_case(workload) {
            return Err(Error::Config(format!(
                "checkpoint belongs to `{}`, not `{workload}`",
                ckpt.workload
            )));
        }
        Ok(Some(ckpt))
    }

    /// Remove a workload's checkpoint.
    pub fn clear(&self, workload: &str) -> Result<()> {
        let path = self.path_for(workload);
        match std::fs::remove_file(path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Checkpoint {
        Checkpoint {
            workload: "LR1S".into(),
            batches: 42,
            processed_up_to: Time::from_secs_f64(123.5),
            inf_pt: 140_000.0,
            cumulative_bytes: 5e6,
            cumulative_proc_secs: 100.0,
            max_lat_sum_secs: 210.0,
            queries: vec![
                QueryMetricState {
                    name: "LR1S".into(),
                    batches: 42,
                    cumulative_bytes: 5e6,
                    cumulative_proc_secs: 100.0,
                    max_lat_sum_secs: 210.0,
                },
                QueryMetricState {
                    name: "side".into(),
                    batches: 42,
                    cumulative_bytes: 5e6,
                    cumulative_proc_secs: 80.0,
                    max_lat_sum_secs: 150.0,
                },
            ],
            history: vec![
                HistoryPoint { throughput: 3e4, max_latency: 5.0, inf_pt: 1.5e5 },
                HistoryPoint { throughput: 3.2e4, max_latency: 4.5, inf_pt: 1.4e5 },
            ],
            wal_high_water: 42,
            round_high_water: 17,
        }
    }

    fn store(name: &str) -> CheckpointStore {
        let d = std::env::temp_dir().join(format!("lmstream-ckpt-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        CheckpointStore::new(&d).unwrap()
    }

    #[test]
    fn save_load_round_trip() {
        let st = store("roundtrip");
        let c = demo();
        st.save(&c).unwrap();
        let loaded = st.load("lr1s").unwrap().unwrap();
        assert_eq!(loaded.batches, c.batches);
        assert_eq!(loaded.processed_up_to, c.processed_up_to);
        assert_eq!(loaded.inf_pt, c.inf_pt);
        assert_eq!(loaded.history.len(), 2);
        assert_eq!(loaded.history[1].max_latency, 4.5);
        // Per-query states (secondary-query metrics) round trip.
        assert_eq!(loaded.queries, c.queries);
        assert_eq!(loaded.queries[1].name, "side");
        assert_eq!(loaded.queries[1].cumulative_proc_secs, 80.0);
        // Format-2 durability fields round trip.
        assert_eq!(loaded.wal_high_water, 42);
        assert_eq!(loaded.round_high_water, 17);
    }

    #[test]
    fn format1_file_loads_with_zero_wal_position() {
        // A pre-durability (format-1) file has neither wal_high_water
        // nor round_high_water; it must still load, with both at 0 (the
        // whole WAL — if any — is recovery tail, rounds renumber).
        let st = store("format1");
        st.save(&demo()).unwrap();
        let path = st.path_for("lr1s");
        let text = std::fs::read_to_string(&path).unwrap();
        let legacy = text
            .replace("\"format\":2,", "\"format\":1,")
            .replace("\"wal_high_water\":42,", "")
            .replace("\"round_high_water\":17,", "");
        assert_ne!(text, legacy, "fixture must strip the format-2 fields");
        std::fs::write(&path, legacy).unwrap();
        let loaded = st.load("lr1s").unwrap().unwrap();
        assert_eq!(loaded.wal_high_water, 0);
        assert_eq!(loaded.round_high_water, 0);
        assert_eq!(loaded.batches, demo().batches);
    }

    #[test]
    fn future_format_rejected() {
        let st = store("future");
        st.save(&demo()).unwrap();
        let path = st.path_for("lr1s");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"format\":2,", "\"format\":3,")).unwrap();
        assert!(matches!(st.load("lr1s"), Err(Error::Json(_))));
    }

    #[test]
    fn legacy_checkpoint_without_queries_loads() {
        // A pre-multi-query file has no `queries` array; loading must
        // succeed with an empty vec (recovery falls back to the legacy
        // primary-only fields).
        let st = store("legacy");
        let mut c = demo();
        c.queries.clear();
        st.save(&c).unwrap();
        let text = std::fs::read_to_string(st.path_for("lr1s")).unwrap();
        let stripped = text.replace(
            "\"queries\":[],",
            "",
        );
        assert_ne!(text, stripped, "fixture must drop the queries field");
        std::fs::write(st.path_for("lr1s"), stripped).unwrap();
        let loaded = st.load("lr1s").unwrap().unwrap();
        assert!(loaded.queries.is_empty());
        assert_eq!(loaded.batches, c.batches);
    }

    #[test]
    fn derived_metrics_survive() {
        let st = store("derived");
        st.save(&demo()).unwrap();
        let loaded = st.load("LR1S").unwrap().unwrap();
        assert_eq!(loaded.avg_throughput(), 5e4);
        assert_eq!(loaded.past_max_lat_avg().unwrap(), Duration::from_secs_f64(5.0));
    }

    #[test]
    fn absent_checkpoint_is_none() {
        let st = store("absent");
        assert!(st.load("cm1s").unwrap().is_none());
    }

    #[test]
    fn workload_mismatch_rejected() {
        let st = store("mismatch");
        let mut c = demo();
        st.save(&c).unwrap();
        // Forge: rename the file to another workload.
        c.workload = "CM1S".into();
        let from = st.path_for("lr1s");
        let to = st.path_for("cm1s");
        std::fs::copy(from, to).unwrap();
        assert!(st.load("cm1s").is_err());
    }

    #[test]
    fn clear_removes() {
        let st = store("clear");
        st.save(&demo()).unwrap();
        st.clear("lr1s").unwrap();
        assert!(st.load("lr1s").unwrap().is_none());
        st.clear("lr1s").unwrap(); // idempotent
    }

    #[test]
    fn corrupt_file_is_json_error() {
        let st = store("corrupt");
        std::fs::write(st.path_for("lr1s"), "not json").unwrap();
        assert!(st.load("lr1s").is_err());
    }
}
