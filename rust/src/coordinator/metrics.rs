//! Metrics bookkeeping: Eqs. 4/5, per-dataset end-to-end latency, and the
//! Table IV phase-time accounting.

use crate::sim::Time;
use std::time::Duration;

/// Record of one executed micro-batch.
#[derive(Clone, Debug)]
pub struct BatchRecord {
    /// Micro-batch index `i`.
    pub index: usize,
    /// Session-wide scheduling round this batch executed in (monotone,
    /// 1-based). Records sharing a `round` — across queries *and*
    /// sources — were co-scheduled on the same per-executor device
    /// timelines, so their `proc`s embed one contended round makespan.
    pub round: usize,
    /// Admission time.
    pub admitted_at: Time,
    /// `NumDS_i`.
    pub num_datasets: usize,
    /// Σ_j Part_(i,j) (bytes).
    pub bytes: usize,
    /// max_j Buff_(i,j).
    pub max_buffering: Duration,
    /// `Proc_i` (includes any shared-GPU contention wait).
    pub proc: Duration,
    /// Share of `proc` spent waiting on the shared GPU timeline while
    /// other queries of the same micro-batch round held the device
    /// (zero for single-query rounds). Observability for cross-query
    /// co-scheduling; already included in `proc`.
    pub gpu_wait: Duration,
    /// `MaxLat_i` (Eq. 5).
    pub max_latency: Duration,
    /// Inflection point used (bytes).
    pub inf_pt: f64,
    /// GPU-mapped ops in the plan.
    pub gpu_ops: usize,
    /// Total ops in the plan.
    pub total_ops: usize,
    /// Time spent inside ConstructMicroBatch for this batch (admission
    /// decision work, including canceled rounds since the previous batch).
    pub construct_time: Duration,
    /// Time spent inside MapDevice.
    pub map_device_time: Duration,
    /// Wait on the async optimizer before planning (Table IV
    /// "Optimization Blocking").
    pub opt_blocking: Duration,
    /// Failed execution attempts this batch's round survived before
    /// completing (executor crashes/stalls recovered by re-planning on
    /// the surviving topology).
    pub retries: usize,
    /// Failure-detection + retry-backoff time the round charged;
    /// already included in `proc`, so Eq. 10 and admission learn the
    /// true degraded-round latency (mirrors `gpu_wait`'s convention).
    pub recovery_wait: Duration,
    /// The round executed on a degraded topology: a crashed executor
    /// missing, a GPU-faulted executor running CPU-only, or a
    /// probationary rejoin in flight.
    pub degraded: bool,
    /// Rows from this batch's source that arrived behind the watermark
    /// (late beyond the allowed lateness) since the previous batch —
    /// dropped, side-output or recomputed per `Config::late_policy`.
    /// Always 0 when event-time processing is off.
    pub late_rows: usize,
    /// How far the processing clock led the source's low-watermark at
    /// admission (`admitted_at` − watermark): the event-time lag this
    /// batch's window logic operated under. Zero when no event has been
    /// seen yet.
    pub watermark_lag: Duration,
    /// Window-state footprint across this query's state at admission,
    /// as if every chunk were held plain (decoded) — the denominator of
    /// the encoded-state ratio. Zero for stateless queries.
    pub state_bytes_raw: usize,
    /// Actual resident window-state footprint: hot chunks plain + cold
    /// chunks at their RLE/dict/delta-encoded size
    /// (`engine::encode`). `state_bytes_encoded ≤ state_bytes_raw`;
    /// equality means nothing was cold (or nothing compressed).
    pub state_bytes_encoded: usize,
    /// Chunks the round's fused chains skipped outright because
    /// per-block min/max stats proved their filter predicates
    /// unsatisfiable. Zero when fusion is off or nothing pruned.
    pub pruned_chunks: usize,
    /// Shard (source group) that staged, planned and executed this
    /// batch under the sharded session runtime (`Config::shards`):
    /// `source_index % shards`. Always 0 on the serial round loop.
    pub shard: usize,
}

/// Per-executor fault counters accumulated over a run (populated by
/// [`ExecutorHealth`](crate::cluster::ExecutorHealth)).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ExecutorHealthStats {
    /// Physical executor id.
    pub executor: usize,
    pub crashes: usize,
    pub gpu_faults: usize,
    pub stalls: usize,
    pub rejoins: usize,
    /// Final health state name (`up`, `gpu-degraded`, `down`,
    /// `probation`).
    pub state: String,
}

/// Per-shard fairness accounting under the sharded session runtime:
/// how much of the session's admitted work each source group carried,
/// and how often its quota pushed back.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardStats {
    /// Shard id (`source_index % Config::shards`).
    pub shard: usize,
    /// Sources assigned to this shard.
    pub sources: usize,
    /// Round epochs in which this shard admitted at least one batch.
    pub rounds: usize,
    /// Batches (query executions) this shard delivered.
    pub batches: usize,
    /// Admitted bytes across this shard's sources.
    pub bytes: usize,
    /// Summed processing time of this shard's batches.
    pub proc: Duration,
    /// Failed attempts this shard's sources retried.
    pub retries: usize,
    /// Admissions vetoed (re-buffered) by this shard's
    /// `Config::shard_quotas` rate limit.
    pub quota_vetoes: usize,
}

/// Run-wide fault-tolerance accounting: what failed, what it cost, and
/// where every executor ended up.
#[derive(Clone, Debug, Default)]
pub struct HealthReport {
    pub executors: Vec<ExecutorHealthStats>,
    /// Failed attempts retried across the run.
    pub retries: usize,
    /// Total detection + backoff time charged to round clocks.
    pub recovery_wait: Duration,
    /// Rounds that executed on a degraded topology.
    pub degraded_rounds: usize,
    /// Per-shard fairness accounting (`Config::shards`); empty on the
    /// serial round loop.
    pub shards: Vec<ShardStats>,
}

/// Aggregate phase times over a run (Table IV rows).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTotals {
    pub buffering: Duration,
    pub construct: Duration,
    pub map_device: Duration,
    pub processing: Duration,
    pub opt_blocking: Duration,
}

impl PhaseTotals {
    pub fn total(&self) -> Duration {
        self.buffering + self.construct + self.map_device + self.processing + self.opt_blocking
    }

    /// Percentage rows of Table IV.
    pub fn ratios(&self) -> [(&'static str, f64); 5] {
        let t = self.total().as_secs_f64().max(f64::MIN_POSITIVE);
        [
            ("Buffering Phase", self.buffering.as_secs_f64() / t * 100.0),
            ("Construct Micro-batch", self.construct.as_secs_f64() / t * 100.0),
            ("Map Device", self.map_device.as_secs_f64() / t * 100.0),
            ("Processing Phase", self.processing.as_secs_f64() / t * 100.0),
            ("Optimization Blocking", self.opt_blocking.as_secs_f64() / t * 100.0),
        ]
    }
}

/// Run-wide metrics accumulator.
#[derive(Debug, Default)]
pub struct Metrics {
    records: Vec<BatchRecord>,
    /// Per-dataset end-to-end latency (buffering + its batch's proc), s.
    dataset_latencies: Vec<f64>,
    /// Batches accounted by a restored checkpoint (their records are
    /// gone, but they still weight Eq. 3/4's running state).
    restored_batches: usize,
    cumulative_bytes: f64,
    cumulative_proc: f64,
    max_lat_sum: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Seed the cumulative Eq. 3/4 state from a recovered checkpoint:
    /// the restored batches keep weighting `avg_throughput` /
    /// `past_max_lat_avg` (and offset batch indices), while their
    /// per-batch records are not resurrected.
    pub fn restore(
        &mut self,
        batches: usize,
        cumulative_bytes: f64,
        cumulative_proc_secs: f64,
        max_lat_sum_secs: f64,
    ) {
        self.restored_batches = batches;
        self.cumulative_bytes = cumulative_bytes;
        self.cumulative_proc = cumulative_proc_secs;
        self.max_lat_sum = max_lat_sum_secs;
    }

    /// Record one executed batch. `dataset_buffs` are the per-dataset
    /// buffering times of the batch (admission - creation).
    pub fn record(&mut self, mut rec: BatchRecord, dataset_buffs: &[Duration]) {
        let max_buff = dataset_buffs.iter().max().copied().unwrap_or(Duration::ZERO);
        rec.max_buffering = max_buff;
        rec.max_latency = max_buff + rec.proc; // Eq. 5
        self.cumulative_bytes += rec.bytes as f64;
        self.cumulative_proc += rec.proc.as_secs_f64();
        self.max_lat_sum += rec.max_latency.as_secs_f64();
        for b in dataset_buffs {
            self.dataset_latencies
                .push(b.as_secs_f64() + rec.proc.as_secs_f64());
        }
        self.records.push(rec);
    }

    /// Raw Eq. 4 numerator (bytes processed so far).
    pub fn cumulative_bytes(&self) -> f64 {
        self.cumulative_bytes
    }

    /// Raw Eq. 4 denominator (processing seconds so far).
    pub fn cumulative_proc_secs(&self) -> f64 {
        self.cumulative_proc
    }

    /// Raw Eq. 3 numerator (sum of per-batch max latencies, seconds).
    pub fn max_lat_sum_secs(&self) -> f64 {
        self.max_lat_sum
    }

    /// Eq. 4: cumulative bytes / cumulative processing time (bytes/s).
    pub fn avg_throughput(&self) -> f64 {
        if self.cumulative_proc <= 0.0 {
            0.0
        } else {
            self.cumulative_bytes / self.cumulative_proc
        }
    }

    /// Eq. 3 RHS: running average of past `MaxLat_k` (None before the
    /// first batch — restored batches count).
    pub fn past_max_lat_avg(&self) -> Option<Duration> {
        let n = self.batches();
        if n == 0 {
            None
        } else {
            Some(Duration::from_secs_f64(self.max_lat_sum / n as f64))
        }
    }

    /// Mean per-dataset end-to-end latency (Fig. 6's metric), seconds.
    pub fn avg_dataset_latency(&self) -> f64 {
        crate::util::stats::mean(&self.dataset_latencies)
    }

    pub fn dataset_latencies(&self) -> &[f64] {
        &self.dataset_latencies
    }

    pub fn records(&self) -> &[BatchRecord] {
        &self.records
    }

    /// Total batches accounted: restored (checkpoint) + this run's.
    pub fn batches(&self) -> usize {
        self.restored_batches + self.records.len()
    }

    /// Table IV totals. Buffering per batch = max dataset buffering (the
    /// window in which the batch's data sat waiting).
    pub fn phase_totals(&self) -> PhaseTotals {
        let mut t = PhaseTotals::default();
        for r in &self.records {
            t.buffering += r.max_buffering;
            t.construct += r.construct_time;
            t.map_device += r.map_device_time;
            t.processing += r.proc;
            t.opt_blocking += r.opt_blocking;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(index: usize, bytes: usize, proc_s: f64) -> BatchRecord {
        BatchRecord {
            index,
            round: index + 1,
            admitted_at: Time::ZERO,
            num_datasets: 1,
            bytes,
            max_buffering: Duration::ZERO,
            proc: Duration::from_secs_f64(proc_s),
            gpu_wait: Duration::ZERO,
            max_latency: Duration::ZERO,
            inf_pt: 150.0 * 1024.0,
            gpu_ops: 0,
            total_ops: 3,
            construct_time: Duration::from_micros(10),
            map_device_time: Duration::from_micros(5),
            opt_blocking: Duration::ZERO,
            retries: 0,
            recovery_wait: Duration::ZERO,
            degraded: false,
            late_rows: 0,
            watermark_lag: Duration::ZERO,
            state_bytes_raw: 0,
            state_bytes_encoded: 0,
            pruned_chunks: 0,
            shard: 0,
        }
    }

    #[test]
    fn eq4_throughput() {
        let mut m = Metrics::new();
        m.record(rec(0, 1000, 1.0), &[Duration::from_secs(1)]);
        m.record(rec(1, 3000, 1.0), &[Duration::from_secs(2)]);
        assert_eq!(m.avg_throughput(), 2000.0);
    }

    #[test]
    fn eq5_max_latency_is_buffering_plus_proc() {
        let mut m = Metrics::new();
        m.record(
            rec(0, 100, 2.0),
            &[Duration::from_secs(1), Duration::from_secs(3)],
        );
        assert_eq!(m.records()[0].max_latency, Duration::from_secs(5));
        assert_eq!(m.records()[0].max_buffering, Duration::from_secs(3));
    }

    #[test]
    fn running_average_of_max_latencies() {
        let mut m = Metrics::new();
        assert!(m.past_max_lat_avg().is_none());
        m.record(rec(0, 1, 2.0), &[Duration::ZERO]);
        m.record(rec(1, 1, 4.0), &[Duration::ZERO]);
        assert_eq!(m.past_max_lat_avg().unwrap(), Duration::from_secs(3));
    }

    #[test]
    fn dataset_latencies_tracked_per_dataset() {
        let mut m = Metrics::new();
        m.record(
            rec(0, 1, 1.0),
            &[Duration::from_secs(0), Duration::from_secs(2)],
        );
        assert_eq!(m.dataset_latencies(), &[1.0, 3.0]);
        assert_eq!(m.avg_dataset_latency(), 2.0);
    }

    #[test]
    fn restore_seeds_cumulative_state() {
        let mut m = Metrics::new();
        m.restore(10, 20_000.0, 10.0, 30.0);
        // Eq. 4/3 derive from the restored state before any new batch.
        assert_eq!(m.batches(), 10);
        assert_eq!(m.avg_throughput(), 2000.0);
        assert_eq!(m.past_max_lat_avg().unwrap(), Duration::from_secs(3));
        assert!(m.records().is_empty(), "restored batches have no records");
        // New batches blend into the restored running state.
        m.record(rec(10, 2000, 1.0), &[Duration::from_secs(1)]);
        assert_eq!(m.batches(), 11);
        assert_eq!(m.avg_throughput(), 22_000.0 / 11.0);
    }

    #[test]
    fn phase_ratios_sum_to_hundred() {
        let mut m = Metrics::new();
        m.record(rec(0, 1, 1.0), &[Duration::from_secs(1)]);
        let ratios = m.phase_totals().ratios();
        let sum: f64 = ratios.iter().map(|(_, v)| v).sum();
        assert!((sum - 100.0).abs() < 1e-9);
    }
}
