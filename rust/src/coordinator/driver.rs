//! Single-query compatibility shims over [`crate::session::Session`].
//!
//! The micro-batch main loop (Fig. 3's execution flow) lives in
//! [`crate::session`]: a `Session` owns the shared coordinator state and
//! multiplexes any number of registered queries per loop iteration. The
//! free functions here are **deprecated thin wrappers** kept so the
//! figure benches, tests and existing examples — all single-query —
//! keep working unchanged: each call builds a one-shot session,
//! registers the workload, and runs it.
//!
//! New code should construct a [`Session`] directly:
//!
//! ```no_run
//! # // no_run: doctest binaries miss the xla rpath in this offline image
//! use lmstream::config::Config;
//! use lmstream::session::Session;
//! use lmstream::workloads;
//! use std::time::Duration;
//!
//! # fn main() -> lmstream::Result<()> {
//! let mut session = Session::new(Config::default())?;
//! session.register(workloads::by_name("lr1s")?)?;
//! let results = session.run(Duration::from_secs(120))?;
//! # Ok(())
//! # }
//! ```

use crate::config::Config;
use crate::engine::sink::{NullSink, Sink};
use crate::error::Result;
use crate::runtime::client::Runtime;
use crate::session::Session;
use crate::workloads::Workload;
use std::time::Duration;

pub use crate::session::RunResult;

/// Run `workload` under `cfg` for `duration` (simulated or wall time).
/// `runtime` is required only for the Real backend.
///
/// Deprecated shim: prefer [`Session::register`] + [`Session::run`].
pub fn run(
    workload: &Workload,
    cfg: &Config,
    duration: Duration,
    runtime: Option<&Runtime>,
) -> Result<RunResult> {
    run_with_sink(workload, cfg, duration, runtime, &mut NullSink)
}

/// Run with results delivered to `sink` (the output stream).
///
/// Deprecated shim: prefer [`Session::register`] +
/// [`Session::run_with_sink`].
pub fn run_with_sink(
    workload: &Workload,
    cfg: &Config,
    duration: Duration,
    runtime: Option<&Runtime>,
    sink: &mut dyn Sink,
) -> Result<RunResult> {
    let mut session = Session::with_runtime_ref(cfg.clone(), runtime)?;
    let id = session.register(workload.clone())?;
    let mut results = session.run_with_sink(duration, id, sink)?;
    Ok(results.remove(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::workloads;

    fn short_run(mode: Mode, workload: &str, secs: u64) -> RunResult {
        let w = workloads::by_name(workload).unwrap();
        let cfg = Config { mode, ..Config::default() };
        run(&w, &cfg, Duration::from_secs(secs), None).unwrap()
    }

    #[test]
    fn baseline_fires_on_trigger_cadence() {
        let r = short_run(Mode::Baseline, "lr1s", 60);
        assert!(!r.batches.is_empty());
        // 10 s trigger over 60 s: at most 6 batches; processing overrun
        // can reduce the count but never increase it.
        assert!(r.batches.len() <= 6, "{} batches", r.batches.len());
        // Every dataset buffered for up to one trigger interval or more.
        assert!(r.batches[0].max_buffering >= Duration::from_secs(9));
    }

    #[test]
    fn lmstream_produces_smaller_batches_than_baseline() {
        let lm = short_run(Mode::LmStream, "lr1s", 90);
        let bl = short_run(Mode::Baseline, "lr1s", 90);
        assert!(!lm.batches.is_empty() && !bl.batches.is_empty());
        let lm_mean_ds: f64 = lm.batches.iter().map(|b| b.num_datasets as f64).sum::<f64>()
            / lm.batches.len() as f64;
        let bl_mean_ds: f64 = bl.batches.iter().map(|b| b.num_datasets as f64).sum::<f64>()
            / bl.batches.len() as f64;
        assert!(
            lm_mean_ds < bl_mean_ds,
            "LMStream {lm_mean_ds} !< baseline {bl_mean_ds}"
        );
    }

    #[test]
    fn lmstream_latency_beats_baseline_on_lr1s() {
        let lm = short_run(Mode::LmStream, "lr1s", 120);
        let bl = short_run(Mode::Baseline, "lr1s", 120);
        assert!(
            lm.avg_latency < bl.avg_latency,
            "LMStream {} !< baseline {}",
            lm.avg_latency,
            bl.avg_latency
        );
    }

    #[test]
    fn deterministic_runs_for_same_seed() {
        let a = short_run(Mode::LmStream, "cm1t", 45);
        let b = short_run(Mode::LmStream, "cm1t", 45);
        assert_eq!(a.batches.len(), b.batches.len());
        assert_eq!(a.avg_throughput, b.avg_throughput);
    }

    #[test]
    fn window_state_respected_by_join_queries() {
        let r = short_run(Mode::LmStream, "lr1t", 60);
        assert!(!r.batches.is_empty());
        assert!(r.avg_throughput > 0.0);
    }

    #[test]
    fn all_modes_run_all_workloads_briefly() {
        for w in workloads::ALL {
            for mode in [Mode::LmStream, Mode::Baseline, Mode::StaticPreference] {
                let r = short_run(mode, w, 35);
                assert!(!r.batches.is_empty(), "{w} {mode:?} produced no batches");
            }
        }
    }
}
