//! The micro-batch main loop (Fig. 3's execution flow).
//!
//! One iteration: poll the source → `ConstructMicroBatch` admission (or
//! the baseline's static trigger) → collect the async optimizer's latest
//! inflection point → `MapDevice` planning (or a baseline policy) →
//! partitioned execution → metrics update → window-state maintenance →
//! submit the optimizer's next fit. Identical code drives the simulated
//! clock (paper-scale experiments) and the wall clock (real PJRT runs).

use crate::cluster;
use crate::config::{Config, ExecBackend, Mode};
use crate::coordinator::admission::{Admission, AdmissionDecision};
use crate::coordinator::checkpoint::{Checkpoint, CheckpointStore};
use crate::coordinator::metrics::{BatchRecord, Metrics, PhaseTotals};
use crate::coordinator::optimizer::{HistoryPoint, OnlineOptimizer};
use crate::coordinator::planner::{map_device, static_preference_plan, SizeEstimator};
use crate::devices::model::DeviceModel;
use crate::devices::Device;
use crate::engine::column::ColumnBatch;
use crate::engine::dataset::MicroBatch;
use crate::engine::partition::mean_partition_bytes;
use crate::engine::sink::{NullSink, Sink};
use crate::engine::window::WindowState;
use crate::error::Result;
use crate::query::dag::OpKind;
use crate::query::exec::{self, DevicePlan, ExecEnv, OpTrace};
use crate::runtime::client::Runtime;
use crate::sim::{Clock, SimClock, Time, WallClock};
use crate::workloads::Workload;
use std::path::Path;
use std::time::{Duration, Instant};

/// Everything a finished run reports.
#[derive(Debug)]
pub struct RunResult {
    pub workload: &'static str,
    pub mode: Mode,
    pub batches: Vec<BatchRecord>,
    /// Mean per-dataset end-to-end latency, seconds (Fig. 6 metric).
    pub avg_latency: f64,
    /// Eq. 4 average throughput, bytes/s (Fig. 7 metric).
    pub avg_throughput: f64,
    /// Table IV phase totals.
    pub phases: PhaseTotals,
    /// Per-dataset latencies (distribution analysis).
    pub dataset_latencies: Vec<f64>,
    /// Final inflection point (bytes).
    pub final_inf_pt: f64,
}

impl RunResult {
    /// Mean processing-phase time per micro-batch (Fig. 10 metric), s.
    pub fn avg_proc(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches.iter().map(|b| b.proc.as_secs_f64()).sum::<f64>()
            / self.batches.len() as f64
    }

    /// Mean per-batch max latency, s.
    pub fn avg_max_latency(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches
            .iter()
            .map(|b| b.max_latency.as_secs_f64())
            .sum::<f64>()
            / self.batches.len() as f64
    }
}

/// Run `workload` under `cfg` for `duration` (simulated or wall time).
/// `runtime` is required only for the Real backend.
pub fn run(
    workload: &Workload,
    cfg: &Config,
    duration: Duration,
    runtime: Option<&Runtime>,
) -> Result<RunResult> {
    run_with_sink(workload, cfg, duration, runtime, &mut NullSink)
}

/// Run with results delivered to `sink` (the output stream).
pub fn run_with_sink(
    workload: &Workload,
    cfg: &Config,
    duration: Duration,
    runtime: Option<&Runtime>,
    sink: &mut dyn Sink,
) -> Result<RunResult> {
    cfg.validate()?;
    let clock: Box<dyn Clock> = match cfg.backend {
        ExecBackend::Simulated => Box::new(SimClock::new()),
        ExecBackend::Real => Box::new(WallClock::new()),
    };
    run_with_clock(workload, cfg, duration, runtime, clock.as_ref(), sink)
}

/// Tumbling-window bootstrap bound before any history exists (§III-C's
/// Eq. 3 is undefined for i < 2; the paper seeds parameters from
/// pre-experiments — one second is our seed).
const INITIAL_TUMBLING_BOUND: Duration = Duration::from_secs(3);

/// Optimizer pickup timeout: how long the driver will wait on the async
/// regression before planning (bounds Table IV's "Optimization Blocking").
const OPT_PICKUP_TIMEOUT: Duration = Duration::from_millis(20);

fn run_with_clock(
    workload: &Workload,
    cfg: &Config,
    duration: Duration,
    runtime: Option<&Runtime>,
    clock: &dyn Clock,
    sink: &mut dyn Sink,
) -> Result<RunResult> {
    // Logical plan rewrites (projection pushdown into joins, §Perf).
    let query = &crate::query::optimize::optimize(&workload.query);
    query.validate()?;
    let model = DeviceModel::default();
    let env = ExecEnv {
        model: &model,
        backend: cfg.backend,
        num_cores: cfg.num_cores,
        num_gpus: cfg.num_gpus,
        runtime,
    };
    // §III-E checkpoint/state-flush substrate.
    let ckpt_store = match &cfg.checkpoint_dir {
        Some(dir) => Some(CheckpointStore::new(Path::new(dir))?),
        None => None,
    };
    let recovered: Option<Checkpoint> = match &ckpt_store {
        Some(st) => st.load(workload.name)?,
        None => None,
    };

    let mut stream = workload.make_stream(cfg.seed);
    let mut window = WindowState::new();
    let mut admission = Admission::new(query.window, INITIAL_TUMBLING_BOUND);
    let mut metrics = Metrics::new();
    let mut optimizer =
        OnlineOptimizer::new(cfg.online_optimizer && cfg.mode == Mode::LmStream,
                             cfg.history_cap, cfg.seed);
    let mut size_est = SizeEstimator::new(query.len());
    let mut inf_pt = cfg.initial_inflection_bytes;
    // Resume from a checkpoint: restore the inflection point + optimizer
    // history and skip the already-processed stream prefix.
    if let Some(ckpt) = &recovered {
        inf_pt = ckpt.inf_pt.max(1.0);
        for h in &ckpt.history {
            optimizer.record(*h, INITIAL_TUMBLING_BOUND);
        }
        stream.fast_forward(ckpt.processed_up_to); // skip processed prefix
    }
    let end = Time::ZERO.add(duration);
    let mut next_trigger = Time::ZERO.add(cfg.trigger);
    let mut construct_acc = Duration::ZERO;

    let has_join = query
        .ops
        .iter()
        .any(|o| matches!(o.spec.kind(), OpKind::Join));

    while clock.now() < end {
        // ---- Buffering phase: trigger (baseline) or admission (LMStream).
        let batch: MicroBatch = if cfg.mode.uses_trigger() {
            clock.sleep_until(next_trigger);
            if clock.now() >= end {
                break;
            }
            let data = stream.poll(clock.now());
            next_trigger = next_trigger.add(cfg.trigger);
            if data.is_empty() {
                continue;
            }
            MicroBatch::new(data)
        } else {
            let deadline = clock.now().add(cfg.poll_interval);
            clock.sleep_until(deadline);
            if clock.now() >= end {
                break;
            }
            let t0 = Instant::now();
            let data = stream.poll(clock.now());
            let thput = {
                let t = metrics.avg_throughput();
                if t > 0.0 { t } else { cfg.initial_throughput }
            };
            let decision =
                admission.construct(data, clock.now(), thput, metrics.past_max_lat_avg());
            construct_acc += t0.elapsed();
            match decision {
                AdmissionDecision::Poll | AdmissionDecision::Buffer { .. } => continue,
                AdmissionDecision::Admit(mb) => mb,
            }
        };

        let admitted_at = clock.now();
        let batch_bytes = batch.wire_bytes();

        // ---- Optimizer pickup (must land before the processing phase).
        let (new_inf, opt_blocking) = if cfg.mode == Mode::LmStream {
            optimizer.take(inf_pt, OPT_PICKUP_TIMEOUT)
        } else {
            (inf_pt, Duration::ZERO)
        };
        inf_pt = new_inf;

        // ---- Window maintenance + execution input assembly.
        if let Some(newest) = batch.newest_event_time() {
            window.evict(newest, &query.window);
        }
        let snapshot = window.snapshot()?;
        let input: ColumnBatch = if query.uses_window_state && !has_join {
            // Windowed aggregation recomputes over state ∪ new data.
            match &snapshot {
                Some(s) => ColumnBatch::concat(&[s, &batch.concat()?])?,
                None => batch.concat()?,
            }
        } else {
            batch.concat()?
        };

        // ---- Query planning (MapDevice or a fixed policy).
        let t_plan = Instant::now();
        let plan: DevicePlan = match cfg.mode {
            Mode::LmStream => {
                // Part_(i,j): partition share of the data the processing
                // phase actually touches (window scope included).
                let part = mean_partition_bytes(input.bytes(), cfg.num_cores);
                map_device(query, part, inf_pt, cfg.base_trans_cost, &size_est)
            }
            Mode::Baseline | Mode::AllGpu => DevicePlan::all(Device::Gpu, query.len()),
            Mode::BaselineCpu | Mode::AllCpu => DevicePlan::all(Device::Cpu, query.len()),
            Mode::StaticPreference => static_preference_plan(query),
        };
        let map_device_time = t_plan.elapsed();
        // A join's build side before any state exists is an empty window.
        let empty_window = ColumnBatch::empty(input.schema.clone());
        let join_side = if has_join {
            Some(snapshot.as_ref().unwrap_or(&empty_window))
        } else {
            None
        };

        // ---- Processing phase (single executor or cluster-wide).
        let (result, proc, traces): (ColumnBatch, Duration, Vec<OpTrace>) =
            match &cfg.cluster {
                None => {
                    let o = exec::execute(query, &plan, input, join_side, &env)?;
                    (o.result, o.proc, o.traces)
                }
                Some(spec) => {
                    let o = cluster::execute_on_cluster(
                        spec, query, &plan, input, join_side, &model, cfg.backend,
                        runtime,
                    )?;
                    // Merge per-executor traces (sum byte volumes per op)
                    // for the size estimator.
                    let mut merged: Vec<OpTrace> = o.per_executor[0].traces.clone();
                    for ex in &o.per_executor[1..] {
                        for (m, t) in merged.iter_mut().zip(&ex.traces) {
                            m.in_bytes += t.in_bytes;
                            m.out_bytes += t.out_bytes;
                        }
                    }
                    (o.result, o.proc, merged)
                }
            };
        clock.advance(proc + map_device_time + construct_acc + opt_blocking);
        sink.deliver(metrics.batches(), &result, clock.now())?;

        // ---- Metrics (Eqs. 4/5, Table IV).
        let buffs: Vec<Duration> = batch
            .datasets
            .iter()
            .map(|d| admitted_at.saturating_sub(d.created_at))
            .collect();
        let rec = BatchRecord {
            index: metrics.batches(),
            admitted_at,
            num_datasets: batch.num_datasets(),
            bytes: batch_bytes,
            max_buffering: Duration::ZERO, // filled by Metrics::record
            proc,
            max_latency: Duration::ZERO, // filled by Metrics::record
            inf_pt,
            gpu_ops: plan.gpu_ops(),
            total_ops: query.len(),
            construct_time: construct_acc,
            map_device_time,
            opt_blocking,
        };
        construct_acc = Duration::ZERO;
        metrics.record(rec, &buffs);
        size_est.observe(&traces);

        // ---- Async parameter optimization (Eq. 10 inputs).
        if cfg.mode == Mode::LmStream {
            let last = metrics.records().last().expect("just recorded");
            optimizer.record(
                HistoryPoint {
                    throughput: metrics.avg_throughput(),
                    max_latency: last.max_latency.as_secs_f64(),
                    inf_pt,
                },
                admission.bound(metrics.past_max_lat_avg()),
            );
        }

        // ---- Window state ingests the processed datasets.
        if query.uses_window_state {
            window.push(&batch.datasets);
        }

        // ---- §III-E checkpoint / state flush (overlapped with the async
        // optimizer in the paper; sequential here, the cost is µs-scale).
        if let Some(st) = &ckpt_store {
            let newest = batch
                .datasets
                .iter()
                .map(|d| d.created_at)
                .max()
                .unwrap_or(admitted_at);
            st.save(&Checkpoint {
                workload: workload.name.to_string(),
                batches: metrics.batches(),
                processed_up_to: newest,
                inf_pt,
                cumulative_bytes: metrics.cumulative_bytes(),
                cumulative_proc_secs: metrics.cumulative_proc_secs(),
                max_lat_sum_secs: metrics.max_lat_sum_secs(),
                history: optimizer.history().to_vec(),
            })?;
        }

        // Baseline trigger catches up if processing overran the interval.
        if cfg.mode.uses_trigger() && next_trigger < clock.now() {
            next_trigger = clock.now();
        }
    }

    Ok(RunResult {
        workload: workload.name,
        mode: cfg.mode,
        avg_latency: metrics.avg_dataset_latency(),
        avg_throughput: metrics.avg_throughput(),
        phases: metrics.phase_totals(),
        dataset_latencies: metrics.dataset_latencies().to_vec(),
        final_inf_pt: inf_pt,
        batches: metrics.records().to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn short_run(mode: Mode, workload: &str, secs: u64) -> RunResult {
        let w = workloads::by_name(workload).unwrap();
        let cfg = Config { mode, ..Config::default() };
        run(&w, &cfg, Duration::from_secs(secs), None).unwrap()
    }

    #[test]
    fn baseline_fires_on_trigger_cadence() {
        let r = short_run(Mode::Baseline, "lr1s", 60);
        assert!(!r.batches.is_empty());
        // 10 s trigger over 60 s: at most 6 batches; processing overrun
        // can reduce the count but never increase it.
        assert!(r.batches.len() <= 6, "{} batches", r.batches.len());
        // Every dataset buffered for up to one trigger interval or more.
        assert!(r.batches[0].max_buffering >= Duration::from_secs(9));
    }

    #[test]
    fn lmstream_produces_smaller_batches_than_baseline() {
        let lm = short_run(Mode::LmStream, "lr1s", 90);
        let bl = short_run(Mode::Baseline, "lr1s", 90);
        assert!(!lm.batches.is_empty() && !bl.batches.is_empty());
        let lm_mean_ds: f64 = lm.batches.iter().map(|b| b.num_datasets as f64).sum::<f64>()
            / lm.batches.len() as f64;
        let bl_mean_ds: f64 = bl.batches.iter().map(|b| b.num_datasets as f64).sum::<f64>()
            / bl.batches.len() as f64;
        assert!(
            lm_mean_ds < bl_mean_ds,
            "LMStream {lm_mean_ds} !< baseline {bl_mean_ds}"
        );
    }

    #[test]
    fn lmstream_latency_beats_baseline_on_lr1s() {
        let lm = short_run(Mode::LmStream, "lr1s", 120);
        let bl = short_run(Mode::Baseline, "lr1s", 120);
        assert!(
            lm.avg_latency < bl.avg_latency,
            "LMStream {} !< baseline {}",
            lm.avg_latency,
            bl.avg_latency
        );
    }

    #[test]
    fn deterministic_runs_for_same_seed() {
        let a = short_run(Mode::LmStream, "cm1t", 45);
        let b = short_run(Mode::LmStream, "cm1t", 45);
        assert_eq!(a.batches.len(), b.batches.len());
        assert_eq!(a.avg_throughput, b.avg_throughput);
    }

    #[test]
    fn window_state_respected_by_join_queries() {
        let r = short_run(Mode::LmStream, "lr1t", 60);
        assert!(!r.batches.is_empty());
        assert!(r.avg_throughput > 0.0);
    }

    #[test]
    fn all_modes_run_all_workloads_briefly() {
        for w in workloads::ALL {
            for mode in [Mode::LmStream, Mode::Baseline, Mode::StaticPreference] {
                let r = short_run(mode, w, 35);
                assert!(!r.batches.is_empty(), "{w} {mode:?} produced no batches");
            }
        }
    }
}
