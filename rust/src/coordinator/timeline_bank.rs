//! The GPU timeline bank — the single cross-shard contention point of
//! the sharded session runtime.
//!
//! A sharded round epoch plans and executes each admitted source's
//! queries independently (see `session`: per-source staging, planning
//! and execution fan out over worker threads), but the per-executor
//! GPUs are *shared physics*: two sources' device reservations on one
//! executor must serialize, never double-book, and the serialization
//! must be identical for every shard count or the sharded runtime stops
//! being deterministic.
//!
//! The bank arbitrates this with a **reservation-lease protocol** run
//! on the coordinator thread, in global source order (the *ticket*
//! order), before execution fans out:
//!
//! 1. [`TimelineBank::lease`] grants the next ticket. The lease carries
//!    one *start offset per physical executor* — the executor's
//!    committed busy-horizon so far this epoch. At most one lease is
//!    outstanding at a time (a second `lease` before `commit` is a
//!    protocol error), so the offsets a holder sees can never move
//!    under it.
//! 2. The holder plans its queries and derives its *predicted*
//!    per-executor busy horizons from the scheduler's serialized
//!    timeline ([`crate::coordinator::schedule::executor_horizons`]).
//! 3. [`TimelineBank::commit`] books those horizons: executor `e`'s
//!    busy-until cursor advances to `offsets[e] + horizon[e]`. The next
//!    ticket's lease starts where this one ends, so granted windows
//!    `[offset, offset + horizon)` are pairwise disjoint per executor
//!    **by construction** — monotone cursors, sequential grants.
//!
//! Execution then seeds each source's local
//! [`GpuTimeline`](crate::query::exec::GpuTimeline)s from its lease
//! offsets ([`GpuTimeline::starting_at`]): a source whose predicted
//! window sits behind another source's pays that occupancy as
//! `gpu_wait`, exactly as the serial round loop's shared timelines
//! price it — while sources with disjoint device needs (or none)
//! overlap freely. Horizons are *predictions* (the scheduler's
//! `SizeEstimator`-fed timeline); actual executed busy time may drift
//! from them, and the non-overlap guarantee is about the granted
//! windows, not the drifted actuals — see ARCHITECTURE.md §Sharded
//! runtime.
//!
//! [`GpuTimeline::starting_at`]: crate::query::exec::GpuTimeline::starting_at

use crate::error::{Error, Result};
use std::time::Duration;

/// One granted reservation lease: the ticket (global grant order) and
/// the per-physical-executor start offsets the holder's local GPU
/// timelines must be seeded with.
#[derive(Clone, Debug)]
pub struct Lease {
    /// Global grant sequence number (0-based, monotone across epochs).
    pub ticket: usize,
    /// Executor `e`'s committed busy-horizon at grant time — where this
    /// lease's window on `e` starts.
    pub offsets: Vec<Duration>,
}

/// Per-epoch arbiter of the shared per-executor GPU timelines across
/// shards. See the module docs for the lease protocol.
#[derive(Clone, Debug)]
pub struct TimelineBank {
    /// Per-physical-executor committed busy-until cursor, from epoch
    /// start. Monotone within an epoch; [`TimelineBank::reset_epoch`]
    /// zeroes it.
    free_at: Vec<Duration>,
    /// Next ticket to grant.
    next_ticket: usize,
    /// Tickets committed so far; `next_ticket > committed` means a
    /// lease is outstanding.
    committed: usize,
}

impl TimelineBank {
    /// A bank over `num_executors` physical executors, all idle.
    pub fn new(num_executors: usize) -> TimelineBank {
        TimelineBank {
            free_at: vec![Duration::ZERO; num_executors],
            next_ticket: 0,
            committed: 0,
        }
    }

    pub fn num_executors(&self) -> usize {
        self.free_at.len()
    }

    /// Executor `e`'s committed busy-horizon this epoch.
    pub fn horizon(&self, e: usize) -> Duration {
        self.free_at[e]
    }

    /// Grant the next ticket. Errors if a lease is already outstanding:
    /// grants are strictly sequential so offsets never move under a
    /// holder.
    pub fn lease(&mut self) -> Result<Lease> {
        if self.next_ticket > self.committed {
            return Err(Error::Plan(format!(
                "timeline bank: ticket {} is still outstanding — commit it \
                 before granting another lease",
                self.next_ticket - 1
            )));
        }
        let lease = Lease { ticket: self.next_ticket, offsets: self.free_at.clone() };
        self.next_ticket += 1;
        Ok(lease)
    }

    /// Book `lease`'s predicted per-executor busy horizons (seconds
    /// from the lease's own start offsets). Consumes the lease; the
    /// next grant starts where these windows end.
    pub fn commit(&mut self, lease: Lease, horizons: &[f64]) -> Result<()> {
        if lease.ticket + 1 != self.next_ticket || self.next_ticket == self.committed {
            return Err(Error::Plan(format!(
                "timeline bank: commit of ticket {} does not match the \
                 outstanding ticket {}",
                lease.ticket,
                self.next_ticket.wrapping_sub(1)
            )));
        }
        if horizons.len() != self.free_at.len() {
            return Err(Error::Plan(format!(
                "timeline bank: {} horizons committed against {} executors",
                horizons.len(),
                self.free_at.len()
            )));
        }
        for (e, &h) in horizons.iter().enumerate() {
            if !h.is_finite() || h < 0.0 {
                return Err(Error::Plan(format!(
                    "timeline bank: executor {e} horizon {h} is not a \
                     finite non-negative duration"
                )));
            }
            self.free_at[e] = lease.offsets[e] + Duration::from_secs_f64(h);
        }
        self.committed = self.next_ticket;
        Ok(())
    }

    /// Start a new round epoch: every executor's cursor returns to
    /// zero. Tickets stay monotone across epochs (they are global grant
    /// ids, not per-epoch slots). Errors while a lease is outstanding.
    pub fn reset_epoch(&mut self) -> Result<()> {
        if self.next_ticket > self.committed {
            return Err(Error::Plan(format!(
                "timeline bank: cannot reset the epoch while ticket {} is \
                 outstanding",
                self.next_ticket - 1
            )));
        }
        self.free_at.iter_mut().for_each(|f| *f = Duration::ZERO);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leases_accumulate_disjoint_windows_per_executor() {
        let mut bank = TimelineBank::new(2);
        // Windows as (start, end) per executor, rebuilt from the grants.
        let mut windows: Vec<Vec<(Duration, Duration)>> = vec![Vec::new(); 2];
        let horizons = [[1.0, 0.5], [2.0, 0.0], [0.25, 3.0]];
        for (i, hs) in horizons.iter().enumerate() {
            let lease = bank.lease().unwrap();
            assert_eq!(lease.ticket, i);
            for (e, &h) in hs.iter().enumerate() {
                windows[e].push((lease.offsets[e], lease.offsets[e] + Duration::from_secs_f64(h)));
            }
            bank.commit(lease, hs).unwrap();
        }
        // Pairwise disjoint and monotone on each executor.
        for per_exec in &windows {
            for w in per_exec.windows(2) {
                assert!(w[0].1 <= w[1].0, "overlapping grants: {w:?}");
            }
        }
        assert_eq!(bank.horizon(0), Duration::from_secs_f64(3.25));
        assert_eq!(bank.horizon(1), Duration::from_secs_f64(3.5));
    }

    #[test]
    fn second_lease_while_outstanding_is_rejected() {
        let mut bank = TimelineBank::new(1);
        let lease = bank.lease().unwrap();
        assert!(bank.lease().is_err());
        assert!(bank.reset_epoch().is_err());
        bank.commit(lease, &[1.0]).unwrap();
        bank.lease().unwrap();
    }

    #[test]
    fn commit_validates_shape_and_values() {
        let mut bank = TimelineBank::new(2);
        let lease = bank.lease().unwrap();
        assert!(bank.commit(lease.clone(), &[1.0]).is_err(), "length mismatch");
        assert!(bank.commit(lease.clone(), &[1.0, -0.5]).is_err(), "negative");
        assert!(bank.commit(lease.clone(), &[1.0, f64::NAN]).is_err(), "nan");
        bank.commit(lease, &[1.0, 0.0]).unwrap();
        // Double-commit of a consumed ticket is rejected.
        let stale = Lease { ticket: 0, offsets: vec![Duration::ZERO; 2] };
        assert!(bank.commit(stale, &[0.0, 0.0]).is_err());
    }

    #[test]
    fn reset_epoch_zeroes_cursors_but_keeps_tickets_monotone() {
        let mut bank = TimelineBank::new(1);
        let lease = bank.lease().unwrap();
        bank.commit(lease, &[2.0]).unwrap();
        bank.reset_epoch().unwrap();
        assert_eq!(bank.horizon(0), Duration::ZERO);
        let lease = bank.lease().unwrap();
        assert_eq!(lease.ticket, 1, "tickets are global grant ids");
        assert_eq!(lease.offsets[0], Duration::ZERO);
        bank.commit(lease, &[0.5]).unwrap();
    }
}
