//! `MapDevice` — Algorithm 2 with the cost models of Eqs. 7–9.
//!
//! Per-operation device selection around the *inflection point*:
//!
//! ```text
//! CPU_(i,j,o)   = baseCost_o × (Part_(i,j) / InfPT_i)          (Eq. 7)
//! GPU_(i,j,o)   = baseCost_o × (InfPT_i / Part_(i,j))          (Eq. 8)
//! Trans_(i,j,o) = baseTransCost × (Part_(i,j) / InfPT_i)       (Eq. 9)
//! ```
//!
//! `Part` is the size of the data the operation processes per partition
//! (§II-B's critique of FineStream is precisely that preference must
//! follow "the size of the data processed by the operation"); since
//! intermediate sizes change along the DAG (join/expand amplify, filter
//! shrinks), the planner propagates per-operation size estimates from
//! ratios learned on past executions ([`SizeEstimator`]) — seeded at 1.0,
//! i.e. the paper's plain per-partition size, before any history exists.
//!
//! The output is a [`PhysicalPlan`] — the logical DAG annotated with one
//! device per op plus the size estimate that drove the choice — which
//! [`crate::query::exec`] walks. Transfer-cost placement is shared with
//! the executor through [`transfer_boundaries`] so the planner's Eq. 9
//! charging and the executor's PCIe charging can never diverge.
//!
//! `MapDevice` is split into two reusable halves:
//!
//! 1. [`op_candidates`] — pure candidate costing: Eq. 7/8/9 cost vectors
//!    per op (no device decision),
//! 2. [`select_devices`] — Alg. 2's traversal: boundary placement +
//!    greedy per-op choice over those vectors.
//!
//! [`map_device`] composes the two. The cross-query scheduler
//! ([`crate::coordinator::schedule`]) consumes [`op_candidates`]
//! directly, so the joint plan reuses — never re-derives — the same
//! Eq. 7–9 economics the per-query planner runs on.

use crate::devices::Device;
use crate::error::{Error, Result};
use crate::query::dag::{OpKind, Query};
use crate::query::exec::OpTrace;
use crate::query::physical::{transfer_boundaries, PhysicalOp, PhysicalPlan};
use crate::util::stats::Ema;

/// Table II: per-operation base cost and initial device preference.
#[derive(Clone, Copy, Debug)]
pub struct BaseCost;

impl BaseCost {
    /// Base cost of Table II (Union is a copy-bound merge, like Expand).
    pub fn cost(kind: OpKind) -> f64 {
        match kind {
            OpKind::Aggregate | OpKind::Filter | OpKind::Shuffle => 1.0,
            OpKind::Project | OpKind::Join | OpKind::Expand | OpKind::Union => 0.9,
            OpKind::Scan | OpKind::Sort => 0.8,
        }
    }

    /// Initial preference of Table II (device at inflection-sized data).
    pub fn initial_preference(kind: OpKind) -> Option<Device> {
        match kind {
            OpKind::Aggregate | OpKind::Filter | OpKind::Shuffle => Some(Device::Cpu),
            // neutral
            OpKind::Project | OpKind::Join | OpKind::Expand | OpKind::Union => None,
            OpKind::Scan | OpKind::Sort => Some(Device::Gpu),
        }
    }
}

/// Learned per-operation output/input size ratios for one query, updated
/// from execution traces (EMA). Gives MapDevice the per-op processed-size
/// estimates Eq. 7/8 need.
#[derive(Clone, Debug)]
pub struct SizeEstimator {
    ratios: Vec<Ema>,
    seeded: Vec<bool>,
}

impl SizeEstimator {
    pub fn new(num_ops: usize) -> SizeEstimator {
        SizeEstimator {
            ratios: vec![Ema::new(0.3); num_ops],
            seeded: vec![false; num_ops],
        }
    }

    /// Ingest per-op in/out byte observations from an execution.
    pub fn observe(&mut self, traces: &[OpTrace]) {
        for t in traces {
            if t.op_id < self.ratios.len() && t.in_bytes > 0 {
                self.ratios[t.op_id].update(t.out_bytes as f64 / t.in_bytes as f64);
                self.seeded[t.op_id] = true;
            }
        }
    }

    /// out/in ratio estimate for op `o` (1.0 until observed).
    pub fn ratio(&self, o: usize) -> f64 {
        if self.seeded.get(o).copied().unwrap_or(false) {
            self.ratios[o].get().unwrap_or(1.0)
        } else {
            1.0
        }
    }

    /// Estimated *processed* size for each op of a linear chain given
    /// the source partition size: the larger of the op's input and its
    /// estimated output (an amplifying join/expand is output-bound, a
    /// filter input-bound) — the "size of the data processed by the
    /// operation" of §II-B.
    pub fn op_sizes(&self, part_bytes: f64) -> Vec<f64> {
        let mut sizes = Vec::with_capacity(self.ratios.len());
        let mut s = part_bytes;
        for o in 0..self.ratios.len() {
            let out = s * self.ratio(o);
            sizes.push(s.max(out));
            s = out;
        }
        sizes
    }

    /// DAG-propagated per-op `(input, output)` byte estimates: an op's
    /// input is the sum of its producers' estimated outputs (a Union
    /// merges branches; the scan reads `part_bytes` from the source),
    /// its output follows the learned ratio. Index-aligned with
    /// `query.ops`.
    pub fn op_flows_for(&self, query: &Query, part_bytes: f64) -> Vec<(f64, f64)> {
        let n = query.ops.len();
        let mut outs = vec![0.0f64; n];
        let mut flows = vec![(0.0f64, 0.0f64); n];
        // Validated queries store producers before consumers (validate()
        // rejects forward edges), so the storage order is topological —
        // no need to re-run Kahn here on the planning hot path.
        for op in &query.ops {
            let input: f64 = if op.inputs.is_empty() {
                part_bytes
            } else {
                op.inputs.iter().map(|&p| outs.get(p).copied().unwrap_or(0.0)).sum()
            };
            let out = input * self.ratio(op.id);
            flows[op.id] = (input, out);
            outs[op.id] = out;
        }
        flows
    }

    /// DAG-aware version of [`SizeEstimator::op_sizes`]: per-op
    /// processed size = max(estimated input, estimated output); for a
    /// linear chain this equals `op_sizes(part_bytes)`.
    pub fn op_sizes_for(&self, query: &Query, part_bytes: f64) -> Vec<f64> {
        self.op_flows_for(query, part_bytes)
            .iter()
            .map(|&(i, o)| i.max(o))
            .collect()
    }

    /// DAG-propagated per-op `(input, output)` **chunk-count** estimates
    /// — the layout analog of [`SizeEstimator::op_flows_for`]. The scan
    /// reads the micro-batch's `input_chunks`; each op's output layout
    /// follows [`op_output_chunks`]'s kernel physics (per-chunk kernels
    /// preserve, aggregate/sort materialize one chunk, expand multiplies
    /// by the window factor); a Union's input is the *sum* of its
    /// branches' chunk lists. Structural today (the chunked kernels'
    /// layouts are deterministic, nothing to learn), but threaded through
    /// the estimator so boundary pricing and size estimation stay one
    /// per-op propagation pass. Index-aligned with `query.ops`.
    ///
    /// [`op_output_chunks`]: crate::devices::model::op_output_chunks
    pub fn op_chunk_flows_for(
        &self,
        query: &Query,
        input_chunks: usize,
    ) -> Vec<(usize, usize)> {
        op_chunk_flows(query, input_chunks)
    }
}

/// The propagation behind [`SizeEstimator::op_chunk_flows_for`], as a
/// free function: nothing about chunk layout is learned, so callers that
/// only have a different *seed* chunk count — the cross-query scheduler
/// re-deriving an executor's share layout — can re-run it without an
/// estimator in hand.
pub fn op_chunk_flows(query: &Query, input_chunks: usize) -> Vec<(usize, usize)> {
    let n = query.ops.len();
    let expand = query.window.expand_factor() as usize;
    let mut outs = vec![0usize; n];
    let mut flows = vec![(0usize, 0usize); n];
    // Storage order is topological (validate() rejects forward
    // edges), exactly as in op_flows_for.
    for op in &query.ops {
        let cin: usize = if op.inputs.is_empty() {
            input_chunks
        } else {
            op.inputs.iter().map(|&p| outs.get(p).copied().unwrap_or(0)).sum()
        };
        let cout =
            crate::devices::model::op_output_chunks(op.spec.kind(), cin, expand);
        flows[op.id] = (cin, cout);
        outs[op.id] = cout;
    }
    flows
}

/// Contiguous-staging share of Eq. 9's transition cost, charged on
/// *entering* boundaries only: the planner's counterpart of the
/// executor's `DeviceModel::coalesce_time` (memcpy staging runs ~4x the
/// PCIe+conversion rate, hence 1/4 of the transfer cost).
pub const COALESCE_TRANS_SHARE: f64 = 0.25;

/// Per-operation candidate costs — Alg. 2's Eq. 7/8/9 inputs, computed
/// *before* any device decision. [`select_devices`] consumes these for
/// the per-query greedy choice; the cross-query scheduler
/// ([`crate::coordinator::schedule`]) consumes them to ration a shared
/// GPU across queries with the exact same economics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpCandidate {
    /// Logical node id (index into `query.ops`).
    pub op_id: usize,
    pub kind: OpKind,
    /// Estimated per-partition input bytes (DAG-propagated).
    pub est_in_bytes: f64,
    /// Estimated per-partition output bytes.
    pub est_out_bytes: f64,
    /// Processed size `max(in, out)` — the Eq. 7/8 `Part`-derived size.
    pub est_bytes: f64,
    /// Estimated chunk count of the op's assembled input
    /// ([`SizeEstimator::op_chunk_flows_for`]): gates the coalesce
    /// staging share at this op's entering boundary — an interior op fed
    /// by an aggregate/sort sees a single chunk however chunked the
    /// micro-batch was.
    pub est_in_chunks: usize,
    /// Eq. 7: `baseCost × (size / InfPT)`.
    pub cpu_cost: f64,
    /// Eq. 8: `baseCost × (InfPT / size)`.
    pub gpu_cost: f64,
    /// Eq. 9: `baseTransCost × (size / InfPT)` — one boundary crossing.
    pub trans_cost: f64,
}

/// Candidate costing: Eq. 7/8/9 vectors for every op of `query`, using
/// the learned size estimates plus the DAG-propagated chunk layout
/// seeded by the micro-batch's `input_chunks`. Pure — no device is
/// chosen here.
///
/// Costing stays strictly **per logical op** even when downstream
/// layers fuse: the fusion pass ([`crate::query::fuse`]) never changes
/// the byte flow an op processes — a fused chain's virtual
/// intermediates are defined to equal the staged sizes — so these
/// vectors are correct inputs for fused and staged execution alike.
/// Consumers that must see a fused chain as *one* unit (the cross-query
/// scheduler's GPU reservations) merge at the chain layer via
/// [`crate::query::fuse::fusable_runs`] rather than asking for merged
/// candidates here; `candidates.len() == query.len()` is an invariant.
/// Likewise, window-state aux bytes enter through the scheduler's
/// `QueryCandidate` (and the executor's `ExecOpts::aux`), both carrying
/// the *encoded* footprint, so the Eq. 9 aux term prices what actually
/// crosses the bus.
///
/// Errors with [`Error::Plan`] on an empty or cyclic query.
pub fn op_candidates(
    query: &Query,
    part_bytes: f64,
    inf_pt: f64,
    base_trans: f64,
    estimator: &SizeEstimator,
    input_chunks: usize,
) -> Result<Vec<OpCandidate>> {
    if query.ops.is_empty() {
        return Err(Error::Plan("cannot plan an empty query".into()));
    }
    query.topo_order()?;
    let flows = estimator.op_flows_for(query, part_bytes.max(1.0));
    let chunk_flows = estimator.op_chunk_flows_for(query, input_chunks);
    let inf = inf_pt.max(1.0);
    Ok(query
        .ops
        .iter()
        .map(|op| {
            let kind = op.spec.kind();
            let (fin, fout) = flows[op.id];
            let size = fin.max(fout).max(1.0);
            let base = BaseCost::cost(kind);
            OpCandidate {
                op_id: op.id,
                kind,
                est_in_bytes: fin,
                est_out_bytes: fout,
                est_bytes: size,
                est_in_chunks: chunk_flows[op.id].0,
                cpu_cost: base * (size / inf),
                gpu_cost: base * (inf / size),
                trans_cost: base_trans * (size / inf),
            }
        })
        .collect())
}

/// Algorithm 2's traversal over precomputed [`OpCandidate`] costs: line
/// 3's all-GPU default, then the greedy per-op choice with Eq. 9
/// boundary placement via the shared [`transfer_boundaries`] rule.
///
/// The coalesce staging share is charged on entering boundaries only for
/// genuinely chunked inputs — a single-chunk input coalesces as an O(1)
/// clone, mirroring [`DeviceModel::coalesce_time`]'s chunk-count gate —
/// using each op's **own** estimated input layout
/// (`OpCandidate::est_in_chunks`, DAG-propagated from the micro-batch's
/// chunk count through [`SizeEstimator::op_chunk_flows_for`]): an
/// interior boundary after an aggregate or sort prices a single-chunk
/// coalesce no matter how chunked the query input was, exactly as the
/// executor charges each op's actual assembled input.
///
/// [`DeviceModel::coalesce_time`]: crate::devices::model::DeviceModel::coalesce_time
pub fn select_devices(
    query: &Query,
    candidates: &[OpCandidate],
) -> Result<PhysicalPlan> {
    let n = query.ops.len();
    if n == 0 {
        return Err(Error::Plan("cannot plan an empty query".into()));
    }
    if candidates.len() != n {
        return Err(Error::Plan(format!(
            "candidate costs cover {} ops, query has {n}",
            candidates.len()
        )));
    }
    let order = query.topo_order()?;
    let consumers = query.consumers();
    // Line 3: initially, map every operation to the GPU.
    let mut plan = vec![Device::Gpu; n];

    // Line 4: traverse from the child node (topological order).
    for &o in &order {
        let c = &candidates[o];

        // Line 5 (Eqs. 7/8).
        let mut cpu_cost = c.cpu_cost;
        let mut gpu_cost = c.gpu_cost;

        // Lines 6-9 (Eq. 9): transition cost placement, via the shared
        // boundary rule. Producers are already mapped (topological
        // order); consumers still sit on the line-3 GPU default, so a
        // sink boundary is the only "leaving" case the planner sees —
        // exactly Alg. 2's first/last/device-switch placement.
        let (entering, leaving) =
            transfer_boundaries(&query.ops[o].inputs, &consumers[o], |i| {
                plan[i] == Device::Cpu
            });
        if entering || leaving {
            gpu_cost += c.trans_cost;
            if entering && c.est_in_chunks > 1 {
                // A GPU op's chunked input must be staged contiguously
                // before crossing host→device (ChunkedBatch::coalesce):
                // charge the staging share alongside Eq. 9, mirroring
                // the executor's DeviceModel::coalesce_time so planner
                // and executor see the same boundary economics. A
                // single-chunk input coalesces as an O(1) clone — free.
                gpu_cost += COALESCE_TRANS_SHARE * c.trans_cost;
            }
        } else {
            cpu_cost += c.trans_cost;
        }

        // Lines 10-11.
        if gpu_cost > cpu_cost {
            plan[o] = Device::Cpu;
        }
    }
    Ok(PhysicalPlan {
        per_op: query
            .ops
            .iter()
            .map(|op| PhysicalOp {
                op_id: op.id,
                kind: op.spec.kind(),
                device: plan[op.id],
                est_bytes: candidates[op.id].est_bytes,
            })
            .collect(),
    })
}

/// Algorithm 2: map each operation to CPU or GPU, producing the
/// physical plan (device + size annotation per op). Composes
/// [`op_candidates`] (Eq. 7/8/9 costing) with [`select_devices`]
/// (boundary placement + greedy choice).
///
/// * `part_bytes` — `Part_(i,j)`: per-partition data size of this
///   micro-batch (mean partition over the topology's total cores; Spark
///   plans once per batch),
/// * `inf_pt` — `InfPT_i` in bytes,
/// * `base_trans` — `baseTransCost` (initially 0.1, §III-D),
/// * `input_chunks` — chunk count of the micro-batch (seeds the per-op
///   chunk propagation gating entering coalesce shares; see
///   [`select_devices`]).
///
/// Errors with [`Error::Plan`] on an empty or cyclic query instead of
/// panicking — plan before `validate()` at your peril no longer.
pub fn map_device(
    query: &Query,
    part_bytes: f64,
    inf_pt: f64,
    base_trans: f64,
    estimator: &SizeEstimator,
    input_chunks: usize,
) -> Result<PhysicalPlan> {
    let candidates =
        op_candidates(query, part_bytes, inf_pt, base_trans, estimator, input_chunks)?;
    select_devices(query, &candidates)
}

/// The FineStream-like comparator of §V-D / Fig. 10: device per operation
/// fixed by Table II's initial preference (neutral ops keep the all-GPU
/// default), ignoring data size.
pub fn static_preference_plan(query: &Query) -> PhysicalPlan {
    PhysicalPlan {
        per_op: query
            .ops
            .iter()
            .map(|op| PhysicalOp {
                op_id: op.id,
                kind: op.spec.kind(),
                device: BaseCost::initial_preference(op.spec.kind())
                    .unwrap_or(Device::Gpu),
                est_bytes: 0.0,
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ops::filter::Predicate;
    use crate::engine::window::WindowSpec;
    use crate::query::builder::QueryBuilder;
    use std::time::Duration;

    const KB: f64 = 1024.0;

    fn spj() -> Query {
        QueryBuilder::scan("spj")
            .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
            .filter("key", Predicate::Ge(0.0))
            .project_affine("a", "b", 1.0, 1.0, "out")
            .join_window("k", "k")
            .build()
            .unwrap()
    }

    fn devices(plan: &PhysicalPlan) -> Vec<Device> {
        plan.per_op.iter().map(|o| o.device).collect()
    }

    #[test]
    fn small_partitions_map_to_cpu() {
        let q = spj();
        let est = SizeEstimator::new(q.len());
        let plan = map_device(&q, 10.0 * KB, 150.0 * KB, 0.1, &est, 4).unwrap();
        // Part ≪ InfPT ⇒ CPU cost (S/I) tiny, GPU cost (I/S) huge.
        assert!(plan.per_op.iter().all(|o| o.device == Device::Cpu), "{plan:?}");
    }

    #[test]
    fn large_partitions_map_to_gpu() {
        let q = spj();
        let est = SizeEstimator::new(q.len());
        let plan = map_device(&q, 4096.0 * KB, 150.0 * KB, 0.1, &est, 4).unwrap();
        assert!(plan.per_op.iter().all(|o| o.device == Device::Gpu), "{plan:?}");
    }

    #[test]
    fn empty_query_is_plan_error_not_panic() {
        let q = Query {
            name: "e".into(),
            ops: vec![],
            window: WindowSpec::tumbling(Duration::from_secs(30)),
            uses_window_state: false,
        };
        let est = SizeEstimator::new(0);
        let r = map_device(&q, 10.0 * KB, 150.0 * KB, 0.1, &est, 4);
        assert!(matches!(r, Err(Error::Plan(_))), "{r:?}");
    }

    #[test]
    fn plan_carries_size_estimates() {
        let q = spj();
        let est = SizeEstimator::new(q.len());
        let plan = map_device(&q, 64.0 * KB, 150.0 * KB, 0.1, &est, 4).unwrap();
        assert!(plan.per_op.iter().all(|o| o.est_bytes >= 64.0 * KB));
        assert_eq!(plan.per_op[3].kind, OpKind::Join);
    }

    #[test]
    fn learned_amplification_flips_downstream_ops() {
        let q = spj();
        let mut est = SizeEstimator::new(q.len());
        // Teach the estimator that the join (op 3) amplifies 50x: feed
        // traces where op 2's output explodes into op 3.
        for _ in 0..10 {
            est.observe(&[
                OpTrace { op_id: 0, kind: OpKind::Scan, device: Device::Cpu, time: Duration::ZERO, in_bytes: 10_000, out_bytes: 10_000 },
                OpTrace { op_id: 1, kind: OpKind::Filter, device: Device::Cpu, time: Duration::ZERO, in_bytes: 10_000, out_bytes: 10_000 },
                OpTrace { op_id: 2, kind: OpKind::Project, device: Device::Cpu, time: Duration::ZERO, in_bytes: 10_000, out_bytes: 500_000 },
                OpTrace { op_id: 3, kind: OpKind::Join, device: Device::Cpu, time: Duration::ZERO, in_bytes: 500_000, out_bytes: 500_000 },
            ]);
        }
        // Small source partition, but the estimated join input (50x) is
        // far beyond the inflection point: join goes GPU, scan stays CPU.
        let plan = map_device(&q, 10.0 * KB, 150.0 * KB, 0.1, &est, 4).unwrap();
        assert_eq!(plan.device(0), Device::Cpu);
        assert_eq!(plan.device(3), Device::Gpu, "{plan:?}");
    }

    #[test]
    fn transition_cost_discourages_lone_gpu_hop() {
        // At sizes just above the inflection point, a single op
        // sandwiched between CPU ops pays entry transfer; the margin
        // decides. With large base_trans the hop should not happen.
        let q = spj();
        let est = SizeEstimator::new(q.len());
        let plan_cheap = map_device(&q, 160.0 * KB, 150.0 * KB, 0.0, &est, 4).unwrap();
        let plan_dear = map_device(&q, 160.0 * KB, 150.0 * KB, 10.0, &est, 4).unwrap();
        assert!(
            plan_dear.gpu_ops() <= plan_cheap.gpu_ops(),
            "{plan_cheap:?} vs {plan_dear:?}"
        );
    }

    #[test]
    fn inflection_point_is_the_decision_boundary() {
        let q = spj();
        let est = SizeEstimator::new(q.len());
        // Same partition size, two inflection points straddling it.
        let low_inf = map_device(&q, 100.0 * KB, 50.0 * KB, 0.1, &est, 4).unwrap();
        let high_inf = map_device(&q, 100.0 * KB, 200.0 * KB, 0.1, &est, 4).unwrap();
        assert!(low_inf.gpu_ops() > high_inf.gpu_ops());
    }

    #[test]
    fn entering_boundary_charges_coalesce_staging_share() {
        // Single scan: both entering and leaving. At 1.5x the inflection
        // point with base_trans 0.4, Eq. 9 alone would leave it on GPU
        // (0.8/1.5 + 0.4·1.5 ≈ 1.13 < 1.2); the entering coalesce share
        // (+0.25 · 0.4 · 1.5 = 0.15) tips it to CPU. A cheaper
        // transition cost keeps it on GPU.
        let q = QueryBuilder::scan("s").build().unwrap();
        let est = SizeEstimator::new(q.len());
        let inf = 100.0 * KB;
        let dear = map_device(&q, 1.5 * inf, inf, 0.4, &est, 4).unwrap();
        assert_eq!(dear.device(0), Device::Cpu, "{dear:?}");
        let cheap = map_device(&q, 1.5 * inf, inf, 0.3, &est, 4).unwrap();
        assert_eq!(cheap.device(0), Device::Gpu, "{cheap:?}");
    }

    #[test]
    fn single_chunk_input_skips_coalesce_share() {
        // Same dear-transition scenario as above, but the micro-batch is
        // a single chunk: the real backend's coalesce is an O(1) clone,
        // so the staging share is not charged and the op stays on GPU —
        // mirroring DeviceModel::coalesce_time's chunk-count gate.
        let q = QueryBuilder::scan("s").build().unwrap();
        let est = SizeEstimator::new(q.len());
        let inf = 100.0 * KB;
        let single = map_device(&q, 1.5 * inf, inf, 0.4, &est, 1).unwrap();
        assert_eq!(single.device(0), Device::Gpu, "{single:?}");
        let chunked = map_device(&q, 1.5 * inf, inf, 0.4, &est, 2).unwrap();
        assert_eq!(chunked.device(0), Device::Cpu, "{chunked:?}");
    }

    #[test]
    fn candidate_selection_split_equals_composed_map_device() {
        // op_candidates + select_devices is exactly map_device — the
        // scheduler reuses, not re-derives, Eq. 7–9.
        let q = spj();
        let est = SizeEstimator::new(q.len());
        for part in [10.0 * KB, 64.0 * KB, 400.0 * KB] {
            let cands = op_candidates(&q, part, 150.0 * KB, 0.1, &est, 4).unwrap();
            let split = select_devices(&q, &cands).unwrap();
            let composed = map_device(&q, part, 150.0 * KB, 0.1, &est, 4).unwrap();
            assert_eq!(split, composed);
        }
    }

    #[test]
    fn candidates_carry_eq789_costs() {
        let q = spj();
        let est = SizeEstimator::new(q.len());
        let inf = 150.0 * KB;
        let part = 64.0 * KB;
        let cands = op_candidates(&q, part, inf, 0.1, &est, 4).unwrap();
        assert_eq!(cands.len(), q.len());
        for c in &cands {
            // Identity ratios: every op processes `part` bytes; the spj
            // chain has no re-chunking op, so every input keeps the
            // micro-batch's 4-chunk layout.
            assert_eq!(c.est_bytes, part);
            assert_eq!(c.est_in_chunks, 4);
            let base = BaseCost::cost(c.kind);
            assert!((c.cpu_cost - base * part / inf).abs() < 1e-12);
            assert!((c.gpu_cost - base * inf / part).abs() < 1e-12);
            assert!((c.trans_cost - 0.1 * part / inf).abs() < 1e-12);
        }
    }

    #[test]
    fn select_devices_checks_candidate_arity() {
        let q = spj();
        let est = SizeEstimator::new(q.len());
        let cands = op_candidates(&q, 64.0 * KB, 150.0 * KB, 0.1, &est, 4).unwrap();
        assert!(select_devices(&q, &cands[..1]).is_err());
    }

    #[test]
    fn chunk_flows_propagate_re_chunking_ops() {
        // scan (4) -> aggregate (in 4, out 1) -> sort (in 1, out 1).
        let q = QueryBuilder::scan("agg")
            .aggregate(&["k"], vec![], None)
            .sort("x", false)
            .build()
            .unwrap();
        let est = SizeEstimator::new(q.len());
        let flows = est.op_chunk_flows_for(&q, 4);
        assert_eq!(flows, vec![(4, 4), (4, 1), (1, 1)]);
        // Diamond: the union's input sums both branch layouts.
        let d = QueryBuilder::scan("d")
            .merge_union(|b| b.filter("x", Predicate::Ge(0.0)))
            .build()
            .unwrap();
        let est = SizeEstimator::new(d.len());
        let flows = est.op_chunk_flows_for(&d, 3);
        assert_eq!(flows[2].0, 6, "union input = sum of branch chunk lists");
    }

    /// The aggregate-then-GPU pin: an interior CPU→GPU boundary after a
    /// re-chunking op (aggregate emits one chunk) must price the
    /// coalesce share by the op's *own* single-chunk input — so the plan
    /// is identical whether the micro-batch arrived as 1 chunk or 4, and
    /// the downstream op stays on the GPU where charging the query
    /// input's chunk count would have flipped it to CPU.
    #[test]
    fn interior_boundary_priced_by_op_output_chunk_count() {
        // scan -> aggregate -> sort, with a learned 7.5x sort-side
        // amplification: scan/aggregate see 0.2x the inflection point
        // (firmly CPU), the sort's processed size is 1.5x — the margin
        // where the staging share is decisive (see
        // entering_boundary_charges_coalesce_staging_share).
        let q = QueryBuilder::scan("agg-gpu")
            .aggregate(&["k"], vec![], None)
            .sort("x", false)
            .build()
            .unwrap();
        let mut est = SizeEstimator::new(q.len());
        for _ in 0..10 {
            est.observe(&[
                OpTrace { op_id: 0, kind: OpKind::Scan, device: Device::Cpu, time: Duration::ZERO, in_bytes: 10_000, out_bytes: 10_000 },
                OpTrace { op_id: 1, kind: OpKind::Aggregate, device: Device::Cpu, time: Duration::ZERO, in_bytes: 10_000, out_bytes: 10_000 },
                OpTrace { op_id: 2, kind: OpKind::Sort, device: Device::Cpu, time: Duration::ZERO, in_bytes: 10_000, out_bytes: 75_000 },
            ]);
        }
        let inf = 100.0 * KB;
        let chunked = map_device(&q, 0.2 * inf, inf, 0.4, &est, 4).unwrap();
        let single = map_device(&q, 0.2 * inf, inf, 0.4, &est, 1).unwrap();
        assert_eq!(chunked, single, "interior boundaries must not see the input layout");
        assert_eq!(chunked.device(0), Device::Cpu, "{chunked:?}");
        assert_eq!(chunked.device(1), Device::Cpu, "{chunked:?}");
        assert_eq!(
            chunked.device(2),
            Device::Gpu,
            "sort's single-chunk (post-aggregate) input must not be charged staging: {chunked:?}"
        );
    }

    #[test]
    fn static_plan_follows_table_two() {
        let q = QueryBuilder::scan("t")
            .filter("x", Predicate::Ge(0.0))
            .expand()
            .shuffle("k")
            .aggregate(&["k"], vec![], None)
            .sort("x", false)
            .build()
            .unwrap();
        let plan = static_preference_plan(&q);
        assert_eq!(
            devices(&plan),
            vec![
                Device::Gpu, // scan
                Device::Cpu, // filter
                Device::Gpu, // expand (neutral -> default)
                Device::Cpu, // shuffle
                Device::Cpu, // aggregate
                Device::Gpu, // sort
            ]
        );
    }

    #[test]
    fn base_costs_match_table_two() {
        assert_eq!(BaseCost::cost(OpKind::Aggregate), 1.0);
        assert_eq!(BaseCost::cost(OpKind::Join), 0.9);
        assert_eq!(BaseCost::cost(OpKind::Scan), 0.8);
    }

    #[test]
    fn size_estimator_defaults_to_identity() {
        let est = SizeEstimator::new(3);
        assert_eq!(est.op_sizes(100.0), vec![100.0, 100.0, 100.0]);
    }

    #[test]
    fn amplifying_op_judged_by_its_output() {
        let mut est = SizeEstimator::new(2);
        est.observe(&[
            OpTrace { op_id: 0, kind: OpKind::Scan, device: Device::Cpu, time: Duration::ZERO, in_bytes: 100, out_bytes: 100 },
            OpTrace { op_id: 1, kind: OpKind::Join, device: Device::Cpu, time: Duration::ZERO, in_bytes: 100, out_bytes: 3000 },
        ]);
        let sizes = est.op_sizes(100.0);
        assert_eq!(sizes[0], 100.0);
        assert!((sizes[1] - 3000.0).abs() < 1.0, "{sizes:?}");
    }

    #[test]
    fn dag_sizes_match_chain_sizes_on_chains() {
        let q = spj();
        let mut est = SizeEstimator::new(q.len());
        est.observe(&[
            OpTrace { op_id: 1, kind: OpKind::Filter, device: Device::Cpu, time: Duration::ZERO, in_bytes: 1000, out_bytes: 500 },
            OpTrace { op_id: 3, kind: OpKind::Join, device: Device::Cpu, time: Duration::ZERO, in_bytes: 500, out_bytes: 5000 },
        ]);
        assert_eq!(est.op_sizes_for(&q, 100.0 * KB), est.op_sizes(100.0 * KB));
    }

    #[test]
    fn union_input_sums_branch_outputs() {
        // Diamond: scan -> {direct, filter} -> union. The union's
        // processed size is the sum of both branch outputs.
        let q = QueryBuilder::scan("d")
            .merge_union(|b| b.filter("x", Predicate::Ge(0.0)))
            .build()
            .unwrap();
        let est = SizeEstimator::new(q.len());
        let sizes = est.op_sizes_for(&q, 100.0);
        // ratios default 1.0: scan out 100, filter out 100, union in 200.
        assert_eq!(sizes[2], 200.0);
    }
}
