//! `ConstructMicroBatch` — Algorithm 1.
//!
//! Replaces the static trigger: a temporary micro-batch (pending buffered
//! data ∪ newly polled data) is admitted exactly when its *estimated* max
//! dataset latency (Eq. 6) reaches the window-derived bound — the window
//! slide time for sliding windows (Eq. 2), the running average of past
//! max-latencies for tumbling windows (Eq. 3). Otherwise it is canceled
//! and keeps buffering.
//!
//! The throughput feeding Eq. 6 comes from `Metrics::avg_throughput`,
//! whose per-batch `proc`s are recorded by the session's scheduling
//! rounds: a query co-scheduled with others (any source) carries its
//! share of the *contended* round makespan, so admission estimates are
//! honest under load — a loaded device makes batches admit sooner, not
//! on idle-device fictions.

use crate::engine::dataset::{Dataset, MicroBatch};
use crate::engine::window::{WindowKind, WindowSpec};
use crate::sim::Time;
use std::time::Duration;

/// Outcome of one admission round (the Alg. 1 result triple, with the
/// canceled batch kept internally as `bufferedFiles`).
#[derive(Debug)]
pub enum AdmissionDecision {
    /// No new data and nothing admissible: keep polling.
    Poll,
    /// Micro-batch admitted for immediate processing.
    Admit(MicroBatch),
    /// Temporary micro-batch canceled (data re-buffered); carries the
    /// estimated latency that fell short of the bound.
    Buffer { est_max_lat: Duration },
}

/// The `AvgThPut_(i-1)` a multi-query source should feed Eq. 6: the
/// **minimum** positive observed throughput across its registered
/// queries — the slowest query dominates how long the batch will really
/// take, so the latency estimate must be sized by it. Queries with no
/// history yet (estimate `<= 0`) are skipped; with no history anywhere,
/// falls back to `initial` (the configured bootstrap throughput).
///
/// Because [`Admission::estimate_max_latency`] is anti-monotone in the
/// throughput, using the minimum yields the **largest** (most
/// conservative) estimate: admission under the shared estimate is at
/// least as eager as under any single query's — pinned by
/// `prop_shared_throughput_is_tightest` in `tests/prop_coordinator.rs`.
pub fn min_positive_throughput(
    estimates: impl IntoIterator<Item = f64>,
    initial: f64,
) -> f64 {
    let mut min: Option<f64> = None;
    for e in estimates {
        if e > 0.0 {
            min = Some(match min {
                None => e,
                Some(m) => m.min(e),
            });
        }
    }
    min.unwrap_or(initial)
}

/// Admission controller state.
pub struct Admission {
    window: WindowSpec,
    buffered: MicroBatch,
    /// Bootstrap bound for the tumbling rule before any history exists.
    initial_avg_bound: Duration,
}

impl Admission {
    pub fn new(window: WindowSpec, initial_avg_bound: Duration) -> Admission {
        Admission {
            window,
            buffered: MicroBatch::default(),
            initial_avg_bound,
        }
    }

    /// Rows currently re-buffered from canceled batches.
    pub fn buffered_datasets(&self) -> usize {
        self.buffered.num_datasets()
    }

    /// Flush everything buffered as a forced micro-batch, bypassing the
    /// Eq. 6 estimate. Event-time sessions use this when the source
    /// watermark crosses a window-close boundary: the window the data
    /// belongs to is complete in event time, so holding it longer only
    /// adds latency — the window term of the admission rule follows
    /// watermark progress, not the wall clock.
    pub fn take_buffered(&mut self) -> MicroBatch {
        std::mem::take(&mut self.buffered)
    }

    /// Return an admitted batch to the buffer, un-admitting it: the
    /// sharded runtime's per-shard quota vetoes an over-budget
    /// admission *after* Alg. 1 said yes (Eq. 6 bounds latency, quotas
    /// bound *share*), and the data must keep buffering rather than be
    /// dropped — it re-merges with whatever buffered since and is
    /// re-offered next round.
    pub fn restore(&mut self, mb: MicroBatch) {
        self.buffered.absorb(mb);
    }

    /// Eq. 6: `EstMaxLat_i = max_j Buff_(i,j) + Σ_j Part_(i,j) / AvgThPut_(i-1)`.
    pub fn estimate_max_latency(
        tmp: &MicroBatch,
        now: Time,
        avg_thput_bytes_per_sec: f64,
    ) -> Duration {
        let max_buff = tmp
            .oldest_created_at()
            .map(|t| now.saturating_sub(t))
            .unwrap_or(Duration::ZERO);
        let est_proc = Duration::from_secs_f64(
            tmp.wire_bytes() as f64 / avg_thput_bytes_per_sec.max(1.0),
        );
        max_buff + est_proc
    }

    /// The latency bound currently in force (Eq. 2 or Eq. 3's RHS).
    pub fn bound(&self, past_max_lat_avg: Option<Duration>) -> Duration {
        match self.window.kind() {
            WindowKind::Sliding => self.window.slide_time(),
            WindowKind::Tumbling => past_max_lat_avg.unwrap_or(self.initial_avg_bound),
        }
    }

    /// One `ConstructMicroBatch()` round (Alg. 1).
    ///
    /// * `new_data` — freshly polled datasets (`newFiles`),
    /// * `now` — current time,
    /// * `avg_thput` — `AvgThPut_(i-1)` in bytes/s (Eq. 4),
    /// * `past_max_lat_avg` — running average of `MaxLat_k` (Eq. 3 RHS),
    ///   `None` before the first batch completes.
    pub fn construct(
        &mut self,
        new_data: Vec<Dataset>,
        now: Time,
        avg_thput: f64,
        past_max_lat_avg: Option<Duration>,
    ) -> AdmissionDecision {
        let bound = self.bound(past_max_lat_avg);
        self.construct_with_bound(new_data, now, avg_thput, bound)
    }

    /// `ConstructMicroBatch()` against an explicit latency bound. A
    /// [`crate::session::Session`] multiplexing several queries over one
    /// source admits against the *tightest* bound across those queries;
    /// single-query callers use [`Admission::construct`], which derives
    /// the bound from this admission's own window (Eq. 2/3).
    pub fn construct_with_bound(
        &mut self,
        mut new_data: Vec<Dataset>,
        now: Time,
        avg_thput: f64,
        bound: Duration,
    ) -> AdmissionDecision {
        if new_data.is_empty() && self.buffered.is_empty() {
            return AdmissionDecision::Poll; // line 2-3: keep polling
        }
        // Lines 4-7: sort new files by creation time, merge with buffered.
        new_data.sort_by_key(|d| (d.created_at, d.id));
        let mut tmp = std::mem::take(&mut self.buffered);
        tmp.absorb(MicroBatch::new(new_data));

        let est = Self::estimate_max_latency(&tmp, now, avg_thput);

        if est >= bound {
            // Lines 9-11 / 13-15: process immediately, clear buffer.
            AdmissionDecision::Admit(tmp)
        } else {
            // Lines 16-17: cancel, keep buffering.
            self.buffered = tmp;
            AdmissionDecision::Buffer { est_max_lat: est }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn ds(id: u64, t: f64, rows: usize) -> Dataset {
        let schema = Schema::new(vec![Field::f32("x")]);
        let batch =
            ColumnBatch::new(schema, vec![Column::F32(vec![0.0; rows].into())]).unwrap();
        let bytes = batch.alloc_bytes();
        Dataset {
            id,
            created_at: Time::from_secs_f64(t),
            event_time: Time::from_secs_f64(t),
            batch,
            wire_bytes: bytes,
        }
    }

    fn sliding(slide_secs: u64) -> Admission {
        Admission::new(
            WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(slide_secs)),
            Duration::from_secs(1),
        )
    }

    fn tumbling() -> Admission {
        Admission::new(
            WindowSpec::tumbling(Duration::from_secs(30)),
            Duration::from_secs(1),
        )
    }

    #[test]
    fn no_data_keeps_polling() {
        let mut a = sliding(5);
        let d = a.construct(vec![], Time::ZERO, 1e6, None);
        assert!(matches!(d, AdmissionDecision::Poll));
    }

    #[test]
    fn below_bound_buffers() {
        let mut a = sliding(5);
        // Tiny data, huge throughput: est latency ≈ buffering only (0s).
        match a.construct(vec![ds(0, 0.0, 10)], Time::from_secs_f64(0.1), 1e9, None) {
            AdmissionDecision::Buffer { est_max_lat } => {
                assert!(est_max_lat < Duration::from_secs(5));
            }
            other => panic!("expected buffer, got {other:?}"),
        }
        assert_eq!(a.buffered_datasets(), 1);
    }

    #[test]
    fn sliding_admits_when_estimate_reaches_slide() {
        let mut a = sliding(5);
        // Oldest dataset has buffered 6 s > slide 5 s.
        let d = a.construct(vec![ds(0, 0.0, 10)], Time::from_secs_f64(6.0), 1e9, None);
        match d {
            AdmissionDecision::Admit(mb) => assert_eq!(mb.num_datasets(), 1),
            other => panic!("expected admit, got {other:?}"),
        }
        assert_eq!(a.buffered_datasets(), 0);
    }

    #[test]
    fn slow_throughput_admits_early() {
        let mut a = sliding(5);
        // 1 KB at 100 B/s → est proc 10 s ≥ bound even with zero buffering.
        let d = a.construct(vec![ds(0, 0.0, 250)], Time::ZERO, 100.0, None);
        assert!(matches!(d, AdmissionDecision::Admit(_)));
    }

    #[test]
    fn buffered_data_rejoins_next_round() {
        let mut a = sliding(5);
        assert!(matches!(
            a.construct(vec![ds(0, 0.0, 10)], Time::from_secs_f64(0.1), 1e9, None),
            AdmissionDecision::Buffer { .. }
        ));
        // Second round: new data joins the buffered dataset; admitted
        // batch contains both, creation-ordered.
        match a.construct(vec![ds(1, 1.0, 10)], Time::from_secs_f64(6.0), 1e9, None) {
            AdmissionDecision::Admit(mb) => {
                assert_eq!(mb.num_datasets(), 2);
                assert_eq!(mb.datasets[0].id, 0);
            }
            other => panic!("expected admit, got {other:?}"),
        }
    }

    #[test]
    fn explicit_bound_overrides_window_rule() {
        let mut a = sliding(5);
        // A co-registered query tightens the shared bound to 1 s: data
        // buffered 2 s admits even though the slide bound is 5 s.
        let d = a.construct_with_bound(
            vec![ds(0, 0.0, 10)],
            Time::from_secs_f64(2.0),
            1e9,
            Duration::from_secs(1),
        );
        assert!(matches!(d, AdmissionDecision::Admit(_)));
    }

    #[test]
    fn tumbling_uses_running_average_bound() {
        let mut a = tumbling();
        let past = Some(Duration::from_secs(3));
        // est ≈ 2 s buffering < 3 s average → buffer.
        assert!(matches!(
            a.construct(vec![ds(0, 0.0, 10)], Time::from_secs_f64(2.0), 1e9, past),
            AdmissionDecision::Buffer { .. }
        ));
        // est ≈ 4 s ≥ 3 s → admit.
        assert!(matches!(
            a.construct(vec![], Time::from_secs_f64(4.0), 1e9, past),
            AdmissionDecision::Admit(_)
        ));
    }

    #[test]
    fn tumbling_bootstrap_bound() {
        let a = tumbling();
        assert_eq!(a.bound(None), Duration::from_secs(1));
        assert_eq!(a.bound(Some(Duration::from_secs(7))), Duration::from_secs(7));
    }

    #[test]
    fn min_positive_throughput_skips_unobserved_queries() {
        assert_eq!(min_positive_throughput([3e4, 1e4, 2e4], 5e4), 1e4);
        assert_eq!(min_positive_throughput([0.0, 2e4], 5e4), 2e4);
        assert_eq!(min_positive_throughput([0.0, 0.0], 5e4), 5e4);
        assert_eq!(min_positive_throughput(std::iter::empty(), 5e4), 5e4);
    }

    #[test]
    fn estimate_combines_buffering_and_processing() {
        let mb = MicroBatch::new(vec![ds(0, 0.0, 100)]);
        let bytes = mb.wire_bytes() as f64;
        let est = Admission::estimate_max_latency(&mb, Time::from_secs_f64(2.0), bytes);
        // 2 s buffered + bytes/bytes-per-sec = 1 s.
        assert!((est.as_secs_f64() - 3.0).abs() < 1e-9);
    }
}
