//! Online cost-model parameter optimization — §III-E, Eq. 10.
//!
//! After every micro-batch the coordinator records
//! `(AvgThPut_i, MaxLat_i, InfPT_i)` — per source, from its primary
//! query's latest record, whose `MaxLat` embeds the session round's
//! *contended* makespan (shared per-executor GPU timelines), so the fit
//! learns the inflection point of the loaded system, not of a private
//! idle device; a background worker fits
//!
//! ```text
//! InflectionPoint = β0 + β1·Throughput + β2·Latency        (Eq. 10)
//! ```
//!
//! by (ridge-regularized) least squares on that history, then predicts the
//! next inflection point at the *target* operating point: target
//! throughput = max observed so far, target latency = the admission bound
//! (slide time under Eq. 2, running average under Eq. 3). The fit runs
//! asynchronously on a worker thread — the paper overlaps it with
//! checkpointing/state-flush after query completion; the driver measures
//! any residual wait as "Optimization Blocking" (Table IV).
//!
//! Interpretation note (documented in DESIGN.md): with a perfectly
//! constant history the regression is degenerate — the paper does not
//! specify its escape; we add ridge damping plus a small deterministic
//! exploration jitter on the *applied* inflection point so the history
//! carries usable signal, and clamp predictions to a sane byte range.

use crate::util::exec::Worker;
use crate::util::rng::Rng;
use crate::util::stats::ols2;
use std::time::Duration;

/// Inflection-point clamp range (bytes).
pub const INF_PT_MIN: f64 = 1024.0;
pub const INF_PT_MAX: f64 = 64.0 * 1024.0 * 1024.0;

/// One per-batch observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistoryPoint {
    /// `AvgThPut_i` (bytes/s).
    pub throughput: f64,
    /// `MaxLat_i` (seconds).
    pub max_latency: f64,
    /// `InfPT_i` used by that batch (bytes).
    pub inf_pt: f64,
}

/// A regression job: the history snapshot plus the target operating point.
#[derive(Clone, Debug)]
pub struct FitJob {
    pub history: Vec<HistoryPoint>,
    pub target_throughput: f64,
    pub target_latency: f64,
}

/// Pure fit: Eq. 10 coefficients from history, evaluated at the target.
/// `None` when the history is too short or degenerate even under ridge.
pub fn fit_inflection(job: &FitJob) -> Option<f64> {
    let n = job.history.len();
    if n < 3 {
        return None;
    }
    // Normalize features to keep the normal equations well-scaled.
    let t_scale = job
        .history
        .iter()
        .map(|h| h.throughput.abs())
        .fold(f64::MIN_POSITIVE, f64::max);
    let l_scale = job
        .history
        .iter()
        .map(|h| h.max_latency.abs())
        .fold(f64::MIN_POSITIVE, f64::max);
    let x1: Vec<f64> = job.history.iter().map(|h| h.throughput / t_scale).collect();
    let x2: Vec<f64> = job.history.iter().map(|h| h.max_latency / l_scale).collect();
    let y: Vec<f64> = job.history.iter().map(|h| h.inf_pt).collect();
    let [b0, b1, b2] = ols2(&x1, &x2, &y, 1e-6)?;
    let pred = b0 + b1 * (job.target_throughput / t_scale)
        + b2 * (job.target_latency / l_scale);
    if !pred.is_finite() {
        return None;
    }
    Some(pred.clamp(INF_PT_MIN, INF_PT_MAX))
}

/// Asynchronous optimizer wrapper.
pub struct OnlineOptimizer {
    worker: Option<Worker<FitJob, Option<f64>>>,
    history: Vec<HistoryPoint>,
    history_cap: Option<usize>,
    rng: Rng,
    enabled: bool,
    max_thput_seen: f64,
}

impl OnlineOptimizer {
    /// `history_cap = None` keeps full history (the paper's default); the
    /// last-N policy is its §III-E future-work extension (ablated in
    /// `benches/ablation_optimizer.rs`).
    pub fn new(enabled: bool, history_cap: Option<usize>, seed: u64) -> OnlineOptimizer {
        let worker = if enabled {
            Some(Worker::spawn("lmstream-optimizer", |job: FitJob| {
                fit_inflection(&job)
            }))
        } else {
            None
        };
        OnlineOptimizer {
            worker,
            history: Vec::new(),
            history_cap,
            rng: Rng::new(seed ^ 0x0971_1235_u64),
            enabled,
            max_thput_seen: 0.0,
        }
    }

    pub fn history_len(&self) -> usize {
        self.history.len()
    }

    /// Full recorded history (checkpointing).
    pub fn history(&self) -> &[HistoryPoint] {
        &self.history
    }

    /// Record a completed batch and kick off an asynchronous refit.
    pub fn record(&mut self, point: HistoryPoint, target_latency: Duration) {
        self.max_thput_seen = self.max_thput_seen.max(point.throughput);
        self.history.push(point);
        if let Some(cap) = self.history_cap {
            let len = self.history.len();
            if len > cap {
                self.history.drain(0..len - cap);
            }
        }
        if let Some(w) = &self.worker {
            w.submit(FitJob {
                history: self.history.clone(),
                target_throughput: self.max_thput_seen,
                target_latency: target_latency.as_secs_f64(),
            });
        }
    }

    /// Collect the freshest fit before the next planning round; returns
    /// `(new_inf_pt, blocked)` where `blocked` is the wall time spent
    /// waiting on the worker (Table IV's "Optimization Blocking").
    pub fn take(&mut self, current: f64, timeout: Duration) -> (f64, Duration) {
        let Some(w) = &self.worker else {
            return (current, Duration::ZERO);
        };
        if self.history.len() < 3 {
            return (current, Duration::ZERO);
        }
        let (result, blocked) = w.wait_latest(timeout);
        let fitted = result.flatten().unwrap_or(current);
        // Exploration jitter (±4%) so the applied InfPT varies enough for
        // the regression to observe its effect.
        let jitter = 1.0 + (self.rng.f64() - 0.5) * 0.08;
        let applied = (fitted * jitter).clamp(INF_PT_MIN, INF_PT_MAX);
        (applied, blocked)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(points: Vec<(f64, f64, f64)>, tt: f64, tl: f64) -> FitJob {
        FitJob {
            history: points
                .into_iter()
                .map(|(t, l, i)| HistoryPoint { throughput: t, max_latency: l, inf_pt: i })
                .collect(),
            target_throughput: tt,
            target_latency: tl,
        }
    }

    #[test]
    fn fit_needs_three_points() {
        assert!(fit_inflection(&job(vec![(1.0, 1.0, 1e5); 2], 1.0, 1.0)).is_none());
    }

    #[test]
    fn fit_recovers_linear_relationship() {
        // InfPT = 1e5 + 2*thput - 1000*lat, exactly.
        let pts: Vec<(f64, f64, f64)> = (0..20)
            .map(|k| {
                let t = 1000.0 + 50.0 * k as f64;
                let l = 1.0 + 0.1 * ((k * 3) % 7) as f64;
                (t, l, 1e5 + 2.0 * t - 1000.0 * l)
            })
            .collect();
        let target_t = 2500.0;
        let target_l = 1.2;
        let want = 1e5 + 2.0 * target_t - 1000.0 * target_l;
        let got = fit_inflection(&job(pts, target_t, target_l)).unwrap();
        assert!((got - want).abs() / want < 0.01, "got {got}, want {want}");
    }

    #[test]
    fn degenerate_history_clamps_not_explodes() {
        let pts = vec![(1000.0, 1.0, 150.0 * 1024.0); 10];
        if let Some(v) = fit_inflection(&job(pts, 1200.0, 0.9)) {
            assert!((INF_PT_MIN..=INF_PT_MAX).contains(&v));
        }
    }

    #[test]
    fn prediction_clamped_to_range() {
        // Steep slope pushing prediction far negative.
        let pts: Vec<(f64, f64, f64)> = (0..10)
            .map(|k| (100.0 + k as f64, 1.0, 1e6 - 1e5 * k as f64))
            .collect();
        let got = fit_inflection(&job(pts, 1e6, 1.0)).unwrap();
        assert!((INF_PT_MIN..=INF_PT_MAX).contains(&got));
    }

    #[test]
    fn async_round_trip_updates_inflection() {
        let mut opt = OnlineOptimizer::new(true, None, 42);
        for k in 0..12 {
            let t = 1000.0 + 100.0 * k as f64;
            let l = 2.0 + 0.05 * ((k * 5) % 3) as f64;
            opt.record(
                HistoryPoint {
                    throughput: t,
                    max_latency: l,
                    inf_pt: 100_000.0 + 500.0 * k as f64,
                },
                Duration::from_secs(5),
            );
        }
        let (inf, _blocked) = opt.take(150_000.0, Duration::from_millis(500));
        assert!((INF_PT_MIN..=INF_PT_MAX).contains(&inf));
        assert!(opt.history_len() == 12);
    }

    #[test]
    fn disabled_optimizer_is_identity() {
        let mut opt = OnlineOptimizer::new(false, None, 1);
        opt.record(
            HistoryPoint { throughput: 1.0, max_latency: 1.0, inf_pt: 1e5 },
            Duration::from_secs(1),
        );
        let (inf, blocked) = opt.take(123_456.0, Duration::from_secs(1));
        assert_eq!(inf, 123_456.0);
        assert_eq!(blocked, Duration::ZERO);
    }

    #[test]
    fn history_cap_enforced() {
        let mut opt = OnlineOptimizer::new(false, Some(5), 1);
        for k in 0..20 {
            opt.record(
                HistoryPoint { throughput: k as f64, max_latency: 1.0, inf_pt: 1e5 },
                Duration::from_secs(1),
            );
        }
        assert_eq!(opt.history_len(), 5);
    }
}
