//! The LMStream coordinator — the paper's system contribution.
//!
//! * [`admission`] — `ConstructMicroBatch` (Alg. 1): latency-bounded
//!   dynamic batching,
//! * [`planner`] — `MapDevice` (Alg. 2): operation-level CPU/GPU planning
//!   around the inflection point (Eqs. 7–9, Table II),
//! * [`schedule`] — cross-query co-scheduling: one micro-batch planned
//!   jointly across a source's queries under a shared GPU timeline
//!   (reuses the planner's Eq. 7–9 candidate costs),
//! * [`timeline_bank`] — the sharded runtime's cross-shard GPU
//!   arbitration: sequential reservation leases over the per-executor
//!   timelines, so concurrent source shards never double-book a device,
//! * [`optimizer`] — asynchronous online regression of the inflection
//!   point (Eq. 10),
//! * [`metrics`] — Eqs. 4/5 bookkeeping, per-dataset latency, Table IV
//!   phase accounting,
//! * [`driver`] — single-query compatibility shims over the session
//!   ([`crate::session::Session`]), which hosts the micro-batch main
//!   loop, the baselines (static trigger + all-GPU) and the
//!   static-preference comparator.

pub mod admission;
pub mod checkpoint;
pub mod driver;
pub mod metrics;
pub mod optimizer;
pub mod planner;
pub mod schedule;
pub mod timeline_bank;

pub use admission::{Admission, AdmissionDecision};
pub use driver::{run, RunResult};
pub use metrics::{
    BatchRecord, ExecutorHealthStats, HealthReport, Metrics, PhaseTotals, ShardStats,
};
pub use optimizer::OnlineOptimizer;
pub use planner::{
    map_device, op_candidates, select_devices, static_preference_plan, BaseCost,
    OpCandidate, SizeEstimator,
};
pub use schedule::{
    executor_horizons, plan_joint, predict_fixed, JointPlan, Prediction, QueryCandidate,
};
pub use timeline_bank::{Lease, TimelineBank};
