//! System configuration: cluster resources, mechanism toggles, cost-model
//! initial values.
//!
//! Defaults mirror one executor of the paper's testbed (§V-A: 12 CPU
//! cores, 1 GPU, trigger 10 s for the baseline, inflection point
//! initialized to 150 KB, `baseTransCost` 0.1).

use crate::error::{Error, Result};
use std::time::Duration;

/// Which coordinator variant drives the run (the systems compared in §V).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Full LMStream: dynamic batching + dynamic device preference +
    /// online optimizer.
    LmStream,
    /// Throughput-oriented baseline: static trigger + all-GPU mapping
    /// (default Spark + Spark-Rapids per §IV).
    Baseline,
    /// Static trigger + all-CPU mapping (plain Spark — the Fig. 1
    /// motivation experiment ran without GPUs).
    BaselineCpu,
    /// LMStream batching but *static* device preference (the
    /// FineStream-like comparator of §V-D / Fig. 10).
    StaticPreference,
    /// Ablations: LMStream batching, all-GPU mapping.
    AllGpu,
    /// Ablations: LMStream batching, all-CPU mapping.
    AllCpu,
}

impl Mode {
    /// Parse a CLI token.
    pub fn parse(s: &str) -> Result<Mode> {
        match s {
            "lmstream" => Ok(Mode::LmStream),
            "baseline" => Ok(Mode::Baseline),
            "baseline-cpu" => Ok(Mode::BaselineCpu),
            "static" | "static-pref" => Ok(Mode::StaticPreference),
            "all-gpu" => Ok(Mode::AllGpu),
            "all-cpu" => Ok(Mode::AllCpu),
            other => Err(Error::Config(format!("unknown mode `{other}`"))),
        }
    }

    /// Display name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            Mode::LmStream => "LMStream",
            Mode::Baseline => "Baseline",
            Mode::BaselineCpu => "BaselineCpu",
            Mode::StaticPreference => "StaticPref",
            Mode::AllGpu => "AllGpu",
            Mode::AllCpu => "AllCpu",
        }
    }

    /// Trigger-driven buffering (the throughput-oriented method) rather
    /// than LMStream's admission control.
    pub fn uses_trigger(&self) -> bool {
        matches!(self, Mode::Baseline | Mode::BaselineCpu)
    }
}

/// What happens to a dataset whose event time is already behind the
/// source watermark (late beyond the allowed lateness) when event-time
/// processing is enabled ([`Config::allowed_lateness`] set).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum LatePolicy {
    /// Drop the dataset and count its rows (`BatchRecord::late_rows`).
    #[default]
    Drop,
    /// Route the dataset to the source's dedicated late sink
    /// (`Session::set_late_sink`) and count it; the primary output never
    /// sees it.
    SideOutput,
    /// Admit the dataset anyway: windows holding its event range
    /// recompute on the next firing (event-ordered window state makes
    /// the refined output identical to in-order delivery). Rows are
    /// still counted as late.
    Recompute,
}

impl LatePolicy {
    /// Parse a CLI token.
    pub fn parse(s: &str) -> Result<LatePolicy> {
        match s {
            "drop" => Ok(LatePolicy::Drop),
            "side-output" | "side_output" => Ok(LatePolicy::SideOutput),
            "recompute" => Ok(LatePolicy::Recompute),
            other => Err(Error::Config(format!("unknown late policy `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LatePolicy::Drop => "drop",
            LatePolicy::SideOutput => "side-output",
            LatePolicy::Recompute => "recompute",
        }
    }
}

/// Execution substrate for operator work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecBackend {
    /// Discrete-event simulation: operators transform data natively while
    /// *time* comes from the calibrated device model (paper-scale
    /// experiments; deterministic).
    Simulated,
    /// Real execution: CPU ops run natively, GPU-mapped ops run through
    /// the PJRT artifacts; wall-clock timing.
    Real,
}

/// Full system configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Coordinator variant.
    pub mode: Mode,
    /// Simulated vs real execution.
    pub backend: ExecBackend,
    /// CPU cores per application == number of data partitions (`NumCores`
    /// in Table I).
    pub num_cores: usize,
    /// GPUs available to the executor.
    pub num_gpus: usize,
    /// Baseline static trigger interval (§V-A: 10 s).
    pub trigger: Duration,
    /// Admission poll period (§III-A: 10 ms).
    pub poll_interval: Duration,
    /// Initial inflection point in bytes (§III-D: 150 KB).
    pub initial_inflection_bytes: f64,
    /// Initial `baseTransCost` (§III-D: 0.1).
    pub base_trans_cost: f64,
    /// Initial average throughput estimate (bytes/s) used before the first
    /// micro-batch completes (the paper seeds cost-model parameters from
    /// pre-experiments; §III-A).
    pub initial_throughput: f64,
    /// Enable the online optimizer (Eq. 10). Disabled for ablations.
    pub online_optimizer: bool,
    /// Joint cross-query planning (LMStream mode, multi-query rounds):
    /// plan each scheduling round across *every* admitted query — all
    /// sources, all executors — under the session's [`DeviceTopology`]
    /// (one simulated GPU timeline per executor) instead of per-query
    /// idle-GPU `MapDevice`. Disabled for ablations — execution still
    /// charges the shared per-executor GPU timelines either way (the
    /// device is shared physics, not policy).
    ///
    /// [`DeviceTopology`]: crate::cluster::DeviceTopology
    pub co_schedule: bool,
    /// Optimizer history cap (None = unbounded, the paper's default; the
    /// last-N policy is the paper's §III-E future-work extension).
    pub history_cap: Option<usize>,
    /// PRNG seed (traffic, exploration jitter).
    pub seed: u64,
    /// Artifact directory for the PJRT runtime (Real backend).
    pub artifact_dir: String,
    /// Multi-executor topology (None = the single-executor model the
    /// paper-figure benches calibrate against; `ClusterSpec::paper()` is
    /// the 4-executor §V-A testbed).
    pub cluster: Option<crate::cluster::ClusterSpec>,
    /// Checkpoint directory — when set, coordinator state is persisted
    /// after every micro-batch (§III-E's checkpointing/state-flush step)
    /// and restored on the next run of the same workload.
    pub checkpoint_dir: Option<String>,
    /// Write-ahead-log directory — when set, every admitted micro-batch
    /// is appended (length-prefixed, CRC-checksummed) and fsynced to a
    /// per-source log *before* execution, and sink deliveries are
    /// recorded in an exactly-once ledger; on restart the session
    /// reconciles checkpoint ⨯ WAL ⨯ ledger per [`Config::recovery_mode`].
    /// Unset = the pre-durability engine, byte-for-byte.
    pub wal_dir: Option<String>,
    /// How a restart treats logged-but-uncheckpointed micro-batches when
    /// [`Config::wal_dir`] is set (see
    /// [`RecoveryMode`](crate::durability::RecoveryMode)).
    pub recovery_mode: crate::durability::RecoveryMode,
    /// Per-source WAL size cap in bytes. Without checkpoints the log is
    /// never truncated (the ROADMAP's unbounded-growth caveat); when a
    /// source's log would exceed this cap the session surfaces a typed
    /// `Error::Durability` — except in `Gap` mode, where the log *rolls*
    /// (oldest frames dropped, the loss accounted by the next recovery)
    /// instead of filling the disk. `None` = unbounded (historical
    /// behavior).
    pub wal_max_bytes: Option<u64>,
    /// Deterministic executor fault schedule for this run (crashes,
    /// GPU-device faults, stalls, rejoins per round/executor). `None` =
    /// fault-free — the oracle the fault-tolerance harness differences
    /// against.
    pub fault_plan: Option<crate::cluster::FaultPlan>,
    /// How many failed attempts of one scheduling round the session
    /// retries (re-planning on the surviving topology each time) before
    /// surfacing `Error::Executor`.
    pub max_round_retries: usize,
    /// Base backoff charged to the round clock before retry attempt `k`
    /// as `retry_backoff * 2^(k-1)` (exponential).
    pub retry_backoff: Duration,
    /// Failure-detection latency (heartbeat timeout): charged to the
    /// round clock once per failed attempt, before backoff.
    pub failure_detection: Duration,
    /// Rounds a rejoining executor spends on probation (active but
    /// health-gated: another failure sends it straight back down).
    pub probation_rounds: usize,
    /// Event-time processing switch + allowed lateness. `None` (default)
    /// keeps the historical arrival-time semantics byte-for-byte.
    /// `Some(d)` turns on per-source low-watermarks (`max` event time
    /// seen − `d`): window eviction and window-close become
    /// watermark-driven, data older than the watermark is handled per
    /// [`Config::late_policy`], and sliding-window admission force-fires
    /// when the watermark crosses a window-close boundary (Eq. 6's
    /// window term follows watermark progress, not the wall clock).
    pub allowed_lateness: Option<Duration>,
    /// Late-data policy in force when [`Config::allowed_lateness`] is
    /// set.
    pub late_policy: LatePolicy,
    /// Sharded concurrent session runtime: `Some(k)` splits the
    /// registered sources into `k` shards (source `s` → shard `s % k`)
    /// whose round loops stage, plan and execute concurrently on worker
    /// threads, meeting only at the shared per-executor GPU timeline
    /// bank ([`crate::coordinator::timeline_bank`]) and a per-epoch
    /// clock barrier (the clock advances by the max source makespan of
    /// the epoch). Deterministic by construction: sink outputs are
    /// bit-identical across shard counts, including `Some(1)`. `None`
    /// (default) keeps the historical serial round loop byte-for-byte.
    /// Simulated backend only; mutually exclusive with
    /// [`Config::allowed_lateness`] (scope cut — see ARCHITECTURE.md
    /// §Sharded runtime).
    pub shards: Option<usize>,
    /// Per-shard admission quotas, bytes/second of admitted micro-batch
    /// data (a token bucket with a one-second burst allowance per
    /// shard). Eq. 6 bounds *latency*; quotas bound *share*: a shard
    /// over its quota has its batch vetoed back into the admission
    /// buffer and re-offered once tokens refill. Requires
    /// [`Config::shards`] with exactly one positive finite quota per
    /// shard; incompatible with trigger-driven modes (they have no
    /// admission buffer to restore a vetoed batch into).
    pub shard_quotas: Option<Vec<f64>>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            mode: Mode::LmStream,
            backend: ExecBackend::Simulated,
            num_cores: 12,
            num_gpus: 1,
            trigger: Duration::from_secs(10),
            poll_interval: Duration::from_millis(10),
            initial_inflection_bytes: 150.0 * 1024.0,
            base_trans_cost: 0.1,
            initial_throughput: 400.0 * 1024.0,
            online_optimizer: true,
            co_schedule: true,
            history_cap: None,
            seed: 0x1a2b3c4d,
            artifact_dir: "artifacts".to_string(),
            cluster: None,
            checkpoint_dir: None,
            wal_dir: None,
            recovery_mode: crate::durability::RecoveryMode::Precise,
            wal_max_bytes: None,
            fault_plan: None,
            max_round_retries: 3,
            retry_backoff: Duration::from_millis(50),
            failure_detection: Duration::from_millis(100),
            probation_rounds: 2,
            allowed_lateness: None,
            late_policy: LatePolicy::Drop,
            shards: None,
            shard_quotas: None,
        }
    }
}

impl Config {
    /// Validate invariants; call once at startup.
    pub fn validate(&self) -> Result<()> {
        if self.num_cores == 0 {
            return Err(Error::Config("num_cores must be > 0".into()));
        }
        if self.num_gpus == 0 {
            return Err(Error::Config("num_gpus must be > 0".into()));
        }
        if self.trigger.is_zero() {
            return Err(Error::Config("trigger must be > 0".into()));
        }
        if self.poll_interval.is_zero() {
            return Err(Error::Config("poll_interval must be > 0".into()));
        }
        if self.initial_inflection_bytes <= 0.0 {
            return Err(Error::Config("inflection point must be positive".into()));
        }
        if self.initial_throughput <= 0.0 {
            return Err(Error::Config("initial throughput must be positive".into()));
        }
        if let Some(cluster) = &self.cluster {
            cluster.validate()?;
        }
        if self.wal_max_bytes == Some(0) {
            return Err(Error::Config("wal_max_bytes must be > 0 (or None)".into()));
        }
        if let Some(k) = self.shards {
            if k == 0 {
                return Err(Error::Config("shards must be > 0 (or None)".into()));
            }
            if self.allowed_lateness.is_some() {
                return Err(Error::Config(
                    "shards and allowed_lateness are mutually exclusive \
                     (event-time watermarks are not shard-aware yet — see \
                     ARCHITECTURE.md §Sharded runtime)"
                        .into(),
                ));
            }
            if self.backend == ExecBackend::Real {
                return Err(Error::Config(
                    "shards require the Simulated backend (the sharded epoch \
                     clock is deterministic simulated time)"
                        .into(),
                ));
            }
        }
        if let Some(quotas) = &self.shard_quotas {
            let Some(k) = self.shards else {
                return Err(Error::Config(
                    "shard_quotas require shards to be set".into(),
                ));
            };
            if quotas.len() != k {
                return Err(Error::Config(format!(
                    "shard_quotas has {} entries for {} shards",
                    quotas.len(),
                    k
                )));
            }
            if quotas.iter().any(|q| !q.is_finite() || *q <= 0.0) {
                return Err(Error::Config(
                    "every shard quota must be a positive finite bytes/sec \
                     rate"
                        .into(),
                ));
            }
            if self.mode.uses_trigger() {
                return Err(Error::Config(
                    "shard_quotas are incompatible with trigger-driven modes \
                     (no admission buffer to restore a vetoed batch into)"
                        .into(),
                ));
            }
        }
        Ok(())
    }

    /// The device topology a scheduling round plans and executes
    /// against: one executor per cluster entry, or the single-node
    /// 1-executor special case owning `num_cores`/`num_gpus`.
    pub fn topology(&self) -> crate::cluster::DeviceTopology {
        match &self.cluster {
            Some(spec) => crate::cluster::DeviceTopology::from_cluster(spec),
            None => crate::cluster::DeviceTopology::single(self.num_cores, self.num_gpus),
        }
    }

    /// Baseline preset (§IV/§V-A).
    pub fn baseline() -> Self {
        Config { mode: Mode::Baseline, ..Config::default() }
    }

    /// LMStream preset.
    pub fn lmstream() -> Self {
        Config::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn rejects_zero_cores() {
        let cfg = Config { num_cores: 0, ..Config::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_zero_trigger() {
        let cfg = Config { trigger: Duration::ZERO, ..Config::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn topology_mirrors_cluster_or_single_node() {
        let single = Config::default();
        let t = single.topology();
        assert_eq!(t.num_executors(), 1);
        assert_eq!(t.total_cores(), single.num_cores);
        let clustered = Config {
            cluster: Some(crate::cluster::ClusterSpec::paper()),
            ..Config::default()
        };
        assert_eq!(clustered.topology().num_executors(), 4);
        assert_eq!(clustered.topology().total_cores(), 48);
    }

    #[test]
    fn rejects_zero_wal_cap() {
        let cfg = Config { wal_max_bytes: Some(0), ..Config::default() };
        assert!(cfg.validate().is_err());
        let cfg = Config { wal_max_bytes: Some(4096), ..Config::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn fault_plan_config_is_valid() {
        let cfg = Config {
            fault_plan: Some(crate::cluster::FaultPlan::new().crash(2, 1).rejoin(4, 1)),
            cluster: Some(crate::cluster::ClusterSpec::of(3)),
            max_round_retries: 2,
            retry_backoff: Duration::from_millis(10),
            failure_detection: Duration::ZERO,
            ..Config::default()
        };
        cfg.validate().unwrap();
    }

    #[test]
    fn late_policy_parse_round_trip() {
        for (s, p) in [
            ("drop", LatePolicy::Drop),
            ("side-output", LatePolicy::SideOutput),
            ("recompute", LatePolicy::Recompute),
        ] {
            assert_eq!(LatePolicy::parse(s).unwrap(), p);
            assert_eq!(LatePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(LatePolicy::parse("bogus").is_err());
        assert_eq!(LatePolicy::default(), LatePolicy::Drop);
        assert!(Config::default().allowed_lateness.is_none());
    }

    #[test]
    fn shard_config_validation() {
        // Well-formed sharded configs pass.
        let cfg = Config { shards: Some(2), ..Config::default() };
        cfg.validate().unwrap();
        let cfg = Config {
            shards: Some(2),
            shard_quotas: Some(vec![1024.0, 2048.0]),
            ..Config::default()
        };
        cfg.validate().unwrap();
        // Zero shards rejected.
        let cfg = Config { shards: Some(0), ..Config::default() };
        assert!(cfg.validate().is_err());
        // Scope cut: sharding is arrival-time, simulated-backend only.
        let cfg = Config {
            shards: Some(2),
            allowed_lateness: Some(Duration::from_secs(1)),
            ..Config::default()
        };
        assert!(cfg.validate().is_err());
        let cfg = Config {
            shards: Some(2),
            backend: ExecBackend::Real,
            ..Config::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn shard_quota_validation() {
        // Quotas without shards rejected.
        let cfg = Config { shard_quotas: Some(vec![1024.0]), ..Config::default() };
        assert!(cfg.validate().is_err());
        // Length must match the shard count.
        let cfg = Config {
            shards: Some(2),
            shard_quotas: Some(vec![1024.0]),
            ..Config::default()
        };
        assert!(cfg.validate().is_err());
        // Quotas must be positive and finite.
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let cfg = Config {
                shards: Some(1),
                shard_quotas: Some(vec![bad]),
                ..Config::default()
            };
            assert!(cfg.validate().is_err(), "quota {bad} accepted");
        }
        // Trigger modes have no admission buffer to veto into.
        let cfg = Config {
            mode: Mode::Baseline,
            shards: Some(1),
            shard_quotas: Some(vec![1024.0]),
            ..Config::default()
        };
        assert!(cfg.validate().is_err());
        // ...but trigger modes without quotas may shard.
        let cfg = Config { mode: Mode::Baseline, shards: Some(2), ..Config::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn mode_parse_round_trip() {
        for (s, m) in [
            ("lmstream", Mode::LmStream),
            ("baseline", Mode::Baseline),
            ("static", Mode::StaticPreference),
            ("all-gpu", Mode::AllGpu),
            ("all-cpu", Mode::AllCpu),
        ] {
            assert_eq!(Mode::parse(s).unwrap(), m);
        }
        assert!(Mode::parse("bogus").is_err());
    }
}
