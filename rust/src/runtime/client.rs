//! PJRT client wrapper: compile-on-first-use executable cache over the
//! AOT artifacts, plus typed literal marshaling helpers.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Outputs were lowered with
//! `return_tuple=True`, so every execution returns a tuple literal that
//! we decompose.

use crate::error::{Error, Result};
use crate::runtime::artifacts::{ArtifactMeta, Manifest};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// A typed host-side tensor crossing the runtime boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => Err(Error::Schema("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => Err(Error::Schema("expected i32 tensor".into())),
        }
    }

    fn to_literal(&self) -> xla::Literal {
        match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        }
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let ty = lit.ty()?;
        match ty {
            xla::ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?)),
            other => Err(Error::Xla(format!("unsupported output element type {other:?}"))),
        }
    }

    /// Pad (with `pad_f`/`pad_i`) or reject to exactly `n` elements.
    pub fn padded_to(&self, n: usize, pad_f: f32, pad_i: i32) -> Result<HostTensor> {
        if self.len() > n {
            return Err(Error::Artifact(format!(
                "tensor of {} elements exceeds bucket {n}",
                self.len()
            )));
        }
        Ok(match self {
            HostTensor::F32(v) => {
                let mut out = v.clone();
                out.resize(n, pad_f);
                HostTensor::F32(out)
            }
            HostTensor::I32(v) => {
                let mut out = v.clone();
                out.resize(n, pad_i);
                HostTensor::I32(out)
            }
        })
    }
}

/// The process-wide PJRT runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<(String, usize), Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    fn executable(&self, meta: &ArtifactMeta) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let key = (meta.op.clone(), meta.rows);
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(Arc::clone(exe));
        }
        // Compile outside the lock (slow); racing compiles are idempotent.
        let path = meta.file.to_str().ok_or_else(|| {
            Error::Artifact(format!("non-utf8 artifact path {:?}", meta.file))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.cache.lock().unwrap().insert(key, Arc::clone(&exe));
        Ok(exe)
    }

    /// Pre-compile an operator at every bucket (warm-up; keeps compile
    /// jitter off the request path).
    pub fn warm(&self, op: &str) -> Result<usize> {
        let metas: Vec<ArtifactMeta> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.op == op)
            .cloned()
            .collect();
        if metas.is_empty() {
            return Err(Error::Artifact(format!("no artifacts for op `{op}`")));
        }
        for m in &metas {
            self.executable(m)?;
        }
        Ok(metas.len())
    }

    /// Execute `op` at the smallest bucket fitting `rows`, padding every
    /// row-dimension input. Inputs must match the artifact's arity and
    /// dtypes; outputs are truncated back to `rows` where row-shaped.
    pub fn execute(
        &self,
        op: &str,
        rows: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let bucket = self.manifest.bucket_for(rows)?;
        let meta = self.manifest.find(op, bucket)?.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Artifact(format!(
                "{op}: {} inputs given, artifact takes {}",
                inputs.len(),
                meta.inputs.len()
            )));
        }
        // Marshal with padding to the declared shapes.
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&meta.inputs) {
            let want = spec.elements();
            let padded = if t.len() == want {
                t.clone()
            } else {
                t.padded_to(want, 0.0, 0)?
            };
            let dtype_ok = matches!(
                (&padded, spec.dtype.as_str()),
                (HostTensor::F32(_), "float32") | (HostTensor::I32(_), "int32")
            );
            if !dtype_ok {
                return Err(Error::Artifact(format!(
                    "{op}: dtype mismatch (artifact wants {})",
                    spec.dtype
                )));
            }
            literals.push(padded.to_literal());
        }
        let exe = self.executable(&meta)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.iter().zip(&meta.outputs) {
            let mut t = HostTensor::from_literal(lit)?;
            // Row-shaped outputs get truncated back to the live row count.
            if spec.shape == vec![bucket] && rows < bucket {
                t = match t {
                    HostTensor::F32(mut v) => {
                        v.truncate(rows);
                        HostTensor::F32(v)
                    }
                    HostTensor::I32(mut v) => {
                        v.truncate(rows);
                        HostTensor::I32(v)
                    }
                };
            }
            out.push(t);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The xla crate's handles are Rc-based (not Send/Sync), so each test
    // constructs its own Runtime; executables compile on first use only.
    thread_local! {
        static RT: Runtime = {
            let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            Runtime::new(&dir).expect("runtime (run `make artifacts` first)")
        };
    }

    fn with_rt<T>(f: impl FnOnce(&Runtime) -> T) -> T {
        RT.with(|rt| f(rt))
    }

    #[test]
    fn filter_ge_round_trip() {
        with_rt(|rt| {
            let keys = HostTensor::F32(vec![1.0, 5.0, 3.0]);
            let valid = HostTensor::F32(vec![1.0, 1.0, 1.0]);
            let thr = HostTensor::F32(vec![3.0]);
            let out = rt.execute("filter_ge", 3, &[keys, valid, thr]).unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].as_f32().unwrap(), &[0.0, 1.0, 1.0]);
        });
    }

    #[test]
    fn window_aggregate_pallas_kernel_runs() {
        // 5 rows, groups 0/1; the pallas one-hot matmul kernel end-to-end
        // through PJRT.
        with_rt(|rt| {
            let gid = HostTensor::I32(vec![0, 1, 0, 1, 0]);
            let vals = HostTensor::F32(vec![1.0, 10.0, 2.0, 20.0, 3.0]);
            let valid = HostTensor::F32(vec![1.0; 5]);
            let out = rt.execute("window_aggregate", 5, &[gid, vals, valid]).unwrap();
            let sums = out[0].as_f32().unwrap();
            let counts = out[1].as_f32().unwrap();
            assert_eq!(sums[0], 6.0);
            assert_eq!(sums[1], 30.0);
            assert_eq!(counts[0], 3.0);
            assert_eq!(counts[1], 2.0);
            assert!(sums[2..].iter().all(|&s| s == 0.0));
        });
    }

    #[test]
    fn padding_rows_are_inert() {
        // 3 live rows in a 1024 bucket: padded rows must not contribute
        // (their valid mask is 0).
        with_rt(|rt| {
            let gid = HostTensor::I32(vec![7, 7, 7]);
            let vals = HostTensor::F32(vec![1.0, 1.0, 1.0]);
            let valid = HostTensor::F32(vec![1.0, 1.0, 1.0]);
            let out = rt.execute("window_aggregate", 3, &[gid, vals, valid]).unwrap();
            assert_eq!(out[0].as_f32().unwrap()[7], 3.0);
            assert_eq!(out[1].as_f32().unwrap()[7], 3.0);
            assert_eq!(out[1].as_f32().unwrap()[0], 0.0);
        });
    }

    #[test]
    fn executable_cache_hits() {
        with_rt(|rt| {
            let thr = HostTensor::F32(vec![0.0]);
            let k = HostTensor::F32(vec![1.0]);
            let v = HostTensor::F32(vec![1.0]);
            rt.execute("filter_lt", 1, &[k.clone(), v.clone(), thr.clone()]).unwrap();
            let after_first = rt.cached_executables();
            rt.execute("filter_lt", 1, &[k, v, thr]).unwrap();
            assert_eq!(rt.cached_executables(), after_first);
            assert!(after_first >= 1);
        });
    }

    #[test]
    fn arity_mismatch_rejected() {
        with_rt(|rt| {
            let r = rt.execute("filter_ge", 1, &[HostTensor::F32(vec![1.0])]);
            assert!(r.is_err());
        });
    }

    #[test]
    fn dtype_mismatch_rejected() {
        with_rt(|rt| {
            let r = rt.execute(
                "filter_ge",
                1,
                &[
                    HostTensor::I32(vec![1]),
                    HostTensor::F32(vec![1.0]),
                    HostTensor::F32(vec![0.0]),
                ],
            );
            assert!(r.is_err());
        });
    }

    #[test]
    fn join_probe_semantics_via_pjrt() {
        with_rt(|rt| {
            let pk = HostTensor::F32(vec![5.0, 7.0, 9.0]);
            let pv = HostTensor::F32(vec![1.0, 1.0, 1.0]);
            let bk = HostTensor::F32(vec![7.0, 5.0]);
            let bv = HostTensor::F32(vec![1.0, 1.0]);
            let out = rt.execute("join_probe", 3, &[pk, pv, bk, bv]).unwrap();
            assert_eq!(out[0].as_i32().unwrap(), &[1, 0, -1]);
            assert_eq!(out[1].as_f32().unwrap(), &[1.0, 1.0, 0.0]);
        });
    }
}
