//! Artifact manifest: the machine-readable index `python/compile/aot.py`
//! writes next to the HLO files.

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    fn from_json(j: &Json) -> Result<TensorMeta> {
        let shape = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| Error::Json("shape not array".into()))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| Error::Json("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .req("dtype")?
            .as_str()
            .ok_or_else(|| Error::Json("dtype not string".into()))?
            .to_string();
        Ok(TensorMeta { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT-compiled operator at one row bucket.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub op: String,
    pub rows: usize,
    pub file: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub num_groups: usize,
    pub row_buckets: Vec<usize>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let format = j.req("format")?.as_usize().unwrap_or(0);
        if format != 1 {
            return Err(Error::Artifact(format!("unsupported manifest format {format}")));
        }
        let num_groups = j.req("num_groups")?.as_usize().unwrap_or(0);
        let row_buckets: Vec<usize> = j
            .req("row_buckets")?
            .as_arr()
            .ok_or_else(|| Error::Json("row_buckets not array".into()))?
            .iter()
            .filter_map(|b| b.as_usize())
            .collect();
        let mut artifacts = Vec::new();
        for a in j
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Json("artifacts not array".into()))?
        {
            let op = a.req("op")?.as_str().unwrap_or("").to_string();
            let rows = a.req("rows")?.as_usize().unwrap_or(0);
            let file = dir.join(a.req("file")?.as_str().unwrap_or(""));
            let inputs = a
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| Error::Json("inputs not array".into()))?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| Error::Json("outputs not array".into()))?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta { op, rows, file, inputs, outputs });
        }
        if artifacts.is_empty() {
            return Err(Error::Artifact("manifest lists no artifacts".into()));
        }
        let mut m = Manifest { dir: dir.to_path_buf(), num_groups, row_buckets, artifacts };
        m.row_buckets.sort_unstable();
        Ok(m)
    }

    /// Smallest row bucket that fits `rows` (mirrors python `bucket_for`);
    /// errors if nothing fits (callers chunk above the top bucket).
    pub fn bucket_for(&self, rows: usize) -> Result<usize> {
        self.row_buckets
            .iter()
            .copied()
            .find(|&b| rows <= b)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "{rows} rows exceeds largest bucket {:?}",
                    self.row_buckets.last()
                ))
            })
    }

    /// Look up the artifact for (op, bucket). Group-space ops are emitted
    /// at the smallest bucket only; fall back to any single emission.
    pub fn find(&self, op: &str, bucket: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.op == op && a.rows == bucket)
            .or_else(|| {
                let hits: Vec<&ArtifactMeta> =
                    self.artifacts.iter().filter(|a| a.op == op).collect();
                if hits.len() == 1 { Some(hits[0]) } else { None }
            })
            .ok_or_else(|| Error::Artifact(format!("no artifact for {op}@{bucket}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        // Tests run from the crate root; `make artifacts` must have run.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&manifest_dir()).unwrap();
        assert_eq!(m.num_groups, 256);
        assert!(m.row_buckets.contains(&1024));
        assert!(m.artifacts.len() >= 18);
    }

    #[test]
    fn bucket_for_picks_smallest_fit() {
        let m = Manifest::load(&manifest_dir()).unwrap();
        assert_eq!(m.bucket_for(1).unwrap(), 1024);
        assert_eq!(m.bucket_for(1024).unwrap(), 1024);
        assert_eq!(m.bucket_for(1025).unwrap(), 4096);
        assert!(m.bucket_for(10_000_000).is_err());
    }

    #[test]
    fn find_resolves_ops_and_group_space_fallback() {
        let m = Manifest::load(&manifest_dir()).unwrap();
        let a = m.find("filter_ge", 4096).unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![4096]);
        // avg_having_lt is group-space: emitted once, found at any bucket.
        let g = m.find("avg_having_lt", 65536).unwrap();
        assert_eq!(g.inputs[0].shape, vec![256]);
        assert!(m.find("nonexistent_op", 1024).is_err());
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
