//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, lowered
//! once from JAX/Pallas) and executes them on the request path. This is
//! the only boundary between the rust coordinator and the XLA world;
//! python is never involved at runtime.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactMeta, Manifest, TensorMeta};
pub use client::Runtime;
