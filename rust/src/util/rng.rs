//! Deterministic PRNG (xoshiro256**) with uniform / normal / poisson-ish
//! helpers. Replaces the unavailable `rand` crate; seeded everywhere so
//! every experiment in EXPERIMENTS.md is exactly reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fork an independent child stream (for per-thread determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Random boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(3);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
