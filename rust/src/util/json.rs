//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! The reader handles the artifact `manifest.json` emitted by
//! `python/compile/aot.py`; the writer serializes experiment reports.
//! Supports the full JSON grammar except `\u` surrogate pairs (unneeded
//! for our ASCII manifests).

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(Error::Json(format!("trailing bytes at {}", p.pos)));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required-field accessors for manifest parsing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field `{key}`")))
    }

    /// Render as compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out);
        out
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_to(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(Error::Json(format!("expected `{}` at {}", c as char, self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!("unexpected {other:?} at {}", self.pos))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at {}", self.pos)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.src[start..self.pos])
            .map_err(|_| Error::Json("non-utf8 number".into()))?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number `{txt}`")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| {
                                Error::Json("truncated \\u escape".into())
                            })?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| Error::Json("bad hex".into()))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| Error::Json("bad codepoint".into()))?,
                        );
                    }
                    other => return Err(Error::Json(format!("bad escape {other:?}"))),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = std::str::from_utf8(&self.src[start..self.pos])
                        .map_err(|_| Error::Json("bad utf8".into()))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => return Err(Error::Json(format!("bad array sep {other:?}"))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => return Err(Error::Json(format!("bad object sep {other:?}"))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn round_trips_through_render() {
        let src = r#"{"artifacts":[{"file":"x.hlo.txt","rows":1024}],"format":1}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.render()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn string_escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\té".into());
        let j2 = Json::parse(&j.render()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn req_reports_missing_field() {
        let j = Json::parse("{}").unwrap();
        assert!(j.req("nope").is_err());
    }
}
